"""L1 butterfly kernel + L2 FFT graph vs numpy oracles."""

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from compile import kernels, model
from compile.kernels import ref


def _planes(n, seed):
    rng = np.random.default_rng(seed)
    return (
        rng.standard_normal(n).astype(np.float32),
        rng.standard_normal(n).astype(np.float32),
    )


@pytest.mark.parametrize("h", [8, 64, 1024, 4096])
def test_butterfly_matches_ref(h):
    a_re, a_im = _planes(h, 1)
    b_re, b_im = _planes(h, 2)
    w_re, w_im = _planes(h, 3)
    got = [np.asarray(p) for p in kernels.butterfly(a_re, a_im, b_re, b_im, w_re, w_im)]
    want = ref.butterfly(a_re, a_im, b_re, b_im, w_re, w_im)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)


def test_butterfly_block_invariance():
    h = 4096
    args = [*_planes(h, 4), *_planes(h, 5), *_planes(h, 6)]
    a = [np.asarray(p) for p in kernels.butterfly(*args, block=256)]
    b = [np.asarray(p) for p in kernels.butterfly(*args, block=4096)]
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y)


@pytest.mark.parametrize("n", [8, 16, 64, 256, 1024, 4096])
def test_fft_matches_numpy(n):
    x_re, x_im = _planes(n, n)
    got_re, got_im = model.fft(x_re, x_im)
    want = np.fft.fft(x_re.astype(np.float64) + 1j * x_im.astype(np.float64))
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(np.asarray(got_re) / scale, want.real / scale, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_im) / scale, want.imag / scale, atol=2e-4)


def test_fft_impulse_is_flat():
    n = 1024
    x_re = np.zeros(n, np.float32)
    x_re[0] = 1.0
    got_re, got_im = model.fft(x_re, np.zeros(n, np.float32))
    np.testing.assert_allclose(np.asarray(got_re), np.ones(n), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_im), np.zeros(n), atol=1e-5)


def test_fft_rejects_non_pow2():
    with pytest.raises(ValueError):
        model.fft(np.zeros(12, np.float32), np.zeros(12, np.float32))


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(3, 11), seed=st.integers(0, 2**31 - 1))
def test_fft_linearity_hypothesis(logn, seed):
    """FFT(a) + FFT(b) == FFT(a + b) — exercises the whole butterfly cascade."""
    n = 1 << logn
    a_re, a_im = _planes(n, seed)
    b_re, b_im = _planes(n, seed + 1)
    fa = model.fft(a_re, a_im)
    fb = model.fft(b_re, b_im)
    fab = model.fft(a_re + b_re, a_im + b_im)
    np.testing.assert_allclose(
        np.asarray(fab[0]), np.asarray(fa[0]) + np.asarray(fb[0]), rtol=1e-3, atol=1e-3
    )
    np.testing.assert_allclose(
        np.asarray(fab[1]), np.asarray(fa[1]) + np.asarray(fb[1]), rtol=1e-3, atol=1e-3
    )
