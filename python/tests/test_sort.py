"""Compare-exchange kernel + L2 bitonic network vs np.sort."""

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from compile import kernels, model
from compile.kernels import ref


def test_compare_exchange_matches_ref():
    rng = np.random.default_rng(0)
    a = rng.standard_normal(2048).astype(np.float32)
    b = rng.standard_normal(2048).astype(np.float32)
    d = rng.choice(np.array([-1, 1], np.int32), 2048)
    lo, hi = kernels.compare_exchange(a, b, d)
    rlo, rhi = ref.compare_exchange(a, b, d)
    np.testing.assert_array_equal(np.asarray(lo), rlo)
    np.testing.assert_array_equal(np.asarray(hi), rhi)


def test_compare_exchange_direction_semantics():
    a = np.array([3.0, 3.0], np.float32)
    b = np.array([1.0, 1.0], np.float32)
    d = np.array([1, -1], np.int32)
    lo, hi = kernels.compare_exchange(a, b, d)
    assert np.asarray(lo).tolist() == [1.0, 3.0]
    assert np.asarray(hi).tolist() == [3.0, 1.0]


@pytest.mark.parametrize("n", [8, 64, 256, 1024])
def test_bitonic_sorts(n):
    rng = np.random.default_rng(n)
    x = rng.standard_normal(n).astype(np.float32)
    (got,) = model.bitonic_sort(x)
    np.testing.assert_array_equal(np.asarray(got), np.sort(x))


def test_bitonic_sorted_input():
    x = np.arange(256, dtype=np.float32)
    (got,) = model.bitonic_sort(x)
    np.testing.assert_array_equal(np.asarray(got), x)


def test_bitonic_reverse_input():
    x = np.arange(256, dtype=np.float32)[::-1].copy()
    (got,) = model.bitonic_sort(x)
    np.testing.assert_array_equal(np.asarray(got), np.sort(x))


def test_bitonic_rejects_non_pow2():
    with pytest.raises(ValueError):
        model.bitonic_sort(np.zeros(100, np.float32))


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(2, 10), seed=st.integers(0, 2**31 - 1))
def test_bitonic_hypothesis(logn, seed):
    n = 1 << logn
    x = np.random.default_rng(seed).integers(-1000, 1000, n).astype(np.float32)
    (got,) = model.bitonic_sort(x)
    np.testing.assert_array_equal(np.asarray(got), np.sort(x))
