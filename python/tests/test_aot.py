"""AOT export sanity: every artifact lowers to parseable HLO text."""

import json
import os

import numpy as np
import pytest

import jax

from compile import aot


@pytest.mark.parametrize("name", sorted(aot.EXPORTS))
def test_export_lowers_to_hlo_text(tmp_path, name):
    entry = aot.export_one(name, str(tmp_path))
    path = tmp_path / f"{name}.hlo.txt"
    text = path.read_text()
    assert len(text) == entry["hlo_bytes"]
    assert "ENTRY" in text, "HLO text missing ENTRY computation"
    assert "HloModule" in text
    # the interchange contract: text, never a serialized proto
    assert text.lstrip().startswith("HloModule")


def test_manifest_covers_all_exports(tmp_path):
    import subprocess
    import sys

    # run the module as `make artifacts` does
    env = dict(os.environ)
    pydir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
         "--only", "priority_f32_16,lu0_f32_64"],
        cwd=pydir,
        check=True,
        capture_output=True,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"priority_f32_16", "lu0_f32_64"}
    for a in manifest["artifacts"]:
        assert (tmp_path / f"{a['name']}.hlo.txt").exists()


def test_export_signatures_match_eval_shape(tmp_path):
    entry = aot.export_one("fft_f32_1024", str(tmp_path))
    assert entry["inputs"] == [
        {"shape": [1024], "dtype": "float32"},
        {"shape": [1024], "dtype": "float32"},
    ]
    assert entry["outputs"] == entry["inputs"]
