"""Priority kernel (paper Figs 2-4) vs the straight-line pseudo-code oracle."""

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def ladder8_hops():
    """Twisted-ladder 8-node hop matrix (the X4600 model; see DESIGN.md §2)."""
    edges = [(0, 1), (6, 7), (0, 2), (2, 4), (4, 6), (1, 3), (3, 5), (5, 7), (2, 5), (3, 4)]
    n = 8
    inf = 99
    d = np.full((n, n), inf)
    np.fill_diagonal(d, 0)
    for a, b in edges:
        d[a, b] = d[b, a] = 1
    for k in range(n):  # Floyd-Warshall
        d = np.minimum(d, d[:, k : k + 1] + d[k : k + 1, :])
    return d.astype(np.int32)


def core_hops(node_hops, cores_per_node):
    """Expand a node hop matrix to per-core (cores on one node: 0 hops)."""
    n = node_hops.shape[0]
    reps = np.repeat(np.arange(n), cores_per_node)
    return node_hops[np.ix_(reps, reps)].astype(np.int32)


def alpha_weights(maxh=8, a0=16.0, decay=0.5):
    return (a0 * decay ** np.arange(maxh)).astype(np.float32)


@pytest.mark.parametrize("cores_per_node", [1, 2, 4])
def test_priority_matches_pseudocode(cores_per_node):
    hops = core_hops(ladder8_hops(), cores_per_node)
    n = hops.shape[0]
    alpha = alpha_weights()
    base = np.full(n, float(cores_per_node), np.float32)
    a = ref.weighted_hop_matrix(hops, alpha)
    want_p1, want_p = ref.priority_scores(a, base)
    got_p1, got_p = model.priority_scores(hops, alpha, base.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got_p1), want_p1, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_p), want_p, rtol=2e-4)


def test_central_nodes_win_on_ladder():
    """Paper §IV: on an asymmetric fabric the central nodes must out-rank
    the corners — that is the whole point of the allocation scheme."""
    hops = core_hops(ladder8_hops(), 2)
    alpha = alpha_weights()
    base = np.full(16, 2.0, np.float32)
    _, p = model.priority_scores(hops, alpha, base)
    p = np.asarray(p)
    corner_cores = [0, 1, 2, 3, 12, 13, 14, 15]  # nodes 0,1,6,7
    central_cores = [4, 5, 6, 7, 8, 9, 10, 11]  # nodes 2,3,4,5
    assert p[central_cores].min() > p[corner_cores].max()


def test_same_node_cores_equal_priority():
    hops = core_hops(ladder8_hops(), 2)
    _, p = model.priority_scores(hops, alpha_weights(), np.full(16, 2.0, np.float32))
    p = np.asarray(p)
    for node in range(8):
        assert p[2 * node] == pytest.approx(p[2 * node + 1], rel=1e-6)


def test_uniform_topology_uniform_priority():
    """Fully-connected (all 1 hop): every core must get the same priority."""
    n = 8
    hops = np.ones((n, n), np.int32) - np.eye(n, dtype=np.int32)
    hops = np.where(np.eye(n, dtype=bool), 0, 1).astype(np.int32)
    _, p = model.priority_scores(hops, alpha_weights(), np.full(n, 1.0, np.float32))
    p = np.asarray(p)
    np.testing.assert_allclose(p, p[0], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([4, 8, 16]))
def test_priority_hypothesis_random_topology(seed, n):
    """Random connected graphs: kernel == pseudo-code oracle."""
    rng = np.random.default_rng(seed)
    inf = 99
    d = np.full((n, n), inf)
    np.fill_diagonal(d, 0)
    # random spanning chain + extra edges => connected
    perm = rng.permutation(n)
    for i in range(n - 1):
        a, b = perm[i], perm[i + 1]
        d[a, b] = d[b, a] = 1
    for _ in range(n):
        a, b = rng.integers(0, n, 2)
        if a != b:
            d[a, b] = d[b, a] = 1
    for k in range(n):
        d = np.minimum(d, d[:, k : k + 1] + d[k : k + 1, :])
    hops = d.astype(np.int32)
    alpha = alpha_weights()
    base = rng.uniform(0, 4, n).astype(np.float32)
    a = ref.weighted_hop_matrix(hops, alpha)
    want_p1, want_p = ref.priority_scores(a, base)
    got_p1, got_p = model.priority_scores(hops, alpha, base)
    np.testing.assert_allclose(np.asarray(got_p1), want_p1, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_p), want_p, rtol=1e-3, atol=1e-3)
