"""L1 matmul_tile kernel vs pure-numpy oracle."""

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 128, 128),
        (256, 256, 256),
        (256, 128, 384),
        (128, 384, 128),
        (512, 128, 256),
    ],
)
def test_matmul_matches_ref(m, k, n):
    x, y = _rand((m, k), m * 3 + k), _rand((k, n), n)
    got = np.asarray(kernels.matmul(x, y))
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bm,bk,bn", [(64, 64, 64), (128, 64, 32), (32, 128, 128)])
def test_matmul_tile_shape_invariance(bm, bk, bn):
    """Result must not depend on the VMEM tiling."""
    x, y = _rand((256, 256), 7), _rand((256, 256), 8)
    base = np.asarray(kernels.matmul(x, y))
    # K-tiling changes the accumulation order => fp noise, not error
    tiled = np.asarray(kernels.matmul(x, y, bm=bm, bk=bk, bn=bn))
    np.testing.assert_allclose(tiled, base, rtol=2e-3, atol=1e-4)


def test_matmul_rejects_mismatch():
    with pytest.raises(ValueError):
        kernels.matmul(np.zeros((128, 128), np.float32), np.zeros((256, 128), np.float32))


def test_matmul_rejects_non_multiple():
    with pytest.raises(ValueError):
        kernels.matmul(np.zeros((100, 128), np.float32), np.zeros((128, 128), np.float32), bm=64)


@settings(max_examples=20, deadline=None)
@given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    ni=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(mi, ki, ni, seed):
    """Hypothesis sweep over tile-multiple shapes."""
    m, k, n = 64 * mi, 64 * ki, 64 * ni
    x, y = _rand((m, k), seed), _rand((k, n), seed + 1)
    got = np.asarray(kernels.matmul(x, y, bm=64, bk=64, bn=64))
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=3e-4, atol=3e-4)


def test_strassen_combine_equals_matmul():
    """One level of Strassen recombination == plain matmul."""
    from compile import model

    rng = np.random.default_rng(42)
    n = 128
    x = rng.standard_normal((2 * n, 2 * n)).astype(np.float32)
    y = rng.standard_normal((2 * n, 2 * n)).astype(np.float32)
    a11, a12, a21, a22 = x[:n, :n], x[:n, n:], x[n:, :n], x[n:, n:]
    b11, b12, b21, b22 = y[:n, :n], y[:n, n:], y[n:, :n], y[n:, n:]
    mm = lambda a, b: np.asarray(kernels.matmul(a, b))
    m1 = mm(a11 + a22, b11 + b22)
    m2 = mm(a21 + a22, b11)
    m3 = mm(a11, b12 - b22)
    m4 = mm(a22, b21 - b11)
    m5 = mm(a11 + a12, b22)
    m6 = mm(a21 - a11, b11 + b12)
    m7 = mm(a12 - a22, b21 + b22)
    (got,) = model.strassen_combine(m1, m2, m3, m4, m5, m6, m7)
    np.testing.assert_allclose(np.asarray(got), x @ y, rtol=1e-3, atol=1e-3)
