import os
import sys

# Allow `python -m pytest tests/` from the python/ directory and
# `pytest python/tests/` from the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
