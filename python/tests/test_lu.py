"""SparseLU block kernels vs numpy oracles (lu0 / fwd / bdiv / bmod)."""

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref


def _spd_block(n, seed):
    """Diagonally-dominant block so pivot-free LU is stable (as in BOTS)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return a + n * np.eye(n, dtype=np.float32)


@pytest.mark.parametrize("n", [4, 16, 64, 128])
def test_lu0_matches_ref(n):
    a = _spd_block(n, n)
    got = np.asarray(kernels.lu0(a))
    np.testing.assert_allclose(got, ref.lu0(a), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [4, 16, 64])
def test_lu0_reconstructs(n):
    a = _spd_block(n, seed=n + 1)
    packed = np.asarray(kernels.lu0(a), dtype=np.float64)
    l, u = ref.unpack_lu(packed)
    np.testing.assert_allclose(l @ u, a, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [16, 64])
def test_fwd_matches_ref(n):
    diag = np.asarray(kernels.lu0(_spd_block(n, 3)))
    b = np.random.default_rng(4).standard_normal((n, n)).astype(np.float32)
    got = np.asarray(kernels.fwd(diag, b))
    np.testing.assert_allclose(got, ref.fwd(diag, b), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [16, 64])
def test_bdiv_matches_ref(n):
    diag = np.asarray(kernels.lu0(_spd_block(n, 5)))
    b = np.random.default_rng(6).standard_normal((n, n)).astype(np.float32)
    got = np.asarray(kernels.bdiv(diag, b))
    np.testing.assert_allclose(got, ref.bdiv(diag, b), rtol=1e-3, atol=1e-3)


def test_bmod_matches_ref():
    rng = np.random.default_rng(7)
    a, b, c = (rng.standard_normal((64, 64)).astype(np.float32) for _ in range(3))
    got = np.asarray(kernels.bmod(a, b, c))
    np.testing.assert_allclose(got, ref.bmod(a, b, c), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 2**31 - 1))
def test_blocked_lu_solves_system_hypothesis(n, seed):
    """Full 2x2-block LU using all four kernels factorizes correctly."""
    rng = np.random.default_rng(seed)
    blocks = {}
    for i in range(2):
        for j in range(2):
            blk = rng.standard_normal((n, n)).astype(np.float32)
            if i == j:
                blk += 2 * n * np.eye(n, dtype=np.float32)
            blocks[i, j] = blk
    a_full = np.block([[blocks[0, 0], blocks[0, 1]], [blocks[1, 0], blocks[1, 1]]])

    d00 = np.asarray(kernels.lu0(blocks[0, 0]))
    u01 = np.asarray(kernels.fwd(d00, blocks[0, 1]))
    l10 = np.asarray(kernels.bdiv(d00, blocks[1, 0]))
    s11 = np.asarray(kernels.bmod(l10, u01, blocks[1, 1]))
    d11 = np.asarray(kernels.lu0(s11))

    l00, u00 = ref.unpack_lu(np.asarray(d00, dtype=np.float64))
    l11, u11 = ref.unpack_lu(np.asarray(d11, dtype=np.float64))
    zero = np.zeros((n, n))
    l_full = np.block([[l00, zero], [l10.astype(np.float64), l11]])
    u_full = np.block([[u00, u01.astype(np.float64)], [zero, u11]])
    rel = np.abs(l_full @ u_full - a_full).max() / np.abs(a_full).max()
    assert rel < 5e-3, f"blocked LU residual too large: {rel}"
