"""Layer-2 JAX compute graphs — the BOTS leaf computations, composed from
Layer-1 Pallas kernels.

Every public function here is AOT-lowered by :mod:`compile.aot` to an HLO
text artifact that the Rust coordinator loads through PJRT and invokes from
task bodies (``--compute pjrt``).  Shapes are static per artifact; the
exported variants are listed in :data:`compile.aot.EXPORTS`.

Data movement (bit-reversal, bitonic regrouping, weight gathers) stays in
the XLA graph where the compiler fuses it; the arithmetic hot loops are the
Pallas kernels.  See DESIGN.md §3/§4.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from compile import kernels


# ---------------------------------------------------------------------------
# Strassen leaf
# ---------------------------------------------------------------------------

def strassen_leaf(x: jax.Array, y: jax.Array) -> tuple[jax.Array]:
    """Leaf matmul of the Strassen recursion (MXU-tiled Pallas matmul)."""
    return (kernels.matmul(x, y),)


def strassen_combine(m1, m2, m3, m4, m5, m6, m7) -> tuple[jax.Array]:
    """Winograd/Strassen quadrant recombination (pure adds, L2-only glue).

    C11 = M1 + M4 - M5 + M7        C12 = M3 + M5
    C21 = M2 + M4                  C22 = M1 - M2 + M3 + M6
    """
    c11 = m1 + m4 - m5 + m7
    c12 = m3 + m5
    c21 = m2 + m4
    c22 = m1 - m2 + m3 + m6
    top = jnp.concatenate([c11, c12], axis=1)
    bot = jnp.concatenate([c21, c22], axis=1)
    return (jnp.concatenate([top, bot], axis=0),)


# ---------------------------------------------------------------------------
# FFT (iterative Cooley-Tukey DIT over the Pallas butterfly kernel)
# ---------------------------------------------------------------------------

def _bit_reverse_perm(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def _bit_reverse(x: jax.Array) -> jax.Array:
    """Bit-reversal permutation as a rank-log2(n) transpose.

    Viewing the vector as a [2]*b tensor and reversing the axis order *is*
    the bit-reversal permutation — no gather involved.  (The xla_extension
    0.5.1 runtime the Rust side links against miscompiles gathers fused
    into downstream reshapes, so the exported graphs avoid gather
    entirely; see DESIGN.md §7.)
    """
    (n,) = x.shape
    bits = n.bit_length() - 1
    t = x.reshape((2,) * bits)
    return t.transpose(tuple(reversed(range(bits)))).reshape(n)


def fft(x_re: jax.Array, x_im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Forward DFT of a power-of-two signal as two f32 planes."""
    (n,) = x_re.shape
    if n & (n - 1):
        raise ValueError(f"fft length must be a power of two, got {n}")
    re = _bit_reverse(x_re)
    im = _bit_reverse(x_im)
    stages = n.bit_length() - 1
    for s in range(1, stages + 1):
        m = 1 << s
        half = m >> 1
        groups = n // m
        # group-major layout: a = X[:, :half], b = X[:, half:]
        re2 = re.reshape(groups, m)
        im2 = im.reshape(groups, m)
        a_re = re2[:, :half].reshape(-1)
        a_im = im2[:, :half].reshape(-1)
        b_re = re2[:, half:].reshape(-1)
        b_im = im2[:, half:].reshape(-1)
        w = np.exp(-2j * np.pi * np.arange(half) / m).astype(np.complex64)
        w_re = jnp.asarray(np.tile(w.real, groups))
        w_im = jnp.asarray(np.tile(w.imag, groups))
        t_re, t_im, u_re, u_im = kernels.butterfly(a_re, a_im, b_re, b_im, w_re, w_im)
        re = jnp.concatenate(
            [t_re.reshape(groups, half), u_re.reshape(groups, half)], axis=1
        ).reshape(n)
        im = jnp.concatenate(
            [t_im.reshape(groups, half), u_im.reshape(groups, half)], axis=1
        ).reshape(n)
    return re, im


# ---------------------------------------------------------------------------
# Bitonic sort (static network over the compare-exchange kernel)
# ---------------------------------------------------------------------------

def bitonic_sort(x: jax.Array) -> tuple[jax.Array]:
    """Ascending sort of a power-of-two key vector via a bitonic network.

    Scatter-free formulation: every stage gathers each lane's partner
    (``i ^ j``, a static permutation XLA fuses) and keeps either the min or
    the max depending on the lane's role — lane ``i`` with ``i & j == 0``
    holds the "low" slot of its pair.  The arithmetic hot loop (min/max
    select) is the Pallas ``compare_exchange`` kernel; its ``lo`` output is
    exactly "min if ascending-low slot else max".  (The old xla_extension
    0.5.1 runtime the Rust side links against mis-executes the scatter this
    network would otherwise need — see DESIGN.md §7.)
    """
    (n,) = x.shape
    if n & (n - 1):
        raise ValueError(f"bitonic length must be a power of two, got {n}")
    idx = np.arange(n)
    out = jnp.asarray(x)
    k = 2
    while k <= n:
        j = k >> 1
        while j >= 1:
            is_low = (idx & j) == 0
            ascending = (idx & k) == 0
            # low slot of an ascending pair keeps the min; so does the
            # high slot of a descending pair.
            take_min = is_low == ascending
            direction = np.where(take_min, 1, -1).astype(np.int32)
            # partner (i ^ j) exchange, gather-free: swap the two j-sized
            # halves of every 2j block (explicit slice + concat — the old
            # runtime also miscompiles reverse over degenerate dims)
            blocks = out.reshape(-1, 2, j)
            xp = jnp.concatenate(
                [blocks[:, 1:2, :], blocks[:, 0:1, :]], axis=1
            ).reshape(n)
            lo, _hi = kernels.compare_exchange(out, xp, jnp.asarray(direction))
            out = lo
            j >>= 1
        k <<= 1
    return (out,)


# ---------------------------------------------------------------------------
# SparseLU block steps (direct kernel exports)
# ---------------------------------------------------------------------------

def sparselu_lu0(a):
    return (kernels.lu0(a),)


def sparselu_fwd(diag, b):
    return (kernels.fwd(diag, b),)


def sparselu_bdiv(diag, b):
    return (kernels.bdiv(diag, b),)


def sparselu_bmod(a, b, c):
    return (kernels.bmod(a, b, c),)


# ---------------------------------------------------------------------------
# Priority scores (paper Figs 2-4)
# ---------------------------------------------------------------------------

def priority_scores(hops: jax.Array, alpha: jax.Array, base: jax.Array):
    """Two-level core priorities from a hop matrix.

    ``hops``  (n, n) int32 — pairwise node hop distances per core.
    ``alpha`` (H,)   f32   — decreasing weight per hop distance (padded).
    ``base``  (n,)   f32   — first-level base priority (node-size rank).

    Returns ``(P1, P)``: after the Fig-2 pass and after the Fig-3 pass.
    """
    a = jnp.take(alpha, hops)  # A[i,j] = alpha[hops[i,j]]
    n = hops.shape[0]
    a = a * (1.0 - jnp.eye(n, dtype=a.dtype))  # self excluded
    p1, p = kernels.priority_scores(a, base)
    return p1, p
