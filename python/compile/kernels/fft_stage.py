"""Radix-2 butterfly Pallas kernel — the FFT benchmark payload.

The BOTS FFT is a cache-oblivious Cooley-Tukey; its hot loop is the
butterfly: ``t = w * b; top = a + t; bot = a - t`` over complex operands.

TPU mapping (DESIGN.md §4): Mosaic has no complex dtype, so complex values
travel as separate real/imaginary f32 planes (VPU-friendly, stride-1).  The
inter-stage shuffles (bit-reversal, stride regrouping) are *data movement*
and stay in the L2 XLA graph where the compiler fuses them; the kernel owns
the arithmetic hot loop, blocked in VMEM-sized chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _butterfly_kernel(are_ref, aim_ref, bre_ref, bim_ref, wre_ref, wim_ref,
                      tre_ref, tim_ref, ure_ref, uim_ref):
    a_re, a_im = are_ref[...], aim_ref[...]
    b_re, b_im = bre_ref[...], bim_ref[...]
    w_re, w_im = wre_ref[...], wim_ref[...]
    # t = w * b   (complex multiply on f32 planes)
    t_re = w_re * b_re - w_im * b_im
    t_im = w_re * b_im + w_im * b_re
    tre_ref[...] = a_re + t_re
    tim_ref[...] = a_im + t_im
    ure_ref[...] = a_re - t_re
    uim_ref[...] = a_im - t_im


@functools.partial(jax.jit, static_argnames=("block",))
def butterfly(a_re, a_im, b_re, b_im, w_re, w_im, *, block: int = 1024):
    """Vector butterfly over flat (h,) planes: returns (a+wb, a-wb) planes."""
    (h,) = a_re.shape
    blk = min(block, h)
    if h % blk:
        raise ValueError(f"butterfly length {h} not a multiple of block {blk}")
    grid = (h // blk,)
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((h,), a_re.dtype)
    return pl.pallas_call(
        _butterfly_kernel,
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=[spec] * 4,
        out_shape=[out] * 4,
        interpret=True,
    )(a_re, a_im, b_re, b_im, w_re, w_im)
