"""Blocked matmul Pallas kernel — the Strassen leaf / SparseLU ``bmod`` payload.

TPU mapping (DESIGN.md §4): the BOTS C code blocks for L1/L2 caches; here the
``BlockSpec`` grid expresses the same HBM->VMEM schedule with MXU-aligned
tiles.  The K axis is the innermost grid dimension so the output tile stays
resident in VMEM across the accumulation (``o_ref`` is revisited, classic
Pallas accumulation idiom).

VMEM footprint per grid step = bm*bk + bk*bn + bm*bn floats; with the default
128x128x128 tiles that is 3 * 64 KiB = 192 KiB, far under the ~16 MiB VMEM
budget, leaving room for double buffering by the Mosaic pipeliner.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ y[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bk: int = 128, bn: int = 128):
    """``x @ y`` via a Pallas grid of MXU tiles.

    Shapes must be multiples of the tile sizes (the L2 model pads when a
    benchmark leaf is smaller); dtype follows ``x``.
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    if m % bm or k % bk or n % bn:
        raise ValueError(f"shapes {x.shape}x{y.shape} not multiples of tile ({bm},{bk},{bn})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)
