"""Pure-jnp / numpy oracles for every Layer-1 kernel.

These are the CORE correctness signal: each Pallas kernel must match its
reference bit-for-fp-tolerance under the pytest sweeps in
``python/tests/``.  Written in the most obvious possible style — no tiling,
no cleverness — so a reviewer can audit them against the BOTS C sources.
"""

from __future__ import annotations

import numpy as np


def matmul(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    return np.asarray(x) @ np.asarray(y)


def butterfly(a_re, a_im, b_re, b_im, w_re, w_im):
    """t = w*b; return (a+t, a-t) as four planes."""
    a = np.asarray(a_re) + 1j * np.asarray(a_im)
    b = np.asarray(b_re) + 1j * np.asarray(b_im)
    w = np.asarray(w_re) + 1j * np.asarray(w_im)
    t = w * b
    top, bot = a + t, a - t
    return top.real, top.imag, bot.real, bot.imag


def lu0(a: np.ndarray) -> np.ndarray:
    """Doolittle LU without pivoting, packed (unit lower implicit)."""
    a = np.array(a, dtype=np.float64, copy=True)
    n = a.shape[0]
    for k in range(n):
        a[k + 1 :, k] /= a[k, k]
        a[k + 1 :, k + 1 :] -= np.outer(a[k + 1 :, k], a[k, k + 1 :])
    return a


def unpack_lu(packed: np.ndarray):
    """Split a packed LU block into (L, U) with unit diagonal L."""
    l = np.tril(packed, -1) + np.eye(packed.shape[0])
    u = np.triu(packed)
    return l, u


def fwd(diag_packed: np.ndarray, b: np.ndarray) -> np.ndarray:
    l, _ = unpack_lu(np.asarray(diag_packed, dtype=np.float64))
    return np.linalg.solve(l, np.asarray(b, dtype=np.float64))


def bdiv(diag_packed: np.ndarray, b: np.ndarray) -> np.ndarray:
    _, u = unpack_lu(np.asarray(diag_packed, dtype=np.float64))
    # solve X @ U = B  =>  X = B @ inv(U)
    return np.linalg.solve(u.T, np.asarray(b, dtype=np.float64).T).T


def bmod(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> np.ndarray:
    return np.asarray(c) - np.asarray(a) @ np.asarray(b)


def compare_exchange(a, b, direction):
    a, b, d = map(np.asarray, (a, b, direction))
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    return np.where(d > 0, lo, hi), np.where(d > 0, hi, lo)


def fft(x: np.ndarray) -> np.ndarray:
    return np.fft.fft(np.asarray(x))


def bitonic_sort(x: np.ndarray) -> np.ndarray:
    return np.sort(np.asarray(x))


def priority_scores(a: np.ndarray, base: np.ndarray):
    """Figs 2-4 as written in the paper's pseudo-code (two sequential passes)."""
    a = np.asarray(a, dtype=np.float64)
    base = np.asarray(base, dtype=np.float64)
    n = a.shape[0]
    p1 = np.zeros(n)
    for i in range(n):  # Fig 2: first level, weighted neighbour counts
        p1[i] = base[i] + sum(a[i, j] for j in range(n))
    p = np.zeros(n)
    for i in range(n):  # Fig 3: second level, weighted neighbour priorities
        p[i] = p1[i] + sum(a[i, j] * p1[j] for j in range(n))
    return p1, p


def weighted_hop_matrix(hops: np.ndarray, alpha: np.ndarray) -> np.ndarray:
    """A[i,j] = alpha[hops[i,j]] with zeroed diagonal (self excluded)."""
    hops = np.asarray(hops)
    a = np.asarray(alpha, dtype=np.float64)[hops]
    np.fill_diagonal(a, 0.0)
    return a
