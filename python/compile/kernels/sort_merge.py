"""Bitonic compare-exchange Pallas kernel — the Sort benchmark payload.

BOTS Sort is a cache-oblivious mergesort whose leaves fall back to a
sequential sort.  A data-dependent merge does not map to a systolic array,
so per DESIGN.md §4 we *rethink* the leaf for the TPU: a bitonic sorting
network, whose compare-exchange stages are branch-free, stride-regular VPU
work.  The inter-stage regrouping (static slices) lives in the L2 graph;
this kernel owns the min/max hot loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmpx_kernel(a_ref, b_ref, d_ref, lo_ref, hi_ref):
    a, b = a_ref[...], b_ref[...]
    direction = d_ref[...]  # +1 ascending pair, -1 descending pair
    lo = jnp.minimum(a, b)
    hi = jnp.maximum(a, b)
    lo_ref[...] = jnp.where(direction > 0, lo, hi)
    hi_ref[...] = jnp.where(direction > 0, hi, lo)


@functools.partial(jax.jit, static_argnames=("block",))
def compare_exchange(a, b, direction, *, block: int = 2048):
    """Elementwise compare-exchange of two key planes.

    ``direction`` (+1/-1 per lane) encodes the ascending/descending region of
    the bitonic network so a whole stage is a single kernel launch.
    """
    (h,) = a.shape
    blk = min(block, h)
    if h % blk:
        raise ValueError(f"length {h} not a multiple of block {blk}")
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((h,), a.dtype)
    return pl.pallas_call(
        _cmpx_kernel,
        grid=(h // blk,),
        in_specs=[spec] * 3,
        out_specs=[spec] * 2,
        out_shape=[out] * 2,
        interpret=True,
    )(a, b, direction)
