"""SparseLU block kernels: ``lu0``, ``fwd``, ``bdiv``, ``bmod``.

These are the four task payloads of the BOTS SparseLU benchmark (blocked,
pivot-free LU over a sparse block matrix):

* ``lu0``  — in-place Doolittle LU of a diagonal block (unit lower L).
* ``fwd``  — forward substitution: ``B := L(diag)^-1 @ B``.
* ``bdiv`` — backward division:   ``B := B @ U(diag)^-1``.
* ``bmod`` — trailing update:     ``C := C - A @ B``.

TPU mapping: each block fits a single VMEM tile (block size <= 128), so each
kernel is a one-tile ``pallas_call``; the sequential k-loop of the
factorizations runs as a ``fori_loop`` over in-register values.  ``bmod`` is
the MXU matmul plus subtraction fused in one kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _single_tile(kernel, nout, shape, dtype):
    out = jax.ShapeDtypeStruct(shape, dtype)
    return pl.pallas_call(
        kernel,
        out_shape=[out] * nout if nout > 1 else out,
        interpret=True,
    )


def _lu0_kernel(a_ref, o_ref):
    a = a_ref[...]
    n = a.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def body(k, a):
        pivot = a[k, k]
        lmask = rows > k
        umask = rows > k  # column mask over a[k, :]
        l = jnp.where(lmask, a[:, k] / pivot, 0.0)
        u = jnp.where(umask, a[k, :], 0.0)
        a = a - jnp.outer(l, u)
        # store the multipliers in the strictly-lower part (Doolittle)
        a = a.at[:, k].set(jnp.where(lmask, l, a[:, k]))
        return a

    o_ref[...] = jax.lax.fori_loop(0, n, body, a)


def lu0(a: jax.Array) -> jax.Array:
    """LU-factorize a square block in place (no pivoting, unit lower L)."""
    n = a.shape[0]
    if a.shape != (n, n):
        raise ValueError(f"lu0 expects a square block, got {a.shape}")
    return _single_tile(_lu0_kernel, 1, (n, n), a.dtype)(a)


def _fwd_kernel(diag_ref, b_ref, o_ref):
    lu = diag_ref[...]
    b = b_ref[...]
    n = lu.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def body(k, b):
        # rows below k: b[i, :] -= L[i, k] * b[k, :]
        l = jnp.where(rows > k, lu[:, k], 0.0)
        return b - jnp.outer(l, b[k, :])

    o_ref[...] = jax.lax.fori_loop(0, n, body, b)


def fwd(diag: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L @ X = B for X, with L the unit-lower factor packed in ``diag``."""
    return _single_tile(_fwd_kernel, 1, b.shape, b.dtype)(diag, b)


def _bdiv_kernel(diag_ref, b_ref, o_ref):
    lu = diag_ref[...]
    b = b_ref[...]
    n = lu.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def body(k, b):
        colk = b[:, k] / lu[k, k]
        b = b.at[:, k].set(colk)
        # columns beyond k: b[:, j] -= colk * U[k, j]
        u = jnp.where(cols > k, lu[k, :], 0.0)
        return b - jnp.outer(colk, u)

    o_ref[...] = jax.lax.fori_loop(0, n, body, b)


def bdiv(diag: jax.Array, b: jax.Array) -> jax.Array:
    """Solve X @ U = B for X, with U the upper factor packed in ``diag``."""
    return _single_tile(_bdiv_kernel, 1, b.shape, b.dtype)(diag, b)


def _bmod_kernel(a_ref, b_ref, c_ref, o_ref):
    o_ref[...] = c_ref[...] - jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def bmod(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """Trailing-block update ``C - A @ B`` (fused MXU matmul + subtract)."""
    return _single_tile(_bmod_kernel, 1, c.shape, c.dtype)(a, b, c)
