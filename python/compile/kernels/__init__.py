"""Layer-1 Pallas kernels for the numanos reproduction.

Each kernel is the numeric hot-spot of one BOTS compute leaf (the task
payloads the paper's schedulers move around), expressed for the TPU MXU/VPU
and lowered with ``interpret=True`` so the CPU PJRT client can run the
resulting HLO (real-TPU Mosaic custom-calls are compile-only targets here;
see DESIGN.md §4).

Correctness oracle for every kernel lives in :mod:`compile.kernels.ref`.
"""

from compile.kernels.matmul_tile import matmul
from compile.kernels.fft_stage import butterfly
from compile.kernels.lu_block import lu0, fwd, bdiv, bmod
from compile.kernels.sort_merge import compare_exchange
from compile.kernels.priority import priority_scores

__all__ = [
    "matmul",
    "butterfly",
    "lu0",
    "fwd",
    "bdiv",
    "bmod",
    "compare_exchange",
    "priority_scores",
]
