"""Priority-score Pallas kernel — the paper's Figs 2-4 allocation math.

The coordinator's ``set_priorities`` (paper §IV) is itself a dense linear
computation once the hop-count matrix is materialized:

*  ``A[i, j] = alpha[hops(i, j)]`` for ``j != i`` (weight lookup, done in the
   L2 graph where XLA gathers are cheap), ``A[i, i] = 0``;
*  first level  (Fig 2): ``P1 = base + A @ 1``          (weighted neighbour count)
*  second level (Fig 3): ``P  = P1  + A @ P1``          (weighted neighbour priority)

so the whole two-pass algorithm of Fig 4 is one matvec pair — a natural MXU
payload.  The Rust coordinator ships the same math in pure Rust and, when the
PJRT engine is enabled, cross-checks it against this artifact (L3<->L1
integration test of the three-layer stack).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _priority_kernel(a_ref, base_ref, p1_ref, p_ref):
    a = a_ref[...]
    base = base_ref[...]
    p1 = base + jnp.sum(a, axis=1)
    p1_ref[...] = p1
    p_ref[...] = p1 + jnp.dot(a, p1[:, None], preferred_element_type=a.dtype)[:, 0]


def priority_scores(a: jax.Array, base: jax.Array):
    """Return ``(P1, P)`` per Figs 2-4 given the weighted hop matrix ``A``."""
    n = a.shape[0]
    if a.shape != (n, n) or base.shape != (n,):
        raise ValueError(f"bad priority shapes: {a.shape}, {base.shape}")
    out = jax.ShapeDtypeStruct((n,), a.dtype)
    return pl.pallas_call(
        _priority_kernel,
        out_shape=[out, out],
        interpret=True,
    )(a, base)
