"""AOT export: lower every Layer-2 graph to an HLO *text* artifact.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Also writes ``manifest.json`` describing each artifact's I/O signature so
the Rust runtime can validate shapes before feeding literals.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# name -> (fn, [input specs]); every fn returns a tuple (lowered with
# return_tuple=True, unwrapped with to_tuple on the Rust side).
EXPORTS = {
    # Strassen leaves: MXU-tile and double-tile variants
    "matmul_f32_128": (model.strassen_leaf, [spec((128, 128)), spec((128, 128))]),
    "matmul_f32_256": (model.strassen_leaf, [spec((256, 256)), spec((256, 256))]),
    "strassen_combine_f32_128": (
        model.strassen_combine,
        [spec((128, 128))] * 7,
    ),
    # FFT segment transforms
    "fft_f32_1024": (model.fft, [spec((1024,)), spec((1024,))]),
    "fft_f32_4096": (model.fft, [spec((4096,)), spec((4096,))]),
    # Sort leaf
    "sort_f32_1024": (model.bitonic_sort, [spec((1024,))]),
    # SparseLU block steps (BOTS default block 64, plus MXU-sized 128)
    "lu0_f32_64": (model.sparselu_lu0, [spec((64, 64))]),
    "fwd_f32_64": (model.sparselu_fwd, [spec((64, 64)), spec((64, 64))]),
    "bdiv_f32_64": (model.sparselu_bdiv, [spec((64, 64)), spec((64, 64))]),
    "bmod_f32_64": (
        model.sparselu_bmod,
        [spec((64, 64)), spec((64, 64)), spec((64, 64))],
    ),
    # Coordinator priority math (Figs 2-4); H padded to 8 hop weights
    "priority_f32_16": (
        model.priority_scores,
        [spec((16, 16), I32), spec((8,)), spec((16,))],
    ),
    "priority_f32_64": (
        model.priority_scores,
        [spec((64, 64), I32), spec((8,)), spec((64,))],
    ),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides arrays > 10 elements as a literal "{...}", which the old
    # xla_extension 0.5.1 parser on the Rust side silently reads as
    # zeros (twiddle factors, sort directions, ... all vanish).
    return comp.as_hlo_text(print_large_constants=True)


def export_one(name: str, out_dir: str) -> dict:
    fn, in_specs = EXPORTS[name]
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_specs = jax.eval_shape(fn, *in_specs)
    return {
        "name": name,
        "inputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in in_specs
        ],
        "outputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in out_specs
        ],
        "hlo_bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="comma-separated export names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    names = args.only.split(",") if args.only else list(EXPORTS)
    manifest = []
    for name in names:
        entry = export_one(name, args.out)
        manifest.append(entry)
        print(f"  exported {name}: {entry['hlo_bytes']} chars")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=2)
    print(f"wrote {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
