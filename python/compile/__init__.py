"""Build-time compile path: JAX/Pallas -> HLO text artifacts.

Nothing in this package runs at request time; ``make artifacts`` invokes
:mod:`compile.aot` once and the Rust coordinator is self-contained after.
"""
