//! Quickstart: run one BOTS benchmark on the simulated SunFire X4600 under
//! the paper's DFWSRPT scheduler with NUMA-aware thread allocation, and
//! compare it against the stock work-first baseline.
//!
//!     cargo run --release --example quickstart
//!
//! This is the five-minute tour of the experiment API: describe a run as
//! a [`RunSpec`], hand it to a [`Session`] (which computes and memoizes
//! the serial baseline for you), read the [`RunRecord`].

use numanos::util::fmt_time;
use numanos::{Policy, RunSpec, Session};

fn main() -> anyhow::Result<()> {
    let session = Session::new();

    // Stock NANOS work-first, unpinned-style linear binding.
    let base_spec = RunSpec::builder().bench("sort").policy(Policy::WorkFirst).linear().build()?;

    // The paper's full stack: priority-based thread allocation (SS IV)
    // + NUMA-aware randomized work stealing (SS VI.B).  Builders are
    // cheap value edits away from each other — that is the point.
    let numa_spec = RunSpec::builder().bench("sort").policy(Policy::Dfwsrpt).numa().build()?;

    let base = session.run(&base_spec)?;
    let numa = session.run(&numa_spec)?;

    // Both records share one memoized serial baseline (same bench, size,
    // seed, topology) — the paper's speedup denominator.
    println!(
        "machine: x4600 | serial sort baseline: {}\n",
        fmt_time(base.serial_makespan)
    );
    for rec in [&base, &numa] {
        let s = &rec.stats;
        println!(
            "{:<26} speedup {:>5.2}x | steals {} @ {:.2} hops | remote {:>4.1}% | lock wait {}",
            rec.label(),
            rec.speedup,
            s.steals,
            s.mean_steal_hops,
            100.0 * s.mem.remote_ratio(),
            fmt_time(s.lock_wait_total),
        );
    }
    let gain = (1.0 - base.stats.makespan as f64 / numa.stats.makespan as f64).abs() * 100.0;
    println!(
        "\nNUMA-aware stack is {gain:.1}% {} than stock work-first on sort.",
        if numa.stats.makespan < base.stats.makespan { "faster" } else { "slower" }
    );
    println!("(specs serialize too: numanos run --json, or RunSpec::to_json_string)");
    Ok(())
}
