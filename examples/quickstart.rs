//! Quickstart: run one BOTS benchmark on the simulated SunFire X4600 under
//! the paper's DFWSRPT scheduler with NUMA-aware thread allocation, and
//! compare it against the stock work-first baseline.
//!
//!     cargo run --release --example quickstart
//!
//! This is the five-minute tour of the public API: build a [`Runtime`]
//! (topology + cost model), instantiate a workload, run it under a
//! scheduler policy, read the stats.

use numanos::bots;
use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::metrics::speedup;
use numanos::util::fmt_time;

fn main() -> anyhow::Result<()> {
    // The paper's testbed: 8 dual-core Opteron sockets, twisted-ladder HT.
    let rt = Runtime::paper_testbed();
    println!(
        "machine: {} ({} cores / {} NUMA nodes, max {} hops)\n",
        rt.topo.name(),
        rt.topo.num_cores(),
        rt.topo.num_nodes(),
        rt.topo.max_hops()
    );

    let bench = "sort";
    let seed = 42;

    // Serial baseline (the paper's speedup denominator).
    let mut serial_w = bots::create(bench, Size::Medium, seed)?;
    let serial = rt.run_serial(serial_w.as_mut(), seed)?;
    println!("serial {bench}: {}", fmt_time(serial.makespan));

    // Stock NANOS work-first, unpinned-style linear binding.
    let mut base_w = bots::create(bench, Size::Medium, seed)?;
    let base = rt.run(base_w.as_mut(), Policy::WorkFirst, BindPolicy::Linear, 16, seed, None)?;

    // The paper's full stack: priority-based thread allocation (SS IV)
    // + NUMA-aware randomized work stealing (SS VI.B).
    let mut numa_w = bots::create(bench, Size::Medium, seed)?;
    let numa = rt.run(numa_w.as_mut(), Policy::Dfwsrpt, BindPolicy::NumaAware, 16, seed, None)?;

    for s in [&base, &numa] {
        println!(
            "{:<26} speedup {:>5.2}x | steals {} @ {:.2} hops | remote {:>4.1}% | lock wait {}",
            s.label(),
            speedup(&serial, s),
            s.steals,
            s.mean_steal_hops,
            100.0 * s.mem.remote_ratio(),
            fmt_time(s.lock_wait_total),
        );
    }
    let gain = (1.0 - base.makespan as f64 / numa.makespan as f64).abs() * 100.0;
    println!(
        "\nNUMA-aware stack is {gain:.1}% {} than stock work-first on {bench}.",
        if numa.makespan < base.makespan { "faster" } else { "slower" }
    );
    Ok(())
}
