//! Experiment manifests: author a sweep grid as data, run it, read it.
//!
//!     cargo run --release --example experiment_manifest
//!
//! Loads `examples/experiment_manifest.json` — a custom two-sweep grid
//! (scheduler scaling plus a slow-DRAM cost ablation) — and executes it
//! on one [`Session`]: baselines are shared, cells run in parallel across
//! OS threads, and the output is deterministic (a `--seq` run of
//! `numanos sweep` produces byte-identical CSV).  The same file drives
//! the CLI directly:
//!
//!     numanos sweep --manifest examples/experiment_manifest.json --json

use std::path::Path;

use numanos::coordinator::binding::BindPolicy;
use numanos::{ExperimentManifest, Policy, Session, Sweep};

fn main() -> anyhow::Result<()> {
    // The manifest is plain data on disk (JSON here; TOML works too)…
    let path = Path::new("examples/experiment_manifest.json");
    let manifest = if path.exists() {
        ExperimentManifest::load(path)?
    } else {
        // …and exactly equivalent to building the sweeps in code.
        ExperimentManifest {
            title: "custom grid: NUMA schedulers under slower DRAM".into(),
            sweeps: vec![Sweep::new("numa-scaling", "DFWSPT vs DFWSRPT scaling")
                .with_benches(["fft", "sort"])
                .with_configs(vec![
                    (Policy::WorkFirst, BindPolicy::NumaAware),
                    (Policy::Dfwspt, BindPolicy::NumaAware),
                    (Policy::Dfwsrpt, BindPolicy::NumaAware),
                ])
                .with_threads(vec![2, 4, 8, 16])
                .with_seed(7)
                .with_size(numanos::config::Size::Small)],
        }
    };

    println!("# {}\n", manifest.title);
    let session = Session::new();
    for sweep in &manifest.sweeps {
        let t0 = std::time::Instant::now();
        let result = session.run_sweep(sweep)?;
        println!("{}", result.table().to_markdown());
        println!(
            "[{} cells in {:.1}s — first CSV line: {}]\n",
            result.records.len(),
            t0.elapsed().as_secs_f64(),
            result.to_csv().lines().nth(1).unwrap_or("-"),
        );
    }
    Ok(())
}
