//! Define, register and sweep a user-defined scheduler — the ~30-line
//! recipe the `coordinator::sched` module docs promise.
//!
//!     cargo run --release --example custom_scheduler
//!
//! The strategy here is `far-first`: it visits victims **farthest group
//! first** — deliberately anti-NUMA, the mirror image of DFWSPT.  Running
//! it next to `wf` and `dfwspt` on the same grid shows the registry
//! treating a user-defined strategy exactly like a built-in one: it can
//! be named in manifests, validated, swept, and labelled in tables, with
//! no engine or spec-layer changes.

use numanos::coordinator::sched::{self, SchedDescriptor, Scheduler, VictimList};
use numanos::util::SplitMix64;
use numanos::{ExperimentManifest, Session};

/// Steal from the farthest distance group first (ids ascending within a
/// group) — maximizes steal-transaction hops and remote data pulls.
struct FarFirst;

impl Scheduler for FarFirst {
    fn name(&self) -> &str {
        "far-first"
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor::WORK_STEALING
    }

    fn victim_order(&self, vl: &VictimList, _rng: &mut SplitMix64, out: &mut Vec<usize>) {
        for (_, group) in vl.groups.iter().rev() {
            out.extend(group.iter().copied());
        }
    }
}

fn main() -> anyhow::Result<()> {
    // One registration call; every surface picks the name up from here.
    sched::register(
        sched::SchedulerInfo::new("far-first", "steal farthest groups first (anti-NUMA demo)"),
        |_params| Ok(Box::new(FarFirst)),
    )?;
    println!("registered schedulers: {}\n", sched::scheduler_names().join(" "));

    // The manifest names the custom scheduler like any stock one.
    let manifest = ExperimentManifest::from_json_str(
        r#"{
          "title": "user-defined scheduler in a sweep",
          "defaults": {"size": "small", "seeds": [7]},
          "sweeps": [
            {"id": "far-vs-near",
             "bench": ["fft"],
             "sched": ["wf", "dfwspt", "far-first"],
             "bind": ["numa"],
             "threads": [4, 8, 16]}
          ]
        }"#,
    )?;

    let session = Session::new();
    for sweep in &manifest.sweeps {
        let result = session.run_sweep(sweep)?;
        println!("{}", result.table().to_markdown());
        for rec in &result.records {
            if rec.spec.threads == 16 {
                println!(
                    "{:<22} 16 threads: {:>5.2}x, mean steal hops {:.2}",
                    rec.spec.sched.name_sig(),
                    rec.speedup,
                    rec.stats.mean_steal_hops,
                );
            }
        }
    }
    println!("\nfar-first pays for every steal with maximum hops — the same");
    println!("machinery that proves DFWSPT's point also quantifies its inverse.");
    Ok(())
}
