//! Scheduler duel: all five policies head-to-head on the data-intensive
//! benchmarks (the paper's §V/§VI storyline in one table).
//!
//!     cargo run --release --example scheduler_duel

use numanos::bots;
use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::metrics::speedup;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::paper_testbed();
    let seed = 42;
    let threads = 16;

    for bench in ["fft", "sort", "strassen"] {
        let mut serial_w = bots::create(bench, Size::Medium, seed)?;
        let serial = rt.run_serial(serial_w.as_mut(), seed)?;
        println!("\n=== {bench} (16 threads, speedup over serial) ===");
        println!(
            "{:<10} {:>8} {:>9} {:>12} {:>10} {:>9}",
            "scheduler", "speedup", "steals", "steal-hops", "remote%", "lockwait"
        );
        for &policy in &[
            Policy::BreadthFirst,
            Policy::CilkBased,
            Policy::WorkFirst,
            Policy::Dfwspt,
            Policy::Dfwsrpt,
        ] {
            // the NUMA-aware schedulers are evaluated the way the paper
            // does: combined with the SS IV allocation
            let bind = match policy {
                Policy::Dfwspt | Policy::Dfwsrpt => BindPolicy::NumaAware,
                _ => BindPolicy::Linear,
            };
            let mut w = bots::create(bench, Size::Medium, seed)?;
            let s = rt.run(w.as_mut(), policy, bind, threads, seed, None)?;
            println!(
                "{:<10} {:>7.2}x {:>9} {:>12.2} {:>9.1}% {:>8}us",
                policy.name(),
                speedup(&serial, &s),
                s.steals,
                s.mean_steal_hops,
                100.0 * s.mem.remote_ratio(),
                s.lock_wait_total / 1_000_000,
            );
        }
    }
    println!("\nDFWSPT/DFWSRPT steal closer (lower steal-hops) and win on the");
    println!("memory-heavy benchmarks — the paper's SS VI result.");
    Ok(())
}
