//! Scheduler duel: the stock policies, the paper's NUMA-aware pair, and
//! the three registry-shipped strategies head-to-head on the
//! data-intensive benchmarks (the paper's §V/§VI storyline in one
//! table) — expressed as one [`Sweep`] instead of nested launch loops.
//!
//!     cargo run --release --example scheduler_duel

use numanos::coordinator::binding::BindPolicy;
use numanos::{SchedSpec, Session, Sweep};

fn main() -> anyhow::Result<()> {
    // The paper evaluates the NUMA-aware schedulers combined with the
    // SS IV allocation, the stock ones with linear binding.  The last
    // three come from the open registry: a parameterized hop-bounded
    // stealer, hierarchical delegation, and an adaptive switcher.
    let configs = vec![
        (SchedSpec::parse("bf")?, BindPolicy::Linear),
        (SchedSpec::parse("cilk")?, BindPolicy::Linear),
        (SchedSpec::parse("wf")?, BindPolicy::Linear),
        (SchedSpec::parse("dfwspt")?, BindPolicy::NumaAware),
        (SchedSpec::parse("dfwsrpt")?, BindPolicy::NumaAware),
        (SchedSpec::parse("hops-threshold:max_hops=1")?, BindPolicy::NumaAware),
        (SchedSpec::parse("hier")?, BindPolicy::NumaAware),
        (SchedSpec::parse("adaptive")?, BindPolicy::NumaAware),
    ];
    let sweep = Sweep::new("duel", "scheduler duel (16 threads, speedup over serial)")
        .with_benches(["fft", "sort", "strassen"])
        .with_configs(configs)
        .with_threads(vec![16]);

    // Cells run in parallel across OS threads; output is deterministic.
    let session = Session::new();
    let result = session.run_sweep(&sweep)?;

    for chunk in result.records.chunks(result.sweep.configs.len()) {
        println!("\n=== {} (16 threads, speedup over serial) ===", chunk[0].spec.bench);
        println!(
            "{:<28} {:>8} {:>9} {:>12} {:>10} {:>9}",
            "scheduler", "speedup", "steals", "steal-hops", "remote%", "lockwait"
        );
        for rec in chunk {
            let s = &rec.stats;
            println!(
                "{:<28} {:>7.2}x {:>9} {:>12.2} {:>9.1}% {:>8}us",
                rec.spec.sched.name_sig(),
                rec.speedup,
                s.steals,
                s.mean_steal_hops,
                100.0 * s.mem.remote_ratio(),
                s.lock_wait_total / 1_000_000,
            );
        }
    }
    println!("\nDFWSPT/DFWSRPT steal closer (lower steal-hops) and win on the");
    println!("memory-heavy benchmarks — the paper's SS VI result.  The");
    println!("registry strategies push the same lever further: hop-bounded");
    println!("and hierarchical stealing cut steal-hops again, and adaptive");
    println!("converges on the priority list only when remote steals hurt.");
    Ok(())
}
