//! End-to-end driver: the full three-layer stack on real workloads.
//!
//!     make artifacts && cargo run --release --example e2e_compute
//!
//! Layer 3 (this binary, Rust) schedules BOTS task graphs over the
//! simulated X4600 with the paper's NUMA-aware policies; every compute
//! leaf invokes its Layer-2 JAX graph — built from Layer-1 Pallas
//! kernels and AOT-lowered to `artifacts/*.hlo.txt` — through the PJRT
//! CPU client.  Python is nowhere in this process.
//!
//! Four real workloads run and are verified numerically:
//!
//! * **SparseLU** — a full blocked LU factorization whose every
//!   lu0/fwd/bdiv/bmod *task* calls its 64x64 kernel artifact on live
//!   data (the scheduler orders the real math); verified by `L@U ≈ A`.
//! * **Strassen** — a one-level 256² Strassen product: seven MXU-tile
//!   `matmul_f32_128` calls + the combine artifact, vs a naive matmul.
//! * **Sort** — a 1024-key bitonic-network sort artifact, vs `sort()`.
//! * **FFT** — a 4096-point butterfly-cascade artifact, vs an O(n²) DFT.
//!
//! Reports per-kernel-call latency and end-to-end throughput — the
//! numbers EXPERIMENTS.md §E2E records.

use std::time::Instant;

use numanos::bots::{fft::Fft, sort::Sort, sparselu, strassen::Strassen};
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::coordinator::task::Workload;
use numanos::runtime::ExecEngine;

fn run_real(
    rt: &Runtime,
    exec: &mut ExecEngine,
    name: &str,
    workload: &mut dyn Workload,
) -> anyhow::Result<()> {
    let calls_before = exec.calls;
    let t0 = Instant::now();
    let stats = rt.run(workload, Policy::Dfwsrpt, BindPolicy::NumaAware, 8, 42, Some(exec))?;
    let wall = t0.elapsed().as_secs_f64();
    let calls = exec.calls - calls_before;
    println!(
        "  {name:<10} OK: {} tasks scheduled, {} PJRT kernel calls, {:.1} ms wall ({:.2} ms/call), verified",
        stats.tasks,
        calls,
        wall * 1e3,
        if calls > 0 { wall * 1e3 / calls as f64 } else { 0.0 },
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("NUMANOS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        anyhow::bail!("artifacts not found in '{dir}' — run `make artifacts` first");
    }
    let mut exec = ExecEngine::cpu(&dir)?;
    println!(
        "PJRT platform: {} | {} artifacts in manifest\n",
        exec.platform(),
        exec.manifest_len()
    );
    let rt = Runtime::paper_testbed();

    println!("running real workloads through the coordinator (DFWSRPT + NUMA binding):");
    let mut lu = sparselu::SparseLu::with_params(4, sparselu::Variant::Single);
    run_real(&rt, &mut exec, "sparselu", &mut lu)?;

    let mut st = Strassen::with_params(512, 128);
    run_real(&rt, &mut exec, "strassen", &mut st)?;

    let mut so = Sort::with_params(1 << 15, 1 << 10, 1 << 10);
    run_real(&rt, &mut exec, "sort", &mut so)?;

    let mut ff = Fft::with_params(1 << 14, 1 << 12, 1 << 10);
    run_real(&rt, &mut exec, "fft", &mut ff)?;

    println!("\ntotal PJRT executions this process: {}", exec.calls);
    println!("all numeric verifications passed — the three layers compose.");
    Ok(())
}
