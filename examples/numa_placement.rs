//! NUMA placement walkthrough — the paper's §IV machinery, visible.
//!
//!     cargo run --release --example numa_placement
//!
//! 1. explores the X4600 fabric and prints the hop matrix + centrality;
//! 2. runs the Fig 2–4 priority algorithm and shows the ranked cores;
//! 3. binds teams of 2/4/8/16 threads both ways and shows which cores
//!    (and which NUMA nodes) each policy picks;
//! 4. runs an FFT under both bindings (two one-line `RunSpec`s on a
//!    shared `Session`) and audits where the pages landed and how far
//!    the misses travelled;
//! 5. sweeps the *allocation* side: page policies (`--mem`) × the
//!    `numa-home` push-to-home scheduler, the locality layer's axis.

use numanos::coordinator::binding::{bind_threads, BindPolicy};
use numanos::coordinator::priority::core_priorities;
use numanos::util::SplitMix64;
use numanos::{MemSpec, Policy, RunSpec, SchedSpec, Session, Topology};

fn main() -> anyhow::Result<()> {
    let topo = Topology::x4600();

    println!("== 1. hardware exploration (the simulated libnuma surface) ==");
    for node in 0..topo.num_nodes() {
        let row: Vec<String> =
            (0..topo.num_nodes()).map(|b| topo.node_hops(node, b).to_string()).collect();
        println!(
            "  node {node}: hops [{}]  mean-to-cores {:.2}",
            row.join(" "),
            topo.mean_hops_from(node)
        );
    }

    println!("\n== 2. Fig 2-4 core priorities ==");
    let pr = core_priorities(&topo);
    let ranked = pr.ranked_cores();
    for &c in ranked.iter().take(4) {
        println!("  core {c:>2} (node {}): P = {:.1}", topo.node_of(c), pr.scores[c]);
    }
    println!("  ... corner cores rank last:");
    for &c in ranked.iter().rev().take(2) {
        println!("  core {c:>2} (node {}): P = {:.1}", topo.node_of(c), pr.scores[c]);
    }

    println!("\n== 3. thread->core binding ==");
    for threads in [2usize, 4, 8, 16] {
        let mut rng = SplitMix64::new(7);
        let lin = bind_threads(&topo, threads, BindPolicy::Linear, &mut rng);
        let numa = bind_threads(&topo, threads, BindPolicy::NumaAware, &mut rng);
        let nodes = |cores: &[usize]| -> Vec<usize> {
            cores.iter().map(|&c| topo.node_of(c)).collect()
        };
        println!("  t={threads:<2} linear -> nodes {:?}", nodes(&lin.cores));
        println!("        numa   -> nodes {:?} (master on node {})",
            nodes(&numa.cores), topo.node_of(numa.master_core()));
    }

    println!("\n== 4. first-touch placement audit (FFT medium, 16 threads) ==");
    let session = Session::new();
    for bind in [BindPolicy::Linear, BindPolicy::NumaAware] {
        let spec = RunSpec::builder().bench("fft").policy(Policy::WorkFirst).bind(bind).build()?;
        let rec = session.run(&spec)?;
        println!(
            "  {:<8} makespan {:>9} us | remote misses {:>4.1}% | mean miss distance {:.2} hops",
            spec.bind.name(),
            rec.stats.makespan / 1_000_000,
            100.0 * rec.stats.mem.remote_ratio(),
            rec.stats.mem.mean_miss_hops(),
        );
    }
    println!("\nCentral-node first touch shortens the average miss path — the");
    println!("paper's SS V.B explanation of its data-intensive speedups.");

    println!("\n== 5. page policy x task placement (sparselu_for, 16 threads) ==");
    for (sched, mem) in [
        (SchedSpec::stock(Policy::Dfwsrpt), MemSpec::default()),
        (SchedSpec::new("numa-home"), MemSpec::default()),
        (SchedSpec::new("numa-home"), MemSpec::new("interleave")),
        (SchedSpec::stock(Policy::WorkFirst), MemSpec::new("next-touch")),
    ] {
        let spec = RunSpec::builder()
            .bench("sparselu_for")
            .size(numanos::config::Size::Small)
            .sched(sched)
            .mem(mem)
            .numa()
            .threads(16)
            .build()?;
        let rec = session.run(&spec)?;
        println!(
            "  {:<12} mem={:<12} remote {:>4.1}% | pushed-home {:>4} | migrated {:>4} | speedup {:.2}x",
            rec.spec.sched.name_sig(),
            rec.spec.mem.name_sig(),
            100.0 * rec.stats.mem.remote_ratio(),
            rec.stats.pushed_home,
            rec.stats.mem.migrated_pages,
            rec.speedup,
        );
    }
    println!("\nThe steal side moves idle workers toward work; numa-home's place()");
    println!("hook moves work toward its data — both halves of the paper's technique.");
    Ok(())
}
