//! Shape assertions for the paper's headline results (the reproduction
//! contract of DESIGN.md §2): who wins, where, by roughly what factor.
//! Absolute values live in EXPERIMENTS.md; these tests pin the *ordering*
//! so a regression in the model or schedulers trips CI.

use numanos::bots;
use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::metrics::speedup;

// Medium scale: the paper's effects are scale-dependent (queue pressure,
// footprint > node capacity); Small inputs do not exhibit them.
fn sp(rt: &Runtime, bench: &str, policy: Policy, bind: BindPolicy, threads: usize) -> f64 {
    let seed = 42;
    let mut ws = bots::create(bench, Size::Medium, seed).unwrap();
    let serial = rt.run_serial(ws.as_mut(), seed).unwrap();
    let mut w = bots::create(bench, Size::Medium, seed).unwrap();
    let s = rt.run(w.as_mut(), policy, bind, threads, seed, None).unwrap();
    speedup(&serial, &s)
}

#[test]
fn fig7_work_stealing_beats_bf_on_fft_at_scale() {
    let rt = Runtime::paper_testbed();
    let bf = sp(&rt, "fft", Policy::BreadthFirst, BindPolicy::Linear, 16);
    let wf = sp(&rt, "fft", Policy::WorkFirst, BindPolicy::Linear, 16);
    let cilk = sp(&rt, "fft", Policy::CilkBased, BindPolicy::Linear, 16);
    assert!(wf > bf, "wf {wf:.2} must beat bf {bf:.2} (paper 9.3 vs 2.39)");
    assert!(cilk > bf, "cilk {cilk:.2} must beat bf {bf:.2} (paper 8.61 vs 2.39)");
}

#[test]
fn fig10_bf_is_competitive_on_nqueens() {
    // nqueens is bf's benchmark (paper: 15.93x, the best config)
    let rt = Runtime::paper_testbed();
    let bf = sp(&rt, "nqueens", Policy::BreadthFirst, BindPolicy::Linear, 16);
    let wf = sp(&rt, "nqueens", Policy::WorkFirst, BindPolicy::Linear, 16);
    assert!(
        bf > 0.75 * wf,
        "bf {bf:.2} must stay competitive with wf {wf:.2} on nqueens"
    );
}

#[test]
fn numa_allocation_helps_fft() {
    // §V.A: the allocation gain is largest for the data-intensive FFT
    let rt = Runtime::paper_testbed();
    let base = sp(&rt, "fft", Policy::WorkFirst, BindPolicy::Linear, 16);
    let numa = sp(&rt, "fft", Policy::WorkFirst, BindPolicy::NumaAware, 16);
    assert!(
        numa > base * 0.98,
        "numa binding {numa:.2} must not lose to linear {base:.2}"
    );
}

#[test]
fn fig13_numa_schedulers_do_not_lose_to_wf_on_fft() {
    let rt = Runtime::paper_testbed();
    let wf = sp(&rt, "fft", Policy::WorkFirst, BindPolicy::NumaAware, 16);
    let pt = sp(&rt, "fft", Policy::Dfwspt, BindPolicy::NumaAware, 16);
    let rpt = sp(&rt, "fft", Policy::Dfwsrpt, BindPolicy::NumaAware, 16);
    assert!(pt > wf * 0.97, "dfwspt {pt:.2} vs wf {wf:.2} (paper: +5.85%)");
    assert!(rpt > wf * 0.97, "dfwsrpt {rpt:.2} vs wf {wf:.2}");
}

#[test]
fn numa_schedulers_steal_closer() {
    // the §VI mechanism itself: priority-list stealing shortens paths
    let rt = Runtime::paper_testbed();
    let seed = 9;
    let hops = |policy| {
        let mut w = bots::create("sort", Size::Medium, seed).unwrap();
        let s = rt.run(w.as_mut(), policy, BindPolicy::NumaAware, 16, seed, None).unwrap();
        assert!(s.steals > 10, "need steals to compare");
        s.mean_steal_hops
    };
    let wf = hops(Policy::WorkFirst);
    let pt = hops(Policy::Dfwspt);
    assert!(pt < wf, "dfwspt steal hops {pt:.2} must be below wf {wf:.2}");
}

#[test]
fn serial_baseline_is_the_fastest_single_thread() {
    // overhead-free serial must beat any 1-thread scheduled run
    let rt = Runtime::paper_testbed();
    for bench in ["fft", "sort"] {
        let mut ws = bots::create(bench, Size::Medium, 1).unwrap();
        let serial = rt.run_serial(ws.as_mut(), 1).unwrap();
        let mut w = bots::create(bench, Size::Medium, 1).unwrap();
        let one = rt.run(w.as_mut(), Policy::WorkFirst, BindPolicy::Linear, 1, 1, None).unwrap();
        assert!(serial.makespan <= one.makespan, "{bench}: serial slower than wf@1");
    }
}
