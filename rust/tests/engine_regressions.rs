//! Engine-level regressions for the locality refactor: the
//! tied-continuation wake-targeting fix (a release used to signal an
//! arbitrary round-robin sleeper, which under bounded-sweep schedulers
//! strands the continuation and charges phantom steal overhead),
//! deterministic engagement of the `resume` / `steal_bias` hooks with
//! their `homed_resumes` / `affine_steals` counters, steal-half
//! batching, per-node continuation mailboxes, and the duplicate-victim
//! dedup after the `steal_bias` hook.
//!
//! The workloads are hand-built task graphs over hand-built topologies:
//! every cross-worker ordering below is separated by tens of
//! microseconds of simulated compute, far above the sub-microsecond
//! queue-op costs, so the traces (and the asserted counters) are stable
//! under any reasonable cost model.  Two traces additionally rely on an
//! engine invariant worth naming: a worker executes a whole scheduling
//! quantum per *event*, so a leaf's long compute finishes (and its
//! completion cascade runs) at the quantum's start event — pool contents
//! observed by later events are exact, not racy.

use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::{
    self, dfwspt, SchedDescriptor, SchedSpec, Scheduler, SchedulerInfo, StealCand, VictimList,
};
use numanos::coordinator::task::{BodyCtx, TaskDesc, Workload};
use numanos::simnuma::{CostModel, MemSim, MemSpec, Region};
use numanos::spec::Session;
use numanos::topology::Topology;
use numanos::util::{SplitMix64, Time, NS};

/// Root spawns A (which parks its worker until late via a 5 us
/// grandchild) and B (a 50 us leaf); the root continuation ends up
/// `Waiting` on a worker two hops from A's worker.  Kinds: 0 root, 1 A,
/// 2 B, 3 A2.
struct TiedOwner;

impl Workload for TiedOwner {
    fn name(&self) -> &'static str {
        "tied-owner"
    }

    fn init(&mut self, _mem: &mut MemSim, _master_core: usize) -> Time {
        0
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                ctx.spawn(TaskDesc::leaf(1)); // A
                ctx.spawn(TaskDesc::leaf(2)); // B
                ctx.taskwait();
                ctx.compute(500);
            }
            1 => {
                // A suspends on a grandchild so its owner's final acquire
                // (and with it A's completion — the root release) lands
                // late in event order, after every other worker parked
                ctx.compute(1_000);
                ctx.spawn(TaskDesc::leaf(3)); // A2
                ctx.taskwait();
                ctx.compute(100);
            }
            2 => ctx.compute(50_000), // B: keeps its runner's clock far out
            3 => ctx.compute(5_000),  // A2
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Satellite regression (wake targeting): when a tied continuation is
/// released while its owner sleeps, the owner must be woken directly.
///
/// Topology: a chain n0—n1—n2 plus a tail n0—n3—n4; threads bound to
/// cores on n0/n1/n2/n4.  Under `hops-threshold:max_hops=1`,
/// W0(n0)↔W1(n1) and W1(n1)↔W2(n2) can steal from each other but
/// W0↔W2 (2 hops) and W3(n4, ≥2 hops from everyone) cannot.
///
/// Trace: W1 steals the root from W0 and re-exposes it spawning B; W2
/// steals it, hits the taskwait (owner = W2) and sleeps.  A completes on
/// W0 — two hops from W2, so W0's own sweep cannot reach the
/// continuation.  The old code signalled the round-robin sleeper (W3,
/// whose sweep is empty), stranding the continuation until W1's acquire
/// 40+ us later re-stole it: a third steal, inflated attempts, and the
/// post phase running off-owner.  With the targeted wake W2 resumes its
/// own continuation and no third steal exists.
#[test]
fn tied_continuation_release_wakes_its_sleeping_owner() {
    let topo = Topology::from_edges(
        "chain-tail",
        vec![1, 1, 1, 1, 1],
        &[(0, 1), (1, 2), (0, 3), (3, 4)],
        2048,
    )
    .unwrap();
    let rt = Runtime::new(topo, CostModel::default());
    let sched = sched::build(
        &SchedSpec::new("hops-threshold")
            .with_param("max_hops", 1.0)
            .with_param("spill_after", 1000.0),
    )
    .unwrap();
    let mut w = TiedOwner;
    let stats = Session::execute_bound_placed(
        &rt,
        &mut w,
        sched.as_ref(),
        &[0, 1, 2, 4],
        false,
        &MemSpec::default(),
        7,
        None,
    )
    .unwrap();

    assert_eq!(stats.tasks, 4, "root + A + B + A2");
    // root stolen twice on its way to W2; never a third time
    assert_eq!(stats.steals, 2, "the continuation must not be re-stolen");
    // W0 ran A2 and A, W1 ran B, W2 — the owner — ran the continuation
    assert_eq!(stats.per_worker_tasks, vec![2, 1, 1, 0]);
    // the woken-wrong-worker path charged its probes to steal_attempts;
    // the targeted wake keeps the sweep count at the structural minimum
    assert!(
        stats.steal_attempts <= 5,
        "phantom sweeps inflate steal_attempts: {}",
        stats.steal_attempts
    );
    // no placement machinery involved for a non-placing scheduler
    assert_eq!(stats.pushed_home, 0);
    assert_eq!(stats.homed_resumes, 0);
    assert_eq!(stats.affine_steals, 0);
}

/// Placement workload for the resume hook: root pushes P to its data's
/// node, keeps itself busy with Q, then steals P back — so P waits on
/// the *wrong* node and its release must be redirected home.  Kinds:
/// 0 root, 1 P, 2 Q, 3 C, 4 C2.
struct HomedResume {
    data: Region,
}

impl Workload for HomedResume {
    fn name(&self) -> &'static str {
        "homed-resume"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.data = mem.alloc(64 * 1024);
        mem.first_touch(master_core, self.data, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                ctx.spawn_on(TaskDesc::leaf(1), self.data); // P -> pushed home
                ctx.spawn(TaskDesc::leaf(2)); // Q keeps the master busy
                ctx.taskwait();
                ctx.compute(100);
            }
            1 => {
                ctx.spawn_on(TaskDesc::leaf(3), self.data); // C (affinity hit)
                ctx.taskwait();
                ctx.read(self.data); // the continuation combines the data
            }
            2 => ctx.compute(10_000), // Q
            3 => {
                ctx.compute(100);
                ctx.spawn(TaskDesc::leaf(4)); // C2 delays C's completion
                ctx.taskwait();
                ctx.compute(50);
            }
            4 => ctx.compute(15_000), // C2
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Tentpole regression (resume hook): a tied continuation whose cached
/// home differs from its owner's node is released to a home-node worker
/// and counted in `homed_resumes`.  Two nodes, one core each; all pages
/// bound to node 1, so P (hinted on the data) is homed on n1 while its
/// taskwait owner ends up being W0 on n0.
#[test]
fn numa_home_redirects_waiting_continuations_to_their_data() {
    let topo = Topology::from_edges("pair", vec![1, 1], &[(0, 1)], 4096).unwrap();
    let rt = Runtime::new(topo, CostModel::default());
    let sched = sched::build(&SchedSpec::new("numa-home")).unwrap();
    let run = || {
        let mut w = HomedResume { data: Region::EMPTY };
        Session::execute_bound_placed(
            &rt,
            &mut w,
            sched.as_ref(),
            &[0, 1],
            false,
            &MemSpec::new("bind").with_param("node", 1.0),
            3,
            None,
        )
        .unwrap()
    };
    let stats = run();
    assert_eq!(stats.tasks, 5);
    assert_eq!(stats.pushed_home, 1, "P's spawn must be pushed to its home node");
    assert_eq!(stats.affinity_hits, 1, "C spawned on the node its data lives on");
    assert_eq!(
        stats.homed_resumes, 1,
        "P's continuation must be released toward node 1, not its owner on node 0"
    );
    // deterministic: same spec, same counters
    let again = run();
    assert_eq!(stats.makespan, again.makespan);
    assert_eq!(stats.steals, again.steals);
    assert_eq!(stats.homed_resumes, again.homed_resumes);
}

/// Steal-bias workload: M is spawned with a node-1 affinity hint and
/// suspends in W0's pool behind the root; W1 (on node 1) drains the pool
/// and its second steal takes M — an affine steal.  Kinds: 0 root, 1 M,
/// 2 L.
struct AffineSteal {
    data: Region,
}

impl Workload for AffineSteal {
    fn name(&self) -> &'static str {
        "affine-steal"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.data = mem.alloc(64 * 1024);
        mem.first_touch(master_core, self.data, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                ctx.spawn_on(TaskDesc::leaf(1), self.data); // M, homed on n1
                ctx.taskwait();
                ctx.compute(200);
            }
            1 => {
                ctx.spawn(TaskDesc::leaf(2)); // L parks W0 far out
                ctx.taskwait();
                ctx.read(self.data);
            }
            2 => ctx.compute(30_000), // L
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Tentpole regression (steal bias + home tags): `numa-steal` never
/// pushes or redirects, but a steal that lands a task on its data's home
/// node is counted in `affine_steals` via the spawn-time home tag.
#[test]
fn numa_steal_counts_affine_steals_without_placing() {
    let topo = Topology::from_edges("pair", vec![1, 1], &[(0, 1)], 4096).unwrap();
    let rt = Runtime::new(topo, CostModel::default());
    let sched = sched::build(&SchedSpec::new("numa-steal")).unwrap();
    let mut w = AffineSteal { data: Region::EMPTY };
    let stats = Session::execute_bound_placed(
        &rt,
        &mut w,
        sched.as_ref(),
        &[0, 1],
        false,
        &MemSpec::new("bind").with_param("node", 1.0),
        3,
        None,
    )
    .unwrap();
    assert_eq!(stats.tasks, 3);
    assert_eq!(stats.steals, 2, "W1 steals the root, then M");
    assert_eq!(stats.affine_steals, 1, "M (homed on n1) stolen by the n1 worker");
    assert_eq!(stats.pushed_home, 0, "steal-side-only: no push-to-home");
    assert_eq!(stats.homed_resumes, 0, "steal-side-only: continuations stay tied");
}

/// Near-free queue/spawn costs: the master's whole spawn chain finishes
/// inside the 120 ns futex wake latency, so the woken thief's first
/// sweep observes the fully built pool — the deterministic window the
/// steal-half and mailbox traces below are built on.
fn fast_queue_cost() -> CostModel {
    CostModel {
        queue_op: 5 * NS,
        spawn_cost: 5 * NS,
        steal_per_hop: 5 * NS,
        ..CostModel::default()
    }
}

/// Steal-half workload: a spawn chain root→A→B→C (each hinted on the
/// node-1 data) ending in a long plain leaf D, so W0's pool holds the
/// four suspended ancestors `[C, B, A, root]` (three of them homed on
/// node 1) when the node-1 thief arrives.  Kinds: 0 root, 1 A, 2 B,
/// 3 C, 4 D.
struct StealHalfChain {
    data: Region,
}

impl Workload for StealHalfChain {
    fn name(&self) -> &'static str {
        "steal-half-chain"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.data = mem.alloc(64 * 1024);
        mem.first_touch(master_core, self.data, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            k @ 0..=2 => {
                ctx.spawn_on(TaskDesc::leaf(k + 1), self.data);
                ctx.taskwait();
                ctx.compute(100);
            }
            3 => {
                ctx.spawn(TaskDesc::leaf(4)); // D: unhinted
                ctx.taskwait();
                ctx.compute(100);
            }
            4 => ctx.compute(50_000), // D parks W0's clock far out
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Tentpole regression (steal-half batching), hand-traced: with all
/// pages bound to node 1, the pool tags read `[C:1, B:1, A:1, root:—]`,
/// so the thief's bias sees `affine=3, queued=4` and `numa-steal:batch=4`
/// sets `take = 4/2 = 2`.  The sweep drains `[root, A]` under one lock:
/// the thief runs root (exactly what a single back-steal would have
/// taken) and requeues A locally — one `batch_steals`, one task
/// migrated.  A then comes off the thief's *own* pool (no second sweep),
/// and B and C are stolen singly (their queues are too shallow to
/// batch), both affine.  D completes at its start event, long before the
/// thief's sweeps, so every count below is exact.
#[test]
fn steal_half_batches_affine_work_to_the_thief() {
    let topo = Topology::from_edges("pair", vec![1, 1], &[(0, 1)], 4096).unwrap();
    let rt = Runtime::new(topo, fast_queue_cost());
    let sched =
        sched::build(&SchedSpec::new("numa-steal").with_param("batch", 4.0)).unwrap();
    let run = || {
        let mut w = StealHalfChain { data: Region::EMPTY };
        Session::execute_bound_placed(
            &rt,
            &mut w,
            sched.as_ref(),
            &[0, 1],
            false,
            &MemSpec::new("bind").with_param("node", 1.0),
            3,
            None,
        )
        .unwrap()
    };
    let stats = run();
    assert_eq!(stats.tasks, 5, "root + A + B + C + D");
    assert_eq!(stats.batch_steals, 1, "exactly the first sweep batches");
    assert_eq!(stats.tasks_migrated, 1, "the batch moved root plus one extra (A)");
    assert_eq!(stats.steals, 3, "batch counts once; B and C are single steals");
    assert_eq!(stats.steal_attempts, 3, "A comes off the thief's own pool, not a sweep");
    assert_eq!(stats.affine_steals, 2, "B and C land on their data's node; root is untagged");
    assert_eq!(stats.per_worker_tasks, vec![1, 4], "W0 ran only D; W1 ran the whole chain");
    assert_eq!(stats.pushed_home, 0, "steal-side-only: no pushes");
    assert_eq!(stats.homed_resumes, 0);
    assert_eq!(stats.mailbox_hits, 0, "no redirects, so the mailboxes stay empty");
    let again = run();
    assert_eq!(stats.makespan, again.makespan);
    assert_eq!(stats.sim_events, again.sim_events);
    assert_eq!(stats.tasks_migrated, again.tasks_migrated);
}

/// Mailbox workload: P and R are pushed home to the two node-1 workers;
/// W0 (node 0) steals P, C and C2 back while the node-1 team is busy, so
/// their continuations wait under a node-0 owner and must be released
/// *toward node 1*.  Kinds: 0 root, 1 P, 2 R, 3 Q, 4 C, 5 C2, 6 C3.
struct MailboxGraph {
    data: Region,
    data2: Region,
}

impl Workload for MailboxGraph {
    fn name(&self) -> &'static str {
        "mailbox-graph"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.data = mem.alloc(64 * 1024);
        self.data2 = mem.alloc(64 * 1024);
        let mut t = mem.first_touch(master_core, self.data, 0);
        t += mem.first_touch(master_core, self.data2, 0);
        t
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                ctx.spawn_on(TaskDesc::leaf(1), self.data); // P -> pushed to W1
                ctx.spawn_on(TaskDesc::leaf(2), self.data2); // R -> pushed to W2
                ctx.spawn(TaskDesc::leaf(3)); // Q keeps the master busy
                ctx.taskwait();
                ctx.compute(100);
            }
            1 => {
                ctx.spawn_on(TaskDesc::leaf(4), self.data); // C (affinity hit)
                ctx.taskwait();
                ctx.compute(50);
            }
            2 => ctx.compute(30_000), // R
            3 => ctx.compute(10_000), // Q
            4 => {
                ctx.compute(100);
                ctx.spawn(TaskDesc::leaf(5)); // C2
                ctx.taskwait();
                ctx.compute(50);
            }
            5 => {
                ctx.spawn(TaskDesc::leaf(6)); // C3 keeps W1 busy until late
                ctx.taskwait();
                ctx.compute(50);
            }
            6 => ctx.compute(20_000), // C3
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Tentpole regression (per-node mailboxes), hand-traced: W0 finishes Q
/// at ~10 µs and steals P, C and C2 out of W1's pool (the node-1 team is
/// busy with C3 until ~20 µs).  C2's completion on W0 releases C — owner
/// W0, home node 1 — into node 1's *mailbox*; nobody on node 1 sleeps,
/// so no wake is issued and W0 parks.  When C3's quantum ends, W1 drains
/// its node mailbox (own stack first, mailbox second, stealing last) and
/// runs C's continuation on the data's node; completing C releases P the
/// same way.  Root's tied release then wakes W0, but W1's next sweep
/// legitimately steals it first.  Every counter below is exact.
#[test]
fn homed_continuations_flow_through_the_node_mailbox() {
    let topo = Topology::from_edges("one-two", vec![1, 2], &[(0, 1)], 4096).unwrap();
    let rt = Runtime::new(topo, fast_queue_cost());
    let sched = sched::build(&SchedSpec::new("numa-home")).unwrap();
    let run = || {
        let mut w = MailboxGraph { data: Region::EMPTY, data2: Region::EMPTY };
        Session::execute_bound_placed(
            &rt,
            &mut w,
            sched.as_ref(),
            &[0, 1, 2],
            false,
            &MemSpec::new("bind").with_param("node", 1.0),
            9,
            None,
        )
        .unwrap()
    };
    let stats = run();
    assert_eq!(stats.tasks, 7);
    assert_eq!(stats.pushed_home, 2, "P and R are pushed to their data's node");
    assert_eq!(stats.affinity_hits, 1, "C is spawned on the node its data lives on");
    assert_eq!(
        stats.homed_resumes, 2,
        "C's and P's continuations redirect home (their owner sat on node 0)"
    );
    assert_eq!(
        stats.mailbox_hits, 2,
        "a same-node peer drains both homed continuations from the node mailbox"
    );
    assert_eq!(
        stats.steals, 4,
        "W0's three steal-backs plus W1 taking root's tied continuation — \
         the mailbox pickups are not steals"
    );
    assert_eq!(stats.affine_steals, 0, "every steal moved work away from its data");
    assert_eq!(stats.batch_steals, 0, "numa-home's default batch is the single steal");
    assert_eq!(
        stats.per_worker_tasks,
        vec![2, 4, 1],
        "the homed post phases (C, P) ran on node-1 workers, not on owner W0"
    );
    let again = run();
    assert_eq!(stats.makespan, again.makespan);
    assert_eq!(stats.sim_events, again.sim_events);
    assert_eq!(stats.mailbox_hits, again.mailbox_hits);
}

/// A steal-bias hook that returns every victim twice, plus two bogus
/// ids — the misbehaving registered scheduler of the dedup satellite.
/// `clean: true` leaves the sweep untouched; everything else (descriptor,
/// victim order, RNG consumption) is identical between the two modes.
struct DupBias {
    clean: bool,
}

impl Scheduler for DupBias {
    fn name(&self) -> &str {
        if self.clean {
            "test-dup-bias-clean"
        } else {
            "test-dup-bias"
        }
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor { places: true, ..SchedDescriptor::WORK_STEALING }
    }

    fn victim_order(&self, vl: &VictimList, _rng: &mut SplitMix64, out: &mut Vec<usize>) {
        dfwspt::order(vl, out);
    }

    fn steal_bias(&self, _thief_node: usize, cands: &mut Vec<StealCand>) {
        if self.clean {
            return;
        }
        // duplicate the whole sweep (first occurrences keep their
        // positions) and append victims that do not exist
        let copy = cands.clone();
        cands.extend(copy);
        cands.push(StealCand::single(usize::MAX, 0, 0, 0));
        cands.push(StealCand::single(1usize << 20, 0, 0, 0));
    }
}

/// Fan-out workload for the dedup regression: three long leaves force
/// idle workers into repeated biased sweeps.  Kinds: 0 root, 1 leaf.
struct FanOut;

impl Workload for FanOut {
    fn name(&self) -> &'static str {
        "fan-out"
    }

    fn init(&mut self, _mem: &mut MemSim, _master_core: usize) -> Time {
        0
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                for _ in 0..3 {
                    ctx.spawn(TaskDesc::leaf(1));
                }
                ctx.taskwait();
                ctx.compute(100);
            }
            1 => ctx.compute(8_000),
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Satellite regression (duplicate-victim dedup): a registered scheduler
/// whose `steal_bias` hook emits each victim twice must not make the
/// engine probe and lock the same pool twice per sweep — duplicates are
/// dropped keeping the first occurrence, so its run is byte-identical to
/// the same scheduler without the duplication (the old code only
/// filtered out-of-range ids and double-charged contention for dupes).
#[test]
fn duplicate_bias_victims_are_probed_once() {
    sched::register(
        SchedulerInfo::new("test-dup-bias", "dedup regression: duplicating bias hook"),
        |_| Ok(Box::new(DupBias { clean: false })),
    )
    .unwrap();
    sched::register(
        SchedulerInfo::new("test-dup-bias-clean", "dedup regression: well-behaved twin"),
        |_| Ok(Box::new(DupBias { clean: true })),
    )
    .unwrap();

    let run = |name: &str| {
        let topo = Topology::from_edges("dual", vec![2, 2], &[(0, 1)], 4096).unwrap();
        let rt = Runtime::new(topo, CostModel::default());
        let sched = sched::build(&SchedSpec::new(name)).unwrap();
        let mut w = FanOut;
        Session::execute_bound_placed(
            &rt,
            &mut w,
            sched.as_ref(),
            &[0, 1, 2, 3],
            false,
            &MemSpec::default(),
            11,
            None,
        )
        .unwrap()
    };
    let dup = run("test-dup-bias");
    let clean = run("test-dup-bias-clean");
    assert!(clean.steals > 0, "the fan-out must actually be stolen");
    assert_eq!(dup.steals, clean.steals);
    assert_eq!(
        dup.steal_attempts, clean.steal_attempts,
        "a duplicated victim must be probed once, not twice"
    );
    assert_eq!(
        dup.overhead_time, clean.overhead_time,
        "double-locking a victim would double-charge contention"
    );
    assert_eq!(dup.lock_wait_total, clean.lock_wait_total);
    assert_eq!(dup.makespan, clean.makespan);
    assert_eq!(dup.sim_events, clean.sim_events);
    assert_eq!(dup.per_worker_tasks, clean.per_worker_tasks);
}

/// Spawn-batch workload: root fires four sibling spawns all hinted on
/// the same node-1 data, then a long plain leaf L that keeps the master
/// busy while the node-1 worker drains the pushes.  Kinds: 0 root,
/// 1 sibling, 2 L.
struct BatchSiblings {
    data: Region,
}

impl Workload for BatchSiblings {
    fn name(&self) -> &'static str {
        "batch-siblings"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.data = mem.alloc(64 * 1024);
        mem.first_touch(master_core, self.data, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                for _ in 0..4 {
                    ctx.spawn_on(TaskDesc::leaf(1), self.data);
                }
                ctx.spawn(TaskDesc::leaf(2)); // L: W0 stays busy for 100 us
                ctx.taskwait();
                ctx.compute(100);
            }
            1 => ctx.compute(10_000),
            2 => ctx.compute(100_000),
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Satellite regression (batch-aware place), hand-traced: all pages
/// bound to node 1, so every sibling push targets the lone node-1
/// worker.  `spawn_batch=1` pays four singleton transfers of
/// `queue_op + hops*steal_per_hop` each; `spawn_batch=4` coalesces them
/// into one flush charging `queue_op + 4*hops*steal_per_hop` — same
/// four `pushed_home`, same FIFO arrival order, and exactly
/// `3 * queue_op` less spawn-path overhead (every other charge in the
/// trace is identical: W1 drains the four siblings and steals the root
/// continuation at ~40 us, W0 re-steals it after L at ~100 us, in both
/// configurations).
#[test]
fn sibling_pushes_coalesce_under_one_transfer() {
    let topo = Topology::from_edges("pair", vec![1, 1], &[(0, 1)], 4096).unwrap();
    let rt = Runtime::new(topo, fast_queue_cost());
    let run = |spawn_batch: f64| {
        let sched = sched::build(
            &SchedSpec::new("numa-home").with_param("spawn_batch", spawn_batch),
        )
        .unwrap();
        let mut w = BatchSiblings { data: Region::EMPTY };
        Session::execute_bound_placed(
            &rt,
            &mut w,
            sched.as_ref(),
            &[0, 1],
            false,
            &MemSpec::new("bind").with_param("node", 1.0),
            5,
            None,
        )
        .unwrap()
    };
    let single = run(1.0);
    let batched = run(4.0);

    // the batch changes transfer accounting, never placement or order
    for stats in [&single, &batched] {
        assert_eq!(stats.tasks, 6, "root + 4 siblings + L");
        assert_eq!(stats.pushed_home, 4, "every sibling still counts as pushed");
        assert_eq!(stats.steals, 2, "W1 takes the root continuation; W0 re-steals it");
        assert_eq!(stats.per_worker_tasks, vec![2, 4]);
        assert_eq!(stats.batch_steals, 0, "spawn batching is not steal batching");
        assert_eq!(stats.homed_resumes, 0);
        assert_eq!(stats.mailbox_hits, 0);
    }
    // one lock + one queue op per batch instead of four: the saved cost
    // is exactly the three coalesced queue ops
    assert_eq!(
        single.overhead_time - batched.overhead_time,
        3 * 5 * NS,
        "a batch of 4 must save 3 queue ops over singleton pushes"
    );
    assert!(batched.makespan < single.makespan, "the spawn path got shorter");
    let again = run(4.0);
    assert_eq!(batched.makespan, again.makespan);
    assert_eq!(batched.sim_events, again.sim_events);
    assert_eq!(batched.overhead_time, again.overhead_time);
}

/// Mailbox-accounting workload for the trident topology (worker nodes
/// n0/n1 both one hop from worker-less n2; the master alone on n3).
/// The root load-shapes the two teams, P runs on the n1 team and waits
/// homed on the n2 data, the master's long filler H probes
/// `home_worker(2)` with a fresh hinted spawn while P's continuation
/// sits in n0's mailbox.  Kinds: 0 root, 1 GA, 2 GB, 3 P, 4 C, 5 Q,
/// 6 H, 7 S.
struct MailboxLoad {
    d2: Region,
    d0: Region,
    d3: Region,
}

impl Workload for MailboxLoad {
    fn name(&self) -> &'static str {
        "mailbox-load"
    }

    fn init(&mut self, mem: &mut MemSim, _master_core: usize) -> Time {
        self.d2 = mem.alloc(64 * 1024);
        self.d0 = mem.alloc(64 * 1024);
        self.d3 = mem.alloc(64 * 1024);
        // first-touch from core 2 (worker-less node 2), core 0 (node 0)
        // and core 3 (the master's node 3)
        let mut t = mem.first_touch(2, self.d2, 0);
        t += mem.first_touch(0, self.d0, 0);
        t += mem.first_touch(3, self.d3, 0);
        t
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                ctx.spawn_on(TaskDesc::leaf(1), self.d0); // GA -> W1 (n0)
                ctx.spawn_on(TaskDesc::leaf(3), self.d2); // P  -> W2 (n1 lighter)
                ctx.spawn_on(TaskDesc::leaf(5), self.d0); // Q  -> W1 (n0)
                ctx.spawn_on(TaskDesc::leaf(2), self.d2); // GB -> W2 (n1 lighter)
                // H is homed on the master's own node: the depth-first
                // switch keeps the master busy to ~65 us with no pool
                // acquire, parking the root continuation for thieves
                ctx.spawn_on(TaskDesc::leaf(6), self.d3);
                ctx.taskwait();
                ctx.compute(100);
            }
            1 => ctx.compute(40_000), // GA: node-0 team busy until ~40 us
            2 => ctx.compute(40_000), // GB: node-1 team busy until ~41 us
            3 => {
                // P: the early compute lets both fillers start before
                // C's placement reads the pools
                ctx.compute(1_000);
                // C is homed on n3 and lands behind the parked root in
                // the busy master's pool: the only stealable work when
                // W2 idles at ~41 us
                ctx.spawn_on(TaskDesc::leaf(4), self.d3);
                ctx.taskwait();
                ctx.read(self.d2);
                ctx.compute(500);
            }
            4 => ctx.compute(20_000), // C: releases P from W2 at ~61 us
            5 => ctx.compute(25_000), // Q: n0 can't drain its mail before ~65 us
            6 => {
                ctx.compute(62_000); // H probes at ~62 us: release < probe < drain
                ctx.spawn_on(TaskDesc::leaf(7), self.d2);
                ctx.compute(3_000);
                ctx.taskwait();
                ctx.compute(100);
            }
            7 => {
                // the discriminator: served at 2 hops iff placed on n1
                ctx.read(self.d0);
                ctx.compute(100);
            }
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Satellite regression (mailbox-aware load accounting), hand-traced:
/// node 2 holds the data but no workers, so `home_worker(2)` arbitrates
/// between the n0 and n1 teams.  W2 (n1) runs P to its taskwait, steals
/// C from the busy master's pool at ~41 us (the only non-empty victim)
/// and completes it at ~61 us: P's release reads a 0/0 tie and homes
/// the continuation into n0's mailbox — W1 is mid-Q until ~65 us, so
/// when the master's filler H spawns its d2-hinted probe S at ~62 us
/// the loads read n0 = 0 pool + 1 mail vs n1 = 0, and S is pushed to
/// the n1 team, whose d0 read is then served across two hops.  Ignoring
/// pending mail (the old accounting) reads the same 0/0 tie and pushes
/// S onto the very team that already owes a homed continuation, and the
/// read stays local.  Every steal sweep in the trace sees exactly one
/// non-empty victim pool, so the randomized victim order can't change
/// any of the asserted counters; the post-65 us mop-up (who re-steals
/// the root and H continuations) is wake-vs-probe sensitive and is
/// deliberately left unpinned.
#[test]
fn pending_mailbox_continuations_count_as_team_load() {
    let topo = Topology::from_edges(
        "trident",
        vec![1, 1, 1, 1],
        &[(0, 2), (1, 2), (0, 3)],
        4096,
    )
    .unwrap();
    let rt = Runtime::new(topo, fast_queue_cost());
    let run = || {
        let sched = sched::build(&SchedSpec::new("numa-home")).unwrap();
        let mut w = MailboxLoad { d2: Region::EMPTY, d0: Region::EMPTY, d3: Region::EMPTY };
        Session::execute_bound_placed(
            &rt,
            &mut w,
            sched.as_ref(),
            // master on n3 (never a home_worker(2) pick), teams on n0 and n1
            &[3, 0, 1],
            false,
            &MemSpec::default(),
            13,
            None,
        )
        .unwrap()
    };
    let stats = run();
    assert_eq!(stats.tasks, 8, "root + GA + GB + Q + H + P + C + probe");
    assert_eq!(
        stats.pushed_home, 6,
        "GA, P, Q, GB, C, S — H alone takes the local depth-first path"
    );
    assert_eq!(stats.homed_resumes, 1, "P's continuation redirects toward its data");
    assert_eq!(stats.mailbox_hits, 1, "W1 drains P from n0's mailbox after Q");
    assert!(
        stats.mem.miss_lines_by_hop[2] > 0,
        "S read its n0 operand from the n1 team: the mailbox entry counted as load"
    );
    assert_eq!(
        stats.mem.miss_lines_by_hop[1], stats.mem.miss_lines_by_hop[2],
        "P's 1-hop d2 read and S's 2-hop d0 read are the same cold 64 KiB stream"
    );
    assert_eq!(stats.affinity_hits, 1, "only H is spawned on its data's node");
    assert!(stats.steals >= 2, "W2 must at least take C and the root continuation");
    assert_eq!(stats.affine_steals, 0, "nothing stolen was homed on its thief's node");
    assert_eq!(stats.batch_steals, 0);
    assert_eq!(stats.tasks_migrated, 0);
    let again = run();
    assert_eq!(stats.makespan, again.makespan);
    assert_eq!(stats.sim_events, again.sim_events);
    assert_eq!(stats.mailbox_hits, again.mailbox_hits);
    assert_eq!(stats.per_worker_tasks, again.per_worker_tasks);
}
