//! Engine-level regressions for the locality refactor: the
//! tied-continuation wake-targeting fix (a release used to signal an
//! arbitrary round-robin sleeper, which under bounded-sweep schedulers
//! strands the continuation and charges phantom steal overhead), and
//! deterministic engagement of the `resume` / `steal_bias` hooks with
//! their `homed_resumes` / `affine_steals` counters.
//!
//! The workloads are hand-built task graphs over hand-built topologies:
//! every cross-worker ordering below is separated by tens of
//! microseconds of simulated compute, far above the sub-microsecond
//! queue-op costs, so the traces (and the asserted counters) are stable
//! under any reasonable cost model.

use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::{self, SchedSpec};
use numanos::coordinator::task::{BodyCtx, TaskDesc, Workload};
use numanos::simnuma::{CostModel, MemSim, MemSpec, Region};
use numanos::spec::Session;
use numanos::topology::Topology;
use numanos::util::Time;

/// Root spawns A (which parks its worker until late via a 5 us
/// grandchild) and B (a 50 us leaf); the root continuation ends up
/// `Waiting` on a worker two hops from A's worker.  Kinds: 0 root, 1 A,
/// 2 B, 3 A2.
struct TiedOwner;

impl Workload for TiedOwner {
    fn name(&self) -> &'static str {
        "tied-owner"
    }

    fn init(&mut self, _mem: &mut MemSim, _master_core: usize) -> Time {
        0
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                ctx.spawn(TaskDesc::leaf(1)); // A
                ctx.spawn(TaskDesc::leaf(2)); // B
                ctx.taskwait();
                ctx.compute(500);
            }
            1 => {
                // A suspends on a grandchild so its owner's final acquire
                // (and with it A's completion — the root release) lands
                // late in event order, after every other worker parked
                ctx.compute(1_000);
                ctx.spawn(TaskDesc::leaf(3)); // A2
                ctx.taskwait();
                ctx.compute(100);
            }
            2 => ctx.compute(50_000), // B: keeps its runner's clock far out
            3 => ctx.compute(5_000),  // A2
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Satellite regression (wake targeting): when a tied continuation is
/// released while its owner sleeps, the owner must be woken directly.
///
/// Topology: a chain n0—n1—n2 plus a tail n0—n3—n4; threads bound to
/// cores on n0/n1/n2/n4.  Under `hops-threshold:max_hops=1`,
/// W0(n0)↔W1(n1) and W1(n1)↔W2(n2) can steal from each other but
/// W0↔W2 (2 hops) and W3(n4, ≥2 hops from everyone) cannot.
///
/// Trace: W1 steals the root from W0 and re-exposes it spawning B; W2
/// steals it, hits the taskwait (owner = W2) and sleeps.  A completes on
/// W0 — two hops from W2, so W0's own sweep cannot reach the
/// continuation.  The old code signalled the round-robin sleeper (W3,
/// whose sweep is empty), stranding the continuation until W1's acquire
/// 40+ us later re-stole it: a third steal, inflated attempts, and the
/// post phase running off-owner.  With the targeted wake W2 resumes its
/// own continuation and no third steal exists.
#[test]
fn tied_continuation_release_wakes_its_sleeping_owner() {
    let topo = Topology::from_edges(
        "chain-tail",
        vec![1, 1, 1, 1, 1],
        &[(0, 1), (1, 2), (0, 3), (3, 4)],
        2048,
    )
    .unwrap();
    let rt = Runtime::new(topo, CostModel::default());
    let sched = sched::build(
        &SchedSpec::new("hops-threshold")
            .with_param("max_hops", 1.0)
            .with_param("spill_after", 1000.0),
    )
    .unwrap();
    let mut w = TiedOwner;
    let stats = Session::execute_bound_placed(
        &rt,
        &mut w,
        sched.as_ref(),
        &[0, 1, 2, 4],
        false,
        &MemSpec::default(),
        7,
        None,
    )
    .unwrap();

    assert_eq!(stats.tasks, 4, "root + A + B + A2");
    // root stolen twice on its way to W2; never a third time
    assert_eq!(stats.steals, 2, "the continuation must not be re-stolen");
    // W0 ran A2 and A, W1 ran B, W2 — the owner — ran the continuation
    assert_eq!(stats.per_worker_tasks, vec![2, 1, 1, 0]);
    // the woken-wrong-worker path charged its probes to steal_attempts;
    // the targeted wake keeps the sweep count at the structural minimum
    assert!(
        stats.steal_attempts <= 5,
        "phantom sweeps inflate steal_attempts: {}",
        stats.steal_attempts
    );
    // no placement machinery involved for a non-placing scheduler
    assert_eq!(stats.pushed_home, 0);
    assert_eq!(stats.homed_resumes, 0);
    assert_eq!(stats.affine_steals, 0);
}

/// Placement workload for the resume hook: root pushes P to its data's
/// node, keeps itself busy with Q, then steals P back — so P waits on
/// the *wrong* node and its release must be redirected home.  Kinds:
/// 0 root, 1 P, 2 Q, 3 C, 4 C2.
struct HomedResume {
    data: Region,
}

impl Workload for HomedResume {
    fn name(&self) -> &'static str {
        "homed-resume"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.data = mem.alloc(64 * 1024);
        mem.first_touch(master_core, self.data, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                ctx.spawn_on(TaskDesc::leaf(1), self.data); // P -> pushed home
                ctx.spawn(TaskDesc::leaf(2)); // Q keeps the master busy
                ctx.taskwait();
                ctx.compute(100);
            }
            1 => {
                ctx.spawn_on(TaskDesc::leaf(3), self.data); // C (affinity hit)
                ctx.taskwait();
                ctx.read(self.data); // the continuation combines the data
            }
            2 => ctx.compute(10_000), // Q
            3 => {
                ctx.compute(100);
                ctx.spawn(TaskDesc::leaf(4)); // C2 delays C's completion
                ctx.taskwait();
                ctx.compute(50);
            }
            4 => ctx.compute(15_000), // C2
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Tentpole regression (resume hook): a tied continuation whose cached
/// home differs from its owner's node is released to a home-node worker
/// and counted in `homed_resumes`.  Two nodes, one core each; all pages
/// bound to node 1, so P (hinted on the data) is homed on n1 while its
/// taskwait owner ends up being W0 on n0.
#[test]
fn numa_home_redirects_waiting_continuations_to_their_data() {
    let topo = Topology::from_edges("pair", vec![1, 1], &[(0, 1)], 4096).unwrap();
    let rt = Runtime::new(topo, CostModel::default());
    let sched = sched::build(&SchedSpec::new("numa-home")).unwrap();
    let run = || {
        let mut w = HomedResume { data: Region::EMPTY };
        Session::execute_bound_placed(
            &rt,
            &mut w,
            sched.as_ref(),
            &[0, 1],
            false,
            &MemSpec::new("bind").with_param("node", 1.0),
            3,
            None,
        )
        .unwrap()
    };
    let stats = run();
    assert_eq!(stats.tasks, 5);
    assert_eq!(stats.pushed_home, 1, "P's spawn must be pushed to its home node");
    assert_eq!(stats.affinity_hits, 1, "C spawned on the node its data lives on");
    assert_eq!(
        stats.homed_resumes, 1,
        "P's continuation must be released toward node 1, not its owner on node 0"
    );
    // deterministic: same spec, same counters
    let again = run();
    assert_eq!(stats.makespan, again.makespan);
    assert_eq!(stats.steals, again.steals);
    assert_eq!(stats.homed_resumes, again.homed_resumes);
}

/// Steal-bias workload: M is spawned with a node-1 affinity hint and
/// suspends in W0's pool behind the root; W1 (on node 1) drains the pool
/// and its second steal takes M — an affine steal.  Kinds: 0 root, 1 M,
/// 2 L.
struct AffineSteal {
    data: Region,
}

impl Workload for AffineSteal {
    fn name(&self) -> &'static str {
        "affine-steal"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.data = mem.alloc(64 * 1024);
        mem.first_touch(master_core, self.data, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::leaf(0)
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            0 => {
                ctx.spawn_on(TaskDesc::leaf(1), self.data); // M, homed on n1
                ctx.taskwait();
                ctx.compute(200);
            }
            1 => {
                ctx.spawn(TaskDesc::leaf(2)); // L parks W0 far out
                ctx.taskwait();
                ctx.read(self.data);
            }
            2 => ctx.compute(30_000), // L
            _ => unreachable!("unknown task kind"),
        }
    }
}

/// Tentpole regression (steal bias + home tags): `numa-steal` never
/// pushes or redirects, but a steal that lands a task on its data's home
/// node is counted in `affine_steals` via the spawn-time home tag.
#[test]
fn numa_steal_counts_affine_steals_without_placing() {
    let topo = Topology::from_edges("pair", vec![1, 1], &[(0, 1)], 4096).unwrap();
    let rt = Runtime::new(topo, CostModel::default());
    let sched = sched::build(&SchedSpec::new("numa-steal")).unwrap();
    let mut w = AffineSteal { data: Region::EMPTY };
    let stats = Session::execute_bound_placed(
        &rt,
        &mut w,
        sched.as_ref(),
        &[0, 1],
        false,
        &MemSpec::new("bind").with_param("node", 1.0),
        3,
        None,
    )
    .unwrap();
    assert_eq!(stats.tasks, 3);
    assert_eq!(stats.steals, 2, "W1 steals the root, then M");
    assert_eq!(stats.affine_steals, 1, "M (homed on n1) stolen by the n1 worker");
    assert_eq!(stats.pushed_home, 0, "steal-side-only: no push-to-home");
    assert_eq!(stats.homed_resumes, 0, "steal-side-only: continuations stay tied");
}
