//! Million-task stress: the hot-loop overhaul's end-to-end guarantee.
//!
//! A ≥1M-task graph (fib at the perf-xl scale) must complete through the
//! ordinary engine with (a) **exact task-count conservation** — every
//! spawn retired, pinned against the closed-form tree size — and (b)
//! **bounded arena growth**: the free-list recycles task slots, so the
//! arena's high-water mark stays orders of magnitude below the total
//! task count instead of scaling with it.
//!
//! Debug builds scale the input down (the graph shape and both
//! assertions are identical); `--release` runs the true perf-xl input,
//! 1,028,457 tasks.

use numanos::bots::fib::{self, Fib};
use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;

#[test]
fn million_task_graph_completes_with_bounded_arena() {
    // release: the perf-xl fib cell (n=40, cutoff=14); debug: same shape
    // four halvings down, so `cargo test` stays fast
    let (n, cutoff) = if cfg!(debug_assertions) { (32, 14) } else { (40, 14) };
    let expected = fib::task_count(n, cutoff);
    if !cfg!(debug_assertions) {
        assert!(expected > 1_000_000, "perf-xl fib must be a >1M-task graph");
    }

    let rt = Runtime::paper_testbed();
    let mut w = Fib::with_params(n, cutoff);
    let stats = rt.run(&mut w, Policy::WorkFirst, BindPolicy::NumaAware, 16, 42, None).unwrap();

    // exact conservation: every spawned task was created exactly once
    // and retired — a leak, double-retire, or lost continuation moves it
    assert_eq!(stats.tasks, expected, "task count must match the closed-form tree size");

    // bounded growth: live tasks are the suspended spawn chains plus
    // queued children — O(depth × workers), not O(total tasks).  The ×8
    // bound is loose (measured peaks are far lower) but scales with the
    // input, so the debug-sized run pins the same property.
    assert!(
        (stats.peak_live as u64) * 8 < stats.tasks,
        "arena high-water mark {} is not far below {} tasks — free-list recycling broken?",
        stats.peak_live,
        stats.tasks
    );

    // the engine retires at least one event per task (spawn→run→retire
    // all ride the event loop); a million-task run that under-counts
    // events means the queue dropped work
    assert!(stats.sim_events >= stats.tasks, "events {} < tasks {}", stats.sim_events, stats.tasks);
}

#[test]
fn xl_size_maps_to_the_million_task_input() {
    // the Size::XL arm and the closed-form count stay in lock-step with
    // the perf-xl bench cells (which run fib at Size::XL)
    let _ = Fib::new(Size::XL); // constructible
    assert_eq!(fib::task_count(40, 14), 1_028_457);
}
