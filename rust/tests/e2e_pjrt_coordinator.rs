//! End-to-end: real numerics scheduled by the simulated coordinator.
//!
//! The same path as `examples/e2e_compute.rs`, as a test: leaf tasks call
//! the AOT kernels through PJRT while the discrete-event engine decides
//! ordering and placement; `Workload::verify` checks the math afterwards.

use numanos::bots::{fft::Fft, sort::Sort, sparselu, strassen::Strassen};
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::runtime::ExecEngine;

fn engine() -> Option<ExecEngine> {
    let dir = std::env::var("NUMANOS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing in '{dir}' — run `make artifacts` first");
        return None;
    }
    match ExecEngine::cpu(dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping: PJRT engine unavailable ({e})");
            None
        }
    }
}

#[test]
fn sparselu_real_factorization_through_scheduler() {
    let Some(mut exec) = engine() else { return };
    let rt = Runtime::paper_testbed();
    // run under two different schedulers: the *numeric* result must be
    // valid under both orderings (dependency correctness of the runtime)
    for policy in [Policy::WorkFirst, Policy::Dfwsrpt] {
        let mut lu = sparselu::SparseLu::with_params(4, sparselu::Variant::Single);
        let stats = rt
            .run(&mut lu, policy, BindPolicy::NumaAware, 8, 7, Some(&mut exec))
            .unwrap_or_else(|e| panic!("{}: {e}", policy.name()));
        assert!(stats.kernel_calls > 5, "{}", policy.name());
    }
}

#[test]
fn strassen_real_product_through_scheduler() {
    let Some(mut exec) = engine() else { return };
    let rt = Runtime::paper_testbed();
    let mut st = Strassen::with_params(512, 128);
    let stats = rt
        .run(&mut st, Policy::Dfwspt, BindPolicy::NumaAware, 8, 3, Some(&mut exec))
        .unwrap();
    assert!(stats.kernel_calls >= 49, "every leaf carries a kernel tag");
}

#[test]
fn sort_and_fft_leaves_verify() {
    let Some(mut exec) = engine() else { return };
    let rt = Runtime::paper_testbed();
    let mut so = Sort::with_params(1 << 14, 1 << 10, 1 << 10);
    rt.run(&mut so, Policy::CilkBased, BindPolicy::Linear, 4, 5, Some(&mut exec)).unwrap();
    let mut ff = Fft::with_params(1 << 13, 1 << 12, 1 << 10);
    rt.run(&mut ff, Policy::BreadthFirst, BindPolicy::Linear, 4, 5, Some(&mut exec)).unwrap();
}
