//! The locality/placement layer end-to-end: page-policy selection flows
//! spec → session → engine, `numa-home` pushes work to its data, stock
//! schedulers under the default policy stay byte-identical to the legacy
//! execution path, and placement × scheduler × topology sweeps run from
//! manifests.

use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::sched::{self, Policy, SchedSpec};
use numanos::simnuma::MemSpec;
use numanos::spec::{ExperimentManifest, RunSpec, Session};
use numanos::{bots, Runtime};

fn spec(bench: &str, sched: SchedSpec, mem: MemSpec, topo: &str, threads: usize) -> RunSpec {
    RunSpec::builder()
        .bench(bench)
        .size(Size::Small)
        .sched(sched)
        .mem(mem)
        .numa()
        .threads(threads)
        .topo(topo)
        .seed(7)
        .build()
        .unwrap()
}

/// Acceptance criterion (parity half): every stock parallel scheduler
/// with the default `MemSpec` produces byte-identical stats/CSV through
/// the new placement-aware path (with steal-half batching, per-node
/// mailboxes and the dedup/underflow fixes in place) vs. the legacy
/// `Runtime::run` verbs, and an *explicit* `first-touch` selection is
/// indistinguishable from the default.  Rows cover a data-heavy workload
/// (`fft`) plus every annotated workload (`fib`, `uts`, `alignment`,
/// `floorplan`) — their sub-floor spawn hints must stay invisible to
/// stock schedulers.
#[test]
fn stock_schedulers_with_default_mem_match_the_legacy_path() {
    let session = Session::new();
    let rt = Runtime::paper_testbed();
    for bench in ["fft", "fib", "uts", "alignment", "floorplan"] {
        for policy in [
            Policy::BreadthFirst,
            Policy::CilkBased,
            Policy::WorkFirst,
            Policy::Dfwspt,
            Policy::Dfwsrpt,
        ] {
            let s = spec(bench, SchedSpec::stock(policy), MemSpec::default(), "x4600", 8);
            let rec = session.run(&s).unwrap();

            let mut w = bots::create(bench, Size::Small, 7).unwrap();
            let legacy = rt.run(w.as_mut(), policy, BindPolicy::NumaAware, 8, 7, None).unwrap();
            let tag = format!("{bench}/{}", policy.name());
            assert_eq!(rec.stats.makespan, legacy.makespan, "{tag}");
            assert_eq!(rec.stats.steals, legacy.steals, "{tag}");
            assert_eq!(rec.stats.sim_events, legacy.sim_events, "{tag}");
            assert_eq!(rec.stats.work_time, legacy.work_time, "{tag}");
            assert_eq!(rec.stats.overhead_time, legacy.overhead_time, "{tag}");
            // the locality counters stay zero on non-placing schedulers —
            // including the appended batch/migration/mailbox columns
            assert_eq!(rec.stats.pushed_home, 0, "{tag}");
            assert_eq!(rec.stats.affinity_hits, 0, "{tag}");
            assert_eq!(rec.stats.mem.migrated_pages, 0, "{tag}");
            assert_eq!(rec.stats.affine_steals, 0, "{tag}");
            assert_eq!(rec.stats.homed_resumes, 0, "{tag}");
            assert_eq!(rec.stats.batch_steals, 0, "{tag}");
            assert_eq!(rec.stats.tasks_migrated, 0, "{tag}");
            assert_eq!(rec.stats.mailbox_hits, 0, "{tag}");
            let row = rec.to_csv_row();
            assert!(row.ends_with(",0,0,0,0,0"), "stock CSV tail must stay zero: {row}");

            // explicit first-touch is the same run, CSV row and all
            let explicit =
                spec(bench, SchedSpec::stock(policy), MemSpec::new("first-touch"), "x4600", 8);
            let rec2 = session.run(&explicit).unwrap();
            assert_eq!(rec.to_csv_row(), rec2.to_csv_row(), "{tag}");
        }
    }

    // the serial baseline stays on the legacy bytes too (run_serial
    // binds linearly, so the spec must as well)
    let serial = RunSpec::builder()
        .bench("fft")
        .size(Size::Small)
        .sched(SchedSpec::stock(Policy::Serial))
        .linear()
        .threads(1)
        .topo("x4600")
        .seed(7)
        .build()
        .unwrap();
    let rec = session.run(&serial).unwrap();
    let mut w = bots::create("fft", Size::Small, 7).unwrap();
    let legacy = rt.run_serial(w.as_mut(), 7).unwrap();
    assert_eq!(rec.stats.makespan, legacy.makespan, "serial");
    assert_eq!(rec.stats.sim_events, legacy.sim_events, "serial");
    assert!(rec.to_csv_row().ends_with(",0,0,0,0,0"), "serial CSV tail must stay zero");
}

/// The fib/uts/alignment/floorplan annotations are real but deliberately
/// sub-floor: their hint regions (256-byte config pages, sub-KB
/// sequences, the 8 KB board) sit below every placement scheduler's
/// default `min_kb=16` hint floor (so defaults behave exactly as before),
/// yet lowering the floor to 0 makes the same hints engage the placement
/// machinery.
#[test]
fn annotated_hints_sit_below_the_default_floor_but_exist() {
    let session = Session::new();
    for bench in ["fib", "uts", "alignment", "floorplan"] {
        let default_floor =
            session.run(&spec(bench, SchedSpec::new("numa-home"), MemSpec::default(), "x4600", 16));
        let rec = default_floor.unwrap();
        assert_eq!(rec.stats.pushed_home, 0, "{bench}: hints sit below min_kb=16");
        assert_eq!(rec.stats.affinity_hits, 0, "{bench}: hints sit below min_kb=16");

        let no_floor = session
            .run(&spec(
                bench,
                SchedSpec::new("numa-home").with_param("min_kb", 0.0),
                MemSpec::default(),
                "x4600",
                16,
            ))
            .unwrap();
        assert!(
            no_floor.stats.pushed_home + no_floor.stats.affinity_hits > 0,
            "{bench}: with min_kb=0 the spawn hints must engage placement \
             (pushed_home={}, affinity_hits={})",
            no_floor.stats.pushed_home,
            no_floor.stats.affinity_hits
        );
    }
}

/// Acceptance criterion (gain half): `numa-home` + first-touch achieves a
/// lower remote-access ratio than breadth-first on a BOTS workload over a
/// multi-node fabric — the paper's point that placement, not just steal
/// order, cuts remote traffic.  The steal-bias + homed-resume extensions
/// must not give back what the push-to-home half won: the full strategy
/// stays at or below the placement-only configuration (`steal_bias=0`,
/// `homed_resume=0` — the pre-extension behaviour as a spec).
#[test]
fn numa_home_beats_bf_remote_ratio_on_sparselu() {
    let session = Session::new();
    let bf = session
        .run(&spec("sparselu_for", SchedSpec::stock(Policy::BreadthFirst),
            MemSpec::default(), "x4600", 16))
        .unwrap();
    let home = session
        .run(&spec("sparselu_for", SchedSpec::new("numa-home"), MemSpec::default(),
            "x4600", 16))
        .unwrap();
    let place_only = session
        .run(&spec(
            "sparselu_for",
            SchedSpec::new("numa-home")
                .with_param("steal_bias", 0.0)
                .with_param("homed_resume", 0.0),
            MemSpec::default(),
            "x4600",
            16,
        ))
        .unwrap();
    assert!(home.stats.pushed_home > 0, "placement must actually engage");
    assert!(
        home.stats.mem.remote_ratio() < bf.stats.mem.remote_ratio(),
        "numa-home {:.3} must beat bf {:.3}",
        home.stats.mem.remote_ratio(),
        bf.stats.mem.remote_ratio()
    );
    assert!(
        home.stats.mem.remote_ratio() <= place_only.stats.mem.remote_ratio(),
        "steal-bias + homed resumes {:.4} must not regress placement-only {:.4}",
        home.stats.mem.remote_ratio(),
        place_only.stats.mem.remote_ratio()
    );
    // the disabled configuration really disabled the new machinery
    assert_eq!(place_only.stats.homed_resumes, 0);
}

/// `numa-steal` (steal-side only) engages on a real workload: sweeps are
/// biased by home tags, nothing is ever pushed or redirected, and the
/// remote ratio lands at or below plain `dfwsrpt` (same base sweep, no
/// locality) on the steal-heavy sort benchmark.
#[test]
fn numa_steal_biases_sweeps_without_pushing() {
    let session = Session::new();
    let plain = session
        .run(&spec("sort", SchedSpec::stock(Policy::Dfwsrpt), MemSpec::default(), "x4600", 16))
        .unwrap();
    let biased = session
        .run(&spec("sort", SchedSpec::new("numa-steal"), MemSpec::default(), "x4600", 16))
        .unwrap();
    assert_eq!(biased.stats.pushed_home, 0, "steal-side-only never pushes");
    assert_eq!(biased.stats.homed_resumes, 0, "steal-side-only never redirects");
    assert!(biased.stats.steals > 0, "sort at 16 threads must steal");
    assert!(
        biased.stats.mem.remote_ratio() <= plain.stats.mem.remote_ratio() * 1.05,
        "steal bias {:.4} should not materially regress dfwsrpt {:.4}",
        biased.stats.mem.remote_ratio(),
        plain.stats.mem.remote_ratio()
    );
}

/// Steal-half batching engages on a real workload: with every page bound
/// to node 1, all hinted tasks are homed there, so node-1 thieves see
/// fully affine victim pools and a `batch` above 1 drains them in bulk.
/// The batch counters move together (each batched steal migrates at
/// least one extra task) and the default batch stays byte-inert.
#[test]
fn numa_steal_batches_on_deep_affine_pools() {
    let session = Session::new();
    let bound = MemSpec::new("bind").with_param("node", 1.0);
    let batched = session
        .run(&spec(
            "sort",
            SchedSpec::new("numa-steal").with_param("batch", 8.0),
            bound.clone(),
            "x4600",
            16,
        ))
        .unwrap();
    assert!(batched.stats.steals > 0, "sort at 16 threads must steal");
    assert!(
        batched.stats.batch_steals > 0,
        "bound pages + batch=8 must produce at least one multi-task steal"
    );
    assert!(
        batched.stats.tasks_migrated >= batched.stats.batch_steals,
        "every batched steal moves at least one extra task: {} vs {}",
        batched.stats.tasks_migrated,
        batched.stats.batch_steals
    );
    // batch=1 (the default) keeps the single-steal path: zero batches
    let single = session
        .run(&spec("sort", SchedSpec::new("numa-steal"), bound, "x4600", 16))
        .unwrap();
    assert_eq!(single.stats.batch_steals, 0);
    assert_eq!(single.stats.tasks_migrated, 0);
}

/// Per-scheduler determinism regression, extended to `numa-home`, the
/// steal-biased `numa-steal` and the adaptive `numa-adapt` across the
/// multi-node presets — including the heterogeneous x4600 variant (the
/// satellite requirement): same spec, fresh sessions, identical records.
#[test]
fn numa_home_is_deterministic_across_topologies() {
    for sched_name in ["numa-home", "numa-steal", "numa-adapt"] {
        for topo in ["x4600", "x4600_hetero", "tile16", "altix16"] {
            let s = spec("sort", SchedSpec::new(sched_name), MemSpec::default(), topo, 8);
            let a =
                Session::new().run(&s).unwrap_or_else(|e| panic!("{sched_name}/{topo}: {e:#}"));
            let b =
                Session::new().run(&s).unwrap_or_else(|e| panic!("{sched_name}/{topo}: {e:#}"));
            assert_eq!(a.stats.makespan, b.stats.makespan, "{sched_name}/{topo}");
            assert_eq!(a.stats.steals, b.stats.steals, "{sched_name}/{topo}");
            assert_eq!(a.stats.pushed_home, b.stats.pushed_home, "{sched_name}/{topo}");
            assert_eq!(a.stats.affine_steals, b.stats.affine_steals, "{sched_name}/{topo}");
            assert_eq!(a.stats.homed_resumes, b.stats.homed_resumes, "{sched_name}/{topo}");
            assert_eq!(a.stats.batch_steals, b.stats.batch_steals, "{sched_name}/{topo}");
            assert_eq!(a.stats.tasks_migrated, b.stats.tasks_migrated, "{sched_name}/{topo}");
            assert_eq!(a.stats.mailbox_hits, b.stats.mailbox_hits, "{sched_name}/{topo}");
            assert_eq!(a.stats.sim_events, b.stats.sim_events, "{sched_name}/{topo}");
            assert_eq!(a.to_csv_row(), b.to_csv_row(), "{sched_name}/{topo}");
            assert_eq!(
                a.to_json().to_compact(),
                b.to_json().to_compact(),
                "{sched_name}/{topo}"
            );
            assert!(a.stats.makespan > 0, "{sched_name}/{topo}");
        }
    }
}

/// Every page policy completes every-scheduler-agnostic workloads and the
/// policy choice is visible in the record surface (CSV axis column + the
/// counter tail).
#[test]
fn every_page_policy_runs_and_reports() {
    let session = Session::new();
    for (mem, expect_migrations) in [
        (MemSpec::default(), false),
        (MemSpec::new("interleave"), false),
        (MemSpec::new("bind").with_param("node", 2.0), false),
        (MemSpec::new("next-touch").with_param("max_moves", 1.0), true),
    ] {
        let s = spec("sort", SchedSpec::stock(Policy::WorkFirst), mem.clone(), "x4600", 8);
        let rec = session.run(&s).unwrap_or_else(|e| panic!("{}: {e:#}", mem.name_sig()));
        assert!(rec.stats.makespan > 0, "{}", mem.name_sig());
        let row = rec.to_csv_row();
        assert!(row.contains(&mem.name_sig()), "{}: {row}", mem.name_sig());
        if expect_migrations {
            assert!(
                rec.stats.mem.migrated_pages > 0,
                "next-touch must migrate on sort's cross-node re-touches"
            );
        } else {
            assert_eq!(rec.stats.mem.migrated_pages, 0, "{}", mem.name_sig());
        }
    }
}

/// The serial-baseline memo distinguishes page policies: speedups inside
/// a placement sweep normalize against a baseline that paid the same
/// allocation behaviour.
#[test]
fn baselines_are_keyed_by_page_policy() {
    let session = Session::new();
    let ft = spec("fib", SchedSpec::stock(Policy::WorkFirst), MemSpec::default(), "x4600", 4);
    let il = spec("fib", SchedSpec::stock(Policy::WorkFirst), MemSpec::new("interleave"),
        "x4600", 4);
    let a = session.baseline(&ft).unwrap();
    let b = session.baseline(&il).unwrap();
    assert!(!std::sync::Arc::ptr_eq(&a, &b), "distinct memo entries per policy");
}

/// Acceptance criterion: placement is a full sweep axis — a JSON manifest
/// sweeping page policy × scheduler × topology expands, runs end-to-end,
/// and the CSV carries the new axis + counter columns.
#[test]
fn placement_sweep_manifest_end_to_end() {
    let manifest = ExperimentManifest::from_json_str(
        r#"{
          "title": "placement grid",
          "defaults": {"size": "small", "seeds": [3]},
          "sweeps": [
            {"id": "grid", "bench": "sparselu_for",
             "sched": ["bf", "dfwsrpt", "numa-home"],
             "mem": ["first-touch", "interleave"],
             "bind": ["numa"], "threads": [8],
             "topos": ["x4600", "altix16"]}
          ]
        }"#,
    )
    .unwrap();
    assert_eq!(manifest.sweeps.len(), 2, "one sweep per topology");
    assert_eq!(manifest.all_cells().unwrap().len(), 2 * 3 * 2);

    let session = Session::new();
    for sweep in &manifest.sweeps {
        let result = session.run_sweep_with(sweep, 2).unwrap();
        assert_eq!(result.records.len(), 6);
        let csv = result.to_csv();
        let header = csv.lines().next().unwrap();
        for col in [
            "mem",
            "pushed_home",
            "affinity_hits",
            "migrated_pages",
            "affine_steals",
            "homed_resumes",
            "batch_steals",
            "tasks_migrated",
            "mailbox_hits",
        ] {
            assert!(header.contains(col), "missing {col} in: {header}");
        }
        assert!(csv.contains("interleave"), "{csv}");
        assert!(csv.contains("numa-home"), "{csv}");
        // sequential re-run is byte-identical (determinism across the axis)
        let seq = session.run_sweep_with(sweep, 1).unwrap();
        assert_eq!(csv, seq.to_csv());
        // multi-mem sweeps disambiguate table rows by policy
        let table = result.table().to_markdown();
        assert!(table.contains("+interleave"), "{table}");
    }
}

/// The tunable-grid helper: `param_grid` expands declared scheduler
/// parameters into sweepable configs without hand-written manifests.
#[test]
fn param_grid_sweeps_end_to_end() {
    let grid = sched::param_grid("hops-threshold", &[("max_hops", &[0.0, 1.0])]).unwrap();
    let sweep = numanos::Sweep::new("hops-grid", "max_hops 0..1")
        .with_bench("fib")
        .with_configs(grid.into_iter().map(|s| (s, BindPolicy::NumaAware)))
        .with_threads(vec![4])
        .with_seeds(vec![2])
        .with_size(Size::Small);
    let result = Session::new().run_sweep(&sweep).unwrap();
    assert_eq!(result.records.len(), 2);
    let csv = result.to_csv();
    assert!(csv.contains("hops-threshold(max_hops=0)"), "{csv}");
    assert!(csv.contains("hops-threshold(max_hops=1)"), "{csv}");
}

/// `numa-home` on a single-node (UMA) machine degenerates gracefully:
/// there is nowhere else to push, so placement never fires.
#[test]
fn numa_home_on_uma_never_pushes() {
    let s = RunSpec::builder()
        .bench("sort")
        .size(Size::Small)
        .sched(SchedSpec::new("numa-home"))
        .threads(8)
        .topo("uma")
        .seed(5)
        .build()
        .unwrap();
    let rec = Session::new().run(&s).unwrap();
    assert_eq!(rec.stats.pushed_home, 0);
    assert!(rec.stats.affinity_hits > 0, "all data is trivially home");
    assert!(rec.stats.makespan > 0);
}
