//! Integration tests for the pinned bench suite (`numanos bench`): suite
//! coverage, `BENCH_*.json` schema round-tripping, run-to-run determinism
//! of the simulated metrics, and the compare policy on real reports.

use numanos::bench::{self, compare::CompareOptions, compare::Status, SuiteReport};
use numanos::spec::Session;

/// The committed BENCH_6.json shape: all nine figures, the four-strategy
/// ablation on four topologies, smoke, and the engine-perf cells — with
/// globally unique ids.
#[test]
fn suite_covers_figures_ablation_and_perf() {
    let entries = bench::suite();
    let figure_groups: Vec<&str> = entries
        .iter()
        .map(|e| e.group.as_str())
        .filter(|g| g.starts_with("fig"))
        .collect();
    assert_eq!(
        figure_groups,
        vec!["fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig13", "fig14", "fig15"],
        "all nine paper figures, in figure order"
    );
    let ablation_cells: usize = entries
        .iter()
        .filter(|e| e.group == "ablation")
        .map(|e| e.sweep.cell_count())
        .sum();
    assert_eq!(ablation_cells, 16, "4 strategies x 4 topologies");
    let mut ids = Vec::new();
    for e in &entries {
        for spec in e.sweep.cells().unwrap() {
            ids.push(bench::cell_id(&e.group, &spec));
        }
    }
    let n = ids.len();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "cell ids are globally unique");
}

/// An executed suite serializes to a document the report parser accepts,
/// with measured sim/wall values and ids matching the committed
/// placeholder's shape for the same cells.
#[test]
fn emitted_document_round_trips_through_the_schema() {
    let session = Session::new();
    let run = bench::run_suite(&session, "smoke", 1).unwrap();
    let doc = run.to_json();
    let report = SuiteReport::parse(&doc.to_pretty()).unwrap();
    assert_eq!(report.suite, bench::SUITE_NAME);
    assert_eq!(report.reps, 1);
    assert_eq!(report.filter, "smoke");
    assert_eq!(report.cells.len(), 2);
    for cell in &report.cells {
        let sim = cell.sim.as_ref().expect("executed cells record sim metrics");
        for key in [
            "makespan",
            "remote_pct",
            "affine_steals",
            "batch_steals",
            "homed_resumes",
            "mailbox_hits",
            "tasks_migrated",
            "pushed_home",
        ] {
            assert!(sim.contains_key(key), "sim must record '{key}'");
        }
        assert!(sim["makespan"] > 0.0);
        assert!(cell.wall_ms.is_some(), "executed cells record wall time");
    }
    assert!(report.total_wall_ms.is_some());

    // the emitted ids are exactly the placeholder's smoke ids: the
    // committed BENCH_6.json and a real run can never disagree on shape
    let placeholder = SuiteReport::from_json(&bench::placeholder_json().unwrap()).unwrap();
    let expect: Vec<&str> = placeholder
        .cells
        .iter()
        .filter(|c| c.group == "smoke")
        .map(|c| c.id.as_str())
        .collect();
    let got: Vec<&str> = report.cells.iter().map(|c| c.id.as_str()).collect();
    assert_eq!(got, expect);
}

/// Two independent runs of the same suite entries produce byte-identical
/// simulated-metric objects (wall time excluded) — the property CI's
/// determinism job leans on.
#[test]
fn suite_runs_are_deterministic_in_their_simulated_metrics() {
    let runs: Vec<_> = (0..2)
        .map(|_| bench::run_suite(&Session::new(), "smoke", 1).unwrap())
        .collect();
    let sims: Vec<Vec<String>> = runs
        .iter()
        .map(|run| {
            run.to_json()
                .get("cells")
                .and_then(|c| c.as_arr().map(<[_]>::to_vec))
                .unwrap()
                .iter()
                .map(|cell| cell.get("sim").unwrap().to_compact())
                .collect()
        })
        .collect();
    assert_eq!(sims[0], sims[1], "simulated metrics must not vary across runs");

    // ...and the library-level compare agrees: no drift, even under the
    // strict determinism policy
    let a = SuiteReport::parse(&runs[0].to_json().to_pretty()).unwrap();
    let b = SuiteReport::parse(&runs[1].to_json().to_pretty()).unwrap();
    let opts = CompareOptions { fail_on_drift: true, ..CompareOptions::default() };
    let cmp = bench::compare::compare(&a, &b, &opts).unwrap();
    assert!(cmp.deltas.iter().all(|d| d.status == Status::Same), "{}", cmp.render());
    assert!(!cmp.failed(&opts));
    assert_eq!(cmp.geomean_ratio, Some(1.0));
}

/// Threshold policy on real executed reports: an injected makespan
/// regression fails at the default 0% threshold, passes a loose one, and
/// the unmeasured committed placeholder never fails as a baseline.
#[test]
fn compare_policy_on_executed_reports() {
    let session = Session::new();
    let run = bench::run_suite(&session, "smoke", 1).unwrap();
    let base = SuiteReport::parse(&run.to_json().to_pretty()).unwrap();

    let mut worse = base.clone();
    let sim = worse.cells[0].sim.as_mut().unwrap();
    *sim.get_mut("makespan").unwrap() *= 1.10;
    let opts = CompareOptions::default();
    let cmp = bench::compare::compare(&base, &worse, &opts).unwrap();
    assert_eq!(cmp.regressions, 1);
    assert!(cmp.failed(&opts), "a 10% makespan increase fails the default threshold");
    let table = cmp.render();
    assert!(table.contains("REGRESS") && table.contains("+10.00%"), "{table}");

    let loose = CompareOptions { max_regress_pct: 15.0, ..CompareOptions::default() };
    let cmp = bench::compare::compare(&base, &worse, &loose).unwrap();
    assert!(!cmp.failed(&loose), "a 10% increase passes a 15% threshold");

    // warn-only mode (CI's committed-baseline step) never fails, and the
    // placeholder baseline classifies everything as unmeasured
    let placeholder = SuiteReport::from_json(&bench::placeholder_json().unwrap()).unwrap();
    let strict = CompareOptions { fail_on_drift: true, ..CompareOptions::default() };
    let cmp = bench::compare::compare(&placeholder, &base, &strict).unwrap();
    assert_eq!(cmp.unmeasured, base.cells.len());
    assert!(!cmp.failed(&strict), "null-sim baseline cells are unmeasured, not drift");
}
