//! CLI surface tests: the `numanos` binary as users drive it.

use std::process::Command;

fn numanos(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_numanos"))
        .args(args)
        .output()
        .expect("spawn numanos");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn list_shows_inventory() {
    let (ok, text) = numanos(&["list"]);
    assert!(ok, "{text}");
    for needle in ["fft", "sparselu_for", "dfwsrpt", "x4600", "fig13"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
}

#[test]
fn list_prints_all_four_sweep_axes() {
    let (ok, text) = numanos(&["list"]);
    assert!(ok, "{text}");
    // one line per axis: benchmarks, schedulers, bindings, topologies
    for axis in ["benchmarks", "schedulers", "bindings", "topologies"] {
        assert_eq!(
            text.lines().filter(|l| l.starts_with(axis)).count(),
            1,
            "missing '{axis}' line in:\n{text}"
        );
    }
    let bindings = text.lines().find(|l| l.starts_with("bindings")).unwrap();
    assert!(bindings.contains("linear") && bindings.contains("numa"), "{bindings}");
    let topos = text.lines().find(|l| l.starts_with("topologies")).unwrap();
    for preset in ["uma", "x4600_hetero", "altix16", "tile16", "tile64"] {
        assert!(topos.contains(preset), "missing {preset} in: {topos}");
    }
    // the scheduler line is registry-derived: new strategies appear
    let scheds = text.lines().find(|l| l.starts_with("schedulers")).unwrap();
    let expected = [
        "serial", "bf", "cilk", "wf", "dfwspt", "dfwsrpt", "hops-threshold", "hier", "adaptive",
    ];
    for sched in expected {
        assert!(scheds.contains(sched), "missing {sched} in: {scheds}");
    }
}

#[test]
fn list_prints_page_policies_with_parameters() {
    let (ok, text) = numanos(&["list"]);
    assert!(ok, "{text}");
    let mems = text.lines().find(|l| l.starts_with("mem")).expect("mem line");
    for needle in ["first-touch", "interleave", "bind(node=0)", "next-touch(max_moves=1)"] {
        assert!(mems.contains(needle), "missing {needle} in: {mems}");
    }
    // the scheduler line picked up the placement strategies, with their
    // declared tunables and defaults (registry-derived, like mem)
    let scheds = text.lines().find(|l| l.starts_with("schedulers")).unwrap();
    assert!(scheds.contains("numa-home("), "{scheds}");
    assert!(scheds.contains("steal_bias=1"), "{scheds}");
    assert!(scheds.contains("homed_resume=1"), "{scheds}");
    assert!(scheds.contains("numa-steal(min_kb=16;batch=1)"), "{scheds}");
    assert!(scheds.contains("numa-adapt("), "{scheds}");
    assert!(scheds.contains("target=0.5"), "{scheds}");
    assert!(scheds.contains("hops-threshold(max_hops=1;spill_after=2)"), "{scheds}");
}

#[test]
fn run_accepts_mem_policy_and_numa_home() {
    let (ok, text) = numanos(&[
        "run", "--bench", "sparselu_for", "--size", "small", "--threads", "8",
        "--sched", "numa-home", "--mem", "interleave", "--bind", "numa", "--seed", "5",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("mem=interleave"), "describe line carries the axis: {text}");
    assert!(text.contains("speedup"), "{text}");

    // parameterized policy form
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--threads", "4",
        "--mem", "next-touch:max_moves=2",
    ]);
    assert!(ok, "{text}");

    // bad policies and parameters are clear errors
    let (ok, text) = numanos(&["run", "--bench", "fib", "--mem", "bogus"]);
    assert!(!ok);
    assert!(text.contains("unknown page policy"), "{text}");
    let (ok, text) = numanos(&["run", "--bench", "fib", "--mem", "bind:node=99"]);
    assert!(!ok);
    assert!(text.contains("out of range"), "{text}");
}

#[test]
fn run_accepts_parameterized_scheduler() {
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--threads", "8",
        "--sched", "hops-threshold:max_hops=1,spill_after=1", "--bind", "numa", "--seed", "5",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("hops-threshold(max_hops=1;spill_after=1)"), "{text}");
    assert!(text.contains("speedup"), "{text}");

    // bad parameter names are a clear error
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--threads", "4",
        "--sched", "hops-threshold:bogus=3",
    ]);
    assert!(!ok);
    assert!(text.contains("bogus") && text.contains("max_hops"), "{text}");
}

#[test]
fn topo_prints_priorities() {
    let (ok, text) = numanos(&["topo", "--name", "x4600"]);
    assert!(ok, "{text}");
    assert!(text.contains("master binds here"));
    assert!(text.contains("hop matrix"));
}

#[test]
fn run_prints_speedup_line() {
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--sched", "dfwspt",
        "--bind", "numa", "--threads", "8", "--seed", "7",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("speedup"), "{text}");
    assert!(text.contains("dfwspt-Scheduler-NUMA"), "{text}");
}

#[test]
fn run_accepts_cost_overrides() {
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--threads", "4",
        "--cost", "dram_base_ns=150,hop_penalty_ns=99",
    ]);
    assert!(ok, "{text}");
}

#[test]
fn figure_small_runs_and_reports_anchors() {
    let (ok, text) = numanos(&["figure", "--id", "fig10", "--size", "small", "--seed", "3"]);
    assert!(ok, "{text}");
    assert!(text.contains("bf-Scheduler"), "{text}");
    assert!(text.contains("paper anchors"), "{text}");
}

#[test]
fn errors_are_actionable() {
    let (ok, text) = numanos(&["run", "--bench", "nope"]);
    assert!(!ok);
    assert!(text.contains("unknown benchmark"), "{text}");

    let (ok, text) = numanos(&["figure", "--id", "fig99"]);
    assert!(!ok);
    assert!(text.contains("unknown figure"), "{text}");

    let (ok, text) = numanos(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");

    let (ok, text) = numanos(&["run", "--sched", "bogus"]);
    assert!(!ok);
    assert!(text.contains("unknown scheduler"), "{text}");
}

#[test]
fn help_lists_commands() {
    let (ok, text) = numanos(&["help"]);
    assert!(ok);
    for cmd in ["run", "figure", "gains", "topo", "list", "bench", "serve", "vet", "lint"] {
        assert!(text.contains(cmd), "missing {cmd}");
    }
}

#[test]
fn vet_all_builtins_clean() {
    let (ok, text) = numanos(&["vet", "--all"]);
    assert!(ok, "{text}");
    assert!(text.contains("clean"), "{text}");
    // machine-readable form: an empty JSON array
    let (ok, text) = numanos(&["vet", "--all", "--json"]);
    assert!(ok, "{text}");
    assert_eq!(text.trim(), "[]", "{text}");
}

#[test]
fn vet_single_scheduler_and_unknown_name() {
    let (ok, text) = numanos(&["vet", "numa-adapt"]);
    assert!(ok, "{text}");
    let (ok, text) = numanos(&["vet", "bogus-strategy"]);
    assert!(!ok);
    assert!(text.contains("unknown scheduler"), "{text}");
}

#[test]
fn lint_example_manifest_clean_and_broken_manifest_coded() {
    let manifest =
        concat!(env!("CARGO_MANIFEST_DIR"), "/examples/experiment_manifest.json");
    let (ok, text) = numanos(&["lint", "--manifest", manifest]);
    assert!(ok, "{text}");
    assert!(text.contains("clean"), "{text}");
    // an invalid cell comes back as a stable LINT code, non-zero exit
    let dir = std::env::temp_dir().join(format!("numanos_cli_lint_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(
        &bad,
        r#"{"title": "t", "sweeps": [
            {"id": "a", "bench": ["fib"], "sched": ["serial"],
             "bind": ["numa"], "threads": [4], "seeds": [1]}
        ]}"#,
    )
    .unwrap();
    let (ok, text) = numanos(&["lint", "--manifest", bad.to_str().unwrap()]);
    assert!(!ok, "{text}");
    assert!(text.contains("LINT004"), "serial at threads=4 must flag LINT004: {text}");
}

#[test]
fn gains_summary_has_all_benchmarks() {
    let (ok, text) = numanos(&["gains", "--size", "small"]);
    assert!(ok, "{text}");
    for bench in ["fft", "sort", "strassen", "nqueens"] {
        assert!(text.contains(bench), "{text}");
    }
}

#[test]
fn flag_equals_syntax_accepted() {
    let (ok, text) = numanos(&[
        "run", "--bench=fib", "--size=small", "--sched=wf", "--threads=4", "--seed=3",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("speedup"), "{text}");
}

#[test]
fn unknown_flags_are_listed_together() {
    let (ok, text) = numanos(&["run", "--bench", "fib", "--bogus", "1", "--also-bad"]);
    assert!(!ok);
    assert!(text.contains("--bogus"), "{text}");
    assert!(text.contains("--also-bad"), "{text}");
    assert!(text.contains("allowed"), "{text}");
}

#[test]
fn valueless_value_flag_is_a_clear_error() {
    let (ok, text) = numanos(&["run", "--bench", "fib", "--threads"]);
    assert!(!ok);
    assert!(text.contains("expects a value"), "{text}");
    // trailing value-less flag (the old parser silently turned this into
    // threads="true")
    let (ok, text) = numanos(&["run", "--threads", "--bench", "fib"]);
    assert!(!ok);
    assert!(text.contains("expects a value"), "{text}");
}

#[test]
fn duplicate_flag_rejected() {
    let (ok, text) = numanos(&["run", "--bench", "fib", "--bench", "fft"]);
    assert!(!ok);
    assert!(text.contains("more than once"), "{text}");
}

#[test]
fn run_json_emits_a_record() {
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--threads", "2", "--json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("\"speedup\""), "{text}");
    assert!(text.contains("\"makespan\""), "{text}");
    assert!(text.contains("\"spec\""), "{text}");
}

#[test]
fn run_accepts_explicit_core_list() {
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--cores", "4,5,6,7", "--seed", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("t=4"), "thread count follows the core list: {text}");
}

fn write_manifest(dir: &std::path::Path) -> std::path::PathBuf {
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        "title = \"cli sweep\"\n\n[defaults]\nsize = \"small\"\nseed = 4\n\n\
         [[sweeps]]\nid = \"mini\"\nbench = \"fib\"\nsched = [\"wf\", \"dfwsrpt\"]\n\
         bind = [\"numa\"]\nthreads = [2, 4]\n",
    )
    .unwrap();
    path
}

#[test]
fn sweep_manifest_end_to_end() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_sweep_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = write_manifest(&dir);

    // parallel run with table output + CSV files
    let out_par = dir.join("par");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--out", out_par.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("wf-Scheduler-NUMA"), "{text}");
    assert!(text.contains("dfwsrpt-Scheduler-NUMA"), "{text}");

    // sequential run: CSV must be byte-identical to the parallel one
    let out_seq = dir.join("seq");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--seq", "--out",
        out_seq.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    let par_csv = std::fs::read_to_string(out_par.join("mini.csv")).unwrap();
    let seq_csv = std::fs::read_to_string(out_seq.join("mini.csv")).unwrap();
    assert_eq!(par_csv, seq_csv, "parallel and sequential sweep CSV must match");
    assert_eq!(par_csv.lines().count(), 1 + 4);

    // --json emits a parseable document on stdout
    let (ok, text) = numanos(&["sweep", "--manifest", manifest.to_str().unwrap(), "--json"]);
    assert!(ok, "{text}");
    assert!(text.contains("\"records\""), "{text}");
    assert!(text.contains("\"speedup\""), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_manifest_with_parameterized_scheduler() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_param_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = dir.join("param.json");
    std::fs::write(
        &manifest,
        r#"{
          "title": "parameterized",
          "defaults": {"size": "small", "seeds": [3]},
          "sweeps": [
            {"id": "near", "bench": "fib",
             "sched": [{"name": "hops-threshold", "max_hops": 1}, "hier", "adaptive"],
             "bind": ["numa"], "threads": [2, 8]}
          ]
        }"#,
    )
    .unwrap();
    let out = dir.join("out");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--out", out.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("hops-threshold(max_hops=1)-Scheduler-NUMA"), "{text}");
    assert!(text.contains("hier-Scheduler-NUMA"), "{text}");
    let csv = std::fs::read_to_string(out.join("near.csv")).unwrap();
    assert_eq!(csv.lines().count(), 1 + 6, "{csv}");
    assert!(csv.contains("hops-threshold(max_hops=1)"), "{csv}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_manifest_with_placement_axis() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_place_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = dir.join("place.json");
    std::fs::write(
        &manifest,
        r#"{
          "title": "placement grid",
          "defaults": {"size": "small", "seeds": [3]},
          "sweeps": [
            {"id": "place", "bench": "sparselu_for",
             "sched": ["bf", "numa-home"],
             "mem": ["first-touch", "interleave"],
             "bind": ["numa"], "threads": [8],
             "topos": ["x4600", "tile16"]}
          ]
        }"#,
    )
    .unwrap();
    let out = dir.join("out");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--out", out.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    // topos expanded into one sweep (and CSV) per fabric
    for id in ["place-x4600", "place-tile16"] {
        let csv = std::fs::read_to_string(out.join(format!("{id}.csv")))
            .unwrap_or_else(|e| panic!("{id}: {e}"));
        let header = csv.lines().next().unwrap();
        for col in [
            "mem",
            "pushed_home",
            "affinity_hits",
            "migrated_pages",
            "affine_steals",
            "homed_resumes",
        ] {
            assert!(header.contains(col), "{id}: missing {col} in {header}");
        }
        assert!(csv.contains("interleave"), "{id}: {csv}");
        assert!(csv.contains("numa-home"), "{id}: {csv}");
        assert_eq!(csv.lines().count(), 1 + 4, "{id}: {csv}");
    }
    // the table disambiguates the memory axis in row labels
    assert!(text.contains("+interleave"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_store_serves_second_run_from_cache() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = write_manifest(&dir);
    let store = dir.join("store");

    // reference: no store, sequential
    let out_ref = dir.join("ref");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--seq", "--out",
        out_ref.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(!text.contains("cache:"), "no store, no cache summary: {text}");

    // cold store: every cell misses and is written
    let out_cold = dir.join("cold");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--seq", "--store",
        store.to_str().unwrap(), "--out", out_cold.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("cache: 0 hit / 4 miss / 4 written"), "{text}");

    // warm store: 100% hits, zero engine runs — and byte-identical CSV
    let out_warm = dir.join("warm");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--seq", "--store",
        store.to_str().unwrap(), "--out", out_warm.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("cache: 4 hit / 0 miss / 0 written"), "{text}");
    let ref_csv = std::fs::read_to_string(out_ref.join("mini.csv")).unwrap();
    for out in [&out_cold, &out_warm] {
        assert_eq!(
            std::fs::read_to_string(out.join("mini.csv")).unwrap(),
            ref_csv,
            "store runs must match the uncached sequential bytes"
        );
    }

    // --resume against the existing store is the same full-hit pass
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--seq", "--resume", "--store",
        store.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("cache: 4 hit"), "{text}");

    // --no-cache re-executes everything but refreshes the records
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--seq", "--no-cache", "--store",
        store.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("cache: 0 hit / 0 miss / 4 written"), "{text}");

    // flag misuse is a clear error
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--resume", "--store",
        dir.join("nonesuch").to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(text.contains("nothing to resume"), "{text}");
    let (ok, text) = numanos(&["sweep", "--manifest", manifest.to_str().unwrap(), "--no-cache"]);
    assert!(!ok);
    assert!(text.contains("--store"), "{text}");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--resume", "--no-cache", "--store",
        store.to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(text.contains("pick one"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_once_processes_spool_and_writes_receipts() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_serve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = write_manifest(&dir);
    let store = dir.join("store");
    let spool = dir.join("spool");
    std::fs::create_dir_all(&spool).unwrap();

    // job 1: cold store — all four cells execute
    std::fs::copy(&manifest, spool.join("job1.toml")).unwrap();
    let (ok, text) = numanos(&[
        "serve", "--store", store.to_str().unwrap(), "--spool", spool.to_str().unwrap(),
        "--once", "--workers", "2",
    ]);
    assert!(ok, "{text}");
    let receipt1 = std::fs::read_to_string(spool.join("job1.receipt.json")).unwrap();
    assert!(receipt1.contains("\"status\": \"ok\""), "{receipt1}");
    assert!(receipt1.contains("\"manifest_fnv\""), "{receipt1}");
    assert!(receipt1.contains("\"cache_hits\": 0"), "{receipt1}");
    assert!(receipt1.contains("\"cache_misses\": 4"), "{receipt1}");
    assert!(receipt1.contains("\"cache_writes\": 4"), "{receipt1}");
    let result1 = std::fs::read_to_string(spool.join("job1.result.json")).unwrap();
    assert!(result1.contains("\"records\""), "{result1}");
    assert!(spool.join("done/job1.toml").exists(), "processed job moves to done/");

    // job 2: identical manifest — served entirely from the shared store
    std::fs::copy(&manifest, spool.join("job2.toml")).unwrap();
    let (ok, text) = numanos(&[
        "serve", "--store", store.to_str().unwrap(), "--spool", spool.to_str().unwrap(),
        "--once",
    ]);
    assert!(ok, "{text}");
    let receipt2 = std::fs::read_to_string(spool.join("job2.receipt.json")).unwrap();
    assert!(receipt2.contains("\"cache_hits\": 4"), "{receipt2}");
    assert!(receipt2.contains("\"cache_misses\": 0"), "{receipt2}");
    let result2 = std::fs::read_to_string(spool.join("job2.result.json")).unwrap();
    assert_eq!(result1, result2, "cached job reproduces the executed job's bytes");

    // a malformed manifest gets an error receipt, moves to failed/, and
    // does not kill the service
    std::fs::write(spool.join("bad.json"), "{not json").unwrap();
    let (ok, text) = numanos(&[
        "serve", "--store", store.to_str().unwrap(), "--spool", spool.to_str().unwrap(),
        "--once",
    ]);
    assert!(ok, "one bad job must not fail the pass: {text}");
    let bad = std::fs::read_to_string(spool.join("bad.receipt.json")).unwrap();
    assert!(bad.contains("\"status\": \"error\""), "{bad}");
    assert!(bad.contains("\"error\""), "{bad}");
    assert!(spool.join("failed/bad.json").exists());
    assert!(!spool.join("bad.result.json").exists(), "failed jobs emit no result file");

    // serve needs both directories
    let (ok, text) = numanos(&["serve", "--spool", spool.to_str().unwrap()]);
    assert!(!ok);
    assert!(text.contains("--store"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shard_sweeps_merge_to_the_sequential_bytes() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_shard_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = write_manifest(&dir);
    let store = dir.join("store");

    // reference: store-free sequential run
    let out_ref = dir.join("ref");
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--seq", "--out",
        out_ref.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");

    // three shard passes over one shared store — separate processes
    for i in 0..3 {
        let spec = format!("{i}/3");
        let (ok, text) = numanos(&[
            "sweep", "--manifest", manifest.to_str().unwrap(), "--shard", &spec, "--store",
            store.to_str().unwrap(),
        ]);
        assert!(ok, "shard {spec}: {text}");
        assert!(text.contains("cell(s) owned"), "{text}");
        assert!(
            store.join(format!("shards/{i}-of-3.json")).exists(),
            "shard {spec} must publish its marker"
        );
    }

    // merge: 100% hits, byte-identical files, strict census passes
    let out_merged = dir.join("merged");
    let (ok, text) = numanos(&[
        "merge", "--manifest", manifest.to_str().unwrap(), "--store", store.to_str().unwrap(),
        "--seq", "--merge-strict", "--out", out_merged.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("3 of 3 shard marker(s) present"), "{text}");
    assert!(text.contains("cache: 4 hit / 0 miss"), "{text}");
    for file in ["mini.csv", "mini.md"] {
        assert_eq!(
            std::fs::read(out_merged.join(file)).unwrap(),
            std::fs::read(out_ref.join(file)).unwrap(),
            "merged {file} must match the sequential reference byte for byte"
        );
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shard_flag_misuse_is_a_clear_error() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_shard_err_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = write_manifest(&dir);

    // --shard without --store
    let (ok, text) =
        numanos(&["sweep", "--manifest", manifest.to_str().unwrap(), "--shard", "0/3"]);
    assert!(!ok);
    assert!(text.contains("--store"), "{text}");

    // --shard with --out: partial output refused, points at merge
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--shard", "0/3", "--store",
        dir.join("s").to_str().unwrap(), "--out", dir.join("o").to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(text.contains("numanos merge"), "{text}");

    // malformed spec: index out of range
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--shard", "3/3", "--store",
        dir.join("s").to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(text.contains("3/3") || text.contains("index"), "{text}");

    // satellite: --resume --shard against a missing store names the
    // shard flag instead of the generic "nothing to resume"
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--resume", "--shard", "0/3",
        "--store", dir.join("fresh").to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(text.contains("--shard 0/3"), "{text}");
    assert!(!text.contains("nothing to resume"), "{text}");

    // merge without a store to merge from
    let (ok, text) = numanos(&[
        "merge", "--manifest", manifest.to_str().unwrap(), "--store",
        dir.join("nonesuch").to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(text.contains("run the shards first"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn merge_strict_reports_missing_shards() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_strict_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = write_manifest(&dir);
    let store = dir.join("store");

    // only shard 0 of 3 ran
    let (ok, text) = numanos(&[
        "sweep", "--manifest", manifest.to_str().unwrap(), "--shard", "0/3", "--store",
        store.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");

    let (ok, text) = numanos(&[
        "merge", "--manifest", manifest.to_str().unwrap(), "--store", store.to_str().unwrap(),
        "--merge-strict",
    ]);
    assert!(!ok, "strict merge over an incomplete shard set must fail: {text}");
    assert!(text.contains("1, 2"), "the missing shards are named: {text}");

    // non-strict merge degrades gracefully: re-executes the gap
    let (ok, text) = numanos(&[
        "merge", "--manifest", manifest.to_str().unwrap(), "--store", store.to_str().unwrap(),
        "--seq",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("missing shard(s): 1, 2"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_fanout_job_drives_shards_and_merge_in_one_pass() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_fanout_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let store = dir.join("store");
    let spool = dir.join("spool");
    std::fs::create_dir_all(&spool).unwrap();

    let body = r#"{
      "title": "fanout",
      "defaults": {"size": "small", "seeds": [4]},
      "sweeps": [
        {"id": "mini", "bench": "fib", "sched": ["wf", "dfwsrpt"],
         "bind": ["numa"], "threads": [2, 4]}
      ]
    }"#;
    // the same manifest twice: once plain, once fanned out into 3 shards
    std::fs::write(spool.join("plain.json"), body).unwrap();
    let mut fan: Vec<String> = body.lines().map(String::from).collect();
    let last = fan.len() - 2; // line before the closing brace
    fan[last] = format!("{},\n      \"shards\": 3", fan[last].trim_end());
    std::fs::write(spool.join("fan.json"), fan.join("\n")).unwrap();

    let (ok, text) = numanos(&[
        "serve", "--store", store.to_str().unwrap(), "--spool", spool.to_str().unwrap(),
        "--once",
    ]);
    assert!(ok, "{text}");

    // the fanout job expanded…
    let expand = std::fs::read_to_string(spool.join("fan.receipt.json")).unwrap();
    assert!(expand.contains("\"kind\": \"expand\""), "{expand}");
    assert!(expand.contains("\"shards\": 3"), "{expand}");
    // …its three shard items ran and published markers…
    for i in 0..3 {
        let receipt = spool.join(format!("fan.shard-{i}-of-3.receipt.json"));
        let text = std::fs::read_to_string(&receipt)
            .unwrap_or_else(|e| panic!("{}: {e}", receipt.display()));
        assert!(text.contains("\"status\": \"ok\""), "{text}");
        assert!(text.contains("\"kind\": \"shard\""), "{text}");
        assert!(store.join(format!("shards/{i}-of-3.json")).exists());
    }
    // …and the gated merge assembled the full result from pure hits
    let merge = std::fs::read_to_string(spool.join("fan.merge.receipt.json")).unwrap();
    assert!(merge.contains("\"kind\": \"merge\""), "{merge}");
    assert!(merge.contains("\"cache_hits\": 4"), "{merge}");
    assert!(merge.contains("\"cache_misses\": 0"), "{merge}");
    assert!(merge.contains("\"shards_present\": 3"), "{merge}");
    let merged = std::fs::read_to_string(spool.join("fan.merge.result.json")).unwrap();
    let plain = std::fs::read_to_string(spool.join("plain.result.json")).unwrap();
    assert_eq!(merged, plain, "fanned-out merge must reproduce the plain job's bytes");
    // shard items produce no result files — partial data never
    // masquerades as a full result
    assert!(!spool.join("fan.shard-0-of-3.result.json").exists());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_resubmitted_job_gets_a_fresh_suffix() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_resub_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = write_manifest(&dir);
    let store = dir.join("store");
    let spool = dir.join("spool");
    std::fs::create_dir_all(&spool).unwrap();

    std::fs::copy(&manifest, spool.join("job1.toml")).unwrap();
    let (ok, text) = numanos(&[
        "serve", "--store", store.to_str().unwrap(), "--spool", spool.to_str().unwrap(),
        "--once",
    ]);
    assert!(ok, "{text}");
    let first = std::fs::read_to_string(spool.join("job1.receipt.json")).unwrap();

    // drop the same name again: outputs get a suffix, nothing is clobbered
    std::fs::copy(&manifest, spool.join("job1.toml")).unwrap();
    let (ok, text) = numanos(&[
        "serve", "--store", store.to_str().unwrap(), "--spool", spool.to_str().unwrap(),
        "--once",
    ]);
    assert!(ok, "{text}");
    let second = std::fs::read_to_string(spool.join("job1.2.receipt.json")).unwrap();
    assert!(second.contains("\"cache_hits\": 4"), "resubmission is all hits: {second}");
    assert_eq!(
        std::fs::read_to_string(spool.join("job1.receipt.json")).unwrap(),
        first,
        "the original receipt must survive the resubmission untouched"
    );
    assert!(spool.join("done/job1.toml").exists());
    assert!(spool.join("done/job1.2.toml").exists(), "the job retires under its unique name");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_requires_manifest() {
    let (ok, text) = numanos(&["sweep"]);
    assert!(!ok);
    assert!(text.contains("--manifest"), "{text}");
}

#[test]
fn help_mentions_sweep_and_equals_syntax() {
    let (ok, text) = numanos(&["help"]);
    assert!(ok);
    assert!(text.contains("sweep"), "{text}");
    assert!(text.contains("--key=value"), "{text}");
}

/// Multiply the first `"makespan"` value in an emitted BENCH_*.json by
/// `factor` — the cheapest way to fake a perf trajectory in a CLI test.
fn bump_makespan(path: &std::path::Path, factor: f64) {
    let text = std::fs::read_to_string(path).unwrap();
    let mut bumped = false;
    let doctored: Vec<String> = text
        .lines()
        .map(|l| {
            if bumped || !l.trim_start().starts_with("\"makespan\":") {
                return l.to_string();
            }
            bumped = true;
            let indent = &l[..l.len() - l.trim_start().len()];
            let val = l.trim_start().trim_start_matches("\"makespan\":").trim().trim_end_matches(',');
            let v: f64 = val.parse().unwrap_or_else(|e| panic!("{val}: {e}"));
            format!("{indent}\"makespan\": {},", v * factor)
        })
        .collect();
    assert!(bumped, "no makespan line in {}", path.display());
    std::fs::write(path, doctored.join("\n")).unwrap();
}

#[test]
fn bench_smoke_emits_report_and_self_compares_clean() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let a = dir.join("a.json");
    let b = dir.join("b.json");

    let (ok, text) = numanos(&[
        "bench", "--filter", "smoke", "--reps", "1", "--out", a.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("wrote"), "{text}");
    let emitted = std::fs::read_to_string(&a).unwrap();
    assert!(emitted.contains("\"suite\": \"numanos-pinned-v1\""), "{emitted}");
    assert!(emitted.contains("\"schema\": 1"), "{emitted}");
    assert!(emitted.contains("\"remote_pct\""), "{emitted}");

    // a second run is simulation-identical: strict compare passes
    let (ok, text) = numanos(&[
        "bench", "--filter", "smoke", "--reps", "1", "--out", b.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    let (ok, text) = numanos(&[
        "bench", "--compare", a.to_str().unwrap(), b.to_str().unwrap(), "--fail-on-drift",
    ]);
    assert!(ok, "determinism: {text}");
    assert!(text.contains("geomean makespan ratio 1.0000"), "{text}");
    assert!(text.contains("0 regression(s), 0 drifted"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_compare_threshold_exit_codes() {
    let dir = std::env::temp_dir().join(format!("numanos_cli_bcmp_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("base.json");
    let worse = dir.join("worse.json");

    let (ok, text) = numanos(&[
        "bench", "--filter", "smoke", "--reps", "1", "--out", base.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    std::fs::copy(&base, &worse).unwrap();
    bump_makespan(&worse, 1.5);

    // regression past the default 0% threshold: non-zero exit + table row
    let (ok, text) =
        numanos(&["bench", "--compare", base.to_str().unwrap(), worse.to_str().unwrap()]);
    assert!(!ok, "{text}");
    assert!(text.contains("REGRESS"), "{text}");
    assert!(text.contains("bench compare failed"), "{text}");

    // a loose threshold or warn-only mode turns the same delta into success
    let (ok, text) = numanos(&[
        "bench", "--compare", base.to_str().unwrap(), worse.to_str().unwrap(),
        "--max-regress-pct", "75",
    ]);
    assert!(ok, "{text}");
    let (ok, text) = numanos(&[
        "bench", "--compare", base.to_str().unwrap(), worse.to_str().unwrap(), "--warn-only",
    ]);
    assert!(ok, "{text}");

    // the improvement direction never fails, and --json emits the counters
    let (ok, text) = numanos(&[
        "bench", "--compare", worse.to_str().unwrap(), base.to_str().unwrap(), "--json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("\"regressions\": 0"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_arg_errors_are_actionable() {
    let (ok, text) = numanos(&["bench", "--filter", "nonesuch", "--out", "/dev/null"]);
    assert!(!ok);
    assert!(text.contains("matches no suite entries"), "{text}");
    assert!(text.contains("ablation"), "the error lists the groups: {text}");

    let (ok, text) = numanos(&["bench", "--compare", "only-one.json"]);
    assert!(!ok);
    assert!(text.contains("exactly two files"), "{text}");

    let (ok, text) = numanos(&["bench", "stray.json"]);
    assert!(!ok);
    assert!(text.contains("--compare"), "{text}");

    let (ok, text) = numanos(&["bench", "--reps", "0", "--out", "/dev/null"]);
    assert!(!ok);
    assert!(text.contains("at least 1"), "{text}");
}
