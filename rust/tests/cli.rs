//! CLI surface tests: the `numanos` binary as users drive it.

use std::process::Command;

fn numanos(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_numanos"))
        .args(args)
        .output()
        .expect("spawn numanos");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn list_shows_inventory() {
    let (ok, text) = numanos(&["list"]);
    assert!(ok, "{text}");
    for needle in ["fft", "sparselu_for", "dfwsrpt", "x4600", "fig13"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
}

#[test]
fn topo_prints_priorities() {
    let (ok, text) = numanos(&["topo", "--name", "x4600"]);
    assert!(ok, "{text}");
    assert!(text.contains("master binds here"));
    assert!(text.contains("hop matrix"));
}

#[test]
fn run_prints_speedup_line() {
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--sched", "dfwspt",
        "--bind", "numa", "--threads", "8", "--seed", "7",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("speedup"), "{text}");
    assert!(text.contains("dfwspt-Scheduler-NUMA"), "{text}");
}

#[test]
fn run_accepts_cost_overrides() {
    let (ok, text) = numanos(&[
        "run", "--bench", "fib", "--size", "small", "--threads", "4",
        "--cost", "dram_base_ns=150,hop_penalty_ns=99",
    ]);
    assert!(ok, "{text}");
}

#[test]
fn figure_small_runs_and_reports_anchors() {
    let (ok, text) = numanos(&["figure", "--id", "fig10", "--size", "small", "--seed", "3"]);
    assert!(ok, "{text}");
    assert!(text.contains("bf-Scheduler"), "{text}");
    assert!(text.contains("paper anchors"), "{text}");
}

#[test]
fn errors_are_actionable() {
    let (ok, text) = numanos(&["run", "--bench", "nope"]);
    assert!(!ok);
    assert!(text.contains("unknown benchmark"), "{text}");

    let (ok, text) = numanos(&["figure", "--id", "fig99"]);
    assert!(!ok);
    assert!(text.contains("unknown figure"), "{text}");

    let (ok, text) = numanos(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");

    let (ok, text) = numanos(&["run", "--sched", "bogus"]);
    assert!(!ok);
    assert!(text.contains("unknown scheduler"), "{text}");
}

#[test]
fn help_lists_commands() {
    let (ok, text) = numanos(&["help"]);
    assert!(ok);
    for cmd in ["run", "figure", "gains", "topo", "list"] {
        assert!(text.contains(cmd), "missing {cmd}");
    }
}

#[test]
fn gains_summary_has_all_benchmarks() {
    let (ok, text) = numanos(&["gains", "--size", "small"]);
    assert!(ok, "{text}");
    for bench in ["fft", "sort", "strassen", "nqueens"] {
        assert!(text.contains(bench), "{text}");
    }
}
