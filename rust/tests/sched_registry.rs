//! The scheduler registry end-to-end: every registered strategy runs
//! deterministically, the stock policies are byte-identical through the
//! trait path vs. the legacy enum verbs, and parameterized schedulers
//! flow through manifests into sweeps.

use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::sched::{self, Policy, SchedSpec};
use numanos::metrics::speedup;
use numanos::spec::{ExperimentManifest, RunSpec, Session, Sweep};
use numanos::{bots, Runtime};

/// Satellite regression: for every registered scheduler, two runs with
/// the same `(bench, topo, bind, threads, seed)` produce identical
/// `RunStats` — guards the trait migration (and future registrations)
/// against accidental RNG-order drift.
#[test]
fn every_registered_scheduler_is_deterministic() {
    for name in sched::scheduler_names() {
        let spec = RunSpec::builder()
            .bench("sort")
            .size(Size::Small)
            .sched(SchedSpec::new(&name))
            .numa()
            .threads(if name == "serial" { 1 } else { 8 })
            .seed(11)
            .build()
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        // fresh sessions: nothing shared but the registry
        let a = Session::new().run(&spec).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let b = Session::new().run(&spec).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(a.stats.makespan, b.stats.makespan, "{name}");
        assert_eq!(a.stats.steals, b.stats.steals, "{name}");
        assert_eq!(a.stats.steal_attempts, b.stats.steal_attempts, "{name}");
        assert_eq!(a.stats.sim_events, b.stats.sim_events, "{name}");
        assert_eq!(a.to_csv_row(), b.to_csv_row(), "{name}");
        assert_eq!(a.to_json().to_compact(), b.to_json().to_compact(), "{name}");
        // the engine records the instance signature: registry name, plus
        // resolved parameters for parameterized strategies
        assert!(a.stats.sched.starts_with(&name), "{name}: {}", a.stats.sched);
    }
}

/// Acceptance criterion: the five stock parallel policies produce
/// byte-identical sweep CSV/JSON through the `Scheduler` trait path vs.
/// the pre-redesign enum path (the legacy `Runtime::run` verbs, which
/// take `Policy` and carry the old engine semantics).
#[test]
fn stock_policies_byte_identical_trait_vs_enum_path() {
    let policies = [
        Policy::BreadthFirst,
        Policy::CilkBased,
        Policy::WorkFirst,
        Policy::Dfwspt,
        Policy::Dfwsrpt,
    ];
    let sweep = Sweep::new("parity", "stock parity")
        .with_bench("fft")
        .with_configs(policies.iter().map(|&p| (p, BindPolicy::NumaAware)))
        .with_threads(vec![2, 8])
        .with_seeds(vec![5])
        .with_size(Size::Small);
    let result = Session::new().run_sweep(&sweep).unwrap();
    assert_eq!(result.records.len(), policies.len() * 2);

    let rt = Runtime::paper_testbed();
    let mut ws = bots::create("fft", Size::Small, 5).unwrap();
    let serial = rt.run_serial(ws.as_mut(), 5).unwrap();

    let mut legacy_csv = format!("sweep,{}\n", numanos::spec::RunRecord::CSV_HEADER);
    for (i, &policy) in policies.iter().enumerate() {
        for (j, &threads) in [2usize, 8].iter().enumerate() {
            let rec = &result.records[i * 2 + j];
            let mut w = bots::create("fft", Size::Small, 5).unwrap();
            let direct =
                rt.run(w.as_mut(), policy, BindPolicy::NumaAware, threads, 5, None).unwrap();
            assert_eq!(rec.stats.makespan, direct.makespan, "{}", policy.name());
            assert_eq!(rec.stats.steals, direct.steals, "{}", policy.name());
            assert_eq!(rec.stats.sim_events, direct.sim_events, "{}", policy.name());
            assert_eq!(rec.stats.sched, policy.name().to_string());
            let want = speedup(&serial, &direct);
            assert!((rec.speedup - want).abs() < 1e-12, "{}", policy.name());
            // reconstruct the CSV row from the legacy stats and spec axes
            legacy_csv.push_str(&format!("parity,{}\n", rec.to_csv_row()));
        }
    }
    assert_eq!(result.to_csv(), legacy_csv);
}

/// Acceptance criterion: `numanos sweep` semantics — a manifest cell
/// selecting a parameterized scheduler runs end-to-end.
#[test]
fn manifest_with_parameterized_scheduler_runs_end_to_end() {
    let manifest = ExperimentManifest::from_json_str(
        r#"{
          "title": "parameterized",
          "defaults": {"size": "small", "seeds": [4]},
          "sweeps": [
            {"id": "bounded", "bench": "strassen",
             "configs": [[{"name": "hops-threshold", "max_hops": 1}, "numa"],
                         ["dfwsrpt", "numa"]],
             "threads": [8]}
          ]
        }"#,
    )
    .unwrap();
    let result = Session::new().run_sweep(&manifest.sweeps[0]).unwrap();
    assert_eq!(result.records.len(), 2);
    let bounded = &result.records[0];
    assert_eq!(bounded.spec.sched.name_sig(), "hops-threshold(max_hops=1)");
    assert_eq!(bounded.label(), "hops-threshold(max_hops=1)-Scheduler-NUMA");
    assert!(bounded.stats.makespan > 0);
    assert!(bounded.stats.steals > 0, "strassen at 8 threads must steal");
    let csv = result.to_csv();
    assert!(csv.contains("hops-threshold(max_hops=1)"), "{csv}");
}

/// The new strategies express behaviours the closed enum could not:
/// hop-bounded stealing really steals closer than uniform random.
#[test]
fn hop_bounded_stealing_steals_closer_than_work_first() {
    let session = Session::new();
    let run = |sched: SchedSpec| {
        let spec = RunSpec::builder()
            .bench("strassen")
            .size(Size::Small)
            .sched(sched)
            .numa()
            .threads(16)
            .seed(9)
            .build()
            .unwrap();
        session.run(&spec).unwrap()
    };
    let wf = run(SchedSpec::stock(Policy::WorkFirst));
    let near = run(SchedSpec::new("hops-threshold").with_param("max_hops", 1.0));
    let hier = run(SchedSpec::new("hier"));
    assert!(wf.stats.steals > 0 && near.stats.steals > 0 && hier.stats.steals > 0);
    assert!(
        near.stats.mean_steal_hops < wf.stats.mean_steal_hops,
        "bounded {} vs wf {}",
        near.stats.mean_steal_hops,
        wf.stats.mean_steal_hops
    );
    assert!(
        hier.stats.mean_steal_hops < wf.stats.mean_steal_hops,
        "hier {} vs wf {}",
        hier.stats.mean_steal_hops,
        wf.stats.mean_steal_hops
    );
}

/// `adaptive` runs and reports its registry name through the stats.
#[test]
fn adaptive_runs_across_thread_counts() {
    let session = Session::new();
    for threads in [2, 16] {
        let spec = RunSpec::builder()
            .bench("fft")
            .size(Size::Small)
            .sched(SchedSpec::new("adaptive").with_param("min_steals", 8.0))
            .numa()
            .threads(threads)
            .seed(2)
            .build()
            .unwrap();
        let rec = session.run(&spec).unwrap();
        assert_eq!(rec.stats.sched, "adaptive(min_steals=8)", "spec-level signature");
        assert!(rec.stats.makespan > 0);
    }
}
