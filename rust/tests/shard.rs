//! Sharded sweep execution end-to-end: the tentpole contract is that a
//! K-shard execute + store-backed merge reproduces the sequential
//! single-process run byte for byte — for K ∈ {2, 3, 7}, including K
//! that does not divide the cell count — with the merge running as 100%
//! cache hits.  Plus: spelling-invariant shard assignment (JSON vs
//! TOML), empty shards, marker census, and the golden partition pin of
//! the repo's examples manifest at N=3.

use std::path::Path;
use std::sync::Arc;

use numanos::spec::{ExperimentManifest, Session, ShardPlan};
use numanos::store::shard::{run_manifest_shard, shard_status};
use numanos::store::{cell_identity, ResultStore};

/// A 7-cell, 2-sweep manifest: 7 is prime, so every K in {2, 3, 7}
/// exercises the K-does-not-divide case (and K=7 the one-cell-per-shard
/// edge).
const MANIFEST_JSON: &str = r#"{
  "title": "shard mini",
  "defaults": {"size": "small", "seeds": [4]},
  "sweeps": [
    {"id": "mini", "bench": "fib", "sched": ["wf", "dfwsrpt"],
     "bind": ["numa"], "threads": [2, 4]},
    {"id": "tail", "bench": "fft", "sched": ["wf"],
     "bind": ["numa"], "threads": [2, 4, 8]}
  ]
}"#;

/// The same manifest spelled as TOML (arrays-of-tables, explicit
/// defaults) — assignments must not notice.
const MANIFEST_TOML: &str = r#"
title = "shard mini"

[defaults]
size = "small"
seeds = [4]

[[sweeps]]
id = "mini"
bench = "fib"
sched = ["wf", "dfwsrpt"]
bind = ["numa"]
threads = [2, 4]

[[sweeps]]
id = "tail"
bench = "fft"
sched = ["wf"]
bind = ["numa"]
threads = [2, 4, 8]
"#;

fn tmp_store(name: &str) -> (std::path::PathBuf, Arc<ResultStore>) {
    let dir = std::env::temp_dir().join(format!("numanos_shard_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ResultStore::open(&dir).unwrap());
    (dir, store)
}

fn all_identities(manifest: &ExperimentManifest) -> Vec<String> {
    manifest
        .all_cells()
        .unwrap()
        .iter()
        .map(|c| cell_identity(c).unwrap())
        .collect()
}

/// The tentpole acceptance test: for K ∈ {2, 3, 7}, K independent
/// shard passes (fresh session each, like separate processes) over one
/// shared store, then a store-backed merge, reproduce the sequential
/// reference byte for byte with zero merge misses.
#[test]
fn k_shard_execute_and_merge_matches_sequential_bytes() {
    let manifest = ExperimentManifest::from_json_str(MANIFEST_JSON).unwrap();
    let identities = all_identities(&manifest);
    assert_eq!(identities.len(), 7, "the mini manifest is the 7-cell prime case");

    // store-free sequential reference
    let reference = Session::new();
    let ref_outputs: Vec<(String, String)> = manifest
        .sweeps
        .iter()
        .map(|sweep| {
            let r = reference.run_sweep_with(sweep, 1).unwrap();
            (r.to_csv(), r.to_json().to_pretty())
        })
        .collect();

    for k in [2usize, 3, 7] {
        let (dir, store) = tmp_store(&format!("k{k}"));
        let mut owned_total = 0usize;
        let mut seen_ids: Vec<String> = Vec::new();
        for i in 0..k {
            // a fresh session per shard — no shared memo, like a
            // separate OS process sharing only the store directory
            let mut session = Session::new();
            session.set_store(store.clone(), true);
            let plan = ShardPlan::new(i, k).unwrap();
            let summary = run_manifest_shard(&session, &store, &manifest, plan, 2).unwrap();
            assert_eq!(summary.total_cells, 7, "k={k} shard {i}");
            assert_eq!(summary.owned_cells, plan.owned_of(7), "k={k} shard {i}");
            owned_total += summary.owned_cells;
            // the marker this shard just published is loadable and owns
            // exactly its cells
            let marker = store.load_shard_marker(i, k).unwrap();
            assert_eq!(marker.cell_ids.len(), summary.owned_cells);
            for id in &marker.cell_ids {
                assert!(identities.contains(id), "k={k} shard {i}: foreign id {id}");
                assert!(!seen_ids.contains(id), "k={k} shard {i}: id {id} owned twice");
            }
            seen_ids.extend(marker.cell_ids.iter().cloned());
        }
        assert_eq!(owned_total, 7, "k={k}: shards must partition the manifest");
        seen_ids.sort();
        let mut want = identities.clone();
        want.sort();
        assert_eq!(seen_ids, want, "k={k}: union of shard ids is the manifest");

        // census: complete, fresh, nothing stale
        let fnv = numanos::store::shard::manifest_fingerprint(&manifest).unwrap();
        let status = shard_status(&store, &fnv);
        assert_eq!(status.count, Some(k));
        assert_eq!(status.present.len(), k);
        assert!(status.missing.is_empty(), "k={k}: {:?}", status.missing);
        assert!(status.stale.is_empty(), "k={k}: {:?}", status.stale);

        // merge: a fresh session re-runs the full manifest through the
        // store — 100% hits, bytes identical to the reference
        let mut merger = Session::new();
        merger.set_store(store.clone(), true);
        let before = store.counters();
        for (sweep, (ref_csv, ref_json)) in manifest.sweeps.iter().zip(&ref_outputs) {
            let r = merger.run_sweep_with(sweep, 1).unwrap();
            assert_eq!(&r.to_csv(), ref_csv, "k={k} sweep '{}'", sweep.id);
            assert_eq!(&r.to_json().to_pretty(), ref_json, "k={k} sweep '{}'", sweep.id);
        }
        let after = store.counters();
        assert_eq!(after.hits - before.hits, 7, "k={k}: merge must be 100% cache hits");
        assert_eq!(after.misses, before.misses, "k={k}: merge must not re-execute");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Satellite: JSON and TOML spellings of one manifest produce identical
/// shard assignments — the partition keys on resolved cell identity,
/// not on the input text.
#[test]
fn json_and_toml_spellings_shard_identically() {
    let mj = ExperimentManifest::from_json_str(MANIFEST_JSON).unwrap();
    let mt = ExperimentManifest::from_toml_str(MANIFEST_TOML).unwrap();
    let ids_j = all_identities(&mj);
    let ids_t = all_identities(&mt);
    assert_eq!(ids_j, ids_t, "both spellings flatten to the same cell sequence");
    assert_eq!(
        numanos::store::shard::manifest_fingerprint(&mj).unwrap(),
        numanos::store::shard::manifest_fingerprint(&mt).unwrap(),
        "and therefore to the same fingerprint"
    );
    // per-shard ownership agrees cell by cell
    for k in [2usize, 3] {
        for i in 0..k {
            let plan = ShardPlan::new(i, k).unwrap();
            let own = |ids: &[String]| -> Vec<String> {
                ids.iter()
                    .enumerate()
                    .filter(|(g, _)| plan.owns(*g))
                    .map(|(_, id)| id.clone())
                    .collect()
            };
            assert_eq!(own(&ids_j), own(&ids_t), "shard {i}/{k}");
        }
    }
}

/// A shard that owns nothing (count > remaining cells for its index)
/// still completes and publishes its (empty) marker — merge must not
/// wait forever on it.
#[test]
fn empty_shards_still_publish_markers() {
    let manifest = ExperimentManifest::from_json_str(
        r#"{
          "title": "tiny",
          "defaults": {"size": "small", "seeds": [4]},
          "sweeps": [
            {"id": "t", "bench": "fib", "sched": ["wf"], "bind": ["numa"],
             "threads": [2, 4, 8, 16]}
          ]
        }"#,
    )
    .unwrap();
    let (dir, store) = tmp_store("empty");
    let mut session = Session::new();
    session.set_store(store.clone(), true);
    let plan = ShardPlan::new(5, 7).unwrap();
    let summary = run_manifest_shard(&session, &store, &manifest, plan, 1).unwrap();
    assert_eq!(summary.total_cells, 4);
    assert_eq!(summary.owned_cells, 0, "shard 5/7 of 4 cells owns nothing");
    let marker = store.load_shard_marker(5, 7).unwrap();
    assert!(marker.cell_ids.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Golden pin: the repo's examples manifest partitions deterministically
/// at N=3 — 52 cells split 18/17/17, with a stable per-sweep ownership
/// matrix and a stable first identity.  This is the cross-machine,
/// cross-process contract: any two builds anywhere agree on who runs
/// what.  (Assignment only — no cell is executed.)
#[test]
fn examples_manifest_golden_partition_at_three_shards() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/experiment_manifest.json");
    let manifest = ExperimentManifest::load(&path).unwrap();
    let identities = all_identities(&manifest);
    assert_eq!(identities.len(), 52, "the examples manifest is the 52-cell reference");
    assert_eq!(
        identities[0], "s1|cell|fft|small|7|x4600|first-touch|wf|2|numa||rtdata=1",
        "cell 0's canonical identity is pinned"
    );

    let totals: Vec<usize> =
        (0..3).map(|i| ShardPlan::new(i, 3).unwrap().owned_of(52)).collect();
    assert_eq!(totals, vec![18, 17, 17]);

    // per-sweep ownership matrix: [shard0, shard1, shard2] per sweep id
    let want: &[(&str, [usize; 3])] = &[
        ("numa-scaling", [8, 8, 8]),
        ("slow-dram", [1, 1, 1]),
        ("new-strategies", [2, 2, 2]),
        ("placement", [3, 3, 3]),
        ("hops-grid", [2, 1, 1]),
        ("steal-side", [2, 2, 2]),
    ];
    let mut base = 0usize;
    for (sweep, (id, owned)) in manifest.sweeps.iter().zip(want) {
        let cells = sweep.cells().unwrap().len();
        assert_eq!(&sweep.id, id, "sweep order is part of the contract");
        for i in 0..3 {
            let plan = ShardPlan::new(i, 3).unwrap();
            let got = (0..cells).filter(|c| plan.owns(base + c)).count();
            assert_eq!(got, owned[i], "sweep '{id}' shard {i}/3");
        }
        base += cells;
    }
    assert_eq!(base, 52);
}
