//! The content-addressed result store end-to-end: read-through /
//! write-through sessions, cached sweeps byte-identical to uncached
//! sequential runs, corruption quarantine, resume from a partial store,
//! `--no-cache` refresh semantics, and identity-level dedup of
//! equivalently spelled scheduler specs.

use std::path::PathBuf;
use std::sync::Arc;

use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::sched::{Policy, SchedSpec};
use numanos::spec::{RunSpec, Session, Sweep};
use numanos::store::{cell_identity, hash, ResultStore};

/// Fresh per-test store directory (pre-cleaned so reruns start empty).
fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("numanos_store_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn spec(bench: &str, sched: SchedSpec, threads: usize, seed: u64) -> RunSpec {
    RunSpec::builder()
        .bench(bench)
        .size(Size::Small)
        .sched(sched)
        .numa()
        .threads(threads)
        .seed(seed)
        .build()
        .unwrap()
}

/// The 4-cell sweep the cache tests run: fib × {wf, dfwsrpt} × {2, 4}.
fn mini_sweep() -> Sweep {
    Sweep::new("mini", "store cache grid")
        .with_bench("fib")
        .with_configs([
            (SchedSpec::stock(Policy::WorkFirst), BindPolicy::NumaAware),
            (SchedSpec::stock(Policy::Dfwsrpt), BindPolicy::NumaAware),
        ])
        .with_threads(vec![2, 4])
        .with_seeds(vec![4])
        .with_size(Size::Small)
}

/// On-disk path of a spec's cell record inside `dir`.
fn record_path(dir: &std::path::Path, s: &RunSpec) -> PathBuf {
    let key = hash::fnv1a_128_hex(cell_identity(s).unwrap().as_bytes());
    dir.join(&key[..2]).join(format!("{}.json", &key[2..]))
}

/// Tentpole acceptance (single cell): the second run is answered entirely
/// from the store — zero engine runs — and reproduces the first run's
/// CSV/JSON bytes.
#[test]
fn second_run_is_served_from_the_store_byte_identically() {
    let dir = tmpdir("roundtrip");
    let s = spec("fib", SchedSpec::stock(Policy::WorkFirst), 4, 7);

    let uncached = Session::new().run(&s).unwrap();

    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let mut first = Session::new();
    first.set_store(store.clone(), true);
    let a = first.run(&s).unwrap();
    let c = store.counters();
    assert_eq!((c.hits, c.misses, c.writes), (0, 1, 1), "cold store: one miss, one write");
    assert!(record_path(&dir, &s).exists(), "record file lands in the sharded layout");
    assert!(dir.join("index.json").exists(), "index header written");

    let store2 = Arc::new(ResultStore::open(&dir).unwrap());
    let mut second = Session::new();
    second.set_store(store2.clone(), true);
    let b = second.run(&s).unwrap();
    let c2 = store2.counters();
    assert_eq!((c2.hits, c2.misses, c2.writes), (1, 0, 0), "warm store: pure hit");

    for rec in [&a, &b] {
        assert_eq!(rec.to_csv_row(), uncached.to_csv_row());
        assert_eq!(rec.to_json().to_compact(), uncached.to_json().to_compact());
    }
}

/// Tentpole acceptance (sweep level): a parallel sweep against a cold
/// store writes every cell; the same sweep against the warm store is 100%
/// hits — and both emit CSV/JSON byte-identical to an uncached
/// sequential run.
#[test]
fn cached_sweeps_match_uncached_sequential_bytes() {
    let dir = tmpdir("sweep");
    let sweep = mini_sweep();
    let reference = Session::new().run_sweep_with(&sweep, 1).unwrap();
    let (ref_csv, ref_json) = (reference.to_csv(), reference.to_json().to_pretty());

    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let mut cold = Session::new();
    cold.set_store(store.clone(), true);
    let first = cold.run_sweep_with(&sweep, 4).unwrap();
    let c = store.counters();
    assert_eq!((c.hits, c.misses, c.writes), (0, 4, 4));
    assert_eq!(first.to_csv(), ref_csv);
    assert_eq!(first.to_json().to_pretty(), ref_json);

    let store2 = Arc::new(ResultStore::open(&dir).unwrap());
    let mut warm = Session::new();
    warm.set_store(store2.clone(), true);
    let second = warm.run_sweep_with(&sweep, 4).unwrap();
    let c2 = store2.counters();
    assert_eq!((c2.hits, c2.misses, c2.writes), (4, 0, 0), "second pass: zero engine runs");
    assert_eq!(second.to_csv(), ref_csv);
    assert_eq!(second.to_json().to_pretty(), ref_json);
}

/// Satellite: concurrent sessions sharing one store handle stay race-free
/// — both finish, both match the sequential bytes, and the shared
/// counters account every cell exactly once as hit-or-miss.
#[test]
fn concurrent_sessions_share_one_store_race_free() {
    let dir = tmpdir("race");
    let sweep = mini_sweep();
    let ref_csv = Session::new().run_sweep_with(&sweep, 1).unwrap().to_csv();

    let store = Arc::new(ResultStore::open(&dir).unwrap());
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let store = store.clone();
                let sweep = &sweep;
                scope.spawn(move || {
                    let mut session = Session::new();
                    session.set_store(store, true);
                    session.run_sweep_with(sweep, 2).unwrap().to_csv()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), ref_csv);
        }
    });
    let c = store.counters();
    assert_eq!(c.hits + c.misses, 8, "each racer accounts all 4 cells");
    assert!(c.writes >= 4, "every cell got written at least once");
    assert_eq!(c.quarantined, 0);
}

/// Satellite: corrupted and mismatched record files degrade to misses,
/// get quarantined (counter + `quarantine/` dir), and write-through
/// repairs the store so the next run hits again.
#[test]
fn corrupt_records_degrade_to_misses_and_are_quarantined() {
    let dir = tmpdir("corrupt");
    let s = spec("fib", SchedSpec::stock(Policy::WorkFirst), 4, 7);
    let uncached_row = Session::new().run(&s).unwrap().to_csv_row();

    {
        let mut session = Session::new();
        session.set_store(Arc::new(ResultStore::open(&dir).unwrap()), true);
        session.run(&s).unwrap();
    }
    let path = record_path(&dir, &s);
    let full = std::fs::read(&path).unwrap();

    // round 0: truncated bytes (unparseable); round 1: valid JSON but a
    // wrong envelope (missing kind/identity)
    let rounds = [full[..40].to_vec(), b"{\"schema\": 1}\n".to_vec()];
    for (i, bad) in rounds.iter().enumerate() {
        std::fs::write(&path, bad).unwrap();
        let store = Arc::new(ResultStore::open(&dir).unwrap());
        let mut session = Session::new();
        session.set_store(store.clone(), true);
        let rec = session.run(&s).unwrap();
        let c = store.counters();
        assert_eq!(
            (c.hits, c.misses, c.writes, c.quarantined),
            (0, 1, 1, 1),
            "round {i}: corrupt record = miss + quarantine + rewrite"
        );
        assert_eq!(rec.to_csv_row(), uncached_row, "round {i}");
        assert!(path.exists(), "round {i}: write-through repaired the record");
    }
    let quarantined = std::fs::read_dir(dir.join("quarantine")).unwrap().count();
    assert_eq!(quarantined, 2, "both bad payloads moved aside");

    // repaired store serves a clean hit
    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let mut session = Session::new();
    session.set_store(store.clone(), true);
    let rec = session.run(&s).unwrap();
    assert_eq!(rec.to_csv_row(), uncached_row);
    let c = store.counters();
    assert_eq!((c.hits, c.misses, c.quarantined), (1, 0, 0));
}

/// The invalidation rule: a store written by a different schema version
/// refuses to open (new schema, new directory) — never silently serves
/// stale records.
#[test]
fn schema_mismatch_is_a_hard_error() {
    let dir = tmpdir("schema");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("index.json"), "{\"schema\": 99}\n").unwrap();
    let err = ResultStore::open(&dir).unwrap_err().to_string();
    assert!(err.contains("schema"), "{err}");
    assert!(err.contains("fresh --store"), "{err}");
}

/// Tentpole acceptance: an interrupted sweep (only some cells stored)
/// resumed against the same store completes the missing cells and emits
/// identical final output.
#[test]
fn resume_completes_a_partial_store_with_identical_output() {
    let dir = tmpdir("resume");
    let sweep = mini_sweep();
    let cells = sweep.cells().unwrap();
    assert_eq!(cells.len(), 4);
    let ref_csv = Session::new().run_sweep_with(&sweep, 1).unwrap().to_csv();

    // "interrupted" first pass: only two of the four cells made it
    {
        let mut session = Session::new();
        session.set_store(Arc::new(ResultStore::open(&dir).unwrap()), true);
        session.run(&cells[0]).unwrap();
        session.run(&cells[3]).unwrap();
    }

    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let mut resumed = Session::new();
    resumed.set_store(store.clone(), true);
    let result = resumed.run_sweep_with(&sweep, 1).unwrap();
    let c = store.counters();
    assert_eq!(
        (c.hits, c.misses, c.writes),
        (2, 2, 2),
        "resume: stored cells hit, the rest execute once"
    );
    assert_eq!(result.to_csv(), ref_csv);
}

/// `--no-cache` semantics: read-through off means every cell re-executes
/// (no hits, no misses — the store is never consulted) while
/// write-through still refreshes the records.
#[test]
fn no_cache_mode_reexecutes_but_refreshes_records() {
    let dir = tmpdir("nocache");
    let s = spec("fib", SchedSpec::stock(Policy::WorkFirst), 4, 7);
    {
        let mut session = Session::new();
        session.set_store(Arc::new(ResultStore::open(&dir).unwrap()), true);
        session.run(&s).unwrap();
    }
    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let mut session = Session::new();
    session.set_store(store.clone(), false);
    session.run(&s).unwrap();
    let c = store.counters();
    assert_eq!(
        (c.hits, c.misses, c.writes),
        (0, 0, 1),
        "no-cache: never reads, still writes"
    );
}

/// Content addressing goes through the *resolved* scheduler signature:
/// `numa-steal` spelled bare and with its defaults written out share one
/// record, while each spelling's output keeps its own label — exactly as
/// uncached runs would.
#[test]
fn equivalent_sched_spellings_share_a_cell_but_keep_their_labels() {
    let dir = tmpdir("spellings");
    let bare = spec("fib", SchedSpec::new("numa-steal"), 4, 7);
    let explicit = spec(
        "fib",
        SchedSpec::new("numa-steal").with_param("batch", 1.0).with_param("min_kb", 16.0),
        4,
        7,
    );
    assert_eq!(cell_identity(&bare).unwrap(), cell_identity(&explicit).unwrap());
    let id = cell_identity(&bare).unwrap();
    assert!(id.contains("batch=1") && id.contains("min_kb=16"), "{id}");

    let uncached_explicit = Session::new().run(&explicit).unwrap();
    {
        let mut session = Session::new();
        session.set_store(Arc::new(ResultStore::open(&dir).unwrap()), true);
        session.run(&bare).unwrap();
    }
    let store = Arc::new(ResultStore::open(&dir).unwrap());
    let mut session = Session::new();
    session.set_store(store.clone(), true);
    let cached_explicit = session.run(&explicit).unwrap();
    assert_eq!(store.counters().hits, 1, "the bare spelling's record answers");
    assert_eq!(cached_explicit.to_csv_row(), uncached_explicit.to_csv_row());
    assert_eq!(
        cached_explicit.to_json().to_compact(),
        uncached_explicit.to_json().to_compact()
    );
    // the two spellings still label their rows differently
    let bare_row = Session::new().run(&bare).unwrap().to_csv_row();
    assert_ne!(bare_row, cached_explicit.to_csv_row());
}
