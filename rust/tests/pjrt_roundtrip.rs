//! PJRT round-trip: every AOT artifact (jax/Pallas → HLO text → xla crate)
//! executes on the CPU client and matches an independent Rust reference.
//!
//! Requires `make artifacts` AND a build with the `pjrt` cargo feature;
//! when either is missing the suite skips (each test returns early with a
//! note on stderr) so the tier-1 `cargo test` run stays green on machines
//! without the artifacts or the vendored `xla` crate.

use numanos::coordinator::priority::{alpha_weights, core_priorities};
use numanos::runtime::{Buf, ExecEngine};
use numanos::topology::Topology;

fn engine() -> Option<ExecEngine> {
    let dir = std::env::var("NUMANOS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing in '{dir}' — run `make artifacts` first");
        return None;
    }
    match ExecEngine::cpu(dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping: PJRT engine unavailable ({e})");
            None
        }
    }
}

fn det(seed: u64, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|i| (((i as u64 * 2654435761 + seed) % 1000) as f32 / 1000.0 - 0.5) * scale).collect()
}

#[test]
fn manifest_lists_all_artifacts() {
    let Some(e) = engine() else { return };
    assert!(e.manifest_len() >= 12, "expected ≥12 artifacts, got {}", e.manifest_len());
}

#[test]
fn matmul_matches_naive() {
    let Some(mut e) = engine() else { return };
    let n = 128usize;
    let a = det(1, n * n, 2.0);
    let b = det(2, n * n, 2.0);
    let got = e
        .call1("matmul_f32_128", &[Buf::f32(a.clone(), &[128, 128]), Buf::f32(b.clone(), &[128, 128])])
        .unwrap();
    for &(r, c) in &[(0usize, 0usize), (5, 77), (127, 127), (64, 1)] {
        let mut want = 0f64;
        for k in 0..n {
            want += a[r * n + k] as f64 * b[k * n + c] as f64;
        }
        let g = got[r * n + c] as f64;
        assert!((g - want).abs() < 1e-3, "({r},{c}): {g} vs {want}");
    }
}

#[test]
fn input_shape_validation_rejects_garbage() {
    let Some(mut e) = engine() else { return };
    let bad = e.call1("matmul_f32_128", &[Buf::f32(vec![0.0; 4], &[2, 2])]);
    assert!(bad.is_err(), "wrong arity/shape must be rejected");
}

#[test]
fn priority_artifact_matches_rust_coordinator() {
    // The Fig 2-4 math: Layer-1 Pallas kernel vs the pure-Rust
    // implementation the coordinator actually uses.
    let Some(mut e) = engine() else { return };
    let topo = Topology::x4600();
    let n = topo.num_cores();
    let alpha = alpha_weights(topo.max_hops());
    let mut alpha8 = [0f32; 8];
    for (i, a) in alpha.iter().enumerate() {
        alpha8[i] = *a as f32;
    }
    let hops: Vec<i32> = (0..n)
        .flat_map(|a| (0..n).map(move |b| (a, b)))
        .map(|(a, b)| topo.core_hops(a, b) as i32)
        .collect();
    let base: Vec<f32> = (0..n)
        .map(|c| topo.cores_per_node(topo.node_of(c)) as f32)
        .collect();
    let out = e
        .call(
            "priority_f32_16",
            &[
                Buf::i32(hops, &[16, 16]),
                Buf::f32(alpha8.to_vec(), &[8]),
                Buf::f32(base, &[16]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2, "priority returns (P1, P)");
    let rust = core_priorities(&topo);
    for c in 0..n {
        assert!(
            (out[0][c] as f64 - rust.p1[c]).abs() < 1e-2,
            "P1[{c}]: kernel {} vs rust {}",
            out[0][c],
            rust.p1[c]
        );
        assert!(
            (out[1][c] as f64 - rust.scores[c]).abs() / rust.scores[c] < 1e-4,
            "P[{c}]: kernel {} vs rust {}",
            out[1][c],
            rust.scores[c]
        );
    }
}

#[test]
fn fft_artifact_matches_dft() {
    let Some(mut e) = engine() else { return };
    let n = 1024usize;
    let re = det(3, n, 1.0);
    let im = det(4, n, 1.0);
    let out = e
        .call("fft_f32_1024", &[Buf::f32(re.clone(), &[1024]), Buf::f32(im.clone(), &[1024])])
        .unwrap();
    // spot-check a few bins against the O(n^2) DFT
    for &k in &[0usize, 1, 17, 511, 1023] {
        let (mut sr, mut si) = (0f64, 0f64);
        for j in 0..n {
            let ang = -2.0 * std::f64::consts::PI * (k as f64) * (j as f64) / n as f64;
            sr += re[j] as f64 * ang.cos() - im[j] as f64 * ang.sin();
            si += re[j] as f64 * ang.sin() + im[j] as f64 * ang.cos();
        }
        assert!((out[0][k] as f64 - sr).abs() < 2e-3, "re[{k}]: {} vs {sr}", out[0][k]);
        assert!((out[1][k] as f64 - si).abs() < 2e-3, "im[{k}]: {} vs {si}", out[1][k]);
    }
}

#[test]
fn sort_artifact_sorts() {
    let Some(mut e) = engine() else { return };
    let xs = det(5, 1024, 1000.0);
    let out = e.call1("sort_f32_1024", &[Buf::f32(xs.clone(), &[1024])]).unwrap();
    let mut want = xs;
    want.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_eq!(out, want, "bitonic network must sort exactly");
}

#[test]
fn lu_artifacts_factorize() {
    let Some(mut e) = engine() else { return };
    let n = 64usize;
    // diagonally dominant block
    let mut a = det(6, n * n, 1.0);
    for d in 0..n {
        a[d * n + d] += 2.0 * n as f32;
    }
    let packed = e.call1("lu0_f32_64", &[Buf::f32(a.clone(), &[64, 64])]).unwrap();
    // L @ U must reconstruct A
    let mut max_err = 0f64;
    for r in 0..n {
        for c in 0..n {
            let mut acc = 0f64;
            for k in 0..=r.min(c) {
                let l = if k == r { 1.0 } else { packed[r * n + k] as f64 };
                let u = packed[k * n + c] as f64;
                acc += l * u;
            }
            max_err = max_err.max((acc - a[r * n + c] as f64).abs());
        }
    }
    assert!(max_err < 2e-2, "LU reconstruction error {max_err}");
}

#[test]
fn bmod_artifact_is_fused_multiply_subtract() {
    let Some(mut e) = engine() else { return };
    let n = 64usize;
    let a = det(7, n * n, 1.0);
    let b = det(8, n * n, 1.0);
    let c = det(9, n * n, 1.0);
    let got = e
        .call1(
            "bmod_f32_64",
            &[
                Buf::f32(a.clone(), &[64, 64]),
                Buf::f32(b.clone(), &[64, 64]),
                Buf::f32(c.clone(), &[64, 64]),
            ],
        )
        .unwrap();
    for &(r, col) in &[(0usize, 0usize), (13, 59), (63, 63)] {
        let mut acc = c[r * n + col] as f64;
        for k in 0..n {
            acc -= a[r * n + k] as f64 * b[k * n + col] as f64;
        }
        assert!((got[r * n + col] as f64 - acc).abs() < 1e-3);
    }
}
