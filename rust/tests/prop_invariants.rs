//! Property tests over randomized workloads and topologies (hand-rolled
//! generator harness — the vendored crate set has no proptest; DESIGN.md §7).
//!
//! Invariants checked for every (random tree, random topology, policy):
//! * no task lost, none duplicated (exact task accounting);
//! * work conservation: pure-compute totals identical across schedulers;
//! * tied-task / phase discipline never deadlocks;
//! * dfwspt steal distances never exceed random-victim distances *on
//!   average* (the §VI design goal);
//! * same seed ⇒ same simulation, different seed ⇒ same task graph.

use numanos::bots::uts::Uts;
use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::coordinator::task::{BodyCtx, TaskDesc, Workload};
use numanos::simnuma::{CostModel, MemSim, Region};
use numanos::topology::Topology;
use numanos::util::{SplitMix64, Time};

/// Random spawn-tree workload: hash-driven shape, touches random slices
/// of a shared arena — a fuzzer for the engine's phase machinery.
struct RandTree {
    seed: u64,
    max_depth: u32,
    max_kids: u64,
    arena: Region,
    post_spawns: bool,
}

impl RandTree {
    fn new(seed: u64, post_spawns: bool) -> Self {
        Self { seed, max_depth: 7, max_kids: 4, arena: Region::EMPTY, post_spawns }
    }

    fn h(&self, a: u64, b: u64) -> u64 {
        let mut r = SplitMix64::new(self.seed ^ a.wrapping_mul(0x9E37).wrapping_add(b));
        r.next_u64()
    }
}

impl Workload for RandTree {
    fn name(&self) -> &'static str {
        "randtree"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.arena = mem.alloc(256 * 1024);
        mem.first_touch(master_core, self.arena, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(0, [1, 0, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        let node = desc.args[0] as u64;
        let depth = desc.args[1] as u32;
        let off = (self.h(node, 1) % 63) * 4096;
        ctx.read(self.arena.slice(off, 4096));
        ctx.compute(500 + self.h(node, 2) % 3000);
        if depth >= self.max_depth {
            return;
        }
        let kids = self.h(node, 3) % (self.max_kids + 1);
        for k in 0..kids {
            ctx.spawn(TaskDesc::new(0, [(node * 5 + k + 1) as i64, depth as i64 + 1, 0, 0]));
        }
        if kids > 0 {
            ctx.taskwait();
            ctx.write(self.arena.slice(off, 1024));
            if self.post_spawns && depth + 2 < self.max_depth && self.h(node, 4) % 3 == 0 {
                // post-phase spawning (the WaitingFinal engine path)
                ctx.spawn(TaskDesc::new(0, [(node * 5 + 4) as i64, self.max_depth as i64, 0, 0]));
            }
        }
    }
}

fn random_topology(rng: &mut SplitMix64) -> Topology {
    let nodes = 2 + (rng.next_u64() % 7) as usize; // 2..=8
    let cores = 1 + (rng.next_u64() % 3) as usize; // 1..=3 per node
    // random connected graph: chain + extra edges
    let mut edges = Vec::new();
    let mut order: Vec<usize> = (0..nodes).collect();
    rng.shuffle(&mut order);
    for w in order.windows(2) {
        edges.push((w[0], w[1]));
    }
    for _ in 0..nodes {
        let a = (rng.next_u64() % nodes as u64) as usize;
        let b = (rng.next_u64() % nodes as u64) as usize;
        if a != b {
            edges.push((a, b));
        }
    }
    Topology::from_edges("random", vec![cores; nodes], &edges, 2048).unwrap()
}

#[test]
fn random_trees_complete_everywhere_with_exact_accounting() {
    for case in 0..24u64 {
        let mut rng = SplitMix64::new(1000 + case);
        let topo = random_topology(&mut rng);
        let rt = Runtime::new(topo, CostModel::default());
        let cores = rt.topo.num_cores();
        let threads = if cores <= 2 { cores } else { 2 + (rng.next_u64() % (cores as u64 - 1)) as usize };
        let threads = threads.min(cores);
        let mut baseline: Option<u64> = None;
        for &policy in Policy::all() {
            let t = if policy == Policy::Serial { 1 } else { threads };
            let mut w = RandTree::new(case, case % 2 == 0);
            let stats = rt
                .run(&mut w, policy, BindPolicy::NumaAware, t, case, None)
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", policy.name()));
            match baseline {
                None => baseline = Some(stats.tasks),
                Some(b) => assert_eq!(
                    stats.tasks,
                    b,
                    "case {case} {}: task count mismatch",
                    policy.name()
                ),
            }
        }
    }
}

#[test]
fn compute_work_is_conserved_across_schedulers() {
    // a pure-compute workload (no memory): work_time must be identical
    struct PureTree;
    impl Workload for PureTree {
        fn name(&self) -> &'static str {
            "pure"
        }
        fn init(&mut self, _m: &mut MemSim, _c: usize) -> Time {
            0
        }
        fn root(&self) -> TaskDesc {
            TaskDesc::new(0, [3, 0, 0, 0])
        }
        fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
            let d = desc.args[0];
            ctx.compute(1000 + d as u64 * 77);
            if d > 0 {
                for _ in 0..3 {
                    ctx.spawn(TaskDesc::new(0, [d - 1, 0, 0, 0]));
                }
                ctx.taskwait();
                ctx.compute(123);
            }
        }
    }
    let rt = Runtime::paper_testbed();
    let mut works = Vec::new();
    for &policy in Policy::all() {
        let t = if policy == Policy::Serial { 1 } else { 16 };
        let mut w = PureTree;
        let s = rt.run(&mut w, policy, BindPolicy::Linear, t, 9, None).unwrap();
        works.push((policy.name(), s.work_time));
    }
    for (name, w) in &works[1..] {
        assert_eq!(*w, works[0].1, "{name} changed total compute work");
    }
}

#[test]
fn numa_steal_order_is_no_farther_than_random() {
    // over several seeds, dfwspt's mean steal distance must not exceed
    // wf's (it probes closest-first by construction)
    let rt = Runtime::paper_testbed();
    let mut wf_total = 0.0;
    let mut pt_total = 0.0;
    let mut samples = 0;
    for seed in 0..6u64 {
        let mut a = Uts::with_params(64, 8, 120, seed);
        let wf = rt.run(&mut a, Policy::WorkFirst, BindPolicy::NumaAware, 16, seed, None).unwrap();
        let mut b = Uts::with_params(64, 8, 120, seed);
        let pt = rt.run(&mut b, Policy::Dfwspt, BindPolicy::NumaAware, 16, seed, None).unwrap();
        if wf.steals > 20 && pt.steals > 20 {
            wf_total += wf.mean_steal_hops;
            pt_total += pt.mean_steal_hops;
            samples += 1;
        }
    }
    assert!(samples >= 3, "not enough steal-heavy samples");
    assert!(
        pt_total <= wf_total,
        "dfwspt mean steal hops {pt_total} exceed wf {wf_total} over {samples} runs"
    );
}

#[test]
fn seeds_change_randomized_schedules_only() {
    let rt = Runtime::paper_testbed();
    let run = |seed: u64| {
        let mut w = RandTree::new(7, true); // workload shape fixed
        rt.run(&mut w, Policy::Dfwsrpt, BindPolicy::NumaAware, 12, seed, None).unwrap()
    };
    let a = run(1);
    let b = run(2);
    assert_eq!(a.tasks, b.tasks, "workload shape must not depend on run seed");
    assert_eq!(a.work_time, b.work_time, "pure work must not depend on run seed");
}

#[test]
fn oversized_team_rejected_gracefully() {
    let rt = Runtime::paper_testbed();
    let mut w = RandTree::new(1, false);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        rt.run(&mut w, Policy::WorkFirst, BindPolicy::Linear, 17, 1, None)
    }));
    assert!(r.is_err(), "17 threads on 16 cores must be rejected");
}

#[test]
fn size_presets_are_ordered() {
    // larger presets must mean more simulated work for every benchmark
    let rt = Runtime::paper_testbed();
    for &bench in numanos::bots::NAMES {
        let time = |size| {
            let mut w = numanos::bots::create(bench, size, 5).unwrap();
            rt.run_serial(w.as_mut(), 5).unwrap().makespan
        };
        let (s, m) = (time(Size::Small), time(Size::Medium));
        assert!(m > s, "{bench}: medium ({m}) not larger than small ({s})");
    }
}
