//! Integration tests for the static-analysis subsystem: vet diagnostics
//! against intentionally-broken schedulers, registration hardening, and
//! checked-mode byte-parity.
//!
//! The broken schedulers register once per test binary under `vetbad-*`
//! names; `numanos::analysis::vet::vet_scheduler` is called per name so
//! the builtin clean-pass assertions stay independent of them.

use std::cell::Cell;
use std::sync::Once;

use numanos::analysis::{checked, vet};
use numanos::coordinator::sched::{
    register, ParamInfo, SchedDescriptor, Scheduler, SchedulerInfo, StealCand, VictimList,
};
use numanos::spec::{RunSpec, Session};
use numanos::util::SplitMix64;

/// All twelve builtins, as pinned by the registry tests.
const BUILTINS: &[&str] = &[
    "serial",
    "bf",
    "cilk",
    "wf",
    "dfwspt",
    "dfwsrpt",
    "hops-threshold",
    "hier",
    "numa-home",
    "numa-steal",
    "numa-adapt",
    "adaptive",
];

fn emit_all(vl: &VictimList, out: &mut Vec<usize>) {
    for (_, g) in &vl.groups {
        out.extend(g.iter().copied());
    }
}

/// Duplicates the first steal candidate — `steal_bias` may only reorder
/// or filter (VET005).
struct DupVictimBias;

impl Scheduler for DupVictimBias {
    fn name(&self) -> &str {
        "vetbad-dup-bias"
    }
    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor { places: true, ..SchedDescriptor::WORK_STEALING }
    }
    fn victim_order(&self, vl: &VictimList, _rng: &mut SplitMix64, out: &mut Vec<usize>) {
        emit_all(vl, out);
    }
    fn steal_bias(&self, _thief_node: usize, cands: &mut Vec<StealCand>) {
        if let Some(&c0) = cands.first() {
            cands.push(c0);
        }
    }
}

/// Emits the first victim twice plus an id that is in nobody's victim
/// list (VET001 + VET002).
struct NonPermOrder;

impl Scheduler for NonPermOrder {
    fn name(&self) -> &str {
        "vetbad-nonperm"
    }
    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor { full_sweep: false, ..SchedDescriptor::WORK_STEALING }
    }
    fn victim_order(&self, vl: &VictimList, _rng: &mut SplitMix64, out: &mut Vec<usize>) {
        if let Some((_, g)) = vl.groups.first() {
            out.push(g[0]);
            out.push(g[0]); // duplicate
        }
        out.push(usize::MAX); // never a victim
    }
}

/// Declares `observes=false` but changes its victim order once an event
/// is delivered (VET008).
struct FalseObserves {
    poked: Cell<bool>,
}

impl Scheduler for FalseObserves {
    fn name(&self) -> &str {
        "vetbad-false-observes"
    }
    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor { observes: false, ..SchedDescriptor::WORK_STEALING }
    }
    fn victim_order(&self, vl: &VictimList, _rng: &mut SplitMix64, out: &mut Vec<usize>) {
        emit_all(vl, out);
        if self.poked.get() {
            out.reverse();
        }
    }
    fn observe(&self, _event: &numanos::coordinator::sched::SchedEvent) {
        self.poked.set(true);
    }
}

/// A well-behaved no-op scheduler whose factory asks for a parameter it
/// never declared (VET009).
struct Undeclared;

impl Scheduler for Undeclared {
    fn name(&self) -> &str {
        "vetbad-undeclared"
    }
    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor::WORK_STEALING
    }
    fn victim_order(&self, vl: &VictimList, _rng: &mut SplitMix64, out: &mut Vec<usize>) {
        emit_all(vl, out);
    }
}

/// The runtime checked flag is process-global and libtest runs tests on
/// parallel threads — every test that flips it holds this lock so the
/// parity comparison never races another test's `set_enabled`.
static CHECKED_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn ensure_broken_registered() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        register(
            SchedulerInfo::new("vetbad-dup-bias", "test: steal_bias duplicates a victim"),
            |_| Ok(Box::new(DupVictimBias)),
        )
        .unwrap();
        register(
            SchedulerInfo::new("vetbad-nonperm", "test: non-permutation victim order"),
            |_| Ok(Box::new(NonPermOrder)),
        )
        .unwrap();
        register(
            SchedulerInfo::new("vetbad-false-observes", "test: observes=false but reacts"),
            |_| Ok(Box::new(FalseObserves { poked: Cell::new(false) })),
        )
        .unwrap();
        register(
            SchedulerInfo::new("vetbad-undeclared", "test: factory wants an undeclared param"),
            |p| {
                p.req("ghost")?; // never declared -> build() must fail
                Ok(Box::new(Undeclared))
            },
        )
        .unwrap();
    });
}

fn codes(name: &str) -> Vec<&'static str> {
    let mut codes: Vec<&'static str> =
        vet::vet_scheduler(name).unwrap().iter().map(|d| d.code).collect();
    codes.sort_unstable();
    codes.dedup();
    codes
}

#[test]
fn all_builtins_vet_clean() {
    for name in BUILTINS {
        let diags = vet::vet_scheduler(name).unwrap();
        assert!(diags.is_empty(), "builtin '{name}' must vet clean, got {diags:?}");
    }
}

#[test]
fn duplicate_bias_victim_fires_vet005() {
    ensure_broken_registered();
    let c = codes("vetbad-dup-bias");
    assert!(c.contains(&"VET005"), "{c:?}");
    assert!(!c.contains(&"VET004"), "duplicating an offered victim is not injection: {c:?}");
    assert!(!c.contains(&"VET001"), "the victim order itself is clean: {c:?}");
}

#[test]
fn non_permutation_order_fires_vet001_and_vet002() {
    ensure_broken_registered();
    let c = codes("vetbad-nonperm");
    assert!(c.contains(&"VET001"), "{c:?}");
    assert!(c.contains(&"VET002"), "{c:?}");
}

#[test]
fn false_observes_declaration_fires_vet008() {
    ensure_broken_registered();
    let c = codes("vetbad-false-observes");
    assert!(c.contains(&"VET008"), "{c:?}");
    assert!(
        !c.contains(&"VET011"),
        "with observe delivered to both replicas the scheduler is deterministic: {c:?}"
    );
}

#[test]
fn undeclared_factory_param_fires_vet009() {
    ensure_broken_registered();
    let c = codes("vetbad-undeclared");
    assert_eq!(c, vec!["VET009"], "build-with-defaults failure is the only finding");
}

#[test]
fn vet_rejects_unknown_names() {
    assert!(vet::vet_scheduler("vetbad-no-such").is_err());
}

/// Satellite: `register()` now hard-rejects invalid parameter
/// declarations in release builds too (previously only a `debug_assert`
/// inside `ParamInfo::bounded`).  The bad declaration is built via the
/// struct literal so the test exercises the registry's own check.
#[test]
fn register_rejects_default_outside_declared_range() {
    let mut info = SchedulerInfo::new("vetbad-bad-default", "test: default out of range");
    info.params.push(ParamInfo {
        name: "k".into(),
        default: 5.0,
        min: 0.0,
        max: 1.0,
        doc: "broken on purpose".into(),
    });
    let err = register(info, |_| Ok(Box::new(Undeclared))).unwrap_err();
    assert!(err.to_string().contains("outside declared range"), "{err}");

    let mut info = SchedulerInfo::new("vetbad-nan-default", "test: NaN default");
    info.params.push(ParamInfo {
        name: "k".into(),
        default: f64::NAN,
        min: 0.0,
        max: 1.0,
        doc: "broken on purpose".into(),
    });
    assert!(register(info, |_| Ok(Box::new(Undeclared))).is_err());

    let mut info = SchedulerInfo::new("vetbad-dup-param", "test: duplicate param names");
    info.params.push(ParamInfo::new("k", 0.5, "first"));
    info.params.push(ParamInfo::new("k", 0.7, "second"));
    let err = register(info, |_| Ok(Box::new(Undeclared))).unwrap_err();
    assert!(err.to_string().contains("twice"), "{err}");
}

/// The checked engine observes without perturbing: the same spec run
/// with the invariant layer on and off produces byte-identical records
/// (the in-process version of CI's `bench --compare --fail-on-drift`).
#[test]
fn checked_mode_is_byte_identical() {
    let _guard = CHECKED_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let spec = |sched: &str| -> RunSpec {
        RunSpec::builder()
            .bench("fib")
            .size(numanos::config::Size::Small)
            .sched(numanos::coordinator::sched::SchedSpec::new(sched))
            .numa()
            .threads(8)
            .seed(3)
            .build()
            .unwrap()
    };
    // numa-adapt exercises placement, mailboxes, steal bias and observe;
    // dfwsrpt is the stock work-stealing path.
    for sched in ["dfwsrpt", "numa-adapt"] {
        let s = spec(sched);
        checked::set_enabled(false);
        let plain = Session::new().run(&s).unwrap().to_csv_row();
        checked::set_enabled(true);
        let checked_row = Session::new().run(&s).unwrap().to_csv_row();
        checked::set_enabled(false);
        assert_eq!(plain, checked_row, "checked mode must not perturb '{sched}'");
    }
}

/// A full checked run over every builtin (small spec): no false-positive
/// invariant reports.
#[test]
fn checked_mode_passes_all_builtins() {
    let _guard = CHECKED_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    checked::set_enabled(true);
    let session = Session::new();
    for sched in BUILTINS {
        let threads = if *sched == "serial" { 1 } else { 4 };
        let s = RunSpec::builder()
            .bench("sort")
            .size(numanos::config::Size::Small)
            .sched(numanos::coordinator::sched::SchedSpec::new(sched))
            .numa()
            .threads(threads)
            .seed(7)
            .build()
            .unwrap();
        let rec = session.run(&s).unwrap();
        assert!(rec.stats.makespan > 0, "{sched}");
    }
    checked::set_enabled(false);
}
