//! Integration: every benchmark × every scheduler completes, task counts
//! are policy-invariant, runs are deterministic, speedup is sane.

use numanos::bots;
use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;

#[test]
fn every_benchmark_completes_under_every_policy() {
    let rt = Runtime::paper_testbed();
    for &bench in bots::NAMES {
        let mut counts = Vec::new();
        for &policy in Policy::all() {
            let threads = if policy == Policy::Serial { 1 } else { 8 };
            let mut w = bots::create(bench, Size::Small, 11).unwrap();
            let stats = rt
                .run(w.as_mut(), policy, BindPolicy::Linear, threads, 11, None)
                .unwrap_or_else(|e| panic!("{bench}/{}: {e}", policy.name()));
            assert!(stats.tasks > 0, "{bench}/{}", policy.name());
            assert!(stats.makespan > 0, "{bench}/{}", policy.name());
            counts.push(stats.tasks);
        }
        // the task graph is a property of the workload, not the scheduler
        assert!(
            counts.windows(2).all(|w| w[0] == w[1]),
            "{bench}: task counts vary across policies: {counts:?}"
        );
    }
}

#[test]
fn parallel_never_slower_than_half_ideal_serial() {
    // loose sanity: 8 threads must be at least 1.2x serial on every bench
    let rt = Runtime::paper_testbed();
    for &bench in bots::NAMES {
        let mut ws = bots::create(bench, Size::Small, 3).unwrap();
        let serial = rt.run_serial(ws.as_mut(), 3).unwrap();
        let mut wp = bots::create(bench, Size::Small, 3).unwrap();
        let par = rt
            .run(wp.as_mut(), Policy::WorkFirst, BindPolicy::NumaAware, 8, 3, None)
            .unwrap();
        let sp = serial.makespan as f64 / par.makespan as f64;
        assert!(sp > 1.2, "{bench}: speedup {sp:.2} at 8 threads");
    }
}

#[test]
fn runs_are_bit_deterministic() {
    let rt = Runtime::paper_testbed();
    for &bench in &["fft", "uts", "sparselu_single", "floorplan"] {
        for &policy in &[Policy::BreadthFirst, Policy::Dfwsrpt] {
            let run = |seed| {
                let mut w = bots::create(bench, Size::Small, seed).unwrap();
                rt.run(w.as_mut(), policy, BindPolicy::NumaAware, 8, seed, None).unwrap()
            };
            let (a, b) = (run(5), run(5));
            assert_eq!(a.makespan, b.makespan, "{bench}/{}", policy.name());
            assert_eq!(a.steals, b.steals);
            assert_eq!(a.mem.miss_lines(), b.mem.miss_lines());
            // a different seed must change victim randomization outcomes
            let c = run(6);
            assert!(
                c.makespan != a.makespan || c.steals != a.steals || bench == "fft",
                "{bench}: seed had no effect at all"
            );
        }
    }
}

#[test]
fn thread_sweep_is_monotonic_enough() {
    // speedup should not crater when adding threads for the scalable
    // work-stealing policies
    let rt = Runtime::paper_testbed();
    for &bench in &["fib", "nqueens", "alignment"] {
        let mut ws = bots::create(bench, Size::Small, 7).unwrap();
        let serial = rt.run_serial(ws.as_mut(), 7).unwrap();
        let mut prev = 0.0;
        for threads in [2usize, 4, 8, 16] {
            let mut w = bots::create(bench, Size::Small, 7).unwrap();
            let s = rt.run(w.as_mut(), Policy::WorkFirst, BindPolicy::NumaAware, threads, 7, None).unwrap();
            let sp = serial.makespan as f64 / s.makespan as f64;
            assert!(
                sp > prev * 0.85,
                "{bench}: speedup dropped hard: {prev:.2} -> {sp:.2} at {threads}"
            );
            prev = sp;
        }
    }
}

#[test]
fn work_stealing_balances_uts() {
    let rt = Runtime::paper_testbed();
    let mut w = bots::create("uts", Size::Small, 13).unwrap();
    let s = rt.run(w.as_mut(), Policy::Dfwsrpt, BindPolicy::NumaAware, 16, 13, None).unwrap();
    let max = *s.per_worker_tasks.iter().max().unwrap() as f64;
    let min = *s.per_worker_tasks.iter().min().unwrap() as f64;
    assert!(min > 0.0, "some worker starved: {:?}", s.per_worker_tasks);
    assert!(max / min < 50.0, "gross imbalance: {:?}", s.per_worker_tasks);
}

#[test]
fn topologies_other_than_x4600_work() {
    use numanos::simnuma::CostModel;
    use numanos::topology::Topology;
    for topo in ["dual", "quad", "altix16", "tile16", "x4600_hetero", "uma"] {
        let rt = Runtime::new(Topology::by_name(topo).unwrap(), CostModel::default());
        let threads = rt.topo.num_cores().min(8);
        let mut w = bots::create("sort", Size::Small, 2).unwrap();
        let s = rt.run(w.as_mut(), Policy::Dfwspt, BindPolicy::NumaAware, threads, 2, None).unwrap();
        assert!(s.tasks > 0, "{topo}");
    }
}
