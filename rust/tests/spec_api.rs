//! The experiment API end-to-end: `RunSpec` validation, JSON/TOML
//! round-trips, sweep cross-products, manifest loading, and — the load-
//! bearing guarantee — parallel sweep execution being byte-identical to
//! sequential execution.

use numanos::config::Size;
use numanos::coordinator::binding::{bind_threads, BindPolicy};
use numanos::coordinator::sched::{build_victim_lists, Policy, VictimList};
use numanos::harness;
use numanos::metrics::speedup;
use numanos::spec::{ExperimentManifest, RunSpec, Session, Sweep};
use numanos::util::SplitMix64;
use numanos::{bots, Runtime, Topology};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("numanos_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn builder_validates_every_axis() {
    assert!(RunSpec::builder().bench("fft").numa().threads(16).build().is_ok());
    for bad in [
        RunSpec::builder().bench("not_a_bench"),
        RunSpec::builder().threads(0),
        RunSpec::builder().threads(64), // > x4600 cores
        RunSpec::builder().topo("not_a_topo"),
        RunSpec::builder().policy(Policy::Serial).threads(2),
        RunSpec::builder().cost("not_a_knob", 1.0),
        RunSpec::builder().cores(vec![3, 3]),
    ] {
        let err = bad.build().unwrap_err();
        assert!(!format!("{err:#}").is_empty());
    }
}

#[test]
fn spec_roundtrips_json_and_toml_agree() {
    let spec = RunSpec::builder()
        .bench("fft")
        .size(Size::Small)
        .policy(Policy::Dfwsrpt)
        .numa()
        .threads(12)
        .seed(77)
        .cost("dram_base_ns", 90.0)
        .build()
        .unwrap();
    // JSON round-trip
    let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(back, spec);
    // the equivalent TOML parses to the same spec
    let toml = "bench = \"fft\"\nsize = \"small\"\nsched = \"dfwsrpt\"\nbind = \"numa\"\n\
                threads = 12\nseed = 77\n\n[cost]\ndram_base_ns = 90\n";
    assert_eq!(RunSpec::from_toml_str(toml).unwrap(), spec);
}

#[test]
fn sweep_cross_product_counts() {
    let sweep = Sweep::new("grid", "grid")
        .with_benches(["fib", "sort", "fft"])
        .with_config(Policy::WorkFirst, BindPolicy::Linear)
        .with_config(Policy::WorkFirst, BindPolicy::NumaAware)
        .with_threads(vec![2, 4, 8, 16])
        .with_seeds(vec![1, 2, 3, 4, 5])
        .with_size(Size::Small);
    assert_eq!(sweep.cell_count(), 3 * 2 * 4 * 5);
    let cells = sweep.cells().unwrap();
    assert_eq!(cells.len(), sweep.cell_count());
    for c in &cells {
        c.validate().unwrap();
    }
}

#[test]
fn parallel_sweep_is_byte_identical_to_sequential() {
    let sweep = Sweep::new("det", "determinism check")
        .with_benches(["fib", "sort"])
        .with_config(Policy::WorkFirst, BindPolicy::Linear)
        .with_config(Policy::Dfwsrpt, BindPolicy::NumaAware)
        .with_threads(vec![2, 8])
        .with_seeds(vec![1, 9])
        .with_size(Size::Small);
    // independent sessions so no memo state leaks between the two modes
    let seq = Session::new().run_sweep_with(&sweep, 1).unwrap();
    let par = Session::new().run_sweep_with(&sweep, 8).unwrap();
    assert_eq!(seq.records.len(), 16);
    assert_eq!(seq.to_csv(), par.to_csv(), "parallel CSV must match sequential byte-for-byte");
    assert_eq!(
        seq.to_json().to_pretty(),
        par.to_json().to_pretty(),
        "parallel JSON must match sequential"
    );
    assert_eq!(seq.table().to_markdown(), par.table().to_markdown());
    // and re-running the same sweep on the same session is stable too
    let again = Session::new().run_sweep(&sweep).unwrap();
    assert_eq!(again.to_csv(), seq.to_csv());
}

#[test]
fn sweep_records_match_direct_runtime_runs() {
    // the declarative path must reproduce exactly what the low-level
    // Runtime verbs produce for the same axes
    let sweep = Sweep::new("parity", "parity")
        .with_bench("fib")
        .with_config(Policy::Dfwspt, BindPolicy::NumaAware)
        .with_threads(vec![4])
        .with_seeds(vec![3])
        .with_size(Size::Small);
    let rec = &Session::new().run_sweep(&sweep).unwrap().records[0];

    let rt = Runtime::paper_testbed();
    let mut ws = bots::create("fib", Size::Small, 3).unwrap();
    let serial = rt.run_serial(ws.as_mut(), 3).unwrap();
    let mut w = bots::create("fib", Size::Small, 3).unwrap();
    let direct = rt.run(w.as_mut(), Policy::Dfwspt, BindPolicy::NumaAware, 4, 3, None).unwrap();

    assert_eq!(rec.stats.makespan, direct.makespan);
    assert_eq!(rec.stats.steals, direct.steals);
    assert_eq!(rec.serial_makespan, serial.makespan);
    assert!((rec.speedup - speedup(&serial, &direct)).abs() < 1e-12);
}

#[test]
fn figure_tables_unchanged_by_the_sweep_port() {
    // same tiny figure both ways: through the sweep-backed harness and
    // through a hand-rolled loop over the legacy Runtime verbs
    let spec = harness::FigureSpec {
        id: "t",
        title: "t",
        bench: "fib",
        size: Size::Small,
        configs: vec![
            (Policy::WorkFirst, BindPolicy::Linear),
            (Policy::Dfwsrpt, BindPolicy::NumaAware),
        ],
        threads: vec![2, 8],
    };
    let rt = Runtime::paper_testbed();
    let ported = harness::run_figure(&rt, &spec, 5).unwrap();

    let mut ws = bots::create("fib", Size::Small, 5).unwrap();
    let serial = rt.run_serial(ws.as_mut(), 5).unwrap();
    for (row, &(policy, bind)) in ported.rows.iter().zip(&spec.configs) {
        assert_eq!(row.0, harness::config_label(policy, bind));
        for (&threads, &got) in spec.threads.iter().zip(&row.1) {
            let mut w = bots::create("fib", Size::Small, 5).unwrap();
            let s = rt.run(w.as_mut(), policy, bind, threads, 5, None).unwrap();
            let want = speedup(&serial, &s);
            assert!((got - want).abs() < 1e-12, "{policy:?}/{bind:?}@{threads}: {got} vs {want}");
        }
    }
}

#[test]
fn nine_figures_expand_to_sweeps() {
    let sweeps = harness::figure_sweeps(Size::Medium, 42);
    assert_eq!(sweeps.len(), 9);
    let total: usize = sweeps.iter().map(|s| s.cell_count()).sum();
    // 6 figures × 6 configs × 6 threads + 3 figures × 3 configs × 6 threads
    assert_eq!(total, 6 * 6 * 6 + 3 * 3 * 6);
}

#[test]
fn manifest_files_run_end_to_end() {
    let dir = tmp_dir("manifest");
    let json_path = dir.join("exp.json");
    std::fs::write(
        &json_path,
        r#"{
          "title": "integration",
          "defaults": {"size": "small", "seed": 2},
          "sweeps": [
            {"id": "mini", "bench": "fib", "sched": ["wf", "dfwspt"],
             "bind": ["numa"], "threads": [2, 4]}
          ]
        }"#,
    )
    .unwrap();
    let toml_path = dir.join("exp.toml");
    std::fs::write(
        &toml_path,
        "title = \"integration\"\n\n[defaults]\nsize = \"small\"\nseed = 2\n\n\
         [[sweeps]]\nid = \"mini\"\nbench = \"fib\"\nsched = [\"wf\", \"dfwspt\"]\n\
         bind = [\"numa\"]\nthreads = [2, 4]\n",
    )
    .unwrap();

    let mj = ExperimentManifest::load(&json_path).unwrap();
    let mt = ExperimentManifest::load(&toml_path).unwrap();
    assert_eq!(mj, mt, "JSON and TOML forms of the same manifest must agree");

    let session = Session::new();
    let result = session.run_sweep(&mj.sweeps[0]).unwrap();
    assert_eq!(result.records.len(), 4);
    let table = result.table();
    assert_eq!(table.rows.len(), 2);
    assert_eq!(table.rows[0].0, "wf-Scheduler-NUMA");
    assert_eq!(table.rows[1].0, "dfwspt-Scheduler-NUMA");
    let csv = result.to_csv();
    assert!(csv.lines().count() == 1 + 4, "{csv}");
    assert!(csv.starts_with("sweep,bench,size,policy,bind,mem,threads"), "{csv}");

    std::fs::remove_dir_all(&dir).ok();
}

/// One sweep cell on `topo` with every thread bound linearly; returns
/// the executed record set plus the victim lists of that binding.
fn run_cell_and_victim_lists(topo_name: &str, threads: usize) -> Vec<VictimList> {
    let sweep = Sweep::new("grid", "non-flagship grid")
        .with_bench("fib")
        .with_config(Policy::Dfwspt, BindPolicy::Linear)
        .with_threads(vec![threads])
        .with_seeds(vec![3])
        .with_size(Size::Small)
        .with_topo(topo_name);
    let result = Session::new().run_sweep(&sweep).unwrap();
    assert_eq!(result.records.len(), 1);
    let rec = &result.records[0];
    assert_eq!(rec.spec.topo, topo_name);
    assert!(rec.stats.makespan > 0, "{topo_name}");
    assert!(rec.stats.tasks > 1, "{topo_name}");

    let topo = Topology::by_name(topo_name).unwrap();
    let mut rng = SplitMix64::new(3);
    let binding = bind_threads(&topo, threads, BindPolicy::Linear, &mut rng);
    let vls = build_victim_lists(&topo, &binding.cores);
    for vl in &vls {
        assert_eq!(vl.total(), threads - 1, "{topo_name}");
        for w in vl.groups.windows(2) {
            assert!(w[0].0 < w[1].0, "{topo_name}: groups must ascend by distance");
        }
    }
    vls
}

#[test]
fn sweep_cell_runs_on_x4600_hetero_with_correct_hop_groups() {
    // 24 cores: corners carry 2, inner sockets 4 (nodes 2..=5)
    let vls = run_cell_and_victim_lists("x4600_hetero", 24);
    // thread 0 is on corner node 0 with a single sibling
    assert_eq!(vls[0].groups[0], (0, vec![1]));
    // thread 4 is the first core of 4-core node 2: three same-node siblings
    assert_eq!(vls[4].groups[0], (0, vec![5, 6, 7]));
    // node 2 neighbours nodes 0, 4 and 5 (the twist link), so the 1-hop
    // group holds their cores: 0,1 (node 0), 12..=15 (node 4), 16..=19 (node 5)
    assert_eq!(vls[4].groups[1], (1, vec![0, 1, 12, 13, 14, 15, 16, 17, 18, 19]));
}

#[test]
fn sweep_cell_runs_on_tile16_with_manhattan_hop_groups() {
    // 4x4 single-core mesh: corner tile 0 sees Manhattan-distance rings
    let vls = run_cell_and_victim_lists("tile16", 16);
    let sizes: Vec<(u8, usize)> = vls[0].groups.iter().map(|(h, g)| (*h, g.len())).collect();
    assert_eq!(sizes, vec![(1, 2), (2, 3), (3, 4), (4, 3), (5, 2), (6, 1)]);
    assert_eq!(vls[0].groups[0], (1, vec![1, 4]), "east and south neighbours");
    // a centre tile (row 1, col 1 = tile 5) reaches everything within 4 hops
    let centre: Vec<(u8, usize)> = vls[5].groups.iter().map(|(h, g)| (*h, g.len())).collect();
    assert_eq!(centre, vec![(1, 4), (2, 6), (3, 4), (4, 1)]);
}

#[test]
fn sweep_cell_runs_on_altix16_with_deep_fabric_groups() {
    // two bridged 8-node ladders, 2 cores per node, 32 cores
    let vls = run_cell_and_victim_lists("altix16", 32);
    // same-node sibling first
    assert_eq!(vls[0].groups[0], (0, vec![1]));
    // node 0 neighbours nodes 1 and 2 -> cores 2..=5 at one hop
    assert_eq!(vls[0].groups[1], (1, vec![2, 3, 4, 5]));
    // the far ladder sits beyond the single bridge: deeper than any
    // x4600 distance (max 3 hops there)
    let deepest = vls[0].groups.last().unwrap().0;
    assert!(deepest > 3, "bridged fabric must exceed x4600 depth, got {deepest}");
}

#[test]
fn session_baseline_dedup_across_grid() {
    // one bench × one seed across many configs/threads → exactly one
    // serial baseline, shared by every record
    let sweep = Sweep::new("dedup", "dedup")
        .with_bench("fib")
        .with_config(Policy::WorkFirst, BindPolicy::Linear)
        .with_config(Policy::CilkBased, BindPolicy::Linear)
        .with_threads(vec![2, 4])
        .with_seeds(vec![8])
        .with_size(Size::Small);
    let result = Session::new().run_sweep(&sweep).unwrap();
    let first = result.records[0].serial_makespan;
    assert!(result.records.iter().all(|r| r.serial_makespan == first));
}
