//! The cost model: every simulated-time constant in one place.
//!
//! Values are loosely calibrated to the paper's SunFire X4600 (dual-core
//! Opteron 8218, DDR2, 3-hop HyperTransport fabric) but what matters for
//! reproduction is the *ratios* (DESIGN.md §2): local-vs-remote NUMA
//! factors ~1.0 : 1.4 : 1.9 : 2.3 across 0–3 hops, caches ~50x cheaper
//! than DRAM, queue operations comparable to a handful of DRAM accesses.
//! The starred knobs are the calibration surface: override any of them
//! from the CLI with `--cost k=v,...` (see `config::apply_cost_override`);
//! EXPERIMENTS.md records the defaults every figure was generated with.

use crate::util::{Time, NS};

/// All simulator cost constants (picosecond units via [`Time`]).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Simulated time per benchmark "compute unit" (1 unit ≈ 1 ns of ALU work).
    pub compute_per_unit: Time,
    /// Cache line size used for bandwidth charging.
    pub line_bytes: u64,
    /// Per-line cost when the line is L1-resident.
    pub l1_hit: Time,
    /// Per-line cost when served from L2.
    pub l2_hit: Time,
    /// DRAM access latency, charged once per page-chunk miss. (*)
    pub dram_base: Time,
    /// Extra latency per interconnect hop on a miss — the NUMA factor. (*)
    pub hop_penalty: Time,
    /// Memory-controller occupancy per line (inverse bandwidth). (*)
    pub mem_service: Time,
    /// Extra occupancy multiplier per hop, in percent (remote streams
    /// consume fabric bandwidth): service *= (100 + hops * this) / 100.
    pub remote_bw_pct_per_hop: u64,
    /// L1/L2 cache capacities in pages.
    pub l1_pages: usize,
    pub l2_pages: usize,
    /// Local task-pool operation (lock + push/pop).
    pub queue_op: Time,
    /// Shared breadth-first queue operation (serialized; contention emerges
    /// from the queue's busy window in the engine). (*)
    pub shared_queue_op: Time,
    /// Creating a task descriptor + runtime bookkeeping at spawn.
    pub spawn_cost: Time,
    /// Probing a (possibly remote) victim deque for emptiness.
    pub probe_base: Time,
    pub probe_per_hop: Time,
    /// Completing a steal: detaching + migrating the task header.
    pub steal_base: Time,
    pub steal_per_hop: Time,
    /// Extra per queue-op penalty per hop when a worker's *runtime data*
    /// lives on a remote node (paper §IV last paragraph).
    pub rtdata_per_hop: Time,
    /// Idle retry backoff when no work is found anywhere.
    pub idle_backoff: Time,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            compute_per_unit: NS,
            line_bytes: 64,
            l1_hit: NS / 2,             // 0.5 ns/line streamed from L1
            l2_hit: 2 * NS,             // 2 ns/line from L2
            dram_base: 100 * NS,        // local DRAM latency (per page chunk)
            hop_penalty: 80 * NS,       // +80 ns/hop first-access latency
            mem_service: 3 * NS,        // ~21 B/ns node bandwidth (DDR2-ish)
            remote_bw_pct_per_hop: 120, // HT streams degrade steeply per hop
            l1_pages: 16,              // 64 KiB
            l2_pages: 256,             // 1 MiB
            queue_op: 60 * NS,
            shared_queue_op: 200 * NS,
            spawn_cost: 90 * NS,
            probe_base: 40 * NS,
            probe_per_hop: 20 * NS,
            steal_base: 150 * NS,
            steal_per_hop: 80 * NS,
            rtdata_per_hop: 15 * NS,
            idle_backoff: 500 * NS,
        }
    }
}

impl CostModel {
    /// Effective NUMA factor for a given hop count (diagnostics).
    pub fn numa_factor(&self, hops: u8) -> f64 {
        (self.dram_base + hops as Time * self.hop_penalty) as f64 / self.dram_base as f64
    }

    /// Per-line service time for a stream from `hops` away.
    pub fn service_per_line(&self, hops: u8) -> Time {
        self.mem_service * (100 + hops as Time * self.remote_bw_pct_per_hop) / 100
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numa_factors_increase() {
        let m = CostModel::default();
        let f: [f64; 4] = std::array::from_fn(|h| m.numa_factor(h as u8));
        assert_eq!(f[0], 1.0);
        for w in f.windows(2) {
            assert!(w[1] > w[0]);
        }
        // steep but bounded: 3-hop latency factor in the 2x-4x band
        // (bandwidth degradation per hop is modeled separately)
        assert!(f[3] > 2.0 && f[3] < 4.0, "{f:?}");
    }

    #[test]
    fn remote_bandwidth_slower() {
        let m = CostModel::default();
        assert!(m.service_per_line(3) > m.service_per_line(0));
    }

    #[test]
    fn cache_much_cheaper_than_dram() {
        let m = CostModel::default();
        assert!(m.dram_base / m.l1_hit >= 50);
    }
}
