//! Memory-system simulator — the paper's §II NUMA behaviour as a
//! deterministic cost model.
//!
//! The paper's effects are *latency-accounting* effects: remote accesses
//! cost more the farther the owning node is, first-touch decides ownership,
//! caches absorb repeated touches, and memory controllers / queues serialize
//! concurrent traffic.  This module charges simulated time ([`util::Time`],
//! picoseconds) for every task memory access so the coordinator's
//! discrete-event engine can reproduce the paper's speedup curves.
//!
//! Submodules:
//! * [`page`]   — page table with **first-touch** placement and nearest-node
//!   spill (the Linux policy the paper's §V.B analysis leans on);
//! * [`cache`]  — per-core two-level cache model (page-granular tags with
//!   version-based coherence);
//! * [`latency`]— the [`CostModel`]: NUMA factors, bandwidth, contention;
//! * [`memory`] — the [`MemSim`] façade the engine calls.

pub mod cache;
pub mod latency;
pub mod memory;
pub mod page;

pub use latency::CostModel;
pub use memory::{MemSim, MemStats, Region};
pub use page::{PageTable, PAGE_BYTES};
