//! Memory-system simulator — the paper's §II NUMA behaviour as a
//! deterministic cost model.
//!
//! The paper's effects are *latency-accounting* effects: remote accesses
//! cost more the farther the owning node is, first-touch decides ownership,
//! caches absorb repeated touches, and memory controllers / queues serialize
//! concurrent traffic.  This module charges simulated time ([`util::Time`],
//! picoseconds) for every task memory access so the coordinator's
//! discrete-event engine can reproduce the paper's speedup curves.
//!
//! Submodules:
//! * [`policy`] — pluggable [`PagePolicy`] (`first-touch` / `interleave` /
//!   `bind` / `next-touch`) and the serializable [`MemSpec`] selection the
//!   experiment surface sweeps;
//! * [`page`]   — page table executing the policy, with nearest-node
//!   capacity spill (the Linux rule the paper's §V.B analysis leans on);
//! * [`cache`]  — per-core two-level cache model (page-granular tags with
//!   version-based coherence);
//! * [`latency`]— the [`CostModel`]: NUMA factors, bandwidth, contention;
//! * [`memory`] — the [`MemSim`] façade the engine calls, including the
//!   [`MemSim::home_node`] majority-owner query that placement decisions
//!   (the scheduler `place()` hook) consult.

pub mod cache;
pub mod latency;
pub mod memory;
pub mod page;
pub mod policy;

pub use latency::CostModel;
pub use memory::{MemSim, MemStats, Region};
pub use page::{PageTable, PAGE_BYTES};
pub use policy::{page_policy_infos, page_policy_names, MemSpec, PagePolicy};
