//! Page table with first-touch NUMA placement.
//!
//! Models the policy the paper describes in §V.B: physical allocation is
//! deferred until the first read/write; the page then lands on the local
//! node of the touching CPU, falling back to the *closest* node with free
//! capacity when the local node is full (`set_mempolicy(2)` default
//! behaviour).  This is exactly why the paper's master-thread placement
//! matters — the master first-touches the program's data during
//! initialization, so its node choice decides everyone's access distances.

use crate::topology::Topology;

/// Page size in bytes (x86-64 default).
pub const PAGE_BYTES: u64 = 4096;

/// Placement + coherence info for one resident page.
#[derive(Clone, Copy, Debug)]
pub struct PageInfo {
    /// Owning NUMA node (fixed at first touch).
    pub node: u32,
    /// Bumped on every write; caches holding an older version are stale.
    pub version: u32,
}

/// First-touch page table over the simulated physical memory.
///
/// Page ids come from [`super::MemSim`]'s bump allocator, so they are
/// dense — a flat `Vec` beats a hash map on the access hot path
/// (EXPERIMENTS.md §Perf it3).
#[derive(Debug)]
pub struct PageTable {
    map: Vec<Option<PageInfo>>,
    resident: usize,
    node_used: Vec<u64>,
    capacity_per_node: u64,
}

impl PageTable {
    pub fn new(nodes: usize, capacity_per_node: u64) -> Self {
        Self {
            map: Vec::new(),
            resident: 0,
            node_used: vec![0; nodes],
            capacity_per_node,
        }
    }

    #[inline]
    fn slot(&mut self, page: u64) -> &mut Option<PageInfo> {
        let idx = page as usize;
        if idx >= self.map.len() {
            self.map.resize(idx + 1, None);
        }
        &mut self.map[idx]
    }

    /// Resolve `page` for an access by a core on `local_node`.
    ///
    /// Returns `(info, first_touch)`.  On first touch the page is placed on
    /// `local_node` if it has room, otherwise on the nearest node (by hop
    /// distance, ties to lower id — deterministic) with free capacity; if
    /// everything is full, placement falls back to `local_node` regardless
    /// (real kernels would swap; the simulator just over-commits).
    pub fn resolve(
        &mut self,
        page: u64,
        local_node: usize,
        topo: &Topology,
    ) -> (PageInfo, bool) {
        if let Some(info) = *self.slot(page) {
            return (info, false);
        }
        let node = self.place(local_node, topo);
        let info = PageInfo { node: node as u32, version: 0 };
        *self.slot(page) = Some(info);
        self.resident += 1;
        self.node_used[node] += 1;
        (info, true)
    }

    fn place(&self, local_node: usize, topo: &Topology) -> usize {
        if self.node_used[local_node] < self.capacity_per_node {
            return local_node;
        }
        for node in topo.nodes_by_distance(local_node) {
            if self.node_used[node] < self.capacity_per_node {
                return node;
            }
        }
        local_node // over-commit
    }

    /// Record a write: bump the page version (invalidates remote copies).
    /// Page must be resident.
    pub fn bump_version(&mut self, page: u64) -> u32 {
        let info = self.slot(page).as_mut().expect("write to unmapped page");
        info.version += 1;
        info.version
    }

    pub fn lookup(&self, page: u64) -> Option<PageInfo> {
        self.map.get(page as usize).copied().flatten()
    }

    /// Pages resident per node (placement audits / EXPERIMENTS tables).
    pub fn node_used(&self) -> &[u64] {
        &self.node_used
    }

    pub fn resident_pages(&self) -> usize {
        self.resident
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::x4600()
    }

    #[test]
    fn first_touch_lands_local() {
        let t = topo();
        let mut pt = PageTable::new(8, 100);
        let (info, fresh) = pt.resolve(42, 3, &t);
        assert!(fresh);
        assert_eq!(info.node, 3);
        let (again, fresh2) = pt.resolve(42, 5, &t);
        assert!(!fresh2, "second touch must not re-place");
        assert_eq!(again.node, 3, "placement is sticky");
    }

    #[test]
    fn spill_goes_to_nearest_node() {
        let t = topo();
        let mut pt = PageTable::new(8, 2);
        pt.resolve(1, 0, &t);
        pt.resolve(2, 0, &t);
        // node 0 now full; next first-touch from node 0 must go to a
        // neighbour at 1 hop (node 1 or 2), deterministically the lower id.
        let (info, _) = pt.resolve(3, 0, &t);
        assert_eq!(t.node_hops(0, info.node as usize), 1);
        assert_eq!(info.node, 1);
    }

    #[test]
    fn overcommit_when_all_full() {
        let t = Topology::dual(2);
        let mut pt = PageTable::new(2, 1);
        pt.resolve(1, 0, &t);
        pt.resolve(2, 1, &t);
        let (info, _) = pt.resolve(3, 0, &t);
        assert_eq!(info.node, 0, "over-commit falls back to local");
    }

    #[test]
    fn version_bumps_on_write() {
        let t = topo();
        let mut pt = PageTable::new(8, 10);
        pt.resolve(9, 0, &t);
        assert_eq!(pt.bump_version(9), 1);
        assert_eq!(pt.bump_version(9), 2);
        assert_eq!(pt.lookup(9).unwrap().version, 2);
    }

    #[test]
    fn node_usage_tracked() {
        let t = topo();
        let mut pt = PageTable::new(8, 10);
        for p in 0..5 {
            pt.resolve(p, 2, &t);
        }
        assert_eq!(pt.node_used()[2], 5);
        assert_eq!(pt.resident_pages(), 5);
    }
}
