//! Page table with pluggable NUMA placement.
//!
//! Models the policies the paper's allocation side turns on: physical
//! allocation is deferred until the first read/write; where the page then
//! lands is the [`PagePolicy`]'s decision.  The default, first-touch,
//! places it on the local node of the touching CPU (`set_mempolicy(2)`
//! default behaviour) — which is exactly why the paper's master-thread
//! placement matters: the master first-touches the program's data during
//! initialization, so its node choice decides everyone's access distances.
//! `interleave`/`bind` reproduce the `numactl` overrides, and `next-touch`
//! adds the migrate-on-remote-re-touch behaviour of Wittmann & Hager
//! (arXiv:1101.0093).
//!
//! Every policy shares one spill rule: when the preferred node is full,
//! the page falls back to the *closest* node (by hop distance, ties to
//! lower id — deterministic) with free capacity; when everything is full,
//! placement over-commits on the preferred node (real kernels would swap).

use crate::simnuma::policy::PagePolicy;
use crate::topology::Topology;

/// Page size in bytes (x86-64 default).
pub const PAGE_BYTES: u64 = 4096;

/// Placement + coherence info for one resident page.
#[derive(Clone, Copy, Debug)]
pub struct PageInfo {
    /// Owning NUMA node (fixed at first touch, unless `next-touch`
    /// migrates it).
    pub node: u32,
    /// Bumped on every write; caches holding an older version are stale.
    pub version: u32,
    /// Migrations performed so far (`next-touch` budget accounting).
    pub moves: u32,
}

/// What one [`PageTable::resolve`] call did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TouchOutcome {
    /// The page was placed by this touch.
    pub fresh: bool,
    /// `next-touch` migrated the page here; carries the previous owner.
    pub migrated_from: Option<u32>,
}

impl TouchOutcome {
    const NONE: TouchOutcome = TouchOutcome { fresh: false, migrated_from: None };
}

/// Policy-driven page table over the simulated physical memory.
///
/// Page ids come from [`super::MemSim`]'s bump allocator, so they are
/// dense — a flat `Vec` beats a hash map on the access hot path
/// (EXPERIMENTS.md §Perf it3).
#[derive(Debug)]
pub struct PageTable {
    map: Vec<Option<PageInfo>>,
    policy: PagePolicy,
    resident: usize,
    migrated: u64,
    node_used: Vec<u64>,
    capacity_per_node: u64,
}

impl PageTable {
    /// First-touch table (the pre-policy default).
    pub fn new(nodes: usize, capacity_per_node: u64) -> Self {
        Self::with_policy(nodes, capacity_per_node, PagePolicy::FirstTouch)
    }

    pub fn with_policy(nodes: usize, capacity_per_node: u64, policy: PagePolicy) -> Self {
        Self {
            map: Vec::new(),
            policy,
            resident: 0,
            migrated: 0,
            node_used: vec![0; nodes],
            capacity_per_node,
        }
    }

    #[inline]
    fn slot(&mut self, page: u64) -> &mut Option<PageInfo> {
        let idx = page as usize;
        if idx >= self.map.len() {
            self.map.resize(idx + 1, None);
        }
        &mut self.map[idx]
    }

    /// Resolve `page` for an access by a core on `local_node`.
    ///
    /// Returns `(info, outcome)`.  On first touch the page is placed on
    /// the policy's preferred node (spilling to the nearest node with
    /// free capacity); under `next-touch`, a later access from a node
    /// other than the owner migrates the page toward the toucher while
    /// the page's move budget lasts.
    pub fn resolve(
        &mut self,
        page: u64,
        local_node: usize,
        topo: &Topology,
    ) -> (PageInfo, TouchOutcome) {
        if let Some(info) = *self.slot(page) {
            if let PagePolicy::NextTouch { max_moves } = self.policy {
                let from = info.node as usize;
                if from != local_node && info.moves < max_moves {
                    let target = self.place_from(local_node, topo);
                    if target != from {
                        self.node_used[from] -= 1;
                        self.node_used[target] += 1;
                        self.migrated += 1;
                        let moved = PageInfo {
                            node: target as u32,
                            version: info.version,
                            moves: info.moves + 1,
                        };
                        *self.slot(page) = Some(moved);
                        return (
                            moved,
                            TouchOutcome { fresh: false, migrated_from: Some(info.node) },
                        );
                    }
                }
            }
            return (info, TouchOutcome::NONE);
        }
        let preferred = match self.policy {
            PagePolicy::FirstTouch | PagePolicy::NextTouch { .. } => local_node,
            PagePolicy::Interleave => (page % self.node_used.len() as u64) as usize,
            PagePolicy::Bind(node) => node,
        };
        let node = self.place_from(preferred, topo);
        let info = PageInfo { node: node as u32, version: 0, moves: 0 };
        *self.slot(page) = Some(info);
        self.resident += 1;
        self.node_used[node] += 1;
        (info, TouchOutcome { fresh: true, migrated_from: None })
    }

    /// `preferred` if it has room, else the nearest node (by hop
    /// distance, ties to lower id) with free capacity, else `preferred`
    /// regardless (over-commit).
    fn place_from(&self, preferred: usize, topo: &Topology) -> usize {
        if self.node_used[preferred] < self.capacity_per_node {
            return preferred;
        }
        for node in topo.nodes_by_distance(preferred) {
            if self.node_used[node] < self.capacity_per_node {
                return node;
            }
        }
        preferred // over-commit
    }

    /// Record a write: bump the page version (invalidates remote copies).
    /// Page must be resident.
    pub fn bump_version(&mut self, page: u64) -> u32 {
        let info = self.slot(page).as_mut().expect("write to unmapped page");
        info.version += 1;
        info.version
    }

    pub fn lookup(&self, page: u64) -> Option<PageInfo> {
        self.map.get(page as usize).copied().flatten()
    }

    /// Pages resident per node (placement audits / EXPERIMENTS tables).
    pub fn node_used(&self) -> &[u64] {
        &self.node_used
    }

    pub fn resident_pages(&self) -> usize {
        self.resident
    }

    /// Total `next-touch` migrations performed.
    pub fn migrated_pages(&self) -> u64 {
        self.migrated
    }

    pub fn policy(&self) -> PagePolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::x4600()
    }

    #[test]
    fn first_touch_lands_local() {
        let t = topo();
        let mut pt = PageTable::new(8, 100);
        let (info, out) = pt.resolve(42, 3, &t);
        assert!(out.fresh);
        assert_eq!(info.node, 3);
        let (again, out2) = pt.resolve(42, 5, &t);
        assert!(!out2.fresh, "second touch must not re-place");
        assert_eq!(again.node, 3, "placement is sticky");
        assert_eq!(out2.migrated_from, None, "first-touch never migrates");
    }

    #[test]
    fn spill_goes_to_nearest_node() {
        let t = topo();
        let mut pt = PageTable::new(8, 2);
        pt.resolve(1, 0, &t);
        pt.resolve(2, 0, &t);
        // node 0 now full; next first-touch from node 0 must go to a
        // neighbour at 1 hop (node 1 or 2), deterministically the lower id.
        let (info, _) = pt.resolve(3, 0, &t);
        assert_eq!(t.node_hops(0, info.node as usize), 1);
        assert_eq!(info.node, 1);
    }

    #[test]
    fn overcommit_when_all_full() {
        let t = Topology::dual(2);
        let mut pt = PageTable::new(2, 1);
        pt.resolve(1, 0, &t);
        pt.resolve(2, 1, &t);
        let (info, _) = pt.resolve(3, 0, &t);
        assert_eq!(info.node, 0, "over-commit falls back to local");
    }

    #[test]
    fn version_bumps_on_write() {
        let t = topo();
        let mut pt = PageTable::new(8, 10);
        pt.resolve(9, 0, &t);
        assert_eq!(pt.bump_version(9), 1);
        assert_eq!(pt.bump_version(9), 2);
        assert_eq!(pt.lookup(9).unwrap().version, 2);
    }

    #[test]
    fn node_usage_tracked() {
        let t = topo();
        let mut pt = PageTable::new(8, 10);
        for p in 0..5 {
            pt.resolve(p, 2, &t);
        }
        assert_eq!(pt.node_used()[2], 5);
        assert_eq!(pt.resident_pages(), 5);
    }

    #[test]
    fn interleave_round_robins_by_page_id() {
        let t = topo();
        let mut pt = PageTable::with_policy(8, 100, PagePolicy::Interleave);
        for p in 0..32u64 {
            let (info, out) = pt.resolve(p, 0, &t);
            assert!(out.fresh);
            assert_eq!(info.node as u64, p % 8, "page {p} on node page%8");
        }
        // every node holds exactly its share, regardless of the toucher
        assert!(pt.node_used().iter().all(|&u| u == 4), "{:?}", pt.node_used());
    }

    #[test]
    fn interleave_spills_near_the_preferred_node() {
        let t = topo();
        let mut pt = PageTable::with_policy(8, 1, PagePolicy::Interleave);
        pt.resolve(0, 3, &t); // node 0 now full
        let (info, _) = pt.resolve(8, 3, &t); // prefers node 0 again
        assert_ne!(info.node, 0);
        assert_eq!(t.node_hops(0, info.node as usize), 1, "spill stays near node 0");
    }

    #[test]
    fn bind_pins_every_page() {
        let t = topo();
        let mut pt = PageTable::with_policy(8, 100, PagePolicy::Bind(5));
        for p in 0..16u64 {
            let (info, _) = pt.resolve(p, (p % 8) as usize, &t);
            assert_eq!(info.node, 5);
        }
        assert_eq!(pt.node_used()[5], 16);
    }

    #[test]
    fn next_touch_migrates_on_remote_retouch() {
        let t = topo();
        let mut pt = PageTable::with_policy(8, 100, PagePolicy::NextTouch { max_moves: 1 });
        let (info, out) = pt.resolve(7, 0, &t);
        assert!(out.fresh);
        assert_eq!(info.node, 0);
        // local re-touch does not move the page
        let (_, out) = pt.resolve(7, 0, &t);
        assert_eq!(out.migrated_from, None);
        // remote re-touch migrates to the toucher
        let (info, out) = pt.resolve(7, 4, &t);
        assert_eq!(out.migrated_from, Some(0));
        assert_eq!(info.node, 4);
        assert_eq!(info.moves, 1);
        assert_eq!(pt.node_used()[0], 0);
        assert_eq!(pt.node_used()[4], 1);
        assert_eq!(pt.migrated_pages(), 1);
        // budget exhausted: a further remote touch stays put
        let (info, out) = pt.resolve(7, 2, &t);
        assert_eq!(out.migrated_from, None);
        assert_eq!(info.node, 4);
        assert_eq!(pt.migrated_pages(), 1);
    }

    #[test]
    fn next_touch_budget_of_two_allows_two_moves() {
        let t = topo();
        let mut pt = PageTable::with_policy(8, 100, PagePolicy::NextTouch { max_moves: 2 });
        pt.resolve(1, 0, &t);
        pt.resolve(1, 3, &t);
        pt.resolve(1, 6, &t);
        assert_eq!(pt.lookup(1).unwrap().node, 6);
        assert_eq!(pt.migrated_pages(), 2);
        pt.resolve(1, 0, &t);
        assert_eq!(pt.lookup(1).unwrap().node, 6, "budget spent");
    }

    #[test]
    fn next_touch_zero_budget_is_first_touch() {
        let t = topo();
        let mut pt = PageTable::with_policy(8, 100, PagePolicy::NextTouch { max_moves: 0 });
        pt.resolve(1, 2, &t);
        pt.resolve(1, 5, &t);
        assert_eq!(pt.lookup(1).unwrap().node, 2);
        assert_eq!(pt.migrated_pages(), 0);
    }

    #[test]
    fn next_touch_migration_preserves_version() {
        let t = topo();
        let mut pt = PageTable::with_policy(8, 100, PagePolicy::NextTouch { max_moves: 1 });
        pt.resolve(3, 0, &t);
        pt.bump_version(3);
        pt.bump_version(3);
        let (info, out) = pt.resolve(3, 7, &t);
        assert_eq!(out.migrated_from, Some(0));
        assert_eq!(info.version, 2, "coherence state survives the move");
    }
}
