//! Per-core cache model.
//!
//! Two levels (the X4600's Opteron 8xxx has private 64 KiB L1D + 1 MiB L2,
//! no shared L3), tracked at *page* granularity with direct-mapped tag
//! arrays — O(1) per access, deterministic, and coherent via page versions:
//! a cached `(page, version)` older than the page table's current version
//! is stale, which models write-invalidate without a directory.
//!
//! Page-granular tags overestimate spatial locality slightly; the cost
//! model compensates by charging per *line* for hits and misses alike
//! (DESIGN.md §2 — shape fidelity, not cycle accuracy).

/// One direct-mapped tag array.
#[derive(Clone, Debug)]
struct Level {
    tags: Vec<(u64, u32)>, // (page, version); u64::MAX = empty
}

impl Level {
    fn new(slots: usize) -> Self {
        Self { tags: vec![(u64::MAX, 0); slots.max(1)] }
    }

    #[inline]
    fn slot(&self, page: u64) -> usize {
        (page % self.tags.len() as u64) as usize
    }

    #[inline]
    fn hit(&self, page: u64, version: u32) -> bool {
        self.tags[self.slot(page)] == (page, version)
    }

    #[inline]
    fn fill(&mut self, page: u64, version: u32) {
        let s = self.slot(page);
        self.tags[s] = (page, version);
    }
}

/// Which level served an access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheHit {
    L1,
    L2,
    Miss,
}

/// A core's private cache hierarchy.
#[derive(Clone, Debug)]
pub struct CoreCache {
    l1: Level,
    l2: Level,
}

impl CoreCache {
    /// `l1_pages` / `l2_pages`: capacity in 4 KiB pages (16 / 256 for the
    /// X4600's 64 KiB / 1 MiB).
    pub fn new(l1_pages: usize, l2_pages: usize) -> Self {
        Self { l1: Level::new(l1_pages), l2: Level::new(l2_pages) }
    }

    /// Probe for `(page, version)`; fills on miss/promote (inclusive).
    pub fn access(&mut self, page: u64, version: u32) -> CacheHit {
        if self.l1.hit(page, version) {
            return CacheHit::L1;
        }
        if self.l2.hit(page, version) {
            self.l1.fill(page, version); // promote
            return CacheHit::L2;
        }
        self.l2.fill(page, version);
        self.l1.fill(page, version);
        CacheHit::Miss
    }

    /// After this core writes the page, it holds the fresh version.
    pub fn note_write(&mut self, page: u64, new_version: u32) {
        self.l1.fill(page, new_version);
        self.l2.fill(page, new_version);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit() {
        let mut c = CoreCache::new(16, 256);
        assert_eq!(c.access(5, 0), CacheHit::Miss);
        assert_eq!(c.access(5, 0), CacheHit::L1);
    }

    #[test]
    fn stale_version_misses() {
        let mut c = CoreCache::new(16, 256);
        c.access(5, 0);
        assert_eq!(c.access(5, 1), CacheHit::Miss, "old version is stale");
        assert_eq!(c.access(5, 1), CacheHit::L1);
    }

    #[test]
    fn l2_promotion() {
        let mut c = CoreCache::new(2, 256);
        c.access(0, 0);
        // pages 2 and 0 collide in a 2-slot L1 (0 % 2 == 2 % 2)
        c.access(2, 0);
        // 0 evicted from L1 but still in the 256-slot L2
        assert_eq!(c.access(0, 0), CacheHit::L2);
        assert_eq!(c.access(0, 0), CacheHit::L1, "promoted back");
    }

    #[test]
    fn conflict_eviction() {
        let mut c = CoreCache::new(1, 1);
        c.access(0, 0);
        c.access(1, 0); // evicts 0 everywhere (1-slot levels)
        assert_eq!(c.access(0, 0), CacheHit::Miss);
    }

    #[test]
    fn write_installs_fresh_version() {
        let mut c = CoreCache::new(16, 256);
        c.access(7, 0);
        c.note_write(7, 3);
        assert_eq!(c.access(7, 3), CacheHit::L1);
    }
}
