//! [`MemSim`]: the memory-system façade the event engine charges against.
//!
//! One instance per run.  The engine calls [`MemSim::access`] for every
//! task `Touch` action; the returned simulated duration folds together
//! cache hits, first-touch placement, NUMA latency and memory-controller
//! queuing (bandwidth contention between concurrently streaming cores).

use crate::simnuma::cache::{CacheHit, CoreCache};
use crate::simnuma::latency::CostModel;
use crate::simnuma::page::{PageTable, PAGE_BYTES};
use crate::simnuma::policy::PagePolicy;
use crate::topology::Topology;
use crate::util::Time;

/// A range of simulated virtual memory (byte addresses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub addr: u64,
    pub bytes: u64,
}

impl Region {
    pub const EMPTY: Region = Region { addr: 0, bytes: 0 };

    /// Sub-range `[offset, offset+len)` of this region.  Bounds-checked in
    /// every profile with overflow-safe arithmetic: `offset + len` could
    /// wrap in release and silently build an out-of-range region that
    /// aliases someone else's allocation.
    pub fn slice(&self, offset: u64, len: u64) -> Region {
        let end = offset.checked_add(len).expect("slice bounds overflow u64");
        assert!(
            end <= self.bytes,
            "slice [{offset}, {end}) out of bounds for a {}-byte region",
            self.bytes
        );
        Region { addr: self.addr + offset, bytes: len }
    }
}

/// Aggregate memory-system statistics for one run.
#[derive(Clone, Debug, Default)]
pub struct MemStats {
    pub l1_hit_lines: u64,
    pub l2_hit_lines: u64,
    pub miss_lines_by_hop: [u64; 9],
    pub first_touch_pages: u64,
    /// Pages moved by the `next-touch` policy (0 under other policies).
    pub migrated_pages: u64,
    /// Simulated time spent copying pages across nodes (`next-touch`).
    pub migration_stall: Time,
    pub contention_stall: Time,
    pub bytes_touched: u64,
}

impl MemStats {
    pub fn miss_lines(&self) -> u64 {
        self.miss_lines_by_hop.iter().sum()
    }

    pub fn remote_lines(&self) -> u64 {
        self.miss_lines_by_hop[1..].iter().sum()
    }

    /// Fraction of missed lines served remotely (paper's key diagnostic).
    pub fn remote_ratio(&self) -> f64 {
        let m = self.miss_lines();
        if m == 0 {
            0.0
        } else {
            self.remote_lines() as f64 / m as f64
        }
    }

    /// Mean hops per missed line.
    pub fn mean_miss_hops(&self) -> f64 {
        let m = self.miss_lines();
        if m == 0 {
            return 0.0;
        }
        let weighted: u64 = self
            .miss_lines_by_hop
            .iter()
            .enumerate()
            .map(|(h, &c)| h as u64 * c)
            .sum();
        weighted as f64 / m as f64
    }

    /// Lossless JSON image (every field; counters above 2^53 survive as
    /// decimal strings) — the result store's record format.
    pub fn to_json(&self) -> crate::serde::Json {
        use crate::serde::Json;
        Json::obj([
            ("l1_hit_lines", Json::from_u64_lossless(self.l1_hit_lines)),
            ("l2_hit_lines", Json::from_u64_lossless(self.l2_hit_lines)),
            (
                "miss_lines_by_hop",
                Json::Arr(self.miss_lines_by_hop.iter().map(|&c| Json::from_u64_lossless(c)).collect()),
            ),
            ("first_touch_pages", Json::from_u64_lossless(self.first_touch_pages)),
            ("migrated_pages", Json::from_u64_lossless(self.migrated_pages)),
            ("migration_stall", Json::from_u64_lossless(self.migration_stall)),
            ("contention_stall", Json::from_u64_lossless(self.contention_stall)),
            ("bytes_touched", Json::from_u64_lossless(self.bytes_touched)),
        ])
    }

    /// Inverse of [`MemStats::to_json`]; strict — a missing or malformed
    /// field is an error (the store treats it as record corruption).
    pub fn from_json(j: &crate::serde::Json) -> anyhow::Result<Self> {
        use crate::serde::Json;
        use anyhow::Context;
        let u = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64_lossless)
                .with_context(|| format!("MemStats field '{k}'"))
        };
        let hops = j
            .get("miss_lines_by_hop")
            .and_then(Json::as_arr)
            .context("MemStats field 'miss_lines_by_hop'")?;
        if hops.len() != 9 {
            anyhow::bail!("MemStats 'miss_lines_by_hop' has {} entries, want 9", hops.len());
        }
        let mut miss_lines_by_hop = [0u64; 9];
        for (slot, v) in miss_lines_by_hop.iter_mut().zip(hops) {
            *slot = v.as_u64_lossless().context("MemStats 'miss_lines_by_hop' entry")?;
        }
        Ok(Self {
            l1_hit_lines: u("l1_hit_lines")?,
            l2_hit_lines: u("l2_hit_lines")?,
            miss_lines_by_hop,
            first_touch_pages: u("first_touch_pages")?,
            migrated_pages: u("migrated_pages")?,
            migration_stall: u("migration_stall")?,
            contention_stall: u("contention_stall")?,
            bytes_touched: u("bytes_touched")?,
        })
    }
}

/// Epoch width for the per-node bandwidth-utilization estimate.
const EPOCH: Time = 50 * crate::util::US;
/// Queueing-delay cap (in multiples of the access's own service time).
const MAX_QUEUE_FACTOR: u64 = 12;

/// Per-node memory-controller load within the current virtual-time epoch.
///
/// A strict busy-horizon would be order-sensitive: the engine executes one
/// scheduling quantum per event, so workers' clocks skew by up to a task
/// length and a horizon set "in the future" would charge phantom stalls to
/// accesses arriving "from the past".  Instead each node tracks the service
/// demand landing in the current [`EPOCH`]; queueing delay follows an
/// M/M/1-flavoured `service * rho / (1 - rho)` with utilization `rho`,
/// which is insensitive to arrival order within the epoch.
#[derive(Clone, Debug, Default)]
struct NodeLoad {
    epoch: u64,
    used: Time,
}

impl NodeLoad {
    /// Record `service` at time `now`; returns the queueing stall.
    fn charge(&mut self, now: Time, service: Time) -> Time {
        let epoch = now / EPOCH;
        if epoch != self.epoch {
            self.epoch = epoch;
            self.used = 0;
        }
        self.used += service;
        let rho = (self.used as f64 / EPOCH as f64).min(0.95);
        let stall = (service as f64 * rho / (1.0 - rho)) as Time;
        stall.min(service * MAX_QUEUE_FACTOR)
    }
}

/// Per-core last-touch memo (one entry per core, no eviction): which
/// page the core touched last, the owner recorded then, and the global
/// invalidation epoch at that point.  While the epoch is unchanged, no
/// write (`bump_version`) and no `next-touch` migration has happened
/// anywhere in the system, so a repeated *read* of the same page by the
/// same core is provably an L1 hit at an unchanged owner: the core's
/// previous access filled its L1 with the current version (every
/// [`CacheHit`] outcome fills L1), nothing evicted it (per-core caches
/// mutate only on that core's accesses, and this was the core's last
/// page), and a pure L1 hit mutates no simulator state — so
/// [`MemSim::access`] can skip the page-table resolve and the cache
/// probe entirely, byte-identically.
#[derive(Clone, Copy)]
struct TouchMemo {
    page: u64,
    node: u8,
    epoch: u64,
}

impl TouchMemo {
    /// No page touched yet (`u64::MAX` is unreachable for a real page:
    /// page ids come from the bump allocator).
    const NONE: TouchMemo = TouchMemo { page: u64::MAX, node: 0, epoch: 0 };
}

/// The simulated memory system: page table + caches + node controllers.
pub struct MemSim {
    topo: Topology,
    cost: CostModel,
    pages: PageTable,
    caches: Vec<CoreCache>,
    /// Memory-controller load per node (bandwidth contention).
    node_load: Vec<NodeLoad>,
    stats: MemStats,
    brk: u64,
    /// Last-`(page, owner, epoch)` per core — the repeated-touch fast
    /// path (see [`TouchMemo`]).
    touch_memo: Vec<TouchMemo>,
    /// Bumped on every write and every `next-touch` migration; a memo
    /// from an older epoch proves nothing and falls back to the full
    /// resolve + cache probe.
    inval_epoch: u64,
}

impl MemSim {
    /// First-touch memory system (the pre-policy default).
    pub fn new(topo: Topology, cost: CostModel) -> Self {
        Self::with_policy(topo, cost, PagePolicy::FirstTouch)
    }

    /// Memory system placing pages under `policy`.
    pub fn with_policy(topo: Topology, cost: CostModel, policy: PagePolicy) -> Self {
        let nodes = topo.num_nodes();
        let cores = topo.num_cores();
        let caches = (0..cores)
            .map(|_| CoreCache::new(cost.l1_pages, cost.l2_pages))
            .collect();
        Self {
            pages: PageTable::with_policy(nodes, topo.node_capacity_pages(), policy),
            caches,
            node_load: vec![NodeLoad::default(); nodes],
            stats: MemStats::default(),
            brk: PAGE_BYTES, // keep address 0 unused
            touch_memo: vec![TouchMemo::NONE; cores],
            inval_epoch: 0,
            topo,
            cost,
        }
    }

    /// Reserve `bytes` of page-aligned simulated address space.  No
    /// placement happens here — pages materialize on first touch.
    pub fn alloc(&mut self, bytes: u64) -> Region {
        let addr = self.brk;
        let span = bytes.div_ceil(PAGE_BYTES) * PAGE_BYTES;
        self.brk += span.max(PAGE_BYTES);
        Region { addr, bytes }
    }

    /// Charge an access by `core` over `region` at simulated time `now`.
    pub fn access(&mut self, core: usize, region: Region, write: bool, now: Time) -> Time {
        if region.bytes == 0 {
            return 0;
        }
        let local_node = self.topo.node_of(core);
        // Under next-touch a *read* of a remote page still migrates it
        // (with charges), so only locally-owned pages may fast-path.
        let next_touch = matches!(self.pages.policy(), PagePolicy::NextTouch { .. });
        let mut cost: Time = 0;
        self.stats.bytes_touched += region.bytes;
        // Manual page walk to avoid borrowing `self` inside the iterator.
        let mut addr = region.addr;
        let end = region.addr + region.bytes;
        while addr < end {
            let page = addr / PAGE_BYTES;
            let page_end = (page + 1) * PAGE_BYTES;
            let take = page_end.min(end) - addr;
            addr += take;
            let lines = take.div_ceil(self.cost.line_bytes);

            // Repeated-touch fast path (see [`TouchMemo`]): a re-read of
            // the core's last page with no intervening write/migration
            // anywhere is a guaranteed L1 hit — charge it and move on
            // without the page-table resolve or the cache probe.
            let memo = self.touch_memo[core];
            if !write
                && memo.page == page
                && memo.epoch == self.inval_epoch
                && (!next_touch || memo.node as usize == local_node)
            {
                cost += lines * self.cost.l1_hit;
                self.stats.l1_hit_lines += lines;
                continue;
            }

            let (mut info, outcome) = self.pages.resolve(page, local_node, &self.topo);
            if outcome.fresh {
                self.stats.first_touch_pages += 1;
            }
            if let Some(from) = outcome.migrated_from {
                // next-touch migration: charge a full page copy from the
                // old owner to the new one (kernel move_pages()-style).
                let hops = self.topo.node_hops(from as usize, info.node as usize) as Time;
                let lines = PAGE_BYTES.div_ceil(self.cost.line_bytes);
                let copy = self.cost.dram_base
                    + hops * self.cost.hop_penalty
                    + lines * self.cost.service_per_line(hops as u8);
                cost += copy;
                self.stats.migration_stall += copy;
                // mirror the page table's count (single source of truth)
                self.stats.migrated_pages = self.pages.migrated_pages();
                // the page changed owner: every core's memo is stale
                self.inval_epoch += 1;
            }
            let hit = self.caches[core].access(page, info.version);
            match hit {
                CacheHit::L1 => {
                    cost += lines * self.cost.l1_hit;
                    self.stats.l1_hit_lines += lines;
                }
                CacheHit::L2 => {
                    cost += lines * self.cost.l2_hit;
                    self.stats.l2_hit_lines += lines;
                }
                CacheHit::Miss => {
                    let node = info.node as usize;
                    let hops = self.topo.node_hops(local_node, node);
                    let service = lines * self.cost.service_per_line(hops);
                    let arrive = now + cost;
                    let stall = self.node_load[node].charge(arrive, service);
                    cost += stall
                        + self.cost.dram_base
                        + hops as Time * self.cost.hop_penalty
                        + service;
                    self.stats.contention_stall += stall;
                    self.stats.miss_lines_by_hop[(hops as usize).min(8)] += lines;
                }
            }
            if write {
                info.version = self.pages.bump_version(page);
                self.caches[core].note_write(page, info.version);
                // remote copies are stale: every other core's memo dies;
                // ours is re-armed below at the *new* epoch (note_write
                // just filled our L1 with the new version)
                self.inval_epoch += 1;
            }
            self.touch_memo[core] =
                TouchMemo { page, node: info.node as u8, epoch: self.inval_epoch };
        }
        cost
    }

    /// Master-style initialization touch (write over the whole region) —
    /// places pages per first-touch.  Returns the simulated cost.
    pub fn first_touch(&mut self, core: usize, region: Region, now: Time) -> Time {
        self.access(core, region, true, now)
    }

    pub fn stats(&self) -> &MemStats {
        &self.stats
    }

    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// Pages resident per node (placement audit).
    pub fn node_used(&self) -> &[u64] {
        self.pages.node_used()
    }

    /// Owning node of an address, if resident.
    pub fn node_of_addr(&self, addr: u64) -> Option<usize> {
        self.pages.lookup(addr / PAGE_BYTES).map(|i| i.node as usize)
    }

    /// The page policy this simulator places under.
    pub fn page_policy(&self) -> PagePolicy {
        self.pages.policy()
    }

    /// Maximum pages sampled by [`MemSim::home_node`]: placement is a
    /// per-spawn decision, so the query must stay O(1)-ish even for
    /// multi-megabyte regions.  A strided sample of 64 pages decides the
    /// majority owner deterministically.
    const HOME_SAMPLE_PAGES: u64 = 64;

    /// Majority owner of `region`'s *resident* pages — the "home node"
    /// placement decisions target.  Ties break to the lower node id
    /// (deterministic); `None` when the region is empty or no sampled
    /// page is resident yet (nothing to be near).
    pub fn home_node(&self, region: Region) -> Option<usize> {
        if region.bytes == 0 {
            return None;
        }
        let first = region.addr / PAGE_BYTES;
        let last = (region.addr + region.bytes - 1) / PAGE_BYTES;
        let pages = last - first + 1;
        let stride = pages.div_ceil(Self::HOME_SAMPLE_PAGES).max(1);
        // per-spawn hot path: tally on the stack (every preset topology
        // has ≤ 16 nodes; the heap fallback keeps odd topologies correct)
        let nodes = self.topo.num_nodes();
        let mut small = [0u32; 32];
        let mut big = Vec::new();
        let counts: &mut [u32] = if nodes <= small.len() {
            &mut small[..nodes]
        } else {
            big.resize(nodes, 0u32);
            &mut big
        };
        let mut any = false;
        let mut page = first;
        while page <= last {
            if let Some(info) = self.pages.lookup(page) {
                counts[info.node as usize] += 1;
                any = true;
            }
            page += stride;
        }
        if !any {
            return None;
        }
        let mut best = 0;
        for (node, &c) in counts.iter().enumerate() {
            if c > counts[best] {
                best = node;
            }
        }
        Some(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> MemSim {
        MemSim::new(Topology::x4600(), CostModel::default())
    }

    #[test]
    fn alloc_is_page_aligned_and_disjoint() {
        let mut m = sim();
        let a = m.alloc(100);
        let b = m.alloc(5000);
        assert_eq!(a.addr % PAGE_BYTES, 0);
        assert_eq!(b.addr % PAGE_BYTES, 0);
        assert!(a.addr + a.bytes <= b.addr);
    }

    #[test]
    fn local_access_cheaper_than_remote() {
        // core 0 (node 0) first-touches; then core 0 re-miss vs core 15
        // (node 7, 3 hops) miss on cold caches.
        let mut m = sim();
        let r = m.alloc(PAGE_BYTES);
        m.first_touch(0, r, 0);
        // evict from core 0's caches by touching um, simpler: use two fresh cores
        let mut m2 = sim();
        let r2 = m2.alloc(PAGE_BYTES);
        m2.first_touch(0, r2, 0);
        let local = m2.access(2, r2, false, 0); // core 2 = node 1, 1 hop
        let mut m3 = sim();
        let r3 = m3.alloc(PAGE_BYTES);
        m3.first_touch(0, r3, 0);
        let remote = m3.access(15, r3, false, 0); // node 7 = 3 hops
        assert!(remote > local, "3-hop {remote} must exceed 1-hop {local}");
    }

    #[test]
    fn cache_hit_cheap_on_reuse() {
        let mut m = sim();
        let r = m.alloc(1024);
        let first = m.access(0, r, false, 0);
        let second = m.access(0, r, false, 0);
        assert!(second * 10 < first, "cached {second} vs cold {first}");
    }

    #[test]
    fn write_invalidates_other_core() {
        let mut m = sim();
        let r = m.alloc(1024);
        m.access(0, r, false, 0);
        m.access(1, r, false, 0);
        let warm = m.access(1, r, false, 0);
        m.access(0, r, true, 0); // core 0 writes -> core 1 stale
        let after = m.access(1, r, false, 0);
        assert!(after > warm, "stale copy must re-fetch: {after} vs {warm}");
    }

    #[test]
    fn contention_stalls_accumulate() {
        let mut m = sim();
        let r = m.alloc(64 * PAGE_BYTES);
        m.first_touch(0, r, 0);
        // two far cores stream the same node at the same instant
        m.access(14, r, false, 1_000_000);
        m.access(15, r, false, 1_000_000);
        assert!(m.stats().contention_stall > 0);
    }

    #[test]
    fn first_touch_page_count() {
        let mut m = sim();
        let r = m.alloc(10 * PAGE_BYTES);
        m.first_touch(0, r, 0);
        assert_eq!(m.stats().first_touch_pages, 10);
        assert_eq!(m.node_used()[0], 10);
    }

    #[test]
    fn hop_histogram_records_distance() {
        let mut m = sim();
        let r = m.alloc(PAGE_BYTES);
        m.first_touch(0, r, 0); // node 0
        m.access(15, r, false, 0); // node 7: 3 hops on x4600
        assert!(m.stats().miss_lines_by_hop[3] > 0);
        assert!(m.stats().remote_ratio() > 0.0);
    }

    #[test]
    fn empty_region_free() {
        let mut m = sim();
        assert_eq!(m.access(0, Region::EMPTY, true, 0), 0);
    }

    #[test]
    fn slice_bounds_checked_in_all_profiles() {
        let r = Region { addr: 4096, bytes: 100 };
        let s = r.slice(10, 20);
        assert_eq!(s.addr, 4106);
        assert_eq!(s.bytes, 20);
        assert!(std::panic::catch_unwind(|| r.slice(90, 20)).is_err(), "past the end");
        // offset + len wraps u64: must panic, not silently alias addr space
        assert!(std::panic::catch_unwind(|| r.slice(u64::MAX, 2)).is_err(), "overflow");
    }

    #[test]
    fn interleave_spreads_a_master_touched_region() {
        let mut m = MemSim::with_policy(
            Topology::x4600(),
            CostModel::default(),
            PagePolicy::Interleave,
        );
        let r = m.alloc(64 * PAGE_BYTES);
        m.first_touch(0, r, 0); // master on node 0 touches everything
        let used = m.node_used();
        assert!(used.iter().all(|&u| u == 8), "even spread, got {used:?}");
    }

    #[test]
    fn bind_keeps_residency_on_the_named_node() {
        let mut m =
            MemSim::with_policy(Topology::x4600(), CostModel::default(), PagePolicy::Bind(6));
        let r = m.alloc(16 * PAGE_BYTES);
        m.first_touch(3, r, 0); // toucher's node is irrelevant under bind
        assert_eq!(m.node_used()[6], 16);
        assert_eq!(m.home_node(r), Some(6));
    }

    #[test]
    fn next_touch_migration_costs_time_and_counts() {
        let mut m = MemSim::with_policy(
            Topology::x4600(),
            CostModel::default(),
            PagePolicy::NextTouch { max_moves: 1 },
        );
        let r = m.alloc(PAGE_BYTES);
        m.first_touch(0, r, 0); // placed on node 0
        // same remote access under plain first-touch, for comparison
        let mut base = sim();
        let rb = base.alloc(PAGE_BYTES);
        base.first_touch(0, rb, 0);
        let plain = base.access(15, rb, false, 0);
        let migrating = m.access(15, r, false, 0); // node 7 re-touch migrates
        assert_eq!(m.stats().migrated_pages, 1);
        assert!(m.stats().migration_stall > 0);
        assert_eq!(m.node_of_addr(r.addr), Some(7), "page followed the toucher");
        assert!(
            migrating > plain,
            "migration {migrating} must cost more than the plain remote access {plain}"
        );
        // after the move, node-7 accesses are local (cold-cache core 14
        // shares node 7 with core 15)
        let after = m.access(14, r, false, 0);
        assert!(after < migrating, "local re-access {after} vs migrating {migrating}");
        assert_eq!(m.stats().migrated_pages, 1, "budget of 1 spent");
    }

    #[test]
    fn home_node_majority_and_ties() {
        let mut m = sim();
        let r = m.alloc(4 * PAGE_BYTES);
        // core 0 = node 0, core 2 = node 1: 3 pages on node 0, 1 on node 1
        m.first_touch(0, r.slice(0, 3 * PAGE_BYTES), 0);
        m.first_touch(2, r.slice(3 * PAGE_BYTES, PAGE_BYTES), 0);
        assert_eq!(m.home_node(r), Some(0));
        // 2-2 tie: lower node id wins, deterministically
        let t = m.alloc(4 * PAGE_BYTES);
        m.first_touch(2, t.slice(0, 2 * PAGE_BYTES), 0); // node 1
        m.first_touch(0, t.slice(2 * PAGE_BYTES, 2 * PAGE_BYTES), 0); // node 0
        assert_eq!(m.home_node(t), Some(0), "tie breaks to the lower node id");
    }

    #[test]
    fn home_node_unresident_and_empty() {
        let mut m = sim();
        assert_eq!(m.home_node(Region::EMPTY), None);
        let r = m.alloc(8 * PAGE_BYTES);
        assert_eq!(m.home_node(r), None, "no page resident yet");
        m.first_touch(4, r, 0); // core 4 = node 2
        assert_eq!(m.home_node(r), Some(2));
    }

    #[test]
    fn home_node_samples_large_regions() {
        let mut m = sim();
        let r = m.alloc(1024 * PAGE_BYTES);
        m.first_touch(6, r, 0); // core 6 = node 3 (with capacity spill)
        // sampling must still find the majority without walking every page
        assert_eq!(m.home_node(r), Some(3));
    }

    /// The repeated-touch memo must charge exactly what the slow path
    /// charges for a guaranteed L1 hit: `lines * l1_hit`, stats moving
    /// only `l1_hit_lines` — pinned against the cost model by hand.
    #[test]
    fn repeated_read_charges_exactly_the_l1_path() {
        let mut m = sim();
        let bytes = 1536u64; // sub-page, non-line-aligned
        let r = m.alloc(bytes);
        m.first_touch(0, r, 0);
        let lines = bytes.div_ceil(m.cost_model().line_bytes);
        let l1 = m.cost_model().l1_hit;
        let before = m.stats().clone();
        let second = m.access(0, r, false, 0);
        let third = m.access(0, r, false, 0);
        assert_eq!(second, lines * l1, "memoized re-read is an L1 charge");
        assert_eq!(third, second, "stable under repetition");
        let after = m.stats();
        assert_eq!(after.l1_hit_lines, before.l1_hit_lines + 2 * lines);
        assert_eq!(after.l2_hit_lines, before.l2_hit_lines);
        assert_eq!(after.miss_lines(), before.miss_lines());
        assert_eq!(after.first_touch_pages, before.first_touch_pages);
        assert_eq!(after.contention_stall, before.contention_stall);
    }

    /// A write by *any* core invalidates every memo: the next read by a
    /// core holding a stale copy must pay the full re-fetch, and its own
    /// re-read afterwards memoizes again.
    #[test]
    fn memo_dies_on_any_write() {
        let mut m = sim();
        let r = m.alloc(512);
        m.first_touch(0, r, 0);
        m.access(1, r, false, 0); // core 1 fills its caches
        let warm = m.access(1, r, false, 0); // memoized L1 charge
        m.access(0, r, true, 0); // core 0 writes: all memos stale
        let refetch = m.access(1, r, false, 0);
        assert!(refetch > warm, "stale memo must not mask the version bump");
        let rewarm = m.access(1, r, false, 0);
        assert_eq!(rewarm, warm, "memo re-arms after the re-fetch");
    }

    /// Under next-touch, a repeated *remote* read migrates on every
    /// touch while the budget lasts — the memo must never swallow those
    /// migrations (only locally-owned pages fast-path).
    #[test]
    fn memo_never_masks_next_touch_migration() {
        let mut m = MemSim::with_policy(
            Topology::x4600(),
            CostModel::default(),
            PagePolicy::NextTouch { max_moves: 2 },
        );
        let r = m.alloc(PAGE_BYTES);
        m.first_touch(0, r, 0); // node 0
        m.access(15, r, false, 0); // migrates to node 7
        assert_eq!(m.node_of_addr(r.addr), Some(7));
        assert_eq!(m.stats().migrated_pages, 1);
        // core 0 re-touches: second migration, even though core 0's
        // memo for this page predates it
        m.access(0, r, false, 0);
        assert_eq!(m.node_of_addr(r.addr), Some(0), "second move spent the budget");
        assert_eq!(m.stats().migrated_pages, 2);
        // budget gone: core 15's touch stays remote, slow-path-resolved
        m.access(15, r, false, 0);
        assert_eq!(m.node_of_addr(r.addr), Some(0));
        assert_eq!(m.stats().migrated_pages, 2);
    }

    #[test]
    fn capacity_spill_changes_node() {
        let topo = Topology::x4600().with_capacity_pages(4);
        let mut m = MemSim::new(topo, CostModel::default());
        let r = m.alloc(8 * PAGE_BYTES);
        m.first_touch(0, r, 0);
        let used = m.node_used();
        assert_eq!(used[0], 4, "local node filled");
        assert_eq!(used.iter().sum::<u64>(), 8, "rest spilled");
        assert!(used[1] > 0, "spill goes to 1-hop neighbour");
    }
}
