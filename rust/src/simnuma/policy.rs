//! Page-placement policies — the allocation side of the paper's technique.
//!
//! First-touch used to be hard-coded inside [`super::page::PageTable`];
//! this module opens it into a [`PagePolicy`] the whole experiment surface
//! can select and sweep.  Wittmann & Hager (arXiv:1101.0093) show the
//! choice of first-touch vs. next-touch page policy — and task-to-data
//! affinity built on top of it — dominates ccNUMA task throughput, so the
//! policy is a first-class [`RunSpec`](crate::spec::RunSpec) axis exactly
//! like the scheduler:
//!
//! | policy | placement of a fresh page | extra behaviour |
//! |---|---|---|
//! | `first-touch` | node of the first touching core (Linux default) | — |
//! | `interleave`  | round-robin by page id over all nodes | — |
//! | `bind`        | one fixed node (`node` parameter) | — |
//! | `next-touch`  | like first-touch | a *remote* re-touch migrates the page to the toucher's node (at most `max_moves` times per page) |
//!
//! Every policy falls back to the nearest node with free capacity when its
//! preferred node is full (the same spill rule first-touch always had), so
//! capacity behaviour stays comparable across policies.
//!
//! [`MemSpec`] is the serializable selection (CLI `--mem next-touch:max_moves=2`,
//! manifest `"mem": {"name": "bind", "node": 3}`), mirroring
//! [`SchedSpec`](crate::coordinator::sched::SchedSpec) so placement ×
//! scheduler × topology sweeps are plain data.

use anyhow::{bail, Context, Result};

use crate::serde::Json;
use crate::util::fmt_f64;

/// A resolved page-placement policy (what [`super::PageTable`] executes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PagePolicy {
    /// Fresh pages land on the touching core's node (Linux default).
    #[default]
    FirstTouch,
    /// Fresh pages round-robin over nodes by page id (`numactl -i all`).
    Interleave,
    /// Fresh pages all land on one node (`numactl -m <node>`).
    Bind(usize),
    /// First-touch placement, but a remote re-touch migrates the page to
    /// the toucher's node, at most `max_moves` times per page.
    NextTouch { max_moves: u32 },
}

/// One declared policy parameter: (name, default, one-line doc).
pub type MemParam = (&'static str, f64, &'static str);

/// Registration-style metadata for one page policy (the `numanos list`
/// and error-message surface; the set is closed, unlike the scheduler
/// registry — policies need page-table support, not just a trait impl).
#[derive(Clone, Copy, Debug)]
pub struct PagePolicyInfo {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    pub params: &'static [MemParam],
}

/// Every supported policy with its declared parameters.
pub fn page_policy_infos() -> &'static [PagePolicyInfo] {
    &[
        PagePolicyInfo {
            name: "first-touch",
            aliases: &["ft"],
            summary: "pages land on the first toucher's node (Linux default)",
            params: &[],
        },
        PagePolicyInfo {
            name: "interleave",
            aliases: &["il"],
            summary: "pages round-robin over nodes by page id",
            params: &[],
        },
        PagePolicyInfo {
            name: "bind",
            aliases: &[],
            summary: "all pages on one fixed node",
            params: &[("node", 0.0, "NUMA node the pages bind to")],
        },
        PagePolicyInfo {
            name: "next-touch",
            aliases: &["nt"],
            summary: "first-touch + migrate on remote re-touch",
            params: &[("max_moves", 1.0, "migration budget per page")],
        },
    ]
}

/// Canonical policy names, in table order.
pub fn page_policy_names() -> Vec<&'static str> {
    page_policy_infos().iter().map(|i| i.name).collect()
}

fn find_info(name: &str) -> Result<&'static PagePolicyInfo> {
    for info in page_policy_infos() {
        if info.name == name || info.aliases.contains(&name) {
            return Ok(info);
        }
    }
    bail!(
        "unknown page policy '{name}' (available: {})",
        page_policy_names().join("|")
    )
}

/// A page-policy selection as data: canonical name plus parameter
/// overrides (kept sorted by key so equal selections compare equal) —
/// the memory-side sibling of [`SchedSpec`](crate::coordinator::sched::SchedSpec).
/// `RunSpec`, sweeps, manifests and the CLI carry this; [`MemSpec::build`]
/// turns it into a live [`PagePolicy`].
#[derive(Clone, Debug, PartialEq)]
pub struct MemSpec {
    pub name: String,
    pub params: Vec<(String, f64)>,
}

impl Default for MemSpec {
    /// The pre-refactor behaviour: plain first-touch.
    fn default() -> Self {
        Self::new("first-touch")
    }
}

impl MemSpec {
    /// By policy name, no overrides (not validated until [`MemSpec::check`]
    /// / `RunSpec::validate`).
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), params: Vec::new() }
    }

    /// Add/replace one parameter override (kept sorted by key).
    pub fn with_param(mut self, key: &str, value: f64) -> Self {
        self.set_param(key, value);
        self
    }

    pub fn set_param(&mut self, key: &str, value: f64) {
        match self.params.binary_search_by(|(k, _)| k.as_str().cmp(key)) {
            Ok(i) => self.params[i].1 = value,
            Err(i) => self.params.insert(i, (key.to_string(), value)),
        }
    }

    fn param(&self, key: &str, default: f64) -> f64 {
        self.params.iter().find(|(k, _)| k == key).map(|(_, v)| *v).unwrap_or(default)
    }

    /// The default (first-touch, no overrides) selection?
    pub fn is_default(&self) -> bool {
        self.name == "first-touch" && self.params.is_empty()
    }

    /// Parse the CLI form: `name` or `name:key=value,key=value` — same
    /// grammar as `--sched`.  Aliases canonicalize; parameters validate
    /// eagerly.
    pub fn parse(text: &str) -> Result<Self> {
        let (name, params_text) = match text.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (text.trim(), None),
        };
        let mut spec = Self::new(find_info(name)?.name);
        if let Some(pairs) = params_text {
            for pair in pairs.split(',').filter(|s| !s.trim().is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .with_context(|| format!("bad page-policy parameter '{pair}' (want k=v)"))?;
                let v: f64 = v
                    .trim()
                    .parse()
                    .with_context(|| format!("bad page-policy parameter value in '{pair}'"))?;
                spec.set_param(k.trim(), v);
            }
        }
        spec.check()?;
        Ok(spec)
    }

    /// Validate name + parameters against the policy table (node ranges
    /// are checked later against the topology by [`MemSpec::build`]).
    pub fn check(&self) -> Result<()> {
        let info = find_info(&self.name)?;
        for (key, _) in &self.params {
            if !info.params.iter().any(|(name, _, _)| name == key) {
                let allowed: Vec<&str> = info.params.iter().map(|(n, _, _)| *n).collect();
                bail!(
                    "page policy '{}' has no parameter '{key}' ({})",
                    info.name,
                    if allowed.is_empty() {
                        "it takes none".to_string()
                    } else {
                        format!("parameters: {}", allowed.join(" "))
                    }
                );
            }
        }
        Ok(())
    }

    /// Resolve into a [`PagePolicy`] for a machine with `nodes` NUMA
    /// nodes (validates node-indexed parameters against the topology).
    pub fn build(&self, nodes: usize) -> Result<PagePolicy> {
        self.check()?;
        Ok(match find_info(&self.name)?.name {
            "first-touch" => PagePolicy::FirstTouch,
            "interleave" => PagePolicy::Interleave,
            "bind" => {
                let node = self.param("node", 0.0);
                if node < 0.0 || node.fract() != 0.0 {
                    bail!("bind node must be a non-negative integer, got {node}");
                }
                let node = node as usize;
                if node >= nodes {
                    bail!("bind node {node} out of range for a {nodes}-node topology");
                }
                PagePolicy::Bind(node)
            }
            "next-touch" => {
                let moves = self.param("max_moves", 1.0);
                if moves < 0.0 || moves.fract() != 0.0 || moves > u32::MAX as f64 {
                    bail!("max_moves must be a non-negative integer, got {moves}");
                }
                PagePolicy::NextTouch { max_moves: moves as u32 }
            }
            other => unreachable!("unhandled page policy '{other}'"),
        })
    }

    /// Canonical signature for describe lines and CSV cells: `name` or
    /// `name(k=v;k=v)` (no commas — CSV-safe).
    pub fn name_sig(&self) -> String {
        if self.params.is_empty() {
            return self.name.clone();
        }
        let parts: Vec<String> =
            self.params.iter().map(|(k, v)| format!("{k}={}", fmt_f64(*v))).collect();
        format!("{}({})", self.name, parts.join(";"))
    }

    /// JSON form: a bare string without parameters, else
    /// `{"name": …, "<param>": <value>, …}` — same shape as `sched`.
    pub fn to_json(&self) -> Json {
        if self.params.is_empty() {
            return Json::from(self.name.as_str());
        }
        let pairs = std::iter::once(("name".to_string(), Json::from(self.name.as_str())))
            .chain(self.params.iter().map(|(k, v)| (k.clone(), Json::from(*v))));
        Json::obj(pairs)
    }

    /// Accept both JSON forms (string name / object with parameters).
    pub fn from_json(j: &Json) -> Result<Self> {
        match j {
            Json::Str(s) => Self::parse(s),
            _ => {
                let obj = j
                    .as_obj()
                    .context("mem must be a page-policy name or {\"name\": …, params…}")?;
                let name = obj
                    .get("name")
                    .and_then(Json::as_str)
                    .context("parameterized mem needs a string 'name'")?;
                let mut spec = Self::new(find_info(name)?.name);
                for (key, val) in obj {
                    if key == "name" {
                        continue;
                    }
                    let v = val
                        .as_num()
                        .with_context(|| format!("mem parameter '{key}' must be a number"))?;
                    spec.set_param(key, v);
                }
                spec.check()?;
                Ok(spec)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_first_touch() {
        let spec = MemSpec::default();
        assert!(spec.is_default());
        assert_eq!(spec.build(8).unwrap(), PagePolicy::FirstTouch);
        assert_eq!(spec.name_sig(), "first-touch");
    }

    #[test]
    fn parse_forms_and_aliases() {
        assert_eq!(MemSpec::parse("ft").unwrap().name, "first-touch");
        assert_eq!(MemSpec::parse("il").unwrap().name, "interleave");
        assert_eq!(MemSpec::parse("nt").unwrap().name, "next-touch");
        let b = MemSpec::parse("bind:node=3").unwrap();
        assert_eq!(b.name_sig(), "bind(node=3)");
        assert_eq!(b.build(8).unwrap(), PagePolicy::Bind(3));
        let n = MemSpec::parse("next-touch:max_moves=2").unwrap();
        assert_eq!(n.build(4).unwrap(), PagePolicy::NextTouch { max_moves: 2 });
        assert!(MemSpec::parse("bogus").is_err());
        assert!(MemSpec::parse("bind:nod=1").is_err(), "unknown parameter");
        assert!(MemSpec::parse("interleave:x=1").is_err(), "takes none");
        assert!(MemSpec::parse("bind:node=").is_err());
    }

    #[test]
    fn build_validates_against_topology() {
        let b = MemSpec::new("bind").with_param("node", 7.0);
        assert!(b.build(8).is_ok());
        let err = format!("{:#}", b.build(4).unwrap_err());
        assert!(err.contains("out of range"), "{err}");
        let frac = MemSpec::new("bind").with_param("node", 1.5);
        assert!(frac.build(8).is_err());
        let neg = MemSpec::new("next-touch").with_param("max_moves", -1.0);
        assert!(neg.build(8).is_err());
        // bind with no override defaults to node 0
        assert_eq!(MemSpec::new("bind").build(2).unwrap(), PagePolicy::Bind(0));
    }

    #[test]
    fn json_roundtrips_both_forms() {
        let plain = MemSpec::new("interleave");
        assert_eq!(plain.to_json().to_compact(), "\"interleave\"");
        assert_eq!(MemSpec::from_json(&plain.to_json()).unwrap(), plain);

        let p = MemSpec::new("bind").with_param("node", 2.0);
        let back = MemSpec::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);

        let j = Json::parse(r#"{"name": "next-touch", "max_moves": 3}"#).unwrap();
        let spec = MemSpec::from_json(&j).unwrap();
        assert_eq!(spec.name_sig(), "next-touch(max_moves=3)");

        assert!(MemSpec::from_json(&Json::parse("{\"node\": 1}").unwrap()).is_err());
        assert!(MemSpec::from_json(&Json::parse("{\"name\": \"bind\", \"node\": \"x\"}").unwrap())
            .is_err());
    }

    #[test]
    fn error_lists_available_policies() {
        let err = format!("{:#}", MemSpec::parse("bogus").unwrap_err());
        for name in page_policy_names() {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }

    #[test]
    fn info_table_is_complete() {
        let names = page_policy_names();
        assert_eq!(names, vec!["first-touch", "interleave", "bind", "next-touch"]);
        let bind = page_policy_infos().iter().find(|i| i.name == "bind").unwrap();
        assert_eq!(bind.params[0].0, "node");
    }
}
