//! Legacy run configuration: flat knobs + a tiny `key = value` config-file
//! format.  New code should use the validated, serializable
//! [`RunSpec`](crate::spec::RunSpec) (see [`RunConfig::to_spec`]); this
//! type remains for config files and the shared [`Size`]/[`ComputeMode`]
//! enums and cost-override parsing.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::binding::BindPolicy;
use crate::coordinator::sched::{Policy, SchedSpec};
use crate::simnuma::{CostModel, MemSpec};
use crate::util::NS;

/// Benchmark input scale (the paper's Medium/Large; Small for tests;
/// XL for the ≥1M-task perf cells — only fib/uts/sort define genuinely
/// larger inputs, the rest alias Large).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Size {
    Small,
    Medium,
    Large,
    XL,
}

impl Size {
    pub fn name(self) -> &'static str {
        match self {
            Size::Small => "small",
            Size::Medium => "medium",
            Size::Large => "large",
            Size::XL => "xl",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "small" | "s" => Size::Small,
            "medium" | "m" => Size::Medium,
            "large" | "l" => Size::Large,
            "xl" => Size::XL,
            other => bail!("unknown size '{other}' (small|medium|large|xl)"),
        })
    }
}

/// Whether leaf tasks invoke the real AOT kernels through PJRT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeMode {
    /// Simulated cost only (figures, sweeps).
    Sim,
    /// Real numerics through `artifacts/*.hlo.txt` (end-to-end proof).
    Pjrt,
}

/// One fully specified run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub bench: String,
    pub size: Size,
    /// Scheduler selection — any registered scheduler, parameterized as
    /// `name:k=v,...` in config files.
    pub sched: SchedSpec,
    /// Page-placement policy, same `name:k=v,...` grammar.
    pub mem: MemSpec,
    pub bind: BindPolicy,
    pub threads: usize,
    pub topo: String,
    pub seed: u64,
    pub compute: ComputeMode,
    pub artifact_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            bench: "fft".into(),
            size: Size::Medium,
            sched: SchedSpec::stock(Policy::WorkFirst),
            mem: MemSpec::default(),
            bind: BindPolicy::Linear,
            threads: 16,
            topo: "x4600".into(),
            seed: 42,
            compute: ComputeMode::Sim,
            artifact_dir: "artifacts".into(),
        }
    }
}

impl RunConfig {
    /// Apply one `key = value` setting.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "bench" => self.bench = value.to_string(),
            "size" => self.size = Size::from_name(value)?,
            "sched" | "policy" => self.sched = SchedSpec::parse(value)?,
            "mem" => self.mem = MemSpec::parse(value)?,
            "bind" => self.bind = BindPolicy::from_name(value)?,
            "threads" => self.threads = value.parse().context("threads")?,
            "topo" => self.topo = value.to_string(),
            "seed" => self.seed = value.parse().context("seed")?,
            "compute" => {
                self.compute = match value {
                    "sim" => ComputeMode::Sim,
                    "pjrt" => ComputeMode::Pjrt,
                    other => bail!("unknown compute mode '{other}' (sim|pjrt)"),
                }
            }
            "artifacts" => self.artifact_dir = value.to_string(),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Parse a config file: `key = value` lines, `#` comments, blank lines.
    pub fn from_file(path: &Path) -> Result<Self> {
        let mut cfg = Self::default();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            cfg.set(k.trim(), v.trim())
                .with_context(|| format!("line {}", lineno + 1))?;
        }
        Ok(cfg)
    }

    /// Lower onto the spec layer — [`RunSpec`](crate::spec::RunSpec) is
    /// the validated form every execution path consumes.
    pub fn to_spec(&self) -> Result<crate::spec::RunSpec> {
        crate::spec::RunSpec::builder()
            .bench(&self.bench)
            .size(self.size)
            .sched(self.sched.clone())
            .mem(self.mem.clone())
            .bind(self.bind)
            .threads(self.threads)
            .topo(&self.topo)
            .seed(self.seed)
            .compute(self.compute)
            .artifact_dir(&self.artifact_dir)
            .build()
    }

    pub fn describe(&self) -> String {
        format!(
            "bench={} size={} sched={} bind={} threads={} topo={} seed={} compute={}",
            self.bench,
            self.size.name(),
            self.sched.name_sig(),
            self.bind.name(),
            self.threads,
            self.topo,
            self.seed,
            match self.compute {
                ComputeMode::Sim => "sim",
                ComputeMode::Pjrt => "pjrt",
            },
        )
    }
}

/// Cost-model overrides from `key = value` pairs (calibration CLI).
pub fn apply_cost_override(cost: &mut CostModel, key: &str, value: &str) -> Result<()> {
    let ns = |v: &str| -> Result<u64> {
        Ok((v.parse::<f64>().context("number")? * NS as f64) as u64)
    };
    match key {
        "l1_hit_ns" => cost.l1_hit = ns(value)?,
        "l2_hit_ns" => cost.l2_hit = ns(value)?,
        "dram_base_ns" => cost.dram_base = ns(value)?,
        "hop_penalty_ns" => cost.hop_penalty = ns(value)?,
        "mem_service_ns" => cost.mem_service = ns(value)?,
        "queue_op_ns" => cost.queue_op = ns(value)?,
        "shared_queue_op_ns" => cost.shared_queue_op = ns(value)?,
        "spawn_cost_ns" => cost.spawn_cost = ns(value)?,
        "steal_base_ns" => cost.steal_base = ns(value)?,
        "steal_per_hop_ns" => cost.steal_per_hop = ns(value)?,
        "probe_base_ns" => cost.probe_base = ns(value)?,
        "probe_per_hop_ns" => cost.probe_per_hop = ns(value)?,
        "rtdata_per_hop_ns" => cost.rtdata_per_hop = ns(value)?,
        "remote_bw_pct_per_hop" => cost.remote_bw_pct_per_hop = value.parse()?,
        "l1_pages" => cost.l1_pages = value.parse()?,
        "l2_pages" => cost.l2_pages = value.parse()?,
        other => bail!("unknown cost knob '{other}'"),
    }
    Ok(())
}

/// Parse a repeated `k=v` CLI override list like `dram_base_ns=100,hop_penalty_ns=40`.
pub fn parse_cost_overrides(cost: &mut CostModel, spec: &str) -> Result<()> {
    for pair in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let (k, v) = pair
            .split_once('=')
            .with_context(|| format!("bad override '{pair}' (want k=v)"))?;
        apply_cost_override(cost, k.trim(), v.trim())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.threads, 16);
        assert_eq!(c.sched, SchedSpec::stock(Policy::WorkFirst));
    }

    #[test]
    fn set_roundtrip() {
        let mut c = RunConfig::default();
        c.set("bench", "sort").unwrap();
        c.set("sched", "dfwsrpt").unwrap();
        c.set("bind", "numa").unwrap();
        c.set("threads", "8").unwrap();
        c.set("size", "large").unwrap();
        c.set("compute", "pjrt").unwrap();
        assert_eq!(c.bench, "sort");
        assert_eq!(c.sched, SchedSpec::stock(Policy::Dfwsrpt));
        assert_eq!(c.bind, BindPolicy::NumaAware);
        assert_eq!(c.threads, 8);
        assert_eq!(c.size, Size::Large);
        assert_eq!(c.compute, ComputeMode::Pjrt);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("threads", "abc").is_err());
    }

    #[test]
    fn config_file_parses() {
        let dir = std::env::temp_dir().join(format!("numanos_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.conf");
        std::fs::write(
            &path,
            "# a comment\nbench = strassen\n\nsched = dfwspt # trailing\nthreads = 12\n",
        )
        .unwrap();
        let c = RunConfig::from_file(&path).unwrap();
        assert_eq!(c.bench, "strassen");
        assert_eq!(c.sched, SchedSpec::stock(Policy::Dfwspt));
        assert_eq!(c.threads, 12);
        // registry schedulers (with parameters) work from config files too
        std::fs::write(&path, "bench = fib\nsched = hops-threshold:max_hops=2\nthreads = 4\n")
            .unwrap();
        let c = RunConfig::from_file(&path).unwrap();
        assert_eq!(c.sched.name_sig(), "hops-threshold(max_hops=2)");
        assert!(c.to_spec().is_ok());
        // page policies too, same name:k=v grammar
        std::fs::write(
            &path,
            "bench = fib\nsched = numa-home\nmem = next-touch:max_moves=2\nthreads = 4\n",
        )
        .unwrap();
        let c = RunConfig::from_file(&path).unwrap();
        assert_eq!(c.mem.name_sig(), "next-touch(max_moves=2)");
        assert_eq!(c.to_spec().unwrap().mem, c.mem);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cost_overrides_apply() {
        let mut cm = CostModel::default();
        parse_cost_overrides(&mut cm, "dram_base_ns=100, hop_penalty_ns=50").unwrap();
        assert_eq!(cm.dram_base, 100 * NS);
        assert_eq!(cm.hop_penalty, 50 * NS);
        assert!(parse_cost_overrides(&mut cm, "nope=1").is_err());
        assert!(parse_cost_overrides(&mut cm, "dram_base_ns").is_err());
    }

    #[test]
    fn size_parse() {
        assert_eq!(Size::from_name("m").unwrap(), Size::Medium);
        assert!(Size::from_name("huge").is_err());
    }

    #[test]
    fn lowers_onto_run_spec() {
        let mut c = RunConfig::default();
        c.set("bench", "sort").unwrap();
        c.set("sched", "dfwspt").unwrap();
        c.set("bind", "numa").unwrap();
        let spec = c.to_spec().unwrap();
        assert_eq!(spec.bench, "sort");
        assert_eq!(spec.sched, crate::coordinator::sched::SchedSpec::stock(Policy::Dfwspt));
        assert_eq!(spec.label(), "dfwspt-Scheduler-NUMA");
        c.threads = 99; // invalid configs are caught at lowering time
        assert!(c.to_spec().is_err());
    }
}
