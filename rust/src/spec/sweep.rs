//! Cross-product sweeps: experiment grids as data.
//!
//! A [`Sweep`] names axes — benchmarks × (scheduler, binding) configs ×
//! page policies × thread counts × seeds on one topology — and expands
//! to a flat list of [`RunSpec`] cells in a fixed order
//! (bench → config → mem → seed → threads).
//! Every paper figure is a sweep (see `harness::sweep_for`); user-authored
//! sweeps come from manifests (`numanos sweep --manifest exp.toml`).
//!
//! A [`SweepResult`] keeps records in cell order, so its CSV/JSON/table
//! renderings are deterministic and independent of how many OS threads
//! executed the cells.
//!
//! A [`ShardPlan`] partitions the flattened cell sequence across N
//! cooperating *processes* (`numanos sweep --shard I/N`); the store is
//! the merge substrate (`numanos merge`, see `crate::store::shard`).

use anyhow::{bail, Context, Result};

use crate::config::{ComputeMode, Size};
use crate::coordinator::binding::BindPolicy;
use crate::coordinator::sched::{Policy, SchedSpec};
use crate::metrics::table::SpeedupTable;
use crate::serde::Json;
use crate::simnuma::MemSpec;
use crate::spec::session::RunRecord;
use crate::spec::{cost_from_json, BindSpec, RunSpec};

/// Thread counts on the paper's x-axis (16-core X4600).
pub const PAPER_THREADS: &[usize] = &[2, 4, 6, 8, 12, 16];

/// One experiment grid.
#[derive(Clone, Debug, PartialEq)]
pub struct Sweep {
    pub id: String,
    pub title: String,
    pub benches: Vec<String>,
    pub size: Size,
    /// (scheduler, binding) pairs — any registered scheduler, stock
    /// `Policy` values convert via `Into<SchedSpec>`.
    pub configs: Vec<(SchedSpec, BindPolicy)>,
    /// Page-placement policies (the memory axis; default: first-touch
    /// only, which keeps pre-placement sweeps bit-for-bit identical).
    pub mems: Vec<MemSpec>,
    pub threads: Vec<usize>,
    pub seeds: Vec<u64>,
    pub topo: String,
    pub cost: Vec<(String, f64)>,
}

impl Sweep {
    /// A sweep with the paper defaults: medium size, x4600, seed 42,
    /// paper thread axis — fill the other axes with the `with_*` chainers.
    pub fn new(id: &str, title: &str) -> Self {
        Self {
            id: id.to_string(),
            title: title.to_string(),
            benches: Vec::new(),
            size: Size::Medium,
            configs: Vec::new(),
            mems: vec![MemSpec::default()],
            threads: PAPER_THREADS.to_vec(),
            seeds: vec![42],
            topo: "x4600".into(),
            cost: Vec::new(),
        }
    }

    pub fn with_bench(mut self, bench: &str) -> Self {
        self.benches.push(bench.to_string());
        self
    }

    pub fn with_benches<I: IntoIterator<Item = S>, S: Into<String>>(mut self, benches: I) -> Self {
        self.benches.extend(benches.into_iter().map(Into::into));
        self
    }

    pub fn with_config<S: Into<SchedSpec>>(mut self, sched: S, bind: BindPolicy) -> Self {
        self.configs.push((sched.into(), bind));
        self
    }

    pub fn with_configs<I, S>(mut self, configs: I) -> Self
    where
        I: IntoIterator<Item = (S, BindPolicy)>,
        S: Into<SchedSpec>,
    {
        self.configs.extend(configs.into_iter().map(|(s, b)| (s.into(), b)));
        self
    }

    /// Replace the memory axis with one policy.
    pub fn with_mem(self, mem: MemSpec) -> Self {
        self.with_mems(vec![mem])
    }

    /// Replace the memory axis (page policy × everything else).
    pub fn with_mems(mut self, mems: Vec<MemSpec>) -> Self {
        self.mems = mems;
        self
    }

    pub fn with_threads(mut self, threads: Vec<usize>) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_seeds(mut self, seeds: Vec<u64>) -> Self {
        self.seeds = seeds;
        self
    }

    pub fn with_seed(self, seed: u64) -> Self {
        self.with_seeds(vec![seed])
    }

    pub fn with_size(mut self, size: Size) -> Self {
        self.size = size;
        self
    }

    pub fn with_topo(mut self, topo: &str) -> Self {
        self.topo = topo.to_string();
        self
    }

    pub fn with_cost(mut self, key: &str, value: f64) -> Self {
        self.cost.push((key.to_string(), value));
        self
    }

    /// Number of cells the cross product expands to.
    pub fn cell_count(&self) -> usize {
        self.benches.len()
            * self.configs.len()
            * self.mems.len()
            * self.seeds.len()
            * self.threads.len()
    }

    /// Expand the cross product (bench → config → mem → seed → threads)
    /// into concrete run specs.
    pub fn cells(&self) -> Result<Vec<RunSpec>> {
        if self.benches.is_empty() {
            bail!("sweep '{}' has no benchmarks", self.id);
        }
        if self.configs.is_empty() {
            bail!("sweep '{}' has no (scheduler, binding) configs", self.id);
        }
        if self.mems.is_empty() {
            bail!("sweep '{}' has no page policies", self.id);
        }
        if self.threads.is_empty() {
            bail!("sweep '{}' has no thread counts", self.id);
        }
        if self.seeds.is_empty() {
            bail!("sweep '{}' has no seeds", self.id);
        }
        let mut cells = Vec::with_capacity(self.cell_count());
        for bench in &self.benches {
            for (sched, bind) in &self.configs {
                for mem in &self.mems {
                    for &seed in &self.seeds {
                        for &threads in &self.threads {
                            cells.push(RunSpec {
                                bench: bench.clone(),
                                size: self.size,
                                sched: sched.clone(),
                                mem: mem.clone(),
                                bind: BindSpec::Policy(*bind),
                                threads,
                                topo: self.topo.clone(),
                                seed,
                                compute: ComputeMode::Sim,
                                artifact_dir: "artifacts".into(),
                                cost: self.cost.clone(),
                                rtdata_local: true,
                            });
                        }
                    }
                }
            }
        }
        Ok(cells)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(String, Json)> = vec![
            ("id".into(), Json::from(self.id.as_str())),
            ("title".into(), Json::from(self.title.as_str())),
            (
                "bench".into(),
                Json::Arr(self.benches.iter().map(|b| Json::from(b.as_str())).collect()),
            ),
            (
                "configs".into(),
                Json::Arr(
                    self.configs
                        .iter()
                        .map(|(s, b)| Json::Arr(vec![s.to_json(), Json::from(b.name())]))
                        .collect(),
                ),
            ),
            (
                "mem".into(),
                Json::Arr(self.mems.iter().map(MemSpec::to_json).collect()),
            ),
            ("threads".into(), Json::Arr(self.threads.iter().map(|&t| Json::from(t)).collect())),
            (
                "seeds".into(),
                Json::Arr(self.seeds.iter().map(|&s| Json::from_u64_lossless(s)).collect()),
            ),
            ("size".into(), Json::from(self.size.name())),
            ("topo".into(), Json::from(self.topo.as_str())),
        ];
        if !self.cost.is_empty() {
            pairs.push((
                "cost".into(),
                Json::obj(self.cost.iter().map(|(k, v)| (k.clone(), Json::from(*v)))),
            ));
        }
        Json::obj(pairs)
    }

    /// Parse one sweep object, filling unset axes from `defaults`.
    /// Configs come either as explicit `configs: [[sched, bind], …]`
    /// pairs, or as the cross product of `sched: […]` × `bind: […]`.
    pub fn from_json(j: &Json, defaults: &SweepDefaults) -> Result<Self> {
        let obj = j.as_obj().context("sweep must be an object")?;
        let mut sweep = Sweep {
            id: String::new(),
            title: String::new(),
            benches: Vec::new(),
            size: defaults.size,
            configs: Vec::new(),
            mems: defaults.mems.clone(),
            threads: defaults.threads.clone(),
            seeds: defaults.seeds.clone(),
            topo: defaults.topo.clone(),
            cost: defaults.cost.clone(),
        };
        let mut scheds: Vec<SchedSpec> = vec![SchedSpec::stock(Policy::WorkFirst)];
        let mut binds: Vec<String> = vec!["linear".into()];
        let mut explicit_configs: Option<Vec<(SchedSpec, BindPolicy)>> = None;
        let mut unknown = Vec::new();
        for (key, val) in obj {
            match key.as_str() {
                "id" => sweep.id = val.as_str().context("id must be a string")?.to_string(),
                "title" => {
                    sweep.title = val.as_str().context("title must be a string")?.to_string()
                }
                "bench" | "benches" => sweep.benches = str_list(val, key)?,
                "sched" | "policies" => scheds = sched_list(val)?,
                "mem" | "mems" => sweep.mems = mem_list(val)?,
                "bind" | "binds" => binds = str_list(val, key)?,
                "topos" => bail!(
                    "'topos' is a manifest-level key (it expands into one sweep per \
                     topology); load the file through ExperimentManifest, or use 'topo'"
                ),
                "configs" => {
                    let pairs = val.as_arr().context("configs must be an array")?;
                    let mut parsed = Vec::with_capacity(pairs.len());
                    for p in pairs {
                        let pair = p.as_arr().context("each config must be [sched, bind]")?;
                        if pair.len() != 2 {
                            bail!("each config must be a [sched, bind] pair");
                        }
                        parsed.push((
                            SchedSpec::from_json(&pair[0]).context("config sched")?,
                            BindPolicy::from_name(pair[1].as_str().context("config bind")?)?,
                        ));
                    }
                    explicit_configs = Some(parsed);
                }
                "threads" => {
                    sweep.threads = num_list(val, key)?
                        .into_iter()
                        .map(|n| usize::try_from(n).context("thread count"))
                        .collect::<Result<_>>()?
                }
                "seeds" | "seed" => sweep.seeds = num_list(val, key)?,
                "size" => sweep.size = Size::from_name(val.as_str().context("size")?)?,
                "topo" => sweep.topo = val.as_str().context("topo")?.to_string(),
                "cost" => sweep.cost = cost_from_json(val)?,
                _ => unknown.push(key.clone()),
            }
        }
        if !unknown.is_empty() {
            bail!(
                "unknown sweep key(s): {} (allowed: id title bench sched mem bind configs \
                 threads seeds size topo cost)",
                unknown.join(", ")
            );
        }
        sweep.configs = match explicit_configs {
            Some(c) => c,
            None => {
                let mut cross = Vec::with_capacity(scheds.len() * binds.len());
                for s in &scheds {
                    for b in &binds {
                        cross.push((s.clone(), BindPolicy::from_name(b)?));
                    }
                }
                cross
            }
        };
        if sweep.id.is_empty() {
            bail!("sweep needs an 'id'");
        }
        if sweep.title.is_empty() {
            sweep.title = sweep.id.clone();
        }
        // surface axis errors at load time, not run time
        sweep.cells()?;
        Ok(sweep)
    }
}

/// Deterministic partition of a flattened cell sequence across `count`
/// cooperating processes: shard `index` owns every cell whose *global*
/// index (its position in the manifest's sweep-by-sweep cell expansion)
/// is congruent to `index` modulo `count`.
///
/// Pure arithmetic over the fixed expansion order, so any two processes
/// that load identical manifests — in any spelling (JSON vs TOML,
/// defaulted vs explicit axes) — compute identical plans; the store's
/// canonical cell identities (`crate::store::cells_fingerprint`) pin
/// that agreement on disk via the per-shard completion markers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// This process's shard, in `0..count`.
    pub index: usize,
    /// Total number of cooperating shards.
    pub count: usize,
}

impl ShardPlan {
    pub fn new(index: usize, count: usize) -> Result<Self> {
        if count == 0 {
            bail!("shard count must be at least 1");
        }
        if index >= count {
            bail!("shard index {index} out of range 0..{count}");
        }
        Ok(Self { index, count })
    }

    /// The trivial single-shard plan: owns every cell.
    pub fn full() -> Self {
        Self { index: 0, count: 1 }
    }

    /// Parse the CLI spelling `I/N` (e.g. `--shard 0/3`).
    pub fn parse(s: &str) -> Result<Self> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("shard spec '{s}' must be I/N (e.g. 0/3)"))?;
        let index: usize = i
            .trim()
            .parse()
            .with_context(|| format!("shard index in '{s}' must be a non-negative integer"))?;
        let count: usize = n
            .trim()
            .parse()
            .with_context(|| format!("shard count in '{s}' must be a positive integer"))?;
        Self::new(index, count).with_context(|| format!("shard spec '{s}'"))
    }

    pub fn is_full(&self) -> bool {
        self.count == 1
    }

    /// Whether this shard owns the cell at `global_index`.
    pub fn owns(&self, global_index: usize) -> bool {
        global_index % self.count == self.index
    }

    /// How many of `total` consecutive cells (from global index 0) this
    /// shard owns.
    pub fn owned_of(&self, total: usize) -> usize {
        if total <= self.index {
            0
        } else {
            (total - self.index - 1) / self.count + 1
        }
    }

    /// Marker-file spelling: `I-of-N` (see `<store>/shards/I-of-N.json`).
    pub fn name(&self) -> String {
        format!("{}-of-{}", self.index, self.count)
    }

    /// CLI spelling: `I/N`.
    pub fn spec(&self) -> String {
        format!("{}/{}", self.index, self.count)
    }
}

/// Defaults a manifest's `[defaults]` section provides to its sweeps.
#[derive(Clone, Debug)]
pub struct SweepDefaults {
    pub size: Size,
    pub topo: String,
    pub threads: Vec<usize>,
    pub seeds: Vec<u64>,
    pub mems: Vec<MemSpec>,
    pub cost: Vec<(String, f64)>,
}

impl Default for SweepDefaults {
    fn default() -> Self {
        Self {
            size: Size::Medium,
            topo: "x4600".into(),
            threads: PAPER_THREADS.to_vec(),
            seeds: vec![42],
            mems: vec![MemSpec::default()],
            cost: Vec::new(),
        }
    }
}

/// Accept one scheduler selection or an array of them; each entry is a
/// name string, a `{"name": …, params…}` object, or a parameter *grid*
/// `{"name": …, fixed params…, "grid": {"<param>": [v, …], …}}` that
/// expands to the cross product of its axes (the ROADMAP's tunable-grid
/// sweep, e.g. `max_hops 0..3` without enumerating four manifest cells).
fn sched_list(v: &Json) -> Result<Vec<SchedSpec>> {
    let items = match v {
        Json::Arr(items) => items,
        single => std::slice::from_ref(single),
    };
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        match item.get("grid") {
            Some(grid) => out.extend(expand_sched_grid(item, grid)?),
            None => out.push(SchedSpec::from_json(item)?),
        }
    }
    Ok(out)
}

/// Expand one `{"name": …, "grid": {…}}` scheduler entry.
fn expand_sched_grid(item: &Json, grid: &Json) -> Result<Vec<SchedSpec>> {
    let obj = item.as_obj().context("gridded sched entry must be an object")?;
    let name = obj
        .get("name")
        .and_then(Json::as_str)
        .context("gridded sched entry needs a string 'name'")?;
    let mut base = SchedSpec::new(&crate::coordinator::sched::resolve_name(name)?);
    for (key, val) in obj {
        if key == "name" || key == "grid" {
            continue;
        }
        let v = val
            .as_num()
            .with_context(|| format!("sched parameter '{key}' must be a number"))?;
        base.set_param(key, v);
    }
    let axes = grid.as_obj().context("sched 'grid' must map parameters to value arrays")?;
    let mut specs = vec![base];
    for (param, values) in axes {
        let values = values
            .as_arr()
            .with_context(|| format!("grid axis '{param}' must be an array of numbers"))?;
        if values.is_empty() {
            bail!("grid axis '{param}' has no values");
        }
        let mut next = Vec::with_capacity(specs.len() * values.len());
        for spec in &specs {
            for v in values {
                let v = v
                    .as_num()
                    .with_context(|| format!("grid axis '{param}' values must be numbers"))?;
                next.push(spec.clone().with_param(param, v));
            }
        }
        specs = next;
    }
    for spec in &specs {
        spec.check()?;
    }
    Ok(specs)
}

/// Accept one page-policy selection or an array of them; entries are
/// names or `{"name": …, params…}` objects, like `sched`.
fn mem_list(v: &Json) -> Result<Vec<MemSpec>> {
    match v {
        Json::Arr(items) => items.iter().map(MemSpec::from_json).collect(),
        single => Ok(vec![MemSpec::from_json(single)?]),
    }
}

/// Accept `"x"` or `["x", "y"]`.
fn str_list(v: &Json, key: &str) -> Result<Vec<String>> {
    match v {
        Json::Str(s) => Ok(vec![s.clone()]),
        Json::Arr(items) => items
            .iter()
            .map(|i| {
                i.as_str()
                    .map(str::to_string)
                    .with_context(|| format!("'{key}' entries must be strings"))
            })
            .collect(),
        other => bail!("'{key}' must be a string or array of strings, got {other:?}"),
    }
}

/// Accept `7`, `"18446744073709551615"` (u64 beyond 2^53), or an array
/// of either.
pub(crate) fn num_list(v: &Json, key: &str) -> Result<Vec<u64>> {
    match v {
        Json::Num(_) | Json::Str(_) => Ok(vec![v
            .as_u64_lossless()
            .with_context(|| format!("'{key}' must be a non-negative integer"))?]),
        Json::Arr(items) => items
            .iter()
            .map(|i| {
                i.as_u64_lossless()
                    .with_context(|| format!("'{key}' entries must be non-negative integers"))
            })
            .collect(),
        other => bail!("'{key}' must be a number or array of numbers, got {other:?}"),
    }
}

/// Executed sweep: records in cell order.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub sweep: Sweep,
    pub records: Vec<RunRecord>,
}

impl SweepResult {
    /// Figure-shaped table: one row per (bench ×) config (× seed), one
    /// column per thread count, cells = speedup over the serial baseline.
    pub fn table(&self) -> SpeedupTable {
        let mut t = SpeedupTable::new(&self.sweep.title, self.sweep.threads.clone());
        let multi_bench = self.sweep.benches.len() > 1;
        let multi_mem = self.sweep.mems.len() > 1;
        let multi_seed = self.sweep.seeds.len() > 1;
        for chunk in self.records.chunks(self.sweep.threads.len()) {
            let first = &chunk[0];
            let mut label = first.label();
            if multi_bench {
                label = format!("{}/{label}", first.spec.bench);
            }
            if multi_mem {
                label = format!("{label}+{}", first.spec.mem.name_sig());
            }
            if multi_seed {
                label = format!("{label}@s{}", first.spec.seed);
            }
            t.push_row(label, chunk.iter().map(|r| r.speedup).collect());
        }
        t
    }

    /// Long-form CSV (deterministic; identical for parallel and
    /// sequential execution).
    pub fn to_csv(&self) -> String {
        let mut s = format!("sweep,{}\n", RunRecord::CSV_HEADER);
        for r in &self.records {
            s.push_str(&format!("{},{}\n", self.sweep.id, r.to_csv_row()));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("id", Json::from(self.sweep.id.as_str())),
            ("title", Json::from(self.sweep.title.as_str())),
            ("cells", Json::from(self.records.len())),
            ("records", Json::Arr(self.records.iter().map(RunRecord::to_json).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Sweep {
        Sweep::new("demo", "Demo sweep")
            .with_benches(["fib", "fft"])
            .with_config(Policy::WorkFirst, BindPolicy::Linear)
            .with_config(Policy::Dfwspt, BindPolicy::NumaAware)
            .with_threads(vec![2, 4, 8])
            .with_seeds(vec![1, 2])
            .with_size(Size::Small)
    }

    #[test]
    fn cross_product_cell_count() {
        let s = demo();
        assert_eq!(s.cell_count(), 2 * 2 * 2 * 3);
        let cells = s.cells().unwrap();
        assert_eq!(cells.len(), 24);
        // fixed nesting order: bench → config → seed → threads
        assert_eq!(cells[0].bench, "fib");
        assert_eq!(cells[0].threads, 2);
        assert_eq!(cells[1].threads, 4);
        assert_eq!(cells[3].seed, 2);
        assert_eq!(cells[6].sched, SchedSpec::stock(Policy::Dfwspt));
        assert_eq!(cells[12].bench, "fft");
        for c in &cells {
            c.validate().unwrap();
        }
    }

    #[test]
    fn empty_axes_rejected() {
        assert!(Sweep::new("x", "x").cells().is_err());
        assert!(Sweep::new("x", "x").with_bench("fib").cells().is_err());
        let no_threads = demo().with_threads(vec![]);
        assert!(no_threads.cells().is_err());
        let no_seeds = demo().with_seeds(vec![]);
        assert!(no_seeds.cells().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let s = demo().with_cost("dram_base_ns", 123.0);
        let j = s.to_json();
        let back = Sweep::from_json(&j, &SweepDefaults::default()).unwrap();
        assert_eq!(back, s);
        // a non-default memory axis survives the roundtrip too
        let s = demo().with_mems(vec![
            MemSpec::default(),
            MemSpec::new("interleave"),
            MemSpec::new("bind").with_param("node", 2.0),
        ]);
        let back = Sweep::from_json(&s.to_json(), &SweepDefaults::default()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn mem_axis_multiplies_cells_between_config_and_seed() {
        let s = demo().with_mems(vec![MemSpec::default(), MemSpec::new("interleave")]);
        assert_eq!(s.cell_count(), 2 * 2 * 2 * 2 * 3);
        let cells = s.cells().unwrap();
        assert_eq!(cells.len(), 48);
        // nesting order: bench → config → mem → seed → threads
        assert!(cells[0].mem.is_default());
        assert_eq!(cells[6].mem.name_sig(), "interleave", "{:?}", cells[6].mem);
        assert_eq!(cells[6].seed, 1, "seed resets inside the mem axis");
        assert_eq!(cells[3].seed, 2);
        for c in &cells {
            c.validate().unwrap();
        }
        // empty mem axis is rejected
        assert!(demo().with_mems(vec![]).cells().is_err());
    }

    #[test]
    fn mem_axis_parses_from_json_forms() {
        let j = Json::parse(
            r#"{"id": "m", "bench": "fib", "sched": ["wf"], "bind": ["numa"],
                "threads": [2], "seed": 1, "size": "small",
                "mem": ["first-touch", "interleave", {"name": "next-touch", "max_moves": 2}]}"#,
        )
        .unwrap();
        let s = Sweep::from_json(&j, &SweepDefaults::default()).unwrap();
        assert_eq!(s.mems.len(), 3);
        assert_eq!(s.mems[2].name_sig(), "next-touch(max_moves=2)");
        assert_eq!(s.cells().unwrap().len(), 3);
        // defaults flow in when the sweep names no mem axis
        let j = Json::parse(r#"{"id": "d", "bench": "fib", "threads": [2], "size": "small"}"#)
            .unwrap();
        let defaults = SweepDefaults {
            mems: vec![MemSpec::new("interleave")],
            ..SweepDefaults::default()
        };
        let s = Sweep::from_json(&j, &defaults).unwrap();
        assert_eq!(s.mems, vec![MemSpec::new("interleave")]);
        // bad entries fail at parse
        let j = Json::parse(r#"{"id": "x", "bench": "fib", "mem": ["bogus"]}"#).unwrap();
        assert!(Sweep::from_json(&j, &SweepDefaults::default()).is_err());
    }

    #[test]
    fn sched_grid_expands_in_manifest_lists() {
        let j = Json::parse(
            r#"{"id": "g", "bench": "fib", "bind": ["numa"], "threads": [2], "size": "small",
                "sched": [{"name": "hops-threshold", "spill_after": 1,
                           "grid": {"max_hops": [0, 1, 2, 3]}}]}"#,
        )
        .unwrap();
        let s = Sweep::from_json(&j, &SweepDefaults::default()).unwrap();
        assert_eq!(s.configs.len(), 4);
        assert_eq!(s.configs[0].0.name_sig(), "hops-threshold(max_hops=0;spill_after=1)");
        assert_eq!(s.configs[3].0.name_sig(), "hops-threshold(max_hops=3;spill_after=1)");
        // two-axis grids cross; plain entries mix with gridded ones
        let j = Json::parse(
            r#"{"id": "g2", "bench": "fib", "threads": [2], "size": "small",
                "sched": ["wf", {"name": "hops-threshold",
                                 "grid": {"max_hops": [1, 2], "spill_after": [1, 2]}}]}"#,
        )
        .unwrap();
        let s = Sweep::from_json(&j, &SweepDefaults::default()).unwrap();
        assert_eq!(s.configs.len(), 1 + 4);
        // bad grids fail at parse, naming the problem
        for bad in [
            r#"{"id": "b", "bench": "fib", "sched": [{"name": "hops-threshold", "grid": {"bogus": [1]}}]}"#,
            r#"{"id": "b", "bench": "fib", "sched": [{"name": "hops-threshold", "grid": {"max_hops": []}}]}"#,
            r#"{"id": "b", "bench": "fib", "sched": [{"grid": {"max_hops": [1]}}]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Sweep::from_json(&j, &SweepDefaults::default()).is_err(), "{bad}");
        }
    }

    #[test]
    fn sched_bind_cross_product_form() {
        let j = Json::parse(
            r#"{"id": "g", "bench": "fib", "sched": ["wf", "cilk"],
                "bind": ["linear", "numa"], "threads": [2], "seed": 3, "size": "small"}"#,
        )
        .unwrap();
        let s = Sweep::from_json(&j, &SweepDefaults::default()).unwrap();
        assert_eq!(s.configs.len(), 4);
        assert_eq!(s.configs[0], (SchedSpec::stock(Policy::WorkFirst), BindPolicy::Linear));
        assert_eq!(s.configs[3], (SchedSpec::stock(Policy::CilkBased), BindPolicy::NumaAware));
        assert_eq!(s.seeds, vec![3]);
        assert_eq!(s.title, "g", "title defaults to id");
    }

    #[test]
    fn parameterized_schedulers_cross_and_roundtrip() {
        let j = Json::parse(
            r#"{"id": "p", "bench": "fib",
                "sched": ["wf", {"name": "hops-threshold", "max_hops": 1}],
                "bind": ["numa"], "threads": [2], "seed": 1, "size": "small"}"#,
        )
        .unwrap();
        let s = Sweep::from_json(&j, &SweepDefaults::default()).unwrap();
        assert_eq!(s.configs.len(), 2);
        assert_eq!(s.configs[1].0.name_sig(), "hops-threshold(max_hops=1)");
        let back = Sweep::from_json(&s.to_json(), &SweepDefaults::default()).unwrap();
        assert_eq!(back, s);
        // explicit configs accept the object form too
        let j = Json::parse(
            r#"{"id": "q", "bench": "fib", "threads": [2], "size": "small",
                "configs": [[{"name": "adaptive", "remote_ratio": 0.25}, "numa"]]}"#,
        )
        .unwrap();
        let s = Sweep::from_json(&j, &SweepDefaults::default()).unwrap();
        assert_eq!(s.configs[0].0.name, "adaptive");
        assert_eq!(s.configs[0].1, BindPolicy::NumaAware);
    }

    #[test]
    fn topos_rejected_outside_manifests() {
        // 'topos' only expands at the manifest layer; accepting it here
        // would silently drop the axis for direct Sweep::from_json users
        let j = Json::parse(
            r#"{"id": "t", "bench": "fib", "threads": [2], "topos": ["x4600", "tile16"]}"#,
        )
        .unwrap();
        let err = format!("{:#}", Sweep::from_json(&j, &SweepDefaults::default()).unwrap_err());
        assert!(err.contains("ExperimentManifest"), "{err}");
    }

    #[test]
    fn unknown_sweep_keys_listed() {
        let j = Json::parse(r#"{"id": "g", "bench": "fib", "treads": [2]}"#).unwrap();
        let err = Sweep::from_json(&j, &SweepDefaults::default()).unwrap_err();
        assert!(format!("{err:#}").contains("treads"));
    }

    #[test]
    fn bad_axis_values_fail_at_load() {
        let j = Json::parse(r#"{"id": "g", "bench": "bogus_bench", "threads": [2]}"#).unwrap();
        // cells() validates lazily at run; from_json eagerly expands once
        let s = Sweep::from_json(&j, &SweepDefaults::default()).unwrap();
        assert!(s.cells().unwrap()[0].validate().is_err());
    }

    #[test]
    fn shard_plan_parses_the_cli_spelling() {
        let p = ShardPlan::parse("1/3").unwrap();
        assert_eq!(p, ShardPlan { index: 1, count: 3 });
        assert_eq!(p.name(), "1-of-3");
        assert_eq!(p.spec(), "1/3");
        assert!(!p.is_full());
        assert!(ShardPlan::parse("0/1").unwrap().is_full());
        for bad in ["", "3", "3/", "/3", "a/3", "1/b", "3/3", "4/3", "0/0", "1/-2"] {
            assert!(ShardPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
        assert_eq!(ShardPlan::full(), ShardPlan { index: 0, count: 1 });
    }

    #[test]
    fn shard_plans_partition_the_global_index_space() {
        // every global index is owned by exactly one shard, and the
        // per-shard totals match owned_of — including counts that do
        // not divide the cell total and counts exceeding it
        for count in [1usize, 2, 3, 7, 100] {
            let plans: Vec<ShardPlan> =
                (0..count).map(|i| ShardPlan::new(i, count).unwrap()).collect();
            let total = 52;
            let mut owned = vec![0usize; count];
            for g in 0..total {
                let owners: Vec<usize> =
                    (0..count).filter(|&i| plans[i].owns(g)).collect();
                assert_eq!(owners.len(), 1, "cell {g} at count {count}");
                owned[owners[0]] += 1;
            }
            for (i, plan) in plans.iter().enumerate() {
                assert_eq!(plan.owned_of(total), owned[i], "shard {i}/{count}");
            }
            assert_eq!(owned.iter().sum::<usize>(), total);
        }
        // the 52-cell examples manifest splits 18/17/17 at N=3
        assert_eq!(ShardPlan::new(0, 3).unwrap().owned_of(52), 18);
        assert_eq!(ShardPlan::new(1, 3).unwrap().owned_of(52), 17);
        assert_eq!(ShardPlan::new(2, 3).unwrap().owned_of(52), 17);
    }
}
