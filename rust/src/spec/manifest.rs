//! Experiment manifests: a JSON or TOML file describing named sweeps.
//!
//! ```toml
//! title = "thread sweep with a slow-DRAM ablation"
//!
//! [defaults]
//! size = "small"
//! topo = "x4600"
//! seeds = [1]
//!
//! [[sweeps]]
//! id = "stock-vs-numa"
//! bench = ["fft", "sort"]
//! sched = ["wf", "cilk"]
//! bind = ["linear", "numa"]
//! threads = [2, 8, 16]
//!
//! [[sweeps]]
//! id = "slow-dram"
//! bench = ["fft"]
//! configs = [["dfwspt", "numa"], ["dfwsrpt", "numa"]]
//! threads = [16]
//! [sweeps.cost]
//! dram_base_ns = 200
//! ```
//!
//! The same structure works in JSON (`{"title": …, "defaults": {…},
//! "sweeps": [{…}]}`); `numanos sweep --manifest <file>` picks the parser
//! by extension (`.toml` vs everything-else-is-JSON).  Scheduler entries
//! (in `sched` lists and `configs` pairs) are registry names, or objects
//! carrying parameters for parameterized strategies:
//! `{"name": "hops-threshold", "max_hops": 1}`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::Size;
use crate::serde::{toml, Json};
use crate::simnuma::MemSpec;
use crate::spec::sweep::{Sweep, SweepDefaults};
use crate::spec::{cost_from_json, RunSpec};

/// A named collection of sweeps loaded from one file.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentManifest {
    pub title: String,
    pub sweeps: Vec<Sweep>,
}

impl ExperimentManifest {
    /// Load from disk, picking the parser by file extension.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let root = if path.extension().and_then(|e| e.to_str()) == Some("toml") {
            toml::parse(&text).with_context(|| format!("parsing TOML {}", path.display()))?
        } else {
            Json::parse(&text).with_context(|| format!("parsing JSON {}", path.display()))?
        };
        Self::from_json(&root).with_context(|| format!("manifest {}", path.display()))
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        Self::from_json(&toml::parse(text)?)
    }

    pub fn from_json(root: &Json) -> Result<Self> {
        let obj = root.as_obj().context("manifest must be an object")?;
        let mut title = String::new();
        let mut defaults = SweepDefaults::default();
        let mut sweeps_json: Option<&[Json]> = None;
        let mut unknown = Vec::new();
        for (key, val) in obj {
            match key.as_str() {
                "title" => title = val.as_str().context("title must be a string")?.to_string(),
                "defaults" => defaults = parse_defaults(val)?,
                "sweeps" => {
                    sweeps_json = Some(val.as_arr().context("sweeps must be an array")?)
                }
                _ => unknown.push(key.clone()),
            }
        }
        if !unknown.is_empty() {
            bail!(
                "unknown manifest key(s): {} (allowed: title defaults sweeps)",
                unknown.join(", ")
            );
        }
        let sweeps_json = sweeps_json.context("manifest missing 'sweeps'")?;
        if sweeps_json.is_empty() {
            bail!("manifest has an empty 'sweeps' list");
        }
        let mut sweeps = Vec::with_capacity(sweeps_json.len());
        let mut seen_ids = Vec::new();
        for (i, sj) in sweeps_json.iter().enumerate() {
            for sweep in
                expand_topos(sj, &defaults).with_context(|| format!("sweeps[{i}]"))?
            {
                if seen_ids.contains(&sweep.id) {
                    bail!("duplicate sweep id '{}'", sweep.id);
                }
                seen_ids.push(sweep.id.clone());
                sweeps.push(sweep);
            }
        }
        Ok(Self { title, sweeps })
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("title", Json::from(self.title.as_str())),
            ("sweeps", Json::Arr(self.sweeps.iter().map(Sweep::to_json).collect())),
        ])
    }

    /// Every cell across every sweep (validated), for sizing/reporting.
    pub fn all_cells(&self) -> Result<Vec<RunSpec>> {
        let mut out = Vec::new();
        for s in &self.sweeps {
            out.extend(s.cells()?);
        }
        Ok(out)
    }
}

/// A sweep with a `"topos": [...]` list expands into one sweep per
/// topology, ids suffixed `-<topo>` — the grid form of "same experiment
/// across fabrics" without copy-pasting the sweep body.
fn expand_topos(sj: &Json, defaults: &SweepDefaults) -> Result<Vec<Sweep>> {
    let topos = match sj.get("topos") {
        None => return Ok(vec![Sweep::from_json(sj, defaults)?]),
        Some(v) => v
            .as_arr()
            .context("'topos' must be an array of topology names")?
            .iter()
            .map(|t| {
                t.as_str()
                    .map(str::to_string)
                    .context("'topos' entries must be strings")
            })
            .collect::<Result<Vec<String>>>()?,
    };
    if topos.is_empty() {
        bail!("'topos' has no entries");
    }
    if sj.get("topo").is_some() {
        bail!("a sweep takes either 'topo' or 'topos', not both");
    }
    // strip the manifest-level key: `Sweep::from_json` rejects 'topos'
    // so direct spec-layer callers can't silently lose the axis
    let stripped = {
        let mut obj = sj.as_obj().context("sweep must be an object")?.clone();
        obj.remove("topos");
        Json::Obj(obj)
    };
    let mut out = Vec::with_capacity(topos.len());
    for topo in &topos {
        let mut d = defaults.clone();
        d.topo = topo.clone();
        let mut sweep = Sweep::from_json(&stripped, &d)?;
        sweep.id = format!("{}-{topo}", sweep.id);
        out.push(sweep);
    }
    Ok(out)
}

fn parse_defaults(v: &Json) -> Result<SweepDefaults> {
    let obj = v.as_obj().context("defaults must be an object")?;
    let mut d = SweepDefaults::default();
    let mut unknown = Vec::new();
    for (key, val) in obj {
        match key.as_str() {
            "size" => d.size = Size::from_name(val.as_str().context("defaults.size")?)?,
            "topo" => d.topo = val.as_str().context("defaults.topo")?.to_string(),
            "threads" => {
                d.threads = val
                    .as_arr()
                    .context("defaults.threads must be an array")?
                    .iter()
                    .map(|t| t.as_usize().context("defaults.threads entries"))
                    .collect::<Result<_>>()?
            }
            "seeds" | "seed" => {
                d.seeds = crate::spec::sweep::num_list(val, "defaults.seeds")?
            }
            "mem" | "mems" => {
                let mems = val
                    .as_arr()
                    .map(|items| items.iter().map(MemSpec::from_json).collect::<Result<Vec<_>>>())
                    .unwrap_or_else(|| Ok(vec![MemSpec::from_json(val)?]))?;
                d.mems = mems;
            }
            "cost" => d.cost = cost_from_json(val)?,
            _ => unknown.push(key.clone()),
        }
    }
    if !unknown.is_empty() {
        bail!(
            "unknown defaults key(s): {} (allowed: size topo threads seeds mem cost)",
            unknown.join(", ")
        );
    }
    Ok(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::sched::{Policy, SchedSpec};

    const JSON: &str = r#"{
      "title": "demo",
      "defaults": {"size": "small", "seeds": [1, 2]},
      "sweeps": [
        {"id": "a", "bench": "fib", "sched": ["wf"], "bind": ["linear", "numa"],
         "threads": [2, 4]},
        {"id": "b", "bench": ["fft"], "configs": [["dfwspt", "numa"]],
         "threads": [8], "seed": 9, "cost": {"dram_base_ns": 120}}
      ]
    }"#;

    const TOML: &str = "\
title = \"demo\"\n\
\n\
[defaults]\n\
size = \"small\"\n\
seeds = [1, 2]\n\
\n\
[[sweeps]]\n\
id = \"a\"\n\
bench = \"fib\"\n\
sched = [\"wf\"]\n\
bind = [\"linear\", \"numa\"]\n\
threads = [2, 4]\n\
\n\
[[sweeps]]\n\
id = \"b\"\n\
bench = [\"fft\"]\n\
configs = [[\"dfwspt\", \"numa\"]]\n\
threads = [8]\n\
seed = 9\n\
\n\
[sweeps.cost]\n\
dram_base_ns = 120\n\
";

    #[test]
    fn json_manifest_parses() {
        let m = ExperimentManifest::from_json_str(JSON).unwrap();
        assert_eq!(m.title, "demo");
        assert_eq!(m.sweeps.len(), 2);
        let a = &m.sweeps[0];
        assert_eq!(a.size, Size::Small, "defaults apply");
        assert_eq!(a.seeds, vec![1, 2], "defaults apply");
        assert_eq!(a.configs.len(), 2);
        let b = &m.sweeps[1];
        assert_eq!(b.seeds, vec![9], "sweep overrides defaults");
        assert_eq!(b.configs, vec![(SchedSpec::stock(Policy::Dfwspt), BindPolicy::NumaAware)]);
        assert_eq!(b.cost, vec![("dram_base_ns".to_string(), 120.0)]);
        assert_eq!(m.all_cells().unwrap().len(), 8 + 1, "2 configs × 2 seeds × 2 threads, + 1");
    }

    #[test]
    fn toml_and_json_manifests_agree() {
        let j = ExperimentManifest::from_json_str(JSON).unwrap();
        let t = ExperimentManifest::from_toml_str(TOML).unwrap();
        assert_eq!(j, t);
    }

    #[test]
    fn manifest_roundtrips_through_its_own_json() {
        let m = ExperimentManifest::from_json_str(JSON).unwrap();
        let back = ExperimentManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn parameterized_scheduler_manifests_parse() {
        let m = ExperimentManifest::from_json_str(
            r#"{
              "title": "param",
              "sweeps": [
                {"id": "near", "bench": "fib", "threads": [2], "size": "small",
                 "sched": [{"name": "hops-threshold", "max_hops": 1}, "adaptive"],
                 "bind": ["numa"]}
              ]
            }"#,
        )
        .unwrap();
        let s = &m.sweeps[0];
        assert_eq!(s.configs.len(), 2);
        assert_eq!(s.configs[0].0.name_sig(), "hops-threshold(max_hops=1)");
        assert_eq!(s.configs[1].0, SchedSpec::new("adaptive"));
        // unknown parameter names fail at manifest load, not at run time
        let bad = r#"{"sweeps": [{"id": "x", "bench": "fib",
            "sched": [{"name": "hops-threshold", "max_hopps": 1}]}]}"#;
        let err = format!("{:#}", ExperimentManifest::from_json_str(bad).unwrap_err());
        assert!(err.contains("max_hopps"), "{err}");
    }

    #[test]
    fn topos_expand_into_one_sweep_per_fabric() {
        let m = ExperimentManifest::from_json_str(
            r#"{
              "title": "fabrics",
              "sweeps": [
                {"id": "grid", "bench": "fib", "sched": ["wf"], "bind": ["numa"],
                 "threads": [2], "size": "small", "topos": ["x4600", "tile16", "altix16"]}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(m.sweeps.len(), 3);
        assert_eq!(m.sweeps[0].id, "grid-x4600");
        assert_eq!(m.sweeps[0].topo, "x4600");
        assert_eq!(m.sweeps[1].id, "grid-tile16");
        assert_eq!(m.sweeps[1].topo, "tile16");
        assert_eq!(m.sweeps[2].topo, "altix16");
        // topo + topos together is ambiguous
        let bad = r#"{"sweeps": [{"id": "x", "bench": "fib", "topo": "dual",
                                  "topos": ["x4600"]}]}"#;
        let err = format!("{:#}", ExperimentManifest::from_json_str(bad).unwrap_err());
        assert!(err.contains("not both"), "{err}");
        let empty = r#"{"sweeps": [{"id": "x", "bench": "fib", "topos": []}]}"#;
        assert!(ExperimentManifest::from_json_str(empty).is_err());
    }

    #[test]
    fn mem_defaults_flow_into_sweeps() {
        let m = ExperimentManifest::from_json_str(
            r#"{
              "title": "mem defaults",
              "defaults": {"size": "small", "mem": ["first-touch", "interleave"]},
              "sweeps": [
                {"id": "a", "bench": "fib", "sched": ["wf"], "threads": [2]},
                {"id": "b", "bench": "fib", "sched": ["wf"], "threads": [2],
                 "mem": "bind"}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(m.sweeps[0].mems.len(), 2, "defaults apply");
        assert_eq!(m.sweeps[1].mems, vec![MemSpec::new("bind")], "sweep overrides");
        assert_eq!(m.all_cells().unwrap().len(), 2 + 1);
    }

    #[test]
    fn errors_name_the_problem() {
        assert!(ExperimentManifest::from_json_str("{}").unwrap_err().to_string().contains("sweeps"));
        let dup = r#"{"sweeps": [{"id": "x", "bench": "fib"}, {"id": "x", "bench": "fib"}]}"#;
        assert!(format!("{:#}", ExperimentManifest::from_json_str(dup).unwrap_err())
            .contains("duplicate"));
        let unk = r#"{"sweeps": [{"id": "x", "bench": "fib"}], "extra": 1}"#;
        assert!(format!("{:#}", ExperimentManifest::from_json_str(unk).unwrap_err())
            .contains("extra"));
    }
}
