//! The [`Session`]: the stateful executor behind every run.
//!
//! A session owns a base cost model, a cache of configured [`Runtime`]s
//! (one per topology × cost-override combination a spec names), and a
//! memo of **serial baselines** — the paper's speedup denominators — keyed
//! by (bench, size, seed, topology, cost).  The four copies of
//! serial-baseline + `bots::create` boilerplate that used to live in
//! `cmd_run`, `run_figure`, `gains_summary` and `bench_figure_main` all
//! collapse into [`Session::baseline`].
//!
//! The low-level execution sequence (the NANOS start-up the paper
//! modifies: bind → per-thread runtime pages → first-touch init → engine)
//! lives here as [`Session::execute`] / [`Session::execute_bound`];
//! `Runtime::{run,run_bound,run_serial}` are thin shims over these.
//!
//! Sweeps execute their cells across OS threads ([`Session::run_sweep`]):
//! every cell is an independent, deterministic simulation whose seed comes
//! from its [`RunSpec`], so a parallel sweep produces byte-identical
//! CSV/tables to a sequential one ([`Session::run_sweep_with`] with
//! `workers = 1`).  [`Session::run_sweep_sharded`] extends the same
//! contract across *processes*: a [`ShardPlan`] partitions the flattened
//! cell sequence, and the shared result store is the merge substrate
//! (`crate::store::shard`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::bots;
use crate::config::ComputeMode;
use crate::coordinator::binding::{bind_threads, BindPolicy, Binding};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::runtime::Runtime;
use crate::coordinator::sched::{self, build_victim_lists, Policy, Scheduler};
use crate::coordinator::task::Workload;
use crate::metrics::RunStats;
use crate::runtime::ExecEngine;
use crate::serde::Json;
use crate::simnuma::{CostModel, MemSim, MemSpec, PAGE_BYTES};
use crate::spec::sweep::{ShardPlan, Sweep, SweepResult};
use crate::spec::{BindSpec, RunSpec};
use crate::store::ResultStore;
use crate::topology::Topology;
use crate::util::{SplitMix64, Time};

/// One executed spec: the input, the full stats, and the speedup against
/// the session's memoized serial baseline.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub spec: RunSpec,
    /// Makespan of the serial baseline this cell is normalized against.
    pub serial_makespan: Time,
    /// serial makespan / this makespan (the paper's metric).
    pub speedup: f64,
    pub stats: RunStats,
}

impl RunRecord {
    /// Paper-legend config label (`wf-Scheduler-NUMA`; explicit-core
    /// pinnings get `-pinned`).  Derived from the spec, not the stats:
    /// `execute_bound` leaves `stats.bind` unset, which would mislabel a
    /// pinned run as a linear one.
    pub fn label(&self) -> String {
        self.spec.label()
    }

    /// Long-form CSV header matching [`RunRecord::to_csv_row`].  The
    /// placement refactor added the `mem` axis column (after `bind`) and
    /// the placement counters at the tail; the steal-bias/homed-resume
    /// refactor appended `affine_steals` and `homed_resumes`; the
    /// steal-half/mailbox refactor appended `batch_steals`,
    /// `tasks_migrated` and `mailbox_hits`.  Every pre-existing column
    /// keeps its name, order and formatting.
    pub const CSV_HEADER: &'static str = "bench,size,policy,bind,mem,threads,topo,seed,\
         makespan,serial_makespan,speedup,tasks,steals,steal_hops,remote_pct,\
         lock_wait,work,overhead,sim_events,pushed_home,affinity_hits,migrated_pages,\
         affine_steals,homed_resumes,batch_steals,tasks_migrated,mailbox_hits";

    /// Deterministic CSV row (no host wall-clock — parallel and sequential
    /// sweep output must be byte-identical).
    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{:.4},{},{},{:.3},{:.4},{},{},{},{},{},{},{},{},{},{},{},{}",
            self.spec.bench,
            self.spec.size.name(),
            self.spec.sched.name_sig(),
            self.spec.bind.name(),
            self.spec.mem.name_sig(),
            self.spec.threads,
            self.spec.topo,
            self.spec.seed,
            self.stats.makespan,
            self.serial_makespan,
            self.speedup,
            self.stats.tasks,
            self.stats.steals,
            self.stats.mean_steal_hops,
            100.0 * self.stats.mem.remote_ratio(),
            self.stats.lock_wait_total,
            self.stats.work_time,
            self.stats.overhead_time,
            self.stats.sim_events,
            self.stats.pushed_home,
            self.stats.affinity_hits,
            self.stats.mem.migrated_pages,
            self.stats.affine_steals,
            self.stats.homed_resumes,
            self.stats.batch_steals,
            self.stats.tasks_migrated,
            self.stats.mailbox_hits,
        )
    }

    /// Deterministic JSON record (same field policy as the CSV).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("spec", self.spec.to_json()),
            ("label", Json::from(self.label())),
            ("makespan", Json::from(self.stats.makespan)),
            ("serial_makespan", Json::from(self.serial_makespan)),
            ("speedup", Json::from(self.speedup)),
            ("tasks", Json::from(self.stats.tasks)),
            ("peak_live", Json::from(self.stats.peak_live)),
            ("steals", Json::from(self.stats.steals)),
            ("steal_hops", Json::from(self.stats.mean_steal_hops)),
            ("remote_pct", Json::from(100.0 * self.stats.mem.remote_ratio())),
            ("lock_wait", Json::from(self.stats.lock_wait_total)),
            ("work", Json::from(self.stats.work_time)),
            ("overhead", Json::from(self.stats.overhead_time)),
            ("sim_events", Json::from(self.stats.sim_events)),
            ("kernel_calls", Json::from(self.stats.kernel_calls)),
            ("pushed_home", Json::from(self.stats.pushed_home)),
            ("affinity_hits", Json::from(self.stats.affinity_hits)),
            ("migrated_pages", Json::from(self.stats.mem.migrated_pages)),
            ("affine_steals", Json::from(self.stats.affine_steals)),
            ("homed_resumes", Json::from(self.stats.homed_resumes)),
            ("batch_steals", Json::from(self.stats.batch_steals)),
            ("tasks_migrated", Json::from(self.stats.tasks_migrated)),
            ("mailbox_hits", Json::from(self.stats.mailbox_hits)),
        ])
    }
}

/// Worker count for parallel sweep execution.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One shard's slice of an executed sweep (see [`ShardPlan`]): the owned
/// records in cell order, the canonical store identities of the owned
/// cells (the shard completion marker's payload), and how many cells were
/// skipped as other shards' property.
pub struct ShardOutcome {
    pub result: SweepResult,
    /// `crate::store::cell_identity` of every owned cell, in cell order.
    pub owned_ids: Vec<String>,
    pub skipped: usize,
}

/// Stateful executor: runtime cache + serial-baseline memo + optional
/// persistent result store.
pub struct Session {
    base_cost: CostModel,
    /// "{topo}|{cost_sig}" → configured runtime.
    runtimes: Mutex<HashMap<String, Arc<Runtime>>>,
    /// [`crate::store::baseline_identity`] → serial baseline stats.  The
    /// key is the canonical six-component baseline identity (bench, size,
    /// seed, topo, mem signature, cost signature) shared with the on-disk
    /// store, so the memo and the store can never drift apart.
    baselines: Mutex<HashMap<String, Arc<RunStats>>>,
    /// Persistent content-addressed result store (write-through always;
    /// read-through unless `store_read` is off, the `--no-cache` mode).
    store: Option<Arc<ResultStore>>,
    store_read: bool,
}

impl Default for Session {
    fn default() -> Self {
        Self::new()
    }
}

impl Session {
    /// Session over the default (paper-calibrated) cost model.
    pub fn new() -> Self {
        Self::with_cost(CostModel::default())
    }

    /// Session whose specs' cost overrides apply on top of `cost`.
    pub fn with_cost(cost: CostModel) -> Self {
        Self {
            base_cost: cost,
            runtimes: Mutex::new(HashMap::new()),
            baselines: Mutex::new(HashMap::new()),
            store: None,
            store_read: true,
        }
    }

    /// Attach a persistent result store.  Executed cells and baselines
    /// are always written through; `read_through = false` is the
    /// `--no-cache` mode — every cell re-executes, but the store is still
    /// refreshed.
    pub fn set_store(&mut self, store: Arc<ResultStore>, read_through: bool) {
        self.store = Some(store);
        self.store_read = read_through;
    }

    /// The attached result store, if any (its counters are the sweep
    /// summaries' cache_hits/misses/writes source).
    pub fn store(&self) -> Option<&Arc<ResultStore>> {
        self.store.as_ref()
    }

    /// Whether the store will answer this spec without execution: read
    /// through is on, the spec is cacheable, and a record exists.  A
    /// cheap existence probe — the record may still fail validation on
    /// load, in which case [`Session::run`] falls back to executing.
    fn store_answers(&self, spec: &RunSpec) -> bool {
        self.store_read
            && crate::store::cacheable(spec)
            && self.store.as_ref().is_some_and(|s| s.contains_cell(spec))
    }

    /// Adopt an existing configured runtime (its cost model becomes the
    /// session base; its topology is cached under its name so specs can
    /// reference it even if it is not a preset).
    pub fn from_runtime(rt: &Runtime) -> Self {
        let s = Self::with_cost(rt.cost.clone());
        s.runtimes
            .lock()
            .unwrap()
            .insert(format!("{}|", rt.topo.name()), Arc::new(rt.clone()));
        s
    }

    fn topology_for(&self, name: &str) -> Result<Topology> {
        if let Some(rt) = self.runtimes.lock().unwrap().get(&format!("{name}|")) {
            return Ok(rt.topo.clone());
        }
        Topology::by_name(name)
    }

    /// The configured runtime a spec executes on (cached).
    pub fn runtime_for(&self, spec: &RunSpec) -> Result<Arc<Runtime>> {
        let key = format!("{}|{}", spec.topo, spec.cost_sig());
        if let Some(rt) = self.runtimes.lock().unwrap().get(&key) {
            return Ok(rt.clone());
        }
        let topo = self.topology_for(&spec.topo)?;
        let cost = spec.cost_model(&self.base_cost)?;
        let rt = Arc::new(Runtime::new(topo, cost));
        Ok(self.runtimes.lock().unwrap().entry(key).or_insert(rt).clone())
    }

    /// Validate a spec against the session's topology view (which may
    /// include adopted non-preset topologies).
    fn validate_spec(&self, spec: &RunSpec) -> Result<()> {
        let topo = self
            .topology_for(&spec.topo)
            .with_context(|| format!("spec '{}'", spec.describe()))?;
        spec.validate_against(&topo)
    }

    /// The serial baseline for a spec's (bench, size, seed, topo, mem,
    /// cost) — computed once, shared by every cell normalizing against
    /// it.  The baseline runs under the spec's page policy: a placement
    /// sweep compares schedulers against a serial denominator that paid
    /// the same allocation behaviour.
    pub fn baseline(&self, spec: &RunSpec) -> Result<Arc<RunStats>> {
        let key = crate::store::baseline_identity(spec);
        if let Some(b) = self.baselines.lock().unwrap().get(&key) {
            return Ok(b.clone());
        }
        // Read through the persistent store before simulating: a cached
        // sweep's denominators come from disk, not a serial re-run.
        if self.store_read && crate::store::cacheable(spec) {
            if let Some(stats) = self.store.as_ref().and_then(|s| s.load_baseline(spec)) {
                let arc = Arc::new(stats);
                return Ok(self.baselines.lock().unwrap().entry(key).or_insert(arc).clone());
            }
        }
        let rt = self.runtime_for(spec)?;
        let mut w = bots::create(&spec.bench, spec.size, spec.seed)?;
        let mut rng = SplitMix64::new(spec.seed);
        let binding = bind_threads(&rt.topo, 1, BindPolicy::Linear, &mut rng);
        let mut stats = Self::execute_bound_placed(
            &rt,
            w.as_mut(),
            sched::stock(Policy::Serial).as_ref(),
            &binding.cores,
            false,
            &spec.mem,
            spec.seed,
            None,
        )?;
        stats.bind = Some(BindPolicy::Linear);
        if crate::store::cacheable(spec) {
            if let Some(store) = &self.store {
                store.store_baseline(spec, &stats)?;
            }
        }
        let arc = Arc::new(stats);
        Ok(self.baselines.lock().unwrap().entry(key).or_insert(arc).clone())
    }

    /// Execute one spec: create the workload, build the scheduler from
    /// the registry, run it, normalize against the memoized serial
    /// baseline.
    pub fn run(&self, spec: &RunSpec) -> Result<RunRecord> {
        self.validate_spec(spec)?;
        // Read through the result store first — a hit is a finished cell
        // (label-normalized, speedup recomputed) with zero engine work,
        // before even the baseline is consulted.
        if self.store_read && crate::store::cacheable(spec) {
            if let Some(rec) = self.store.as_ref().and_then(|s| s.load_cell(spec)) {
                return Ok(rec);
            }
        }
        let rt = self.runtime_for(spec)?;
        let baseline = self.baseline(spec)?;
        let mut workload = bots::create(&spec.bench, spec.size, spec.seed)?;
        let sched = sched::build(&spec.sched)?;
        let mut exec = match spec.compute {
            ComputeMode::Pjrt => Some(ExecEngine::cpu(&spec.artifact_dir)?),
            ComputeMode::Sim => None,
        };
        let mut stats = match &spec.bind {
            BindSpec::Policy(bind) => Self::execute_placed(
                &rt,
                workload.as_mut(),
                sched.as_ref(),
                *bind,
                spec.threads,
                &spec.mem,
                spec.seed,
                exec.as_mut(),
            )?,
            BindSpec::Cores(cores) => Self::execute_bound_placed(
                &rt,
                workload.as_mut(),
                sched.as_ref(),
                cores,
                spec.rtdata_local,
                &spec.mem,
                spec.seed,
                exec.as_mut(),
            )?,
        };
        // Normalize to the spec-level signature (overrides only) so run
        // summaries, sweep tables and CSV all label one configuration
        // identically; the raw execute_with paths — which have no spec —
        // keep the engine's fully-resolved Scheduler::signature().
        stats.sched = spec.sched.name_sig();
        let record = RunRecord {
            spec: spec.clone(),
            serial_makespan: baseline.makespan,
            speedup: baseline.makespan as f64 / stats.makespan as f64,
            stats,
        };
        if crate::store::cacheable(spec) {
            if let Some(store) = &self.store {
                store.store_cell(&record)?;
            }
        }
        Ok(record)
    }

    /// Run a sweep's cells in parallel across OS threads (deterministic:
    /// identical output to [`Session::run_sweep_with`] at `workers = 1`).
    pub fn run_sweep(&self, sweep: &Sweep) -> Result<SweepResult> {
        self.run_sweep_with(sweep, default_workers())
    }

    /// Run a sweep with an explicit worker count (1 = sequential).
    pub fn run_sweep_with(&self, sweep: &Sweep, workers: usize) -> Result<SweepResult> {
        Ok(self.run_sweep_sharded(sweep, workers, ShardPlan::full(), 0)?.result)
    }

    /// Run only the cells of `sweep` that `plan` owns.  `base` is the
    /// global index of this sweep's first cell within the manifest's
    /// flattened cell sequence (0 for a standalone sweep); ownership is
    /// decided on global indices, so a manifest's shards agree on the
    /// partition regardless of where sweep boundaries fall.  Every cell —
    /// owned or skipped — is still validated: a shard must not succeed on
    /// a manifest another shard will reject.
    pub fn run_sweep_sharded(
        &self,
        sweep: &Sweep,
        workers: usize,
        plan: ShardPlan,
        base: usize,
    ) -> Result<ShardOutcome> {
        let all = sweep.cells()?;
        for spec in &all {
            self.validate_spec(spec)?;
        }
        let mut cells = Vec::with_capacity(plan.owned_of(base + all.len()));
        let mut owned_ids = Vec::with_capacity(cells.capacity());
        for (i, spec) in all.iter().enumerate() {
            if plan.owns(base + i) {
                owned_ids.push(crate::store::cell_identity(spec)?);
                cells.push(spec.clone());
            }
        }
        let skipped = all.len() - cells.len();
        // Pre-compute the distinct baselines sequentially so parallel
        // workers only read the memo (and no baseline is computed twice).
        // Cells the store will answer skip this — their records carry the
        // serial makespan, so a fully cached sweep does zero engine runs.
        // (If a record then fails validation on load, `run` falls back to
        // executing and computes the baseline lazily under the memo lock —
        // deterministic, just not pre-shared.)
        for spec in &cells {
            if self.store_answers(spec) {
                continue;
            }
            self.baseline(spec)?;
        }
        let n = cells.len();
        let records: Vec<RunRecord> = if workers <= 1 || n <= 1 {
            cells.iter().map(|s| self.run(s)).collect::<Result<_>>()?
        } else {
            let next = AtomicUsize::new(0);
            let done: Mutex<Vec<(usize, Result<RunRecord>)>> = Mutex::new(Vec::with_capacity(n));
            std::thread::scope(|scope| {
                for _ in 0..workers.min(n) {
                    scope.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = self.run(&cells[i]);
                        done.lock().unwrap().push((i, r));
                    });
                }
            });
            let mut slots = done.into_inner().unwrap();
            slots.sort_by_key(|(i, _)| *i);
            slots.into_iter().map(|(_, r)| r).collect::<Result<_>>()?
        };
        Ok(ShardOutcome {
            result: SweepResult { sweep: sweep.clone(), records },
            owned_ids,
            skipped,
        })
    }

    // -----------------------------------------------------------------
    // The canonical low-level execution sequence (previously
    // Runtime::{run,run_bound}; those are now shims over these).
    // -----------------------------------------------------------------

    /// Execute `workload` under a stock `policy` (legacy-shim form of
    /// [`Session::execute_with`]).
    pub fn execute(
        rt: &Runtime,
        workload: &mut dyn Workload,
        policy: Policy,
        bind: BindPolicy,
        threads: usize,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        Self::execute_with(rt, workload, sched::stock(policy).as_ref(), bind, threads, seed, exec)
    }

    /// Execute `workload` under `sched`/`bind` with `threads` threads on
    /// `rt`, resolving the thread→core binding from the §IV policy
    /// (first-touch shim over [`Session::execute_placed`]).
    pub fn execute_with(
        rt: &Runtime,
        workload: &mut dyn Workload,
        sched: &dyn Scheduler,
        bind: BindPolicy,
        threads: usize,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        Self::execute_placed(rt, workload, sched, bind, threads, &MemSpec::default(), seed, exec)
    }

    /// Like [`Session::execute_with`], but placing pages under `mem`'s
    /// page policy.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_placed(
        rt: &Runtime,
        workload: &mut dyn Workload,
        sched: &dyn Scheduler,
        bind: BindPolicy,
        threads: usize,
        mem: &MemSpec,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        let mut rng = SplitMix64::new(seed);
        let binding = bind_threads(&rt.topo, threads, bind, &mut rng);
        let numa_rtdata = bind == BindPolicy::NumaAware;
        let mut stats = Self::execute_bound_placed(
            rt,
            workload,
            sched,
            &binding.cores,
            numa_rtdata,
            mem,
            seed,
            exec,
        )?;
        stats.bind = Some(bind);
        Ok(stats)
    }

    /// Explicit-binding legacy shim over [`Session::execute_bound_with`].
    pub fn execute_bound(
        rt: &Runtime,
        workload: &mut dyn Workload,
        policy: Policy,
        cores: &[usize],
        numa_rtdata: bool,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        Self::execute_bound_with(
            rt,
            workload,
            sched::stock(policy).as_ref(),
            cores,
            numa_rtdata,
            seed,
            exec,
        )
    }

    /// Explicit-binding first-touch shim over
    /// [`Session::execute_bound_placed`].
    pub fn execute_bound_with(
        rt: &Runtime,
        workload: &mut dyn Workload,
        sched: &dyn Scheduler,
        cores: &[usize],
        numa_rtdata: bool,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        Self::execute_bound_placed(
            rt,
            workload,
            sched,
            cores,
            numa_rtdata,
            &MemSpec::default(),
            seed,
            exec,
        )
    }

    /// Execute with an explicit thread→core binding (thread 0 = master).
    /// `numa_rtdata` controls whether per-thread runtime pages are touched
    /// locally (§IV) or all by the master; `mem` selects the page
    /// policy.  This is the ablation surface: any placement heuristic —
    /// and any registered scheduler — can be fed in.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_bound_placed(
        rt: &Runtime,
        workload: &mut dyn Workload,
        sched: &dyn Scheduler,
        cores: &[usize],
        numa_rtdata: bool,
        mem_spec: &MemSpec,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        let wall_start = std::time::Instant::now();
        let threads = cores.len();
        let binding = Binding { cores: cores.to_vec(), priorities: None };
        let policy = mem_spec.build(rt.topo.num_nodes())?;
        let mut mem = MemSim::with_policy(rt.topo.clone(), rt.cost.clone(), policy);

        // Per-thread runtime data (pools, descriptors): one page each.
        // Baseline: the master first-touches everything (all pages land on
        // its node). NUMA-aware: each thread touches its own page from its
        // own core at start-up.
        let mut rt_penalty: Vec<Time> = Vec::with_capacity(threads);
        for t in 0..threads {
            let region = mem.alloc(PAGE_BYTES);
            let toucher = if numa_rtdata { binding.cores[t] } else { binding.master_core() };
            mem.first_touch(toucher, region, 0);
            let data_node = mem.node_of_addr(region.addr).expect("rt page resident");
            let worker_node = rt.topo.node_of(binding.cores[t]);
            let hops = rt.topo.node_hops(worker_node, data_node) as Time;
            rt_penalty.push(hops * rt.cost.rtdata_per_hop);
        }

        // Master-side workload init: allocations + first touches.
        let init_time = workload.init(&mut mem, binding.master_core());

        let victims = build_victim_lists(&rt.topo, &binding.cores);
        let root = workload.root();
        let engine = Engine::new(
            EngineConfig { cores: binding.cores.clone(), rt_penalty, seed },
            mem,
            victims,
            sched,
            workload,
            exec,
        );
        let mut stats = engine.run(root)?;
        stats.bench = workload.name().to_string();
        stats.seed = seed;
        stats.init_time = init_time;
        stats.wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(bench: &str, policy: Policy, threads: usize) -> RunSpec {
        RunSpec::builder()
            .bench(bench)
            .size(crate::config::Size::Small)
            .policy(policy)
            .numa()
            .threads(threads)
            .seed(5)
            .build()
            .unwrap()
    }

    #[test]
    fn run_normalizes_against_serial_baseline() {
        let session = Session::new();
        let rec = session.run(&small("fib", Policy::WorkFirst, 8)).unwrap();
        assert!(rec.speedup > 1.0, "8 threads must beat serial, got {}", rec.speedup);
        assert_eq!(rec.stats.threads, 8);
        assert_eq!(rec.label(), "wf-Scheduler-NUMA");
    }

    #[test]
    fn baseline_is_memoized() {
        let session = Session::new();
        let spec = small("fib", Policy::WorkFirst, 4);
        let a = session.baseline(&spec).unwrap();
        let b = session.baseline(&spec).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the memo");
        // different seed → different baseline entry
        let mut other = spec.clone();
        other.seed = 6;
        let c = session.baseline(&other).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn runtime_cache_distinguishes_cost_overrides() {
        let session = Session::new();
        let plain = small("fib", Policy::WorkFirst, 2);
        let mut tweaked = plain.clone();
        tweaked.cost.push(("dram_base_ns".into(), 500.0));
        let a = session.runtime_for(&plain).unwrap();
        let b = session.runtime_for(&tweaked).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(b.cost.dram_base > a.cost.dram_base);
    }

    #[test]
    fn explicit_cores_run() {
        let session = Session::new();
        let spec = RunSpec::builder()
            .bench("fib")
            .size(crate::config::Size::Small)
            .cores(vec![4, 5, 6, 7])
            .seed(3)
            .build()
            .unwrap();
        let rec = session.run(&spec).unwrap();
        assert_eq!(rec.stats.threads, 4);
        assert!(rec.stats.makespan > 0);
    }

    #[test]
    fn records_are_deterministic() {
        let session = Session::new();
        let spec = small("sort", Policy::Dfwsrpt, 8);
        let a = session.run(&spec).unwrap();
        let b = session.run(&spec).unwrap();
        assert_eq!(a.stats.makespan, b.stats.makespan);
        assert_eq!(a.to_csv_row(), b.to_csv_row());
        assert_eq!(a.to_json().to_compact(), b.to_json().to_compact());
    }

    #[test]
    fn sharded_sweep_slices_union_to_the_full_sweep() {
        let session = Session::new();
        let sweep = Sweep::new("slice", "slice")
            .with_bench("fib")
            .with_config(Policy::WorkFirst, BindPolicy::NumaAware)
            .with_config(Policy::Dfwsrpt, BindPolicy::NumaAware)
            .with_threads(vec![2, 4])
            .with_seed(5)
            .with_size(crate::config::Size::Small);
        let full = session.run_sweep_with(&sweep, 2).unwrap();
        assert_eq!(full.records.len(), 4);
        // shard at K=3 with a non-zero base offset, reassemble by global
        // index, and compare row-for-row against the full run
        let mut rows: Vec<Option<String>> = vec![None; full.records.len()];
        for i in 0..3 {
            let plan = ShardPlan::new(i, 3).unwrap();
            let out = session.run_sweep_sharded(&sweep, 1, plan, 10).unwrap();
            assert_eq!(out.result.records.len() + out.skipped, 4);
            assert_eq!(out.owned_ids.len(), out.result.records.len());
            let mut it = out.result.records.iter();
            for (g, slot) in rows.iter_mut().enumerate() {
                if plan.owns(10 + g) {
                    assert!(slot.is_none(), "cell {g} owned twice");
                    *slot = Some(it.next().unwrap().to_csv_row());
                }
            }
            assert!(it.next().is_none(), "shard {i} ran cells it does not own");
        }
        for (g, row) in rows.iter().enumerate() {
            assert_eq!(
                row.as_deref(),
                Some(full.records[g].to_csv_row().as_str()),
                "cell {g}"
            );
        }
    }

    #[test]
    fn session_adopts_custom_runtime() {
        let rt = Runtime::paper_testbed();
        let session = Session::from_runtime(&rt);
        let rec = session.run(&small("fib", Policy::WorkFirst, 2)).unwrap();
        assert_eq!(rec.spec.topo, "x4600");
        assert!(rec.stats.makespan > 0);
    }
}
