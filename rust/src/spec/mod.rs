//! Declarative run specifications — the experiment API.
//!
//! The paper's evaluation is a grid of (benchmark × scheduler × binding ×
//! threads × topology) runs.  This module makes that grid *data*:
//!
//! * [`RunSpec`] — one fully-described run, buildable fluently
//!   (`RunSpec::builder().bench("fft").policy(Policy::Dfwspt).numa()
//!   .threads(16).build()?`), validated eagerly, and (de)serializable
//!   to/from JSON and TOML through [`crate::serde`];
//! * [`Session`](session::Session) — owns runtimes and memoized serial
//!   baselines, executes single specs and whole sweeps (cells in parallel
//!   across OS threads, deterministically);
//! * [`Sweep`](sweep::Sweep) — a cross-product of spec axes (the paper
//!   figures are sweeps, not launch code);
//! * [`ExperimentManifest`](manifest::ExperimentManifest) — a JSON/TOML
//!   file holding named sweeps (`numanos sweep --manifest exp.json`).

pub mod manifest;
pub mod session;
pub mod sweep;

pub use manifest::ExperimentManifest;
pub use session::{RunRecord, Session, ShardOutcome};
pub use sweep::{ShardPlan, Sweep, SweepResult};

use anyhow::{bail, Context, Result};

use crate::bots;
use crate::config::{apply_cost_override, ComputeMode, Size};
use crate::coordinator::binding::BindPolicy;
use crate::coordinator::sched::{Policy, SchedSpec};
use crate::serde::Json;
use crate::simnuma::{CostModel, MemSpec};
use crate::topology::Topology;
use crate::util::fmt_f64;

/// How threads map onto cores: a named policy, or an explicit pinning.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BindSpec {
    /// One of the named §IV policies (`linear` / `numa`).
    Policy(BindPolicy),
    /// Explicit thread→core list (thread 0 = master) — the ablation
    /// surface `Runtime::run_bound` used to expose positionally.
    Cores(Vec<usize>),
}

impl BindSpec {
    /// Short name for describe lines and CSV cells.
    pub fn name(&self) -> String {
        match self {
            BindSpec::Policy(b) => b.name().to_string(),
            BindSpec::Cores(cores) => {
                let list: Vec<String> = cores.iter().map(|c| c.to_string()).collect();
                format!("cores:{}", list.join("+"))
            }
        }
    }

    fn to_json(&self) -> Json {
        match self {
            BindSpec::Policy(b) => Json::from(b.name()),
            BindSpec::Cores(cores) => {
                Json::Arr(cores.iter().map(|&c| Json::from(c)).collect())
            }
        }
    }

    fn from_json(j: &Json) -> Result<Self> {
        match j {
            Json::Str(s) => Ok(BindSpec::Policy(BindPolicy::from_name(s)?)),
            Json::Arr(items) => {
                let cores = items
                    .iter()
                    .map(|v| v.as_usize().context("bind core list entries must be integers"))
                    .collect::<Result<Vec<usize>>>()?;
                Ok(BindSpec::Cores(cores))
            }
            other => bail!("bind must be a policy name or a core list, got {other:?}"),
        }
    }
}

/// One fully specified, validated run — the unit every execution path
/// (CLI, figures, sweeps, manifests) now goes through.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    pub bench: String,
    pub size: Size,
    /// Scheduler selection: registry name + parameter overrides.  Stock
    /// policies arrive here through the [`RunSpecBuilder::policy`] shim.
    pub sched: SchedSpec,
    /// Page-placement policy selection (default: plain first-touch, the
    /// pre-placement behaviour).
    pub mem: MemSpec,
    pub bind: BindSpec,
    pub threads: usize,
    pub topo: String,
    pub seed: u64,
    pub compute: ComputeMode,
    pub artifact_dir: String,
    /// Cost-model overrides applied on top of the session's base model,
    /// in order (`[("dram_base_ns", 100.0), …]`).
    pub cost: Vec<(String, f64)>,
    /// With [`BindSpec::Cores`]: whether per-thread runtime pages are
    /// first-touched locally (§IV) or all by the master.
    pub rtdata_local: bool,
}

impl Default for RunSpec {
    fn default() -> Self {
        Self {
            bench: "fft".into(),
            size: Size::Medium,
            sched: SchedSpec::stock(Policy::WorkFirst),
            mem: MemSpec::default(),
            bind: BindSpec::Policy(BindPolicy::Linear),
            threads: 16,
            topo: "x4600".into(),
            seed: 42,
            compute: ComputeMode::Sim,
            artifact_dir: "artifacts".into(),
            cost: Vec::new(),
            rtdata_local: true,
        }
    }
}

impl RunSpec {
    pub fn builder() -> RunSpecBuilder {
        RunSpecBuilder::default()
    }

    /// Human-readable one-liner (the CLI's `# …` header).
    pub fn describe(&self) -> String {
        let mut s = format!(
            "bench={} size={} sched={} bind={} threads={} topo={} seed={} compute={}",
            self.bench,
            self.size.name(),
            self.sched.name_sig(),
            self.bind.name(),
            self.threads,
            self.topo,
            self.seed,
            match self.compute {
                ComputeMode::Sim => "sim",
                ComputeMode::Pjrt => "pjrt",
            },
        );
        if !self.mem.is_default() {
            s.push_str(&format!(" mem={}", self.mem.name_sig()));
        }
        if !self.cost.is_empty() {
            s.push_str(&format!(" cost={}", self.cost_sig()));
        }
        s
    }

    /// Paper-legend style config label (`wf-Scheduler-NUMA`).
    pub fn label(&self) -> String {
        if self.sched.is_serial() {
            return "serial".into();
        }
        let sched = format!("{}-Scheduler", self.sched.name_sig());
        match &self.bind {
            BindSpec::Policy(BindPolicy::NumaAware) => format!("{sched}-NUMA"),
            BindSpec::Policy(BindPolicy::Linear) => sched,
            BindSpec::Cores(_) => format!("{sched}-pinned"),
        }
    }

    /// Canonical cost-override signature (cache keys, describe lines).
    pub fn cost_sig(&self) -> String {
        let parts: Vec<String> =
            self.cost.iter().map(|(k, v)| format!("{k}={}", fmt_f64(*v))).collect();
        parts.join(",")
    }

    /// The cost model this spec runs under: `base` + overrides.
    pub fn cost_model(&self, base: &CostModel) -> Result<CostModel> {
        let mut cm = base.clone();
        for (k, v) in &self.cost {
            apply_cost_override(&mut cm, k, &fmt_f64(*v))?;
        }
        Ok(cm)
    }

    /// Check every axis; all construction paths (builder, JSON/TOML,
    /// CLI flags) funnel through this before a spec can run.
    pub fn validate(&self) -> Result<()> {
        let topo = Topology::by_name(&self.topo)?;
        self.validate_against(&topo)
    }

    /// Like [`RunSpec::validate`], but against an already-resolved
    /// topology (sessions may carry adopted non-preset topologies).
    pub fn validate_against(&self, topo: &Topology) -> Result<()> {
        if !bots::NAMES.contains(&self.bench.as_str()) {
            bail!("unknown benchmark '{}' (see `numanos list`)", self.bench);
        }
        // scheduler name + parameters must resolve against the registry
        self.sched.check()?;
        // page policy must resolve and fit the topology (bind node range)
        self.mem.build(topo.num_nodes())?;
        if self.threads < 1 || self.threads > topo.num_cores() {
            bail!(
                "threads={} out of range 1..={} for topology '{}'",
                self.threads,
                topo.num_cores(),
                self.topo
            );
        }
        if self.sched.is_serial() && self.threads != 1 {
            bail!("the serial scheduler is the 1-thread baseline; got threads={}", self.threads);
        }
        if let BindSpec::Cores(cores) = &self.bind {
            if cores.is_empty() {
                bail!("explicit core list is empty");
            }
            if cores.len() != self.threads {
                bail!("{} cores bound but threads={}", cores.len(), self.threads);
            }
            let mut seen = vec![false; topo.num_cores()];
            for &c in cores {
                if c >= topo.num_cores() {
                    bail!("core {c} out of range for topology '{}'", self.topo);
                }
                if seen[c] {
                    bail!("core {c} bound twice");
                }
                seen[c] = true;
            }
        }
        // cost keys/values must be applicable
        self.cost_model(&CostModel::default())?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(String, Json)> = vec![
            ("bench".into(), Json::from(self.bench.as_str())),
            ("size".into(), Json::from(self.size.name())),
            ("sched".into(), self.sched.to_json()),
            ("bind".into(), self.bind.to_json()),
            ("threads".into(), Json::from(self.threads)),
            ("topo".into(), Json::from(self.topo.as_str())),
            ("seed".into(), Json::from_u64_lossless(self.seed)),
            (
                "compute".into(),
                Json::from(match self.compute {
                    ComputeMode::Sim => "sim",
                    ComputeMode::Pjrt => "pjrt",
                }),
            ),
        ];
        if !self.mem.is_default() {
            pairs.push(("mem".into(), self.mem.to_json()));
        }
        if !self.cost.is_empty() {
            pairs.push((
                "cost".into(),
                Json::obj(self.cost.iter().map(|(k, v)| (k.clone(), Json::from(*v)))),
            ));
        }
        if self.artifact_dir != "artifacts" {
            pairs.push(("artifacts".into(), Json::from(self.artifact_dir.as_str())));
        }
        if !self.rtdata_local {
            pairs.push(("rtdata_local".into(), Json::from(false)));
        }
        Json::obj(pairs)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_pretty()
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().context("RunSpec must be an object")?;
        let mut b = RunSpecBuilder::default();
        let mut unknown = Vec::new();
        for (key, val) in obj {
            match key.as_str() {
                "bench" => b.spec.bench = str_field(val, key)?,
                "size" => b.spec.size = Size::from_name(&str_field(val, key)?)?,
                "sched" | "policy" => b.spec.sched = SchedSpec::from_json(val)?,
                "mem" => b.spec.mem = MemSpec::from_json(val)?,
                "bind" => b.spec.bind = BindSpec::from_json(val)?,
                "threads" => {
                    b.threads = Some(val.as_usize().context("threads must be a positive integer")?)
                }
                "topo" => b.spec.topo = str_field(val, key)?,
                "seed" => {
                    b.spec.seed = val
                        .as_u64_lossless()
                        .context("seed must be a non-negative integer (string form for ≥2^53)")?
                }
                "compute" => {
                    b.spec.compute = match str_field(val, key)?.as_str() {
                        "sim" => ComputeMode::Sim,
                        "pjrt" => ComputeMode::Pjrt,
                        other => bail!("unknown compute mode '{other}' (sim|pjrt)"),
                    }
                }
                "artifacts" => b.spec.artifact_dir = str_field(val, key)?,
                "cost" => b.spec.cost = cost_from_json(val)?,
                "rtdata_local" => {
                    b.spec.rtdata_local = val.as_bool().context("rtdata_local must be a bool")?
                }
                _ => unknown.push(key.clone()),
            }
        }
        if !unknown.is_empty() {
            bail!(
                "unknown RunSpec key(s): {} (allowed: bench size sched mem bind threads topo \
                 seed compute artifacts cost rtdata_local)",
                unknown.join(", ")
            );
        }
        b.build()
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text).context("parsing RunSpec JSON")?)
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        Self::from_json(&crate::serde::toml::parse(text).context("parsing RunSpec TOML")?)
    }
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    v.as_str().map(str::to_string).with_context(|| format!("'{key}' must be a string"))
}

/// `{"dram_base_ns": 100, …}` → ordered override pairs (BTreeMap order).
pub(crate) fn cost_from_json(v: &Json) -> Result<Vec<(String, f64)>> {
    let obj = v.as_obj().context("cost must be an object of numeric overrides")?;
    obj.iter()
        .map(|(k, v)| {
            let n = v.as_num().with_context(|| format!("cost.{k} must be a number"))?;
            Ok((k.clone(), n))
        })
        .collect()
}

/// Parse a `k=v,k=v` override list into pairs (CLI `--cost`).
pub fn parse_cost_pairs(spec: &str) -> Result<Vec<(String, f64)>> {
    spec.split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|pair| {
            let (k, v) = pair
                .split_once('=')
                .with_context(|| format!("bad override '{pair}' (want k=v)"))?;
            let n: f64 =
                v.trim().parse().with_context(|| format!("bad override value in '{pair}'"))?;
            Ok((k.trim().to_string(), n))
        })
        .collect()
}

/// Fluent, validating builder for [`RunSpec`].
#[derive(Clone, Debug, Default)]
pub struct RunSpecBuilder {
    spec: RunSpec,
    /// Explicit thread count (checked against an explicit core list).
    threads: Option<usize>,
}

impl RunSpecBuilder {
    pub fn bench(mut self, name: &str) -> Self {
        self.spec.bench = name.to_string();
        self
    }

    pub fn size(mut self, size: Size) -> Self {
        self.spec.size = size;
        self
    }

    /// Select a stock policy (legacy shim over [`RunSpecBuilder::sched`]).
    pub fn policy(self, policy: Policy) -> Self {
        self.sched(SchedSpec::stock(policy))
    }

    /// Select any registered scheduler, with parameters.
    pub fn sched(mut self, sched: SchedSpec) -> Self {
        self.spec.sched = sched;
        self
    }

    /// Select a page-placement policy, with parameters.
    pub fn mem(mut self, mem: MemSpec) -> Self {
        self.spec.mem = mem;
        self
    }

    pub fn bind(mut self, bind: BindPolicy) -> Self {
        self.spec.bind = BindSpec::Policy(bind);
        self
    }

    /// NUMA-aware §IV binding (the paper's allocation).
    pub fn numa(self) -> Self {
        self.bind(BindPolicy::NumaAware)
    }

    /// Baseline linear binding.
    pub fn linear(self) -> Self {
        self.bind(BindPolicy::Linear)
    }

    /// Explicit thread→core pinning (thread count follows the list unless
    /// [`threads`](Self::threads) is also given, which must then match).
    pub fn cores(mut self, cores: Vec<usize>) -> Self {
        self.spec.bind = BindSpec::Cores(cores);
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    pub fn topo(mut self, name: &str) -> Self {
        self.spec.topo = name.to_string();
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = seed;
        self
    }

    pub fn compute(mut self, mode: ComputeMode) -> Self {
        self.spec.compute = mode;
        self
    }

    /// Real AOT kernels through PJRT (needs `artifacts/`).
    pub fn pjrt(self) -> Self {
        self.compute(ComputeMode::Pjrt)
    }

    pub fn artifact_dir(mut self, dir: &str) -> Self {
        self.spec.artifact_dir = dir.to_string();
        self
    }

    /// Add one cost-model override (repeatable).
    pub fn cost(mut self, key: &str, value: f64) -> Self {
        self.spec.cost.push((key.to_string(), value));
        self
    }

    pub fn rtdata_local(mut self, local: bool) -> Self {
        self.spec.rtdata_local = local;
        self
    }

    /// Apply one CLI-style `key value` setting (shared by `numanos run`
    /// flag handling and config files).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "bench" => self.spec.bench = value.to_string(),
            "size" => self.spec.size = Size::from_name(value)?,
            // `name` or `name:k=v,k=v` — any registered scheduler
            "sched" | "policy" => self.spec.sched = SchedSpec::parse(value)?,
            // `name` or `name:k=v,k=v` — any page policy
            "mem" => self.spec.mem = MemSpec::parse(value)?,
            "bind" => self.spec.bind = BindSpec::Policy(BindPolicy::from_name(value)?),
            "cores" => {
                let cores = value
                    .split(',')
                    .filter(|s| !s.trim().is_empty())
                    .map(|s| s.trim().parse::<usize>().context("core list"))
                    .collect::<Result<Vec<usize>>>()?;
                self.spec.bind = BindSpec::Cores(cores);
            }
            "threads" => self.threads = Some(value.parse().context("threads")?),
            "topo" => self.spec.topo = value.to_string(),
            "seed" => self.spec.seed = value.parse().context("seed")?,
            "compute" => {
                self.spec.compute = match value {
                    "sim" => ComputeMode::Sim,
                    "pjrt" => ComputeMode::Pjrt,
                    other => bail!("unknown compute mode '{other}' (sim|pjrt)"),
                }
            }
            "artifacts" => self.spec.artifact_dir = value.to_string(),
            "cost" => self.spec.cost.extend(parse_cost_pairs(value)?),
            "rtdata" => self.spec.rtdata_local = value.parse().context("rtdata")?,
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Validate and produce the spec.
    pub fn build(self) -> Result<RunSpec> {
        let mut spec = self.spec;
        spec.threads = match (&spec.bind, self.threads) {
            (BindSpec::Cores(cores), None) => cores.len(),
            (_, Some(n)) => n,
            (BindSpec::Policy(_), None) => spec.threads,
        };
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_fluent_happy_path() {
        let spec = RunSpec::builder()
            .bench("fft")
            .policy(Policy::Dfwspt)
            .numa()
            .threads(16)
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(spec.bench, "fft");
        assert_eq!(spec.sched, SchedSpec::stock(Policy::Dfwspt));
        assert_eq!(spec.bind, BindSpec::Policy(BindPolicy::NumaAware));
        assert_eq!(spec.threads, 16);
        assert_eq!(spec.label(), "dfwspt-Scheduler-NUMA");
    }

    #[test]
    fn builder_accepts_parameterized_schedulers() {
        let spec = RunSpec::builder()
            .bench("fib")
            .sched(SchedSpec::new("hops-threshold").with_param("max_hops", 1.0))
            .numa()
            .threads(8)
            .build()
            .unwrap();
        assert_eq!(spec.sched.name_sig(), "hops-threshold(max_hops=1)");
        assert_eq!(spec.label(), "hops-threshold(max_hops=1)-Scheduler-NUMA");
        // unknown parameters fail at build()
        let bad = RunSpec::builder()
            .bench("fib")
            .sched(SchedSpec::new("hops-threshold").with_param("bogus", 1.0))
            .threads(8);
        assert!(bad.build().is_err());
    }

    #[test]
    fn builder_rejects_bad_axes() {
        assert!(RunSpec::builder().bench("bogus").build().is_err());
        assert!(RunSpec::builder().threads(0).build().is_err());
        assert!(RunSpec::builder().threads(17).build().is_err(), "x4600 has 16 cores");
        assert!(RunSpec::builder().topo("nope").build().is_err());
        assert!(RunSpec::builder().policy(Policy::Serial).threads(4).build().is_err());
        assert!(RunSpec::builder().cost("bogus_knob", 1.0).build().is_err());
        assert!(RunSpec::builder().cores(vec![0, 0]).build().is_err(), "duplicate core");
        assert!(RunSpec::builder().cores(vec![99]).build().is_err(), "core out of range");
        assert!(RunSpec::builder().cores(vec![0, 1]).threads(3).build().is_err());
        assert!(RunSpec::builder().cores(vec![]).build().is_err());
    }

    #[test]
    fn explicit_cores_imply_thread_count() {
        let spec = RunSpec::builder().cores(vec![4, 5, 6]).build().unwrap();
        assert_eq!(spec.threads, 3);
        assert_eq!(spec.bind.name(), "cores:4+5+6");
        assert_eq!(spec.label(), "wf-Scheduler-pinned");
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let spec = RunSpec::builder()
            .bench("sort")
            .size(Size::Small)
            .policy(Policy::Dfwsrpt)
            .numa()
            .threads(8)
            .topo("x4600")
            .seed(9)
            .cost("dram_base_ns", 100.0)
            .cost("remote_bw_pct_per_hop", 12.5)
            .build()
            .unwrap();
        let text = spec.to_json_string();
        let back = RunSpec::from_json_str(&text).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn huge_seeds_roundtrip_exactly() {
        let spec = RunSpec::builder().seed(u64::MAX - 1).build().unwrap();
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back.seed, u64::MAX - 1);
        assert_eq!(back, spec);
    }

    #[test]
    fn toml_spec_parses() {
        let spec = RunSpec::from_toml_str(
            "bench = \"strassen\"\nsched = \"dfwspt\"\nbind = \"numa\"\nthreads = 12\nseed = 3\n",
        )
        .unwrap();
        assert_eq!(spec.bench, "strassen");
        assert_eq!(spec.sched, SchedSpec::stock(Policy::Dfwspt));
        assert_eq!(spec.threads, 12);
    }

    #[test]
    fn parameterized_sched_roundtrips_json() {
        let spec = RunSpec::builder()
            .bench("fib")
            .sched(SchedSpec::new("adaptive").with_param("remote_ratio", 0.25))
            .threads(8)
            .build()
            .unwrap();
        let text = spec.to_json_string();
        assert!(text.contains("\"remote_ratio\""), "{text}");
        let back = RunSpec::from_json_str(&text).unwrap();
        assert_eq!(back, spec);
        // and the object form parses from authored JSON too
        let authored = r#"{"bench": "fib", "threads": 8,
            "sched": {"name": "hops-threshold", "max_hops": 2}}"#;
        let spec = RunSpec::from_json_str(authored).unwrap();
        assert_eq!(spec.sched.name_sig(), "hops-threshold(max_hops=2)");
    }

    #[test]
    fn mem_axis_roundtrips_and_validates() {
        // default stays implicit: old JSON shape is unchanged
        let plain = RunSpec::builder().build().unwrap();
        assert!(plain.mem.is_default());
        assert!(!plain.to_json_string().contains("\"mem\""), "{}", plain.to_json_string());

        let spec = RunSpec::builder()
            .bench("sort")
            .mem(MemSpec::new("interleave"))
            .threads(8)
            .build()
            .unwrap();
        let text = spec.to_json_string();
        assert!(text.contains("\"mem\"") && text.contains("interleave"), "{text}");
        assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec);

        let spec = RunSpec::builder()
            .mem(MemSpec::new("bind").with_param("node", 3.0))
            .build()
            .unwrap();
        let back = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back.mem.name_sig(), "bind(node=3)");
        assert!(
            spec.describe().contains("mem=bind(node=3)"),
            "{}",
            spec.describe()
        );

        // validation catches bad policies and topology-range violations
        assert!(RunSpec::builder().mem(MemSpec::new("bogus")).build().is_err());
        let out_of_range = RunSpec::builder()
            .mem(MemSpec::new("bind").with_param("node", 9.0))
            .topo("x4600"); // 8 nodes
        assert!(out_of_range.build().is_err());
        // ... but bind:node=9 is fine on a 16-node fabric
        assert!(RunSpec::builder()
            .mem(MemSpec::new("bind").with_param("node", 9.0))
            .topo("altix16")
            .build()
            .is_ok());
    }

    #[test]
    fn cli_style_set_accepts_mem_policies() {
        let mut b = RunSpec::builder();
        b.set("bench", "fib").unwrap();
        b.set("mem", "next-touch:max_moves=2").unwrap();
        b.set("threads", "4").unwrap();
        let spec = b.build().unwrap();
        assert_eq!(spec.mem.name_sig(), "next-touch(max_moves=2)");
        let mut bad = RunSpec::builder();
        assert!(bad.set("mem", "bogus").is_err());
        assert!(bad.set("mem", "bind:bogus=1").is_err());
    }

    #[test]
    fn unknown_json_keys_are_listed() {
        let err = RunSpec::from_json_str(r#"{"bench": "fft", "trheads": 4, "sceed": 1}"#)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("trheads") && msg.contains("sceed"), "{msg}");
    }

    #[test]
    fn describe_matches_legacy_format() {
        let spec = RunSpec::builder().build().unwrap();
        assert_eq!(
            spec.describe(),
            "bench=fft size=medium sched=wf bind=linear threads=16 topo=x4600 seed=42 compute=sim"
        );
    }

    #[test]
    fn cli_style_set() {
        let mut b = RunSpec::builder();
        for (k, v) in [
            ("bench", "sort"),
            ("sched", "dfwsrpt"),
            ("bind", "numa"),
            ("threads", "8"),
            ("size", "large"),
            ("cost", "dram_base_ns=150,hop_penalty_ns=99"),
        ] {
            b.set(k, v).unwrap();
        }
        let spec = b.build().unwrap();
        assert_eq!(spec.sched, SchedSpec::stock(Policy::Dfwsrpt));
        assert_eq!(spec.size, Size::Large);
        assert_eq!(spec.cost.len(), 2);
        let mut bad = RunSpec::builder();
        assert!(bad.set("bogus", "1").is_err());
        assert!(bad.set("threads", "abc").is_err());
    }

    #[test]
    fn cli_style_set_accepts_scheduler_parameters() {
        let mut b = RunSpec::builder();
        b.set("bench", "fib").unwrap();
        b.set("sched", "hops-threshold:max_hops=2,spill_after=1").unwrap();
        b.set("threads", "8").unwrap();
        let spec = b.build().unwrap();
        assert_eq!(spec.sched.name_sig(), "hops-threshold(max_hops=2;spill_after=1)");
        let mut bad = RunSpec::builder();
        assert!(bad.set("sched", "hops-threshold:bogus=1").is_err());
    }
}
