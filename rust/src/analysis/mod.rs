//! Static analysis & vetting: the correctness tooling layer.
//!
//! Three pillars, all surfaced through the CLI (`numanos vet`,
//! `numanos lint`, `--checked`) and CI:
//!
//! * [`vet`] — a **scheduler contract checker**.  Drives every
//!   registered scheduler through synthetic probe contexts (victim
//!   lists across several topologies, spawn/resume fixtures, steal
//!   candidate sets, replayed event streams) and verifies the
//!   [`Scheduler`](crate::coordinator::sched::Scheduler) /
//!   [`SchedDescriptor`](crate::coordinator::sched::SchedDescriptor)
//!   contract *before* a sweep burns hours on a misbehaving strategy.
//! * [`lint`] — a **static linter** for experiment manifests,
//!   `key = value` run configs, and result-store indexes: catches
//!   invalid cells, dead sweep axes, unreachable hint floors, and
//!   schema drift without executing anything.
//! * [`checked`] — the **checked engine mode**: promotes the
//!   load-bearing `debug_assert`s in `engine.rs` / `pool.rs` into an
//!   always-on invariant layer (enabled by `--checked` or the
//!   `checked` cargo feature).  Violations abort with a structured
//!   report instead of silently corrupting results.
//!
//! Every finding is a [`Diagnostic`]: a stable machine-readable code
//! (`VET001`, `LINT004`, …), a severity, the subject (scheduler name or
//! file), the probe context that triggered it, and a human message.
//! The README's "Static analysis & vetting" section carries the full
//! code table.

use crate::serde::Json;

pub mod checked;
pub mod lint;
pub mod vet;

/// How bad a finding is.  `Error` findings fail `vet`/`lint` (non-zero
/// exit); `Warning`s are advisory (suspicious but contract-legal).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One machine-readable finding from `vet` or `lint`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Stable code (`VET001`-style); the README documents the table.
    pub code: &'static str,
    pub severity: Severity,
    /// What is being diagnosed: a scheduler name or a file path.
    pub subject: String,
    /// The probe context that triggered the finding
    /// (`"x4600 threads=8 worker=3 seed=1"`), or `-` for static checks.
    pub context: String,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, subject: &str, context: &str, message: String) -> Self {
        Self {
            code,
            severity: Severity::Error,
            subject: subject.to_string(),
            context: context.to_string(),
            message,
        }
    }

    pub fn warning(code: &'static str, subject: &str, context: &str, message: String) -> Self {
        Self {
            code,
            severity: Severity::Warning,
            subject: subject.to_string(),
            context: context.to_string(),
            message,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("code", Json::from(self.code)),
            ("severity", Json::from(self.severity.name())),
            ("subject", Json::from(self.subject.as_str())),
            ("context", Json::from(self.context.as_str())),
            ("message", Json::from(self.message.as_str())),
        ])
    }
}

/// Render a diagnostic list as a JSON array (the `--json` output).
pub fn diagnostics_to_json(diags: &[Diagnostic]) -> Json {
    Json::Arr(diags.iter().map(Diagnostic::to_json).collect())
}

/// Render a diagnostic list as an aligned text table.
pub fn render_table(diags: &[Diagnostic]) -> String {
    let header = ["CODE", "SEVERITY", "SUBJECT", "CONTEXT", "MESSAGE"];
    let mut rows: Vec<[String; 5]> = Vec::with_capacity(diags.len());
    for d in diags {
        rows.push([
            d.code.to_string(),
            d.severity.name().to_string(),
            d.subject.clone(),
            d.context.clone(),
            d.message.clone(),
        ]);
    }
    let mut width = [0usize; 4];
    for (i, w) in width.iter_mut().enumerate() {
        *w = header[i].len();
        for r in &rows {
            *w = (*w).max(r[i].len());
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{:<w0$}  {:<w1$}  {:<w2$}  {:<w3$}  {}\n",
        header[0],
        header[1],
        header[2],
        header[3],
        header[4],
        w0 = width[0],
        w1 = width[1],
        w2 = width[2],
        w3 = width[3],
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<w0$}  {:<w1$}  {:<w2$}  {:<w3$}  {}\n",
            r[0],
            r[1],
            r[2],
            r[3],
            r[4],
            w0 = width[0],
            w1 = width[1],
            w2 = width[2],
            w3 = width[3],
        ));
    }
    out
}

/// Count of `Error`-severity findings (the exit-status driver).
pub fn error_count(diags: &[Diagnostic]) -> usize {
    diags.iter().filter(|d| d.severity == Severity::Error).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_and_json_render() {
        let diags = vec![
            Diagnostic::error("VET001", "bad-sched", "x4600 w=0", "duplicate victim 3".into()),
            Diagnostic::warning("VET012", "odd-sched", "-", "inert min_hint_bytes".into()),
        ];
        let table = render_table(&diags);
        assert!(table.contains("VET001"));
        assert!(table.contains("duplicate victim 3"));
        assert!(table.lines().count() == 3);
        let json = diagnostics_to_json(&diags).to_compact();
        assert!(json.contains("\"code\":\"VET012\""));
        assert!(json.contains("\"severity\":\"warning\""));
        assert_eq!(error_count(&diags), 1);
    }
}
