//! `numanos lint` — static validation of experiment inputs.
//!
//! Lints manifests, `key = value` run configs, and result-store indexes
//! **without executing anything**: every check below is resolvable from
//! the file plus the in-process registries (schedulers, page policies,
//! topology presets, benchmarks).  Codes:
//!
//! | code    | severity | rule                                                    |
//! |---------|----------|---------------------------------------------------------|
//! | LINT001 | error    | manifest unloadable / unknown key / invalid cell axis   |
//! | LINT002 | error    | scheduler unknown or parameter out of declared bounds   |
//! | LINT003 | error    | page policy unknown or invalid for the cell's topology  |
//! | LINT004 | error    | topology/thread/bind mismatch (incl. serial threads>1)  |
//! | LINT005 | error    | duplicate sweep cells (a dead axis re-runs work)        |
//! | LINT006 | error    | placement hint floor above total machine memory         |
//! | LINT007 | error    | result-store schema differs from [`STORE_SCHEMA`]       |
//! | LINT008 | error    | run-config file invalid                                 |
//! | LINT009 | error    | shard directive malformed, or a hand-written shard-job  |
//! |         |          | set overlaps / gaps / mixes counts over one manifest    |
//! | LINT010 | warning  | shard count exceeds the manifest's cell count           |
//!
//! Spool job files (manifests carrying a `shards`/`shard`/`merge_of`
//! directive, see [`crate::store::shard`]) lint like plain manifests:
//! the directive is stripped before manifest validation, then checked
//! on its own.  [`lint_dir`] additionally cross-checks every
//! `"shard": "I/N"` job under the tree as a set, grouped by the
//! fingerprint of the stripped manifest's cell sequence, so an
//! overlapping or gapped hand-written partition is caught before any
//! process runs.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::Path;

use anyhow::Result;

use super::Diagnostic;
use crate::bots;
use crate::config::RunConfig;
use crate::coordinator::sched::{resolve_name, scheduler_infos, SchedSpec};
use crate::serde::Json;
use crate::simnuma::{CostModel, PAGE_BYTES};
use crate::spec::{BindSpec, ExperimentManifest, RunSpec, ShardPlan};
use crate::store::shard::{classify_job, JobKind};
use crate::store::{cells_fingerprint, STORE_SCHEMA};
use crate::topology::Topology;

/// Lint one experiment manifest (JSON or TOML) — or a spool job file
/// carrying a shard directive on top of one.
pub fn lint_manifest(path: &Path) -> Vec<Diagnostic> {
    lint_manifest_inner(path).0
}

/// What a `"shard": "I/N"` job file declares — collected by
/// [`lint_dir`] so hand-written shard sets are cross-checked as a
/// group.
struct ShardJobInfo {
    path: String,
    /// Fingerprint of the stripped manifest's flattened cell sequence
    /// ([`cells_fingerprint`]) — shard files of one logical manifest
    /// group by this, whatever their spelling.
    fnv: String,
    plan: ShardPlan,
}

fn lint_manifest_inner(path: &Path) -> (Vec<Diagnostic>, Option<ShardJobInfo>) {
    let subject = path.display().to_string();
    let mut diags = Vec::new();
    let doc = match load_doc(path) {
        Ok(d) => d,
        Err(e) => {
            diags.push(Diagnostic::error("LINT001", &subject, "-", format!("{e:#}")));
            return (diags, None);
        }
    };
    let (kind, stripped) = match classify_job(&doc) {
        Ok(split) => split,
        Err(e) => {
            diags.push(Diagnostic::error(
                "LINT009",
                &subject,
                "-",
                format!("shard directive: {e:#}"),
            ));
            return (diags, None);
        }
    };
    let manifest = match ExperimentManifest::from_json(&stripped) {
        Ok(m) => m,
        Err(e) => {
            diags.push(Diagnostic::error(
                "LINT001",
                &subject,
                "-",
                format!("manifest {}: {e:#}", path.display()),
            ));
            return (diags, None);
        }
    };
    let mut seen: HashMap<String, String> = HashMap::new();
    for sweep in &manifest.sweeps {
        let cells = match sweep.cells() {
            Ok(c) => c,
            Err(e) => {
                diags.push(Diagnostic::error(
                    "LINT001",
                    &subject,
                    &format!("sweep '{}'", sweep.id),
                    format!("{e:#}"),
                ));
                continue;
            }
        };
        for cell in &cells {
            let ctx = format!("sweep '{}' cell {}", sweep.id, cell_key(cell));
            lint_cell(&mut diags, &subject, &ctx, cell);
            match seen.entry(cell_key(cell)) {
                std::collections::hash_map::Entry::Occupied(prev) => {
                    diags.push(Diagnostic::error(
                        "LINT005",
                        &subject,
                        &ctx,
                        format!(
                            "duplicate cell: already produced by sweep '{}' — a dead \
                             axis re-runs identical work",
                            prev.get()
                        ),
                    ));
                }
                std::collections::hash_map::Entry::Vacant(slot) => {
                    slot.insert(sweep.id.clone());
                }
            }
        }
    }
    // shard-plan checks against the flattened cell count; only possible
    // when every sweep expanded (axis errors above already reported)
    let mut info = None;
    if let Ok(cells) = manifest.all_cells() {
        let declared = match kind {
            JobKind::Fanout(n) => Some(n),
            JobKind::Shard(plan) => Some(plan.count),
            JobKind::Plain | JobKind::Merge(_) => None,
        };
        if let Some(n) = declared {
            if n > cells.len() {
                diags.push(Diagnostic::warning(
                    "LINT010",
                    &subject,
                    "-",
                    format!(
                        "shard count {n} exceeds the manifest's {} cell(s) — {} shard(s) \
                         will own nothing",
                        cells.len(),
                        n - cells.len()
                    ),
                ));
            }
        }
        if let JobKind::Shard(plan) = kind {
            if let Ok(fnv) = cells_fingerprint(&cells) {
                info = Some(ShardJobInfo { path: subject, fnv, plan });
            }
        }
    }
    (diags, info)
}

/// Read and parse a manifest / job document — TOML by extension, JSON
/// otherwise — without interpreting its keys.
fn load_doc(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    if path.extension().and_then(|e| e.to_str()) == Some("toml") {
        crate::serde::toml::parse(&text)
    } else {
        Json::parse(&text)
    }
}

/// One cell's full identity — every axis that changes simulated output.
fn cell_key(spec: &RunSpec) -> String {
    let cost: Vec<String> =
        spec.cost.iter().map(|(k, v)| format!("{k}={v}")).collect();
    format!(
        "{}|{}|{}|{}|{}|{}|{}|{}|{}",
        spec.bench,
        spec.size.name(),
        spec.sched.name_sig(),
        spec.mem.name_sig(),
        spec.bind.name(),
        spec.threads,
        spec.topo,
        spec.seed,
        cost.join(",")
    )
}

/// Validate one cell, classifying each failure axis to its code.
/// Mirrors [`RunSpec::validate_against`] piecewise so one lint run
/// reports *every* broken axis instead of stopping at the first.
fn lint_cell(diags: &mut Vec<Diagnostic>, subject: &str, ctx: &str, spec: &RunSpec) {
    if !bots::NAMES.contains(&spec.bench.as_str()) {
        diags.push(Diagnostic::error(
            "LINT001",
            subject,
            ctx,
            format!("unknown benchmark '{}'", spec.bench),
        ));
    }
    if let Err(e) = spec.sched.check() {
        diags.push(Diagnostic::error("LINT002", subject, ctx, format!("{e:#}")));
    }
    if let Err(e) = spec.cost_model(&CostModel::default()) {
        diags.push(Diagnostic::error("LINT001", subject, ctx, format!("{e:#}")));
    }
    let topo = match Topology::by_name(&spec.topo) {
        Ok(t) => t,
        Err(e) => {
            diags.push(Diagnostic::error("LINT004", subject, ctx, format!("{e:#}")));
            return;
        }
    };
    if let Err(e) = spec.mem.build(topo.num_nodes()) {
        diags.push(Diagnostic::error("LINT003", subject, ctx, format!("{e:#}")));
    }
    if spec.threads < 1 || spec.threads > topo.num_cores() {
        diags.push(Diagnostic::error(
            "LINT004",
            subject,
            ctx,
            format!(
                "threads={} out of range 1..={} for topology '{}'",
                spec.threads,
                topo.num_cores(),
                spec.topo
            ),
        ));
    }
    if spec.sched.is_serial() && spec.threads != 1 {
        diags.push(Diagnostic::error(
            "LINT004",
            subject,
            ctx,
            format!("the serial scheduler is the 1-thread baseline; got threads={}", spec.threads),
        ));
    }
    if let BindSpec::Cores(cores) = &spec.bind {
        if cores.len() != spec.threads || cores.iter().any(|&c| c >= topo.num_cores()) {
            diags.push(Diagnostic::error(
                "LINT004",
                subject,
                ctx,
                format!("explicit core list {cores:?} does not fit threads={} on '{}'",
                    spec.threads, spec.topo),
            ));
        }
    }
    if let Some(floor) = hint_floor_bytes(&spec.sched) {
        let total = topo.node_capacity_pages() * PAGE_BYTES * topo.num_nodes() as u64;
        if floor > total {
            diags.push(Diagnostic::error(
                "LINT006",
                subject,
                ctx,
                format!(
                    "min_kb floor ({floor} bytes) exceeds total machine memory \
                     ({total} bytes on '{}') — the placement hook can never engage",
                    spec.topo
                ),
            ));
        }
    }
}

/// The effective `min_kb` hint floor (bytes) of a scheduler spec, if it
/// declares one: the override when given, the declared default otherwise.
fn hint_floor_bytes(sched: &SchedSpec) -> Option<u64> {
    let canonical = resolve_name(&sched.name).ok()?;
    let info = scheduler_infos().into_iter().find(|i| i.name == canonical)?;
    let declared = info.params.iter().find(|p| p.name == "min_kb")?;
    let v = sched
        .params
        .iter()
        .find(|(k, _)| k == "min_kb")
        .map(|(_, v)| *v)
        .unwrap_or(declared.default);
    if v.is_finite() && v >= 0.0 {
        Some((v * 1024.0) as u64)
    } else {
        None
    }
}

/// Lint one `key = value` run-config file.
pub fn lint_config(path: &Path) -> Vec<Diagnostic> {
    let subject = path.display().to_string();
    let cfg = match RunConfig::from_file(path) {
        Ok(c) => c,
        Err(e) => {
            return vec![Diagnostic::error("LINT008", &subject, "-", format!("{e:#}"))];
        }
    };
    match cfg.to_spec() {
        Ok(spec) => {
            let mut diags = Vec::new();
            lint_cell(&mut diags, &subject, &cfg.describe(), &spec);
            diags
        }
        // to_spec validates; surface its error when the piecewise pass
        // cannot even build a spec (builder-level failures).
        Err(e) => vec![Diagnostic::error("LINT008", &subject, "-", format!("{e:#}"))],
    }
}

/// Lint one result-store `index.json` for schema drift.
pub fn lint_store_index(path: &Path) -> Vec<Diagnostic> {
    let subject = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return vec![Diagnostic::error("LINT007", &subject, "-", format!("{e}"))],
    };
    let json = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            return vec![Diagnostic::error(
                "LINT007",
                &subject,
                "-",
                format!("unparseable store index: {e:#}"),
            )]
        }
    };
    match json.get("schema").and_then(Json::as_u64) {
        Some(s) if s == STORE_SCHEMA => Vec::new(),
        Some(s) => vec![Diagnostic::error(
            "LINT007",
            &subject,
            "-",
            format!("store schema {s} != supported {STORE_SCHEMA}"),
        )],
        None => vec![Diagnostic::error(
            "LINT007",
            &subject,
            "-",
            "store index carries no schema field".to_string(),
        )],
    }
}

/// Lint everything recognizable under a directory (recursive):
/// `*.json`/`*.toml` manifests (identified by a top-level `sweeps`
/// key — other JSON files are skipped), `*.conf` run configs, and
/// `index.json` store indexes.  `"shard": "I/N"` job files are
/// additionally cross-checked as a set per manifest fingerprint —
/// mixed counts, overlapping indices, and gapped partitions are
/// LINT009 errors.
pub fn lint_dir(dir: &Path) -> Result<Vec<Diagnostic>> {
    let mut diags = Vec::new();
    let mut shard_jobs: Vec<ShardJobInfo> = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    let mut scanned = 0usize;
    while let Some(d) = stack.pop() {
        let entries = std::fs::read_dir(&d)
            .map_err(|e| anyhow::anyhow!("reading directory {}: {e}", d.display()))?;
        let mut paths: Vec<_> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
        paths.sort();
        for path in paths {
            if path.is_dir() {
                stack.push(path);
                continue;
            }
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
            if name == "index.json" {
                diags.extend(lint_store_index(&path));
                scanned += 1;
            } else if ext == "conf" {
                diags.extend(lint_config(&path));
                scanned += 1;
            } else if (ext == "json" || ext == "toml") && looks_like_manifest(&path) {
                let (d, info) = lint_manifest_inner(&path);
                diags.extend(d);
                shard_jobs.extend(info);
                scanned += 1;
            }
        }
    }
    if scanned == 0 {
        anyhow::bail!("no manifests, configs, or store indexes under {}", dir.display());
    }
    lint_shard_sets(&mut diags, dir, shard_jobs);
    Ok(diags)
}

/// Cross-check hand-written shard-job sets: every `"shard": "I/N"` file
/// of one manifest (same cell-sequence fingerprint) must use one count
/// and claim each index exactly once — otherwise a multi-process run
/// silently double-executes or drops cells and the merge can't see it.
fn lint_shard_sets(diags: &mut Vec<Diagnostic>, dir: &Path, jobs: Vec<ShardJobInfo>) {
    let subject = dir.display().to_string();
    let mut groups: BTreeMap<String, Vec<ShardJobInfo>> = BTreeMap::new();
    for job in jobs {
        groups.entry(job.fnv.clone()).or_default().push(job);
    }
    for (fnv, mut jobs) in groups {
        jobs.sort_by(|a, b| a.path.cmp(&b.path));
        let ctx = format!("shard set (cells fnv {fnv})");
        let counts: BTreeSet<usize> = jobs.iter().map(|j| j.plan.count).collect();
        if counts.len() > 1 {
            let specs: Vec<String> =
                jobs.iter().map(|j| format!("{} ({})", j.path, j.plan.spec())).collect();
            diags.push(Diagnostic::error(
                "LINT009",
                &subject,
                &ctx,
                format!(
                    "mixed shard counts over one manifest — the partitions disagree: {}",
                    specs.join(", ")
                ),
            ));
            continue;
        }
        let count = *counts.iter().next().expect("non-empty group");
        let mut by_index: BTreeMap<usize, Vec<&str>> = BTreeMap::new();
        for job in &jobs {
            by_index.entry(job.plan.index).or_default().push(&job.path);
        }
        for (index, files) in &by_index {
            if files.len() > 1 {
                diags.push(Diagnostic::error(
                    "LINT009",
                    &subject,
                    &ctx,
                    format!(
                        "overlapping partition: shard {index}/{count} is claimed by {} \
                         files ({}) — its cells would execute twice",
                        files.len(),
                        files.join(", ")
                    ),
                ));
            }
        }
        let missing: Vec<String> =
            (0..count).filter(|i| !by_index.contains_key(i)).map(|i| i.to_string()).collect();
        if !missing.is_empty() {
            diags.push(Diagnostic::error(
                "LINT009",
                &subject,
                &ctx,
                format!(
                    "gapped partition: no job file claims shard(s) {} of {count} — a \
                     merge over this set would re-execute their cells",
                    missing.join(", ")
                ),
            ));
        }
    }
}

/// A file is treated as a manifest when it parses to an object with a
/// top-level `sweeps` key — arbitrary JSON (bench reports, figures)
/// under the same tree is skipped rather than false-positived.
fn looks_like_manifest(path: &Path) -> bool {
    let Ok(text) = std::fs::read_to_string(path) else {
        return false;
    };
    let parsed = if path.extension().and_then(|e| e.to_str()) == Some("toml") {
        crate::serde::toml::parse(&text)
    } else {
        Json::parse(&text)
    };
    match parsed {
        Ok(j) => j.get("sweeps").is_some(),
        // unparseable but named like a manifest: let lint_manifest report
        Err(_) => text.contains("\"sweeps\"") || text.contains("[[sweeps]]"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::error_count;

    fn tmp(name: &str, text: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("numanos_lint_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn clean_manifest_passes() {
        let p = tmp(
            "clean.json",
            r#"{"title": "t", "sweeps": [
                {"id": "a", "title": "a", "bench": ["fib"],
                 "sched": ["wf"], "bind": ["numa"], "threads": [4], "seeds": [1]}
            ]}"#,
        );
        assert!(lint_manifest(&p).is_empty());
    }

    #[test]
    fn duplicate_cells_flagged() {
        let p = tmp(
            "dup.json",
            r#"{"title": "t", "sweeps": [
                {"id": "a", "title": "a", "bench": ["fib"],
                 "sched": ["wf"], "bind": ["numa"], "threads": [4], "seeds": [1, 1]}
            ]}"#,
        );
        let diags = lint_manifest(&p);
        assert!(diags.iter().any(|d| d.code == "LINT005"), "{diags:?}");
    }

    #[test]
    fn thread_overflow_flagged() {
        let p = tmp(
            "threads.json",
            r#"{"title": "t", "sweeps": [
                {"id": "a", "title": "a", "bench": ["fib"], "topo": "quad",
                 "sched": ["wf"], "bind": ["numa"], "threads": [64], "seeds": [1]}
            ]}"#,
        );
        let diags = lint_manifest(&p);
        assert!(diags.iter().any(|d| d.code == "LINT004"), "{diags:?}");
    }

    #[test]
    fn bad_sched_param_flagged() {
        let p = tmp(
            "sched.json",
            r#"{"title": "t", "sweeps": [
                {"id": "a", "title": "a", "bench": ["fib"],
                 "sched": [{"name": "hops-threshold", "max_hops": 999}],
                 "bind": ["numa"], "threads": [4], "seeds": [1]}
            ]}"#,
        );
        let diags = lint_manifest(&p);
        assert!(diags.iter().any(|d| d.code == "LINT002"), "{diags:?}");
    }

    #[test]
    fn unreachable_hint_floor_flagged() {
        let p = tmp(
            "floor.json",
            r#"{"title": "t", "sweeps": [
                {"id": "a", "title": "a", "bench": ["fib"],
                 "sched": [{"name": "numa-home", "min_kb": 8000000000}],
                 "bind": ["numa"], "threads": [4], "seeds": [1]}
            ]}"#,
        );
        let diags = lint_manifest(&p);
        assert!(diags.iter().any(|d| d.code == "LINT006"), "{diags:?}");
    }

    #[test]
    fn store_schema_drift_flagged() {
        let good = tmp("index.json", r#"{"schema": 1, "runs": []}"#);
        assert!(lint_store_index(&good).is_empty());
        let bad = tmp("index_bad.json", r#"{"schema": 99, "runs": []}"#);
        let diags = lint_store_index(&bad);
        assert_eq!(error_count(&diags), 1);
        assert_eq!(diags[0].code, "LINT007");
    }

    #[test]
    fn conf_file_lints() {
        let good = tmp("run.conf", "bench = fib\nsched = wf\nthreads = 4\n");
        assert!(lint_config(&good).is_empty());
        let bad = tmp("bad.conf", "bench = fib\nbogus_key = 1\n");
        let diags = lint_config(&bad);
        assert!(diags.iter().any(|d| d.code == "LINT008"), "{diags:?}");
    }

    #[test]
    fn repo_example_manifest_is_clean() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/experiment_manifest.json");
        if p.exists() {
            let diags = lint_manifest(&p);
            assert!(diags.is_empty(), "{diags:?}");
        }
    }

    #[test]
    fn malformed_shard_directive_flagged() {
        for (name, text) in [
            ("bad_spec.json", r#"{"title": "t", "sweeps": [], "shard": "5/3"}"#),
            ("bad_count.json", r#"{"title": "t", "sweeps": [], "shards": 0}"#),
            ("both.json", r#"{"title": "t", "sweeps": [], "shards": 3, "shard": "0/3"}"#),
        ] {
            let p = tmp(name, text);
            let diags = lint_manifest(&p);
            assert!(diags.iter().any(|d| d.code == "LINT009"), "{name}: {diags:?}");
        }
    }

    #[test]
    fn shard_job_lints_like_the_plain_manifest() {
        // the directive key must not trip LINT001's unknown-key check,
        // and cell-level checks still run on the stripped manifest
        let p = tmp(
            "sharded_ok.json",
            r#"{"title": "t", "sweeps": [
                {"id": "a", "title": "a", "bench": ["fib"],
                 "sched": ["wf"], "bind": ["numa"], "threads": [4], "seeds": [1]}
            ], "shard": "0/1"}"#,
        );
        assert!(lint_manifest(&p).is_empty());
        let p = tmp(
            "sharded_bad_cell.json",
            r#"{"title": "t", "sweeps": [
                {"id": "a", "title": "a", "bench": ["fib"], "topo": "quad",
                 "sched": ["wf"], "bind": ["numa"], "threads": [64], "seeds": [1]}
            ], "shards": 2}"#,
        );
        let diags = lint_manifest(&p);
        assert!(diags.iter().any(|d| d.code == "LINT004"), "{diags:?}");
    }

    #[test]
    fn oversized_shard_count_warns() {
        let p = tmp(
            "toomany.json",
            r#"{"title": "t", "sweeps": [
                {"id": "a", "title": "a", "bench": ["fib"],
                 "sched": ["wf"], "bind": ["numa"], "threads": [2, 4], "seeds": [1]}
            ], "shards": 7}"#,
        );
        let diags = lint_manifest(&p);
        assert!(diags.iter().any(|d| d.code == "LINT010"), "{diags:?}");
        assert_eq!(error_count(&diags), 0, "LINT010 is a warning: {diags:?}");
    }

    /// A fresh directory per test — the shared `tmp()` dir accumulates
    /// other tests' deliberately-broken files, which `lint_dir` would
    /// also pick up.
    fn shard_set_dir(name: &str, files: &[(&str, &str)]) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("numanos_lint_shardset_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (file, text) in files {
            std::fs::write(dir.join(file), text).unwrap();
        }
        dir
    }

    fn shard_job(spec: &str) -> String {
        format!(
            r#"{{"title": "t", "sweeps": [
                {{"id": "a", "title": "a", "bench": ["fib"],
                 "sched": ["wf"], "bind": ["numa"], "threads": [2, 4, 8], "seeds": [1]}}
            ], "shard": "{spec}"}}"#
        )
    }

    #[test]
    fn clean_shard_set_passes_dir_lint() {
        let dir = shard_set_dir(
            "clean",
            &[
                ("s0.json", &shard_job("0/3")),
                ("s1.json", &shard_job("1/3")),
                ("s2.json", &shard_job("2/3")),
            ],
        );
        let diags = lint_dir(&dir).unwrap();
        assert!(diags.is_empty(), "{diags:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gapped_and_overlapping_shard_sets_flagged() {
        let dir = shard_set_dir(
            "gap",
            &[("s0.json", &shard_job("0/3")), ("s2.json", &shard_job("2/3"))],
        );
        let diags = lint_dir(&dir).unwrap();
        assert!(
            diags.iter().any(|d| d.code == "LINT009" && d.message.contains("gapped")),
            "{diags:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);

        let dir = shard_set_dir(
            "overlap",
            &[
                ("s0.json", &shard_job("0/2")),
                ("s0b.json", &shard_job("0/2")),
                ("s1.json", &shard_job("1/2")),
            ],
        );
        let diags = lint_dir(&dir).unwrap();
        assert!(
            diags.iter().any(|d| d.code == "LINT009" && d.message.contains("overlapping")),
            "{diags:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);

        let dir = shard_set_dir(
            "mixed",
            &[("s0.json", &shard_job("0/2")), ("s1.json", &shard_job("1/3"))],
        );
        let diags = lint_dir(&dir).unwrap();
        assert!(
            diags.iter().any(|d| d.code == "LINT009" && d.message.contains("mixed")),
            "{diags:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
