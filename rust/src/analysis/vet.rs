//! `numanos vet` — the scheduler contract checker.
//!
//! Drives a scheduler (or every registered scheduler) through synthetic
//! probe contexts — victim-list permutations across several topology
//! presets, [`SpawnCtx`]/[`ResumeCtx`]/[`StealCand`] fixtures, and
//! replayed [`SchedEvent`] streams — and verifies the hook contract the
//! engine depends on.  Each violated rule is a stable diagnostic code:
//!
//! | code   | severity | contract rule                                             |
//! |--------|----------|-----------------------------------------------------------|
//! | VET001 | error    | `victim_order` emitted a duplicate victim                 |
//! | VET002 | error    | `victim_order` emitted an id outside the victim list      |
//! | VET003 | error    | `full_sweep=true` but a sweep missed victims              |
//! | VET004 | error    | `steal_bias` injected a victim absent from the sweep      |
//! | VET005 | error    | `steal_bias` duplicated a victim                          |
//! | VET006 | error    | `place` returned an out-of-range home node                |
//! | VET007 | error    | `resume` returned an out-of-range home node               |
//! | VET008 | error    | `observes=false` but behaviour changed with observe driven|
//! | VET009 | error    | factory failed on declared defaults / undeclared param    |
//! | VET010 | error    | `ParamInfo` default outside its declared range            |
//! | VET011 | error    | same-seed replay produced different decisions             |
//! | VET012 | warning  | `places=false` with inert placement knobs declared        |
//!
//! Vet is read-only over the registry: it builds throwaway instances via
//! the same [`build`] path the engine uses and never mutates shared
//! state, so it is safe to run in-process before a sweep.

use anyhow::Result;

use super::{Diagnostic, Severity};
use crate::coordinator::sched::{
    build, build_victim_lists, resolve_name, scheduler_infos, scheduler_names, Placement,
    ResumeCtx, SchedEvent, SchedSpec, Scheduler, SpawnCtx, StealCand, VictimList,
};
use crate::simnuma::Region;
use crate::topology::Topology;
use crate::util::SplitMix64;

/// Topology presets vet probes against: the paper's 16-core NUMA box, a
/// 16-node mesh, and the fat-tree Altix — distinct hop structures so
/// hierarchical/bounded strategies see non-trivial victim groupings.
pub const PROBE_TOPOS: &[&str] = &["x4600", "tile16", "altix16"];

/// Per-(topo) thread counts to probe (clamped to the core count).
const PROBE_THREADS: &[usize] = &[2, 5, 16];

/// Seeds per probe point.
const PROBE_SEEDS: u64 = 3;

/// Vet every registered scheduler (registration order).  The returned
/// list aggregates each scheduler's findings; an empty list is a clean
/// pass.
pub fn vet_all() -> Result<Vec<Diagnostic>> {
    let mut out = Vec::new();
    for name in scheduler_names() {
        out.extend(vet_scheduler(&name)?);
    }
    Ok(out)
}

/// Vet one scheduler by name or alias.  Errors only on an unknown name;
/// contract violations come back as diagnostics.  At most one
/// diagnostic per code is reported (the first triggering probe context)
/// so a systematically broken hook does not flood the output.
pub fn vet_scheduler(name: &str) -> Result<Vec<Diagnostic>> {
    let canonical = resolve_name(name)?;
    let mut v = Vetter::new(&canonical);

    // --- static checks: declared parameters ---------------------------
    let info = scheduler_infos()
        .into_iter()
        .find(|i| i.name == canonical)
        .expect("resolved names come from the registry");
    for p in &info.params {
        if !p.default.is_finite() || !(p.min <= p.default && p.default <= p.max) {
            v.report(
                "VET010",
                Severity::Error,
                "-",
                format!(
                    "parameter '{}' default {} outside declared range {}..={}",
                    p.name, p.default, p.min, p.max
                ),
            );
        }
    }

    // --- build with declared defaults (catches undeclared params) -----
    let sched = match build(&SchedSpec::new(&canonical)) {
        Ok(s) => s,
        Err(e) => {
            v.report(
                "VET009",
                Severity::Error,
                "-",
                format!("factory failed on declared defaults: {e:#}"),
            );
            return Ok(v.diags);
        }
    };
    let desc = sched.descriptor();

    if !desc.places && (desc.min_hint_bytes > 0 || desc.spawn_batch > 1) {
        v.report(
            "VET012",
            Severity::Warning,
            "-",
            format!(
                "places=false but min_hint_bytes={} spawn_batch={} — the engine never \
                 consults these without a place hook",
                desc.min_hint_bytes, desc.spawn_batch
            ),
        );
    }

    // --- dynamic probes -----------------------------------------------
    // A strategy that never emits victims anywhere is stealing-free by
    // construction (serial baseline, shared-FIFO breadth-first); the
    // full-sweep coverage rule only binds schedulers that actually sweep.
    let mut emitted_any = false;
    let mut coverage_miss: Option<(String, String)> = None;

    for topo_name in PROBE_TOPOS {
        let topo = Topology::by_name(topo_name)?;
        let nodes = topo.num_nodes();
        let mut thread_axis: Vec<usize> =
            PROBE_THREADS.iter().map(|&t| t.min(topo.num_cores())).collect();
        thread_axis.dedup();
        for threads in thread_axis {
            let cores: Vec<usize> = (0..threads).collect();
            let vls = build_victim_lists(&topo, &cores);
            for (w, vl) in vls.iter().enumerate() {
                for seed in 0..PROBE_SEEDS {
                    let ctx = format!("{topo_name} threads={threads} worker={w} seed={seed}");
                    let mut rng = SplitMix64::new(seed.wrapping_mul(0x9E37).wrapping_add(w as u64));
                    let mut order = Vec::new();
                    sched.victim_order(vl, &mut rng, &mut order);
                    emitted_any |= !order.is_empty();
                    check_order(&mut v, &ctx, vl, w, &order);
                    if desc.full_sweep && coverage_miss.is_none() && order.len() < vl.total()
                    {
                        let missing = vl.total() - order.len();
                        coverage_miss = Some((
                            ctx.clone(),
                            format!(
                                "full_sweep=true but the order covered {} of {} victims \
                                 ({missing} missed)",
                                order.len(),
                                vl.total()
                            ),
                        ));
                    }
                    if desc.places {
                        check_steal_bias(&mut v, &ctx, sched.as_ref(), vl, w, nodes);
                    }
                }
            }
            if desc.places {
                check_placement(&mut v, topo_name, sched.as_ref(), &desc, threads, nodes);
            }
        }
    }

    if emitted_any {
        if let Some((ctx, msg)) = coverage_miss {
            v.report("VET003", Severity::Error, &ctx, msg);
        }
    } else if desc.full_sweep && !desc.shared_queue() && !desc.overhead_free {
        v.report(
            "VET003",
            Severity::Error,
            "-",
            "full_sweep=true but victim_order never emitted a single victim".to_string(),
        );
    }

    // --- behavioural replays: determinism + observe gating -------------
    let topo = Topology::by_name(PROBE_TOPOS[0])?;
    let threads = 8.min(topo.num_cores());
    let cores: Vec<usize> = (0..threads).collect();
    let vls = build_victim_lists(&topo, &cores);

    // Replay on fresh instances: the probe loops above already drove
    // `sched`, and a scheduler is only required to be deterministic for
    // identical call histories.
    let fresh = |v: &mut Vetter| -> Option<Box<dyn Scheduler>> {
        match build(&SchedSpec::new(&canonical)) {
            Ok(s) => Some(s),
            Err(e) => {
                v.report(
                    "VET009",
                    Severity::Error,
                    "-",
                    format!("factory failed on a rebuild with identical defaults: {e:#}"),
                );
                None
            }
        }
    };
    let a = match fresh(&mut v) {
        Some(a) => transcript(a.as_ref(), &vls, topo.num_nodes(), true),
        None => return Ok(v.diags),
    };
    if let Some(b) = fresh(&mut v) {
        let bt = transcript(b.as_ref(), &vls, topo.num_nodes(), true);
        if let Some((i, la, lb)) = first_divergence(&a, &bt) {
            v.report(
                "VET011",
                Severity::Error,
                &format!("{} threads={threads} step={i}", PROBE_TOPOS[0]),
                format!("same-seed replay diverged: '{la}' vs '{lb}'"),
            );
        }
    }
    if !desc.observes {
        if let Some(c) = fresh(&mut v) {
            let ct = transcript(c.as_ref(), &vls, topo.num_nodes(), false);
            if let Some((i, la, lc)) = first_divergence(&a, &ct) {
                v.report(
                    "VET008",
                    Severity::Error,
                    &format!("{} threads={threads} step={i}", PROBE_TOPOS[0]),
                    format!(
                        "observes=false but stubbing observe changed decisions: \
                         '{la}' vs '{lc}'"
                    ),
                );
            }
        }
    }

    Ok(v.diags)
}

/// Diagnostic accumulator: first context per code wins.
struct Vetter {
    subject: String,
    diags: Vec<Diagnostic>,
}

impl Vetter {
    fn new(subject: &str) -> Self {
        Self { subject: subject.to_string(), diags: Vec::new() }
    }

    fn report(&mut self, code: &'static str, sev: Severity, context: &str, message: String) {
        if self.diags.iter().any(|d| d.code == code) {
            return;
        }
        self.diags.push(match sev {
            Severity::Error => Diagnostic::error(code, &self.subject, context, message),
            Severity::Warning => Diagnostic::warning(code, &self.subject, context, message),
        });
    }
}

/// The victims a worker's list actually contains.
fn victim_set(vl: &VictimList) -> Vec<usize> {
    vl.groups.iter().flat_map(|(_, g)| g.iter().copied()).collect()
}

/// VET001/VET002: emitted order must be a duplicate-free subset of the
/// victim list (which never contains the sweeping worker itself).
fn check_order(v: &mut Vetter, ctx: &str, vl: &VictimList, me: usize, order: &[usize]) {
    let allowed = victim_set(vl);
    let mut seen = Vec::with_capacity(order.len());
    for &t in order {
        if seen.contains(&t) {
            v.report(
                "VET001",
                Severity::Error,
                ctx,
                format!("victim_order emitted victim {t} twice"),
            );
        } else {
            seen.push(t);
        }
        if !allowed.contains(&t) {
            let why = if t == me { "the sweeping worker itself" } else { "not in the victim list" };
            v.report(
                "VET002",
                Severity::Error,
                ctx,
                format!("victim_order emitted id {t} ({why})"),
            );
        }
    }
}

/// Synthetic steal-candidate set for one sweep: alternating affinity and
/// varying queue depths so bias hooks see both classes.
fn make_cands(vl: &VictimList) -> Vec<StealCand> {
    let mut cands = Vec::new();
    for (hops, group) in &vl.groups {
        for &t in group {
            let affine = if t % 2 == 0 { 2 } else { 0 };
            cands.push(StealCand::single(t, *hops, affine, 3 + (t as u32 % 5)));
        }
    }
    cands
}

/// VET004/VET005: `steal_bias` may reorder, filter, and raise `take`,
/// but never invent or duplicate victims (the engine drops offenders at
/// `engine.rs` steal_sweep — vet names the bug instead of masking it).
fn check_steal_bias(
    v: &mut Vetter,
    ctx: &str,
    sched: &dyn Scheduler,
    vl: &VictimList,
    _me: usize,
    nodes: usize,
) {
    let input = make_cands(vl);
    let offered: Vec<usize> = input.iter().map(|c| c.victim).collect();
    for thief_node in [0, nodes.saturating_sub(1)] {
        let mut cands = input.clone();
        sched.steal_bias(thief_node, &mut cands);
        let mut seen = Vec::with_capacity(cands.len());
        for c in &cands {
            if !offered.contains(&c.victim) {
                v.report(
                    "VET004",
                    Severity::Error,
                    ctx,
                    format!(
                        "steal_bias injected victim {} (thief_node={thief_node}); \
                         the hook may only reorder or filter the offered sweep",
                        c.victim
                    ),
                );
            }
            if seen.contains(&c.victim) {
                v.report(
                    "VET005",
                    Severity::Error,
                    ctx,
                    format!(
                        "steal_bias duplicated victim {} (thief_node={thief_node})",
                        c.victim
                    ),
                );
            } else {
                seen.push(c.victim);
            }
        }
    }
}

/// VET006/VET007: placement hooks must return home nodes the topology
/// actually has.  Fixtures sweep hint sizes across the descriptor's
/// `min_hint_bytes` floor and every resident-home node.
fn check_placement(
    v: &mut Vetter,
    topo_name: &str,
    sched: &dyn Scheduler,
    desc: &crate::coordinator::sched::SchedDescriptor,
    threads: usize,
    nodes: usize,
) {
    let floor = desc.min_hint_bytes.max(1);
    let sizes = [0u64, floor.saturating_sub(1), floor, floor.saturating_mul(4), 1 << 24];
    let homes: Vec<Option<usize>> = [None, Some(0), Some(nodes.saturating_sub(1))].to_vec();
    for worker_node in [0, nodes.saturating_sub(1)] {
        for &bytes in &sizes {
            for &home in &homes {
                let ctx = SpawnCtx {
                    worker: 0,
                    worker_node,
                    affinity: Region { addr: 1 << 20, bytes },
                    home,
                };
                if let Placement::HomeNode(n) = sched.place(&ctx) {
                    if n >= nodes {
                        v.report(
                            "VET006",
                            Severity::Error,
                            &format!("{topo_name} threads={threads}"),
                            format!(
                                "place returned HomeNode({n}) but the topology has \
                                 {nodes} nodes (hint {bytes}B, home {home:?})"
                            ),
                        );
                    }
                }
                let rctx = ResumeCtx {
                    releaser: 0,
                    owner: 1 % threads,
                    owner_node: worker_node,
                    home,
                };
                if let Placement::HomeNode(n) = sched.resume(&rctx) {
                    if n >= nodes {
                        v.report(
                            "VET007",
                            Severity::Error,
                            &format!("{topo_name} threads={threads}"),
                            format!(
                                "resume returned HomeNode({n}) but the topology has \
                                 {nodes} nodes (home {home:?})"
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// A scripted replay: interleaves victim orders, bias/placement queries,
/// and (optionally) observe events, recording every decision as a line.
/// Two schedulers given the same script and seeds must produce identical
/// transcripts (VET011); an `observes=false` scheduler must produce the
/// same transcript whether or not the events are delivered (VET008).
fn transcript(
    sched: &dyn Scheduler,
    vls: &[VictimList],
    nodes: usize,
    with_observe: bool,
) -> Vec<String> {
    let desc = sched.descriptor();
    let threads = vls.len();
    let mut lines = Vec::new();
    let events = [
        SchedEvent::Spawn { worker: 0 },
        SchedEvent::Steal { thief: 1 % threads, victim: 0, hops: 1, affine: true },
        SchedEvent::StealMiss { worker: 1 % threads },
        SchedEvent::Spawn { worker: 2 % threads },
        SchedEvent::Steal { thief: 0, victim: 2 % threads, hops: 2, affine: false },
        SchedEvent::StealMiss { worker: 0 },
    ];
    for (round, ev) in events.iter().enumerate() {
        for (w, vl) in vls.iter().enumerate() {
            let mut rng = SplitMix64::new((round as u64) << 8 | w as u64);
            let mut order = Vec::new();
            sched.victim_order(vl, &mut rng, &mut order);
            lines.push(format!("r{round} w{w} order={order:?}"));
            if desc.places {
                let mut cands = make_cands(vl);
                sched.steal_bias(w % nodes, &mut cands);
                let taken: Vec<(usize, u32)> =
                    cands.iter().map(|c| (c.victim, c.take)).collect();
                lines.push(format!("r{round} w{w} bias={taken:?}"));
                let p = sched.place(&SpawnCtx {
                    worker: w,
                    worker_node: w % nodes,
                    affinity: Region { addr: 1 << 20, bytes: desc.min_hint_bytes.max(4096) },
                    home: Some(round % nodes),
                });
                let r = sched.resume(&ResumeCtx {
                    releaser: w,
                    owner: (w + 1) % threads,
                    owner_node: (w + 1) % nodes,
                    home: Some(round % nodes),
                });
                lines.push(format!("r{round} w{w} place={p:?} resume={r:?}"));
            }
        }
        if with_observe {
            sched.observe(ev);
        }
    }
    lines
}

/// First index where two transcripts differ, with both lines.
fn first_divergence(a: &[String], b: &[String]) -> Option<(usize, String, String)> {
    for i in 0..a.len().max(b.len()) {
        let la = a.get(i).cloned().unwrap_or_else(|| "<missing>".into());
        let lb = b.get(i).cloned().unwrap_or_else(|| "<missing>".into());
        if la != lb {
            return Some((i, la, lb));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_vet_clean() {
        // Every builtin satisfies the contract it declares — the same
        // property CI pins via `numanos vet --all`.
        for name in crate::coordinator::sched::scheduler_names() {
            if !name.starts_with("test-") && !name.starts_with("vetbad-") {
                let diags = vet_scheduler(&name).unwrap();
                assert!(diags.is_empty(), "{name}: {diags:?}");
            }
        }
    }

    #[test]
    fn unknown_scheduler_errors() {
        assert!(vet_scheduler("no-such-strategy").is_err());
    }
}
