//! Checked engine mode: a sanitizer-style invariant layer.
//!
//! The engine and pool carry `debug_assert`s on load-bearing invariants
//! (event-queue occupancy, task conservation, `homed` summary counts).
//! Those vanish in `--release`, which is exactly where long sweeps run.
//! Checked mode promotes them into an always-on verification pass the
//! engine runs while it executes: read-only, so a checked run produces
//! **byte-identical** results to an unchecked one (proven in CI by
//! `bench --compare --fail-on-drift`), and any violation aborts with a
//! structured report instead of silently corrupting results.
//!
//! Enablement, in order of precedence:
//! * the `checked` cargo feature (compile-time; CI's tier-1 `analysis`
//!   job builds tests with `--features checked`),
//! * `cfg!(test)` — lib unit tests always run checked,
//! * the process-global runtime flag set by `--checked` on
//!   `run` / `sweep` / `bench`.

use std::sync::atomic::{AtomicBool, Ordering};

static RUNTIME_FLAG: AtomicBool = AtomicBool::new(false);

/// Is checked mode on for engines constructed from now on?
/// (Each engine samples this once, at construction.)
pub fn enabled() -> bool {
    cfg!(any(test, feature = "checked")) || RUNTIME_FLAG.load(Ordering::Relaxed)
}

/// Flip the runtime flag (the CLI's `--checked`).
pub fn set_enabled(on: bool) {
    RUNTIME_FLAG.store(on, Ordering::Relaxed);
}

/// One violated engine invariant, `CHK001`-style coded.  Codes are
/// stable and documented in the README diagnostic table.
#[derive(Clone, Debug)]
pub struct Violation {
    pub code: &'static str,
    /// The invariant, stated as what should have held.
    pub invariant: &'static str,
    /// What was actually observed.
    pub detail: String,
}

impl Violation {
    pub fn new(code: &'static str, invariant: &'static str, detail: String) -> Self {
        Self { code, invariant, detail }
    }
}

/// Render violations as the multi-line abort report the engine bails
/// with: one header line (grep-able), then one line per violation.
pub fn render_report(context: &str, violations: &[Violation]) -> String {
    let mut out = format!(
        "checked engine: {} invariant violation(s) at {context}",
        violations.len()
    );
    for v in violations {
        out.push_str(&format!("\n  [{}] {} — {}", v.code, v.invariant, v.detail));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tests_run_checked() {
        // cfg!(test) holds for lib unit tests, so the whole in-crate
        // engine test surface exercises the invariant layer.
        assert!(enabled());
    }

    #[test]
    fn report_renders_all_violations() {
        let vs = vec![
            Violation::new("CHK003", "spawned == completed + live", "5 != 3 + 1".into()),
            Violation::new("CHK009", "no pool tag desyncs", "2 desyncs".into()),
        ];
        let r = render_report("event 17 (worker 3, t=42)", &vs);
        assert!(r.contains("2 invariant violation(s)"));
        assert!(r.contains("[CHK003]"));
        assert!(r.contains("[CHK009]"));
        assert!(r.contains("event 17"));
    }
}
