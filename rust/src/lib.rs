//! # numanos — NUMA-aware OpenMP-style task runtime
//!
//! A full reproduction of *"Towards Efficient OpenMP Strategies for
//! Non-Uniform Architectures"* (O. Tahan, 2014) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: a
//!   task-centric OpenMP-style runtime (a NANOS analogue) with the paper's
//!   NUMA-aware thread→core priority allocation (§IV, Figs 2–4) and the
//!   DFWSPT / DFWSRPT NUMA-aware work-stealing schedulers (§VI), executed
//!   over a deterministic discrete-event NUMA machine simulator.
//! * **Layer 2 (`python/compile/model.py`)** — the BOTS compute leaves as
//!   JAX graphs, AOT-lowered to HLO text artifacts.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   numeric hot-spots (MXU-tiled matmul, FFT butterfly, LU blocks,
//!   bitonic compare-exchange, the Fig 2–4 priority math).
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! compute graphs once; [`runtime`] loads them through PJRT (`xla` crate)
//! and [`coordinator`] invokes them from task bodies when real compute is
//! requested (`--compute pjrt`).
//!
//! Module map (see `DESIGN.md` for the full inventory):
//!
//! | module | role |
//! |---|---|
//! | [`topology`] | NUMA fabric models (X4600 twisted ladder & friends) |
//! | [`analysis`] | static analysis: scheduler contract vetting (`numanos vet`), manifest linting (`numanos lint`), checked engine mode (`--checked`) |
//! | [`simnuma`]  | memory-system simulator: pluggable page placement (first-touch / interleave / bind / next-touch), caches, NUMA latencies, contention |
//! | [`coordinator`] | the runtime: tasks, pools, binding, priorities, the pluggable scheduler registry, event engine |
//! | [`bots`]     | the 11 BOTS benchmark task-graph generators |
//! | [`runtime`]  | PJRT artifact loading + execution (the AOT bridge) |
//! | [`metrics`]  | run statistics, speedup tables, paper reference data |
//! | [`harness`]  | figure regeneration: the paper figures as sweep data |
//! | [`bench`]    | pinned perf-trajectory suite (`numanos bench`, `BENCH_*.json`) |
//! | [`spec`]     | the experiment API: `RunSpec`, `Session`, `Sweep`, manifests |
//! | [`store`]    | content-addressed result store: persistent cell cache, `numanos serve` spool service |
//! | [`serde`]    | self-contained JSON/TOML (de)serialization |
//! | [`config`]   | legacy run configuration + tiny key=value config file parser |
//! | [`util`]     | deterministic PRNG and misc helpers |
//!
//! The experiment surface is the [`spec`] module: build a validated
//! [`RunSpec`], hand it to a [`Session`] (which memoizes serial
//! baselines), or expand whole grids as [`Sweep`]s:
//!
//! ```
//! use numanos::{RunSpec, Session, Policy};
//!
//! let spec = RunSpec::builder()
//!     .bench("fib")
//!     .size(numanos::config::Size::Small)
//!     .policy(Policy::Dfwspt)
//!     .numa()
//!     .threads(8)
//!     .build()
//!     .unwrap();
//! let record = Session::new().run(&spec).unwrap();
//! assert!(record.speedup > 0.0 && record.stats.makespan > 0);
//! ```

pub mod analysis;
pub mod bench;
pub mod bots;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod metrics;
pub mod runtime;
pub mod serde;
pub mod simnuma;
pub mod spec;
pub mod store;
pub mod topology;
pub mod util;

pub use config::RunConfig;
pub use coordinator::binding::BindPolicy;
pub use coordinator::runtime::Runtime;
pub use coordinator::sched::{Policy, SchedSpec, Scheduler};
pub use simnuma::MemSpec;
pub use spec::{ExperimentManifest, RunRecord, RunSpec, Session, Sweep};
pub use store::ResultStore;
pub use topology::Topology;
