//! Thread→core binding — where the paper's §IV allocation decisions land.
//!
//! Two policies:
//!
//! * [`BindPolicy::Linear`] — the baseline: threads bound to cores in
//!   enumeration order (what an unpinned NANOS effectively gets on a quiet
//!   Linux box: master on core 0 of node 0, workers following).  On the
//!   X4600 node 0 is a *corner* — exactly the pathology §V.B describes.
//! * [`BindPolicy::NumaAware`] — the paper's scheme: master binds to the
//!   highest-priority core (ties broken randomly); each subsequent worker
//!   goes as close to the master as possible, preferring higher-priority
//!   cores among equidistant ones, random among full ties.

use crate::coordinator::priority::{core_priorities, PriorityAlloc};
use crate::topology::Topology;
use crate::util::SplitMix64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BindPolicy {
    Linear,
    NumaAware,
}

impl BindPolicy {
    pub fn name(self) -> &'static str {
        match self {
            BindPolicy::Linear => "linear",
            BindPolicy::NumaAware => "numa",
        }
    }

    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "linear" | "baseline" => BindPolicy::Linear,
            "numa" | "numa-aware" => BindPolicy::NumaAware,
            other => anyhow::bail!("unknown bind policy '{other}' (linear|numa)"),
        })
    }
}

/// The outcome: `cores[t]` is the core thread `t` runs on; thread 0 is the
/// master.
#[derive(Clone, Debug)]
pub struct Binding {
    pub cores: Vec<usize>,
    pub priorities: Option<PriorityAlloc>,
}

impl Binding {
    pub fn master_core(&self) -> usize {
        self.cores[0]
    }
}

/// Bind `threads` threads per `policy`.  Panics if more threads than cores
/// (the paper never oversubscribes; neither do we).
pub fn bind_threads(
    topo: &Topology,
    threads: usize,
    policy: BindPolicy,
    rng: &mut SplitMix64,
) -> Binding {
    assert!(threads >= 1 && threads <= topo.num_cores(), "1..=cores threads");
    match policy {
        BindPolicy::Linear => Binding {
            cores: (0..threads).collect(),
            priorities: None,
        },
        BindPolicy::NumaAware => {
            let pr = core_priorities(topo);
            let cores = bind_with_scores(topo, threads, &pr.scores, rng);
            Binding { cores, priorities: Some(pr) }
        }
    }
}

/// The §IV placement given an arbitrary per-core score vector (used by the
/// NumaAware policy and by the priority-ablation bench with V1-only or
/// flat scores): master on the best core (random among ties), each worker
/// as close to the master as possible, higher score among equidistant
/// cores, random among full ties.
pub fn bind_with_scores(
    topo: &Topology,
    threads: usize,
    scores: &[f64],
    rng: &mut SplitMix64,
) -> Vec<usize> {
    assert_eq!(scores.len(), topo.num_cores());
    let mut cores = Vec::with_capacity(threads);
    let mut taken = vec![false; topo.num_cores()];

    // Master: highest score, random among exact ties.
    let best_score = scores.iter().cloned().fold(f64::MIN, f64::max);
    let best: Vec<usize> = (0..topo.num_cores())
        .filter(|&c| (scores[c] - best_score).abs() < 1e-9)
        .collect();
    let master = best[rng.gen_range(best.len() as u64) as usize];
    cores.push(master);
    taken[master] = true;

    // Workers: nearest to master, then higher score, then random.
    for _ in 1..threads {
        let mut cands: Vec<usize> = (0..topo.num_cores()).filter(|&c| !taken[c]).collect();
        let key = |c: usize| (topo.core_hops(master, c), -scores[c]);
        let best_key = cands
            .iter()
            .map(|&c| key(c))
            .min_by(|a, b| a.partial_cmp(b).unwrap())
            .unwrap();
        cands.retain(|&c| {
            let k = key(c);
            k.0 == best_key.0 && (k.1 - best_key.1).abs() < 1e-9
        });
        let pick = cands[rng.gen_range(cands.len() as u64) as usize];
        cores.push(pick);
        taken[pick] = true;
    }
    cores
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_is_identity() {
        let topo = Topology::x4600();
        let mut rng = SplitMix64::new(1);
        let b = bind_threads(&topo, 6, BindPolicy::Linear, &mut rng);
        assert_eq!(b.cores, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(b.master_core(), 0);
    }

    #[test]
    fn numa_master_is_central_on_x4600() {
        let topo = Topology::x4600();
        for seed in 0..10 {
            let mut rng = SplitMix64::new(seed);
            let b = bind_threads(&topo, 16, BindPolicy::NumaAware, &mut rng);
            let node = topo.node_of(b.master_core());
            assert!((2..=5).contains(&node), "master node {node} not central");
        }
    }

    #[test]
    fn numa_binding_is_compact() {
        // mean pairwise distance of the chosen 8 cores must beat linear's
        let topo = Topology::x4600();
        let mut rng = SplitMix64::new(3);
        let numa = bind_threads(&topo, 8, BindPolicy::NumaAware, &mut rng);
        let linear = bind_threads(&topo, 8, BindPolicy::Linear, &mut rng);
        let mean = |cores: &[usize]| {
            let mut s = 0.0;
            for &a in cores {
                for &b in cores {
                    s += topo.core_hops(a, b) as f64;
                }
            }
            s / (cores.len() * cores.len()) as f64
        };
        assert!(
            mean(&numa.cores) <= mean(&linear.cores),
            "numa {:?} vs linear {:?}",
            numa.cores,
            linear.cores
        );
    }

    #[test]
    fn no_duplicate_cores() {
        let topo = Topology::altix16();
        let mut rng = SplitMix64::new(5);
        let b = bind_threads(&topo, 20, BindPolicy::NumaAware, &mut rng);
        let mut sorted = b.cores.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn workers_fill_masters_node_first() {
        let topo = Topology::x4600();
        let mut rng = SplitMix64::new(7);
        let b = bind_threads(&topo, 2, BindPolicy::NumaAware, &mut rng);
        assert_eq!(
            topo.node_of(b.cores[0]),
            topo.node_of(b.cores[1]),
            "second thread shares the master's node"
        );
    }

    #[test]
    fn deterministic_under_seed() {
        let topo = Topology::x4600();
        let a = bind_threads(&topo, 12, BindPolicy::NumaAware, &mut SplitMix64::new(9));
        let b = bind_threads(&topo, 12, BindPolicy::NumaAware, &mut SplitMix64::new(9));
        assert_eq!(a.cores, b.cores);
    }

    #[test]
    #[should_panic]
    fn oversubscription_rejected() {
        let topo = Topology::dual(2);
        bind_threads(&topo, 5, BindPolicy::Linear, &mut SplitMix64::new(0));
    }
}
