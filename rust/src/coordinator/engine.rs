#![deny(clippy::unwrap_used)]
//! Discrete-event execution engine.
//!
//! Simulates a team of worker threads (one per bound core) executing an
//! OpenMP-style task graph under a [`Scheduler`], charging simulated time
//! for every compute unit, memory touch ([`MemSim`]), queue operation,
//! spawn, probe and steal.  Events are processed in global virtual-time
//! order (ties FIFO), all randomness is seeded — a run is a pure function
//! of `(workload, topology, cost model, scheduler, binding, seed)`.
//!
//! The engine never matches on a policy enum: it caches the scheduler's
//! [`SchedDescriptor`] (queue discipline, steal end, overhead accounting),
//! asks [`Scheduler::victim_order`] for each steal sweep's visiting
//! order, and reports spawns, steals and failed sweeps back through
//! [`Scheduler::observe`] so adaptive strategies can react.  For
//! schedulers that opt into placement ([`SchedDescriptor::places`]),
//! three locality hooks additionally engage: every spawn is routed
//! through [`Scheduler::place`] (a [`Placement::HomeNode`] answer pushes
//! the child onto a worker bound to its data's home node while the
//! parent keeps running), every steal sweep through
//! [`Scheduler::steal_bias`] (victims' per-node resident-home summaries
//! let the strategy probe work homed near the thief first, and a
//! [`StealCand::take`] above 1 drains a *batch* from the victim's back
//! end under one lock — the thief runs the first task and requeues the
//! rest locally), and every tied-continuation release through
//! [`Scheduler::resume`] (a redirected continuation lands in the home
//! node's *mailbox*, drained by whichever team member idles first: own
//! stack → node mailbox → steal sweep).  The home node of each
//! affinity-hinted spawn is resolved once and cached on the task, so the
//! hooks never re-sample the page table.
//!
//! ## Semantics (mirroring NANOS)
//!
//! * **Tied tasks**: a task suspended at its `taskwait` resumes on the
//!   worker that started it (the continuation is pushed to that worker's
//!   pool when the last child completes).  Placing schedulers may relax
//!   this through [`Scheduler::resume`]; the new runner then owns it.
//! * **Depth-first policies** (`serial/cilk/wf/dfwspt/dfwsrpt`): `Spawn`
//!   suspends the parent (pushed to the worker's own pool front) and the
//!   worker continues with the child immediately.
//! * **Breadth-first**: `Spawn` appends the child to the shared FIFO and
//!   the parent keeps running.
//! * **Idle protocol**: pop own pool (or shared FIFO) → sweep victims in
//!   the policy's order → sleep; a push signals one sleeper (staggered,
//!   futex-style — see [`Engine::wake_sleepers`]).
//!
//! ## Fidelity note
//!
//! A worker executes one scheduling quantum (acquire, or run-to-boundary)
//! per event; its clock may advance past other workers' pending events
//! within the quantum, so shared-resource state (pool locks, memory
//! controllers) is causal at quantum granularity, not per-access.  Quanta
//! are bounded by task boundaries (spawn/wait/completion), i.e. a few µs —
//! far below the effects being measured (DESIGN.md §2).

use anyhow::Result;

use crate::coordinator::pool::Pool;
use crate::coordinator::sched::{
    dfwspt, Placement, ResumeCtx, SchedDescriptor, SchedEvent, Scheduler, SpawnCtx, StealCand,
    StealEnd, VictimList,
};
use crate::coordinator::task::{
    Action, BodyCtx, TaskArena, TaskId, TaskState, Workload, NO_HOME,
};
use crate::metrics::RunStats;
use crate::runtime::ExecEngine;
use crate::simnuma::MemSim;
use crate::topology::Topology;
use crate::util::{SplitMix64, Time};

/// The engine-visible slice of the cost model, copied once at
/// construction.  `MemSim::cost_model()` hands out a borrow of the
/// memory simulator, so every scheduling charge used to re-borrow it —
/// and `steal_sweep` cloned the *whole* model (line sizes, latency
/// tables and all) per sweep to appease the borrow checker.  The eight
/// plain `Time` fields here cover every charge the engine makes;
/// memory-access costs stay inside [`MemSim::access`].
#[derive(Clone, Copy)]
struct EngineCosts {
    compute_per_unit: Time,
    queue_op: Time,
    shared_queue_op: Time,
    spawn_cost: Time,
    probe_base: Time,
    probe_per_hop: Time,
    steal_base: Time,
    steal_per_hop: Time,
}

impl EngineCosts {
    fn from_model(cm: &crate::simnuma::CostModel) -> Self {
        Self {
            compute_per_unit: cm.compute_per_unit,
            queue_op: cm.queue_op,
            shared_queue_op: cm.shared_queue_op,
            spawn_cost: cm.spawn_cost,
            probe_base: cm.probe_base,
            probe_per_hop: cm.probe_per_hop,
            steal_base: cm.steal_base,
            steal_per_hop: cm.steal_per_hop,
        }
    }
}

/// Pending-event queue specialized to the engine's dispatch invariant:
/// every worker has at most one scheduled event at any time (each
/// `schedule` call either re-arms the worker whose quantum just ran or
/// wakes a sleeping one, and both are slot-free at that point).  That
/// bounds the queue at `workers` entries, so a flat per-worker slot
/// array replaces the old `BinaryHeap<Reverse<(Time, u64, usize)>>`:
/// push is a store, pop is a branch-predictable linear min-scan over a
/// few cache lines — no sift-up/sift-down per event, no allocation
/// ever.  Pop order is exactly the heap's: minimal `(time, seq)` wins,
/// and seqs are unique, so the worker id never tie-breaks.
struct EventQueue {
    /// `(time, seq)` per worker; [`EventQueue::EMPTY`] = none pending.
    slots: Vec<(Time, u64)>,
    pending: usize,
}

impl EventQueue {
    const EMPTY: (Time, u64) = (Time::MAX, u64::MAX);

    fn with_workers(n: usize) -> Self {
        Self { slots: vec![Self::EMPTY; n], pending: 0 }
    }

    #[inline]
    fn push(&mut self, w: usize, t: Time, seq: u64) {
        debug_assert_eq!(self.slots[w], Self::EMPTY, "worker {w} double-scheduled");
        self.slots[w] = (t, seq);
        self.pending += 1;
    }

    #[inline]
    fn pop(&mut self) -> Option<(Time, u64, usize)> {
        if self.pending == 0 {
            return None;
        }
        let mut best = 0;
        for w in 1..self.slots.len() {
            if self.slots[w] < self.slots[best] {
                best = w;
            }
        }
        let (t, seq) = std::mem::replace(&mut self.slots[best], Self::EMPTY);
        self.pending -= 1;
        Some((t, seq, best))
    }
}

/// Engine knobs (assembled by [`crate::spec::Session`]).
pub struct EngineConfig {
    /// Per-thread bound core ids (index = thread id, 0 = master).
    pub cores: Vec<usize>,
    /// Extra per-queue-op penalty per thread when its runtime data is
    /// remote (paper §IV: runtime structures on the thread's own node).
    pub rt_penalty: Vec<Time>,
    pub seed: u64,
}

struct Worker {
    core: usize,
    clock: Time,
    current: Option<TaskId>,
    victims: VictimList,
    rng: SplitMix64,
    rt_penalty: Time,
    sleeping: bool,
    // stats
    work_time: Time,
    overhead_time: Time,
    tasks_run: u64,
    steals: u64,
    steal_attempts: u64,
    steal_hops: u64,
}

/// The engine; one instance per run.
pub struct Engine<'a> {
    sched: &'a dyn Scheduler,
    /// Cached [`Scheduler::descriptor`] (hot-path reads).
    desc: SchedDescriptor,
    topo: Topology,
    workload: &'a mut dyn Workload,
    exec: Option<&'a mut ExecEngine>,
    mem: MemSim,
    arena: TaskArena,
    workers: Vec<Worker>,
    pools: Vec<Pool>,
    shared: Pool,
    /// Per-node continuation mailboxes (placing schedulers only): a
    /// redirected tied-continuation release lands here instead of in one
    /// pre-picked worker's deque, and every worker drains its own node's
    /// mailbox after its own pool, before sweeping victims — so
    /// whichever same-node team member idles first picks the homed
    /// continuation up.  Indexed by node; only nodes with bound workers
    /// ever receive mail (releases route through [`Engine::home_worker`]).
    /// Stock schedulers never probe nor fill these.
    mailboxes: Vec<Pool>,
    /// thread-to-thread hop distances (precomputed from the binding).
    thops: Vec<Vec<u8>>,
    /// node -> worker ids bound there (placement targets).
    node_workers: Vec<Vec<usize>>,
    /// node -> candidate home nodes: every node with bound workers at the
    /// minimal hop distance (identity when the node itself has some), in
    /// ascending node-id order.  Usually one entry; a worker-less node
    /// equidistant from several teams lists them all, so
    /// [`Engine::home_worker`] can pick the least-loaded team instead of
    /// always funnelling pushes to the lowest-numbered one.
    place_cands: Vec<Vec<usize>>,
    /// Scheduling charges, copied out of the cost model once (hot path —
    /// see [`EngineCosts`]).
    costs: EngineCosts,
    events: EventQueue,
    seq: u64,
    live: u64,
    makespan: Time,
    kernel_calls: u64,
    sim_events: u64,
    pushed_home: u64,
    affinity_hits: u64,
    /// Successful steals whose stolen task was homed on the thief's node.
    affine_steals: u64,
    /// Tied continuations released to a home-node worker instead of the
    /// first owner (the `resume` hook redirected).
    homed_resumes: u64,
    /// Steals that transferred more than one task (steal-half batching).
    batch_steals: u64,
    /// Extra tasks moved by batched steals (beyond the one the thief
    /// runs; each was requeued on the thief's own pool).
    tasks_migrated: u64,
    /// Continuations picked up from a per-node mailbox.
    mailbox_hits: u64,
    victim_buf: Vec<usize>,
    /// Scratch for steal-bias candidate snapshots (allocation reuse).
    cand_buf: Vec<StealCand>,
    /// Per-victim batch sizes aligned with `victim_buf` (empty = all 1).
    take_buf: Vec<u32>,
    /// Scratch for multi-pop steal batches (allocation reuse).
    drain_buf: Vec<TaskId>,
    /// Coalesced same-target home pushes awaiting one batched transfer
    /// ([`SchedDescriptor::spawn_batch`] > 1 only; always empty between
    /// events — every quantum exit path flushes).
    pending_home: Vec<TaskId>,
    /// Target worker of the buffered pushes (meaningless while
    /// `pending_home` is empty).
    pending_target: usize,
    wake_rr: usize,
    /// Checked mode ([`crate::analysis::checked`]) sampled once at
    /// construction: run the invariant layer after every event.
    checked: bool,
    /// Last popped event time (checked mode's monotonicity watermark;
    /// side state only — never feeds a scheduling decision).
    chk_last_event: Time,
}

impl<'a> Engine<'a> {
    pub fn new(
        cfg: EngineConfig,
        mem: MemSim,
        victims: Vec<VictimList>,
        sched: &'a dyn Scheduler,
        workload: &'a mut dyn Workload,
        exec: Option<&'a mut ExecEngine>,
    ) -> Self {
        let topo = mem.topo().clone();
        let mut root_rng = SplitMix64::new(cfg.seed);
        let workers: Vec<Worker> = cfg
            .cores
            .iter()
            .zip(victims)
            .enumerate()
            .map(|(i, (&core, victims))| Worker {
                core,
                clock: 0,
                current: None,
                victims,
                rng: root_rng.fork(i as u64),
                rt_penalty: cfg.rt_penalty.get(i).copied().unwrap_or(0),
                sleeping: false,
                work_time: 0,
                overhead_time: 0,
                tasks_run: 0,
                steals: 0,
                steal_attempts: 0,
                steal_hops: 0,
            })
            .collect();
        let n = workers.len();
        let thops = (0..n)
            .map(|a| (0..n).map(|b| topo.core_hops(workers[a].core, workers[b].core)).collect())
            .collect();
        let pools = (0..n).map(|_| Pool::new()).collect();
        let mut node_workers = vec![Vec::new(); topo.num_nodes()];
        for (i, wk) in workers.iter().enumerate() {
            node_workers[topo.node_of(wk.core)].push(i);
        }
        // every worker-bearing node at the minimal distance (not just the
        // first): nodes_by_distance sorts by (hops, id), so scanning the
        // leading distance group keeps the old single pick as cands[0]
        let place_cands: Vec<Vec<usize>> = (0..topo.num_nodes())
            .map(|node| {
                let by_dist = topo.nodes_by_distance(node);
                let nearest = by_dist
                    .iter()
                    .copied()
                    .find(|&m| !node_workers[m].is_empty())
                    .expect("a team has at least one bound worker");
                let d = topo.node_hops(node, nearest);
                by_dist
                    .into_iter()
                    .filter(|&m| !node_workers[m].is_empty() && topo.node_hops(node, m) == d)
                    .collect()
            })
            .collect();
        let mailboxes = (0..topo.num_nodes()).map(|_| Pool::new()).collect();
        let costs = EngineCosts::from_model(mem.cost_model());
        Self {
            sched,
            desc: sched.descriptor(),
            topo,
            workload,
            exec,
            mem,
            arena: TaskArena::new(),
            workers,
            pools,
            shared: Pool::new(),
            mailboxes,
            thops,
            node_workers,
            place_cands,
            costs,
            events: EventQueue::with_workers(n),
            seq: 0,
            live: 0,
            makespan: 0,
            kernel_calls: 0,
            sim_events: 0,
            pushed_home: 0,
            affinity_hits: 0,
            affine_steals: 0,
            homed_resumes: 0,
            batch_steals: 0,
            tasks_migrated: 0,
            mailbox_hits: 0,
            victim_buf: Vec::new(),
            cand_buf: Vec::new(),
            take_buf: Vec::new(),
            drain_buf: Vec::new(),
            pending_home: Vec::new(),
            pending_target: 0,
            wake_rr: 0,
            checked: crate::analysis::checked::enabled(),
            chk_last_event: 0,
        }
    }

    #[inline]
    fn schedule(&mut self, w: usize, t: Time) {
        self.seq += 1;
        self.events.push(w, t, self.seq);
    }

    /// Wake up to `budget` sleeping workers (condvar `signal`, not
    /// `broadcast`: one unit of new work wakes one waiter — waking the
    /// whole team for a single task is the thundering herd that would
    /// serialize everyone on the pool lock).  Wake-ups are staggered as a
    /// real futex wake chain is; a rotating start index keeps it fair.
    fn wake_sleepers(&mut self, now: Time, mut budget: usize) {
        let n = self.workers.len();
        let mut delay: Time = 0;
        for k in 0..n {
            if budget == 0 {
                break;
            }
            let i = (self.wake_rr + k) % n;
            if self.workers[i].sleeping {
                self.workers[i].sleeping = false;
                budget -= 1;
                delay += 120; // 0.12 us per woken thread
                let t = (now + delay).max(self.workers[i].clock);
                self.workers[i].clock = t;
                self.schedule(i, t);
            }
        }
        self.wake_rr = (self.wake_rr + 1) % n;
    }

    /// Targeted wake: rouse exactly `target` (who must be sleeping) at
    /// `now` plus the futex-wake latency.  Unlike [`Engine::wake_sleepers`]
    /// this neither scans nor advances the round-robin cursor — it is the
    /// "I know who this work is for" wake that `push_home` and homed /
    /// bounded-sweep continuation releases use.
    fn wake_worker(&mut self, target: usize, now: Time) {
        debug_assert!(self.workers[target].sleeping);
        self.workers[target].sleeping = false;
        let t = (now + 120).max(self.workers[target].clock);
        self.workers[target].clock = t;
        self.schedule(target, t);
    }

    /// Start or resume `tid` on worker `w`.  A pool can hold three flavours:
    /// fresh tasks (body not yet materialized), suspended parents (state
    /// `Pre`, mid-phase — what depth-first thieves steal), and released
    /// continuations (state `Post`).  Whoever runs the task now owns it
    /// (the tied-task resume target follows the thief, as in Cilk-style
    /// continuation stealing).
    fn start_task(&mut self, tid: TaskId, w: usize) {
        let inst = self.arena.get_mut(tid);
        inst.owner = w as u16;
        match inst.state {
            TaskState::Fresh => {
                inst.state = TaskState::Pre;
                inst.cursor = 0;
                let desc = inst.desc;
                // recycle the slot's previous action vectors (§Perf)
                let body = std::mem::take(&mut inst.body);
                let mut ctx = BodyCtx::with_body(body);
                self.workload.body(desc, &mut ctx);
                self.arena.get_mut(tid).body = ctx.finish();
            }
            // suspended parent resuming, or an unblocked continuation:
            // cursor already points at the right action
            TaskState::Pre | TaskState::Post => {}
            s => panic!("starting task in state {s:?}"),
        }
        self.workers[w].current = Some(tid);
    }

    /// Run the engine to completion; returns statistics.
    pub fn run(mut self, root: crate::coordinator::task::TaskDesc) -> Result<RunStats> {
        let root_id = self.arena.create(root, None, 0);
        self.live = 1;
        self.start_task(root_id, 0);
        self.schedule(0, self.workers[0].clock);
        // everyone else parks until work appears
        for w in self.workers.iter_mut().skip(1) {
            w.sleeping = true;
        }

        while let Some((t, _, w)) = self.events.pop() {
            self.sim_events += 1;
            if self.workers[w].clock < t {
                self.workers[w].clock = t;
            }
            if self.workers[w].current.is_some() {
                self.run_quantum(w)?;
            } else {
                self.acquire(w);
            }
            if self.checked {
                self.verify_invariants(t, w)?;
            }
            if self.live == 0 {
                break;
            }
        }
        if self.live != 0 {
            anyhow::bail!(
                "engine deadlock: {} tasks live with no runnable worker (scheduler {})",
                self.live,
                self.sched.name()
            );
        }
        if let Some(exec) = self.exec.as_deref_mut() {
            self.workload.verify(exec)?;
        }
        Ok(self.into_stats())
    }

    /// Idle worker tries to find work: own pool / shared FIFO, then steal,
    /// else sleep.
    fn acquire(&mut self, w: usize) {
        let free = self.desc.overhead_free;
        if self.desc.shared_queue() {
            let op = if free { 0 } else { self.costs.shared_queue_op };
            let now = self.workers[w].clock;
            let cost = self.shared.lock(now, op);
            self.workers[w].clock += cost;
            self.workers[w].overhead_time += cost;
            if let Some(tid) = self.shared.pop_front() {
                self.start_task(tid, w);
                let t = self.workers[w].clock;
                self.schedule(w, t);
            } else {
                self.workers[w].sleeping = true;
            }
            return;
        }

        // own pool first (LIFO)
        let op = if free { 0 } else { self.costs.queue_op + self.workers[w].rt_penalty };
        let now = self.workers[w].clock;
        let cost = self.pools[w].lock(now, op);
        self.workers[w].clock += cost;
        self.workers[w].overhead_time += cost;
        if let Some(tid) = self.pools[w].pop_front() {
            self.start_task(tid, w);
            let t = self.workers[w].clock;
            self.schedule(w, t);
            return;
        }

        // Node mailbox second (places opt-in only): homed continuations
        // released toward this node wait here for *any* team member, and
        // draining them beats stealing remotely — the continuation's
        // pages live on this node by construction.  The emptiness check
        // is free (a shared counter read, like the sweep's probe target
        // selection); only an actual drain pays a queue op.  Stock
        // schedulers never reach this branch, keeping them byte-identical.
        if self.desc.places {
            let node = self.topo.node_of(self.workers[w].core);
            if !self.mailboxes[node].is_empty() {
                let op = self.costs.queue_op + self.workers[w].rt_penalty;
                let now = self.workers[w].clock;
                let cost = self.mailboxes[node].lock(now, op);
                self.workers[w].clock += cost;
                self.workers[w].overhead_time += cost;
                if let Some(tid) = self.mailboxes[node].pop_front() {
                    self.mailbox_hits += 1;
                    self.start_task(tid, w);
                    let t = self.workers[w].clock;
                    self.schedule(w, t);
                    return;
                }
            }
        }

        // steal sweep: the scheduler names the victims, in order
        let mut buf = std::mem::take(&mut self.victim_buf);
        let mut takes = std::mem::take(&mut self.take_buf);
        buf.clear();
        takes.clear();
        {
            let sched = self.sched;
            let wk = &mut self.workers[w];
            let mut rng = wk.rng.clone();
            sched.victim_order(&wk.victims, &mut rng, &mut buf);
            wk.rng = rng;
        }
        // Steal-bias hook (places opt-in only): snapshot each victim's
        // per-node resident-home summary and let the strategy reorder or
        // filter the sweep toward work homed near this thief — and set
        // per-victim batch sizes (`StealCand::take`, default 1).  The
        // summary is a word read per victim — no deque scan, no
        // simulated cost (like victim_order itself).
        if self.desc.places && !buf.is_empty() {
            let thief_node = self.topo.node_of(self.workers[w].core);
            let mut cands = std::mem::take(&mut self.cand_buf);
            cands.clear();
            cands.extend(buf.iter().map(|&v| StealCand {
                victim: v,
                hops: self.thops[w][v],
                affine: self.pools[v].homed_count(thief_node),
                queued: self.pools[v].len() as u32,
                take: 1,
            }));
            self.sched.steal_bias(thief_node, &mut cands);
            buf.clear();
            // a misbehaving custom hook cannot inject bogus victims, and
            // a victim returned twice is probed (and its lock charged)
            // once — first occurrence wins, so the hook's preferred
            // position is kept
            let n = self.workers.len();
            for c in &cands {
                if c.victim < n && c.victim != w && !buf.contains(&c.victim) {
                    buf.push(c.victim);
                    takes.push(c.take.max(1));
                }
            }
            self.cand_buf = cands;
        }
        let mut got = self.steal_sweep(w, &buf, &takes);
        if got.is_none() {
            if self.desc.observes {
                self.sched.observe(&SchedEvent::StealMiss { worker: w });
            }
            // Liveness net for *partial* sweeps (bounded / hierarchical
            // strategies may skip victims): a sleeper is only woken by a
            // future push, so the last awake worker must not park while
            // unprobed pools still hold tasks — nobody would be left to
            // issue the wake.  One fallback sweep in priority order
            // (closest first) restores full coverage.  A missed *full*
            // sweep implies every probed pool was empty (the sim is
            // sequential, so nothing refills between probe and check),
            // making the non-empty-pool test below exactly "work remains
            // that this sweep skipped" — for the stock schedulers it is
            // always false and the legacy path stays byte-identical.
            // Mailboxes get the same net: a remote node's mailbox is
            // normally drained by that node's team, but the last awake
            // worker grabs from any non-empty one rather than park on
            // live work (always empty under stock schedulers).
            let others_parked =
                (0..self.workers.len()).all(|i| i == w || self.workers[i].sleeping);
            if others_parked {
                if self.desc.places {
                    got = self.drain_any_mailbox(w);
                }
                if got.is_none() && self.pools.iter().any(|p| !p.is_empty()) {
                    buf.clear();
                    dfwspt::order(&self.workers[w].victims, &mut buf);
                    got = self.steal_sweep(w, &buf, &[]);
                }
            }
        }
        self.victim_buf = buf;
        self.take_buf = takes;
        match got {
            Some(tid) => {
                self.start_task(tid, w);
                let t = self.workers[w].clock;
                self.schedule(w, t);
            }
            None => {
                self.workers[w].sleeping = true;
            }
        }
    }

    /// Probe `order`'s victims in turn, charging probe/lock costs, and
    /// steal from the first non-empty pool (the scheduler's descriptor
    /// picks the deque end).  `takes` holds per-victim batch sizes
    /// aligned with `order` (empty = all 1, the stock single steal): a
    /// take of `k` drains up to `k` tasks from the victim's *back* end
    /// under one lock — the thief runs the first and requeues the rest
    /// on its own pool, paying `steal_base` plus a per-task distance
    /// transfer on the victim's lock and one local queue op for the
    /// requeue.  Front-end (Cilk THE) steals ignore the batch: taking a
    /// victim's hottest suspended parents in bulk would steal its
    /// working set, not balance load.  Reports the successful steal (the
    /// task the thief runs) to the scheduler's observe hook.
    fn steal_sweep(&mut self, w: usize, order: &[usize], takes: &[u32]) -> Option<TaskId> {
        let cm = self.costs;
        for (i, &v) in order.iter().enumerate() {
            let vhops = self.thops[w][v];
            let hops = vhops as Time;
            self.workers[w].steal_attempts += 1;
            let probe = cm.probe_base + hops * cm.probe_per_hop;
            self.workers[w].clock += probe;
            self.workers[w].overhead_time += probe;
            let avail = self.pools[v].len();
            if avail == 0 {
                continue;
            }
            let k = match self.desc.steal_end {
                StealEnd::Front => 1,
                StealEnd::Back => (takes.get(i).copied().unwrap_or(1).max(1) as usize).min(avail),
            };
            let now = self.workers[w].clock;
            let cost =
                self.pools[v].lock(now, cm.steal_base + (k as Time) * hops * cm.steal_per_hop);
            self.workers[w].clock += cost;
            self.workers[w].overhead_time += cost;
            let taken = match self.desc.steal_end {
                StealEnd::Front => self.pools[v].pop_front(),
                StealEnd::Back if k > 1 => {
                    let mut batch = std::mem::take(&mut self.drain_buf);
                    batch.clear();
                    self.pools[v].drain_back(k, &mut batch);
                    // pop order: the first drained task is exactly what a
                    // single pop_back would have returned — the thief
                    // runs it and requeues the remainder locally under
                    // one queue op, oldest nearest its own back end
                    let first = batch.first().copied();
                    if batch.len() > 1 {
                        let op = cm.queue_op + self.workers[w].rt_penalty;
                        let now = self.workers[w].clock;
                        let cost = self.pools[w].lock(now, op);
                        self.workers[w].clock += cost;
                        self.workers[w].overhead_time += cost;
                        for &t in batch.iter().skip(1).rev() {
                            // retag on push: re-read the arena's *current*
                            // home — a tag cached at the original queuing
                            // may have been re-resolved since
                            let home = self.arena.get(t).home;
                            self.pools[w].push_back(t, home);
                        }
                        self.batch_steals += 1;
                        self.tasks_migrated += (batch.len() - 1) as u64;
                    }
                    self.drain_buf = batch;
                    first
                }
                StealEnd::Back => self.pools[v].pop_back(),
            };
            if let Some(tid) = taken {
                self.workers[w].steals += 1;
                self.workers[w].steal_hops += hops;
                // a steal that lands work on its data's home node (tags
                // exist only under placing schedulers; stock stays 0)
                let home = self.arena.get(tid).home;
                let affine = home != NO_HOME
                    && home as usize == self.topo.node_of(self.workers[w].core);
                if affine {
                    self.affine_steals += 1;
                }
                if self.desc.observes {
                    self.sched.observe(&SchedEvent::Steal {
                        thief: w,
                        victim: v,
                        hops: vhops,
                        affine,
                    });
                }
                return Some(tid);
            }
        }
        None
    }

    /// Liveness fallback: the last awake worker drains the first
    /// non-empty mailbox (nearest node first), paying the same
    /// distance-scaled queue op a remote release does.  Normally inert —
    /// a mailbox push wakes a home-node sleeper, and busy home-node
    /// workers drain their mailbox on their next acquire — but a custom
    /// scheduler could strand mail on a node whose team never idles
    /// last.  Always empty (and never probed) under stock schedulers.
    fn drain_any_mailbox(&mut self, w: usize) -> Option<TaskId> {
        let my_node = self.topo.node_of(self.workers[w].core);
        // nearest non-empty mailbox, ties to the lower node id — the
        // same pick `nodes_by_distance` (sorted by (hops, id)) made,
        // without materializing the sorted node list per call
        let node = (0..self.mailboxes.len())
            .filter(|&n| !self.mailboxes[n].is_empty())
            .min_by_key(|&n| (self.topo.node_hops(my_node, n), n))?;
        let cm = self.costs;
        let hops = self.topo.node_hops(my_node, node) as Time;
        let op = cm.queue_op + hops * cm.steal_per_hop + self.workers[w].rt_penalty;
        let now = self.workers[w].clock;
        let cost = self.mailboxes[node].lock(now, op);
        self.workers[w].clock += cost;
        self.workers[w].overhead_time += cost;
        let tid = self.mailboxes[node].pop_front()?;
        self.mailbox_hits += 1;
        Some(tid)
    }

    /// Execute the current task until a boundary: spawn-switch (depth-
    /// first), wait-suspension, or completion.
    fn run_quantum(&mut self, w: usize) -> Result<()> {
        let free = self.desc.overhead_free;
        let tid = self.workers[w].current.expect("run_quantum without task");
        debug_assert!(self.pending_home.is_empty(), "push batch leaked across events");
        loop {
            // single arena access per step: copy the small Copy action out
            // so the arena can be mutated freely below (hot path — see
            // EXPERIMENTS.md §Perf)
            let (state, action) = {
                let inst = self.arena.get(tid);
                let list = match inst.state {
                    TaskState::Pre => &inst.body.pre,
                    TaskState::Post => &inst.body.post,
                    s => panic!("running task in state {s:?}"),
                };
                (inst.state, list.get(inst.cursor).copied())
            };
            match action {
                Some(Action::Compute(units)) => {
                    self.flush_pending(w);
                    let dt = units * self.costs.compute_per_unit;
                    self.workers[w].clock += dt;
                    self.workers[w].work_time += dt;
                    self.arena.get_mut(tid).cursor += 1;
                }
                Some(Action::Touch { region, write }) => {
                    self.flush_pending(w);
                    let core = self.workers[w].core;
                    let now = self.workers[w].clock;
                    let dt = self.mem.access(core, region, write, now);
                    self.workers[w].clock += dt;
                    self.workers[w].work_time += dt;
                    self.arena.get_mut(tid).cursor += 1;
                }
                Some(Action::Kernel(tag)) => {
                    self.flush_pending(w);
                    self.kernel_calls += 1;
                    if let Some(exec) = self.exec.as_deref_mut() {
                        self.workload.run_kernel(tag, exec)?;
                    }
                    self.arena.get_mut(tid).cursor += 1;
                }
                Some(Action::Spawn { desc, affinity }) => {
                    self.arena.get_mut(tid).cursor += 1;
                    if self.desc.observes {
                        self.sched.observe(&SchedEvent::Spawn { worker: w });
                    }
                    let spawn_cost = if free { 0 } else { self.costs.spawn_cost };
                    self.workers[w].clock += spawn_cost;
                    self.workers[w].overhead_time += spawn_cost;
                    let depth = self.arena.get(tid).depth + 1;
                    let child = self.arena.create(desc, Some(tid), depth);
                    self.live += 1;
                    self.arena.get_mut(tid).pending_children += 1;

                    // Placement hook: only schedulers whose descriptor
                    // opts in pay for it (stock strategies skip the home
                    // query and the hook entirely — the byte-parity
                    // guarantee for non-placing schedulers).
                    if self.desc.places
                        && !self.desc.shared_queue()
                        && affinity.bytes > 0
                        && affinity.bytes >= self.desc.min_hint_bytes
                    {
                        let worker_node = self.topo.node_of(self.workers[w].core);
                        let home = self.mem.home_node(affinity);
                        if home == Some(worker_node) {
                            self.affinity_hits += 1;
                        }
                        // cache the resolved home on the task: pool
                        // summaries, steal-bias and continuation homing
                        // all read this tag instead of re-sampling the
                        // page table
                        if let Some(h) = home.filter(|&h| h < NO_HOME as usize) {
                            self.arena.get_mut(child).home = h as u8;
                        }
                        let sctx = SpawnCtx { worker: w, worker_node, affinity, home };
                        if let Placement::HomeNode(node) = self.sched.place(&sctx) {
                            if let Some(target) = self.home_worker(node) {
                                if target != w {
                                    if self.desc.spawn_batch > 1 {
                                        // coalesce: sibling pushes to one
                                        // target share a single transfer
                                        self.queue_push_home(child, w, target);
                                    } else {
                                        self.push_home(child, w, target);
                                    }
                                    // parent keeps running: loop continues
                                    continue;
                                }
                            }
                        }
                    }

                    // the spawn takes the local path, so any coalesced
                    // pushes must land first (their simulated transfer
                    // precedes this spawn's queue op)
                    self.flush_pending(w);
                    if self.desc.shared_queue() {
                        let op = self.costs.shared_queue_op;
                        let now = self.workers[w].clock;
                        let cost = self.shared.lock(now, op);
                        self.workers[w].clock += cost;
                        self.workers[w].overhead_time += cost;
                        self.shared.push_back(child, NO_HOME);
                        let now = self.workers[w].clock;
                        self.wake_sleepers(now, 1);
                        // parent keeps running: loop continues
                    } else {
                        // depth-first: suspend parent, run child now
                        if !free {
                            let op = self.costs.queue_op + self.workers[w].rt_penalty;
                            let now = self.workers[w].clock;
                            let cost = self.pools[w].lock(now, op);
                            self.workers[w].clock += cost;
                            self.workers[w].overhead_time += cost;
                        }
                        let parent_home = self.arena.get(tid).home;
                        self.pools[w].push_front(tid, parent_home);
                        let now = self.workers[w].clock;
                        if !free {
                            self.wake_sleepers(now, 1);
                        }
                        self.start_task(child, w);
                        let t = self.workers[w].clock;
                        self.schedule(w, t);
                        return Ok(());
                    }
                }
                None => {
                    // phase boundary: the quantum may end here, so any
                    // coalesced pushes must land now
                    self.flush_pending(w);
                    match state {
                        TaskState::Pre => {
                            let inst = self.arena.get_mut(tid);
                            if inst.pending_children > 0 {
                                inst.state = TaskState::Waiting;
                                self.workers[w].current = None;
                                let t = self.workers[w].clock;
                                self.schedule(w, t);
                                return Ok(());
                            }
                            inst.state = TaskState::Post;
                            inst.cursor = 0;
                            // fall through: loop runs the post phase
                        }
                        TaskState::Post => {
                            // A combine phase may itself have spawned
                            // children; the task completes with them.
                            if self.arena.get(tid).pending_children > 0 {
                                self.arena.get_mut(tid).state = TaskState::WaitingFinal;
                            } else {
                                self.complete(tid, w);
                            }
                            self.workers[w].current = None;
                            if self.live > 0 {
                                let t = self.workers[w].clock;
                                self.schedule(w, t);
                            }
                            return Ok(());
                        }
                        s => panic!("phase end in state {s:?}"),
                    }
                }
            }
        }
    }

    /// The worker a [`Placement::HomeNode`] push targets: on the node
    /// itself when workers are bound there (else across the *nearest
    /// worker-bearing nodes* — all of them when several tie on distance),
    /// the member with the least load, ties to the candidate-order /
    /// lowest-thread-id pick — deterministic.  Load counts the worker's
    /// pool *plus its node's pending mailbox continuations*: a homed
    /// continuation parked in the mailbox is work the team must absorb
    /// just like a queued task, and ignoring it used to pile pushes onto
    /// a node whose deques merely *looked* empty.  Within one team the
    /// mailbox term is a shared constant (same argmin as before), so the
    /// accounting only changes picks across distinct candidate nodes.
    /// `None` for an out-of-range node (a misbehaving custom scheduler
    /// falls back to the local path).
    fn home_worker(&self, node: usize) -> Option<usize> {
        let cands = self.place_cands.get(node)?;
        let mut best = None;
        let mut best_load = usize::MAX;
        for &nd in cands {
            let mail = self.mailboxes[nd].len();
            for &cand in &self.node_workers[nd] {
                let load = self.pools[cand].len() + mail;
                if load < best_load {
                    best_load = load;
                    best = Some(cand);
                }
            }
        }
        best
    }

    /// Push freshly spawned `child` onto `target`'s pool (a cross-node
    /// "push to home").  The spawning worker `w` pays the remote queue
    /// op — a local op plus the same per-hop transfer a steal would pay,
    /// charged on the target pool's lock (contention included) — and the
    /// target is woken if parked.  FIFO entry (push_back): the home
    /// worker drains its own child-first stack before mailbox arrivals,
    /// and back-end thieves re-balance the oldest pushes first.
    fn push_home(&mut self, child: TaskId, w: usize, target: usize) {
        let cm = self.costs;
        let hops = self.thops[w][target] as Time;
        let op = cm.queue_op + hops * cm.steal_per_hop + self.workers[w].rt_penalty;
        let now = self.workers[w].clock;
        let cost = self.pools[target].lock(now, op);
        self.workers[w].clock += cost;
        self.workers[w].overhead_time += cost;
        let home = self.arena.get(child).home;
        self.pools[target].push_back(child, home);
        self.pushed_home += 1;
        if self.workers[target].sleeping {
            let now = self.workers[w].clock;
            self.wake_worker(target, now);
        }
    }

    /// Buffer a home push for batched transfer
    /// ([`SchedDescriptor::spawn_batch`] > 1).  A target change flushes
    /// the open batch first (buffered pushes stay in spawn order), and a
    /// full batch flushes immediately — the buffer never outlives the
    /// spawning worker's quantum (every quantum exit path calls
    /// [`Engine::flush_pending`]).
    fn queue_push_home(&mut self, child: TaskId, w: usize, target: usize) {
        if !self.pending_home.is_empty() && self.pending_target != target {
            self.flush_pending(w);
        }
        self.pending_target = target;
        self.pending_home.push(child);
        if self.pending_home.len() >= self.desc.spawn_batch.max(1) as usize {
            self.flush_pending(w);
        }
    }

    /// Transfer the buffered sibling pushes to their shared target under
    /// one pool lock: one queue op plus the same per-task per-hop
    /// transfer a batched steal charges (`k * hops * steal_per_hop`), so
    /// a batch of `k` saves `k-1` queue ops and lock acquisitions over
    /// `k` singleton [`Engine::push_home`] calls.  FIFO entry in spawn
    /// order; the target is woken once if parked.  No-op on an empty
    /// buffer (the common, unbatched case).
    fn flush_pending(&mut self, w: usize) {
        if self.pending_home.is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.pending_home);
        let target = self.pending_target;
        let hops = self.thops[w][target] as Time;
        let op = self.costs.queue_op
            + (batch.len() as Time) * hops * self.costs.steal_per_hop
            + self.workers[w].rt_penalty;
        let now = self.workers[w].clock;
        let cost = self.pools[target].lock(now, op);
        self.workers[w].clock += cost;
        self.workers[w].overhead_time += cost;
        for &child in &batch {
            let home = self.arena.get(child).home;
            self.pools[target].push_back(child, home);
        }
        self.pushed_home += batch.len() as u64;
        if self.workers[target].sleeping {
            let now = self.workers[w].clock;
            self.wake_worker(target, now);
        }
        self.pending_home = batch;
        self.pending_home.clear();
    }

    /// Finish `tid`: notify the parent, release its continuation when the
    /// implicit taskwait clears, and cascade completion through parents
    /// whose post phase already finished (`WaitingFinal`).
    fn complete(&mut self, tid: TaskId, w: usize) {
        let free = self.desc.overhead_free;
        let mut finished = tid;
        loop {
            {
                let inst = self.arena.get_mut(finished);
                debug_assert_eq!(inst.pending_children, 0);
                inst.state = TaskState::Done;
            }
            self.live -= 1;
            self.workers[w].tasks_run += 1;
            self.makespan = self.makespan.max(self.workers[w].clock);

            let parent = self.arena.get(finished).parent;
            self.arena.release(finished);
            let Some(p) = parent else { return };
            let (pending, pstate) = {
                let pi = self.arena.get_mut(p);
                pi.pending_children -= 1;
                (pi.pending_children, pi.state)
            };
            if pending > 0 {
                return;
            }
            match pstate {
                TaskState::Waiting => {
                    // release the continuation: tied (owner's pool), or —
                    // for placing schedulers — wherever the resume hook
                    // sends it
                    let (owner, home) = {
                        let pi = self.arena.get_mut(p);
                        pi.state = TaskState::Post;
                        pi.cursor = 0;
                        (pi.owner as usize, pi.home)
                    };
                    if self.desc.shared_queue() {
                        let op = self.costs.shared_queue_op;
                        let now = self.workers[w].clock;
                        let cost = self.shared.lock(now, op);
                        self.workers[w].clock += cost;
                        self.workers[w].overhead_time += cost;
                        self.shared.push_back(p, NO_HOME);
                        let now = self.workers[w].clock;
                        self.wake_sleepers(now, 1);
                        return;
                    }
                    // Resume hook (places opt-in): the continuation may
                    // be released toward the data's home node instead of
                    // the first owner — the post phase combines the very
                    // pages the affinity hint named.  A redirected
                    // release lands in the node's *mailbox*, not one
                    // worker's deque: any same-node team member drains
                    // it (own stack → node mailbox → steal sweep), so
                    // the continuation is not hostage to one pre-picked
                    // worker staying least-loaded.
                    let mut target = owner;
                    let mut mail_node = None;
                    if self.desc.places {
                        let rctx = ResumeCtx {
                            releaser: w,
                            owner,
                            owner_node: self.topo.node_of(self.workers[owner].core),
                            home: (home != NO_HOME).then_some(home as usize),
                        };
                        if let Placement::HomeNode(node) = self.sched.resume(&rctx) {
                            if let Some(t) = self.home_worker(node) {
                                if t != owner {
                                    target = t;
                                    // the mailbox is the chosen worker's
                                    // own node's: home_worker may resolve
                                    // a worker-less node to any of the
                                    // equidistant worker-bearing teams,
                                    // and the mail must land where the
                                    // pick (and its wake) actually lives
                                    mail_node =
                                        Some(self.topo.node_of(self.workers[t].core));
                                    self.homed_resumes += 1;
                                }
                            }
                        }
                    }
                    if !free {
                        // a redirected release pays the same per-hop
                        // transfer push_home does; the tied release
                        // keeps its flat queue-op cost
                        let cm = self.costs;
                        let mut op = cm.queue_op + self.workers[w].rt_penalty;
                        if target != owner {
                            op += self.thops[w][target] as Time * cm.steal_per_hop;
                        }
                        let now = self.workers[w].clock;
                        let cost = match mail_node {
                            Some(nd) => self.mailboxes[nd].lock(now, op),
                            None => self.pools[target].lock(now, op),
                        };
                        self.workers[w].clock += cost;
                        self.workers[w].overhead_time += cost;
                    }
                    let now = self.workers[w].clock;
                    if let Some(nd) = mail_node {
                        // FIFO entry: homed continuations are drained
                        // oldest-first by whoever on the node idles next
                        self.mailboxes[nd].push_back(p, home);
                        // wake the least-loaded pick if it sleeps, else
                        // any sleeping team member — a busy team drains
                        // the mailbox on its next acquire anyway
                        let sleeper = if self.workers[target].sleeping {
                            Some(target)
                        } else {
                            self.node_workers[nd]
                                .iter()
                                .copied()
                                .find(|&i| self.workers[i].sleeping)
                        };
                        if let Some(s) = sleeper {
                            self.wake_worker(s, now);
                        }
                        return;
                    }
                    self.pools[target].push_front(p, home);
                    // Wake-targeting: when the engine knows who should
                    // run the continuation — a placing scheduler, or one
                    // whose bounded sweeps might never probe the owner's
                    // pool (full_sweep = false) — the release wakes that
                    // worker directly.  The old unconditional
                    // round-robin signal could rouse a worker that never
                    // finds the task, stranding it on the liveness net
                    // and charging phantom steal overhead.  Stock
                    // full-sweep schedulers keep the round-robin
                    // futex-style signal, byte-identically.
                    if (self.desc.places || !self.desc.full_sweep)
                        && self.workers[target].sleeping
                    {
                        self.wake_worker(target, now);
                    } else {
                        self.wake_sleepers(now, 1);
                    }
                    return;
                }
                TaskState::WaitingFinal => {
                    // parent had nothing left to run: cascade its completion
                    finished = p;
                }
                // parent still executing its pre/post phase: the taskwait
                // (if any) will observe pending_children == 0.
                _ => return,
            }
        }
    }

    /// Checked-mode invariant layer (`CHK001`–`CHK010`): the release
    /// promotion of the engine's load-bearing `debug_assert`s, run after
    /// every processed event.  Strictly read-only over simulation state —
    /// no cost charges, no RNG consumption, no queue mutation — so a
    /// checked run is byte-identical to an unchecked one (CI pins this
    /// with `bench --compare --fail-on-drift`).  The per-item pool
    /// recount (`CHK005`) amortizes on a 1024-event cadence; everything
    /// else is O(workers) per event.
    fn verify_invariants(&mut self, now: Time, w: usize) -> Result<()> {
        use crate::analysis::checked::{render_report, Violation};
        let mut vs: Vec<Violation> = Vec::new();

        // CHK001: the queue's pending count matches its occupied slots
        // (≤ 1 pending event per worker is structural in the slot array;
        // the count is what pop trusts).
        let occupied =
            self.events.slots.iter().filter(|&&s| s != EventQueue::EMPTY).count();
        if occupied != self.events.pending {
            vs.push(Violation::new(
                "CHK001",
                "event-queue pending count == occupied slots",
                format!("pending={} occupied={occupied}", self.events.pending),
            ));
        }
        // CHK010: a sleeping worker holds no scheduled event (waking
        // always clears `sleeping` before re-arming the slot).
        for (i, wk) in self.workers.iter().enumerate() {
            if wk.sleeping && self.events.slots[i] != EventQueue::EMPTY {
                vs.push(Violation::new(
                    "CHK010",
                    "sleeping workers have no pending event",
                    format!("worker {i} sleeps with slot {:?}", self.events.slots[i]),
                ));
                break;
            }
        }
        // CHK002: events pop in non-decreasing virtual time.
        if now < self.chk_last_event {
            vs.push(Violation::new(
                "CHK002",
                "event times are monotone",
                format!("popped t={now} after t={}", self.chk_last_event),
            ));
        }
        self.chk_last_event = self.chk_last_event.max(now);
        // CHK003: task conservation — every created task is either
        // completed (counted into exactly one worker's tasks_run) or live.
        let run: u64 = self.workers.iter().map(|wk| wk.tasks_run).sum();
        if self.arena.total_created() != run + self.live {
            vs.push(Violation::new(
                "CHK003",
                "spawned == completed + live",
                format!(
                    "created={} completed={run} live={}",
                    self.arena.total_created(),
                    self.live
                ),
            ));
        }
        // CHK004: the engine's live counter agrees with the arena's.
        if self.arena.live() as u64 != self.live {
            vs.push(Violation::new(
                "CHK004",
                "engine live count == arena live count",
                format!("engine={} arena={}", self.live, self.arena.live()),
            ));
        }
        // CHK008: spawn-batch buffers never leak across events.
        if !self.pending_home.is_empty() {
            vs.push(Violation::new(
                "CHK008",
                "home-push batch is flushed between events",
                format!("{} buffered pushes leaked", self.pending_home.len()),
            ));
        }
        // CHK006: non-placing schedulers never touch mailboxes.
        if !self.desc.places
            && (self.mailbox_hits != 0 || self.mailboxes.iter().any(|m| !m.is_empty()))
        {
            vs.push(Violation::new(
                "CHK006",
                "mailboxes stay empty without a place hook",
                format!("mailbox_hits={}", self.mailbox_hits),
            ));
        }
        // CHK007: only shared-FIFO schedulers use the shared pool.
        if !self.desc.shared_queue() && !self.shared.is_empty() {
            vs.push(Violation::new(
                "CHK007",
                "shared FIFO stays empty under per-worker queues",
                format!("{} tasks in the shared pool", self.shared.len()),
            ));
        }
        // CHK009: no pool observed a home-tag desync (pool.rs note_pop).
        let desyncs: u64 = self.pools.iter().map(|p| p.tag_desyncs).sum::<u64>()
            + self.shared.tag_desyncs
            + self.mailboxes.iter().map(|m| m.tag_desyncs).sum::<u64>();
        if desyncs != 0 {
            vs.push(Violation::new(
                "CHK009",
                "no pool home-tag desyncs",
                format!("{desyncs} desynced pops (see Pool::tag_desyncs)"),
            ));
        }
        // CHK005: deep recount of every pool's per-node homed summary
        // against its actual entries — O(total queued), so amortized.
        if self.sim_events % 1024 == 0 || self.live == 0 {
            let bad = self
                .pools
                .iter()
                .enumerate()
                .find(|(_, p)| !p.home_summary_consistent())
                .map(|(i, _)| format!("pool {i}"))
                .or_else(|| {
                    (!self.shared.home_summary_consistent()).then(|| "shared pool".into())
                })
                .or_else(|| {
                    self.mailboxes
                        .iter()
                        .enumerate()
                        .find(|(_, m)| !m.home_summary_consistent())
                        .map(|(i, _)| format!("mailbox {i}"))
                });
            if let Some(which) = bad {
                vs.push(Violation::new(
                    "CHK005",
                    "pool homed summaries == recounted entry tags",
                    which,
                ));
            }
        }

        if vs.is_empty() {
            return Ok(());
        }
        anyhow::bail!(
            "{}",
            render_report(
                &format!(
                    "event {} (worker {w}, t={now}, scheduler {})",
                    self.sim_events,
                    self.sched.name()
                ),
                &vs
            )
        )
    }

    fn into_stats(self) -> RunStats {
        let lock_wait_total: Time = self.pools.iter().map(|p| p.lock_wait).sum::<Time>()
            + self.shared.lock_wait
            + self.mailboxes.iter().map(|m| m.lock_wait).sum::<Time>();
        let steals: u64 = self.workers.iter().map(|w| w.steals).sum();
        let steal_attempts: u64 = self.workers.iter().map(|w| w.steal_attempts).sum();
        let steal_hops: u64 = self.workers.iter().map(|w| w.steal_hops).sum();
        RunStats {
            bench: String::new(),
            sched: self.sched.signature(),
            bind: None,
            threads: self.workers.len(),
            topo: self.topo.name().to_string(),
            seed: 0,
            makespan: self.makespan,
            init_time: 0,
            tasks: self.arena.total_created(),
            peak_live: self.arena.peak_live(),
            steals,
            steal_attempts,
            mean_steal_hops: if steals == 0 { 0.0 } else { steal_hops as f64 / steals as f64 },
            pushed_home: self.pushed_home,
            affinity_hits: self.affinity_hits,
            affine_steals: self.affine_steals,
            homed_resumes: self.homed_resumes,
            batch_steals: self.batch_steals,
            tasks_migrated: self.tasks_migrated,
            mailbox_hits: self.mailbox_hits,
            lock_wait_total,
            shared_lock_wait: self.shared.lock_wait,
            shared_ops: self.shared.ops,
            work_time: self.workers.iter().map(|w| w.work_time).sum(),
            overhead_time: self.workers.iter().map(|w| w.overhead_time).sum(),
            per_worker_tasks: self.workers.iter().map(|w| w.tasks_run).collect(),
            mem: self.mem.stats().clone(),
            kernel_calls: self.kernel_calls,
            sim_events: self.sim_events,
            wall_ms: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// The slot queue must pop in exactly the order the old
    /// `BinaryHeap<Reverse<(Time, u64, usize)>>` did: ascending
    /// `(time, seq)`, worker id never consulted (seqs are unique).
    #[test]
    fn event_queue_matches_heap_order() {
        let mut rng = SplitMix64::new(7);
        let workers = 9;
        let mut q = EventQueue::with_workers(workers);
        let mut heap: BinaryHeap<Reverse<(Time, u64, usize)>> = BinaryHeap::new();
        let mut seq = 0u64;
        let mut pending: Vec<bool> = vec![false; workers];
        for _ in 0..5000 {
            // random interleave of pushes and pops, respecting the
            // engine's one-pending-event-per-worker invariant
            if rng.next_u64() % 3 != 0 {
                let w = (rng.next_u64() % workers as u64) as usize;
                if !pending[w] {
                    // duplicate times force (t, seq) tie-breaks
                    let t = (rng.next_u64() % 50) as Time;
                    seq += 1;
                    q.push(w, t, seq);
                    heap.push(Reverse((t, seq, w)));
                    pending[w] = true;
                }
            } else {
                let got = q.pop();
                let want = heap.pop().map(|Reverse((t, s, w))| (t, s, w));
                assert_eq!(got, want);
                if let Some((_, _, w)) = got {
                    pending[w] = false;
                }
            }
        }
        // drain both to empty
        loop {
            let got = q.pop();
            let want = heap.pop().map(|Reverse((t, s, w))| (t, s, w));
            assert_eq!(got, want);
            if got.is_none() {
                break;
            }
        }
    }
}
