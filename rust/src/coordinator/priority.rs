//! Core-priority allocation — the paper's §IV algorithm (Figs 2–4).
//!
//! Priorities are computed at runtime start-up from the explored hardware
//! (here: the [`Topology`] — the simulated `libnuma`/`sched.h` surface):
//!
//! 1. **base**: cores on bigger NUMA nodes rank higher (first attribution
//!    level — "largest number of cores attached to the same node");
//! 2. **V1** (Fig 2): `Σ_i α_i · N_i` — weighted count of cores at each hop
//!    distance, weights strictly decreasing with distance;
//! 3. **V2** (Fig 3): `Σ_i Σ_j α_i · P1_j` — same weights applied to the
//!    *previously computed* priorities of those cores (second pass of
//!    Fig 4, lines 14–31).
//!
//! Final priority `P = P1 + V2` with `P1 = base + V1`.
//!
//! The identical math ships as the Layer-1 Pallas kernel
//! `priority_f32_{16,64}` (`python/compile/kernels/priority.py`); in PJRT
//! mode the runtime cross-checks this pure-Rust implementation against the
//! AOT artifact (see `rust/tests/pjrt_roundtrip.rs`).

use crate::topology::Topology;

/// Result of the §IV allocation pass.
#[derive(Clone, Debug)]
pub struct PriorityAlloc {
    /// First-level priorities (base + V1), per core.
    pub p1: Vec<f64>,
    /// Final priorities (P1 + V2), per core.
    pub scores: Vec<f64>,
    /// The hop-distance weights used.
    pub alpha: Vec<f64>,
}

/// Decreasing hop weights `α_0 > α_1 > … > α_max`, `α_{max+1} = 0`
/// (paper Fig 2).  Geometric decay keeps near cores dominant while still
/// discriminating far topologies; `ALPHA0`/`DECAY` are fixed constants so
/// priorities are comparable across runs.
pub fn alpha_weights(max_hops: u8) -> Vec<f64> {
    const ALPHA0: f64 = 16.0;
    const DECAY: f64 = 0.5;
    (0..=max_hops as usize).map(|i| ALPHA0 * DECAY.powi(i as i32)).collect()
}

/// Weighted hop matrix `A[i][j] = α[hops(i,j)]`, diagonal zeroed.
pub fn weighted_hop_matrix(topo: &Topology, alpha: &[f64]) -> Vec<Vec<f64>> {
    let n = topo.num_cores();
    (0..n)
        .map(|i| {
            (0..n)
                .map(|j| if i == j { 0.0 } else { alpha[topo.core_hops(i, j) as usize] })
                .collect()
        })
        .collect()
}

/// Run the full Fig-4 algorithm for `topo`.
pub fn core_priorities(topo: &Topology) -> PriorityAlloc {
    let n = topo.num_cores();
    let alpha = alpha_weights(topo.max_hops());
    let a = weighted_hop_matrix(topo, &alpha);

    // First attribution level: node size, then V1 (Fig 2).
    let mut p1 = vec![0.0; n];
    for (i, p) in p1.iter_mut().enumerate() {
        let base = topo.cores_per_node(topo.node_of(i)) as f64;
        let v1: f64 = a[i].iter().sum();
        *p = base + v1;
    }

    // Second pass (Fig 3): V2 folds neighbours' first-level priorities.
    let mut scores = vec![0.0; n];
    for i in 0..n {
        let v2: f64 = a[i].iter().zip(&p1).map(|(w, p)| w * p).sum();
        scores[i] = p1[i] + v2;
    }

    PriorityAlloc { p1, scores, alpha }
}

impl PriorityAlloc {
    /// Cores ordered best-first (ties by lower id — determinism; the
    /// paper breaks ties randomly, which [`super::binding`] layers on top).
    pub fn ranked_cores(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.scores.len()).collect();
        order.sort_by(|&a, &b| {
            self.scores[b].partial_cmp(&self.scores[a]).unwrap().then(a.cmp(&b))
        });
        order
    }

    /// All cores whose score ties the maximum (random pick candidates).
    pub fn best_cores(&self) -> Vec<usize> {
        let best = self.scores.iter().cloned().fold(f64::MIN, f64::max);
        self.scores
            .iter()
            .enumerate()
            .filter(|(_, &s)| (s - best).abs() < 1e-9)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_strictly_decreasing() {
        let a = alpha_weights(5);
        assert_eq!(a.len(), 6);
        for w in a.windows(2) {
            assert!(w[0] > w[1] && w[1] > 0.0);
        }
    }

    #[test]
    fn x4600_central_cores_rank_first() {
        let topo = Topology::x4600();
        let pr = core_priorities(&topo);
        // central sockets 2..=5 hold cores 4..=11
        let best = pr.ranked_cores()[0];
        assert!((4..=11).contains(&best), "best core {best} should be central");
        // and every central core outranks every corner core
        let worst_central =
            (4..=11).map(|c| pr.scores[c]).fold(f64::INFINITY, f64::min);
        let best_corner = (0..4)
            .chain(12..16)
            .map(|c| pr.scores[c])
            .fold(f64::MIN, f64::max);
        assert!(worst_central > best_corner);
    }

    #[test]
    fn uma_all_equal() {
        let pr = core_priorities(&Topology::uma(8));
        for &s in &pr.scores[1..] {
            assert!((s - pr.scores[0]).abs() < 1e-9);
        }
        assert_eq!(pr.best_cores().len(), 8);
    }

    #[test]
    fn same_node_cores_tie() {
        let pr = core_priorities(&Topology::x4600());
        for node in 0..8 {
            let (a, b) = (2 * node, 2 * node + 1);
            assert!((pr.scores[a] - pr.scores[b]).abs() < 1e-9, "node {node}");
        }
    }

    #[test]
    fn hetero_big_nodes_win() {
        // x4600_hetero gives inner sockets 4 cores: both the base term and
        // the centrality term favour them.
        let topo = Topology::x4600_hetero();
        let pr = core_priorities(&topo);
        let best = pr.ranked_cores()[0];
        assert_eq!(topo.cores_per_node(topo.node_of(best)), 4);
    }

    #[test]
    fn matches_kernel_reference_values() {
        // Mirror of python/tests/test_priority.py::test_priority_matches_pseudocode
        // on the 8-node ladder with 1 core/node: cross-language pin.
        let topo = Topology::from_edges(
            "ladder1",
            vec![1; 8],
            &[(0, 1), (6, 7), (0, 2), (2, 4), (4, 6), (1, 3), (3, 5), (5, 7), (2, 5), (3, 4)],
            16,
        )
        .unwrap();
        let pr = core_priorities(&topo);
        // independent straight-line recomputation
        let alpha = alpha_weights(topo.max_hops());
        for i in 0..8 {
            let mut v1 = 0.0;
            for j in 0..8 {
                if i != j {
                    v1 += alpha[topo.core_hops(i, j) as usize];
                }
            }
            let p1 = 1.0 + v1;
            assert!((pr.p1[i] - p1).abs() < 1e-9);
        }
    }

    #[test]
    fn ranked_cores_is_permutation() {
        let pr = core_priorities(&Topology::altix16());
        let mut r = pr.ranked_cores();
        r.sort_unstable();
        assert_eq!(r, (0..32).collect::<Vec<_>>());
    }
}
