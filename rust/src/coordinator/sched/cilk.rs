//! Cilk-based scheduler (stock NANOS `cilk`).
//!
//! Depth-first: a spawned child runs **immediately** on the spawning
//! worker; the suspended parent is pushed on the worker's own deque.  This
//! keeps the child's working set — typically just written by the parent —
//! hot in the core's private caches (paper §V.A: "a copy of this shared
//! data may still be hot in the core's two level caches").
//!
//! Stealing is Cilk-THE-flavoured: a thief picks a victim **uniformly at
//! random** and takes from the **front** of the victim's deque — the most
//! recently suspended parent, i.e. the continuation of the task the victim
//! is currently working under.  (Work-first, by contrast, steals the
//! *oldest* entry; see [`super::wf`].)  Both inherit breadth-ish stolen
//! work, but the front-steal grabs deeper, smaller continuations, which
//! costs slightly more steals on deep trees — one of the small cilk/wf
//! gaps visible across the paper's figures.

pub use super::Policy;

#[cfg(test)]
mod tests {
    use super::super::*;

    #[test]
    fn cilk_descriptor() {
        let p = Policy::CilkBased;
        assert!(p.depth_first());
        assert!(!p.shared_queue());
        assert_eq!(p.steal_end(), StealEnd::Front);
        assert_eq!(p.victim_kind(), VictimKind::Random);
    }
}
