//! Cilk-based scheduler (stock NANOS `cilk`).
//!
//! Depth-first: a spawned child runs **immediately** on the spawning
//! worker; the suspended parent is pushed on the worker's own deque.  This
//! keeps the child's working set — typically just written by the parent —
//! hot in the core's private caches (paper §V.A: "a copy of this shared
//! data may still be hot in the core's two level caches").
//!
//! Stealing is Cilk-THE-flavoured: a thief picks a victim **uniformly at
//! random** and takes from the **front** of the victim's deque — the most
//! recently suspended parent, i.e. the continuation of the task the victim
//! is currently working under.  (Work-first, by contrast, steals the
//! *oldest* entry; see [`super::wf`].)  Both inherit breadth-ish stolen
//! work, but the front-steal grabs deeper, smaller continuations, which
//! costs slightly more steals on deep trees — one of the small cilk/wf
//! gaps visible across the paper's figures.

use super::wf::random_order;
use super::{SchedDescriptor, Scheduler, StealEnd, VictimList};
use crate::util::SplitMix64;

/// The Cilk-style scheduler.
pub struct CilkBased;

impl Scheduler for CilkBased {
    fn name(&self) -> &str {
        "cilk"
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            steal_end: StealEnd::Front,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        random_order(vl, rng, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cilk_descriptor() {
        let d = CilkBased.descriptor();
        assert!(d.child_first);
        assert!(!d.shared_queue());
        assert_eq!(d.steal_end, StealEnd::Front);
    }

    #[test]
    fn cilk_and_wf_share_victim_selection() {
        let vl = VictimList { groups: vec![(1, vec![1, 2, 3, 4])] };
        let (mut ra, mut rb) = (SplitMix64::new(7), SplitMix64::new(7));
        let (mut a, mut b) = (Vec::new(), Vec::new());
        CilkBased.victim_order(&vl, &mut ra, &mut a);
        super::super::wf::WorkFirst.victim_order(&vl, &mut rb, &mut b);
        assert_eq!(a, b);
    }
}
