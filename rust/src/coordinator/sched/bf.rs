//! Breadth-first scheduler (stock NANOS `bf`).
//!
//! One **shared FIFO** for the whole team: spawns append to the tail,
//! idle workers pop from the head.  Load balance is ideal — any worker can
//! take any ready task — which is why NQueens (cheap, uniform tasks, tiny
//! data) loves it (paper Fig 10).
//!
//! Its two failure modes, both reproduced by the simulator, are exactly the
//! paper's §V.A FFT analysis:
//!
//! 1. **Queue contention** — every spawn *and* every dispatch serializes on
//!    the shared queue's lock ([`Pool::lock`](crate::coordinator::pool::Pool::lock)).
//!    With millions of microsecond-scale tasks the lock saturates around
//!    6–8 workers and speedup *decreases* beyond (Fig 7: 4.43x @ 6 cores
//!    falling to 2.39x @ 16).
//! 2. **No locality** — a popped task rarely lands on the core whose caches
//!    (or NUMA node) hold its data, so the cache model charges misses and
//!    remote-hop latencies that depth-first policies avoid.
//!
//! There is no work stealing: the shared queue *is* the only pool.

use super::{QueueKind, SchedDescriptor, Scheduler, StealEnd, VictimList};
use crate::util::SplitMix64;

/// The shared-FIFO scheduler.
pub struct BreadthFirst;

impl Scheduler for BreadthFirst {
    fn name(&self) -> &str {
        "bf"
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            queue: QueueKind::SharedFifo,
            steal_end: StealEnd::Back,
            child_first: false,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, _vl: &VictimList, _rng: &mut SplitMix64, _out: &mut Vec<usize>) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf_descriptor() {
        let d = BreadthFirst.descriptor();
        assert!(d.shared_queue());
        assert!(!d.child_first);
        assert!(!d.overhead_free);
    }

    #[test]
    fn bf_has_no_victims() {
        let vl = VictimList { groups: vec![(0, vec![1]), (2, vec![2, 3])] };
        let mut rng = SplitMix64::new(3);
        let mut out = Vec::new();
        BreadthFirst.victim_order(&vl, &mut rng, &mut out);
        assert!(out.is_empty());
    }
}
