//! Breadth-first scheduler (stock NANOS `bf`).
//!
//! One **shared FIFO** for the whole team: spawns append to the tail,
//! idle workers pop from the head.  Load balance is ideal — any worker can
//! take any ready task — which is why NQueens (cheap, uniform tasks, tiny
//! data) loves it (paper Fig 10).
//!
//! Its two failure modes, both reproduced by the simulator, are exactly the
//! paper's §V.A FFT analysis:
//!
//! 1. **Queue contention** — every spawn *and* every dispatch serializes on
//!    the shared queue's lock ([`Pool::lock`](crate::coordinator::pool::Pool::lock)).
//!    With millions of microsecond-scale tasks the lock saturates around
//!    6–8 workers and speedup *decreases* beyond (Fig 7: 4.43x @ 6 cores
//!    falling to 2.39x @ 16).
//! 2. **No locality** — a popped task rarely lands on the core whose caches
//!    (or NUMA node) hold its data, so the cache model charges misses and
//!    remote-hop latencies that depth-first policies avoid.
//!
//! There is no work stealing: the shared queue *is* the only pool.

pub use super::Policy;

#[cfg(test)]
mod tests {
    use super::super::*;

    #[test]
    fn bf_descriptor() {
        let p = Policy::BreadthFirst;
        assert!(p.shared_queue());
        assert!(!p.depth_first());
        assert_eq!(p.victim_kind(), VictimKind::None);
        assert!(!p.overhead_free());
    }
}
