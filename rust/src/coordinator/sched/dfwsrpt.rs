//! DFWSRPT — Depth-First Work-Stealing **Random Priority Threads**
//! (paper §VI.B).
//!
//! Identical to [`super::dfwspt`] except inside a distance group: "when
//! several threads are at equal distance from the idle thread … it will
//! randomly choose its victim thread.  Randomizing thread's selection
//! mechanism may allow applications to avoid contentions that happen when
//! several threads try to steal tasks from the closest thread holding the
//! lowest thread id."
//!
//! Each steal sweep reshuffles every group independently, so repeated
//! sweeps from the same thread (and concurrent sweeps from different
//! threads) spread across equidistant victims instead of convoying — the
//! effect that buys Strassen its extra ~17% over work-first in Fig 15.

use super::{SchedDescriptor, Scheduler, VictimList};
use crate::util::SplitMix64;

/// Emit the §VI.B visiting order: distance groups ascending, fresh random
/// permutation within each group.
pub fn order(vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
    for (_, group) in &vl.groups {
        let start = out.len();
        out.extend(group.iter().copied());
        rng.shuffle(&mut out[start..]);
    }
}

/// The §VI.B scheduler.
pub struct Dfwsrpt;

impl Scheduler for Dfwsrpt {
    fn name(&self) -> &str {
        "dfwsrpt"
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor::WORK_STEALING
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        order(vl, rng, out);
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    fn vl() -> VictimList {
        VictimList {
            groups: vec![(0, vec![2]), (1, vec![1, 5, 6, 8]), (2, vec![0, 4])],
        }
    }

    #[test]
    fn groups_stay_in_distance_order() {
        let mut rng = SplitMix64::new(11);
        let mut out = Vec::new();
        Dfwsrpt.victim_order(&vl(), &mut rng, &mut out);
        assert_eq!(out[0], 2, "closest group first");
        let mid: std::collections::BTreeSet<_> = out[1..5].iter().copied().collect();
        assert_eq!(mid, [1, 5, 6, 8].into_iter().collect());
        let far: std::collections::BTreeSet<_> = out[5..].iter().copied().collect();
        assert_eq!(far, [0, 4].into_iter().collect());
    }

    #[test]
    fn shuffles_within_group_across_sweeps() {
        let mut rng = SplitMix64::new(13);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..32 {
            let mut out = Vec::new();
            Dfwsrpt.victim_order(&vl(), &mut rng, &mut out);
            seen.insert(out[1..5].to_vec());
        }
        assert!(seen.len() > 1, "group order must vary across sweeps");
    }

    #[test]
    fn dfwsrpt_descriptor() {
        let d = Dfwsrpt.descriptor();
        assert!(d.child_first);
        assert_eq!(d.steal_end, StealEnd::Back);
        assert_eq!(Policy::Dfwsrpt.victim_kind(), VictimKind::RandomPriorityList);
    }
}
