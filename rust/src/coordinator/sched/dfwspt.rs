//! DFWSPT — Depth-First Work-Stealing **Priority Threads** (paper §VI.A).
//!
//! Queue discipline is exactly work-first ([`super::wf`]); the contribution
//! is the victim order.  At start-up every thread receives a *priority
//! list* of the other team threads ranked by the hop distance between
//! their bound cores (closest first).  **Threads at equal distance are
//! ordered by ascending thread id** — the paper: "If several cores turned
//! out to be at equal distance from target core, threads are placed
//! according to their identification number.  Threads with smaller id are
//! placed first."
//!
//! An idle thread sweeps this list in order, probing each victim's pool
//! until it finds a task (stolen from the back).  Close steals win twice:
//! the steal transaction itself crosses fewer hops, and the stolen task's
//! data — first-touched by the nearby victim — lives on a nearby node.
//!
//! The deterministic id-tiebreak is also the strategy's weakness: every
//! idle thread in a neighbourhood converges on the *same* lowest-id
//! victim and convoys on its pool lock.  That is precisely what
//! [`super::dfwsrpt`] randomizes away (and why Strassen, with its high
//! steal rate, favours DFWSRPT in Fig 15).

use super::{SchedDescriptor, Scheduler, VictimList};
use crate::util::SplitMix64;

/// Emit the §VI.A visiting order: distance groups ascending, ids ascending
/// within a group.  (The [`VictimList`] is already built sorted this way;
/// this function is the policy's explicit, tested statement of that order.)
pub fn order(vl: &VictimList, out: &mut Vec<usize>) {
    for (_, group) in &vl.groups {
        out.extend(group.iter().copied());
    }
}

/// The §VI.A scheduler.
pub struct Dfwspt;

impl Scheduler for Dfwspt {
    fn name(&self) -> &str {
        "dfwspt"
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor::WORK_STEALING
    }

    fn victim_order(&self, vl: &VictimList, _rng: &mut SplitMix64, out: &mut Vec<usize>) {
        order(vl, out);
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    #[test]
    fn order_is_distance_then_id() {
        let vl = VictimList {
            groups: vec![(0, vec![2]), (1, vec![1, 5]), (3, vec![0, 4])],
        };
        let mut out = Vec::new();
        super::order(&vl, &mut out);
        assert_eq!(out, vec![2, 1, 5, 0, 4]);
    }

    #[test]
    fn deterministic() {
        let vl = VictimList { groups: vec![(1, vec![3, 4, 7])] };
        let mut rng = SplitMix64::new(9);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        Dfwspt.victim_order(&vl, &mut rng, &mut a);
        Dfwspt.victim_order(&vl, &mut rng, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn dfwspt_descriptor() {
        let d = Dfwspt.descriptor();
        assert!(d.child_first);
        assert_eq!(d.steal_end, StealEnd::Back);
        assert_eq!(Policy::Dfwspt.victim_kind(), VictimKind::PriorityList);
    }
}
