//! `adaptive` — work-first that learns it is on a NUMA machine.
//!
//! Starts exactly like [`super::wf`]: uniform random victim sweeps, the
//! strongest stock baseline when steals are rare or data is small.  The
//! [`SchedEvent::Steal`] feedback hook meanwhile measures the **remote
//! steal ratio** — the fraction of successful steals that crossed at
//! least one interconnect hop.  Once at least `min_steals` steals have
//! been observed and the ratio exceeds `remote_ratio`, the strategy
//! switches (permanently, for the rest of the run) to the §VI.A
//! hop-ordered priority list of [`super::dfwspt`].
//!
//! The rationale is the paper's own data read backwards: random stealing
//! only hurts when steals actually cross the fabric (FFT/Sort/Strassen at
//! high thread counts); when they don't (NQueens, small teams, one busy
//! node), the priority list buys nothing.  A strategy that *observes*
//! which regime it is in needs runtime feedback — precisely what the
//! closed descriptor enum could not express.

use std::cell::Cell;

use super::{dfwspt, wf, SchedDescriptor, SchedEvent, Scheduler, VictimList};
use crate::util::SplitMix64;

/// Uniform random victim selection until the observed remote-steal ratio
/// crosses `remote_ratio`, then the §VI.A priority list.
pub struct Adaptive {
    remote_ratio: f64,
    min_steals: u64,
    steals: Cell<u64>,
    remote_steals: Cell<u64>,
    switched: Cell<bool>,
}

impl Adaptive {
    pub fn new(remote_ratio: f64, min_steals: u64) -> Self {
        Self {
            remote_ratio,
            min_steals,
            steals: Cell::new(0),
            remote_steals: Cell::new(0),
            switched: Cell::new(false),
        }
    }

    /// Has the strategy switched to the priority list?
    pub fn switched(&self) -> bool {
        self.switched.get()
    }
}

impl Scheduler for Adaptive {
    fn name(&self) -> &str {
        "adaptive"
    }

    fn signature(&self) -> String {
        format!(
            "adaptive(min_steals={};remote_ratio={})",
            self.min_steals,
            crate::util::fmt_f64(self.remote_ratio)
        )
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            // the steal-hops feedback below drives the mode switch
            observes: true,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        if self.switched.get() {
            dfwspt::order(vl, out);
        } else {
            wf::random_order(vl, rng, out);
        }
    }

    fn observe(&self, event: &SchedEvent) {
        let SchedEvent::Steal { hops, .. } = event else { return };
        let steals = self.steals.get() + 1;
        self.steals.set(steals);
        if *hops > 0 {
            self.remote_steals.set(self.remote_steals.get() + 1);
        }
        if !self.switched.get() && steals >= self.min_steals {
            let ratio = self.remote_steals.get() as f64 / steals as f64;
            if ratio > self.remote_ratio {
                self.switched.set(true);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    fn vl() -> VictimList {
        VictimList {
            groups: vec![(0, vec![3]), (1, vec![1, 2]), (2, vec![0])],
        }
    }

    #[test]
    fn starts_in_work_first_mode() {
        let s = Adaptive::new(0.5, 4);
        let (mut ra, mut rb) = (SplitMix64::new(1), SplitMix64::new(1));
        let (mut got, mut want) = (Vec::new(), Vec::new());
        s.victim_order(&vl(), &mut ra, &mut got);
        wf::random_order(&vl(), &mut rb, &mut want);
        assert_eq!(got, want);
        assert!(!s.switched());
    }

    #[test]
    fn switches_when_remote_ratio_crosses() {
        let s = Adaptive::new(0.5, 4);
        // 3 local steals: below min_steals, no switch
        for _ in 0..3 {
            s.observe(&SchedEvent::Steal { thief: 0, victim: 3, hops: 0, affine: false });
        }
        assert!(!s.switched());
        // remote steals push the ratio over 0.5 once min_steals is met
        for _ in 0..5 {
            s.observe(&SchedEvent::Steal { thief: 0, victim: 1, hops: 2, affine: false });
        }
        assert!(s.switched(), "5/8 remote > 0.5");
        let mut rng = SplitMix64::new(2);
        let mut out = Vec::new();
        s.victim_order(&vl(), &mut rng, &mut out);
        assert_eq!(out, vec![3, 1, 2, 0], "priority-list order after the switch");
    }

    #[test]
    fn switch_is_sticky() {
        let s = Adaptive::new(0.5, 2);
        s.observe(&SchedEvent::Steal { thief: 0, victim: 1, hops: 1, affine: false });
        s.observe(&SchedEvent::Steal { thief: 0, victim: 1, hops: 1, affine: false });
        assert!(s.switched());
        // a flood of local steals later must not flip it back
        for _ in 0..32 {
            s.observe(&SchedEvent::Steal { thief: 0, victim: 3, hops: 0, affine: false });
        }
        assert!(s.switched());
    }

    #[test]
    fn local_steals_never_trigger_a_switch() {
        let s = Adaptive::new(0.5, 2);
        for _ in 0..64 {
            s.observe(&SchedEvent::Steal { thief: 0, victim: 3, hops: 0, affine: false });
        }
        assert!(!s.switched());
        // misses and spawns are not steals and change nothing
        s.observe(&SchedEvent::StealMiss { worker: 0 });
        s.observe(&SchedEvent::Spawn { worker: 0 });
        assert!(!s.switched());
    }

    #[test]
    fn registry_builds_and_bounds_the_ratio() {
        assert!(build(&SchedSpec::new("adaptive")).is_ok());
        let spec = SchedSpec::new("adaptive")
            .with_param("remote_ratio", 0.25)
            .with_param("min_steals", 8.0);
        assert_eq!(build(&spec).unwrap().name(), "adaptive");
        assert!(build(&SchedSpec::new("adaptive").with_param("remote_ratio", -0.1)).is_err());
        assert!(build(&SchedSpec::new("adaptive").with_param("remote_ratio", 2.0)).is_err());
    }
}
