//! Work-first scheduler (stock NANOS `wf`).
//!
//! Depth-first like [`super::cilk`]: the child executes immediately, the
//! suspended parent goes to the **front** of the spawning worker's deque
//! (LIFO for the owner — resume order matches the serial execution).
//!
//! Thieves pick a victim **uniformly at random** and steal from the
//! **back**: the *oldest* suspended parent, i.e. the shallowest ancestor,
//! which hands the thief the largest available subtree and minimizes steal
//! frequency (the classic work-first principle).
//!
//! This is the strongest stock baseline in the paper's data-intensive
//! figures (FFT 9.3x, Strassen 9.15x @ 16 cores) and the scheduler the
//! paper's DFWSPT/DFWSRPT extend: they keep exactly this queue discipline
//! and only replace the *victim selection* with the NUMA-aware priority
//! list (see [`super::dfwspt`], [`super::dfwsrpt`]).

pub use super::Policy;

#[cfg(test)]
mod tests {
    use super::super::*;

    #[test]
    fn wf_descriptor() {
        let p = Policy::WorkFirst;
        assert!(p.depth_first());
        assert_eq!(p.steal_end(), StealEnd::Back);
        assert_eq!(p.victim_kind(), VictimKind::Random);
    }

    #[test]
    fn dfwspt_extends_wf_queue_discipline() {
        assert_eq!(Policy::Dfwspt.steal_end(), Policy::WorkFirst.steal_end());
        assert_eq!(Policy::Dfwspt.depth_first(), Policy::WorkFirst.depth_first());
    }
}
