//! Work-first scheduler (stock NANOS `wf`).
//!
//! Depth-first like [`super::cilk`]: the child executes immediately, the
//! suspended parent goes to the **front** of the spawning worker's deque
//! (LIFO for the owner — resume order matches the serial execution).
//!
//! Thieves pick a victim **uniformly at random** and steal from the
//! **back**: the *oldest* suspended parent, i.e. the shallowest ancestor,
//! which hands the thief the largest available subtree and minimizes steal
//! frequency (the classic work-first principle).
//!
//! This is the strongest stock baseline in the paper's data-intensive
//! figures (FFT 9.3x, Strassen 9.15x @ 16 cores) and the scheduler the
//! paper's DFWSPT/DFWSRPT extend: they keep exactly this queue discipline
//! and only replace the *victim selection* with the NUMA-aware priority
//! list (see [`super::dfwspt`], [`super::dfwsrpt`]).

use super::{SchedDescriptor, Scheduler, VictimList};
use crate::util::SplitMix64;

/// Emit a uniform random sweep over every other worker: flatten the hop
/// groups, then one Fisher–Yates shuffle of the whole list.  Shared by
/// [`WorkFirst`] and [`super::cilk::CilkBased`] (they differ only in the
/// steal end), and the pre-switch mode of [`super::adaptive`].
pub fn random_order(vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
    out.extend(vl.groups.iter().flat_map(|(_, g)| g.iter().copied()));
    rng.shuffle(out);
}

/// The work-first scheduler.
pub struct WorkFirst;

impl Scheduler for WorkFirst {
    fn name(&self) -> &str {
        "wf"
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor::WORK_STEALING
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        random_order(vl, rng, out);
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    #[test]
    fn wf_descriptor() {
        let d = WorkFirst.descriptor();
        assert!(d.child_first);
        assert!(!d.shared_queue());
        assert_eq!(d.steal_end, StealEnd::Back);
    }

    #[test]
    fn random_order_is_a_permutation() {
        let vl = VictimList { groups: vec![(0, vec![1]), (1, vec![2, 4]), (3, vec![0, 3])] };
        let mut rng = SplitMix64::new(5);
        let mut out = Vec::new();
        WorkFirst.victim_order(&vl, &mut rng, &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn dfwspt_extends_wf_queue_discipline() {
        assert_eq!(dfwspt::Dfwspt.descriptor().steal_end, WorkFirst.descriptor().steal_end);
        assert_eq!(dfwspt::Dfwspt.descriptor().child_first, WorkFirst.descriptor().child_first);
    }
}
