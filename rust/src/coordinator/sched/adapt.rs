//! `numa-adapt` — steal-side locality that *watches itself work*.
//!
//! [`super::steal`] applies a fixed affine-first bias; this strategy
//! makes the bias (and the batch size) a function of the observed
//! **affine-steal ratio** — the fraction of its own successful steals
//! that landed work on the thief's home node, reported through
//! [`SchedEvent::Steal`]'s `affine` flag.  The adaptive counterpart that
//! completes the dfwsrpt → numa-steal → numa-home → numa-adapt ablation:
//! how much of the locality win needs feedback rather than a static
//! policy?
//!
//! The ratio is measured over an *aged* sample — once the observation
//! count reaches four times the trust threshold, both counters are
//! halved (ratio-preserving), so a long cold start cannot pin the
//! verdict for the rest of the run and a genuine regime change shows up
//! within tens of steals.  Two regimes, re-evaluated on every observed
//! steal once `min_steals` have accumulated:
//!
//! * **Relaxed** (ratio ≥ `target`, and the starting state): the shared
//!   affine-first reorder plus steal-half batching
//!   ([`super::steal_half_takes`], capped at `batch`) — affine victims
//!   are probed first and drained in bulk, everyone else keeps the
//!   stock single steal.
//! * **Tight** (ratio < `target`): the bias has not been enough — too
//!   many steals still pull remote-homed work.  Sweeps are additionally
//!   *filtered* to affine victims only (whenever at least one exists),
//!   so every steal that can be affine is.  The sweep turns partial,
//!   which the descriptor declares (`full_sweep = false`) and the
//!   engine's liveness net covers; the moment the ratio recovers above
//!   `target` the filter relaxes again (unlike [`super::adaptive`]'s
//!   one-way switch, drift is tracked in both directions).
//!
//! The base sweep is the §VI.B random priority list, so with a cold page
//! table (all summaries zero, no steals observed) `numa-adapt`
//! degenerates to exactly [`super::dfwsrpt`].  Like `numa-steal` it
//! never pushes or redirects: `place`/`resume` keep their `LocalQueue`
//! defaults, and the [`SchedDescriptor::places`] opt-in exists purely so
//! the engine resolves and caches the home tags the summaries and the
//! `affine` feedback are built from.

use std::cell::Cell;

use super::{
    bias_affine_first, dfwsrpt, steal_half_takes, SchedDescriptor, SchedEvent, Scheduler,
    StealCand, VictimList,
};
use crate::util::SplitMix64;

/// Default affine-steal ratio the strategy tries to hold.
pub const DEFAULT_TARGET: f64 = 0.5;
/// Default steal-half cap (tasks per steal).
pub const DEFAULT_BATCH: f64 = 4.0;

/// Affine-first + steal-half stealing whose aggressiveness follows the
/// observed affine-steal ratio.
pub struct NumaAdapt {
    /// Minimum affinity-hint size (bytes) worth resolving a home for.
    min_bytes: u64,
    /// Affine-steal ratio below which sweeps tighten to affine-only.
    target: f64,
    /// Steals observed before the ratio is trusted.
    min_steals: u64,
    /// Steal-half cap (max tasks drained per steal).
    batch: u32,
    /// Sample cap: reaching it halves both counters (estimator aging).
    window: u64,
    steals: Cell<u64>,
    affine_steals: Cell<u64>,
    tight: Cell<bool>,
}

impl NumaAdapt {
    pub fn new(min_kb: f64, target: f64, min_steals: u64, batch: u32) -> Self {
        Self {
            min_bytes: (min_kb * 1024.0) as u64,
            target,
            min_steals,
            batch,
            // the estimator remembers at most ~4x the trust threshold:
            // enough samples to be stable, few enough that a regime
            // change shows up within tens of steals
            window: min_steals.max(16) * 4,
            steals: Cell::new(0),
            affine_steals: Cell::new(0),
            tight: Cell::new(false),
        }
    }

    /// Currently filtering sweeps to affine victims only?
    pub fn tight(&self) -> bool {
        self.tight.get()
    }

    /// Observed affine-steal ratio so far (0 before any steal).
    pub fn affine_ratio(&self) -> f64 {
        let steals = self.steals.get();
        if steals == 0 {
            return 0.0;
        }
        self.affine_steals.get() as f64 / steals as f64
    }
}

impl Scheduler for NumaAdapt {
    fn name(&self) -> &str {
        "numa-adapt"
    }

    fn signature(&self) -> String {
        format!(
            "numa-adapt(batch={};min_kb={};min_steals={};target={})",
            self.batch,
            crate::util::fmt_f64(self.min_bytes as f64 / 1024.0),
            self.min_steals,
            crate::util::fmt_f64(self.target),
        )
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            // home tags + hooks, but no pushes (place/resume keep their
            // LocalQueue defaults)
            places: true,
            min_hint_bytes: self.min_bytes,
            // tight mode drops non-affine victims, making sweeps partial:
            // the engine must wake tied-continuation owners directly and
            // keep its liveness net armed
            full_sweep: false,
            // steal-affinity feedback drives the loose/tight switch
            observes: true,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        dfwsrpt::order(vl, rng, out);
    }

    fn observe(&self, event: &SchedEvent) {
        let SchedEvent::Steal { affine, .. } = event else { return };
        let mut steals = self.steals.get() + 1;
        let mut affine_steals = self.affine_steals.get() + u64::from(*affine);
        // Age the estimator: at the window cap, halve both counts.  The
        // ratio is preserved but old samples stop dominating — a
        // whole-run cumulative average would keep a long cold start's
        // verdict alive for thousands of steals after locality actually
        // recovered, pinning the strategy in tight mode.
        if steals >= self.window {
            steals /= 2;
            affine_steals /= 2;
        }
        self.steals.set(steals);
        self.affine_steals.set(affine_steals);
        if steals >= self.min_steals {
            // re-evaluated every steal, in both directions: drift below
            // the target tightens, recovery relaxes
            self.tight.set(self.affine_ratio() < self.target);
        }
    }

    fn steal_bias(&self, _thief_node: usize, cands: &mut Vec<StealCand>) {
        bias_affine_first(cands);
        steal_half_takes(cands, self.batch);
        if self.tight.get() && cands.iter().any(|c| c.affine > 0) {
            cands.retain(|c| c.affine > 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    fn steal(affine: bool) -> SchedEvent {
        SchedEvent::Steal { thief: 0, victim: 1, hops: 1, affine }
    }

    fn cands() -> Vec<StealCand> {
        vec![
            StealCand::single(1, 0, 0, 6),
            StealCand::single(2, 1, 3, 6),
            StealCand::single(3, 2, 0, 2),
        ]
    }

    #[test]
    fn relaxed_mode_biases_and_batches_without_filtering() {
        let s = NumaAdapt::new(16.0, 0.5, 4, 4);
        let mut c = cands();
        s.steal_bias(0, &mut c);
        let order: Vec<usize> = c.iter().map(|x| x.victim).collect();
        assert_eq!(order, vec![2, 1, 3], "affine victim leads, nobody dropped");
        let takes: Vec<u32> = c.iter().map(|x| x.take).collect();
        assert_eq!(takes, vec![3, 1, 1], "steal-half (6/2=3) on the affine victim only");
        assert!(!s.tight());
    }

    #[test]
    fn ratio_below_target_tightens_to_affine_only() {
        let s = NumaAdapt::new(16.0, 0.5, 4, 4);
        // 1 affine out of 4: ratio 0.25 < 0.5 once min_steals is met
        s.observe(&steal(true));
        for _ in 0..3 {
            s.observe(&steal(false));
        }
        assert!(s.tight(), "ratio {} must tighten", s.affine_ratio());
        let mut c = cands();
        s.steal_bias(0, &mut c);
        assert_eq!(c.len(), 1, "non-affine victims filtered");
        assert_eq!(c[0].victim, 2);
        assert_eq!(c[0].take, 3, "batching stays on while tight");
        // an all-cold sweep (no affine anywhere) is never emptied
        let mut cold = vec![StealCand::single(1, 0, 0, 4), StealCand::single(2, 1, 0, 4)];
        s.steal_bias(0, &mut cold);
        assert_eq!(cold.len(), 2, "tight mode must not starve a cold sweep");
    }

    #[test]
    fn recovery_above_target_relaxes_again() {
        let s = NumaAdapt::new(16.0, 0.5, 2, 4);
        s.observe(&steal(false));
        s.observe(&steal(false));
        assert!(s.tight());
        // six affine steals pull the ratio back over 0.5
        for _ in 0..6 {
            s.observe(&steal(true));
        }
        assert!(!s.tight(), "drift is tracked in both directions: {}", s.affine_ratio());
    }

    /// The estimator ages: a long bad phase must not pin tight mode for
    /// the rest of the run once locality genuinely recovers.  A
    /// cumulative whole-run average after 1000 misses would need ~1000
    /// affine steals to cross 0.5 again; the halving window recovers in
    /// well under 100.
    #[test]
    fn aged_estimator_recovers_from_a_long_cold_start() {
        let s = NumaAdapt::new(16.0, 0.5, 4, 4);
        for _ in 0..1000 {
            s.observe(&steal(false));
        }
        assert!(s.tight(), "a long all-remote phase tightens");
        for _ in 0..100 {
            s.observe(&steal(true));
        }
        assert!(
            !s.tight(),
            "100 affine steals must outweigh the aged history (ratio {})",
            s.affine_ratio()
        );
    }

    #[test]
    fn ratio_untrusted_below_min_steals() {
        let s = NumaAdapt::new(16.0, 0.9, 64, 4);
        for _ in 0..10 {
            s.observe(&steal(false));
        }
        assert!(!s.tight(), "10 < min_steals=64: stay relaxed");
        // non-steal events never move the estimator
        s.observe(&SchedEvent::StealMiss { worker: 0 });
        s.observe(&SchedEvent::Spawn { worker: 0 });
        assert_eq!(s.affine_ratio(), 0.0);
    }

    #[test]
    fn sweeps_like_dfwsrpt_and_declares_partial_sweeps() {
        let s = NumaAdapt::new(16.0, 0.5, 16, 4);
        let d = s.descriptor();
        assert!(d.places, "home tags require the opt-in");
        assert!(!d.full_sweep, "tight mode drops victims");
        assert_eq!(d.min_hint_bytes, 16 * 1024);
        let vl = VictimList { groups: vec![(0, vec![1]), (2, vec![2, 3])] };
        for seed in 0..8 {
            let mut rng_a = SplitMix64::new(seed);
            let mut rng_b = SplitMix64::new(seed);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            s.victim_order(&vl, &mut rng_a, &mut a);
            dfwsrpt::order(&vl, &mut rng_b, &mut b);
            assert_eq!(a, b, "base order is §VI.B");
        }
        // no pushes, no redirects: the stock hook defaults
        let ctx = SpawnCtx {
            worker: 0,
            worker_node: 0,
            affinity: crate::simnuma::Region { addr: 1 << 20, bytes: 1 << 20 },
            home: Some(5),
        };
        assert_eq!(s.place(&ctx), Placement::LocalQueue);
        let rctx = ResumeCtx { releaser: 0, owner: 1, owner_node: 0, home: Some(5) };
        assert_eq!(s.resume(&rctx), Placement::LocalQueue);
    }

    #[test]
    fn registry_builds_with_defaults_and_overrides() {
        let s = build(&SchedSpec::new("numa-adapt")).unwrap();
        assert_eq!(s.name(), "numa-adapt");
        assert_eq!(s.signature(), "numa-adapt(batch=4;min_kb=16;min_steals=16;target=0.5)");
        let s = build(
            &SchedSpec::new("numa-adapt").with_param("target", 0.75).with_param("batch", 8.0),
        )
        .unwrap();
        assert_eq!(s.signature(), "numa-adapt(batch=8;min_kb=16;min_steals=16;target=0.75)");
        assert!(build(&SchedSpec::new("numa-adapt").with_param("target", -0.5)).is_err());
        assert!(build(&SchedSpec::new("numa-adapt").with_param("batch", 0.0)).is_err());
        assert!(build(&SchedSpec::new("numa-adapt").with_param("bogus", 1.0)).is_err());
    }
}
