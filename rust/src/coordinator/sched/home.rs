//! `numa-home` — the paper's placement strategy: push tasks to their
//! data's home node.
//!
//! The steal side of the paper's technique (DFWSPT/DFWSRPT) moves *idle
//! workers toward work*; this strategy adds the allocation side and moves
//! *work toward its data*.  Every spawn annotated with a data-affinity
//! hint ([`BodyCtx::spawn_on`](crate::coordinator::task::BodyCtx::spawn_on))
//! is routed through [`Scheduler::place`]: if the hint's pages mostly
//! live on a node other than the spawner's, the child is pushed onto a
//! worker bound to that node instead of running child-first locally.
//! Executing on the owner node turns would-be remote misses into local
//! ones — the `remote_ratio` drop Wittmann & Hager (arXiv:1101.0093)
//! attribute to task-to-data affinity.
//!
//! Two guard rails keep the push from degenerating:
//!
//! * **Hint-size floor** (`min_kb`): tiny shared regions (a config page
//!   every task reads, like nqueens' board) would otherwise funnel the
//!   entire task graph onto one node.  Hints below the floor are ignored
//!   — caches absorb small shared state anyway.
//! * **Local-home fast path**: when the data is already home (or nothing
//!   is resident yet), the spawn stays on today's child-first path, so
//!   well-placed graphs schedule exactly like `dfwsrpt`.
//!
//! Stealing is NUMA-aware twice over: the base sweep is the §VI.B random
//! priority list, and on top of it the [`Scheduler::steal_bias`] hook
//! moves victims whose pools hold tasks homed on the thief's node to the
//! front of the sweep (`steal_bias=0` turns the reorder off), and a
//! `batch` above 1 additionally drains up to half of an affine victim's
//! queue per steal ([`super::steal_half_takes`]; the default of 1 keeps
//! the stock single steal).  Tied
//! continuations follow the data too: the [`Scheduler::resume`] hook
//! releases a waiting task's continuation to a worker on its home node
//! when the first owner sits elsewhere (`homed_resume=0` restores the
//! strict resume-on-first-owner behaviour) — the post phase typically
//! combines the very pages the hint named.

use super::{
    bias_affine_first, dfwsrpt, steal_half_takes, Placement, ResumeCtx, SchedDescriptor,
    Scheduler, SpawnCtx, StealCand, VictimList,
};
use crate::util::SplitMix64;

/// Default hint-size floor in KiB (4 pages).
pub const DEFAULT_MIN_KB: f64 = 16.0;

/// Push-to-home placement over §VI.B locality stealing.
pub struct NumaHome {
    /// Minimum affinity-hint size (bytes) that may trigger a push.
    min_bytes: u64,
    /// Reorder steal sweeps affine-victims-first?
    steal_bias: bool,
    /// Release tied continuations toward their data's home node?
    homed_resume: bool,
    /// Steal-half cap: max tasks drained per steal from an affine victim
    /// (1 = the stock single steal).
    batch: u32,
    /// Push-side coalescing width: max same-target home pushes the engine
    /// may transfer under one pool lock (1 = push each spawn immediately).
    spawn_batch: u32,
}

impl NumaHome {
    /// Placement with both locality extensions on (the registry default).
    pub fn new(min_kb: f64) -> Self {
        Self::configured(min_kb, true, true, 1, 1)
    }

    /// Placement with explicit steal-bias / homed-resume / batch knobs.
    pub fn configured(
        min_kb: f64,
        steal_bias: bool,
        homed_resume: bool,
        batch: u32,
        spawn_batch: u32,
    ) -> Self {
        Self {
            min_bytes: (min_kb * 1024.0) as u64,
            steal_bias,
            homed_resume,
            batch,
            spawn_batch,
        }
    }
}

impl Scheduler for NumaHome {
    fn name(&self) -> &str {
        "numa-home"
    }

    fn signature(&self) -> String {
        format!(
            "numa-home(batch={};homed_resume={};min_kb={};spawn_batch={};steal_bias={})",
            self.batch,
            self.homed_resume as u8,
            crate::util::fmt_f64(self.min_bytes as f64 / 1024.0),
            self.spawn_batch,
            self.steal_bias as u8,
        )
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            places: true,
            // surfaces the floor so the engine never resolves homes for
            // hints place() would discard anyway
            min_hint_bytes: self.min_bytes,
            spawn_batch: self.spawn_batch,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        dfwsrpt::order(vl, rng, out);
    }

    fn place(&self, ctx: &SpawnCtx) -> Placement {
        // the engine already gates on descriptor().min_hint_bytes; this
        // re-check keeps the strategy self-contained for direct callers
        if ctx.affinity.bytes < self.min_bytes {
            return Placement::LocalQueue;
        }
        match ctx.home {
            Some(node) if node != ctx.worker_node => Placement::HomeNode(node),
            _ => Placement::LocalQueue,
        }
    }

    fn steal_bias(&self, _thief_node: usize, cands: &mut Vec<StealCand>) {
        if self.steal_bias {
            bias_affine_first(cands);
            steal_half_takes(cands, self.batch);
        }
    }

    fn resume(&self, ctx: &ResumeCtx) -> Placement {
        if !self.homed_resume {
            return Placement::LocalQueue;
        }
        match ctx.home {
            Some(node) if node != ctx.owner_node => Placement::HomeNode(node),
            _ => Placement::LocalQueue,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;
    use crate::simnuma::Region;

    fn ctx(worker_node: usize, bytes: u64, home: Option<usize>) -> SpawnCtx {
        SpawnCtx {
            worker: 0,
            worker_node,
            affinity: Region { addr: 1 << 20, bytes },
            home,
        }
    }

    #[test]
    fn pushes_to_a_remote_home() {
        let s = NumaHome::new(16.0);
        assert_eq!(s.place(&ctx(0, 1 << 20, Some(5))), Placement::HomeNode(5));
    }

    #[test]
    fn local_home_stays_on_the_child_first_path() {
        let s = NumaHome::new(16.0);
        assert_eq!(s.place(&ctx(3, 1 << 20, Some(3))), Placement::LocalQueue);
    }

    #[test]
    fn unresident_hint_stays_local() {
        let s = NumaHome::new(16.0);
        assert_eq!(s.place(&ctx(0, 1 << 20, None)), Placement::LocalQueue);
    }

    #[test]
    fn tiny_hints_are_ignored() {
        let s = NumaHome::new(16.0);
        assert_eq!(s.place(&ctx(0, 256, Some(5))), Placement::LocalQueue, "below the floor");
        assert_eq!(s.place(&ctx(0, 16 * 1024, Some(5))), Placement::HomeNode(5), "at the floor");
        let eager = NumaHome::new(0.0);
        assert_eq!(eager.place(&ctx(0, 256, Some(5))), Placement::HomeNode(5), "floor disabled");
    }

    #[test]
    fn descriptor_opts_into_placement() {
        let d = NumaHome::new(16.0).descriptor();
        assert!(d.places);
        assert!(d.child_first);
        assert_eq!(d.steal_end, StealEnd::Back);
        assert_eq!(d.min_hint_bytes, 16 * 1024, "the floor is engine-visible");
        // stock strategies never opt in
        for &p in Policy::all() {
            assert!(!stock(p).descriptor().places, "{}", p.name());
        }
    }

    #[test]
    fn steals_like_dfwsrpt() {
        let vl = VictimList { groups: vec![(0, vec![1]), (2, vec![2, 3])] };
        for seed in 0..8 {
            let mut rng_a = SplitMix64::new(seed);
            let mut rng_b = SplitMix64::new(seed);
            let mut a = Vec::new();
            let mut b = Vec::new();
            NumaHome::new(16.0).victim_order(&vl, &mut rng_a, &mut a);
            dfwsrpt::order(&vl, &mut rng_b, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn registry_builds_with_defaults_and_overrides() {
        let s = build(&SchedSpec::new("numa-home")).unwrap();
        assert_eq!(s.name(), "numa-home");
        assert_eq!(
            s.signature(),
            "numa-home(batch=1;homed_resume=1;min_kb=16;spawn_batch=1;steal_bias=1)"
        );
        let s = build(&SchedSpec::new("numa-home").with_param("min_kb", 4.0)).unwrap();
        assert_eq!(
            s.signature(),
            "numa-home(batch=1;homed_resume=1;min_kb=4;spawn_batch=1;steal_bias=1)"
        );
        let s = build(
            &SchedSpec::new("numa-home")
                .with_param("steal_bias", 0.0)
                .with_param("homed_resume", 0.0)
                .with_param("batch", 4.0)
                .with_param("spawn_batch", 8.0),
        )
        .unwrap();
        assert_eq!(
            s.signature(),
            "numa-home(batch=4;homed_resume=0;min_kb=16;spawn_batch=8;steal_bias=0)"
        );
        assert_eq!(
            build(&SchedSpec::new("numa-home").with_param("spawn_batch", 8.0))
                .unwrap()
                .descriptor()
                .spawn_batch,
            8,
            "the coalescing width reaches the engine through the descriptor"
        );
        assert!(build(&SchedSpec::new("numa-home").with_param("min_kb", -1.0)).is_err());
        assert!(build(&SchedSpec::new("numa-home").with_param("batch", 0.0)).is_err());
        assert!(build(&SchedSpec::new("numa-home").with_param("bogus", 1.0)).is_err());
        assert!(
            build(&SchedSpec::new("numa-home").with_param("steal_bias", 0.5)).is_err(),
            "flags are 0/1"
        );
    }

    #[test]
    fn steal_bias_prefers_affine_victims_and_respects_its_switch() {
        let cand = |victim, affine| StealCand::single(victim, 1, affine, 2);
        let mut cands = vec![cand(3, 0), cand(5, 2), cand(1, 0)];
        NumaHome::new(16.0).steal_bias(0, &mut cands);
        assert_eq!(cands.iter().map(|c| c.victim).collect::<Vec<_>>(), vec![5, 3, 1]);
        assert!(cands.iter().all(|c| c.take == 1), "batch=1 keeps single steals");
        let mut cands = vec![cand(3, 0), cand(5, 2), cand(1, 0)];
        NumaHome::configured(16.0, false, true, 1, 1).steal_bias(0, &mut cands);
        assert_eq!(
            cands.iter().map(|c| c.victim).collect::<Vec<_>>(),
            vec![3, 5, 1],
            "steal_bias=0 leaves the sweep untouched"
        );
    }

    #[test]
    fn batch_above_one_steals_half_from_affine_victims() {
        let cand = |victim, affine, queued| StealCand::single(victim, 1, affine, queued);
        let mut cands = vec![cand(3, 0, 8), cand(5, 2, 8), cand(1, 1, 3)];
        NumaHome::configured(16.0, true, true, 4, 1).steal_bias(0, &mut cands);
        let got: Vec<(usize, u32)> = cands.iter().map(|c| (c.victim, c.take)).collect();
        // affine victims lead and batch steal-half (8/2=4, 3/2=1); the
        // non-affine victim keeps the stock single steal
        assert_eq!(got, vec![(5, 4), (1, 1), (3, 1)]);
        // steal_bias=0 disables batching along with the reorder
        let mut cands = vec![cand(3, 0, 8), cand(5, 2, 8)];
        NumaHome::configured(16.0, false, true, 4, 1).steal_bias(0, &mut cands);
        assert!(cands.iter().all(|c| c.take == 1));
    }

    #[test]
    fn resume_homes_continuations_unless_disabled() {
        let rctx = |home, owner_node| ResumeCtx { releaser: 0, owner: 1, owner_node, home };
        let s = NumaHome::new(16.0);
        assert_eq!(s.resume(&rctx(Some(5), 0)), Placement::HomeNode(5));
        assert_eq!(s.resume(&rctx(Some(3), 3)), Placement::LocalQueue, "owner already home");
        assert_eq!(s.resume(&rctx(None, 0)), Placement::LocalQueue, "unhinted task");
        let off = NumaHome::configured(16.0, true, false, 1, 1);
        assert_eq!(off.resume(&rctx(Some(5), 0)), Placement::LocalQueue, "homed_resume=0");
    }

    #[test]
    fn default_place_hook_is_local() {
        // the trait default keeps every non-placing scheduler on today's
        // path even if the engine were to call it
        let wf = stock(Policy::WorkFirst);
        assert_eq!(wf.place(&ctx(0, 1 << 20, Some(7))), Placement::LocalQueue);
    }
}
