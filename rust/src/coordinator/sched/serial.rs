//! Serial baseline — the paper's speedup denominator.
//!
//! Depth-first execution on a single thread with every runtime overhead
//! constant zeroed ([`SchedDescriptor::overhead_free`]): what the paper
//! calls "serial execution time".  It never steals (there is nobody to
//! steal from — `RunSpec` validation pins it to one thread).

use super::{SchedDescriptor, Scheduler, VictimList};
use crate::util::SplitMix64;

/// The overhead-free single-thread baseline.
pub struct Serial;

impl Scheduler for Serial {
    fn name(&self) -> &str {
        "serial"
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            overhead_free: true,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, _vl: &VictimList, _rng: &mut SplitMix64, _out: &mut Vec<usize>) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_descriptor() {
        let d = Serial.descriptor();
        assert!(d.overhead_free);
        assert!(d.child_first);
        assert!(!d.shared_queue());
    }

    #[test]
    fn serial_never_names_victims() {
        let vl = VictimList { groups: vec![(1, vec![1, 2, 3])] };
        let mut rng = SplitMix64::new(1);
        let mut out = Vec::new();
        Serial.victim_order(&vl, &mut rng, &mut out);
        assert!(out.is_empty());
    }
}
