//! `hops-threshold` — bounded-distance stealing with starvation spill.
//!
//! The closed enum could say *which order* to visit victims in, but never
//! *which victims to skip*.  This strategy steals only from victims at
//! most `max_hops` interconnect hops away (random within each distance
//! group, like [`super::dfwsrpt`]), keeping every steal transaction — and
//! the stolen task's first-touched data — inside a bounded NUMA
//! neighbourhood.
//!
//! Pure distance-capping deadlocks a neighbourhood whose pools have all
//! drained while work piles up across the fabric, so the cap is softened
//! by a **starvation spill**: the [`SchedEvent::StealMiss`] feedback hook
//! counts consecutive empty sweeps (team-wide — starvation is a property
//! of the run, not of one thread), and once `spill_after` misses
//! accumulate, sweeps extend past the cap until the next successful steal
//! resets the counter.  This is the kind of stateful, feedback-driven
//! strategy the [`Scheduler`] trait exists for.

use std::cell::Cell;

use super::{SchedDescriptor, SchedEvent, Scheduler, VictimList};
use crate::util::SplitMix64;

/// Steal within `max_hops`; probe beyond only after `spill_after`
/// consecutive empty sweeps.
pub struct HopsThreshold {
    max_hops: u8,
    spill_after: u32,
    /// Consecutive empty sweeps, team-wide (one engine run is
    /// single-threaded, so a `Cell` is race-free and deterministic).
    starved: Cell<u32>,
}

impl HopsThreshold {
    pub fn new(max_hops: u8, spill_after: u32) -> Self {
        Self { max_hops, spill_after, starved: Cell::new(0) }
    }

    /// Currently spilling past the hop cap?
    pub fn spilling(&self) -> bool {
        self.starved.get() >= self.spill_after
    }
}

impl Scheduler for HopsThreshold {
    fn name(&self) -> &str {
        "hops-threshold"
    }

    fn signature(&self) -> String {
        format!("hops-threshold(max_hops={};spill_after={})", self.max_hops, self.spill_after)
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            // sweeps skip victims beyond the cap, so a round-robin-woken
            // worker may never probe a tied continuation owner's pool:
            // tell the engine to wake the owner directly instead
            full_sweep: false,
            // steal/miss feedback feeds the starvation spill counter
            observes: true,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        for (hops, group) in &vl.groups {
            if *hops > self.max_hops {
                break; // groups ascend by distance
            }
            let start = out.len();
            out.extend(group.iter().copied());
            rng.shuffle(&mut out[start..]);
        }
        if self.spilling() {
            for (hops, group) in &vl.groups {
                if *hops <= self.max_hops {
                    continue;
                }
                let start = out.len();
                out.extend(group.iter().copied());
                rng.shuffle(&mut out[start..]);
            }
        }
    }

    fn observe(&self, event: &SchedEvent) {
        match event {
            SchedEvent::Steal { .. } => self.starved.set(0),
            SchedEvent::StealMiss { .. } => {
                self.starved.set(self.starved.get().saturating_add(1))
            }
            SchedEvent::Spawn { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    fn vl() -> VictimList {
        VictimList {
            groups: vec![(0, vec![1]), (1, vec![2, 3]), (3, vec![4, 5, 6])],
        }
    }

    #[test]
    fn caps_at_max_hops_when_fed() {
        let s = HopsThreshold::new(1, 2);
        let mut rng = SplitMix64::new(1);
        let mut out = Vec::new();
        s.victim_order(&vl(), &mut rng, &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 2, 3], "victims beyond 1 hop are skipped");
        assert_eq!(out[0], 1, "the hops-0 group still comes first");
    }

    #[test]
    fn spills_after_consecutive_misses_and_resets_on_steal() {
        let s = HopsThreshold::new(1, 2);
        let mut rng = SplitMix64::new(2);
        s.observe(&SchedEvent::StealMiss { worker: 0 });
        assert!(!s.spilling(), "one miss is not starvation");
        s.observe(&SchedEvent::StealMiss { worker: 3 });
        assert!(s.spilling());
        let mut out = Vec::new();
        s.victim_order(&vl(), &mut rng, &mut out);
        assert_eq!(out.len(), 6, "spill extends the sweep to every victim");
        let near: Vec<usize> = out[..3].to_vec();
        assert!(near.contains(&1) && near.contains(&2) && near.contains(&3));

        s.observe(&SchedEvent::Steal { thief: 0, victim: 1, hops: 0, affine: false });
        assert!(!s.spilling(), "a successful steal resets the counter");
        out.clear();
        s.victim_order(&vl(), &mut rng, &mut out);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn zero_cap_is_node_local_only() {
        let s = HopsThreshold::new(0, 2);
        let mut rng = SplitMix64::new(3);
        let mut out = Vec::new();
        s.victim_order(&vl(), &mut rng, &mut out);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn spawn_events_are_ignored() {
        let s = HopsThreshold::new(1, 1);
        s.observe(&SchedEvent::StealMiss { worker: 0 });
        s.observe(&SchedEvent::Spawn { worker: 0 });
        assert!(s.spilling(), "spawns must not reset the starvation counter");
    }

    #[test]
    fn signature_carries_resolved_parameters() {
        let s = HopsThreshold::new(1, 2);
        assert_eq!(s.signature(), "hops-threshold(max_hops=1;spill_after=2)");
        assert_eq!(s.name(), "hops-threshold");
    }

    #[test]
    fn registry_builds_with_defaults_and_overrides() {
        assert!(build(&SchedSpec::new("hops-threshold")).is_ok());
        let spec = SchedSpec::new("hops-threshold")
            .with_param("max_hops", 2.0)
            .with_param("spill_after", 1.0);
        assert_eq!(build(&spec).unwrap().name(), "hops-threshold");
        let bad = SchedSpec::new("hops-threshold").with_param("max_hops", 300.0);
        assert!(build(&bad).is_err(), "u8 range enforced");
        let bad = SchedSpec::new("hops-threshold").with_param("spill_after", 4294967296.0);
        assert!(build(&bad).is_err(), "u32 range enforced, no silent wrap to 0");
    }
}
