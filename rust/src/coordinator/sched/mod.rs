//! Task scheduling — an open, pluggable strategy layer.
//!
//! Scheduling used to be a closed six-variant `enum Policy` whose
//! semantics the engine interpreted through accessor matches.  It is now
//! a first-class [`Scheduler`] **trait** plus a string-keyed **registry**:
//! every strategy (the three stock NANOS schedulers, the paper's two
//! NUMA-aware contributions, the serial baseline, and any number of
//! user-defined ones) is a value the engine drives through one small
//! interface:
//!
//! * [`Scheduler::descriptor`] — the declarative part: queue discipline
//!   ([`QueueKind`]), steal end ([`StealEnd`]), child-first execution,
//!   overhead accounting;
//! * [`Scheduler::victim_order`] — the behavioural part: emit this sweep's
//!   victim visiting order from the per-worker [`VictimList`];
//! * [`Scheduler::observe`] — an optional feedback hook ([`SchedEvent`]:
//!   spawns, steals, failed sweeps) that lets adaptive strategies change
//!   their victim order mid-run;
//! * [`Scheduler::place`] — an optional *task-placement* hook: for
//!   schedulers whose descriptor sets [`SchedDescriptor::places`], every
//!   spawn's [`SpawnCtx`] (affinity hint + resolved home node) is offered
//!   to the strategy, which answers [`Placement::LocalQueue`] (today's
//!   child-first behaviour) or [`Placement::HomeNode`] (push the child to
//!   a worker on its data's node; the parent keeps running).  Non-placing
//!   schedulers never see the hook and stay byte-identical to the
//!   pre-placement engine.
//! * [`Scheduler::steal_bias`] — an optional *steal-side* locality hook
//!   (also gated on [`SchedDescriptor::places`]): before a sweep, the
//!   engine snapshots each victim's per-node resident-home summary into
//!   [`StealCand`]s and lets the strategy reorder or filter them —
//!   "steal from the victim holding work homed near me first", without
//!   scanning any deque.  The hook also sets each candidate's *batch
//!   size* ([`StealCand::take`], default 1): a take of `k` makes the
//!   engine drain up to `k` tasks from that victim's back end under one
//!   lock (the thief runs the first and requeues the rest locally) —
//!   steal-half from deep affine pools instead of one-task-at-a-time
//!   transfers.  The default keeps the sweep untouched and every take
//!   at 1, which is byte-identical to the stock single steal.
//! * [`Scheduler::resume`] — an optional *tied-continuation* hook (gated
//!   the same way): when a task's last child completes, the engine
//!   offers the [`ResumeCtx`] (first owner + the task's cached home
//!   node) and the strategy may answer [`Placement::HomeNode`] to
//!   release the continuation to a worker on the data's node instead of
//!   unconditionally to the first owner.  Redirected releases land in a
//!   **per-node mailbox** (not one worker's deque): every worker drains
//!   its own stack, then its node's mailbox, then sweeps victims — so
//!   whichever same-node team member idles first picks the continuation
//!   up instead of it waiting on one pre-picked worker.
//!
//! | scheduler | queueing | steal end | victim selection |
//! |---|---|---|---|
//! | `serial`  overhead-free baseline | per-worker, child-first | — | — (1 thread) |
//! | [`bf`]    breadth-first | one shared FIFO | — | — (no stealing) |
//! | [`cilk`]  Cilk-based | per-worker deque, child-first | front | uniform random |
//! | [`wf`]    work-first | per-worker deque, child-first | back | uniform random |
//! | [`dfwspt`]  §VI.A | per-worker deque, child-first | back | hop-ordered priority list, id-ties first |
//! | [`dfwsrpt`] §VI.B | per-worker deque, child-first | back | hop-ordered priority list, random within a distance group |
//! | [`hops`]  `hops-threshold` | per-worker deque, child-first | back | near groups only (≤ `max_hops`), spill beyond on starvation |
//! | [`hier`]  two-level | per-worker deque, child-first | back | node-local random first, ~one delegate per node (in expectation) probes remote nodes |
//! | [`home`]  `numa-home` | per-worker deque, child-first, **push-to-home placement + homed resumes** | back | hop-ordered priority list, random within a distance group, **affine victims first** |
//! | [`steal`] `numa-steal` | per-worker deque, child-first | back | hop-ordered priority list, random within a distance group, **affine victims first** (steal-side only: no pushes, no homed resumes) |
//! | [`adapt`] `numa-adapt` | per-worker deque, child-first | back | affine-first + steal-half batching; tightens to affine-only sweeps while the observed affine-steal ratio sits below `target` |
//! | [`adaptive`] | per-worker deque, child-first | back | starts uniform random, switches to the priority list when the remote-steal ratio crosses `remote_ratio` |
//!
//! ## Adding a scheduler (~30 lines)
//!
//! Implement the trait, register a factory, and every surface — `RunSpec`
//! validation, sweep grids, manifests, `numanos list`, "unknown
//! scheduler" error lists — picks it up automatically:
//!
//! ```
//! use numanos::coordinator::sched::{
//!     self, SchedDescriptor, Scheduler, VictimList,
//! };
//! use numanos::util::SplitMix64;
//!
//! /// Steals farthest-first — an anti-locality strawman.
//! struct FarFirst;
//!
//! impl Scheduler for FarFirst {
//!     fn name(&self) -> &str {
//!         "far-first"
//!     }
//!     fn descriptor(&self) -> SchedDescriptor {
//!         SchedDescriptor::WORK_STEALING
//!     }
//!     fn victim_order(&self, vl: &VictimList, _rng: &mut SplitMix64, out: &mut Vec<usize>) {
//!         for (_, group) in vl.groups.iter().rev() {
//!             out.extend(group.iter().copied());
//!         }
//!     }
//! }
//!
//! sched::register(
//!     sched::SchedulerInfo::new("far-first", "steal farthest groups first"),
//!     |_params| Ok(Box::new(FarFirst)),
//! )
//! .unwrap();
//! assert!(sched::scheduler_names().contains(&"far-first".to_string()));
//! ```
//!
//! Parameterized strategies declare [`ParamInfo`]s in their
//! [`SchedulerInfo`]; a [`SchedSpec`] (`{"name": "hops-threshold",
//! "max_hops": 1}` in a manifest, `--sched hops-threshold:max_hops=1` on
//! the CLI) carries the overrides and [`build`] validates them against the
//! declaration.
//!
//! **Vet your scheduler**: `numanos vet <name>` (see
//! [`crate::analysis::vet`]) drives every hook above through synthetic
//! probe contexts and checks the contract each doc comment states —
//! permutation-subset victim orders, full-sweep coverage, reorder-only
//! `steal_bias`, in-range placement nodes, observe-gating, and
//! same-seed determinism — as stable `VET0xx` diagnostics.  The README's
//! "Static analysis & vetting" section carries the full code table and a
//! scheduler-author checklist; CI runs `numanos vet --all` on every
//! change.
//!
//! The legacy closed [`Policy`] enum survives as a deprecated-in-spirit
//! shim for the six stock strategies: existing `Runtime::run(policy, …)`
//! call sites, figure specs, and CSV columns are untouched, and
//! [`victim_sequence`] keeps the pre-trait victim-order logic verbatim so
//! parity tests can pin the two paths together.

pub mod adapt;
pub mod adaptive;
pub mod bf;
pub mod cilk;
pub mod dfwsrpt;
pub mod dfwspt;
pub mod hier;
pub mod home;
pub mod hops;
pub mod serial;
pub mod steal;
pub mod wf;

use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::serde::Json;
use crate::simnuma::Region;
use crate::topology::Topology;
use crate::util::{fmt_f64, SplitMix64};

/// Which end of a victim's deque a thief takes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StealEnd {
    /// Most recently suspended parent (Cilk THE-style).
    Front,
    /// Oldest / shallowest task (work-first style).
    Back,
}

/// Where ready tasks wait.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueKind {
    /// One deque per worker (work-stealing family).
    PerWorker,
    /// A single team-wide FIFO (breadth-first).
    SharedFifo,
}

/// The declarative half of a scheduler: everything the engine needs to
/// know *statically* about queueing and stealing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedDescriptor {
    pub queue: QueueKind,
    /// Which deque end thieves take from (ignored for [`QueueKind::SharedFifo`]).
    pub steal_end: StealEnd,
    /// Child-first (depth-first) execution on spawn?
    pub child_first: bool,
    /// Charge no runtime overheads (the serial measurement baseline).
    pub overhead_free: bool,
    /// Consult [`Scheduler::place`] on every spawn?  When false (the
    /// stock default) the engine skips the locality hooks entirely — no
    /// home-node query, no `place`/`steal_bias`/`resume` call — which is
    /// what keeps non-placing schedulers byte-identical to the
    /// pre-placement engine.
    pub places: bool,
    /// Does [`Scheduler::victim_order`] always emit *every* victim?
    /// Stock strategies guarantee it (true); bounded / hierarchical
    /// strategies that may skip victims set false, which tells the
    /// engine a round-robin-woken worker might never probe a tied
    /// continuation owner's pool — so the owner is woken directly when
    /// it sleeps, instead of leaving the continuation to the liveness
    /// net and charging phantom steal overhead.
    pub full_sweep: bool,
    /// Smallest affinity hint (bytes) worth resolving: below this the
    /// engine skips the home-node page-table sample *and* the hook call
    /// (the spawn stays on the local path).  Placement strategies with a
    /// hint floor (numa-home's `min_kb`) surface it here so hot spawn
    /// loops over tiny shared regions — nqueens' board — never pay the
    /// query they are guaranteed to discard.
    pub min_hint_bytes: u64,
    /// Remote-push coalescing width for [`Scheduler::place`] decisions:
    /// the engine buffers up to this many consecutive same-target
    /// [`Placement::HomeNode`] spawns from one worker's quantum and
    /// transfers them under a single pool lock, charging one queue op
    /// plus a per-task hop transfer — sibling spawns over one bound
    /// region stop paying a full remote push each (the push-side twin of
    /// [`StealCand::take`] batching).  1 (the default) flushes every
    /// spawn immediately, which is byte-identical to the unbatched path.
    pub spawn_batch: u32,
    /// Does this strategy consume [`Scheduler::observe`] feedback?  When
    /// false (the stock default) the engine never calls `observe` — no
    /// virtual dispatch per spawn/steal/miss on the hot path.  Observe is
    /// advisory telemetry by contract, so skipping it for strategies
    /// that ignore it cannot change scheduling decisions.
    pub observes: bool,
}

impl SchedDescriptor {
    /// The work-stealing family default: per-worker deques, child-first,
    /// back-end steals, full overhead accounting, no placement hook.
    pub const WORK_STEALING: SchedDescriptor = SchedDescriptor {
        queue: QueueKind::PerWorker,
        steal_end: StealEnd::Back,
        child_first: true,
        overhead_free: false,
        places: false,
        full_sweep: true,
        min_hint_bytes: 0,
        spawn_batch: 1,
        observes: false,
    };

    pub fn shared_queue(&self) -> bool {
        self.queue == QueueKind::SharedFifo
    }
}

/// Where a freshly spawned task should go — the answer a scheduler's
/// [`Scheduler::place`] hook returns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Today's NANOS behaviour: child-first switch on the spawning worker
    /// (or the shared FIFO under breadth-first).
    LocalQueue,
    /// Push the child onto a worker bound to NUMA node `n` — the
    /// paper's "smart allocation": run the task where its data lives.
    /// The parent keeps executing (no child-first switch).
    HomeNode(usize),
}

/// Everything a [`Scheduler::place`] decision can see about one spawn.
/// The engine resolves the affinity hint's home node *before* calling the
/// hook (and only for schedulers whose descriptor sets
/// [`SchedDescriptor::places`] — the query costs a page-table sample).
#[derive(Clone, Copy, Debug)]
pub struct SpawnCtx {
    /// Spawning worker (thread id).
    pub worker: usize,
    /// NUMA node of the spawning worker's core.
    pub worker_node: usize,
    /// The spawn's data-affinity hint ([`Region::EMPTY`] when unhinted).
    pub affinity: Region,
    /// Majority owner of the hint's resident pages
    /// ([`crate::simnuma::MemSim::home_node`]); `None` when unhinted or
    /// nothing is resident yet.
    pub home: Option<usize>,
}

/// One victim's locality snapshot, offered to [`Scheduler::steal_bias`]
/// before a sweep.  `affine` comes from the victim pool's per-node
/// resident-home summary ([`crate::coordinator::pool::Pool::homed_count`])
/// — a word read, not a deque scan — so consulting it per victim keeps
/// the sweep O(victims).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StealCand {
    /// Victim thread id (as emitted by [`Scheduler::victim_order`]).
    pub victim: usize,
    /// Interconnect hops from the thief to this victim.
    pub hops: u8,
    /// Tasks in the victim's pool homed on the *thief's* node.
    pub affine: u32,
    /// Victim pool length (affine + everything else).
    pub queued: u32,
    /// Batch size for this victim: how many tasks a successful steal may
    /// drain from its back end (clamped to the pool length).  The engine
    /// initializes it to 1 — the stock single steal — and only
    /// [`Scheduler::steal_bias`] can raise it, so non-batching strategies
    /// stay byte-identical.  The thief runs the first drained task and
    /// requeues the rest on its own pool, paying one victim lock plus a
    /// per-task transfer charge (see `Engine::steal_sweep`).  Ignored for
    /// front-end ([`StealEnd::Front`]) steals.
    pub take: u32,
}

impl StealCand {
    /// A stock single-steal candidate (`take` = 1).
    pub fn single(victim: usize, hops: u8, affine: u32, queued: u32) -> Self {
        Self { victim, hops, affine, queued, take: 1 }
    }
}

/// Stable affine-first reorder: victims whose pools hold tasks homed on
/// the thief's node move to the front, preserving the sweep's relative
/// order within both classes — the shared locality bias behind
/// [`home`]/[`steal`].  A stable partition (not a sort by count): the
/// underlying strategy's distance/randomization structure is preserved,
/// only the affine/non-affine interleaving changes.
pub fn bias_affine_first(cands: &mut [StealCand]) {
    cands.sort_by_key(|c| c.affine == 0);
}

/// Steal-half batch sizing (Wang et al., arXiv:2502.05293: batched
/// transfers are what keep fine-grained task systems scaling): every
/// *affine* candidate's [`StealCand::take`] is set to half its queue
/// depth, capped at `max_take` — a thief pulling work homed on its own
/// node takes it in bulk instead of re-paying a sweep per task.
/// Non-affine candidates keep the stock single steal, and `max_take <= 1`
/// leaves the whole sweep untouched (the byte-identical default).
pub fn steal_half_takes(cands: &mut [StealCand], max_take: u32) {
    if max_take <= 1 {
        return;
    }
    for c in cands.iter_mut() {
        if c.affine > 0 {
            c.take = (c.queued / 2).clamp(1, max_take);
        }
    }
}

/// Everything a [`Scheduler::resume`] decision can see about one tied
/// continuation release (the task's last child just completed).
#[derive(Clone, Copy, Debug)]
pub struct ResumeCtx {
    /// Worker that completed the last child (pays the release queue op).
    pub releaser: usize,
    /// Worker that last ran the task — the tied resume target today.
    pub owner: usize,
    /// NUMA node of the owner's core.
    pub owner_node: usize,
    /// The task's home node, cached at spawn time from its affinity
    /// hint; `None` when the task was unhinted or nothing was resident.
    pub home: Option<usize>,
}

/// Runtime events the engine reports to the scheduler — the feedback
/// channel adaptive strategies act on.  Events arrive in deterministic
/// simulated-event order.
#[derive(Clone, Copy, Debug)]
pub enum SchedEvent {
    /// Worker `worker` spawned a task.
    Spawn { worker: usize },
    /// `thief` took a task from `victim`'s pool, `hops` apart.  `affine`
    /// is true when the stolen task's cached home node is the thief's
    /// node (always false under non-placing schedulers, whose tasks
    /// carry no home tags) — the feedback `numa-adapt` steers on.
    Steal { thief: usize, victim: usize, hops: u8, affine: bool },
    /// `worker` swept its whole victim order and found nothing.
    StealMiss { worker: usize },
}

/// A scheduling strategy the engine can drive.
///
/// Implementations are per-run values built by the registry ([`build`]);
/// adaptive state lives in `Cell`s behind `&self` (one engine run is
/// single-threaded, so interior mutability is race-free and
/// deterministic).
pub trait Scheduler {
    /// Registry name (the `policy` column of stats output).
    fn name(&self) -> &str;

    /// Display signature with resolved parameters (`name(k=v;…)`, keys
    /// sorted) — what the engine records in `RunStats::sched`, so two
    /// instances of the same strategy with different parameters stay
    /// distinguishable on every execution path.  Parameterless
    /// strategies keep the bare name.
    fn signature(&self) -> String {
        self.name().to_string()
    }

    /// Static queueing/stealing shape.
    fn descriptor(&self) -> SchedDescriptor;

    /// Append this sweep's victim visiting order to `out` (the engine
    /// clears `out` first).  `vl` is the sweeping worker's hop-grouped
    /// victim list; `rng` is that worker's deterministic stream.
    ///
    /// The order may be *partial* (bounded / hierarchical strategies may
    /// skip victims): the engine guarantees liveness with a fallback
    /// full sweep when the last awake worker would otherwise park while
    /// unprobed pools still hold tasks.
    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>);

    /// Observe a runtime event (default: ignore).
    fn observe(&self, _event: &SchedEvent) {}

    /// Decide where a freshly spawned task goes.  Only called when the
    /// descriptor sets [`SchedDescriptor::places`]; the default preserves
    /// today's child-first/local behaviour, so stock schedulers are
    /// untouched by the placement layer.  Returning
    /// [`Placement::HomeNode`] pushes the child to a worker on that node
    /// (the engine resolves nodes without bound workers to the nearest
    /// one that has some) and the parent keeps running.
    fn place(&self, _ctx: &SpawnCtx) -> Placement {
        Placement::LocalQueue
    }

    /// Reorder or filter a steal sweep by the victims' locality
    /// snapshots.  Only called when the descriptor sets
    /// [`SchedDescriptor::places`] and the sweep is non-empty; `cands`
    /// arrives in the [`Scheduler::victim_order`] order and the engine
    /// probes whatever order (and subset) is left in it.  Duplicated
    /// victims are probed once (first occurrence wins) and out-of-range
    /// ids are dropped.  Raising a candidate's [`StealCand::take`] above
    /// 1 requests a *batch*: a successful steal from that victim drains
    /// up to `take` tasks from its back end under one lock — the thief
    /// runs the first and requeues the rest locally (see
    /// [`steal_half_takes`] for the canonical sizing rule).  Dropping
    /// victims makes the sweep partial — the engine's liveness net still
    /// guarantees progress.  The default leaves the sweep untouched, so
    /// non-placing schedulers never pay for (or observe) the snapshot.
    fn steal_bias(&self, _thief_node: usize, _cands: &mut Vec<StealCand>) {}

    /// Decide where a tied task's continuation is released when its last
    /// child completes.  Only called when the descriptor sets
    /// [`SchedDescriptor::places`]; the default preserves the tied-task
    /// contract (resume on the first owner).  Returning
    /// [`Placement::HomeNode`] releases the continuation into that
    /// node's *mailbox* — a per-node FIFO every worker drains after its
    /// own pool and before sweeping victims — so whichever team member
    /// of the home node idles first runs the post phase where the data
    /// lives, and becomes the new owner when it starts the task.
    fn resume(&self, _ctx: &ResumeCtx) -> Placement {
        Placement::LocalQueue
    }
}

// ---------------------------------------------------------------------
// Parameters
// ---------------------------------------------------------------------

/// One declared scheduler parameter (name, default, accepted range,
/// one-line doc).  [`build`] rejects out-of-range overrides for every
/// registered scheduler *before* any factory runs — factories used to
/// each hand-roll their negative checks, and a parameter nobody thought
/// to check (a negative `min_kb` or `target`) would silently invert the
/// comparison it feeds.
#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub default: f64,
    /// Smallest accepted value (inclusive; `f64::NEG_INFINITY` = unbounded).
    pub min: f64,
    /// Largest accepted value (inclusive; `f64::INFINITY` = unbounded).
    pub max: f64,
    pub doc: String,
}

impl ParamInfo {
    /// An unbounded parameter (any finite value accepted).
    pub fn new(name: &str, default: f64, doc: &str) -> Self {
        Self::bounded(name, default, f64::NEG_INFINITY, f64::INFINITY, doc)
    }

    /// A parameter accepting only `min..=max` (checked at [`build`]).
    pub fn bounded(name: &str, default: f64, min: f64, max: f64, doc: &str) -> Self {
        debug_assert!(min <= default && default <= max, "default outside declared range");
        Self { name: name.to_string(), default, min, max, doc: doc.to_string() }
    }
}

/// Resolved parameter set a factory receives: declared defaults overlaid
/// with the [`SchedSpec`]'s overrides.
#[derive(Clone, Debug, Default)]
pub struct SchedParams {
    pairs: Vec<(String, f64)>,
}

impl SchedParams {
    pub fn get(&self, key: &str) -> Option<f64> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// A declared parameter (defaults make it always present).
    pub fn req(&self, key: &str) -> Result<f64> {
        self.get(key).with_context(|| format!("missing scheduler parameter '{key}'"))
    }

    /// A declared parameter that must be a non-negative integer.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        let v = self.req(key)?;
        if v < 0.0 || v.fract() != 0.0 || v > 9.0e15 {
            bail!("scheduler parameter '{key}' must be a non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    /// A declared on/off parameter: exactly 0 or 1.
    pub fn req_flag(&self, key: &str) -> Result<bool> {
        let v = self.req(key)?;
        if v != 0.0 && v != 1.0 {
            bail!("scheduler parameter '{key}' must be 0 or 1, got {v}");
        }
        Ok(v == 1.0)
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// Registration metadata: canonical name, aliases, a one-line summary,
/// and the declared parameters.
#[derive(Clone, Debug)]
pub struct SchedulerInfo {
    pub name: String,
    pub aliases: Vec<String>,
    pub summary: String,
    pub params: Vec<ParamInfo>,
}

impl SchedulerInfo {
    pub fn new(name: &str, summary: &str) -> Self {
        Self {
            name: name.to_string(),
            aliases: Vec::new(),
            summary: summary.to_string(),
            params: Vec::new(),
        }
    }

    pub fn alias(mut self, alias: &str) -> Self {
        self.aliases.push(alias.to_string());
        self
    }

    pub fn param(mut self, name: &str, default: f64, doc: &str) -> Self {
        self.params.push(ParamInfo::new(name, default, doc));
        self
    }

    /// Declare a range-checked parameter (`min..=max`, inclusive).
    pub fn param_in(mut self, name: &str, default: f64, min: f64, max: f64, doc: &str) -> Self {
        self.params.push(ParamInfo::bounded(name, default, min, max, doc));
        self
    }
}

type Factory = Box<dyn Fn(&SchedParams) -> Result<Box<dyn Scheduler>> + Send + Sync>;

struct Entry {
    info: SchedulerInfo,
    factory: Factory,
}

static REGISTRY: OnceLock<Mutex<Vec<Arc<Entry>>>> = OnceLock::new();

fn registry() -> &'static Mutex<Vec<Arc<Entry>>> {
    REGISTRY.get_or_init(|| Mutex::new(builtin_entries()))
}

fn builtin_entries() -> Vec<Arc<Entry>> {
    fn entry(
        info: SchedulerInfo,
        factory: impl Fn(&SchedParams) -> Result<Box<dyn Scheduler>> + Send + Sync + 'static,
    ) -> Arc<Entry> {
        Arc::new(Entry { info, factory: Box::new(factory) })
    }
    vec![
        entry(
            SchedulerInfo::new("serial", "overhead-free depth-first baseline (1 thread)"),
            |_| Ok(Box::new(serial::Serial)),
        ),
        entry(
            SchedulerInfo::new("bf", "breadth-first: one shared FIFO, no stealing")
                .alias("breadth-first"),
            |_| Ok(Box::new(bf::BreadthFirst)),
        ),
        entry(
            SchedulerInfo::new("cilk", "Cilk-based: child-first, random front steals")
                .alias("cilk-based"),
            |_| Ok(Box::new(cilk::CilkBased)),
        ),
        entry(
            SchedulerInfo::new("wf", "work-first: child-first, random back steals")
                .alias("work-first"),
            |_| Ok(Box::new(wf::WorkFirst)),
        ),
        entry(
            SchedulerInfo::new("dfwspt", "§VI.A: hop-ordered priority list, id-ties first"),
            |_| Ok(Box::new(dfwspt::Dfwspt)),
        ),
        entry(
            SchedulerInfo::new("dfwsrpt", "§VI.B: priority list, random within a distance group"),
            |_| Ok(Box::new(dfwsrpt::Dfwsrpt)),
        ),
        entry(
            SchedulerInfo::new("hops-threshold", "steal within max_hops, spill on starvation")
                .param_in(
                    "max_hops",
                    1.0,
                    0.0,
                    u8::MAX as f64,
                    "steal only from victims at most this many hops away",
                )
                .param_in(
                    "spill_after",
                    2.0,
                    0.0,
                    u32::MAX as f64,
                    "consecutive empty sweeps before probing beyond",
                ),
            |p| {
                let max_hops = p.req_usize("max_hops")?;
                let spill_after = p.req_usize("spill_after")?;
                Ok(Box::new(hops::HopsThreshold::new(max_hops as u8, spill_after as u32)))
            },
        ),
        entry(
            SchedulerInfo::new("hier", "two-level: node-local random, stochastic remote delegate")
                .alias("hierarchical"),
            |_| Ok(Box::new(hier::Hierarchical)),
        ),
        entry(
            SchedulerInfo::new("numa-home", "push affinity-tagged tasks to their data's home node")
                .param_in(
                    "min_kb",
                    home::DEFAULT_MIN_KB,
                    0.0,
                    f64::INFINITY,
                    "ignore affinity hints smaller than this many KiB",
                )
                .param_in(
                    "steal_bias",
                    1.0,
                    0.0,
                    1.0,
                    "probe victims holding tasks homed on the thief's node first (0 disables)",
                )
                .param_in(
                    "homed_resume",
                    1.0,
                    0.0,
                    1.0,
                    "release tied continuations to their data's home node (0 disables)",
                )
                .param_in(
                    "batch",
                    1.0,
                    1.0,
                    MAX_BATCH,
                    "max tasks per steal (steal-half from deep affine pools; 1 = single steal)",
                )
                .param_in(
                    "spawn_batch",
                    1.0,
                    1.0,
                    MAX_BATCH,
                    "coalesce this many same-target home pushes per lock (1 = push each spawn)",
                ),
            |p| {
                Ok(Box::new(home::NumaHome::configured(
                    p.req("min_kb")?,
                    p.req_flag("steal_bias")?,
                    p.req_flag("homed_resume")?,
                    p.req_usize("batch")? as u32,
                    p.req_usize("spawn_batch")? as u32,
                )))
            },
        ),
        entry(
            SchedulerInfo::new("numa-steal", "steal-side-only locality: affine victims first")
                .param_in(
                    "min_kb",
                    home::DEFAULT_MIN_KB,
                    0.0,
                    f64::INFINITY,
                    "ignore affinity hints smaller than this many KiB",
                )
                .param_in(
                    "batch",
                    1.0,
                    1.0,
                    MAX_BATCH,
                    "max tasks per steal (steal-half from deep affine pools; 1 = single steal)",
                ),
            |p| {
                Ok(Box::new(steal::NumaSteal::configured(
                    p.req("min_kb")?,
                    p.req_usize("batch")? as u32,
                )))
            },
        ),
        entry(
            SchedulerInfo::new(
                "numa-adapt",
                "steal-half affine bias that tightens while the affine-steal ratio lags target",
            )
            .param_in(
                "min_kb",
                home::DEFAULT_MIN_KB,
                0.0,
                f64::INFINITY,
                "ignore affinity hints smaller than this many KiB",
            )
            .param_in(
                "target",
                adapt::DEFAULT_TARGET,
                0.0,
                1.0,
                "affine-steal ratio below which the bias tightens to affine-only sweeps",
            )
            .param_in(
                "min_steals",
                16.0,
                0.0,
                9.0e15,
                "steals observed before the ratio is trusted",
            )
            .param_in(
                "batch",
                adapt::DEFAULT_BATCH,
                1.0,
                MAX_BATCH,
                "max tasks per steal (steal-half from deep affine pools)",
            ),
            |p| {
                Ok(Box::new(adapt::NumaAdapt::new(
                    p.req("min_kb")?,
                    p.req("target")?,
                    p.req_usize("min_steals")? as u64,
                    p.req_usize("batch")? as u32,
                )))
            },
        ),
        entry(
            SchedulerInfo::new("adaptive", "work-first until the remote-steal ratio crosses")
                .param_in(
                    "remote_ratio",
                    0.5,
                    0.0,
                    1.0,
                    "remote-steal ratio that triggers the switch",
                )
                .param_in(
                    "min_steals",
                    16.0,
                    0.0,
                    9.0e15,
                    "steals observed before the ratio is trusted",
                ),
            |p| {
                let ratio = p.req("remote_ratio")?;
                let min_steals = p.req_usize("min_steals")? as u64;
                Ok(Box::new(adaptive::Adaptive::new(ratio, min_steals)))
            },
        ),
    ]
}

/// Upper bound for declared `batch` parameters (far above any real pool
/// depth; keeps the u32 cast trivially safe).
const MAX_BATCH: f64 = 65536.0;

/// Hard validation of a registration's declared parameters — enforced
/// in release builds too (the `ParamInfo::bounded` `debug_assert`
/// vanishes under `--release`, and a user scheduler whose default sits
/// outside its own declared range would then register fine and fail
/// only when [`build`] range-checks the untouched default).  `vet`
/// reports the same rule as `VET010`.
fn validate_info(info: &SchedulerInfo) -> Result<()> {
    for (i, p) in info.params.iter().enumerate() {
        if !p.default.is_finite() || !(p.min <= p.default && p.default <= p.max) {
            bail!(
                "scheduler '{}' parameter '{}': default {} outside declared range {}..={}",
                info.name,
                p.name,
                p.default,
                p.min,
                p.max
            );
        }
        if info.params[..i].iter().any(|q| q.name == p.name) {
            bail!("scheduler '{}' declares parameter '{}' twice", info.name, p.name);
        }
    }
    Ok(())
}

/// Register a scheduler.  Fails on a name/alias collision or an invalid
/// parameter declaration.  The factory must not call back into the
/// registry.
pub fn register(
    info: SchedulerInfo,
    factory: impl Fn(&SchedParams) -> Result<Box<dyn Scheduler>> + Send + Sync + 'static,
) -> Result<()> {
    validate_info(&info)?;
    let mut reg = registry().lock().unwrap();
    let mut new_names: Vec<&str> = vec![info.name.as_str()];
    new_names.extend(info.aliases.iter().map(String::as_str));
    for e in reg.iter() {
        for n in &new_names {
            if e.info.name == *n || e.info.aliases.iter().any(|a| a == n) {
                bail!("scheduler name '{n}' is already registered");
            }
        }
    }
    reg.push(Arc::new(Entry { info, factory: Box::new(factory) }));
    Ok(())
}

/// Canonical names, in registration order (builtins first).
pub fn scheduler_names() -> Vec<String> {
    registry().lock().unwrap().iter().map(|e| e.info.name.clone()).collect()
}

/// Full registration metadata for every scheduler.
pub fn scheduler_infos() -> Vec<SchedulerInfo> {
    registry().lock().unwrap().iter().map(|e| e.info.clone()).collect()
}

fn find_entry(name: &str) -> Result<Arc<Entry>> {
    let reg = registry().lock().unwrap();
    for e in reg.iter() {
        if e.info.name == name || e.info.aliases.iter().any(|a| a == name) {
            return Ok(e.clone());
        }
    }
    let known: Vec<String> = reg.iter().map(|e| e.info.name.clone()).collect();
    bail!("unknown scheduler '{name}' (registered: {})", known.join("|"))
}

/// Resolve a name or alias to its canonical registry name.
pub fn resolve_name(name: &str) -> Result<String> {
    Ok(find_entry(name)?.info.name.clone())
}

/// Build a scheduler instance from a spec: resolves the name, validates
/// the parameter overrides against the declared [`ParamInfo`]s, overlays
/// them on the defaults, and calls the factory.
pub fn build(spec: &SchedSpec) -> Result<Box<dyn Scheduler>> {
    let entry = find_entry(&spec.name)?;
    let declared = &entry.info.params;
    let mut params = SchedParams {
        pairs: declared.iter().map(|p| (p.name.clone(), p.default)).collect(),
    };
    for (key, value) in &spec.params {
        let Some(slot) = params.pairs.iter_mut().find(|(k, _)| k == key) else {
            let allowed: Vec<&str> = declared.iter().map(|p| p.name.as_str()).collect();
            bail!(
                "scheduler '{}' has no parameter '{key}' ({})",
                entry.info.name,
                if allowed.is_empty() {
                    "it takes none".to_string()
                } else {
                    format!("parameters: {}", allowed.join(" "))
                }
            );
        };
        slot.1 = *value;
    }
    // Factories range-check their own parameters but f64 casts swallow
    // NaN/inf silently (`NaN as u64 == 0` would turn numa-home's hint
    // floor off); reject non-finite values for every scheduler here,
    // before any factory sees them.  Declared [`ParamInfo`] ranges are
    // enforced in the same place: a negative `min_kb` or `target` used
    // to reach the factory, and any factory without its own check would
    // silently invert the comparison the parameter feeds.
    for (key, value) in &params.pairs {
        if !value.is_finite() {
            bail!(
                "scheduler '{}' parameter '{key}' must be finite, got {value}",
                entry.info.name
            );
        }
        let info = declared
            .iter()
            .find(|p| &p.name == key)
            .expect("params are built from the declarations");
        if *value < info.min || *value > info.max {
            bail!(
                "scheduler '{}' parameter '{key}' must be in {}..={}, got {value}",
                entry.info.name,
                info.min,
                info.max
            );
        }
    }
    (entry.factory)(&params)
        .with_context(|| format!("building scheduler '{}'", entry.info.name))
}

/// Expand a parameter grid into concrete [`SchedSpec`]s: the cross
/// product of every `(param, values)` axis over one scheduler, validated
/// against its declared [`ParamInfo`]s — the ROADMAP's "tunable-grid
/// sweep axis" without hand-enumerated manifest cells.
///
/// ```
/// use numanos::coordinator::sched;
/// let grid = sched::param_grid(
///     "hops-threshold",
///     &[("max_hops", &[0.0, 1.0, 2.0, 3.0]), ("spill_after", &[2.0])],
/// )
/// .unwrap();
/// assert_eq!(grid.len(), 4);
/// assert_eq!(grid[1].name_sig(), "hops-threshold(max_hops=1;spill_after=2)");
/// ```
pub fn param_grid(name: &str, axes: &[(&str, &[f64])]) -> Result<Vec<SchedSpec>> {
    let base = SchedSpec::new(&resolve_name(name)?);
    let mut specs = vec![base];
    for (param, values) in axes {
        if values.is_empty() {
            bail!("parameter grid axis '{param}' has no values");
        }
        let mut next = Vec::with_capacity(specs.len() * values.len());
        for spec in &specs {
            for &v in *values {
                next.push(spec.clone().with_param(param, v));
            }
        }
        specs = next;
    }
    for spec in &specs {
        spec.check()?;
    }
    Ok(specs)
}

/// Build one of the six stock strategies directly (infallible; the shim
/// behind every legacy `Policy`-typed entry point).
pub fn stock(policy: Policy) -> Box<dyn Scheduler> {
    match policy {
        Policy::Serial => Box::new(serial::Serial),
        Policy::BreadthFirst => Box::new(bf::BreadthFirst),
        Policy::CilkBased => Box::new(cilk::CilkBased),
        Policy::WorkFirst => Box::new(wf::WorkFirst),
        Policy::Dfwspt => Box::new(dfwspt::Dfwspt),
        Policy::Dfwsrpt => Box::new(dfwsrpt::Dfwsrpt),
    }
}

// ---------------------------------------------------------------------
// SchedSpec — the serializable scheduler selection
// ---------------------------------------------------------------------

/// A scheduler selection as data: registry name plus parameter overrides
/// (kept sorted by key so equal selections compare equal).  This is what
/// `RunSpec`, sweeps, manifests and the CLI carry; [`build`] turns it
/// into a live [`Scheduler`].
#[derive(Clone, Debug, PartialEq)]
pub struct SchedSpec {
    pub name: String,
    pub params: Vec<(String, f64)>,
}

impl SchedSpec {
    /// By registry name, no overrides (not validated until [`build`] /
    /// `RunSpec::validate`).
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), params: Vec::new() }
    }

    /// The stock strategy behind a legacy [`Policy`].
    pub fn stock(policy: Policy) -> Self {
        Self::new(policy.name())
    }

    /// Add/replace one parameter override (kept sorted by key).
    pub fn with_param(mut self, key: &str, value: f64) -> Self {
        self.set_param(key, value);
        self
    }

    pub fn set_param(&mut self, key: &str, value: f64) {
        match self.params.binary_search_by(|(k, _)| k.as_str().cmp(key)) {
            Ok(i) => self.params[i].1 = value,
            Err(i) => self.params.insert(i, (key.to_string(), value)),
        }
    }

    /// Parse the CLI form: `name` or `name:key=value,key=value`.  The
    /// name (or alias) is resolved to its canonical form and the
    /// parameters are validated eagerly.
    pub fn parse(text: &str) -> Result<Self> {
        let (name, params_text) = match text.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (text.trim(), None),
        };
        let mut spec = Self::new(&resolve_name(name)?);
        if let Some(pairs) = params_text {
            for pair in pairs.split(',').filter(|s| !s.trim().is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .with_context(|| format!("bad scheduler parameter '{pair}' (want k=v)"))?;
                let v: f64 = v
                    .trim()
                    .parse()
                    .with_context(|| format!("bad scheduler parameter value in '{pair}'"))?;
                spec.set_param(k.trim(), v);
            }
        }
        spec.check()?;
        Ok(spec)
    }

    /// Validate name + parameters against the registry.
    pub fn check(&self) -> Result<()> {
        build(self).map(|_| ())
    }

    /// The serial measurement baseline?
    pub fn is_serial(&self) -> bool {
        self.name == "serial"
    }

    /// Canonical signature for describe lines and CSV cells: `name` or
    /// `name(k=v;k=v)` (no commas — CSV-safe).
    pub fn name_sig(&self) -> String {
        if self.params.is_empty() {
            return self.name.clone();
        }
        let parts: Vec<String> =
            self.params.iter().map(|(k, v)| format!("{k}={}", fmt_f64(*v))).collect();
        format!("{}({})", self.name, parts.join(";"))
    }

    /// JSON form: a bare string without parameters, else
    /// `{"name": …, "<param>": <value>, …}`.
    pub fn to_json(&self) -> Json {
        if self.params.is_empty() {
            return Json::from(self.name.as_str());
        }
        let pairs = std::iter::once(("name".to_string(), Json::from(self.name.as_str())))
            .chain(self.params.iter().map(|(k, v)| (k.clone(), Json::from(*v))));
        Json::obj(pairs)
    }

    /// Accept both JSON forms (string name / object with parameters).
    pub fn from_json(j: &Json) -> Result<Self> {
        match j {
            Json::Str(s) => Self::parse(s),
            _ => {
                let obj = j
                    .as_obj()
                    .context("sched must be a scheduler name or {\"name\": …, params…}")?;
                let name = obj
                    .get("name")
                    .and_then(Json::as_str)
                    .context("parameterized sched needs a string 'name'")?;
                let mut spec = Self::new(&resolve_name(name)?);
                for (key, val) in obj {
                    if key == "name" {
                        continue;
                    }
                    let v = val
                        .as_num()
                        .with_context(|| format!("sched parameter '{key}' must be a number"))?;
                    spec.set_param(key, v);
                }
                spec.check()?;
                Ok(spec)
            }
        }
    }
}

impl From<Policy> for SchedSpec {
    fn from(policy: Policy) -> Self {
        SchedSpec::stock(policy)
    }
}

// ---------------------------------------------------------------------
// Legacy Policy shim
// ---------------------------------------------------------------------

/// How an idle worker picks victims — the legacy declarative table
/// (kept for the [`victim_sequence`] parity shim).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VictimKind {
    /// No stealing (breadth-first / serial).
    None,
    /// Uniform random sweep over all other workers.
    Random,
    /// Paper §VI.A: hop-distance groups, ascending; lower thread id first
    /// within a group.
    PriorityList,
    /// Paper §VI.B: hop-distance groups, ascending; random order within a
    /// group (de-convoys the lowest-id victim).
    RandomPriorityList,
}

/// The six stock strategies as a closed enum — a **deprecated shim** kept
/// so pre-registry call sites (`Runtime::run`, figure specs, config
/// files) stay source-compatible.  New code should carry a [`SchedSpec`]
/// and let the registry construct a [`Scheduler`]; strategies outside the
/// stock six are not representable here.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Overhead-free depth-first baseline (speedup denominator).
    Serial,
    BreadthFirst,
    CilkBased,
    WorkFirst,
    Dfwspt,
    Dfwsrpt,
}

impl Policy {
    pub fn all() -> &'static [Policy] {
        &[
            Policy::Serial,
            Policy::BreadthFirst,
            Policy::CilkBased,
            Policy::WorkFirst,
            Policy::Dfwspt,
            Policy::Dfwsrpt,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Serial => "serial",
            Policy::BreadthFirst => "bf",
            Policy::CilkBased => "cilk",
            Policy::WorkFirst => "wf",
            Policy::Dfwspt => "dfwspt",
            Policy::Dfwsrpt => "dfwsrpt",
        }
    }

    /// Resolve through the registry (so aliases and the "unknown
    /// scheduler" list stay in sync with it), then map onto the stock
    /// enum.  Registered non-stock strategies are rejected with a pointer
    /// to [`SchedSpec`].
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        Ok(match resolve_name(s)?.as_str() {
            "serial" => Policy::Serial,
            "bf" => Policy::BreadthFirst,
            "cilk" => Policy::CilkBased,
            "wf" => Policy::WorkFirst,
            "dfwspt" => Policy::Dfwspt,
            "dfwsrpt" => Policy::Dfwsrpt,
            other => anyhow::bail!(
                "scheduler '{other}' is not expressible as a legacy Policy; \
                 select it through a SchedSpec (e.g. --sched {other})"
            ),
        })
    }

    /// Child-first (depth-first) execution on spawn?
    pub fn depth_first(self) -> bool {
        !matches!(self, Policy::BreadthFirst)
    }

    /// Single shared FIFO instead of per-worker deques?
    pub fn shared_queue(self) -> bool {
        matches!(self, Policy::BreadthFirst)
    }

    pub fn steal_end(self) -> StealEnd {
        match self {
            Policy::CilkBased => StealEnd::Front,
            _ => StealEnd::Back,
        }
    }

    pub fn victim_kind(self) -> VictimKind {
        match self {
            Policy::Serial | Policy::BreadthFirst => VictimKind::None,
            Policy::CilkBased | Policy::WorkFirst => VictimKind::Random,
            Policy::Dfwspt => VictimKind::PriorityList,
            Policy::Dfwsrpt => VictimKind::RandomPriorityList,
        }
    }

    /// Serial baseline charges no runtime overheads.
    pub fn overhead_free(self) -> bool {
        matches!(self, Policy::Serial)
    }
}

// ---------------------------------------------------------------------
// Victim lists
// ---------------------------------------------------------------------

/// Per-worker victim structure: other workers grouped by hop distance from
/// this worker's core, groups ascending by distance, members ascending by
/// thread id (the paper's "priority list").
#[derive(Clone, Debug)]
pub struct VictimList {
    /// (hops, thread ids at that distance)
    pub groups: Vec<(u8, Vec<usize>)>,
}

impl VictimList {
    pub fn total(&self) -> usize {
        self.groups.iter().map(|(_, g)| g.len()).sum()
    }
}

/// Build every worker's victim list from the thread→core binding.
pub fn build_victim_lists(topo: &Topology, cores: &[usize]) -> Vec<VictimList> {
    (0..cores.len())
        .map(|me| {
            let mut by_hops: Vec<(u8, usize)> = (0..cores.len())
                .filter(|&t| t != me)
                .map(|t| (topo.core_hops(cores[me], cores[t]), t))
                .collect();
            by_hops.sort_unstable();
            let mut groups: Vec<(u8, Vec<usize>)> = Vec::new();
            for (h, t) in by_hops {
                match groups.last_mut() {
                    Some((gh, g)) if *gh == h => g.push(t),
                    _ => groups.push((h, vec![t])),
                }
            }
            VictimList { groups }
        })
        .collect()
}

/// Produce a stock policy's victim visiting order into `out`.
///
/// This is the **pre-redesign enum interpreter**, kept verbatim: the
/// parity tests pin every stock [`Scheduler`] implementation against it
/// (same RNG stream, same output), which is what guarantees byte-identical
/// sweep CSV/JSON across the trait migration.
pub fn victim_sequence(
    policy: Policy,
    vl: &VictimList,
    rng: &mut SplitMix64,
    out: &mut Vec<usize>,
) {
    out.clear();
    match policy.victim_kind() {
        VictimKind::None => {}
        VictimKind::Random => {
            out.extend(vl.groups.iter().flat_map(|(_, g)| g.iter().copied()));
            rng.shuffle(out);
        }
        VictimKind::PriorityList => dfwspt::order(vl, out),
        VictimKind::RandomPriorityList => dfwsrpt::order(vl, rng, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::{bind_threads, BindPolicy};

    fn lists(threads: usize) -> (Topology, Vec<VictimList>) {
        let topo = Topology::x4600();
        let mut rng = SplitMix64::new(1);
        let b = bind_threads(&topo, threads, BindPolicy::Linear, &mut rng);
        let vls = build_victim_lists(&topo, &b.cores);
        (topo, vls)
    }

    #[test]
    fn policy_roundtrip_names() {
        for &p in Policy::all() {
            assert_eq!(Policy::from_name(p.name()).unwrap(), p);
        }
        let err = format!("{:#}", Policy::from_name("bogus").unwrap_err());
        assert!(err.contains("unknown scheduler"), "{err}");
    }

    /// Builtin names, fixed (not `scheduler_names()`: other tests may
    /// register extra schedulers concurrently).
    const BUILTINS: [&str; 12] = [
        "serial",
        "bf",
        "cilk",
        "wf",
        "dfwspt",
        "dfwsrpt",
        "hops-threshold",
        "hier",
        "numa-home",
        "numa-steal",
        "numa-adapt",
        "adaptive",
    ];

    #[test]
    fn policy_from_name_error_lists_registered_schedulers() {
        let err = format!("{:#}", Policy::from_name("bogus").unwrap_err());
        for name in BUILTINS {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }

    #[test]
    fn registered_non_stock_names_are_rejected_by_the_shim() {
        let err = format!("{:#}", Policy::from_name("hops-threshold").unwrap_err());
        assert!(err.contains("SchedSpec"), "{err}");
    }

    #[test]
    fn victim_groups_ascending_distance() {
        let (_, vls) = lists(16);
        for vl in &vls {
            for w in vl.groups.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
            assert_eq!(vl.total(), 15);
        }
    }

    #[test]
    fn same_node_sibling_is_first_group() {
        let (_, vls) = lists(16);
        // thread 0 on core 0; thread 1 on core 1 shares node 0
        assert_eq!(vls[0].groups[0], (0, vec![1]));
    }

    #[test]
    fn random_sequence_is_permutation() {
        let (_, vls) = lists(8);
        let mut rng = SplitMix64::new(2);
        let mut out = Vec::new();
        victim_sequence(Policy::WorkFirst, &vls[3], &mut rng, &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 4, 5, 6, 7]);
    }

    #[test]
    fn bf_has_no_victims() {
        let (_, vls) = lists(8);
        let mut rng = SplitMix64::new(2);
        let mut out = vec![99];
        victim_sequence(Policy::BreadthFirst, &vls[0], &mut rng, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn trait_victim_order_matches_legacy_enum_path() {
        // The load-bearing parity guarantee: for every stock policy, the
        // registry-built Scheduler consumes the same RNG stream and emits
        // the same victim order as the pre-redesign enum interpreter.
        for threads in [2, 5, 8, 16] {
            let (_, vls) = lists(threads);
            for &p in Policy::all() {
                let sched = build(&SchedSpec::stock(p)).unwrap();
                for seed in 0..20 {
                    for vl in &vls {
                        let mut rng_a = SplitMix64::new(seed);
                        let mut rng_b = SplitMix64::new(seed);
                        let mut legacy = Vec::new();
                        let mut ported = Vec::new();
                        victim_sequence(p, vl, &mut rng_a, &mut legacy);
                        sched.victim_order(vl, &mut rng_b, &mut ported);
                        assert_eq!(legacy, ported, "{} t={threads} seed={seed}", p.name());
                        assert_eq!(
                            rng_a.next_u64(),
                            rng_b.next_u64(),
                            "{} consumed a different amount of randomness",
                            p.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn stock_descriptors_match_legacy_accessors() {
        for &p in Policy::all() {
            let d = stock(p).descriptor();
            assert_eq!(d.shared_queue(), p.shared_queue(), "{}", p.name());
            assert_eq!(d.child_first, p.depth_first(), "{}", p.name());
            assert_eq!(d.steal_end, p.steal_end(), "{}", p.name());
            assert_eq!(d.overhead_free, p.overhead_free(), "{}", p.name());
            assert_eq!(stock(p).name(), p.name());
        }
    }

    #[test]
    fn registry_lists_builtins_in_order() {
        let names = scheduler_names();
        for stock_name in ["serial", "bf", "cilk", "wf", "dfwspt", "dfwsrpt"] {
            assert!(names.contains(&stock_name.to_string()), "{names:?}");
        }
        for new_name in
            ["hops-threshold", "hier", "numa-home", "numa-steal", "numa-adapt", "adaptive"]
        {
            assert!(names.contains(&new_name.to_string()), "{names:?}");
        }
    }

    /// Satellite regression: NaN/inf parameter values are rejected at
    /// `build()` for every scheduler (a NaN `min_kb` used to cast to 0
    /// and silently disable numa-home's hint floor; the factories only
    /// range-checked negatives).
    #[test]
    fn non_finite_params_rejected_for_every_scheduler() {
        for (name, param) in [
            ("numa-home", "min_kb"),
            ("numa-steal", "min_kb"),
            ("hops-threshold", "max_hops"),
            ("adaptive", "remote_ratio"),
        ] {
            for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
                let spec = SchedSpec::new(name).with_param(param, bad);
                let err = format!("{:#}", build(&spec).unwrap_err());
                assert!(err.contains("finite"), "{name}.{param}={bad}: {err}");
            }
        }
        // finite values still build
        assert!(build(&SchedSpec::new("numa-home").with_param("min_kb", 4.0)).is_ok());
    }

    /// Satellite regression: negative (and otherwise out-of-range)
    /// parameter values are rejected at `build()` from the declared
    /// [`ParamInfo`] ranges, for every registered scheduler — a negative
    /// `min_kb` or `target` used to reach the factory and silently invert
    /// the comparison it feeds when the factory forgot its own check.
    #[test]
    fn out_of_range_params_rejected_for_every_scheduler() {
        for (name, param, bad) in [
            ("numa-home", "min_kb", -1.0),
            ("numa-home", "steal_bias", -1.0),
            ("numa-home", "batch", 0.0),
            ("numa-home", "spawn_batch", 0.0),
            ("numa-steal", "min_kb", -0.5),
            ("numa-steal", "batch", -2.0),
            ("numa-adapt", "target", -0.1),
            ("numa-adapt", "target", 1.5),
            ("numa-adapt", "min_kb", -4.0),
            ("numa-adapt", "batch", 0.0),
            ("hops-threshold", "max_hops", -1.0),
            ("hops-threshold", "max_hops", 300.0),
            ("hops-threshold", "spill_after", -1.0),
            ("adaptive", "remote_ratio", -0.25),
            ("adaptive", "remote_ratio", 1.5),
            ("adaptive", "min_steals", -8.0),
        ] {
            let spec = SchedSpec::new(name).with_param(param, bad);
            let err = format!("{:#}", build(&spec).unwrap_err());
            assert!(
                err.contains("must be in"),
                "{name}.{param}={bad} must fail the range check: {err}"
            );
        }
        // boundary values still build
        assert!(build(&SchedSpec::new("numa-home").with_param("min_kb", 0.0)).is_ok());
        assert!(build(&SchedSpec::new("numa-adapt").with_param("target", 1.0)).is_ok());
        assert!(build(&SchedSpec::new("hops-threshold").with_param("max_hops", 255.0)).is_ok());
    }

    #[test]
    fn steal_half_takes_batches_affine_candidates_only() {
        let cand = |victim, affine, queued| StealCand { victim, hops: 1, affine, queued, take: 1 };
        let mut cands =
            vec![cand(1, 0, 9), cand(2, 3, 9), cand(3, 1, 1), cand(4, 2, 100), cand(5, 1, 3)];
        steal_half_takes(&mut cands, 8);
        let takes: Vec<u32> = cands.iter().map(|c| c.take).collect();
        // non-affine keeps 1; half of 9 is 4; half of 1 clamps up to 1;
        // half of 100 clamps down to the cap; half of 3 is 1
        assert_eq!(takes, vec![1, 4, 1, 8, 1]);
        // max_take <= 1 leaves everything at the stock single steal
        let mut cands = vec![cand(1, 5, 40)];
        steal_half_takes(&mut cands, 1);
        assert_eq!(cands[0].take, 1);
        // the constructor shorthand defaults to a single steal
        assert_eq!(StealCand::single(3, 2, 1, 4).take, 1);
    }

    #[test]
    fn bias_affine_first_is_a_stable_partition() {
        let cand = |victim, affine| StealCand::single(victim, 1, affine, affine + 1);
        let mut cands = vec![cand(4, 0), cand(2, 1), cand(7, 0), cand(1, 3), cand(5, 0)];
        bias_affine_first(&mut cands);
        let order: Vec<usize> = cands.iter().map(|c| c.victim).collect();
        // affine victims lead, both classes keep their relative order
        assert_eq!(order, vec![2, 1, 4, 7, 5]);
        // all-zero summaries leave the sweep untouched
        let mut plain = vec![cand(3, 0), cand(9, 0), cand(0, 0)];
        bias_affine_first(&mut plain);
        assert_eq!(plain.iter().map(|c| c.victim).collect::<Vec<_>>(), vec![3, 9, 0]);
    }

    #[test]
    fn aliases_resolve_to_canonical_names() {
        assert_eq!(resolve_name("work-first").unwrap(), "wf");
        assert_eq!(resolve_name("breadth-first").unwrap(), "bf");
        assert_eq!(resolve_name("hierarchical").unwrap(), "hier");
        assert!(resolve_name("bogus").is_err());
    }

    #[test]
    fn build_validates_parameters() {
        // unknown parameter names are listed
        let bad = SchedSpec::new("hops-threshold").with_param("max_hopps", 1.0);
        let err = format!("{:#}", build(&bad).unwrap_err());
        assert!(err.contains("max_hopps") && err.contains("max_hops"), "{err}");
        // parameterless schedulers reject any parameter
        let bad = SchedSpec::new("wf").with_param("x", 1.0);
        assert!(format!("{:#}", build(&bad).unwrap_err()).contains("takes none"));
        // out-of-range values are caught by the factory
        let bad = SchedSpec::new("adaptive").with_param("remote_ratio", 1.5);
        assert!(build(&bad).is_err());
        let bad = SchedSpec::new("hops-threshold").with_param("max_hops", 1.5);
        assert!(build(&bad).is_err(), "fractional integer parameter");
        // defaults apply when no overrides are given
        assert!(build(&SchedSpec::new("hops-threshold")).is_ok());
    }

    #[test]
    fn sched_spec_parse_and_signatures() {
        let plain = SchedSpec::parse("wf").unwrap();
        assert_eq!(plain, SchedSpec::stock(Policy::WorkFirst));
        assert_eq!(plain.name_sig(), "wf");

        let aliased = SchedSpec::parse("work-first").unwrap();
        assert_eq!(aliased.name, "wf", "aliases canonicalize at parse time");

        let p = SchedSpec::parse("hops-threshold:max_hops=2,spill_after=1").unwrap();
        assert_eq!(p.name_sig(), "hops-threshold(max_hops=2;spill_after=1)");
        assert!(SchedSpec::parse("hops-threshold:max_hops=").is_err());
        assert!(SchedSpec::parse("hops-threshold:bogus=1").is_err());
        assert!(SchedSpec::parse("nope").is_err());
    }

    #[test]
    fn sched_spec_json_roundtrips() {
        let plain = SchedSpec::stock(Policy::Dfwspt);
        assert_eq!(plain.to_json().to_compact(), "\"dfwspt\"");
        assert_eq!(SchedSpec::from_json(&plain.to_json()).unwrap(), plain);

        let p = SchedSpec::new("hops-threshold").with_param("max_hops", 1.0);
        let back = SchedSpec::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);

        let j = Json::parse(r#"{"name": "adaptive", "remote_ratio": 0.25}"#).unwrap();
        let spec = SchedSpec::from_json(&j).unwrap();
        assert_eq!(spec.name, "adaptive");
        assert_eq!(spec.params, vec![("remote_ratio".to_string(), 0.25)]);

        assert!(SchedSpec::from_json(&Json::parse("{\"max_hops\": 1}").unwrap()).is_err());
    }

    #[test]
    fn param_grid_expands_the_cross_product() {
        let grid = param_grid(
            "hops-threshold",
            &[("max_hops", &[0.0, 1.0, 2.0, 3.0]), ("spill_after", &[1.0, 2.0])],
        )
        .unwrap();
        assert_eq!(grid.len(), 8);
        assert_eq!(grid[0].name_sig(), "hops-threshold(max_hops=0;spill_after=1)");
        assert_eq!(grid[7].name_sig(), "hops-threshold(max_hops=3;spill_after=2)");
        // aliases canonicalize, single-axis grids work
        let grid = param_grid("hierarchical", &[]).unwrap();
        assert_eq!(grid, vec![SchedSpec::new("hier")]);
        // invalid axes fail loudly
        assert!(param_grid("bogus", &[]).is_err());
        assert!(param_grid("hops-threshold", &[("bogus", &[1.0])]).is_err());
        assert!(param_grid("hops-threshold", &[("max_hops", &[])]).is_err(), "empty axis");
        assert!(param_grid("hops-threshold", &[("max_hops", &[300.0])]).is_err(), "u8 range");
    }

    #[test]
    fn params_stay_sorted_so_equal_specs_compare_equal() {
        let a = SchedSpec::new("hops-threshold")
            .with_param("spill_after", 3.0)
            .with_param("max_hops", 1.0);
        let b = SchedSpec::new("hops-threshold")
            .with_param("max_hops", 1.0)
            .with_param("spill_after", 3.0);
        assert_eq!(a, b);
    }

    #[test]
    fn user_registration_shows_up_everywhere() {
        struct Nop;
        impl Scheduler for Nop {
            fn name(&self) -> &str {
                "test-nop"
            }
            fn descriptor(&self) -> SchedDescriptor {
                SchedDescriptor::WORK_STEALING
            }
            fn victim_order(&self, _: &VictimList, _: &mut SplitMix64, _: &mut Vec<usize>) {}
        }
        register(SchedulerInfo::new("test-nop", "no-op test scheduler"), |_| Ok(Box::new(Nop)))
            .unwrap();
        assert!(scheduler_names().contains(&"test-nop".to_string()));
        assert!(build(&SchedSpec::new("test-nop")).is_ok());
        // duplicate registration is rejected
        assert!(register(SchedulerInfo::new("test-nop", "dup"), |_| Ok(Box::new(Nop))).is_err());
        assert!(register(SchedulerInfo::new("wf", "dup"), |_| Ok(Box::new(Nop))).is_err());
    }

    #[test]
    fn descriptor_table_matches_paper() {
        assert!(!Policy::BreadthFirst.depth_first());
        assert!(Policy::BreadthFirst.shared_queue());
        assert_eq!(Policy::CilkBased.steal_end(), StealEnd::Front);
        assert_eq!(Policy::WorkFirst.steal_end(), StealEnd::Back);
        assert_eq!(Policy::Dfwspt.victim_kind(), VictimKind::PriorityList);
        assert_eq!(Policy::Dfwsrpt.victim_kind(), VictimKind::RandomPriorityList);
        assert!(Policy::Serial.overhead_free());
    }
}
