//! Task scheduling policies — three stock NANOS schedulers plus the
//! paper's two NUMA-aware contributions.
//!
//! | policy | queueing | steal end | victim selection |
//! |---|---|---|---|
//! | [`bf`]      breadth-first | one shared FIFO | —     | — (no stealing) |
//! | [`cilk`]    Cilk-based    | per-worker deque, child-first | front | uniform random |
//! | [`wf`]      work-first    | per-worker deque, child-first | back  | uniform random |
//! | [`dfwspt`]  §VI.A         | per-worker deque, child-first | back  | hop-ordered priority list, id-ties first |
//! | [`dfwsrpt`] §VI.B         | per-worker deque, child-first | back  | hop-ordered priority list, random within a distance group |
//!
//! `Serial` is the measurement baseline: depth-first execution with every
//! runtime overhead constant zeroed (the paper's "serial execution time"
//! denominator).
//!
//! The policies are *declarative* here (an enum plus descriptors); the
//! event engine interprets them.  Victim *order* generation is delegated to
//! the per-policy modules so each strategy's logic sits next to its
//! documentation and tests.

pub mod bf;
pub mod cilk;
pub mod dfwsrpt;
pub mod dfwspt;
pub mod wf;

use crate::topology::Topology;
use crate::util::SplitMix64;

/// Which end of a victim's deque a thief takes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StealEnd {
    /// Most recently suspended parent (Cilk THE-style).
    Front,
    /// Oldest / shallowest task (work-first style).
    Back,
}

/// How an idle worker picks victims.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VictimKind {
    /// No stealing (breadth-first / serial).
    None,
    /// Uniform random sweep over all other workers.
    Random,
    /// Paper §VI.A: hop-distance groups, ascending; lower thread id first
    /// within a group.
    PriorityList,
    /// Paper §VI.B: hop-distance groups, ascending; random order within a
    /// group (de-convoys the lowest-id victim).
    RandomPriorityList,
}

/// Scheduling policy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Overhead-free depth-first baseline (speedup denominator).
    Serial,
    BreadthFirst,
    CilkBased,
    WorkFirst,
    Dfwspt,
    Dfwsrpt,
}

impl Policy {
    pub fn all() -> &'static [Policy] {
        &[
            Policy::Serial,
            Policy::BreadthFirst,
            Policy::CilkBased,
            Policy::WorkFirst,
            Policy::Dfwspt,
            Policy::Dfwsrpt,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Serial => "serial",
            Policy::BreadthFirst => "bf",
            Policy::CilkBased => "cilk",
            Policy::WorkFirst => "wf",
            Policy::Dfwspt => "dfwspt",
            Policy::Dfwsrpt => "dfwsrpt",
        }
    }

    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "serial" => Policy::Serial,
            "bf" | "breadth-first" => Policy::BreadthFirst,
            "cilk" | "cilk-based" => Policy::CilkBased,
            "wf" | "work-first" => Policy::WorkFirst,
            "dfwspt" => Policy::Dfwspt,
            "dfwsrpt" => Policy::Dfwsrpt,
            other => anyhow::bail!(
                "unknown scheduler '{other}' (serial|bf|cilk|wf|dfwspt|dfwsrpt)"
            ),
        })
    }

    /// Child-first (depth-first) execution on spawn?
    pub fn depth_first(self) -> bool {
        !matches!(self, Policy::BreadthFirst)
    }

    /// Single shared FIFO instead of per-worker deques?
    pub fn shared_queue(self) -> bool {
        matches!(self, Policy::BreadthFirst)
    }

    pub fn steal_end(self) -> StealEnd {
        match self {
            Policy::CilkBased => StealEnd::Front,
            _ => StealEnd::Back,
        }
    }

    pub fn victim_kind(self) -> VictimKind {
        match self {
            Policy::Serial | Policy::BreadthFirst => VictimKind::None,
            Policy::CilkBased | Policy::WorkFirst => VictimKind::Random,
            Policy::Dfwspt => VictimKind::PriorityList,
            Policy::Dfwsrpt => VictimKind::RandomPriorityList,
        }
    }

    /// Serial baseline charges no runtime overheads.
    pub fn overhead_free(self) -> bool {
        matches!(self, Policy::Serial)
    }
}

/// Per-worker victim structure: other workers grouped by hop distance from
/// this worker's core, groups ascending by distance, members ascending by
/// thread id (the paper's "priority list").
#[derive(Clone, Debug)]
pub struct VictimList {
    /// (hops, thread ids at that distance)
    pub groups: Vec<(u8, Vec<usize>)>,
}

impl VictimList {
    pub fn total(&self) -> usize {
        self.groups.iter().map(|(_, g)| g.len()).sum()
    }
}

/// Build every worker's victim list from the thread→core binding.
pub fn build_victim_lists(topo: &Topology, cores: &[usize]) -> Vec<VictimList> {
    (0..cores.len())
        .map(|me| {
            let mut by_hops: Vec<(u8, usize)> = (0..cores.len())
                .filter(|&t| t != me)
                .map(|t| (topo.core_hops(cores[me], cores[t]), t))
                .collect();
            by_hops.sort_unstable();
            let mut groups: Vec<(u8, Vec<usize>)> = Vec::new();
            for (h, t) in by_hops {
                match groups.last_mut() {
                    Some((gh, g)) if *gh == h => g.push(t),
                    _ => groups.push((h, vec![t])),
                }
            }
            VictimList { groups }
        })
        .collect()
}

/// Produce this policy's victim visiting order into `out`.
pub fn victim_sequence(
    policy: Policy,
    vl: &VictimList,
    rng: &mut SplitMix64,
    out: &mut Vec<usize>,
) {
    out.clear();
    match policy.victim_kind() {
        VictimKind::None => {}
        VictimKind::Random => {
            out.extend(vl.groups.iter().flat_map(|(_, g)| g.iter().copied()));
            rng.shuffle(out);
        }
        VictimKind::PriorityList => dfwspt::order(vl, out),
        VictimKind::RandomPriorityList => dfwsrpt::order(vl, rng, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::{bind_threads, BindPolicy};

    fn lists(threads: usize) -> (Topology, Vec<VictimList>) {
        let topo = Topology::x4600();
        let mut rng = SplitMix64::new(1);
        let b = bind_threads(&topo, threads, BindPolicy::Linear, &mut rng);
        let vls = build_victim_lists(&topo, &b.cores);
        (topo, vls)
    }

    #[test]
    fn policy_roundtrip_names() {
        for &p in Policy::all() {
            assert_eq!(Policy::from_name(p.name()).unwrap(), p);
        }
        assert!(Policy::from_name("bogus").is_err());
    }

    #[test]
    fn victim_groups_ascending_distance() {
        let (_, vls) = lists(16);
        for vl in &vls {
            for w in vl.groups.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
            assert_eq!(vl.total(), 15);
        }
    }

    #[test]
    fn same_node_sibling_is_first_group() {
        let (_, vls) = lists(16);
        // thread 0 on core 0; thread 1 on core 1 shares node 0
        assert_eq!(vls[0].groups[0], (0, vec![1]));
    }

    #[test]
    fn random_sequence_is_permutation() {
        let (_, vls) = lists(8);
        let mut rng = SplitMix64::new(2);
        let mut out = Vec::new();
        victim_sequence(Policy::WorkFirst, &vls[3], &mut rng, &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 4, 5, 6, 7]);
    }

    #[test]
    fn bf_has_no_victims() {
        let (_, vls) = lists(8);
        let mut rng = SplitMix64::new(2);
        let mut out = vec![99];
        victim_sequence(Policy::BreadthFirst, &vls[0], &mut rng, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn descriptor_table_matches_paper() {
        assert!(!Policy::BreadthFirst.depth_first());
        assert!(Policy::BreadthFirst.shared_queue());
        assert_eq!(Policy::CilkBased.steal_end(), StealEnd::Front);
        assert_eq!(Policy::WorkFirst.steal_end(), StealEnd::Back);
        assert_eq!(Policy::Dfwspt.victim_kind(), VictimKind::PriorityList);
        assert_eq!(Policy::Dfwsrpt.victim_kind(), VictimKind::RandomPriorityList);
        assert!(Policy::Serial.overhead_free());
    }
}
