//! `numa-steal` — steal-side-only locality: affine victims first.
//!
//! The paper's placement strategy ([`super::home`]) moves *work toward
//! its data* with push-to-home spawns; this strategy isolates the other
//! lever the same infrastructure enables: leave every spawn on the stock
//! child-first path (no pushes, no homed resumes) and only *bias the
//! steal sweep* — when a worker goes idle, probe the victims whose pools
//! hold tasks homed on the thief's own node before anyone else (Wittmann
//! & Hager's task-to-data affinity, arXiv:1101.0093, applied at steal
//! time).  A biased thief tends to pull work whose pages already live
//! next to it, so the steal itself repairs locality instead of eroding
//! it — without ever paying the cross-node push traffic `numa-home`
//! risks on badly-hinted graphs.
//!
//! A `batch` above 1 turns the bias into *steal-half*
//! ([`super::steal_half_takes`]): a thief probing a deep affine pool
//! drains up to half of it under one lock instead of re-sweeping per
//! task (Wang et al., arXiv:2502.05293).  The default of 1 keeps the
//! stock single steal.
//!
//! The base sweep is the §VI.B random priority list, so with a cold page
//! table (no hints resolved yet, all summaries zero) `numa-steal`
//! degenerates to exactly [`super::dfwsrpt`]'s behaviour.  The strategy
//! opts into [`SchedDescriptor::places`] purely so the engine resolves
//! and caches spawn-time home tags (that is what feeds the pool
//! summaries); its [`Scheduler::place`] hook keeps the default
//! `LocalQueue` answer, so no task is ever pushed anywhere.
//!
//! Ablation triangle: `dfwsrpt` (no locality) vs `numa-steal` (steal
//! side only) vs `numa-home` (both sides) separates how much of the
//! remote-ratio drop comes from biased steals alone.

use super::{
    bias_affine_first, dfwsrpt, steal_half_takes, SchedDescriptor, Scheduler, StealCand,
    VictimList,
};
use crate::util::SplitMix64;

/// Locality-biased stealing over §VI.B victim selection.
pub struct NumaSteal {
    /// Minimum affinity-hint size (bytes) worth resolving a home for.
    min_bytes: u64,
    /// Steal-half cap: max tasks drained per steal from an affine victim
    /// (1 = the stock single steal).
    batch: u32,
}

impl NumaSteal {
    pub fn new(min_kb: f64) -> Self {
        Self::configured(min_kb, 1)
    }

    /// Biased stealing with an explicit steal-half cap.
    pub fn configured(min_kb: f64, batch: u32) -> Self {
        Self { min_bytes: (min_kb * 1024.0) as u64, batch }
    }
}

impl Scheduler for NumaSteal {
    fn name(&self) -> &str {
        "numa-steal"
    }

    fn signature(&self) -> String {
        format!(
            "numa-steal(batch={};min_kb={})",
            self.batch,
            crate::util::fmt_f64(self.min_bytes as f64 / 1024.0)
        )
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            // opt into the locality hooks: the engine resolves + caches
            // home tags (feeding the pool summaries steal_bias reads)
            // and routes sweeps through the hook.  place() stays the
            // default LocalQueue, so spawns are untouched.
            places: true,
            min_hint_bytes: self.min_bytes,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        dfwsrpt::order(vl, rng, out);
    }

    fn steal_bias(&self, _thief_node: usize, cands: &mut Vec<StealCand>) {
        bias_affine_first(cands);
        steal_half_takes(cands, self.batch);
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;
    use crate::simnuma::Region;

    #[test]
    fn descriptor_opts_into_hooks_but_never_pushes() {
        let s = NumaSteal::new(16.0);
        let d = s.descriptor();
        assert!(d.places, "hooks require the opt-in");
        assert!(d.full_sweep, "the base sweep visits every victim");
        assert_eq!(d.min_hint_bytes, 16 * 1024);
        // the place hook keeps the stock answer: no push-to-home
        let ctx = SpawnCtx {
            worker: 0,
            worker_node: 0,
            affinity: Region { addr: 1 << 20, bytes: 1 << 20 },
            home: Some(5),
        };
        assert_eq!(s.place(&ctx), Placement::LocalQueue);
        // and continuations stay tied to their first owner
        let rctx = ResumeCtx { releaser: 0, owner: 1, owner_node: 0, home: Some(5) };
        assert_eq!(s.resume(&rctx), Placement::LocalQueue);
    }

    #[test]
    fn sweeps_like_dfwsrpt_then_biases_affine_first() {
        let vl = VictimList { groups: vec![(0, vec![1]), (2, vec![2, 3])] };
        for seed in 0..8 {
            let mut rng_a = SplitMix64::new(seed);
            let mut rng_b = SplitMix64::new(seed);
            let mut a = Vec::new();
            let mut b = Vec::new();
            NumaSteal::new(16.0).victim_order(&vl, &mut rng_a, &mut a);
            dfwsrpt::order(&vl, &mut rng_b, &mut b);
            assert_eq!(a, b, "base order is §VI.B");
        }
        let cand = |victim, affine| StealCand::single(victim, 0, affine, 3);
        let mut cands = vec![cand(1, 0), cand(2, 0), cand(3, 4)];
        NumaSteal::new(16.0).steal_bias(0, &mut cands);
        assert_eq!(cands.iter().map(|c| c.victim).collect::<Vec<_>>(), vec![3, 1, 2]);
        assert!(cands.iter().all(|c| c.take == 1), "default batch keeps single steals");
    }

    #[test]
    fn batch_above_one_enables_steal_half() {
        let cand = |victim, affine, queued| StealCand::single(victim, 0, affine, queued);
        let mut cands = vec![cand(1, 0, 10), cand(2, 3, 10), cand(3, 1, 5)];
        NumaSteal::configured(16.0, 4).steal_bias(0, &mut cands);
        let got: Vec<(usize, u32)> = cands.iter().map(|c| (c.victim, c.take)).collect();
        assert_eq!(got, vec![(2, 4), (3, 2), (1, 1)], "steal-half on affine victims only");
    }

    #[test]
    fn registry_builds_with_defaults_and_overrides() {
        let s = build(&SchedSpec::new("numa-steal")).unwrap();
        assert_eq!(s.name(), "numa-steal");
        assert_eq!(s.signature(), "numa-steal(batch=1;min_kb=16)");
        let s = build(&SchedSpec::new("numa-steal").with_param("min_kb", 0.0)).unwrap();
        assert_eq!(s.signature(), "numa-steal(batch=1;min_kb=0)");
        let s = build(&SchedSpec::new("numa-steal").with_param("batch", 4.0)).unwrap();
        assert_eq!(s.signature(), "numa-steal(batch=4;min_kb=16)");
        assert!(build(&SchedSpec::new("numa-steal").with_param("min_kb", -1.0)).is_err());
        assert!(build(&SchedSpec::new("numa-steal").with_param("batch", 0.0)).is_err());
        assert!(build(&SchedSpec::new("numa-steal").with_param("bogus", 1.0)).is_err());
    }
}
