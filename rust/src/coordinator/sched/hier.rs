//! `hier` — two-level hierarchical stealing (after Thibault et al.'s
//! bubble scheduling, arXiv:0706.2073).
//!
//! Victim selection mirrors the machine hierarchy instead of flattening
//! it:
//!
//! 1. **Node-local level** — victims on the thief's own NUMA node
//!    (hop distance 0), in random order.  Intra-node steals are nearly
//!    free: no interconnect crossing, data on the local memory.
//! 2. **Delegate level** — delegation to the rest of the machine is
//!    stochastic: each sweep, a worker extends past its node with
//!    probability `1/k` where `k` is the node's team size, so *in
//!    expectation* one thread per node probes remote pools at a time
//!    (several may in unlucky overlapping sweeps — the shaping is
//!    statistical, not a mutex).  Remote groups keep the hop-ascending
//!    priority order, randomized within a group.
//!
//! The effect is bubble-like traffic shaping: a starving node forwards
//! roughly one representative across the fabric instead of stampeding
//! every idle core over the interconnect — the many-thieves convoy that
//! [`super::dfwsrpt`] mitigates *within* a group is damped *between*
//! nodes too.

use super::{SchedDescriptor, Scheduler, VictimList};
use crate::util::SplitMix64;

/// Two-level node-local / delegate stealing.
pub struct Hierarchical;

impl Scheduler for Hierarchical {
    fn name(&self) -> &str {
        "hier"
    }

    fn descriptor(&self) -> SchedDescriptor {
        SchedDescriptor {
            // non-delegate sweeps stop at the node boundary, so the
            // engine must wake a sleeping tied-continuation owner
            // directly (a round-robin-woken worker might never probe it)
            full_sweep: false,
            ..SchedDescriptor::WORK_STEALING
        }
    }

    fn victim_order(&self, vl: &VictimList, rng: &mut SplitMix64, out: &mut Vec<usize>) {
        // Level 1: node-local victims (hop distance 0), random order.
        // Groups ascend by distance, so only the first can be local.
        let mut local_len = 0;
        if let Some((0, group)) = vl.groups.first() {
            out.extend(group.iter().copied());
            rng.shuffle(out);
            local_len = group.len();
        }
        // Level 2: delegate election.  The node's team is this worker
        // plus its local victims; with probability 1/team one sweep
        // crosses the interconnect.  (A worker alone on its node always
        // delegates itself — there is no local level to try.)
        let team = local_len as u64 + 1;
        if rng.gen_range(team) == 0 {
            for (hops, group) in &vl.groups {
                if *hops == 0 {
                    continue;
                }
                let start = out.len();
                out.extend(group.iter().copied());
                rng.shuffle(&mut out[start..]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    fn vl() -> VictimList {
        VictimList {
            groups: vec![(0, vec![1, 2, 3]), (1, vec![4, 5]), (2, vec![6])],
        }
    }

    #[test]
    fn local_victims_always_lead() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..64 {
            let mut out = Vec::new();
            Hierarchical.victim_order(&vl(), &mut rng, &mut out);
            assert!(out.len() >= 3, "local group always present");
            let local: std::collections::BTreeSet<_> = out[..3].iter().copied().collect();
            assert_eq!(local, [1, 2, 3].into_iter().collect());
            if out.len() > 3 {
                // remote tail keeps hop-ascending group order
                let mid: std::collections::BTreeSet<_> = out[3..5].iter().copied().collect();
                assert_eq!(mid, [4, 5].into_iter().collect());
                assert_eq!(out[5], 6);
            }
        }
    }

    #[test]
    fn delegation_is_occasional_not_constant() {
        let mut rng = SplitMix64::new(2);
        let mut remote_sweeps = 0;
        const SWEEPS: usize = 400;
        for _ in 0..SWEEPS {
            let mut out = Vec::new();
            Hierarchical.victim_order(&vl(), &mut rng, &mut out);
            if out.len() > 3 {
                remote_sweeps += 1;
            }
        }
        // expectation is SWEEPS/4 (team of 4); allow a wide band
        assert!(remote_sweeps > SWEEPS / 10, "{remote_sweeps}");
        assert!(remote_sweeps < SWEEPS / 2, "{remote_sweeps}");
    }

    #[test]
    fn lone_worker_on_a_node_always_delegates() {
        // no hops-0 group: every sweep must reach the remote victims,
        // or the worker could never steal at all
        let vl = VictimList { groups: vec![(1, vec![1]), (2, vec![2, 3])] };
        let mut rng = SplitMix64::new(3);
        for _ in 0..16 {
            let mut out = Vec::new();
            Hierarchical.victim_order(&vl, &mut rng, &mut out);
            assert_eq!(out.len(), 3);
            assert_eq!(out[0], 1, "nearest group first");
        }
    }

    #[test]
    fn registry_resolves_hier_and_its_alias() {
        assert_eq!(build(&SchedSpec::new("hier")).unwrap().name(), "hier");
        assert_eq!(resolve_name("hierarchical").unwrap(), "hier");
    }
}
