#![deny(clippy::unwrap_used)]
//! Task pools: per-worker deques and the breadth-first shared queue.
//!
//! Pools carry a simulated-time *contention model*.  The engine executes
//! one scheduling quantum per event, so workers' clocks skew by up to a
//! task length; a strict lock busy-horizon would charge phantom waits to
//! ops arriving "from the virtual past".  Instead each pool tracks the
//! lock demand landing in the current epoch and prices an op by M/M/1
//! queueing on that utilization, with the critical section itself
//! inflating under sustained contention (lock cache-line ping-pong).
//!
//! This is how the paper's contention effects emerge without real
//! threads: the breadth-first shared queue *collapses* once op demand
//! saturates it (Fig 7/9: speedup declines beyond ~6 threads), and steal
//! convoys pile onto the lowest-id closest victim under DFWSPT — exactly
//! the contention DFWSRPT randomizes away (§VI.B).

use std::collections::VecDeque;

use crate::coordinator::task::{TaskId, NO_HOME};
use crate::util::{Time, US};

/// Utilization-averaging window.
const EPOCH: Time = 20 * US;
/// Critical-section inflation per estimated queued contender.
const CONVOY_FACTOR: f64 = 0.35;
/// Estimator cap (≈ team size).
const MAX_CONTENDERS: f64 = 16.0;
/// Utilization cap (keeps the M/M/1 term finite).
const MAX_RHO: f64 = 0.95;

/// A lockable task container (deque or FIFO discipline chosen by caller).
///
/// Every entry carries its task's cached home-node tag
/// ([`crate::coordinator::task::TaskInst::home`]), and the pool keeps a
/// per-node count of resident tags — the O(1) "does this victim hold
/// work homed near me?" summary steal-bias hooks read without scanning
/// the deque.  Under stock schedulers every tag is [`NO_HOME`] and the
/// summary stays all-zero.
#[derive(Debug, Default)]
pub struct Pool {
    items: VecDeque<(TaskId, u8)>,
    /// Per-node count of resident tasks' home tags (grown on demand;
    /// [`NO_HOME`] entries are not counted).
    homed: Vec<u32>,
    /// Lock demand (inflated op durations) within the current epoch.
    epoch: u64,
    used: Time,
    /// Total simulated queueing delay charged on this pool's lock.
    pub lock_wait: Time,
    pub ops: u64,
    /// Home-summary desyncs observed by [`Pool::note_pop`]: pops whose
    /// tag was never pushed (or whose node count had already drained).
    /// Always 0 on a healthy engine; checked mode
    /// ([`crate::analysis::checked`]) verifies that every event and
    /// aborts with a `CHK009` report otherwise, where a `debug_assert`
    /// would have vanished in `--release`.
    pub tag_desyncs: u64,
}

impl Pool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Acquire the pool lock at `now` for a base op of `duration`.
    /// Returns the op's total cost (queueing + inflated holding).
    #[inline]
    pub fn lock(&mut self, now: Time, duration: Time) -> Time {
        if duration == 0 {
            self.ops += 1;
            return 0; // overhead-free serial baseline
        }
        // Workers' clocks legitimately skew within a quantum, so ops can
        // arrive from an *older* epoch than the newest one seen.  Only a
        // genuinely newer epoch opens a fresh window; a stale-epoch op is
        // charged against the current window instead of zeroing it (the
        // old `!=` reset erased the epoch's accumulated demand and
        // undercounted convoy costs for every later op).
        let epoch = now / EPOCH;
        if epoch > self.epoch {
            self.epoch = epoch;
            self.used = 0;
        }
        // Zero-contention fast path: with no demand in the window,
        // rho == 0.0 exactly, so contenders == 0.0, eff == duration and
        // wait == 0 — provably the slow path's result (pinned below by
        // `zero_contention_fast_path_is_exact`), minus the f64 M/M/1
        // arithmetic.  First op of every epoch takes this branch, which
        // on lightly-contended pools is nearly every op.
        if self.used == 0 {
            self.used = duration;
            self.ops += 1;
            return duration;
        }
        let rho = (self.used as f64 / EPOCH as f64).min(MAX_RHO);
        // expected queue length ahead of us (M/M/1), also the convoy size
        let contenders = (rho / (1.0 - rho)).min(MAX_CONTENDERS);
        let eff = duration + (duration as f64 * CONVOY_FACTOR * contenders) as Time;
        let wait = (eff as f64 * contenders) as Time;
        self.used += eff;
        self.lock_wait += wait;
        self.ops += 1;
        wait + eff
    }

    #[inline]
    fn note_push(&mut self, home: u8) {
        if home != NO_HOME {
            let node = home as usize;
            if self.homed.len() <= node {
                self.homed.resize(node + 1, 0);
            }
            self.homed[node] += 1;
        }
    }

    #[inline]
    fn note_pop(&mut self, home: u8) {
        if home != NO_HOME {
            // The per-entry tag is recorded at push time, so pushes and
            // pops pair up — but a task whose home is re-resolved between
            // queuing and re-queuing (homed resumes make the new runner
            // the owner) used to be able to decrement a count its push
            // never incremented, underflowing the summary and poisoning
            // every later `homed_count` bias decision.  Callers now retag
            // on push (the engine re-reads the arena's current home at
            // every push site); this guard keeps the summary sane even if
            // a future caller slips a stale tag through — and counts the
            // desync into `tag_desyncs` so checked mode can surface it
            // in release builds too.
            match self.homed.get_mut(home as usize) {
                Some(count) => {
                    if *count == 0 {
                        self.tag_desyncs += 1;
                    }
                    *count = count.saturating_sub(1);
                }
                None => self.tag_desyncs += 1,
            }
        }
    }

    #[inline]
    pub fn push_front(&mut self, t: TaskId, home: u8) {
        self.note_push(home);
        self.items.push_front((t, home));
    }

    #[inline]
    pub fn push_back(&mut self, t: TaskId, home: u8) {
        self.note_push(home);
        self.items.push_back((t, home));
    }

    #[inline]
    pub fn pop_front(&mut self) -> Option<TaskId> {
        let (t, home) = self.items.pop_front()?;
        self.note_pop(home);
        Some(t)
    }

    #[inline]
    pub fn pop_back(&mut self) -> Option<TaskId> {
        let (t, home) = self.items.pop_back()?;
        self.note_pop(home);
        Some(t)
    }

    /// Pop up to `n` entries from the back — the multi-pop behind
    /// steal-half batching.  Entries are appended to `out` in pop order
    /// (so `out`'s first new element is exactly what [`Pool::pop_back`]
    /// would have returned), and the per-node home summary is maintained
    /// entry by entry, same as `n` individual pops.
    pub fn drain_back(&mut self, n: usize, out: &mut Vec<TaskId>) {
        for _ in 0..n {
            match self.pop_back() {
                Some(t) => out.push(t),
                None => break,
            }
        }
    }

    /// Resident tasks homed on `node` — the per-node summary steal-bias
    /// hooks consult (a word read, no deque scan).
    #[inline]
    pub fn homed_count(&self, node: usize) -> u32 {
        self.homed.get(node).copied().unwrap_or(0)
    }

    /// Does the per-node `homed` summary equal an actual recount of the
    /// resident entries' tags?  O(len) — checked mode's periodic pool
    /// verification (`CHK005`); never called on the hot path.
    pub fn home_summary_consistent(&self) -> bool {
        let mut counts = vec![0u32; self.homed.len()];
        for &(_, home) in &self.items {
            if home != NO_HOME {
                let node = home as usize;
                if node >= counts.len() {
                    return false; // tagged entry the summary never saw
                }
                counts[node] += 1;
            }
        }
        counts == self.homed
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.items.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deque_discipline() {
        let mut p = Pool::new();
        p.push_front(1, NO_HOME);
        p.push_front(2, NO_HOME);
        p.push_back(3, NO_HOME);
        // order: [2, 1, 3]
        assert_eq!(p.pop_front(), Some(2));
        assert_eq!(p.pop_back(), Some(3));
        assert_eq!(p.pop_front(), Some(1));
        assert_eq!(p.pop_front(), None);
    }

    #[test]
    fn home_summary_tracks_resident_tags() {
        let mut p = Pool::new();
        p.push_front(1, 2);
        p.push_back(2, 2);
        p.push_back(3, 0);
        p.push_back(4, NO_HOME); // untagged tasks are never counted
        assert_eq!(p.homed_count(2), 2);
        assert_eq!(p.homed_count(0), 1);
        assert_eq!(p.homed_count(1), 0);
        assert_eq!(p.homed_count(99), 0, "unseen nodes read as empty");
        assert_eq!(p.pop_front(), Some(1));
        assert_eq!(p.homed_count(2), 1);
        assert_eq!(p.pop_back(), Some(4));
        assert_eq!(p.pop_back(), Some(3));
        assert_eq!(p.homed_count(0), 0);
        assert_eq!(p.pop_back(), Some(2));
        assert_eq!(p.homed_count(2), 0, "summary drains with the deque");
    }

    /// `drain_back(n)` is exactly `n` individual `pop_back`s: same task
    /// order, same home-summary maintenance, short pools stop early.
    #[test]
    fn drain_back_preserves_order_and_home_accounting() {
        let mut p = Pool::new();
        p.push_front(1, 2);
        p.push_front(2, NO_HOME);
        p.push_front(3, 2);
        p.push_front(4, 0);
        // front-to-back: [4, 3, 2, 1]
        let mut out = Vec::new();
        p.drain_back(3, &mut out);
        assert_eq!(out, vec![1, 2, 3], "pop order: first element == pop_back()");
        assert_eq!(p.len(), 1);
        assert_eq!(p.homed_count(2), 0, "both node-2 tags drained");
        assert_eq!(p.homed_count(0), 1, "task 4 still resident");
        // over-asking stops at empty without touching the summary again
        p.drain_back(10, &mut out);
        assert_eq!(out, vec![1, 2, 3, 4]);
        assert!(p.is_empty());
        assert_eq!(p.homed_count(0), 0);
        // draining an empty pool is a no-op
        p.drain_back(2, &mut out);
        assert_eq!(out.len(), 4);
    }

    /// Satellite regression: a continuation re-queued under a *changed*
    /// home tag (homed resumes re-resolve ownership between queuings)
    /// must keep the per-node summary consistent — the old unchecked
    /// `homed[home] -= 1` relied on push/pop tags never drifting.
    #[test]
    fn requeue_under_changed_home_keeps_summary_consistent() {
        let mut p = Pool::new();
        p.push_front(7, 1);
        assert_eq!(p.pop_front(), Some(7));
        // the task's home was re-resolved to node 2 before the requeue
        p.push_front(7, 2);
        assert_eq!(p.homed_count(1), 0);
        assert_eq!(p.homed_count(2), 1);
        assert_eq!(p.pop_back(), Some(7));
        assert_eq!(p.homed_count(1), 0, "no underflow on the old node");
        assert_eq!(p.homed_count(2), 0);
        // and again toward a node the pool never saw before
        p.push_back(7, 5);
        assert_eq!(p.pop_front(), Some(7));
        assert_eq!(p.homed_count(5), 0);
    }

    #[test]
    fn light_load_is_cheap() {
        let mut p = Pool::new();
        // a handful of ops spread over epochs: near-base cost
        for i in 0..10 {
            let cost = p.lock(i * US, 100 * crate::util::NS);
            assert!(cost < 120 * crate::util::NS, "uncontended op cost {cost}");
        }
        assert_eq!(p.ops, 10);
    }

    #[test]
    fn saturation_collapses_throughput() {
        // hammer one epoch far past its capacity: per-op cost must blow up
        let mut p = Pool::new();
        let ns = crate::util::NS;
        let first = p.lock(0, 100 * ns);
        let mut last = 0;
        for _ in 0..300 {
            last = p.lock(0, 100 * ns);
        }
        assert!(last > 10 * first, "no collapse: first {first} last {last}");
        assert!(p.lock_wait > 0);
        // a later epoch starts fresh
        let fresh = p.lock(100 * EPOCH, 100 * ns);
        assert!(fresh < 120 * ns, "estimate must decay: {fresh}");
    }

    #[test]
    fn cost_grows_with_utilization() {
        let mut p = Pool::new();
        let mut prev = 0;
        for k in 0..20 {
            // all within one epoch, increasing cumulative demand
            let cost = p.lock(k, 500 * crate::util::NS);
            assert!(cost >= prev, "cost must be monotone in utilization");
            prev = cost;
        }
    }

    #[test]
    fn zero_duration_free() {
        let mut p = Pool::new();
        assert_eq!(p.lock(0, 0), 0);
        assert_eq!(p.lock_wait, 0);
    }

    /// The `used == 0` short-circuit must be indistinguishable from the
    /// M/M/1 slow path: rho is exactly 0.0, so contenders is exactly
    /// 0.0, eff == duration and wait == 0 in exact f64 arithmetic.
    /// Pin every observable (cost, used-demand carried into the next
    /// op, lock_wait, ops) against the formula evaluated by hand.
    #[test]
    fn zero_contention_fast_path_is_exact() {
        let ns = crate::util::NS;
        for d in [1, 100 * ns, 4000 * ns, EPOCH] {
            let mut p = Pool::new();
            // first op of the epoch: the fast path
            let cost = p.lock(3 * EPOCH, d);
            // slow-path formula at used == 0
            let rho = (0f64 / EPOCH as f64).min(MAX_RHO);
            let contenders = (rho / (1.0 - rho)).min(MAX_CONTENDERS);
            let eff = d + (d as f64 * CONVOY_FACTOR * contenders) as Time;
            let wait = (eff as f64 * contenders) as Time;
            assert_eq!(cost, wait + eff);
            assert_eq!(cost, d, "zero contention charges the bare duration");
            assert_eq!(p.lock_wait, 0);
            assert_eq!(p.ops, 1);
            // the fast path must seed the window's demand exactly like
            // the slow path (used += eff), so the *next* op prices
            // identically to a pool that never took the shortcut
            let second = p.lock(3 * EPOCH + 1, d);
            let rho2 = (eff as f64 / EPOCH as f64).min(MAX_RHO);
            let contenders2 = (rho2 / (1.0 - rho2)).min(MAX_CONTENDERS);
            let eff2 = d + (d as f64 * CONVOY_FACTOR * contenders2) as Time;
            let wait2 = (eff2 as f64 * contenders2) as Time;
            assert_eq!(second, wait2 + eff2, "d={d}");
            assert_eq!(p.lock_wait, wait2);
            assert_eq!(p.ops, 2);
        }
    }

    /// Regression: an op arriving from an *older* epoch (worker clocks
    /// skew within a quantum) must charge into the current window, not
    /// reset it — the old `epoch != self.epoch` test zeroed `used` and
    /// erased the epoch's accumulated demand.
    #[test]
    fn stale_epoch_op_keeps_demand_monotone() {
        let ns = crate::util::NS;
        let d = 4000 * ns; // a fifth of the 20 us window per op
        let mut p = Pool::new();
        let c1 = p.lock(5 * EPOCH, d); // opens epoch 5, uncontended
        let c2 = p.lock(4 * EPOCH, d); // stale op: sees c1's demand
        let c3 = p.lock(5 * EPOCH + 1, d); // back in epoch 5: sees both
        assert!(c2 > c1, "stale op must pay for current demand: {c1} vs {c2}");
        assert!(c3 > c2, "demand must stay monotone within the window: {c2} vs {c3}");
        // with the old reset bug c3 re-opened the window and priced like
        // the very first op — pin the repaired behaviour explicitly
        assert!(c3 > c1, "window must survive a stale-epoch op: {c1} vs {c3}");
        // a genuinely newer epoch still starts fresh
        let fresh = p.lock(9 * EPOCH, d);
        assert_eq!(fresh, c1, "newer epochs reset the window");
    }

    /// A pop whose home tag was never pushed no longer vanishes in
    /// release builds: it counts into `tag_desyncs` (checked mode's
    /// CHK009 feed) and the summary stays saturated, never underflowed.
    #[test]
    fn stale_tag_pops_count_desyncs() {
        let mut p = Pool::new();
        assert_eq!(p.tag_desyncs, 0);
        // tag 3 was never pushed: the homed vec has no slot for it
        p.items.push_back((1, 3));
        assert_eq!(p.pop_back(), Some(1));
        assert_eq!(p.tag_desyncs, 1, "unknown tag counts a desync");
        // node 0's count drains to zero, then a second stale pop of the
        // same tag underflows — counted, not asserted away
        p.push_back(2, 0);
        assert_eq!(p.pop_back(), Some(2));
        p.items.push_back((3, 0));
        assert_eq!(p.pop_back(), Some(3));
        assert_eq!(p.tag_desyncs, 2, "drained-count pop counts a desync");
        assert_eq!(p.homed_count(0), 0, "summary saturates instead of underflowing");
    }

    /// The checked-mode recount agrees with the incremental summary
    /// through a push/pop mix, and detects a hand-broken summary.
    #[test]
    fn home_summary_consistency_probe() {
        let mut p = Pool::new();
        assert!(p.home_summary_consistent(), "empty pool is consistent");
        p.push_back(1, 0);
        p.push_front(2, 2);
        p.push_back(3, NO_HOME);
        assert!(p.home_summary_consistent());
        p.pop_front();
        assert!(p.home_summary_consistent());
        // resident tagged entry the summary never counted
        p.items.push_back((4, 1));
        assert!(!p.home_summary_consistent(), "recount must catch the desync");
    }
}
