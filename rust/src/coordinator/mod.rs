//! The coordinator — the paper's system contribution as a library.
//!
//! * [`task`]     — task model: descriptors, bodies, the [`task::Workload`] trait;
//! * [`pool`]     — lockable task pools (contention via busy horizons);
//! * [`priority`] — §IV core-priority allocation (Figs 2–4);
//! * [`binding`]  — thread→core binding policies (baseline vs NUMA-aware);
//! * [`sched`]    — the pluggable scheduler trait + registry (stock NANOS
//!   strategies, DFWSPT/DFWSRPT, and the locality strategies);
//! * [`engine`]   — deterministic discrete-event execution engine;
//! * [`runtime`]  — the assembled [`runtime::Runtime`] façade.

pub mod binding;
pub mod engine;
pub mod pool;
pub mod priority;
pub mod runtime;
pub mod sched;
pub mod task;

pub use binding::{bind_threads, BindPolicy, Binding};
pub use priority::{alpha_weights, core_priorities, PriorityAlloc};
pub use runtime::Runtime;
pub use sched::Policy;
pub use task::{Action, Body, BodyCtx, TaskDesc, Workload};
