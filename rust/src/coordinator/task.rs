#![deny(clippy::unwrap_used)]
//! Task representation: descriptors, bodies, the workload trait and the
//! task-instance arena.
//!
//! A benchmark (see [`crate::bots`]) is a [`Workload`]: a deterministic
//! generator of OpenMP-style tied tasks.  Task *descriptors* are plain-old
//! data (16 B of args) so spawning is allocation-free; a task's *body* (its
//! action list) is materialized once, when the task first runs, by calling
//! [`Workload::body`].
//!
//! Bodies follow the BOTS idiom: a **pre** phase (compute / touch / spawn
//! actions), an implicit `taskwait`, and a **post** phase (the continuation
//! after all children completed).  Tasks are *tied* as in NANOS: a
//! suspended task resumes on the worker that started it.

use crate::simnuma::{MemSim, Region};
use crate::util::Time;

/// Index into the [`TaskArena`].
pub type TaskId = u32;

/// Sentinel for [`TaskInst::home`]: no resolved home node.  Tasks get a
/// real tag only when a placement-aware scheduler is active (the engine
/// resolves the spawn's affinity hint once, at spawn time); under stock
/// schedulers every task keeps the sentinel, so home-keyed bookkeeping
/// (pool summaries, affine-steal counting) is provably inert for them.
pub const NO_HOME: u8 = u8::MAX;

/// Plain-old-data task descriptor; `kind`/`args` are interpreted by the
/// owning [`Workload`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskDesc {
    pub kind: u16,
    pub args: [i64; 4],
}

impl TaskDesc {
    pub fn new(kind: u16, args: [i64; 4]) -> Self {
        Self { kind, args }
    }

    pub fn leaf(kind: u16) -> Self {
        Self { kind, args: [0; 4] }
    }
}

/// One step of a task body.  `Copy`: the engine's inner loop copies one
/// action out of the body per step (a few dozen bytes, no heap) instead
/// of borrowing across the arena mutations the action triggers.
#[derive(Clone, Copy, Debug)]
pub enum Action {
    /// Pure ALU work in compute units (1 unit ≈ 1 ns, see `CostModel`).
    Compute(u64),
    /// Memory traffic over a simulated region.
    Touch { region: Region, write: bool },
    /// Create a child task.  `affinity` is the region the child will
    /// mostly touch ([`Region::EMPTY`] = no hint); placement-aware
    /// schedulers may push the child toward that data's home node, the
    /// rest ignore it entirely.
    Spawn { desc: TaskDesc, affinity: Region },
    /// Invoke a real AOT kernel (PJRT mode only; tag is workload-defined).
    /// Simulated cost must be modeled by an accompanying `Compute`/`Touch`.
    Kernel(u64),
}

/// Materialized body: pre-phase actions, then (after children) post-phase.
#[derive(Clone, Debug, Default)]
pub struct Body {
    pub pre: Vec<Action>,
    pub post: Vec<Action>,
}

/// Builder handed to [`Workload::body`].
#[derive(Debug, Default)]
pub struct BodyCtx {
    body: Body,
    waited: bool,
}

impl BodyCtx {
    /// Rebuild into an existing (cleared) body — lets the engine recycle
    /// the action vectors' capacity across task-slot reuse (hot path).
    pub fn with_body(mut body: Body) -> Self {
        body.pre.clear();
        body.post.clear();
        Self { body, waited: false }
    }

    fn actions(&mut self) -> &mut Vec<Action> {
        if self.waited {
            &mut self.body.post
        } else {
            &mut self.body.pre
        }
    }

    /// ALU work in compute units.
    pub fn compute(&mut self, units: u64) {
        if units > 0 {
            self.actions().push(Action::Compute(units));
        }
    }

    /// Read traffic over `region`.
    pub fn read(&mut self, region: Region) {
        if region.bytes > 0 {
            self.actions().push(Action::Touch { region, write: false });
        }
    }

    /// Write traffic over `region` (bumps page versions -> invalidations).
    pub fn write(&mut self, region: Region) {
        if region.bytes > 0 {
            self.actions().push(Action::Touch { region, write: true });
        }
    }

    /// Spawn a child task with no data-affinity hint.
    pub fn spawn(&mut self, desc: TaskDesc) {
        self.spawn_on(desc, Region::EMPTY);
    }

    /// Spawn a child task hinting the region it will mostly touch — the
    /// OpenMP `affinity(data)` clause analogue.  Purely a hint:
    /// schedulers without a placement strategy (and hints over unresident
    /// regions) behave exactly like [`BodyCtx::spawn`].
    pub fn spawn_on(&mut self, desc: TaskDesc, affinity: Region) {
        self.actions().push(Action::Spawn { desc, affinity });
    }

    /// `#pragma omp taskwait`: subsequent actions form the continuation.
    /// At most one per body (the BOTS benchmarks need no more).
    pub fn taskwait(&mut self) {
        assert!(!self.waited, "only one taskwait per task body is modeled");
        self.waited = true;
    }

    /// Invoke real kernel `tag` at this point (PJRT compute mode).
    pub fn kernel(&mut self, tag: u64) {
        self.actions().push(Action::Kernel(tag));
    }

    pub fn finish(self) -> Body {
        self.body
    }
}

/// A benchmark: deterministic task-graph generator + optional real compute.
pub trait Workload {
    fn name(&self) -> &'static str;

    /// Allocate the workload's data in `mem` and perform the master's
    /// initialization touches (first-touch placement!).  Returns the
    /// simulated cost of the init phase (excluded from the timed region,
    /// like the BOTS timers, but its placement persists).
    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time;

    /// Descriptor of the root task.
    fn root(&self) -> TaskDesc;

    /// Emit the body of `desc` into `ctx`.
    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx);

    /// Run real kernel `tag` through the PJRT engine (compute mode).
    /// Default: no real compute.
    fn run_kernel(
        &mut self,
        _tag: u64,
        _exec: &mut crate::runtime::ExecEngine,
    ) -> anyhow::Result<()> {
        Ok(())
    }

    /// Verify real-compute results after the run (compute mode).
    fn verify(&self, _exec: &mut crate::runtime::ExecEngine) -> anyhow::Result<()> {
        Ok(())
    }

    /// Rough task-count hint (progress display / arena pre-sizing).
    fn task_count_hint(&self) -> Option<u64> {
        None
    }
}

/// Lifecycle of a task instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Created, queued, body not yet materialized.
    Fresh,
    /// Executing / suspended-by-child inside the pre phase.
    Pre,
    /// Pre phase done, children outstanding (implicit taskwait).
    Waiting,
    /// Children done; continuation queued or running.
    Post,
    /// Post phase done but it spawned children of its own (BOTS combine
    /// phases); completes when they do.
    WaitingFinal,
    Done,
}

/// A live task.
#[derive(Debug)]
pub struct TaskInst {
    pub desc: TaskDesc,
    pub parent: Option<TaskId>,
    /// Worker that first ran the task (tied-task resume target).
    pub owner: u16,
    /// Home NUMA node of the task's affinity region, resolved once at
    /// spawn time by the engine ([`NO_HOME`] when unhinted, unresolved,
    /// or the scheduler does not place).  Cached so steal-bias summaries
    /// and continuation homing never re-sample the page table.
    pub home: u8,
    pub state: TaskState,
    pub pending_children: u32,
    pub body: Body,
    /// Next action index within the current phase.
    pub cursor: usize,
    pub depth: u16,
    /// Generation counter for id reuse safety.
    pub gen: u32,
}

/// Slab arena of task instances with freelist reuse (millions of tasks
/// per run; peak-live is what bounds memory, not total).
pub struct TaskArena {
    slots: Vec<TaskInst>,
    free: Vec<TaskId>,
    live: usize,
    total_created: u64,
    peak_live: usize,
}

impl TaskArena {
    pub fn new() -> Self {
        Self { slots: Vec::new(), free: Vec::new(), live: 0, total_created: 0, peak_live: 0 }
    }

    pub fn create(&mut self, desc: TaskDesc, parent: Option<TaskId>, depth: u16) -> TaskId {
        self.total_created += 1;
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        if let Some(id) = self.free.pop() {
            let slot = &mut self.slots[id as usize];
            let gen = slot.gen + 1;
            let body = std::mem::take(&mut slot.body); // recycle capacity
            *slot = TaskInst {
                desc,
                parent,
                owner: u16::MAX,
                home: NO_HOME,
                state: TaskState::Fresh,
                pending_children: 0,
                body,
                cursor: 0,
                depth,
                gen,
            };
            id
        } else {
            self.slots.push(TaskInst {
                desc,
                parent,
                owner: u16::MAX,
                home: NO_HOME,
                state: TaskState::Fresh,
                pending_children: 0,
                body: Body::default(),
                cursor: 0,
                depth,
                gen: 0,
            });
            (self.slots.len() - 1) as TaskId
        }
    }

    pub fn release(&mut self, id: TaskId) {
        debug_assert_eq!(self.slots[id as usize].state, TaskState::Done);
        self.live -= 1;
        // body storage stays in the slot: its capacity is recycled by the
        // next task materialized there (see Engine::start_task)
        self.free.push(id);
    }

    #[inline]
    pub fn get(&self, id: TaskId) -> &TaskInst {
        &self.slots[id as usize]
    }

    #[inline]
    pub fn get_mut(&mut self, id: TaskId) -> &mut TaskInst {
        &mut self.slots[id as usize]
    }

    pub fn live(&self) -> usize {
        self.live
    }

    pub fn total_created(&self) -> u64 {
        self.total_created
    }

    pub fn peak_live(&self) -> usize {
        self.peak_live
    }
}

impl Default for TaskArena {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn body_ctx_splits_phases() {
        let mut ctx = BodyCtx::default();
        ctx.compute(5);
        ctx.spawn(TaskDesc::leaf(1));
        ctx.taskwait();
        ctx.compute(7);
        let body = ctx.finish();
        assert_eq!(body.pre.len(), 2);
        assert_eq!(body.post.len(), 1);
        assert!(matches!(body.post[0], Action::Compute(7)));
    }

    #[test]
    fn spawn_on_records_the_affinity_hint() {
        let mut ctx = BodyCtx::default();
        let region = Region { addr: 4096, bytes: 512 };
        ctx.spawn_on(TaskDesc::leaf(1), region);
        ctx.spawn(TaskDesc::leaf(2));
        let body = ctx.finish();
        match body.pre[0] {
            Action::Spawn { desc, affinity } => {
                assert_eq!(desc.kind, 1);
                assert_eq!(affinity, region);
            }
            ref other => panic!("expected a spawn, got {other:?}"),
        }
        match body.pre[1] {
            Action::Spawn { affinity, .. } => {
                assert_eq!(affinity, Region::EMPTY, "plain spawn carries no hint")
            }
            ref other => panic!("expected a spawn, got {other:?}"),
        }
    }

    #[test]
    fn zero_cost_actions_elided() {
        let mut ctx = BodyCtx::default();
        ctx.compute(0);
        ctx.read(Region::EMPTY);
        assert!(ctx.finish().pre.is_empty());
    }

    #[test]
    #[should_panic(expected = "one taskwait")]
    fn double_taskwait_panics() {
        let mut ctx = BodyCtx::default();
        ctx.taskwait();
        ctx.taskwait();
    }

    #[test]
    fn arena_reuses_slots() {
        let mut a = TaskArena::new();
        let t0 = a.create(TaskDesc::leaf(0), None, 0);
        a.get_mut(t0).home = 3;
        a.get_mut(t0).state = TaskState::Done;
        a.release(t0);
        let t1 = a.create(TaskDesc::leaf(1), None, 0);
        assert_eq!(t0, t1, "slot reused");
        assert_eq!(a.get(t1).gen, 1, "generation bumped");
        assert_eq!(a.get(t1).home, NO_HOME, "home tag must not leak across slot reuse");
        assert_eq!(a.total_created(), 2);
        assert_eq!(a.live(), 1);
    }

    #[test]
    fn arena_tracks_peak() {
        let mut a = TaskArena::new();
        let ids: Vec<_> = (0..10).map(|i| a.create(TaskDesc::leaf(i), None, 0)).collect();
        for id in &ids {
            a.get_mut(*id).state = TaskState::Done;
            a.release(*id);
        }
        assert_eq!(a.peak_live(), 10);
        assert_eq!(a.live(), 0);
    }
}
