//! The [`Runtime`] façade: a configured machine (topology + cost model).
//!
//! The NANOS start-up sequence the paper modifies (explore hardware →
//! compute priorities and bind → allocate per-thread runtime data →
//! first-touch init → execute under a scheduler) lives in
//! [`Session::execute`](crate::spec::Session::execute) /
//! [`Session::execute_bound`](crate::spec::Session::execute_bound); the
//! methods here are thin compatibility shims over that canonical path,
//! kept because "run this workload on that machine" is still the natural
//! verb for tests, benches and one-off programs.  Anything experiment-
//! shaped (baselines, sweeps, manifests) should go through
//! [`Session`](crate::spec::Session) / [`RunSpec`](crate::spec::RunSpec)
//! instead.

use anyhow::Result;

use crate::coordinator::binding::BindPolicy;
use crate::coordinator::sched::Policy;
use crate::coordinator::task::Workload;
use crate::metrics::RunStats;
use crate::runtime::ExecEngine;
use crate::simnuma::CostModel;
use crate::spec::Session;
use crate::topology::Topology;

/// A configured machine, ready to run workloads.
#[derive(Clone)]
pub struct Runtime {
    pub topo: Topology,
    pub cost: CostModel,
}

impl Runtime {
    pub fn new(topo: Topology, cost: CostModel) -> Self {
        Self { topo, cost }
    }

    /// X4600 with default calibration — the paper's testbed.
    pub fn paper_testbed() -> Self {
        Self::new(Topology::x4600(), CostModel::default())
    }

    /// Execute `workload` under `policy`/`bind` with `threads` threads.
    ///
    /// `exec` enables real PJRT compute for `Action::Kernel` steps.
    /// Shim over [`Session::execute`].
    pub fn run(
        &self,
        workload: &mut dyn Workload,
        policy: Policy,
        bind: BindPolicy,
        threads: usize,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        Session::execute(self, workload, policy, bind, threads, seed, exec)
    }

    /// Like [`Runtime::run`] but with an explicit thread→core binding
    /// (thread 0 = master).  `numa_rtdata` controls whether per-thread
    /// runtime pages are touched locally (§IV) or all by the master.
    /// Shim over [`Session::execute_bound`] — the ablation surface.
    pub fn run_bound(
        &self,
        workload: &mut dyn Workload,
        policy: Policy,
        cores: &[usize],
        numa_rtdata: bool,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        Session::execute_bound(self, workload, policy, cores, numa_rtdata, seed, exec)
    }

    /// The paper's speedup denominator: 1 thread, overhead-free depth-first
    /// execution, baseline binding.
    pub fn run_serial(&self, workload: &mut dyn Workload, seed: u64) -> Result<RunStats> {
        self.run(workload, Policy::Serial, BindPolicy::Linear, 1, seed, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{BodyCtx, TaskDesc};
    use crate::simnuma::{MemSim, Region};
    use crate::util::Time;

    /// Tiny deterministic workload: a two-level tree touching one array.
    struct Tree {
        data: Region,
        fanout: i64,
    }

    impl Workload for Tree {
        fn name(&self) -> &'static str {
            "tree"
        }

        fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
            self.data = mem.alloc(64 * 1024);
            mem.first_touch(master_core, self.data, 0)
        }

        fn root(&self) -> TaskDesc {
            TaskDesc::new(0, [self.fanout, 0, 0, 0])
        }

        fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
            match desc.kind {
                0 => {
                    for i in 0..desc.args[0] {
                        ctx.spawn(TaskDesc::new(1, [i, 0, 0, 0]));
                    }
                    ctx.taskwait();
                    ctx.compute(100);
                }
                _ => {
                    let chunk = self.data.bytes / self.fanout as u64;
                    ctx.read(self.data.slice(desc.args[0] as u64 * chunk, chunk));
                    ctx.compute(2_000);
                }
            }
        }
    }

    fn run_one(policy: Policy, bind: BindPolicy, threads: usize) -> RunStats {
        let rt = Runtime::paper_testbed();
        let mut w = Tree { data: Region::EMPTY, fanout: 64 };
        rt.run(&mut w, policy, bind, threads, 42, None).unwrap()
    }

    #[test]
    fn all_tasks_complete_under_every_policy() {
        for &p in Policy::all() {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let s = run_one(p, BindPolicy::Linear, threads);
            assert_eq!(s.tasks, 65, "{}", p.name());
            assert!(s.makespan > 0);
        }
    }

    #[test]
    fn parallel_beats_serial() {
        let serial = run_one(Policy::Serial, BindPolicy::Linear, 1);
        let par = run_one(Policy::WorkFirst, BindPolicy::Linear, 8);
        assert!(
            par.makespan < serial.makespan,
            "8 threads {} vs serial {}",
            par.makespan,
            serial.makespan
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_one(Policy::Dfwsrpt, BindPolicy::NumaAware, 8);
        let b = run_one(Policy::Dfwsrpt, BindPolicy::NumaAware, 8);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.steals, b.steals);
    }

    #[test]
    fn work_stealing_actually_steals() {
        let s = run_one(Policy::WorkFirst, BindPolicy::Linear, 8);
        assert!(s.steals > 0, "fanout tree must trigger steals");
    }

    #[test]
    fn bf_uses_shared_queue_only() {
        let s = run_one(Policy::BreadthFirst, BindPolicy::Linear, 8);
        assert_eq!(s.steals, 0);
        assert!(s.shared_ops > 0);
    }

    #[test]
    fn numa_bind_records_policy() {
        let s = run_one(Policy::Dfwspt, BindPolicy::NumaAware, 4);
        assert_eq!(s.bind, Some(BindPolicy::NumaAware));
        assert_eq!(s.label(), "dfwspt-Scheduler-NUMA");
    }

    #[test]
    fn shim_and_session_agree() {
        // Runtime::run must stay byte-equivalent to the Session path it
        // delegates to (same engine, same seed handling).
        let rt = Runtime::paper_testbed();
        let mut a = Tree { data: Region::EMPTY, fanout: 32 };
        let mut b = Tree { data: Region::EMPTY, fanout: 32 };
        let via_shim = rt.run(&mut a, Policy::Dfwspt, BindPolicy::NumaAware, 8, 9, None).unwrap();
        let via_session =
            Session::execute(&rt, &mut b, Policy::Dfwspt, BindPolicy::NumaAware, 8, 9, None)
                .unwrap();
        assert_eq!(via_shim.makespan, via_session.makespan);
        assert_eq!(via_shim.steals, via_session.steals);
    }
}
