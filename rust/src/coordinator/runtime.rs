//! The [`Runtime`] façade: topology + cost model + binding + engine.
//!
//! Mirrors the NANOS start-up sequence the paper modifies:
//!
//! 1. explore the hardware (here: the [`Topology`]);
//! 2. compute core priorities and bind the master (Figs 2–4) — or bind
//!    linearly for the baseline;
//! 3. allocate per-thread runtime data (locally per node when NUMA-aware,
//!    all on the master's node otherwise — paper §IV last paragraph);
//! 4. run the workload's master-side init (first-touch placement!);
//! 5. execute the task graph under the chosen scheduler.

use anyhow::Result;

use crate::coordinator::binding::{bind_threads, BindPolicy};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::sched::{build_victim_lists, Policy};
use crate::coordinator::task::Workload;
use crate::metrics::RunStats;
use crate::runtime::ExecEngine;
use crate::simnuma::{CostModel, MemSim, PAGE_BYTES};
use crate::topology::Topology;
use crate::util::{SplitMix64, Time};

/// A configured machine, ready to run workloads.
#[derive(Clone)]
pub struct Runtime {
    pub topo: Topology,
    pub cost: CostModel,
}

impl Runtime {
    pub fn new(topo: Topology, cost: CostModel) -> Self {
        Self { topo, cost }
    }

    /// X4600 with default calibration — the paper's testbed.
    pub fn paper_testbed() -> Self {
        Self::new(Topology::x4600(), CostModel::default())
    }

    /// Execute `workload` under `policy`/`bind` with `threads` threads.
    ///
    /// `exec` enables real PJRT compute for `Action::Kernel` steps.
    pub fn run(
        &self,
        workload: &mut dyn Workload,
        policy: Policy,
        bind: BindPolicy,
        threads: usize,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        let mut rng = SplitMix64::new(seed);
        let binding = bind_threads(&self.topo, threads, bind, &mut rng);
        let numa_rtdata = bind == BindPolicy::NumaAware;
        let mut stats = self.run_bound(workload, policy, &binding.cores, numa_rtdata, seed, exec)?;
        stats.bind = Some(bind);
        Ok(stats)
    }

    /// Like [`Runtime::run`] but with an explicit thread→core binding
    /// (thread 0 = master).  `numa_rtdata` controls whether per-thread
    /// runtime pages are touched locally (§IV) or all by the master.
    /// This is the ablation surface: any placement heuristic can be fed in.
    pub fn run_bound(
        &self,
        workload: &mut dyn Workload,
        policy: Policy,
        cores: &[usize],
        numa_rtdata: bool,
        seed: u64,
        exec: Option<&mut ExecEngine>,
    ) -> Result<RunStats> {
        let wall_start = std::time::Instant::now();
        let threads = cores.len();
        let binding = crate::coordinator::binding::Binding {
            cores: cores.to_vec(),
            priorities: None,
        };
        let mut mem = MemSim::new(self.topo.clone(), self.cost.clone());

        // Per-thread runtime data (pools, descriptors): one page each.
        // Baseline: the master first-touches everything (all pages land on
        // its node). NUMA-aware: each thread touches its own page from its
        // own core at start-up.
        let mut rt_penalty: Vec<Time> = Vec::with_capacity(threads);
        for t in 0..threads {
            let region = mem.alloc(PAGE_BYTES);
            let toucher = if numa_rtdata { binding.cores[t] } else { binding.master_core() };
            mem.first_touch(toucher, region, 0);
            let data_node = mem.node_of_addr(region.addr).expect("rt page resident");
            let worker_node = self.topo.node_of(binding.cores[t]);
            let hops = self.topo.node_hops(worker_node, data_node) as Time;
            rt_penalty.push(hops * self.cost.rtdata_per_hop);
        }

        // Master-side workload init: allocations + first touches.
        let init_time = workload.init(&mut mem, binding.master_core());

        let victims = build_victim_lists(&self.topo, &binding.cores);
        let root = workload.root();
        let engine = Engine::new(
            EngineConfig { policy, cores: binding.cores.clone(), rt_penalty, seed },
            mem,
            victims,
            workload,
            exec,
        );
        let mut stats = engine.run(root)?;
        stats.bench = workload.name().to_string();
        stats.seed = seed;
        stats.init_time = init_time;
        stats.wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
        Ok(stats)
    }

    /// The paper's speedup denominator: 1 thread, overhead-free depth-first
    /// execution, baseline binding.
    pub fn run_serial(&self, workload: &mut dyn Workload, seed: u64) -> Result<RunStats> {
        self.run(workload, Policy::Serial, BindPolicy::Linear, 1, seed, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{BodyCtx, TaskDesc};
    use crate::simnuma::Region;

    /// Tiny deterministic workload: a two-level tree touching one array.
    struct Tree {
        data: Region,
        fanout: i64,
    }

    impl Workload for Tree {
        fn name(&self) -> &'static str {
            "tree"
        }

        fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
            self.data = mem.alloc(64 * 1024);
            mem.first_touch(master_core, self.data, 0)
        }

        fn root(&self) -> TaskDesc {
            TaskDesc::new(0, [self.fanout, 0, 0, 0])
        }

        fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
            match desc.kind {
                0 => {
                    for i in 0..desc.args[0] {
                        ctx.spawn(TaskDesc::new(1, [i, 0, 0, 0]));
                    }
                    ctx.taskwait();
                    ctx.compute(100);
                }
                _ => {
                    let chunk = self.data.bytes / self.fanout as u64;
                    ctx.read(self.data.slice(desc.args[0] as u64 * chunk, chunk));
                    ctx.compute(2_000);
                }
            }
        }
    }

    fn run_one(policy: Policy, bind: BindPolicy, threads: usize) -> RunStats {
        let rt = Runtime::paper_testbed();
        let mut w = Tree { data: Region::EMPTY, fanout: 64 };
        rt.run(&mut w, policy, bind, threads, 42, None).unwrap()
    }

    #[test]
    fn all_tasks_complete_under_every_policy() {
        for &p in Policy::all() {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let s = run_one(p, BindPolicy::Linear, threads);
            assert_eq!(s.tasks, 65, "{}", p.name());
            assert!(s.makespan > 0);
        }
    }

    #[test]
    fn parallel_beats_serial() {
        let serial = run_one(Policy::Serial, BindPolicy::Linear, 1);
        let par = run_one(Policy::WorkFirst, BindPolicy::Linear, 8);
        assert!(
            par.makespan < serial.makespan,
            "8 threads {} vs serial {}",
            par.makespan,
            serial.makespan
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_one(Policy::Dfwsrpt, BindPolicy::NumaAware, 8);
        let b = run_one(Policy::Dfwsrpt, BindPolicy::NumaAware, 8);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.steals, b.steals);
    }

    #[test]
    fn work_stealing_actually_steals() {
        let s = run_one(Policy::WorkFirst, BindPolicy::Linear, 8);
        assert!(s.steals > 0, "fanout tree must trigger steals");
    }

    #[test]
    fn bf_uses_shared_queue_only() {
        let s = run_one(Policy::BreadthFirst, BindPolicy::Linear, 8);
        assert_eq!(s.steals, 0);
        assert!(s.shared_ops > 0);
    }

    #[test]
    fn numa_bind_records_policy() {
        let s = run_one(Policy::Dfwspt, BindPolicy::NumaAware, 4);
        assert_eq!(s.bind, Some(BindPolicy::NumaAware));
        assert_eq!(s.label(), "dfwspt-Scheduler-NUMA");
    }
}
