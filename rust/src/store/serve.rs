//! `numanos serve` — a filesystem-spool manifest service over the store.
//!
//! The long-running loop watches a spool directory for dropped
//! [`ExperimentManifest`] files (`*.json` / `*.toml`).  Each job executes
//! through one shared [`Session`] + [`ResultStore`], so overlapping
//! manifests from many clients cost one execution per distinct cell.  Per
//! job the service writes, next to where the job was dropped:
//!
//! * `<stem>.result.json` — `{title, sweeps: [...]}`, the same document
//!   `numanos sweep --json` prints (only on success, and only for jobs
//!   that produce full results — shard items don't), and
//! * `<stem>.receipt.json` — the machine-readable receipt: manifest name +
//!   FNV-128 content hash, wall time, store counter deltas
//!   (hits/misses/writes/quarantined) overall and per sweep, or the error
//!   string on failure,
//!
//! then moves the manifest itself to `<spool>/done/` or `<spool>/failed/`.
//! A re-submitted job whose name already finished gets a unique numeric
//! suffix (`job1` → `job1.2`), so earlier result/receipt pairs are never
//! overwritten.  A malformed or failing manifest produces a receipt and
//! keeps the loop alive — one bad client must not take the service down.
//!
//! ## Shard fanout
//!
//! Jobs may carry a shard directive (see [`shard::classify_job`]):
//!
//! * `"shards": N` — the job *expands*: the service writes N shard work
//!   items (`<stem>.shard-I-of-N.json`, the same manifest plus
//!   `"shard": "I/N"`) and one merge item (`<stem>.merge.json`, plus
//!   `"merge_of": N`) back into the spool, then retires the original with
//!   an expansion receipt.
//! * `"shard": "I/N"` — runs that shard's cells into the store and
//!   publishes its completion marker; receipt only, no result file.
//! * `"merge_of": N` — stays pending until all N sibling receipts
//!   (`<base>.shard-I-of-N.receipt.json`) exist; fails if any sibling
//!   failed; otherwise re-runs the full manifest (100% cache hits when
//!   the shards covered everything) and writes the merged result.
//!
//! Under `--once` the scan repeats until a pass makes no progress, so a
//! single invocation drives expand → shards → merge to completion — a
//! hostfile-free multi-process driver, testable end-to-end with plain
//! files.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::serde::Json;
use crate::spec::{ExperimentManifest, Session, ShardPlan};
use crate::store::shard::{self, JobKind};
use crate::store::{hash, ResultStore, STORE_SCHEMA};

/// Knobs for [`serve`].
pub struct ServeOptions {
    /// Sleep between spool scans, in milliseconds.
    pub poll_ms: u64,
    /// Process until the spool reaches a fixpoint, then return (for
    /// tests and CI) — fanout jobs still drive their shards and merge.
    pub once: bool,
    /// Sweep worker threads per job.
    pub workers: usize,
}

/// Run the spool service.  Returns only on `opts.once` (or an error
/// opening the store / creating the spool — never a per-job failure).
pub fn serve(store_dir: &Path, spool: &Path, opts: &ServeOptions) -> Result<()> {
    let store = Arc::new(ResultStore::open(store_dir)?);
    let mut session = Session::new();
    session.set_store(store.clone(), true);
    std::fs::create_dir_all(spool)
        .with_context(|| format!("creating spool directory '{}'", spool.display()))?;
    eprintln!(
        "[serve: store '{}', spool '{}', {} worker(s){}]",
        store_dir.display(),
        spool.display(),
        opts.workers,
        if opts.once { ", one pass" } else { "" }
    );
    loop {
        let mut progressed = false;
        for job in scan_jobs(spool)? {
            if matches!(
                process_job(&session, &store, spool, &job, opts.workers),
                Processed::Finished
            ) {
                progressed = true;
            }
        }
        if opts.once {
            // fixpoint: a fanout pass drops shard items and a gated
            // merge item back into the spool — keep scanning while
            // passes finish jobs.  A merge whose siblings never arrive
            // stays pending rather than spinning.
            if !progressed {
                return Ok(());
            }
            continue;
        }
        std::thread::sleep(std::time::Duration::from_millis(opts.poll_ms.max(1)));
    }
}

/// Pending job files, sorted by name for deterministic processing order.
/// Our own outputs (`*.result.json`, `*.receipt.json`), dotfiles and the
/// `done/`/`failed/` subdirectories are not jobs.
fn scan_jobs(spool: &Path) -> Result<Vec<PathBuf>> {
    let mut jobs = Vec::new();
    for entry in std::fs::read_dir(spool)
        .with_context(|| format!("scanning spool '{}'", spool.display()))?
    {
        let path = entry?.path();
        if !path.is_file() {
            continue;
        }
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if name.starts_with('.')
            || name.ends_with(".result.json")
            || name.ends_with(".receipt.json")
        {
            continue;
        }
        if name.ends_with(".json") || name.ends_with(".toml") {
            jobs.push(path);
        }
    }
    jobs.sort();
    Ok(jobs)
}

/// What one scan pass did with a job.
enum Processed {
    /// Executed (ok or failed): receipt written, job left the scan set.
    Finished,
    /// A merge item whose sibling shard receipts are not all present
    /// yet — left in place for a later pass.
    Deferred,
}

/// Everything the receipt reports about a successful job.
struct JobOutcome {
    /// `manifest` | `expand` | `shard` | `merge` — what the job was.
    kind: &'static str,
    title: String,
    cells: u64,
    /// `{id, cells, hits, misses, writes}` per sweep (shard items report
    /// `{id, owned, skipped}` instead).
    sweeps: Vec<Json>,
    /// `result.to_json()` per sweep — the result-file payload.  Empty for
    /// jobs with no full results (expansions, shard items): no file.
    results: Vec<Json>,
    /// Kind-specific receipt fields.
    extra: Vec<(String, Json)>,
}

/// How a merge item's gate on its sibling shard receipts resolved.
enum MergeGate {
    /// Some sibling receipt is absent — the shard is queued or running.
    Waiting,
    /// All siblings reported ok.
    Ready,
    /// At least one sibling failed (named) — the merge must fail too.
    SiblingFailed(Vec<String>),
}

/// Execute one job and write its receipt (+ result when the job produces
/// one); never propagates the job's own failure.
fn process_job(
    session: &Session,
    store: &ResultStore,
    spool: &Path,
    job: &Path,
    workers: usize,
) -> Processed {
    let name = job.file_name().and_then(|n| n.to_str()).unwrap_or("job").to_string();
    let t0 = std::time::Instant::now();
    let before = store.counters();
    let parsed = parse_job(job);

    // merge items gate on their sibling shard receipts (derived from the
    // *original* job name, so a suffixed re-submission still finds them)
    let mut gate_failure = None;
    if let Ok((JobKind::Merge(count), _)) = &parsed {
        match merge_gate(spool, &name, *count) {
            MergeGate::Waiting => return Processed::Deferred,
            MergeGate::Ready => {}
            MergeGate::SiblingFailed(failed) => {
                gate_failure = Some(anyhow::anyhow!(
                    "sibling shard receipt(s) report errors: {}",
                    failed.join(", ")
                ));
            }
        }
    }

    let (stem, final_name) = unique_stem(spool, &name);
    let outcome: Result<JobOutcome> = match (gate_failure, parsed) {
        (Some(e), _) => Err(e),
        (None, Err(e)) => Err(e),
        (None, Ok((kind, doc))) => {
            execute_job(session, store, spool, &stem, &kind, &doc, workers)
        }
    };
    let after = store.counters();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut receipt: Vec<(String, Json)> = vec![
        ("schema".to_string(), Json::from(STORE_SCHEMA)),
        ("manifest".to_string(), Json::from(name.as_str())),
        (
            "manifest_fnv".to_string(),
            std::fs::read(job)
                .map(|bytes| Json::from(hash::fnv1a_128_hex(&bytes)))
                .unwrap_or(Json::Null),
        ),
        ("status".to_string(), Json::from(if outcome.is_ok() { "ok" } else { "error" })),
        ("wall_ms".to_string(), Json::from(wall_ms)),
        ("cache_hits".to_string(), Json::from(after.hits - before.hits)),
        ("cache_misses".to_string(), Json::from(after.misses - before.misses)),
        ("cache_writes".to_string(), Json::from(after.writes - before.writes)),
        (
            "cache_quarantined".to_string(),
            Json::from(after.quarantined - before.quarantined),
        ),
    ];
    match &outcome {
        Ok(out) => {
            receipt.push(("kind".to_string(), Json::from(out.kind)));
            receipt.push(("title".to_string(), Json::from(out.title.as_str())));
            receipt.push(("cells".to_string(), Json::from(out.cells)));
            receipt.push(("sweeps".to_string(), Json::Arr(out.sweeps.clone())));
            receipt.extend(out.extra.iter().cloned());
            if !out.results.is_empty() {
                let result_doc = Json::obj([
                    ("title", Json::from(out.title.as_str())),
                    ("sweeps", Json::Arr(out.results.clone())),
                ]);
                report(spool, &stem, "result", &result_doc);
            }
        }
        Err(e) => {
            receipt.push(("error".to_string(), Json::from(format!("{e:#}"))));
        }
    }
    report(spool, &stem, "receipt", &Json::obj(receipt));
    finish(spool, job, &final_name, outcome.is_ok());
    match &outcome {
        Ok(out) => eprintln!(
            "[serve '{name}' ({}): {} cell(s), {} hit / {} miss / {} written, {:.1}s]",
            out.kind,
            out.cells,
            after.hits - before.hits,
            after.misses - before.misses,
            after.writes - before.writes,
            wall_ms / 1e3
        ),
        Err(e) => eprintln!("[serve '{name}': FAILED: {e:#}]"),
    }
    Processed::Finished
}

/// Read + parse a job file (TOML by extension, else JSON) and split off
/// its shard directive.
fn parse_job(job: &Path) -> Result<(JobKind, Json)> {
    let text = std::fs::read_to_string(job)
        .with_context(|| format!("reading job '{}'", job.display()))?;
    let doc = if job.extension().and_then(|e| e.to_str()) == Some("toml") {
        crate::serde::toml::parse(&text)
            .with_context(|| format!("parsing TOML {}", job.display()))?
    } else {
        Json::parse(&text).with_context(|| format!("parsing JSON {}", job.display()))?
    };
    shard::classify_job(&doc)
}

/// A merge item `<base>.merge.json` waits for its sibling shard receipts
/// `<base>.shard-I-of-N.receipt.json`, `I` in `0..N` (the names the
/// expansion that wrote the merge item also wrote).  All present and ok →
/// ready; any reporting an error → the merge fails, naming them; any
/// absent → keep waiting.
fn merge_gate(spool: &Path, name: &str, count: usize) -> MergeGate {
    let stem = name.rsplit_once('.').map(|(s, _)| s).unwrap_or(name);
    let base = stem.strip_suffix(".merge").unwrap_or(stem);
    let mut failed = Vec::new();
    for i in 0..count {
        let receipt = spool.join(format!("{base}.shard-{i}-of-{count}.receipt.json"));
        let Ok(text) = std::fs::read_to_string(&receipt) else {
            return MergeGate::Waiting;
        };
        let ok = Json::parse(&text)
            .ok()
            .and_then(|j| j.get("status").and_then(Json::as_str).map(|s| s == "ok"))
            .unwrap_or(false);
        if !ok {
            failed.push(format!("{base}.shard-{i}-of-{count}"));
        }
    }
    if failed.is_empty() {
        MergeGate::Ready
    } else {
        MergeGate::SiblingFailed(failed)
    }
}

/// A (stem, file name) pair that will not clobber a finished job: if the
/// name already sits in `done/`/`failed/` or left a receipt, suffix the
/// stem with the first free `.<k>` (k ≥ 2) — `job1.toml` → `job1.2.toml`.
fn unique_stem(spool: &Path, name: &str) -> (String, String) {
    let (stem, ext) = name.rsplit_once('.').unwrap_or((name, "json"));
    let taken = |stem: &str, name: &str| {
        spool.join("done").join(name).exists()
            || spool.join("failed").join(name).exists()
            || spool.join(format!("{stem}.receipt.json")).exists()
    };
    if !taken(stem, name) {
        return (stem.to_string(), name.to_string());
    }
    let mut k = 2u64;
    loop {
        let stem_k = format!("{stem}.{k}");
        let name_k = format!("{stem_k}.{ext}");
        if !taken(&stem_k, &name_k) {
            return (stem_k, name_k);
        }
        k += 1;
    }
}

/// Run a job's manifest work according to its [`JobKind`].
fn execute_job(
    session: &Session,
    store: &ResultStore,
    spool: &Path,
    stem: &str,
    kind: &JobKind,
    doc: &Json,
    workers: usize,
) -> Result<JobOutcome> {
    let manifest = ExperimentManifest::from_json(doc)?;
    match kind {
        JobKind::Plain => run_full(session, store, &manifest, workers, "manifest", Vec::new()),
        JobKind::Fanout(n) => expand_fanout(spool, stem, &manifest, doc, *n),
        JobKind::Shard(plan) => run_shard(session, store, &manifest, *plan, workers),
        JobKind::Merge(n) => {
            let fnv = shard::manifest_fingerprint(&manifest)?;
            let status = shard::shard_status(store, &fnv);
            let extra = vec![
                ("merge_of".to_string(), Json::from(*n)),
                ("cells_fnv".to_string(), Json::from(fnv.as_str())),
                (
                    "shards_present".to_string(),
                    Json::from(status.present.len()),
                ),
                (
                    "shards_missing".to_string(),
                    Json::Arr(status.missing.iter().map(|&i| Json::from(i)).collect()),
                ),
                (
                    "shards_stale".to_string(),
                    Json::Arr(
                        status.stale.iter().map(|s| Json::from(s.as_str())).collect(),
                    ),
                ),
            ];
            run_full(session, store, &manifest, workers, "merge", extra)
        }
    }
}

/// Execute every sweep of `manifest` and collect full results (the plain
/// job path, and the merge path — a merge is just a full run that the
/// shards' write-through turned into cache hits).
fn run_full(
    session: &Session,
    store: &ResultStore,
    manifest: &ExperimentManifest,
    workers: usize,
    kind: &'static str,
    extra: Vec<(String, Json)>,
) -> Result<JobOutcome> {
    let mut out = JobOutcome {
        kind,
        title: manifest.title.clone(),
        cells: 0,
        sweeps: Vec::new(),
        results: Vec::new(),
        extra,
    };
    for sweep in &manifest.sweeps {
        let before = store.counters();
        let result = session.run_sweep_with(sweep, workers)?;
        let after = store.counters();
        out.cells += result.records.len() as u64;
        out.sweeps.push(Json::obj([
            ("id", Json::from(sweep.id.as_str())),
            ("cells", Json::from(result.records.len())),
            ("hits", Json::from(after.hits - before.hits)),
            ("misses", Json::from(after.misses - before.misses)),
            ("writes", Json::from(after.writes - before.writes)),
        ]));
        out.results.push(result.to_json());
    }
    Ok(out)
}

/// Expand a `"shards": N` job into N shard items plus a gated merge item
/// (written with temp + rename so a concurrent scan never reads a torn
/// job), all derived from this job's unique stem.
fn expand_fanout(
    spool: &Path,
    stem: &str,
    manifest: &ExperimentManifest,
    doc: &Json,
    n: usize,
) -> Result<JobOutcome> {
    let total = manifest.all_cells()?.len();
    let mut items = Vec::with_capacity(n + 1);
    for i in 0..n {
        let plan = ShardPlan::new(i, n)?;
        items.push(write_item(spool, &format!("{stem}.shard-{}.json", plan.name()), doc, |o| {
            o.insert("shard".to_string(), Json::from(plan.spec()));
        })?);
    }
    items.push(write_item(spool, &format!("{stem}.merge.json"), doc, |o| {
        o.insert("merge_of".to_string(), Json::from(n));
    })?);
    Ok(JobOutcome {
        kind: "expand",
        title: manifest.title.clone(),
        cells: total as u64,
        sweeps: Vec::new(),
        results: Vec::new(),
        extra: vec![
            ("shards".to_string(), Json::from(n)),
            (
                "items".to_string(),
                Json::Arr(items.iter().map(|i| Json::from(i.as_str())).collect()),
            ),
        ],
    })
}

/// Write one derived spool item: the stripped manifest document plus one
/// directive key.  Returns the item's file name.
fn write_item(
    spool: &Path,
    name: &str,
    doc: &Json,
    directive: impl FnOnce(&mut std::collections::BTreeMap<String, Json>),
) -> Result<String> {
    let mut obj = doc.as_obj().context("job must be an object")?.clone();
    directive(&mut obj);
    let tmp = spool.join(format!(".{name}.tmp.{}", std::process::id()));
    let path = spool.join(name);
    std::fs::write(&tmp, Json::Obj(obj).to_pretty())
        .with_context(|| format!("writing spool item '{}'", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publishing spool item '{}'", path.display()))?;
    Ok(name.to_string())
}

/// Run one shard of the manifest into the store (receipt only — partial
/// results never masquerade as a full result file).
fn run_shard(
    session: &Session,
    store: &ResultStore,
    manifest: &ExperimentManifest,
    plan: ShardPlan,
    workers: usize,
) -> Result<JobOutcome> {
    let summary = shard::run_manifest_shard(session, store, manifest, plan, workers)?;
    Ok(JobOutcome {
        kind: "shard",
        title: manifest.title.clone(),
        cells: summary.owned_cells as u64,
        sweeps: summary
            .sweeps
            .iter()
            .map(|s| {
                Json::obj([
                    ("id", Json::from(s.id.as_str())),
                    ("owned", Json::from(s.owned)),
                    ("skipped", Json::from(s.skipped)),
                ])
            })
            .collect(),
        results: Vec::new(),
        extra: vec![
            ("shard".to_string(), Json::from(summary.plan.spec())),
            ("cells_total".to_string(), Json::from(summary.total_cells)),
            ("cells_fnv".to_string(), Json::from(summary.manifest_fnv.as_str())),
        ],
    })
}

/// Write `<spool>/<stem>.<kind>.json` (best-effort: a full disk must not
/// kill the loop, and the job still moves to `done/`/`failed/`).
fn report(spool: &Path, stem: &str, kind: &str, doc: &Json) {
    let path = spool.join(format!("{stem}.{kind}.json"));
    if let Err(e) = std::fs::write(&path, doc.to_pretty()) {
        eprintln!("[serve: could not write '{}': {e}]", path.display());
    }
}

/// Move a finished job out of the scan set (under its unique name — see
/// [`unique_stem`]).  If the move fails the job is deleted — leaving it
/// behind would re-execute it every poll.
fn finish(spool: &Path, job: &Path, name: &str, ok: bool) {
    let dir = spool.join(if ok { "done" } else { "failed" });
    let moved =
        std::fs::create_dir_all(&dir).is_ok() && std::fs::rename(job, dir.join(name)).is_ok();
    if !moved {
        let _ = std::fs::remove_file(job);
    }
}
