//! `numanos serve` — a filesystem-spool manifest service over the store.
//!
//! The long-running loop watches a spool directory for dropped
//! [`ExperimentManifest`] files (`*.json` / `*.toml`).  Each job executes
//! through one shared [`Session`] + [`ResultStore`], so overlapping
//! manifests from many clients cost one execution per distinct cell.  Per
//! job the service writes, next to where the job was dropped:
//!
//! * `<stem>.result.json` — `{title, sweeps: [...]}`, the same document
//!   `numanos sweep --json` prints (only on success), and
//! * `<stem>.receipt.json` — the machine-readable receipt: manifest name +
//!   FNV-128 content hash, wall time, store counter deltas
//!   (hits/misses/writes/quarantined) overall and per sweep, or the error
//!   string on failure,
//!
//! then moves the manifest itself to `<spool>/done/` or `<spool>/failed/`.
//! A malformed or failing manifest produces a receipt and keeps the loop
//! alive — one bad client must not take the service down.  Everything is
//! plain files, so the whole request/receipt protocol is testable
//! end-to-end without network dependencies.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::serde::Json;
use crate::spec::{ExperimentManifest, Session};
use crate::store::{hash, ResultStore, STORE_SCHEMA};

/// Knobs for [`serve`].
pub struct ServeOptions {
    /// Sleep between spool scans, in milliseconds.
    pub poll_ms: u64,
    /// Process the jobs present now, then return (for tests and CI).
    pub once: bool,
    /// Sweep worker threads per job.
    pub workers: usize,
}

/// Run the spool service.  Returns only on `opts.once` (or an error
/// opening the store / creating the spool — never a per-job failure).
pub fn serve(store_dir: &Path, spool: &Path, opts: &ServeOptions) -> Result<()> {
    let store = Arc::new(ResultStore::open(store_dir)?);
    let mut session = Session::new();
    session.set_store(store.clone(), true);
    std::fs::create_dir_all(spool)
        .with_context(|| format!("creating spool directory '{}'", spool.display()))?;
    eprintln!(
        "[serve: store '{}', spool '{}', {} worker(s){}]",
        store_dir.display(),
        spool.display(),
        opts.workers,
        if opts.once { ", one pass" } else { "" }
    );
    loop {
        for job in scan_jobs(spool)? {
            process_job(&session, &store, spool, &job, opts.workers);
        }
        if opts.once {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(opts.poll_ms.max(1)));
    }
}

/// Pending job files, sorted by name for deterministic processing order.
/// Our own outputs (`*.result.json`, `*.receipt.json`), dotfiles and the
/// `done/`/`failed/` subdirectories are not jobs.
fn scan_jobs(spool: &Path) -> Result<Vec<PathBuf>> {
    let mut jobs = Vec::new();
    for entry in std::fs::read_dir(spool)
        .with_context(|| format!("scanning spool '{}'", spool.display()))?
    {
        let path = entry?.path();
        if !path.is_file() {
            continue;
        }
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if name.starts_with('.')
            || name.ends_with(".result.json")
            || name.ends_with(".receipt.json")
        {
            continue;
        }
        if name.ends_with(".json") || name.ends_with(".toml") {
            jobs.push(path);
        }
    }
    jobs.sort();
    Ok(jobs)
}

/// Everything the receipt reports about a successful job.
struct JobOutcome {
    title: String,
    cells: u64,
    /// `{id, cells, hits, misses, writes}` per sweep.
    sweeps: Vec<Json>,
    /// `result.to_json()` per sweep — the result-file payload.
    results: Vec<Json>,
}

/// Execute one job and write its receipt (+ result on success); never
/// propagates the job's own failure.
fn process_job(session: &Session, store: &ResultStore, spool: &Path, job: &Path, workers: usize) {
    let name = job.file_name().and_then(|n| n.to_str()).unwrap_or("job").to_string();
    let stem = name.rsplit_once('.').map(|(s, _)| s).unwrap_or(&name).to_string();
    let t0 = std::time::Instant::now();
    let before = store.counters();
    let outcome = execute_job(session, store, job, workers);
    let after = store.counters();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut receipt: Vec<(String, Json)> = vec![
        ("schema".to_string(), Json::from(STORE_SCHEMA)),
        ("manifest".to_string(), Json::from(name.as_str())),
        (
            "manifest_fnv".to_string(),
            std::fs::read(job)
                .map(|bytes| Json::from(hash::fnv1a_128_hex(&bytes)))
                .unwrap_or(Json::Null),
        ),
        ("status".to_string(), Json::from(if outcome.is_ok() { "ok" } else { "error" })),
        ("wall_ms".to_string(), Json::from(wall_ms)),
        ("cache_hits".to_string(), Json::from(after.hits - before.hits)),
        ("cache_misses".to_string(), Json::from(after.misses - before.misses)),
        ("cache_writes".to_string(), Json::from(after.writes - before.writes)),
        (
            "cache_quarantined".to_string(),
            Json::from(after.quarantined - before.quarantined),
        ),
    ];
    match &outcome {
        Ok(out) => {
            receipt.push(("title".to_string(), Json::from(out.title.as_str())));
            receipt.push(("cells".to_string(), Json::from(out.cells)));
            receipt.push(("sweeps".to_string(), Json::Arr(out.sweeps.clone())));
            let result_doc = Json::obj([
                ("title", Json::from(out.title.as_str())),
                ("sweeps", Json::Arr(out.results.clone())),
            ]);
            report(spool, &stem, "result", &result_doc);
        }
        Err(e) => {
            receipt.push(("error".to_string(), Json::from(format!("{e:#}"))));
        }
    }
    report(spool, &stem, "receipt", &Json::obj(receipt));
    finish(spool, job, &name, outcome.is_ok());
    match &outcome {
        Ok(out) => eprintln!(
            "[serve '{name}': {} cell(s), {} hit / {} miss / {} written, {:.1}s]",
            out.cells,
            after.hits - before.hits,
            after.misses - before.misses,
            after.writes - before.writes,
            wall_ms / 1e3
        ),
        Err(e) => eprintln!("[serve '{name}': FAILED: {e:#}]"),
    }
}

fn execute_job(
    session: &Session,
    store: &ResultStore,
    job: &Path,
    workers: usize,
) -> Result<JobOutcome> {
    let manifest = ExperimentManifest::load(job)?;
    let mut out = JobOutcome {
        title: manifest.title.clone(),
        cells: 0,
        sweeps: Vec::new(),
        results: Vec::new(),
    };
    for sweep in &manifest.sweeps {
        let before = store.counters();
        let result = session.run_sweep_with(sweep, workers)?;
        let after = store.counters();
        out.cells += result.records.len() as u64;
        out.sweeps.push(Json::obj([
            ("id", Json::from(sweep.id.as_str())),
            ("cells", Json::from(result.records.len())),
            ("hits", Json::from(after.hits - before.hits)),
            ("misses", Json::from(after.misses - before.misses)),
            ("writes", Json::from(after.writes - before.writes)),
        ]));
        out.results.push(result.to_json());
    }
    Ok(out)
}

/// Write `<spool>/<stem>.<kind>.json` (best-effort: a full disk must not
/// kill the loop, and the job still moves to `done/`/`failed/`).
fn report(spool: &Path, stem: &str, kind: &str, doc: &Json) {
    let path = spool.join(format!("{stem}.{kind}.json"));
    if let Err(e) = std::fs::write(&path, doc.to_pretty()) {
        eprintln!("[serve: could not write '{}': {e}]", path.display());
    }
}

/// Move a finished job out of the scan set.  If the move fails the job
/// is deleted — leaving it behind would re-execute it every poll.
fn finish(spool: &Path, job: &Path, name: &str, ok: bool) {
    let dir = spool.join(if ok { "done" } else { "failed" });
    let moved =
        std::fs::create_dir_all(&dir).is_ok() && std::fs::rename(job, dir.join(name)).is_ok();
    if !moved {
        let _ = std::fs::remove_file(job);
    }
}
