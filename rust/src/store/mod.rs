//! Content-addressed on-disk result store — the persistent cell cache.
//!
//! Every simulated cell is a pure function of its [`RunSpec`] identity:
//! bench, size, seed, topology, page-policy signature, the *resolved*
//! [`Scheduler::signature`](crate::coordinator::sched::Scheduler::signature)
//! (two spellings of the same configuration share one cell), thread
//! count, bind policy, cost-model signature — plus [`STORE_SCHEMA`], so a
//! format change can never serve stale bytes.  The canonical identity
//! string is hashed with a self-contained 128-bit FNV-1a ([`hash`]) and
//! the record lands at `store/ab/cdef….json` (first two hex digits shard
//! the directory), serialized through [`crate::serde`].
//!
//! Layout:
//!
//! ```text
//! <root>/index.json          schema header (hard error on mismatch)
//! <root>/ab/cdef….json       one record per cell / baseline
//! <root>/shards/I-of-N.json  per-shard completion markers ([`ShardMarker`])
//! <root>/quarantine/         corrupt records, moved aside on read
//! ```
//!
//! Robustness contract: an unreadable, truncated, or mismatched record is
//! a cache *miss* — the file is moved to `quarantine/`, the
//! [`StoreCounters::quarantined`] counter ticks, the cell re-executes and
//! write-through refreshes the record.  Records embed their full identity
//! string, so even an FNV collision or a stale key degrades to a detected
//! miss, never a wrong result.  Writers go through a temp-file + rename,
//! and any two writers of the same key produce identical bytes
//! (simulations are deterministic), so concurrent sweeps — threads or
//! whole processes — can share one store without coordination.
//!
//! [`Session`](crate::spec::Session) integrates read-through /
//! write-through via [`Session::set_store`](crate::spec::Session::set_store);
//! `numanos sweep --store/--resume/--no-cache` and `numanos serve`
//! ([`serve`]) sit on top.  The store is also the merge substrate for
//! sharded multi-process sweeps ([`shard`], `numanos sweep --shard I/N` +
//! `numanos merge`): shards write cells through, publish [`ShardMarker`]s,
//! and the merge pass re-reads everything as cache hits.

pub mod hash;
pub mod serve;
pub mod shard;

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::config::ComputeMode;
use crate::coordinator::sched;
use crate::metrics::RunStats;
use crate::serde::Json;
use crate::spec::{RunRecord, RunSpec};

/// Store format version.  Embedded in every record identity (and checked
/// against the index header at open), so a change to the record format or
/// the identity definition invalidates old stores loudly instead of
/// matching stale keys.
pub const STORE_SCHEMA: u64 = 1;

/// Canonical serial-baseline identity — the six components a baseline
/// actually depends on.  [`Session::baseline`](crate::spec::Session::baseline)
/// keys its in-memory memo with this exact helper, so the memo and the
/// store can never drift apart.
pub fn baseline_identity(spec: &RunSpec) -> String {
    format!(
        "{}|{}|{}|{}|{}|{}",
        spec.bench,
        spec.size.name(),
        spec.seed,
        spec.topo,
        spec.mem.name_sig(),
        spec.cost_sig()
    )
}

/// Canonical full cell identity.  Uses the *resolved* scheduler signature
/// (defaults filled in), not the spec spelling: `numa-steal` and
/// `numa-steal:batch=1,min_kb=16` are the same simulation and share one
/// record.  Fails only if the scheduler spec doesn't resolve (which
/// validation would reject anyway).
pub fn cell_identity(spec: &RunSpec) -> Result<String> {
    let resolved = sched::build(&spec.sched)?.signature();
    Ok(format!(
        "s{STORE_SCHEMA}|cell|{}|{}|{}|{}|{}|{}|{}|{}|{}|rtdata={}",
        spec.bench,
        spec.size.name(),
        spec.seed,
        spec.topo,
        spec.mem.name_sig(),
        resolved,
        spec.threads,
        spec.bind.name(),
        spec.cost_sig(),
        spec.rtdata_local as u8,
    ))
}

fn baseline_record_identity(spec: &RunSpec) -> String {
    format!("s{STORE_SCHEMA}|baseline|{}", baseline_identity(spec))
}

/// Canonical fingerprint of a flattened cell sequence: FNV-128 over the
/// newline-joined [`cell_identity`] strings in manifest order.  Two
/// spellings of one manifest (JSON vs TOML, defaulted vs explicit
/// scheduler parameters) produce one fingerprint; any change to an axis,
/// the cell order, or [`STORE_SCHEMA`] produces another.  Shard markers
/// embed it so `numanos merge` can tell a stale shard from a fresh one.
pub fn cells_fingerprint(cells: &[RunSpec]) -> Result<String> {
    let mut buf = String::new();
    for spec in cells {
        buf.push_str(&cell_identity(spec)?);
        buf.push('\n');
    }
    Ok(hash::fnv1a_128_hex(buf.as_bytes()))
}

/// Per-shard completion marker: which cells shard `index` of `count`
/// finished for the manifest fingerprinted by `manifest_fnv`.  Lives at
/// `<root>/shards/I-of-N.json`; `numanos merge` reads the set of markers
/// to report missing or stale shards instead of silently re-executing
/// their cells.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMarker {
    pub index: usize,
    pub count: usize,
    /// [`cells_fingerprint`] of the *full* manifest the shard ran.
    pub manifest_fnv: String,
    /// Cell count of the full manifest (all shards together).
    pub total_cells: u64,
    /// Canonical [`cell_identity`] of every cell this shard completed,
    /// in global cell order.
    pub cell_ids: Vec<String>,
}

impl ShardMarker {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("schema", Json::from(STORE_SCHEMA)),
            ("kind", Json::from("shard")),
            ("index", Json::from(self.index)),
            ("count", Json::from(self.count)),
            ("manifest_fnv", Json::from(self.manifest_fnv.as_str())),
            ("total_cells", Json::from_u64_lossless(self.total_cells)),
            (
                "cells",
                Json::Arr(self.cell_ids.iter().map(|id| Json::from(id.as_str())).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        if j.get("schema").and_then(Json::as_u64) != Some(STORE_SCHEMA) {
            bail!("shard marker schema mismatch");
        }
        if j.get("kind").and_then(Json::as_str) != Some("shard") {
            bail!("shard marker kind mismatch");
        }
        let index = j.get("index").and_then(Json::as_usize).context("marker field 'index'")?;
        let count = j.get("count").and_then(Json::as_usize).context("marker field 'count'")?;
        if count == 0 || index >= count {
            bail!("shard marker {index}/{count} out of range");
        }
        let manifest_fnv = j
            .get("manifest_fnv")
            .and_then(Json::as_str)
            .context("marker field 'manifest_fnv'")?
            .to_string();
        let total_cells = j
            .get("total_cells")
            .and_then(Json::as_u64_lossless)
            .context("marker field 'total_cells'")?;
        let cells = j.get("cells").and_then(Json::as_arr).context("marker field 'cells'")?;
        let cell_ids = cells
            .iter()
            .map(|c| c.as_str().map(str::to_string).context("marker cell ids must be strings"))
            .collect::<Result<_>>()?;
        Ok(Self { index, count, manifest_fnv, total_cells, cell_ids })
    }
}

/// Whether a spec's result may be cached at all: only deterministic
/// simulations are; PJRT-backed runs bypass the store entirely.
pub fn cacheable(spec: &RunSpec) -> bool {
    matches!(spec.compute, ComputeMode::Sim)
}

/// Snapshot of a store's cell-level counters.  Baseline records are
/// read/written uncounted so `hits + misses` always equals the number of
/// cells consulted (the "second pass is 100% hits" acceptance check).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    pub hits: u64,
    pub misses: u64,
    pub writes: u64,
    /// Corrupt records moved to `quarantine/` (counted for baselines too
    /// — corruption is corruption).
    pub quarantined: u64,
}

/// Handle on one store directory.  Cheap to share behind an `Arc`; all
/// state beyond the root path is atomic counters.
pub struct ResultStore {
    root: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    writes: AtomicU64,
    quarantined: AtomicU64,
    tmp_seq: AtomicU64,
}

impl ResultStore {
    /// Open (creating if needed) a store directory.  An existing index
    /// with a different schema is a hard error — the invalidation rule is
    /// "new schema, new directory" — and a corrupt index is too: unlike a
    /// single bad record, it means the store as a whole can't be trusted.
    pub fn open(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root)
            .with_context(|| format!("creating store directory '{}'", root.display()))?;
        let index = root.join("index.json");
        match fs::read_to_string(&index) {
            Ok(text) => {
                let j = Json::parse(&text).with_context(|| {
                    format!(
                        "store index '{}' is corrupt; move the directory aside or start a \
                         fresh --store",
                        index.display()
                    )
                })?;
                let schema = j.get("schema").and_then(Json::as_u64);
                if schema != Some(STORE_SCHEMA) {
                    bail!(
                        "store '{}' has schema {}, this build reads/writes schema \
                         {STORE_SCHEMA}; use a fresh --store directory",
                        root.display(),
                        schema.map(|s| s.to_string()).unwrap_or_else(|| "?".into()),
                    );
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                let doc = Json::obj([
                    ("schema", Json::from(STORE_SCHEMA)),
                    ("store", Json::from("numanos-result-store")),
                    ("hash", Json::from("fnv1a-128")),
                ]);
                // temp + rename, like records: two processes opening a
                // fresh store concurrently race to identical bytes
                let tmp = root.join(format!(".index.tmp.{}", std::process::id()));
                fs::write(&tmp, doc.to_pretty())
                    .with_context(|| format!("writing store index '{}'", index.display()))?;
                fs::rename(&tmp, &index)
                    .with_context(|| format!("publishing store index '{}'", index.display()))?;
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading store index '{}'", index.display()));
            }
        }
        Ok(Self {
            root,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            tmp_seq: AtomicU64::new(0),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn counters(&self) -> StoreCounters {
        StoreCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
        }
    }

    /// Fast existence probe (no counters, no validation).  Sweeps use it
    /// to skip baseline pre-computation for cells the store will answer;
    /// a record that later fails validation falls back to executing, with
    /// its baseline computed lazily.
    pub fn contains_cell(&self, spec: &RunSpec) -> bool {
        cell_identity(spec).map(|id| self.record_path(&id).exists()).unwrap_or(false)
    }

    /// Read-through lookup.  `None` is a miss (counted); corrupt records
    /// are quarantined on the way.  A hit reconstructs the [`RunRecord`]
    /// against *this* spec — label normalization and speedup arithmetic
    /// match an uncached run exactly.
    pub fn load_cell(&self, spec: &RunSpec) -> Option<RunRecord> {
        let identity = cell_identity(spec).ok()?;
        let path = self.record_path(&identity);
        if !path.exists() {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        match Self::read_cell(&path, &identity, spec) {
            Ok(rec) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(rec)
            }
            Err(_) => {
                self.quarantine(&path);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Write-through: persist an executed cell (atomic temp + rename).
    pub fn store_cell(&self, record: &RunRecord) -> Result<()> {
        let identity = cell_identity(&record.spec)?;
        let doc = Self::record_doc(
            &identity,
            "cell",
            [
                ("spec".to_string(), record.spec.to_json()),
                (
                    "serial_makespan".to_string(),
                    Json::from_u64_lossless(record.serial_makespan),
                ),
                ("stats".to_string(), record.stats.to_json()),
            ],
        );
        self.write_record(&identity, &doc)?;
        self.writes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Baseline lookup (uncounted — baselines are shared denominators,
    /// not cells; see [`StoreCounters`]).
    pub fn load_baseline(&self, spec: &RunSpec) -> Option<RunStats> {
        let identity = baseline_record_identity(spec);
        let path = self.record_path(&identity);
        if !path.exists() {
            return None;
        }
        match Self::read_baseline(&path, &identity) {
            Ok(stats) => Some(stats),
            Err(_) => {
                self.quarantine(&path);
                None
            }
        }
    }

    /// Persist a serial baseline (uncounted, same record machinery).
    pub fn store_baseline(&self, spec: &RunSpec, stats: &RunStats) -> Result<()> {
        let identity = baseline_record_identity(spec);
        let doc =
            Self::record_doc(&identity, "baseline", [("stats".to_string(), stats.to_json())]);
        self.write_record(&identity, &doc)
    }

    /// Publish a shard completion marker (atomic temp + rename; shard
    /// `I` is the only writer of `shards/I-of-N.json`, and re-runs of an
    /// identical shard produce identical bytes).
    pub fn write_shard_marker(&self, marker: &ShardMarker) -> Result<()> {
        let dir = self.root.join("shards");
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating shard-marker directory '{}'", dir.display()))?;
        let path = dir.join(format!("{}-of-{}.json", marker.index, marker.count));
        let tmp = dir.join(format!(
            ".tmp.{}.{}",
            std::process::id(),
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, marker.to_json().to_pretty())
            .with_context(|| format!("writing shard marker '{}'", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing shard marker '{}'", path.display()))?;
        Ok(())
    }

    /// Load one shard marker.  `None` if absent; a corrupt or mismatched
    /// marker is quarantined (a merge then reports that shard missing).
    pub fn load_shard_marker(&self, index: usize, count: usize) -> Option<ShardMarker> {
        let path = self.root.join("shards").join(format!("{index}-of-{count}.json"));
        if !path.exists() {
            return None;
        }
        let parsed = fs::read_to_string(&path)
            .map_err(anyhow::Error::from)
            .and_then(|text| Json::parse(&text))
            .and_then(|j| ShardMarker::from_json(&j));
        match parsed {
            Ok(m) if m.index == index && m.count == count => Some(m),
            _ => {
                self.quarantine(&path);
                None
            }
        }
    }

    /// Every parseable marker under `shards/`, sorted by (count, index).
    /// Corrupt files are quarantined on the way, like records.
    pub fn shard_markers(&self) -> Vec<ShardMarker> {
        let dir = self.root.join("shards");
        let Ok(entries) = fs::read_dir(&dir) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(stem) = name.strip_suffix(".json") else { continue };
            if name.starts_with('.') {
                continue;
            }
            let Some((i, n)) = stem.split_once("-of-") else { continue };
            let (Ok(index), Ok(count)) = (i.parse::<usize>(), n.parse::<usize>()) else {
                continue;
            };
            if let Some(m) = self.load_shard_marker(index, count) {
                out.push(m);
            }
        }
        out.sort_by_key(|m| (m.count, m.index));
        out
    }

    // -----------------------------------------------------------------
    // internals
    // -----------------------------------------------------------------

    fn record_path(&self, identity: &str) -> PathBuf {
        let key = hash::fnv1a_128_hex(identity.as_bytes());
        self.root.join(&key[..2]).join(format!("{}.json", &key[2..]))
    }

    /// Common envelope: schema + kind + full identity (the corruption /
    /// collision / staleness check on read) + the hash key for humans
    /// grepping the shard dirs.
    fn record_doc(
        identity: &str,
        kind: &str,
        body: impl IntoIterator<Item = (String, Json)>,
    ) -> Json {
        let mut pairs: Vec<(String, Json)> = vec![
            ("schema".to_string(), Json::from(STORE_SCHEMA)),
            ("kind".to_string(), Json::from(kind)),
            ("identity".to_string(), Json::from(identity)),
            (
                "key".to_string(),
                Json::from(hash::fnv1a_128_hex(identity.as_bytes())),
            ),
        ];
        pairs.extend(body);
        Json::obj(pairs)
    }

    /// Parse + validate a record envelope.  Every failure mode here is
    /// "treat as miss, quarantine" at the call sites.
    fn read_record(path: &Path, identity: &str, kind: &str) -> Result<Json> {
        let text = fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        if j.get("schema").and_then(Json::as_u64) != Some(STORE_SCHEMA) {
            bail!("record schema mismatch");
        }
        if j.get("kind").and_then(Json::as_str) != Some(kind) {
            bail!("record kind mismatch");
        }
        if j.get("identity").and_then(Json::as_str) != Some(identity) {
            bail!("record identity mismatch (hash collision or stale key)");
        }
        Ok(j)
    }

    fn read_cell(path: &Path, identity: &str, spec: &RunSpec) -> Result<RunRecord> {
        let j = Self::read_record(path, identity, "cell")?;
        let serial_makespan = j
            .get("serial_makespan")
            .and_then(Json::as_u64_lossless)
            .context("record field 'serial_makespan'")?;
        let mut stats = RunStats::from_json(j.get("stats").context("record field 'stats'")?)?;
        if stats.makespan == 0 {
            bail!("record has a zero makespan");
        }
        // Re-apply the session's label normalization: a differently
        // spelled spec can resolve to the same signature (same cell), but
        // its CSV/JSON must carry *this* spec's name_sig, exactly as an
        // uncached run would.
        stats.sched = spec.sched.name_sig();
        Ok(RunRecord {
            spec: spec.clone(),
            serial_makespan,
            speedup: serial_makespan as f64 / stats.makespan as f64,
            stats,
        })
    }

    fn read_baseline(path: &Path, identity: &str) -> Result<RunStats> {
        let j = Self::read_record(path, identity, "baseline")?;
        let stats = RunStats::from_json(j.get("stats").context("record field 'stats'")?)?;
        if stats.makespan == 0 {
            bail!("baseline record has a zero makespan");
        }
        Ok(stats)
    }

    fn write_record(&self, identity: &str, doc: &Json) -> Result<()> {
        let path = self.record_path(identity);
        let dir = path.parent().expect("record paths are sharded");
        fs::create_dir_all(dir)
            .with_context(|| format!("creating store shard '{}'", dir.display()))?;
        let tmp = dir.join(format!(
            ".tmp.{}.{}",
            std::process::id(),
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, doc.to_pretty())
            .with_context(|| format!("writing store record '{}'", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing store record '{}'", path.display()))?;
        Ok(())
    }

    /// Move a corrupt record aside (flat `quarantine/` dir — file names
    /// are unique hash tails, so no collisions).  If the move itself
    /// fails the record is deleted instead: either way the bad bytes can
    /// never satisfy a future read, and write-through can refresh the key.
    fn quarantine(&self, path: &Path) {
        let qdir = self.root.join("quarantine");
        let moved = fs::create_dir_all(&qdir).is_ok()
            && path
                .file_name()
                .map(|name| fs::rename(path, qdir.join(name)).is_ok())
                .unwrap_or(false);
        if !moved {
            let _ = fs::remove_file(path);
        }
        self.quarantined.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Size;
    use crate::coordinator::sched::{Policy, SchedSpec};

    fn spec() -> RunSpec {
        RunSpec::builder()
            .bench("fib")
            .size(Size::Small)
            .policy(Policy::WorkFirst)
            .numa()
            .threads(4)
            .seed(7)
            .build()
            .unwrap()
    }

    /// Golden identity strings: every component of the cell key, pinned.
    /// A change here re-keys (silently invalidates) every store on disk —
    /// bump [`STORE_SCHEMA`] instead.
    #[test]
    fn identity_strings_are_pinned() {
        let s = spec();
        assert_eq!(
            cell_identity(&s).unwrap(),
            "s1|cell|fib|small|7|x4600|first-touch|wf|4|numa||rtdata=1"
        );
        assert_eq!(baseline_identity(&s), "fib|small|7|x4600|first-touch|");
        assert_eq!(
            baseline_record_identity(&s),
            "s1|baseline|fib|small|7|x4600|first-touch|"
        );
    }

    /// Full pipeline golden value: identity → FNV-128 → sharded path.
    #[test]
    fn record_keys_and_layout_are_pinned() {
        let id = cell_identity(&spec()).unwrap();
        let key = hash::fnv1a_128_hex(id.as_bytes());
        assert_eq!(key, "93d310237839fe47d8dcace9d20ae742");
        let store = ResultStore {
            root: PathBuf::from("/store"),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            tmp_seq: AtomicU64::new(0),
        };
        assert_eq!(
            store.record_path(&id),
            PathBuf::from("/store/93/d310237839fe47d8dcace9d20ae742.json")
        );
    }

    #[test]
    fn cells_fingerprint_is_spelling_invariant_and_order_sensitive() {
        let a = spec();
        let mut b = spec();
        b.seed = 8;
        let fwd = cells_fingerprint(&[a.clone(), b.clone()]).unwrap();
        let rev = cells_fingerprint(&[b, a]).unwrap();
        assert_ne!(fwd, rev, "cell order is part of the fingerprint");
        // resolved scheduler signatures: two spellings, one fingerprint
        let mut bare = spec();
        bare.sched = SchedSpec::new("numa-steal");
        let mut explicit = spec();
        explicit.sched =
            SchedSpec::new("numa-steal").with_param("batch", 1.0).with_param("min_kb", 16.0);
        assert_eq!(
            cells_fingerprint(std::slice::from_ref(&bare)).unwrap(),
            cells_fingerprint(std::slice::from_ref(&explicit)).unwrap()
        );
    }

    #[test]
    fn shard_markers_roundtrip_and_survive_corruption() {
        let dir =
            std::env::temp_dir().join(format!("numanos_store_marker_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let store = ResultStore::open(&dir).unwrap();
        let marker = ShardMarker {
            index: 1,
            count: 3,
            manifest_fnv: "abc123".into(),
            total_cells: 7,
            cell_ids: vec!["id-a".into(), "id-b".into()],
        };
        store.write_shard_marker(&marker).unwrap();
        assert_eq!(store.load_shard_marker(1, 3), Some(marker.clone()));
        assert_eq!(store.load_shard_marker(0, 3), None, "absent marker");
        assert_eq!(store.shard_markers(), vec![marker]);
        // a corrupt marker is quarantined and reported absent
        fs::write(dir.join("shards/0-of-3.json"), "{nope").unwrap();
        assert_eq!(store.load_shard_marker(0, 3), None);
        assert_eq!(store.shard_markers().len(), 1);
        assert!(dir.join("quarantine/0-of-3.json").exists());
        assert_eq!(store.counters().quarantined, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Two spellings of one configuration resolve to one cell; any axis
    /// change resolves to a different one.
    #[test]
    fn identity_uses_resolved_scheduler_signatures() {
        let mut bare = spec();
        bare.sched = SchedSpec::new("numa-steal");
        let mut explicit = spec();
        explicit.sched =
            SchedSpec::new("numa-steal").with_param("batch", 1.0).with_param("min_kb", 16.0);
        assert_eq!(cell_identity(&bare).unwrap(), cell_identity(&explicit).unwrap());

        let mut other = spec();
        other.sched = SchedSpec::new("numa-steal").with_param("batch", 2.0);
        assert_ne!(cell_identity(&bare).unwrap(), cell_identity(&other).unwrap());

        let mut reseeded = spec();
        reseeded.seed = 8;
        assert_ne!(cell_identity(&spec()).unwrap(), cell_identity(&reseeded).unwrap());
    }
}
