//! Sharded sweep execution and store-backed merge — the multi-process
//! layer over the content-addressed store.
//!
//! A [`ShardPlan`] deterministically partitions a manifest's flattened
//! cell sequence (global cell index mod shard count, across sweep
//! boundaries).  N processes each run
//! `numanos sweep --shard I/N --store DIR` against one shared store; a
//! final `numanos merge --manifest F --store DIR` re-runs the full
//! manifest as 100% cache hits and emits CSV/JSON byte-identical to a
//! sequential single-process sweep.  Each finished shard publishes a
//! completion marker (`<store>/shards/I-of-N.json`, see
//! [`crate::store::ShardMarker`]) embedding the manifest fingerprint
//! ([`cells_fingerprint`]), so the merge reports missing or stale shards
//! instead of silently re-executing their cells (`--merge-strict` turns
//! any such gap — or any cache miss — into a hard failure).
//!
//! `numanos serve` drives the same pipeline hostfile-free: a spool job
//! carrying `"shards": N` fans out into N shard work items plus a merge
//! item gated on their receipts (see [`classify_job`] and
//! [`super::serve`]).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::serde::Json;
use crate::spec::{ExperimentManifest, Session, ShardPlan};
use crate::store::{cells_fingerprint, ResultStore, ShardMarker};

/// Fingerprint of a manifest's flattened cell sequence (see
/// [`cells_fingerprint`] — resolved identities, so every spelling of one
/// manifest agrees).
pub fn manifest_fingerprint(manifest: &ExperimentManifest) -> Result<String> {
    cells_fingerprint(&manifest.all_cells()?)
}

/// Per-sweep slice of one shard pass, for progress reporting.
pub struct ShardSweepSummary {
    pub id: String,
    /// Cells this shard owned and ran (or served from the store).
    pub owned: usize,
    /// Cells skipped as other shards' property.
    pub skipped: usize,
}

/// What one [`run_manifest_shard`] pass did.
pub struct ShardRunSummary {
    pub plan: ShardPlan,
    pub manifest_fnv: String,
    pub total_cells: usize,
    pub owned_cells: usize,
    pub sweeps: Vec<ShardSweepSummary>,
}

/// Execute the cells of `manifest` that `plan` owns — walking the sweeps
/// in order with a running global-index base, so every shard of a
/// manifest agrees on the partition — then publish this shard's
/// completion marker in `store`.  The records themselves land in the
/// store via the session's write-through; a later `numanos merge` (or
/// any full sweep over the same store) assembles them.
pub fn run_manifest_shard(
    session: &Session,
    store: &ResultStore,
    manifest: &ExperimentManifest,
    plan: ShardPlan,
    workers: usize,
) -> Result<ShardRunSummary> {
    let manifest_fnv = manifest_fingerprint(manifest)?;
    let mut sweeps = Vec::with_capacity(manifest.sweeps.len());
    let mut cell_ids = Vec::new();
    let mut base = 0usize;
    for sweep in &manifest.sweeps {
        let out = session.run_sweep_sharded(sweep, workers, plan, base)?;
        base += out.result.records.len() + out.skipped;
        sweeps.push(ShardSweepSummary {
            id: sweep.id.clone(),
            owned: out.result.records.len(),
            skipped: out.skipped,
        });
        cell_ids.extend(out.owned_ids);
    }
    let summary = ShardRunSummary {
        plan,
        manifest_fnv: manifest_fnv.clone(),
        total_cells: base,
        owned_cells: cell_ids.len(),
        sweeps,
    };
    store.write_shard_marker(&ShardMarker {
        index: plan.index,
        count: plan.count,
        manifest_fnv,
        total_cells: base as u64,
        cell_ids,
    })?;
    Ok(summary)
}

/// Marker census for one manifest fingerprint — what `numanos merge`
/// reports before assembling.
pub struct ShardStatus {
    /// Shard count the census is judged against: among marker groups
    /// matching the manifest, a complete group wins, else the largest
    /// count seen.  `None` when no marker matches.
    pub count: Option<usize>,
    /// Fresh markers of that count: `(index, cells completed)`.
    pub present: Vec<(usize, u64)>,
    /// Indices in `0..count` with no fresh marker.
    pub missing: Vec<usize>,
    /// Marker names (any count) whose fingerprint does not match this
    /// manifest — leftovers from an edited manifest or another run.
    pub stale: Vec<String>,
}

/// Scan `store`'s shard markers against a manifest fingerprint.
pub fn shard_status(store: &ResultStore, manifest_fnv: &str) -> ShardStatus {
    let mut fresh: BTreeMap<usize, Vec<(usize, u64)>> = BTreeMap::new();
    let mut stale = Vec::new();
    for m in store.shard_markers() {
        if m.manifest_fnv == manifest_fnv {
            fresh.entry(m.count).or_default().push((m.index, m.cell_ids.len() as u64));
        } else {
            stale.push(format!("{}-of-{}", m.index, m.count));
        }
    }
    let count = fresh
        .iter()
        .rev()
        .find(|(count, marks)| marks.len() == **count)
        .map(|(c, _)| *c)
        .or_else(|| fresh.keys().next_back().copied());
    let (present, missing) = match count {
        Some(c) => {
            let marks = fresh.remove(&c).unwrap_or_default();
            let missing =
                (0..c).filter(|i| !marks.iter().any(|(idx, _)| idx == i)).collect();
            (marks, missing)
        }
        None => (Vec::new(), Vec::new()),
    };
    ShardStatus { count, present, missing, stale }
}

/// What a spool job file asks for, beyond the manifest it carries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// A plain manifest: execute everything.
    Plain,
    /// `"shards": N` — fan out into N shard items plus a merge item.
    Fanout(usize),
    /// `"shard": "I/N"` — execute one shard and publish its marker.
    Shard(ShardPlan),
    /// `"merge_of": N` — merge item, gated on N sibling shard receipts.
    Merge(usize),
}

/// Split a spool job document into its shard directive and the plain
/// manifest document (directive keys stripped — [`ExperimentManifest`]
/// rejects unknown keys, deliberately, so shard job files must pass
/// through here before manifest parsing; `numanos lint` does the same).
pub fn classify_job(doc: &Json) -> Result<(JobKind, Json)> {
    let mut obj = doc.as_obj().context("job must be a JSON/TOML object")?.clone();
    let shards = obj.remove("shards");
    let shard = obj.remove("shard");
    let merge_of = obj.remove("merge_of");
    if [shards.is_some(), shard.is_some(), merge_of.is_some()]
        .iter()
        .filter(|given| **given)
        .count()
        > 1
    {
        bail!("job carries more than one of 'shards', 'shard', 'merge_of'");
    }
    let kind = if let Some(v) = shards {
        let n = v.as_usize().context("'shards' must be a positive integer")?;
        if n == 0 {
            bail!("'shards' must be at least 1");
        }
        JobKind::Fanout(n)
    } else if let Some(v) = shard {
        let spec = v.as_str().context("'shard' must be a string like \"0/3\"")?;
        JobKind::Shard(ShardPlan::parse(spec)?)
    } else if let Some(v) = merge_of {
        let n = v.as_usize().context("'merge_of' must be a positive integer")?;
        if n == 0 {
            bail!("'merge_of' must be at least 1");
        }
        JobKind::Merge(n)
    } else {
        JobKind::Plain
    };
    Ok((kind, Json::Obj(obj)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_job_strips_shard_directives() {
        let doc = Json::parse(
            r#"{"title": "t", "sweeps": [{"id": "a", "bench": "fib"}], "shards": 3}"#,
        )
        .unwrap();
        let (kind, stripped) = classify_job(&doc).unwrap();
        assert_eq!(kind, JobKind::Fanout(3));
        assert!(stripped.get("shards").is_none());
        assert!(stripped.get("sweeps").is_some());

        let doc = Json::parse(r#"{"sweeps": [], "shard": "1/3"}"#).unwrap();
        let (kind, _) = classify_job(&doc).unwrap();
        assert_eq!(kind, JobKind::Shard(ShardPlan { index: 1, count: 3 }));

        let doc = Json::parse(r#"{"sweeps": [], "merge_of": 3}"#).unwrap();
        assert_eq!(classify_job(&doc).unwrap().0, JobKind::Merge(3));

        let doc = Json::parse(r#"{"sweeps": []}"#).unwrap();
        assert_eq!(classify_job(&doc).unwrap().0, JobKind::Plain);
    }

    #[test]
    fn classify_job_rejects_malformed_directives() {
        for bad in [
            r#"{"sweeps": [], "shards": 0}"#,
            r#"{"sweeps": [], "shards": "three"}"#,
            r#"{"sweeps": [], "shard": "5/3"}"#,
            r#"{"sweeps": [], "shard": 2}"#,
            r#"{"sweeps": [], "merge_of": 0}"#,
            r#"{"sweeps": [], "shards": 3, "shard": "0/3"}"#,
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(classify_job(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn shard_status_classifies_markers() {
        let dir =
            std::env::temp_dir().join(format!("numanos_shard_status_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ResultStore::open(&dir).unwrap();
        let marker = |index, count, fnv: &str| ShardMarker {
            index,
            count,
            manifest_fnv: fnv.into(),
            total_cells: 6,
            cell_ids: vec!["x".into(), "y".into()],
        };
        // empty store: no census at all
        let s = shard_status(&store, "fresh");
        assert_eq!(s.count, None);
        assert!(s.present.is_empty() && s.missing.is_empty() && s.stale.is_empty());
        // incomplete group + a stale marker from another manifest
        store.write_shard_marker(&marker(0, 3, "fresh")).unwrap();
        store.write_shard_marker(&marker(2, 3, "fresh")).unwrap();
        store.write_shard_marker(&marker(0, 2, "old")).unwrap();
        let s = shard_status(&store, "fresh");
        assert_eq!(s.count, Some(3));
        assert_eq!(s.present, vec![(0, 2), (2, 2)]);
        assert_eq!(s.missing, vec![1]);
        assert_eq!(s.stale, vec!["0-of-2".to_string()]);
        // completing the group clears the misses
        store.write_shard_marker(&marker(1, 3, "fresh")).unwrap();
        let s = shard_status(&store, "fresh");
        assert_eq!(s.count, Some(3));
        assert_eq!(s.present.len(), 3);
        assert!(s.missing.is_empty());
        // a complete smaller group wins over an incomplete larger one
        store.write_shard_marker(&marker(0, 5, "fresh")).unwrap();
        let s = shard_status(&store, "fresh");
        assert_eq!(s.count, Some(3), "complete 3-group beats incomplete 5-group");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
