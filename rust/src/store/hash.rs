//! Self-contained FNV-1a hashing for store keys.
//!
//! The vendored dependency set has no hashing crate, and
//! `std::collections::hash_map::DefaultHasher` is explicitly not stable
//! across releases — useless for an on-disk cache whose keys must outlive
//! the binary that wrote them.  FNV-1a is tiny, fully specified, and fast
//! on the short identity strings we feed it; the 128-bit variant gives a
//! collision probability that is negligible at any realistic store size
//! (and records embed their full identity string, so even a collision
//! degrades to a detected miss, never a wrong result).
//!
//! The parameters below are the published FNV-1a constants; the unit tests
//! pin them against independently computed vectors so a refactor cannot
//! silently re-key (and thereby invalidate) every existing store.

const FNV64_OFFSET: u64 = 0xcbf29ce484222325;
const FNV64_PRIME: u64 = 0x100000001b3;

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// 2^88 + 2^8 + 0x3b, the specified 128-bit FNV prime.
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

/// 64-bit FNV-1a.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = FNV64_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV64_PRIME);
    }
    h
}

/// 128-bit FNV-1a (native `u128` arithmetic).
pub fn fnv1a_128(bytes: &[u8]) -> u128 {
    let mut h = FNV128_OFFSET;
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    h
}

/// 128-bit FNV-1a as 32 lowercase hex digits — the store's record key.
pub fn fnv1a_128_hex(bytes: &[u8]) -> String {
    format!("{:032x}", fnv1a_128(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden vectors (computed independently from the FNV spec).  These
    /// pin the exact key function: changing any constant or the fold
    /// order re-keys every store on disk, which must never be silent.
    #[test]
    fn fnv1a_64_golden_vectors() {
        assert_eq!(fnv1a_64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_64(b"abc"), 0xe71fa2190541574b);
        assert_eq!(fnv1a_64(b"numanos"), 0x3a2c16e325844b02);
    }

    #[test]
    fn fnv1a_128_golden_vectors() {
        assert_eq!(fnv1a_128_hex(b""), "6c62272e07bb014262b821756295c58d");
        assert_eq!(fnv1a_128_hex(b"a"), "d228cb696f1a8caf78912b704e4a8964");
        assert_eq!(fnv1a_128_hex(b"abc"), "a68d622cec8b5822836dbc7977af7f3b");
        assert_eq!(fnv1a_128_hex(b"numanos"), "f555f8a58f4ff78d8214de860a2f8fb2");
    }

    #[test]
    fn hex_is_fixed_width() {
        // leading zeros are kept: shard dirs always have 2 hex chars
        assert_eq!(fnv1a_128_hex(b"").len(), 32);
        for probe in [&b"x"[..], b"yy", b"zzz"] {
            assert_eq!(fnv1a_128_hex(probe).len(), 32);
        }
    }
}
