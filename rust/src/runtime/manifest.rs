//! Artifact manifest (`artifacts/manifest.json`) — signatures for shape
//! checking before feeding literals to PJRT.
//!
//! The JSON parsing that used to live here moved to [`crate::serde`]
//! (shared with the experiment-spec layer); this module keeps the
//! manifest model.  Parsing failures degrade gracefully: the engine
//! simply skips signature validation.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub use crate::serde::Json;

use super::Buf;

/// Dtype of a tensor in the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// Shape+dtype of one tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<i64>,
    pub dtype: Dtype,
}

impl TensorSig {
    pub fn elements(&self) -> i64 {
        self.shape.iter().product()
    }
}

/// Signature of one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

impl ArtifactSig {
    /// Validate call inputs against the signature.
    pub fn check_inputs(&self, bufs: &[Buf]) -> Result<()> {
        if bufs.len() != self.inputs.len() {
            bail!("{} inputs given, {} expected", bufs.len(), self.inputs.len());
        }
        for (i, (buf, sig)) in bufs.iter().zip(&self.inputs).enumerate() {
            let (len, shape, dt) = match buf {
                Buf::F32(d, s) => (d.len() as i64, s, Dtype::F32),
                Buf::I32(d, s) => (d.len() as i64, s, Dtype::I32),
            };
            if dt != sig.dtype {
                bail!("input {i}: dtype {dt:?} != {:?}", sig.dtype);
            }
            if shape != &sig.shape {
                bail!("input {i}: shape {shape:?} != {:?}", sig.shape);
            }
            if len != sig.elements() {
                bail!("input {i}: {len} elements != {}", sig.elements());
            }
        }
        Ok(())
    }
}

/// The whole `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    by_name: HashMap<String, ArtifactSig>,
}

fn tensor_sig(j: &Json) -> Result<TensorSig> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor missing shape")?
        .iter()
        .map(|v| v.as_num().map(|n| n as i64).context("bad dim"))
        .collect::<Result<Vec<i64>>>()?;
    let dtype = match j.get("dtype").and_then(Json::as_str) {
        Some("float32") => Dtype::F32,
        Some("int32") => Dtype::I32,
        other => bail!("unsupported dtype {other:?}"),
    };
    Ok(TensorSig { shape, dtype })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text)?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?;
        let mut by_name = HashMap::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let parse_list = |key: &str| -> Result<Vec<TensorSig>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .with_context(|| format!("artifact missing {key}"))?
                    .iter()
                    .map(tensor_sig)
                    .collect()
            };
            let sig = ArtifactSig {
                name: name.clone(),
                inputs: parse_list("inputs")?,
                outputs: parse_list("outputs")?,
            };
            by_name.insert(name, sig);
        }
        Ok(Self { by_name })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSig> {
        self.by_name.get(name)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_name.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "matmul_f32_128",
         "inputs": [{"shape": [128, 128], "dtype": "float32"},
                    {"shape": [128, 128], "dtype": "float32"}],
         "outputs": [{"shape": [128, 128], "dtype": "float32"}],
         "hlo_bytes": 1234},
        {"name": "priority_f32_16",
         "inputs": [{"shape": [16, 16], "dtype": "int32"},
                    {"shape": [8], "dtype": "float32"},
                    {"shape": [16], "dtype": "float32"}],
         "outputs": [{"shape": [16], "dtype": "float32"},
                     {"shape": [16], "dtype": "float32"}],
         "hlo_bytes": 99}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let sig = m.get("matmul_f32_128").unwrap();
        assert_eq!(sig.inputs.len(), 2);
        assert_eq!(sig.inputs[0].shape, vec![128, 128]);
        assert_eq!(sig.outputs[0].elements(), 128 * 128);
        let p = m.get("priority_f32_16").unwrap();
        assert_eq!(p.inputs[0].dtype, Dtype::I32);
        assert_eq!(p.outputs.len(), 2);
        assert_eq!(m.names(), vec!["matmul_f32_128", "priority_f32_16"]);
    }

    #[test]
    fn check_inputs_validates() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let sig = m.get("matmul_f32_128").unwrap();
        let good = vec![
            Buf::f32(vec![0.0; 128 * 128], &[128, 128]),
            Buf::f32(vec![0.0; 128 * 128], &[128, 128]),
        ];
        assert!(sig.check_inputs(&good).is_ok());
        let wrong_shape = vec![
            Buf::f32(vec![0.0; 64], &[8, 8]),
            Buf::f32(vec![0.0; 128 * 128], &[128, 128]),
        ];
        assert!(sig.check_inputs(&wrong_shape).is_err());
        let wrong_dtype = vec![
            Buf::i32(vec![0; 128 * 128], &[128, 128]),
            Buf::f32(vec![0.0; 128 * 128], &[128, 128]),
        ];
        assert!(sig.check_inputs(&wrong_dtype).is_err());
        assert!(sig.check_inputs(&good[..1]).is_err());
    }

    #[test]
    fn bad_manifest_is_an_error_not_a_panic() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }
}
