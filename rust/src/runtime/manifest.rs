//! Artifact manifest (`artifacts/manifest.json`) — signatures for shape
//! checking before feeding literals to PJRT.
//!
//! The vendored dependency set has no serde, so this module carries a
//! small self-contained JSON parser (objects, arrays, strings, numbers,
//! bools, null — no unicode escapes beyond BMP, which the manifest never
//! uses).  Parsing failures degrade gracefully: the engine simply skips
//! signature validation.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Buf;

// ---------------------------------------------------------------------------
// Minimal JSON value + parser
// ---------------------------------------------------------------------------

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .context("short \\u escape")?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).context("bad codepoint")?);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Manifest model
// ---------------------------------------------------------------------------

/// Dtype of a tensor in the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// Shape+dtype of one tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<i64>,
    pub dtype: Dtype,
}

impl TensorSig {
    pub fn elements(&self) -> i64 {
        self.shape.iter().product()
    }
}

/// Signature of one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

impl ArtifactSig {
    /// Validate call inputs against the signature.
    pub fn check_inputs(&self, bufs: &[Buf]) -> Result<()> {
        if bufs.len() != self.inputs.len() {
            bail!("{} inputs given, {} expected", bufs.len(), self.inputs.len());
        }
        for (i, (buf, sig)) in bufs.iter().zip(&self.inputs).enumerate() {
            let (len, shape, dt) = match buf {
                Buf::F32(d, s) => (d.len() as i64, s, Dtype::F32),
                Buf::I32(d, s) => (d.len() as i64, s, Dtype::I32),
            };
            if dt != sig.dtype {
                bail!("input {i}: dtype {dt:?} != {:?}", sig.dtype);
            }
            if shape != &sig.shape {
                bail!("input {i}: shape {shape:?} != {:?}", sig.shape);
            }
            if len != sig.elements() {
                bail!("input {i}: {len} elements != {}", sig.elements());
            }
        }
        Ok(())
    }
}

/// The whole `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    by_name: HashMap<String, ArtifactSig>,
}

fn tensor_sig(j: &Json) -> Result<TensorSig> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor missing shape")?
        .iter()
        .map(|v| v.as_num().map(|n| n as i64).context("bad dim"))
        .collect::<Result<Vec<i64>>>()?;
    let dtype = match j.get("dtype").and_then(Json::as_str) {
        Some("float32") => Dtype::F32,
        Some("int32") => Dtype::I32,
        other => bail!("unsupported dtype {other:?}"),
    };
    Ok(TensorSig { shape, dtype })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text)?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?;
        let mut by_name = HashMap::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let parse_list = |key: &str| -> Result<Vec<TensorSig>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .with_context(|| format!("artifact missing {key}"))?
                    .iter()
                    .map(tensor_sig)
                    .collect()
            };
            let sig = ArtifactSig {
                name: name.clone(),
                inputs: parse_list("inputs")?,
                outputs: parse_list("outputs")?,
            };
            by_name.insert(name, sig);
        }
        Ok(Self { by_name })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSig> {
        self.by_name.get(name)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_name.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "matmul_f32_128",
         "inputs": [{"shape": [128, 128], "dtype": "float32"},
                    {"shape": [128, 128], "dtype": "float32"}],
         "outputs": [{"shape": [128, 128], "dtype": "float32"}],
         "hlo_bytes": 1234},
        {"name": "priority_f32_16",
         "inputs": [{"shape": [16, 16], "dtype": "int32"},
                    {"shape": [8], "dtype": "float32"},
                    {"shape": [16], "dtype": "float32"}],
         "outputs": [{"shape": [16], "dtype": "float32"},
                     {"shape": [16], "dtype": "float32"}],
         "hlo_bytes": 99}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let sig = m.get("matmul_f32_128").unwrap();
        assert_eq!(sig.inputs.len(), 2);
        assert_eq!(sig.inputs[0].shape, vec![128, 128]);
        assert_eq!(sig.outputs[0].elements(), 128 * 128);
        let p = m.get("priority_f32_16").unwrap();
        assert_eq!(p.inputs[0].dtype, Dtype::I32);
        assert_eq!(p.outputs.len(), 2);
    }

    #[test]
    fn check_inputs_validates() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let sig = m.get("matmul_f32_128").unwrap();
        let good = vec![
            Buf::f32(vec![0.0; 128 * 128], &[128, 128]),
            Buf::f32(vec![0.0; 128 * 128], &[128, 128]),
        ];
        assert!(sig.check_inputs(&good).is_ok());
        let wrong_shape = vec![
            Buf::f32(vec![0.0; 64], &[8, 8]),
            Buf::f32(vec![0.0; 128 * 128], &[128, 128]),
        ];
        assert!(sig.check_inputs(&wrong_shape).is_err());
        let wrong_dtype = vec![
            Buf::i32(vec![0; 128 * 128], &[128, 128]),
            Buf::f32(vec![0.0; 128 * 128], &[128, 128]),
        ];
        assert!(sig.check_inputs(&wrong_dtype).is_err());
        assert!(sig.check_inputs(&good[..1]).is_err());
    }

    #[test]
    fn json_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn json_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
