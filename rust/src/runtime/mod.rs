//! PJRT execution engine — the AOT bridge (Layer-3 ↔ Layer-2/1).
//!
//! Loads the HLO **text** artifacts produced by `python/compile/aot.py`
//! (`make artifacts`), compiles them once on the PJRT CPU client, and
//! executes them with `f32`/`i32` literals from task bodies.
//!
//! Interchange is HLO text, never a serialized `HloModuleProto`: jax ≥ 0.5
//! emits 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md and
//! DESIGN.md §3).  Python is *never* on this path — the binary is
//! self-contained once `artifacts/` exists.
//!
//! The `xla` crate is only available where it has been vendored, so the
//! real engine sits behind the `pjrt` cargo feature.  The default build
//! ships an [`ExecEngine`] stub with the same surface whose constructor
//! returns a clear error — callers (CLI `--compute pjrt`, the e2e tests)
//! degrade gracefully instead of breaking the build.

pub mod manifest;

#[cfg(feature = "pjrt")]
use anyhow::{bail, Result};

pub use manifest::{ArtifactSig, Manifest};

/// A typed input buffer for [`ExecEngine::call`].
#[derive(Clone, Debug)]
pub enum Buf {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl Buf {
    pub fn f32(data: Vec<f32>, shape: &[i64]) -> Self {
        Buf::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[i64]) -> Self {
        Buf::I32(data, shape.to_vec())
    }
}

#[cfg(feature = "pjrt")]
fn ensure_len(len: usize, want: i64) -> Result<()> {
    if len as i64 != want {
        bail!("buffer has {len} elements, shape wants {want}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
impl Buf {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Buf::F32(data, shape) => {
                let n: i64 = shape.iter().product();
                ensure_len(data.len(), n)?;
                xla::Literal::vec1(data).reshape(shape)?
            }
            Buf::I32(data, shape) => {
                let n: i64 = shape.iter().product();
                ensure_len(data.len(), n)?;
                xla::Literal::vec1(data).reshape(shape)?
            }
        };
        Ok(lit)
    }
}

#[cfg(feature = "pjrt")]
mod engine_impl {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use anyhow::{anyhow, bail, Context, Result};

    use super::{ArtifactSig, Buf, Manifest};

    /// A loaded-and-compiled artifact cache over one PJRT client.
    pub struct ExecEngine {
        client: xla::PjRtClient,
        dir: PathBuf,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        manifest: Option<Manifest>,
        /// Executions performed (telemetry for EXPERIMENTS.md).
        pub calls: u64,
    }

    impl ExecEngine {
        /// Create a CPU PJRT engine over `artifact_dir` (usually `artifacts/`).
        pub fn cpu<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
            let dir = artifact_dir.as_ref().to_path_buf();
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let manifest = Manifest::load(&dir.join("manifest.json")).ok();
            Ok(Self { client, dir, exes: HashMap::new(), manifest, calls: 0 })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Artifact signature from the manifest, if present.
        pub fn signature(&self, name: &str) -> Option<&ArtifactSig> {
            self.manifest.as_ref().and_then(|m| m.get(name))
        }

        /// Number of artifacts listed in the manifest.
        pub fn manifest_len(&self) -> usize {
            self.manifest.as_ref().map_or(0, |m| m.len())
        }

        /// Load + compile `name` (idempotent).
        pub fn load(&mut self, name: &str) -> Result<()> {
            if self.exes.contains_key(name) {
                return Ok(());
            }
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            self.exes.insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute artifact `name` with `inputs`; returns every tuple element
        /// as a flat `f32` vector (all exported graphs return f32 planes).
        pub fn call(&mut self, name: &str, inputs: &[Buf]) -> Result<Vec<Vec<f32>>> {
            self.load(name)?;
            if let Some(sig) = self.signature(name) {
                sig.check_inputs(inputs)
                    .with_context(|| format!("artifact '{name}' input mismatch"))?;
            }
            let lits: Vec<xla::Literal> =
                inputs.iter().map(Buf::to_literal).collect::<Result<_>>()?;
            let exe = self.exes.get(name).ok_or_else(|| anyhow!("artifact vanished"))?;
            self.calls += 1;
            let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: unwrap all elements.
            let elems = result.to_tuple()?;
            elems
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(Into::into))
                .collect()
        }

        /// Convenience: single-output artifact over f32 buffers.
        pub fn call1(&mut self, name: &str, inputs: &[Buf]) -> Result<Vec<f32>> {
            let mut out = self.call(name, inputs)?;
            if out.len() != 1 {
                bail!("artifact '{name}' returned {} outputs, expected 1", out.len());
            }
            Ok(out.pop().unwrap())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine_impl {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{ArtifactSig, Buf};

    /// Stub engine for builds without the `pjrt` feature: same surface as
    /// the real one, but [`ExecEngine::cpu`] always errors, so no instance
    /// ever exists (the methods are the type-level contract task bodies
    /// compile against).
    pub struct ExecEngine {
        /// Executions performed (always 0 for the stub).
        pub calls: u64,
        _private: (),
    }

    impl ExecEngine {
        pub fn cpu<P: AsRef<Path>>(_artifact_dir: P) -> Result<Self> {
            bail!(
                "PJRT compute is not available: numanos was built without the \
                 `pjrt` cargo feature (requires the vendored `xla` crate); \
                 rerun with `--compute sim` or rebuild with `--features pjrt`"
            )
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn signature(&self, _name: &str) -> Option<&ArtifactSig> {
            None
        }

        pub fn manifest_len(&self) -> usize {
            0
        }

        pub fn load(&mut self, name: &str) -> Result<()> {
            bail!("artifact '{name}': built without the `pjrt` feature")
        }

        pub fn call(&mut self, name: &str, _inputs: &[Buf]) -> Result<Vec<Vec<f32>>> {
            bail!("artifact '{name}': built without the `pjrt` feature")
        }

        pub fn call1(&mut self, name: &str, _inputs: &[Buf]) -> Result<Vec<f32>> {
            bail!("artifact '{name}': built without the `pjrt` feature")
        }
    }
}

pub use engine_impl::ExecEngine;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buf_constructors_preserve_shape() {
        match Buf::f32(vec![1.0; 4], &[2, 2]) {
            Buf::F32(d, s) => {
                assert_eq!(d.len(), 4);
                assert_eq!(s, vec![2, 2]);
            }
            _ => unreachable!(),
        }
        match Buf::i32(vec![1; 6], &[2, 3]) {
            Buf::I32(d, s) => {
                assert_eq!(d.len(), 6);
                assert_eq!(s, vec![2, 3]);
            }
            _ => unreachable!(),
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_errors_clearly() {
        let e = ExecEngine::cpu("artifacts").unwrap_err();
        assert!(format!("{e}").contains("pjrt"), "{e}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn buf_shape_validation() {
        assert!(Buf::f32(vec![1.0; 4], &[2, 2]).to_literal().is_ok());
        assert!(Buf::f32(vec![1.0; 3], &[2, 2]).to_literal().is_err());
        assert!(Buf::i32(vec![1; 6], &[2, 3]).to_literal().is_ok());
    }

    // Full round-trip tests (artifact load + execute + numeric check) live
    // in rust/tests/pjrt_roundtrip.rs since they need `make artifacts`.
}
