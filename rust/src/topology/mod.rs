//! NUMA fabric models — the paper's §II hardware substrate.
//!
//! A [`Topology`] is a set of NUMA nodes (each with some cores and a local
//! memory), connected by an interconnect graph.  Hop distances between
//! nodes are derived from the edge list by BFS, exactly as `hwloc` /
//! `libnuma` would report them via the ACPI SLIT on a real machine (the
//! paper reads them with `numa.h` + `sched.h`; our coordinator reads them
//! from here — same information, simulated source).
//!
//! The flagship preset is [`Topology::x4600`]: the SunFire X4600 used in
//! the paper's evaluation — 8 dual-core Opteron sockets on an *enhanced
//! twisted ladder* HyperTransport fabric.  Corner sockets (0, 1, 6, 7)
//! spend one HT link on I/O and are less central than the inner sockets
//! (2, 3, 4, 5); maximum distance is 3 hops.  This centrality asymmetry is
//! what makes the paper's priority allocation matter: Linux first-touch on
//! node 0 (a corner) is measurably worse than on a central node.

use std::collections::VecDeque;

use anyhow::{bail, Result};

/// A NUMA machine model: nodes, cores and the hop-distance matrix.
#[derive(Clone, Debug)]
pub struct Topology {
    name: String,
    /// cores_per_node[n] = number of cores directly attached to node n.
    cores_per_node: Vec<usize>,
    /// node_hops[a][b] = interconnect hops between nodes a and b (0 on-node).
    node_hops: Vec<Vec<u8>>,
    /// core -> owning node (derived).
    core_node: Vec<usize>,
    /// Pages of local memory per node (capacity for first-touch placement).
    node_capacity_pages: u64,
}

impl Topology {
    /// Build a topology from an interconnect edge list.
    ///
    /// `edges` connect node indices; hop distances are all-pairs BFS over
    /// the (unweighted) graph.  Fails if the graph is disconnected.
    pub fn from_edges(
        name: &str,
        cores_per_node: Vec<usize>,
        edges: &[(usize, usize)],
        node_capacity_pages: u64,
    ) -> Result<Self> {
        let n = cores_per_node.len();
        if n == 0 {
            bail!("topology needs at least one node");
        }
        let mut adj = vec![Vec::new(); n];
        for &(a, b) in edges {
            if a >= n || b >= n || a == b {
                bail!("bad edge ({a},{b}) for {n} nodes");
            }
            adj[a].push(b);
            adj[b].push(a);
        }
        let mut node_hops = vec![vec![u8::MAX; n]; n];
        for (start, hops) in node_hops.iter_mut().enumerate() {
            // BFS from `start`
            hops[start] = 0;
            let mut q = VecDeque::from([start]);
            while let Some(u) = q.pop_front() {
                for &v in &adj[u] {
                    if hops[v] == u8::MAX {
                        hops[v] = hops[u] + 1;
                        q.push_back(v);
                    }
                }
            }
            if hops.iter().any(|&h| h == u8::MAX) {
                bail!("topology '{name}' is disconnected from node {start}");
            }
        }
        let mut core_node = Vec::new();
        for (node, &c) in cores_per_node.iter().enumerate() {
            core_node.extend(std::iter::repeat(node).take(c));
        }
        if core_node.is_empty() {
            bail!("topology '{name}' has no cores");
        }
        Ok(Self {
            name: name.to_string(),
            cores_per_node,
            node_hops,
            core_node,
            node_capacity_pages,
        })
    }

    // ---- presets --------------------------------------------------------

    /// Single-node UMA box (the degenerate control case).
    pub fn uma(cores: usize) -> Self {
        Self::from_edges("uma", vec![cores], &[], 1 << 16).unwrap()
    }

    /// Two sockets, one hop apart (entry-level Opteron/Nehalem 2P).
    pub fn dual(cores_per_socket: usize) -> Self {
        Self::from_edges("dual", vec![cores_per_socket; 2], &[(0, 1)], 1 << 15).unwrap()
    }

    /// Four sockets in a square (Opteron 4P): hops 1 (edge) and 2 (diagonal).
    pub fn quad(cores_per_socket: usize) -> Self {
        Self::from_edges(
            "quad",
            vec![cores_per_socket; 4],
            &[(0, 1), (1, 3), (3, 2), (2, 0)],
            1 << 15,
        )
        .unwrap()
    }

    /// The paper's machine: SunFire X4600, 8 dual-core Opteron sockets on an
    /// enhanced-twisted-ladder HT fabric (diameter 3, asymmetric centrality;
    /// corner sockets 0/1/6/7 keep one HT link for I/O).  Node capacity is
    /// scaled 1:256 from the real 4 GiB/node so that the paper's
    /// footprint-to-capacity ratios are preserved at simulator scale
    /// (see DESIGN.md §2): 4 GiB / 256 = 16 MiB = 4096 pages.
    pub fn x4600() -> Self {
        let edges = [
            (0, 1), (6, 7),                 // end rungs
            (0, 2), (2, 4), (4, 6),         // left rail
            (1, 3), (3, 5), (5, 7),         // right rail
            (2, 5), (3, 4),                 // the "twist" cross links
        ];
        Self::from_edges("x4600", vec![2; 8], &edges, 4096).unwrap()
    }

    /// SGI-Altix-like deeper fabric: 16 dual-core nodes, two X4600-style
    /// ladders bridged by a single router link => up to 5 hops (used for the
    /// related-work comparison where MTS degrades, §III.B).
    pub fn altix16() -> Self {
        let mut edges = vec![
            (0, 1), (6, 7), (0, 2), (2, 4), (4, 6), (1, 3), (3, 5), (5, 7), (2, 5), (3, 4),
        ];
        // second ladder shifted by 8
        let second: Vec<(usize, usize)> = edges.iter().map(|&(a, b)| (a + 8, b + 8)).collect();
        edges.extend(second);
        edges.push((4, 10)); // single bridge
        Self::from_edges("altix16", vec![2; 16], &edges, 4096).unwrap()
    }

    /// Tile-style mesh (TilePro64-like, used by LOCAWR §III.B): `side`²
    /// single-core tiles, 2-D mesh, hops up to 2·(side-1).
    pub fn tile_mesh(side: usize) -> Self {
        let mut edges = Vec::new();
        let id = |r: usize, c: usize| r * side + c;
        for r in 0..side {
            for c in 0..side {
                if c + 1 < side {
                    edges.push((id(r, c), id(r, c + 1)));
                }
                if r + 1 < side {
                    edges.push((id(r, c), id(r + 1, c)));
                }
            }
        }
        Self::from_edges("tile_mesh", vec![1; side * side], &edges, 512).unwrap()
    }

    /// Heterogeneous variant of the X4600 (paper §IV: "future heterogeneous
    /// architectures where number of cores per node may vary"): inner
    /// sockets carry 4 cores, corners 2.
    pub fn x4600_hetero() -> Self {
        let edges = [
            (0, 1), (6, 7), (0, 2), (2, 4), (4, 6), (1, 3), (3, 5), (5, 7), (2, 5), (3, 4),
        ];
        let cores = vec![2, 2, 4, 4, 4, 4, 2, 2];
        Self::from_edges("x4600_hetero", cores, &edges, 4096).unwrap()
    }

    /// Look up a preset by name (CLI surface).
    pub fn by_name(name: &str) -> Result<Self> {
        Ok(match name {
            "uma" => Self::uma(16),
            "dual" => Self::dual(8),
            "quad" => Self::quad(4),
            "x4600" => Self::x4600(),
            "x4600_hetero" => Self::x4600_hetero(),
            "altix16" => Self::altix16(),
            "tile64" => Self::tile_mesh(8),
            "tile16" => Self::tile_mesh(4),
            other => bail!(
                "unknown topology '{other}' (try: uma dual quad x4600 x4600_hetero altix16 tile16 tile64)"
            ),
        })
    }

    pub fn preset_names() -> &'static [&'static str] {
        &["uma", "dual", "quad", "x4600", "x4600_hetero", "altix16", "tile16", "tile64"]
    }

    // ---- queries --------------------------------------------------------

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_nodes(&self) -> usize {
        self.cores_per_node.len()
    }

    pub fn num_cores(&self) -> usize {
        self.core_node.len()
    }

    pub fn cores_on_node(&self, node: usize) -> impl Iterator<Item = usize> + '_ {
        self.core_node
            .iter()
            .enumerate()
            .filter(move |&(_, &n)| n == node)
            .map(|(c, _)| c)
    }

    pub fn cores_per_node(&self, node: usize) -> usize {
        self.cores_per_node[node]
    }

    pub fn node_of(&self, core: usize) -> usize {
        self.core_node[core]
    }

    /// Interconnect hops between two nodes (0 for the same node).
    pub fn node_hops(&self, a: usize, b: usize) -> u8 {
        self.node_hops[a][b]
    }

    /// Hops between the nodes of two cores (0 if they share a node).
    pub fn core_hops(&self, a: usize, b: usize) -> u8 {
        self.node_hops[self.core_node[a]][self.core_node[b]]
    }

    /// Largest hop distance in the fabric (the paper's `max-numa-distance`).
    pub fn max_hops(&self) -> u8 {
        self.node_hops
            .iter()
            .flat_map(|row| row.iter().copied())
            .max()
            .unwrap_or(0)
    }

    pub fn node_capacity_pages(&self) -> u64 {
        self.node_capacity_pages
    }

    /// Override the per-node memory capacity (workload scaling studies).
    pub fn with_capacity_pages(mut self, pages: u64) -> Self {
        self.node_capacity_pages = pages;
        self
    }

    /// Mean hop distance from `node` to every core in the machine —
    /// the centrality measure behind the paper's allocation argument.
    pub fn mean_hops_from(&self, node: usize) -> f64 {
        let total: u64 = self
            .core_node
            .iter()
            .map(|&cn| self.node_hops[node][cn] as u64)
            .sum();
        total as f64 / self.core_node.len() as f64
    }

    /// Per-core hop matrix (what the priority kernels consume).
    pub fn core_hop_matrix(&self) -> Vec<Vec<u8>> {
        let nc = self.num_cores();
        (0..nc)
            .map(|a| (0..nc).map(|b| self.core_hops(a, b)).collect())
            .collect()
    }

    /// Nodes sorted by distance from `from`, nearest first (steal sweeps).
    pub fn nodes_by_distance(&self, from: usize) -> Vec<usize> {
        let mut nodes: Vec<usize> = (0..self.num_nodes()).collect();
        nodes.sort_by_key(|&n| (self.node_hops[from][n], n));
        nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn x4600_shape() {
        let t = Topology::x4600();
        assert_eq!(t.num_nodes(), 8);
        assert_eq!(t.num_cores(), 16);
        assert_eq!(t.max_hops(), 3);
    }

    #[test]
    fn x4600_symmetry_and_diagonal() {
        let t = Topology::x4600();
        for a in 0..8 {
            assert_eq!(t.node_hops(a, a), 0);
            for b in 0..8 {
                assert_eq!(t.node_hops(a, b), t.node_hops(b, a));
            }
        }
    }

    #[test]
    fn x4600_triangle_inequality() {
        let t = Topology::x4600();
        for a in 0..8 {
            for b in 0..8 {
                for c in 0..8 {
                    assert!(t.node_hops(a, c) <= t.node_hops(a, b) + t.node_hops(b, c));
                }
            }
        }
    }

    #[test]
    fn x4600_corners_are_less_central() {
        // the property the whole paper §IV leans on
        let t = Topology::x4600();
        let corner = [0usize, 1, 6, 7];
        let inner = [2usize, 3, 4, 5];
        let worst_inner = inner.iter().map(|&n| t.mean_hops_from(n)).fold(0.0, f64::max);
        let best_corner = corner
            .iter()
            .map(|&n| t.mean_hops_from(n))
            .fold(f64::INFINITY, f64::min);
        assert!(
            worst_inner < best_corner,
            "inner {worst_inner} vs corner {best_corner}"
        );
    }

    #[test]
    fn same_node_cores_zero_hops() {
        let t = Topology::x4600();
        assert_eq!(t.core_hops(0, 1), 0);
        assert_eq!(t.node_of(0), t.node_of(1));
        assert!(t.core_hops(0, 2) >= 1);
    }

    #[test]
    fn disconnected_graph_rejected() {
        assert!(Topology::from_edges("bad", vec![1; 3], &[(0, 1)], 16).is_err());
    }

    #[test]
    fn bad_edge_rejected() {
        assert!(Topology::from_edges("bad", vec![1; 2], &[(0, 5)], 16).is_err());
        assert!(Topology::from_edges("bad", vec![1; 2], &[(0, 0)], 16).is_err());
    }

    #[test]
    fn tile_mesh_distances() {
        let t = Topology::tile_mesh(4);
        assert_eq!(t.num_nodes(), 16);
        // manhattan distance corner-to-corner
        assert_eq!(t.node_hops(0, 15), 6);
        assert_eq!(t.max_hops(), 6);
    }

    #[test]
    fn quad_diagonal_is_two() {
        let t = Topology::quad(4);
        assert_eq!(t.node_hops(0, 3), 2);
        assert_eq!(t.node_hops(0, 1), 1);
    }

    #[test]
    fn altix_deeper_than_x4600() {
        let t = Topology::altix16();
        assert_eq!(t.num_cores(), 32);
        assert!(t.max_hops() > Topology::x4600().max_hops());
    }

    #[test]
    fn presets_all_resolve() {
        for name in Topology::preset_names() {
            let t = Topology::by_name(name).unwrap();
            assert!(t.num_cores() > 0, "{name}");
        }
        assert!(Topology::by_name("nope").is_err());
    }

    #[test]
    fn nodes_by_distance_sorted() {
        let t = Topology::x4600();
        for from in 0..8 {
            let order = t.nodes_by_distance(from);
            assert_eq!(order[0], from);
            for w in order.windows(2) {
                assert!(t.node_hops(from, w[0]) <= t.node_hops(from, w[1]));
            }
        }
    }

    #[test]
    fn hetero_core_counts() {
        let t = Topology::x4600_hetero();
        assert_eq!(t.num_cores(), 24);
        assert_eq!(t.cores_per_node(0), 2);
        assert_eq!(t.cores_per_node(2), 4);
    }
}
