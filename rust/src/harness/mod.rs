//! Figure regeneration harness — one entry per paper table/figure
//! (DESIGN.md §5 experiment index).
//!
//! A [`FigureSpec`] names a benchmark, a set of scheduler configurations
//! and a thread sweep; [`run_figure`] executes the sweep against a fresh
//! serial baseline and returns a [`SpeedupTable`] shaped exactly like the
//! paper's figure.  [`report`] renders the table with the paper's anchor
//! values beside the measured ones.

use anyhow::Result;

use crate::bots;
use crate::config::Size;
use crate::coordinator::binding::BindPolicy;
use crate::coordinator::runtime::Runtime;
use crate::coordinator::sched::Policy;
use crate::metrics::paper;
use crate::metrics::speedup;
use crate::metrics::table::SpeedupTable;

/// Thread counts on the paper's x-axis (16-core X4600).
pub const PAPER_THREADS: &[usize] = &[2, 4, 6, 8, 12, 16];

/// One reproducible figure.
#[derive(Clone, Debug)]
pub struct FigureSpec {
    pub id: &'static str,
    pub title: &'static str,
    pub bench: &'static str,
    pub size: Size,
    pub configs: Vec<(Policy, BindPolicy)>,
    pub threads: Vec<usize>,
}

/// The six stock-vs-NUMA configurations of Figs 5–10.
pub fn stock_configs() -> Vec<(Policy, BindPolicy)> {
    vec![
        (Policy::BreadthFirst, BindPolicy::Linear),
        (Policy::CilkBased, BindPolicy::Linear),
        (Policy::WorkFirst, BindPolicy::Linear),
        (Policy::BreadthFirst, BindPolicy::NumaAware),
        (Policy::CilkBased, BindPolicy::NumaAware),
        (Policy::WorkFirst, BindPolicy::NumaAware),
    ]
}

/// The three NUMA-scheduler configurations of Figs 13–15.
pub fn numa_sched_configs() -> Vec<(Policy, BindPolicy)> {
    vec![
        (Policy::WorkFirst, BindPolicy::NumaAware),
        (Policy::Dfwspt, BindPolicy::NumaAware),
        (Policy::Dfwsrpt, BindPolicy::NumaAware),
    ]
}

/// Every figure in the paper's evaluation (E1–E9 of DESIGN.md §5).
pub fn figures() -> Vec<FigureSpec> {
    let t = PAPER_THREADS.to_vec();
    vec![
        FigureSpec { id: "fig5", title: "Fig 5 — Floorplan speedup", bench: "floorplan", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig6", title: "Fig 6 — SparseLU (for) speedup", bench: "sparselu_for", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig7", title: "Fig 7 — FFT speedup", bench: "fft", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig8", title: "Fig 8 — Strassen speedup", bench: "strassen", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig9", title: "Fig 9 — Sort speedup", bench: "sort", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig10", title: "Fig 10 — NQueens speedup", bench: "nqueens", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig13", title: "Fig 13 — FFT, NUMA-aware task schedulers", bench: "fft", size: Size::Medium, configs: numa_sched_configs(), threads: t.clone() },
        FigureSpec { id: "fig14", title: "Fig 14 — Sort, NUMA-aware task schedulers", bench: "sort", size: Size::Medium, configs: numa_sched_configs(), threads: t.clone() },
        FigureSpec { id: "fig15", title: "Fig 15 — Strassen, NUMA-aware task schedulers", bench: "strassen", size: Size::Medium, configs: numa_sched_configs(), threads: t },
    ]
}

pub fn figure_by_id(id: &str) -> Option<FigureSpec> {
    figures().into_iter().find(|f| f.id == id)
}

/// Label used in tables for a (policy, bind) pair — paper legend style.
pub fn config_label(policy: Policy, bind: BindPolicy) -> String {
    match bind {
        BindPolicy::NumaAware => format!("{}-Scheduler-NUMA", policy.name()),
        BindPolicy::Linear => format!("{}-Scheduler", policy.name()),
    }
}

/// Run one figure sweep.  `seed` shapes workload + randomized decisions;
/// the paper takes best-of-50 wall-clock runs, we take the deterministic
/// simulated makespan of one seed.
pub fn run_figure(rt: &Runtime, spec: &FigureSpec, seed: u64) -> Result<SpeedupTable> {
    let mut serial_w = bots::create(spec.bench, spec.size, seed)?;
    let serial = rt.run_serial(serial_w.as_mut(), seed)?;

    let mut table = SpeedupTable::new(spec.title, spec.threads.clone());
    for &(policy, bind) in &spec.configs {
        let mut row = Vec::with_capacity(spec.threads.len());
        for &threads in &spec.threads {
            let mut w = bots::create(spec.bench, spec.size, seed)?;
            let stats = rt.run(w.as_mut(), policy, bind, threads, seed, None)?;
            row.push(speedup(&serial, &stats));
        }
        table.push_row(config_label(policy, bind), row);
    }
    Ok(table)
}

/// Render a figure's table plus paper-anchor comparison lines.
pub fn report(spec: &FigureSpec, table: &SpeedupTable) -> String {
    let mut out = table.to_markdown();
    out.push('\n');
    let anchors = paper::anchors_for(spec.id);
    if !anchors.is_empty() {
        out.push_str("paper anchors (measured vs published):\n\n");
        out.push_str("| config | threads | measured | paper |\n|---|---|---|---|\n");
        for a in anchors {
            let got = table
                .get(a.config, a.threads)
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "—".into());
            out.push_str(&format!(
                "| {} | {} | {} | {:.2} |\n",
                a.config, a.threads, got, a.speedup
            ));
        }
        out.push('\n');
    }
    let gains = paper::gains_for(spec.id);
    if !gains.is_empty() {
        out.push_str("paper gain claims (measured vs published, % faster):\n\n");
        out.push_str("| better | worse | threads | measured % | paper % |\n|---|---|---|---|---|\n");
        for g in gains {
            let got = table
                .gain_pct(g.better, g.worse, g.threads)
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "—".into());
            out.push_str(&format!(
                "| {} | {} | {} | {} | {:.2} |\n",
                g.better, g.worse, g.threads, got, g.pct
            ));
        }
        out.push('\n');
    }
    out
}

/// E10: the §V.A headline-gain summary across data-intensive benchmarks.
pub fn gains_summary(rt: &Runtime, size: Size, seed: u64) -> Result<SpeedupTable> {
    let mut table = SpeedupTable::new(
        "NUMA-aware allocation gain at 16 threads (% faster execution)",
        vec![16],
    );
    for bench in ["fft", "sort", "strassen", "sparselu_for", "nqueens", "floorplan"] {
        let mut serial_w = bots::create(bench, size, seed)?;
        let serial = rt.run_serial(serial_w.as_mut(), seed)?;
        for policy in [Policy::CilkBased, Policy::WorkFirst] {
            let mut base_w = bots::create(bench, size, seed)?;
            let base = rt.run(base_w.as_mut(), policy, BindPolicy::Linear, 16, seed, None)?;
            let mut numa_w = bots::create(bench, size, seed)?;
            let numa = rt.run(numa_w.as_mut(), policy, BindPolicy::NumaAware, 16, seed, None)?;
            let gain = (1.0 - speedup(&serial, &base) / speedup(&serial, &numa)) * 100.0;
            table.push_row(format!("{bench}/{}", policy.name()), vec![gain]);
        }
    }
    Ok(table)
}

/// Shared entry point for the `rust/benches/figNN_*.rs` bench binaries:
/// regenerate one paper figure at Medium scale, print the table, the
/// paper-anchor comparison and wall-clock, and write CSV/markdown into
/// `results/` (created if needed).
pub fn bench_figure_main(id: &str) -> Result<()> {
    let seed: u64 = std::env::var("NUMANOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let size = match std::env::var("NUMANOS_SIZE").as_deref() {
        Ok("small") => Size::Small,
        Ok("large") => Size::Large,
        _ => Size::Medium,
    };
    let rt = Runtime::paper_testbed();
    let mut spec = figure_by_id(id)
        .ok_or_else(|| anyhow::anyhow!("unknown figure '{id}'"))?;
    spec.size = size;
    let t0 = std::time::Instant::now();
    let table = run_figure(&rt, &spec, seed)?;
    println!("{}", report(&spec, &table));
    println!("{}", table.to_ascii());
    println!("[{} regenerated in {:.2}s]", spec.id, t0.elapsed().as_secs_f64());
    std::fs::create_dir_all("results").ok();
    std::fs::write(format!("results/{}.md", spec.id), report(&spec, &table))?;
    std::fs::write(format!("results/{}.csv", spec.id), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_figures_defined() {
        let figs = figures();
        assert_eq!(figs.len(), 9);
        for f in &figs {
            assert!(!f.configs.is_empty());
            assert_eq!(f.threads, PAPER_THREADS);
            assert!(bots::NAMES.contains(&f.bench), "{}", f.bench);
        }
    }

    #[test]
    fn figure_lookup() {
        assert!(figure_by_id("fig7").is_some());
        assert!(figure_by_id("fig99").is_none());
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(
            config_label(Policy::CilkBased, BindPolicy::NumaAware),
            "cilk-Scheduler-NUMA"
        );
    }

    #[test]
    fn tiny_figure_runs_end_to_end() {
        // a small custom spec exercising the full path quickly
        let rt = Runtime::paper_testbed();
        let spec = FigureSpec {
            id: "test",
            title: "test",
            bench: "fib",
            size: Size::Small,
            configs: vec![
                (Policy::WorkFirst, BindPolicy::Linear),
                (Policy::Dfwsrpt, BindPolicy::NumaAware),
            ],
            threads: vec![2, 8],
        };
        let table = run_figure(&rt, &spec, 1).unwrap();
        assert_eq!(table.rows.len(), 2);
        for (_, row) in &table.rows {
            for v in row {
                assert!(*v > 0.5, "speedup {v} nonsensical");
            }
        }
        // more threads should not be slower for fib
        let r = &table.rows[0].1;
        assert!(r[1] > r[0]);
    }

    #[test]
    fn report_contains_anchor_section() {
        let spec = figure_by_id("fig7").unwrap();
        let mut table = SpeedupTable::new(&spec.title, PAPER_THREADS.to_vec());
        for (p, b) in &spec.configs {
            table.push_row(config_label(*p, *b), vec![1.0; PAPER_THREADS.len()]);
        }
        let rep = report(&spec, &table);
        assert!(rep.contains("paper anchors"));
        assert!(rep.contains("bf-Scheduler"));
    }
}
