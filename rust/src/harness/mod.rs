//! Figure regeneration harness — one entry per paper table/figure
//! (DESIGN.md §5 experiment index).
//!
//! Every figure is **sweep data**: a [`FigureSpec`] names a benchmark, a
//! set of scheduler configurations and a thread axis, and
//! [`sweep_for`] lowers it onto a [`Sweep`] the generic
//! [`Session`] executor runs — there is no per-figure launch code.
//! [`report`] renders the resulting [`SpeedupTable`] with the paper's
//! anchor values beside the measured ones.

use anyhow::Result;

use crate::config::Size;
use crate::coordinator::binding::BindPolicy;
use crate::coordinator::runtime::Runtime;
use crate::coordinator::sched::{Policy, SchedSpec};
use crate::metrics::paper;
use crate::metrics::table::SpeedupTable;
use crate::spec::{Session, Sweep};

pub use crate::spec::sweep::PAPER_THREADS;

/// One reproducible figure.
#[derive(Clone, Debug)]
pub struct FigureSpec {
    pub id: &'static str,
    pub title: &'static str,
    pub bench: &'static str,
    pub size: Size,
    pub configs: Vec<(Policy, BindPolicy)>,
    pub threads: Vec<usize>,
}

/// The six stock-vs-NUMA configurations of Figs 5–10.
pub fn stock_configs() -> Vec<(Policy, BindPolicy)> {
    vec![
        (Policy::BreadthFirst, BindPolicy::Linear),
        (Policy::CilkBased, BindPolicy::Linear),
        (Policy::WorkFirst, BindPolicy::Linear),
        (Policy::BreadthFirst, BindPolicy::NumaAware),
        (Policy::CilkBased, BindPolicy::NumaAware),
        (Policy::WorkFirst, BindPolicy::NumaAware),
    ]
}

/// The three NUMA-scheduler configurations of Figs 13–15.
pub fn numa_sched_configs() -> Vec<(Policy, BindPolicy)> {
    vec![
        (Policy::WorkFirst, BindPolicy::NumaAware),
        (Policy::Dfwspt, BindPolicy::NumaAware),
        (Policy::Dfwsrpt, BindPolicy::NumaAware),
    ]
}

/// The locality-strategy ablation the bench suite pins across
/// topologies: the paper's best stock NUMA scheduler (dfwsrpt), then the
/// three placement strategies layered on it — steal-side bias only
/// (numa-steal), push-to-home placement (numa-home), and the adaptive
/// hybrid (numa-adapt) — all under the §IV NUMA binding.
pub fn ablation_configs() -> Vec<(SchedSpec, BindPolicy)> {
    vec![
        (SchedSpec::stock(Policy::Dfwsrpt), BindPolicy::NumaAware),
        (SchedSpec::new("numa-steal"), BindPolicy::NumaAware),
        (SchedSpec::new("numa-home"), BindPolicy::NumaAware),
        (SchedSpec::new("numa-adapt"), BindPolicy::NumaAware),
    ]
}

/// Every figure in the paper's evaluation (E1–E9 of DESIGN.md §5).
pub fn figures() -> Vec<FigureSpec> {
    let t = PAPER_THREADS.to_vec();
    vec![
        FigureSpec { id: "fig5", title: "Fig 5 — Floorplan speedup", bench: "floorplan", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig6", title: "Fig 6 — SparseLU (for) speedup", bench: "sparselu_for", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig7", title: "Fig 7 — FFT speedup", bench: "fft", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig8", title: "Fig 8 — Strassen speedup", bench: "strassen", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig9", title: "Fig 9 — Sort speedup", bench: "sort", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig10", title: "Fig 10 — NQueens speedup", bench: "nqueens", size: Size::Medium, configs: stock_configs(), threads: t.clone() },
        FigureSpec { id: "fig13", title: "Fig 13 — FFT, NUMA-aware task schedulers", bench: "fft", size: Size::Medium, configs: numa_sched_configs(), threads: t.clone() },
        FigureSpec { id: "fig14", title: "Fig 14 — Sort, NUMA-aware task schedulers", bench: "sort", size: Size::Medium, configs: numa_sched_configs(), threads: t.clone() },
        FigureSpec { id: "fig15", title: "Fig 15 — Strassen, NUMA-aware task schedulers", bench: "strassen", size: Size::Medium, configs: numa_sched_configs(), threads: t },
    ]
}

pub fn figure_by_id(id: &str) -> Option<FigureSpec> {
    figures().into_iter().find(|f| f.id == id)
}

/// Label used in tables for a (policy, bind) pair — paper legend style.
pub fn config_label(policy: Policy, bind: BindPolicy) -> String {
    match bind {
        BindPolicy::NumaAware => format!("{}-Scheduler-NUMA", policy.name()),
        BindPolicy::Linear => format!("{}-Scheduler", policy.name()),
    }
}

/// Lower a figure onto generic sweep data.  `seed` shapes workload +
/// randomized decisions; the paper takes best-of-50 wall-clock runs, we
/// instead take the deterministic simulated makespan of one seed.
pub fn sweep_for(spec: &FigureSpec, seed: u64) -> Sweep {
    Sweep::new(spec.id, spec.title)
        .with_bench(spec.bench)
        .with_configs(spec.configs.clone())
        .with_threads(spec.threads.clone())
        .with_seed(seed)
        .with_size(spec.size)
}

/// All nine paper figures as sweeps — the whole evaluation as data.
pub fn figure_sweeps(size: Size, seed: u64) -> Vec<Sweep> {
    figures()
        .into_iter()
        .map(|mut f| {
            f.size = size;
            sweep_for(&f, seed)
        })
        .collect()
}

/// Run one figure sweep on a session (memoized baselines shared across
/// figures; cells execute in parallel, deterministically).
pub fn run_figure_with(session: &Session, spec: &FigureSpec, seed: u64) -> Result<SpeedupTable> {
    Ok(session.run_sweep(&sweep_for(spec, seed))?.table())
}

/// Compatibility shim: run one figure on a bare runtime (the session
/// adopts the runtime's topology and cost model).
pub fn run_figure(rt: &Runtime, spec: &FigureSpec, seed: u64) -> Result<SpeedupTable> {
    let session = Session::from_runtime(rt);
    let mut sweep = sweep_for(spec, seed);
    sweep.topo = rt.topo.name().to_string();
    Ok(session.run_sweep(&sweep)?.table())
}

/// Render a figure's table plus paper-anchor comparison lines.
pub fn report(spec: &FigureSpec, table: &SpeedupTable) -> String {
    let mut out = table.to_markdown();
    out.push('\n');
    let anchors = paper::anchors_for(spec.id);
    if !anchors.is_empty() {
        out.push_str("paper anchors (measured vs published):\n\n");
        out.push_str("| config | threads | measured | paper |\n|---|---|---|---|\n");
        for a in anchors {
            let got = table
                .get(a.config, a.threads)
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "—".into());
            out.push_str(&format!(
                "| {} | {} | {} | {:.2} |\n",
                a.config, a.threads, got, a.speedup
            ));
        }
        out.push('\n');
    }
    let gains = paper::gains_for(spec.id);
    if !gains.is_empty() {
        out.push_str("paper gain claims (measured vs published, % faster):\n\n");
        out.push_str("| better | worse | threads | measured % | paper % |\n|---|---|---|---|---|\n");
        for g in gains {
            let got = table
                .gain_pct(g.better, g.worse, g.threads)
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "—".into());
            out.push_str(&format!(
                "| {} | {} | {} | {} | {:.2} |\n",
                g.better, g.worse, g.threads, got, g.pct
            ));
        }
        out.push('\n');
    }
    out
}

/// The benchmarks of the §V.A gain summary.
const GAINS_BENCHES: &[&str] = &["fft", "sort", "strassen", "sparselu_for", "nqueens", "floorplan"];

/// E10: the §V.A headline-gain summary — also just a sweep, post-processed
/// into the paper's gain metric.
fn gains_table(session: &Session, size: Size, seed: u64, topo: &str) -> Result<SpeedupTable> {
    let sweep = Sweep::new("gains", "NUMA-aware allocation gain at 16 threads (% faster execution)")
        .with_benches(GAINS_BENCHES.iter().copied())
        .with_configs(vec![
            (Policy::CilkBased, BindPolicy::Linear),
            (Policy::CilkBased, BindPolicy::NumaAware),
            (Policy::WorkFirst, BindPolicy::Linear),
            (Policy::WorkFirst, BindPolicy::NumaAware),
        ])
        .with_threads(vec![16])
        .with_seed(seed)
        .with_size(size)
        .with_topo(topo);
    let result = session.run_sweep(&sweep)?;
    let mut table = SpeedupTable::new(&sweep.title, vec![16]);
    // cells are bench-major, config-minor: [cilk/lin, cilk/numa, wf/lin, wf/numa]
    for (bench, chunk) in GAINS_BENCHES.iter().zip(result.records.chunks(4)) {
        for (policy, pair) in
            [Policy::CilkBased, Policy::WorkFirst].iter().zip(chunk.chunks(2))
        {
            let (base, numa) = (&pair[0], &pair[1]);
            let gain = (1.0 - base.speedup / numa.speedup) * 100.0;
            table.push_row(format!("{bench}/{}", policy.name()), vec![gain]);
        }
    }
    Ok(table)
}

/// §V.A gain summary on a session (x4600, the paper's testbed).
pub fn gains_summary_with(session: &Session, size: Size, seed: u64) -> Result<SpeedupTable> {
    gains_table(session, size, seed, "x4600")
}

/// Compatibility shim: gain summary on a bare runtime (adopting its
/// topology and cost model).
pub fn gains_summary(rt: &Runtime, size: Size, seed: u64) -> Result<SpeedupTable> {
    gains_table(&Session::from_runtime(rt), size, seed, rt.topo.name())
}

/// Shared entry point for the `rust/benches/figNN_*.rs` bench binaries:
/// regenerate one paper figure at Medium scale, print the table, the
/// paper-anchor comparison and wall-clock, and write CSV/markdown into
/// `results/` (created if needed).
pub fn bench_figure_main(id: &str) -> Result<()> {
    let seed: u64 = std::env::var("NUMANOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let size = match std::env::var("NUMANOS_SIZE").as_deref() {
        Ok("small") => Size::Small,
        Ok("large") => Size::Large,
        _ => Size::Medium,
    };
    let session = Session::new();
    let mut spec = figure_by_id(id)
        .ok_or_else(|| anyhow::anyhow!("unknown figure '{id}'"))?;
    spec.size = size;
    let t0 = std::time::Instant::now();
    let table = run_figure_with(&session, &spec, seed)?;
    println!("{}", report(&spec, &table));
    println!("{}", table.to_ascii());
    println!("[{} regenerated in {:.2}s]", spec.id, t0.elapsed().as_secs_f64());
    std::fs::create_dir_all("results").ok();
    std::fs::write(format!("results/{}.md", spec.id), report(&spec, &table))?;
    std::fs::write(format!("results/{}.csv", spec.id), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bots;

    #[test]
    fn nine_figures_defined() {
        let figs = figures();
        assert_eq!(figs.len(), 9);
        for f in &figs {
            assert!(!f.configs.is_empty());
            assert_eq!(f.threads, PAPER_THREADS);
            assert!(bots::NAMES.contains(&f.bench), "{}", f.bench);
        }
    }

    #[test]
    fn ablation_configs_name_registered_strategies() {
        let configs = ablation_configs();
        assert_eq!(configs.len(), 4);
        assert_eq!(configs[0].0.name_sig(), "dfwsrpt");
        for (spec, bind) in &configs {
            assert_eq!(*bind, BindPolicy::NumaAware);
            crate::coordinator::sched::build(spec).unwrap();
        }
    }

    #[test]
    fn figure_lookup() {
        assert!(figure_by_id("fig7").is_some());
        assert!(figure_by_id("fig99").is_none());
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(
            config_label(Policy::CilkBased, BindPolicy::NumaAware),
            "cilk-Scheduler-NUMA"
        );
    }

    #[test]
    fn all_nine_figures_are_sweep_data() {
        let sweeps = figure_sweeps(Size::Small, 7);
        assert_eq!(sweeps.len(), 9);
        for (f, s) in figures().iter().zip(&sweeps) {
            assert_eq!(s.id, f.id);
            assert_eq!(s.benches, vec![f.bench.to_string()]);
            assert_eq!(s.cell_count(), f.configs.len() * f.threads.len());
            assert_eq!(s.seeds, vec![7]);
        }
    }

    #[test]
    fn tiny_figure_runs_end_to_end() {
        // a small custom spec exercising the full path quickly
        let rt = Runtime::paper_testbed();
        let spec = FigureSpec {
            id: "test",
            title: "test",
            bench: "fib",
            size: Size::Small,
            configs: vec![
                (Policy::WorkFirst, BindPolicy::Linear),
                (Policy::Dfwsrpt, BindPolicy::NumaAware),
            ],
            threads: vec![2, 8],
        };
        let table = run_figure(&rt, &spec, 1).unwrap();
        assert_eq!(table.rows.len(), 2);
        assert_eq!(table.rows[0].0, "wf-Scheduler");
        assert_eq!(table.rows[1].0, "dfwsrpt-Scheduler-NUMA");
        for (_, row) in &table.rows {
            for v in row {
                assert!(*v > 0.5, "speedup {v} nonsensical");
            }
        }
        // more threads should not be slower for fib
        let r = &table.rows[0].1;
        assert!(r[1] > r[0]);
    }

    #[test]
    fn report_contains_anchor_section() {
        let spec = figure_by_id("fig7").unwrap();
        let mut table = SpeedupTable::new(spec.title, PAPER_THREADS.to_vec());
        for (p, b) in &spec.configs {
            table.push_row(config_label(*p, *b), vec![1.0; PAPER_THREADS.len()]);
        }
        let rep = report(&spec, &table);
        assert!(rep.contains("paper anchors"));
        assert!(rep.contains("bf-Scheduler"));
    }

    #[test]
    fn gains_summary_rows_cover_benches() {
        let session = Session::new();
        let t = gains_summary_with(&session, Size::Small, 3).unwrap();
        assert_eq!(t.rows.len(), GAINS_BENCHES.len() * 2);
        assert_eq!(t.rows[0].0, "fft/cilk");
        assert_eq!(t.rows[1].0, "fft/wf");
    }
}
