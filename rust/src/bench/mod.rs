//! The pinned perf-trajectory suite behind `numanos bench`.
//!
//! The paper's argument is comparative measurement, so the repo tracks
//! its own trajectory the same way: a **pinned suite** — the nine paper
//! figures plus the dfwsrpt → numa-steal → numa-home → numa-adapt
//! ablation across four topologies, at fixed sizes/threads/seeds — runs
//! through the ordinary [`Sweep`]/[`Session`] machinery (cells stay
//! byte-identical to `numanos sweep`) and lands in one machine-readable
//! `BENCH_<n>.json`:
//!
//! * per cell, the **simulated** metrics (makespan cycles, remote-access
//!   ratio, the locality counters: `affine_steals`, `batch_steals`,
//!   `homed_resumes`, `mailbox_hits`, `tasks_migrated`, `pushed_home`) —
//!   deterministic, diffable, and the thing CI fails on when it drifts;
//! * per cell and suite-total, the **host wall-time** of the simulator
//!   itself (median of `--reps` repetitions) — the engine-perf signal,
//!   noisy by nature, so comparisons only ever warn on it.
//!
//! [`compare`] renders the delta report between two such files and
//! decides the exit code; `benches/engine_perf.rs` consumes the same
//! `perf` group so the bench binary and the suite can never disagree
//! about which cells constitute "the hot loop".

pub mod compare;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::Size;
use crate::coordinator::binding::BindPolicy;
use crate::coordinator::sched::{Policy, SchedSpec};
use crate::harness;
use crate::metrics::median_ms;
use crate::serde::Json;
use crate::simnuma::MemSpec;
use crate::spec::session::RunRecord;
use crate::spec::{Session, Sweep};

/// Schema version stamped into every report this module emits.
pub const SCHEMA_VERSION: u64 = 1;
/// Suite identity — bump when the pinned cell set changes incompatibly
/// (comparisons across different suites are refused).
pub const SUITE_NAME: &str = "numanos-pinned-v1";

/// Thread count every pinned cell runs with: the paper's 16-core X4600
/// axis end-point, kept constant across the ablation topologies so the
/// strategy columns stay comparable.
const SUITE_THREADS: usize = 16;
/// Seed every pinned cell runs with.
const SUITE_SEED: u64 = 42;
/// Ablation topologies: paper testbed, its heterogeneous variant, the
/// mesh, and the fat tree.
const ABLATION_TOPOS: &[&str] = &["x4600", "x4600_hetero", "tile16", "altix16"];
/// Hot-loop cells (bench, scheduler): the engine-perf working set,
/// shared with `benches/engine_perf.rs` through [`perf_entries`] so the
/// bench binary and the suite measure the same cells.
const PERF_CELLS: &[(&str, Policy)] = &[
    ("fft", Policy::WorkFirst),
    ("fft", Policy::BreadthFirst),
    ("sort", Policy::Dfwsrpt),
    ("uts", Policy::Dfwsrpt),
    ("sparselu_for", Policy::Dfwspt),
    ("nqueens", Policy::BreadthFirst),
];
/// Million-task cells (bench, scheduler): the XL stress tier exercising
/// the allocation-free hot path at the paper's task-count scale (fft at
/// the same scale would also need ~10M tasks; these three hit ≥1M with
/// distinct shapes: binary recursion, hash-random tree, data-bound merge
/// tree).  Depth-first schedulers only — breadth-first at 1M tasks means
/// a 1M-entry shared queue, which is a different experiment.
const PERF_XL_CELLS: &[(&str, Policy)] = &[
    ("fib", Policy::WorkFirst),
    ("uts", Policy::Dfwsrpt),
    ("sort", Policy::Dfwsrpt),
];

/// One pinned suite member: a group label over a concrete sweep.  The
/// sweep is ordinary [`Sweep`] data, so a suite cell executes exactly
/// like the equivalent `numanos sweep` cell.
#[derive(Clone, Debug)]
pub struct SuiteEntry {
    /// Filter/reporting group (`smoke`, `fig5`…`fig15`, `ablation`,
    /// `perf`); also the first segment of every cell id.
    pub group: String,
    pub sweep: Sweep,
}

/// The full pinned suite, in emission order: `smoke`, the nine paper
/// figures, the four-strategy × four-topology ablation, then the
/// engine-perf hot-loop cells.
pub fn suite() -> Vec<SuiteEntry> {
    let mut entries = Vec::new();

    // smoke: two tiny cells CI can run on every push.
    entries.push(SuiteEntry {
        group: "smoke".into(),
        sweep: Sweep::new("smoke", "Smoke: tiny sanity cells")
            .with_bench("fib")
            .with_config(Policy::WorkFirst, BindPolicy::NumaAware)
            .with_config(SchedSpec::new("numa-home"), BindPolicy::NumaAware)
            .with_threads(vec![4])
            .with_seed(SUITE_SEED)
            .with_size(Size::Small),
    });

    // the nine paper figures, pinned to one thread count and the small
    // size (trajectory tracking wants fast, stable cells; the full
    // figure grids stay with `numanos figure`).
    for f in harness::figures() {
        entries.push(SuiteEntry {
            group: f.id.to_string(),
            sweep: Sweep::new(f.id, f.title)
                .with_bench(f.bench)
                .with_configs(f.configs.clone())
                .with_threads(vec![SUITE_THREADS])
                .with_seed(SUITE_SEED)
                .with_size(Size::Small),
        });
    }

    // the scheduler ablation across topologies, under interleaved pages
    // so the placing strategies have remote traffic to win back.
    for topo in ABLATION_TOPOS {
        entries.push(SuiteEntry {
            group: "ablation".into(),
            sweep: Sweep::new(
                &format!("ablation-{topo}"),
                &format!("Strategy ablation on {topo}"),
            )
            .with_bench("sparselu_for")
            .with_configs(harness::ablation_configs())
            .with_threads(vec![SUITE_THREADS])
            .with_seed(SUITE_SEED)
            .with_size(Size::Small)
            .with_topo(topo)
            .with_mem(MemSpec::new("interleave")),
        });
    }

    entries.extend(perf_entries());
    entries.extend(perf_xl_entries());
    entries
}

/// The `perf` group alone: the medium-size hot-loop cells
/// `benches/engine_perf.rs` drives for events/s measurement.
pub fn perf_entries() -> Vec<SuiteEntry> {
    PERF_CELLS
        .iter()
        .map(|(bench, policy)| {
            let sig = SchedSpec::stock(*policy).name_sig();
            SuiteEntry {
                group: "perf".into(),
                sweep: Sweep::new(
                    &format!("perf-{bench}-{sig}"),
                    &format!("Engine perf: {bench} under {sig}"),
                )
                .with_bench(bench)
                .with_config(*policy, BindPolicy::NumaAware)
                .with_threads(vec![SUITE_THREADS])
                .with_seed(SUITE_SEED)
                .with_size(Size::Medium),
            }
        })
        .collect()
}

/// The `perf-xl` group alone: ≥1M-task cells.  Deliberately last in the
/// suite and selectable via `--filter perf-xl` (or excluded by filtering
/// on any other group) — a full-suite run pays for them, CI's quick
/// paths don't.
pub fn perf_xl_entries() -> Vec<SuiteEntry> {
    PERF_XL_CELLS
        .iter()
        .map(|(bench, policy)| {
            let sig = SchedSpec::stock(*policy).name_sig();
            SuiteEntry {
                group: "perf-xl".into(),
                sweep: Sweep::new(
                    &format!("perf-xl-{bench}-{sig}"),
                    &format!("Engine perf (million-task): {bench} under {sig}"),
                )
                .with_bench(bench)
                .with_config(*policy, BindPolicy::NumaAware)
                .with_threads(vec![SUITE_THREADS])
                .with_seed(SUITE_SEED)
                .with_size(Size::XL),
            }
        })
        .collect()
}

/// Suite entries whose group or sweep id contains `filter` (empty filter
/// keeps everything).  Errors when nothing matches, listing the groups.
pub fn filtered(filter: &str) -> Result<Vec<SuiteEntry>> {
    let entries: Vec<SuiteEntry> = suite()
        .into_iter()
        .filter(|e| filter.is_empty() || e.group.contains(filter) || e.sweep.id.contains(filter))
        .collect();
    if entries.is_empty() {
        let mut groups: Vec<String> = suite().into_iter().map(|e| e.group).collect();
        groups.dedup();
        bail!("--filter '{filter}' matches no suite entries (groups: {})", groups.join(" "));
    }
    Ok(entries)
}

/// One executed suite cell: the rep-0 record (simulated metrics are
/// identical across reps — the engine is deterministic) plus the median
/// host wall-time across reps.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub id: String,
    pub group: String,
    pub record: RunRecord,
    pub wall_ms: f64,
}

/// An executed (possibly filtered) suite.
#[derive(Clone, Debug)]
pub struct SuiteRun {
    pub reps: usize,
    pub filter: String,
    pub cells: Vec<CellResult>,
    /// Sum of the per-cell median wall times.
    pub total_wall_ms: f64,
}

/// Stable cell identity: every pinned axis, so any change to the suite
/// definition shows up as added/removed ids rather than silently
/// comparing different experiments under one name.
pub fn cell_id(group: &str, spec: &crate::spec::RunSpec) -> String {
    format!(
        "{group}/{}/{}/{}/{}/t{}/{}/s{}",
        spec.bench,
        spec.sched.name_sig(),
        spec.bind.name(),
        spec.mem.name_sig(),
        spec.threads,
        spec.topo,
        spec.seed
    )
}

/// Run one suite entry `reps` times (sequentially — wall-time medians
/// want an unloaded machine, not sweep-level parallelism) and fold the
/// repetitions into per-cell results.
pub fn run_entry(session: &Session, entry: &SuiteEntry, reps: usize) -> Result<Vec<CellResult>> {
    let reps = reps.max(1);
    let mut rep_runs = Vec::with_capacity(reps);
    for _ in 0..reps {
        rep_runs.push(session.run_sweep_with(&entry.sweep, 1)?);
    }
    let n = rep_runs[0].records.len();
    let mut cells = Vec::with_capacity(n);
    for i in 0..n {
        let record = rep_runs[0].records[i].clone();
        let mut walls: Vec<f64> = rep_runs.iter().map(|r| r.records[i].stats.wall_ms).collect();
        cells.push(CellResult {
            id: cell_id(&entry.group, &record.spec),
            group: entry.group.clone(),
            wall_ms: median_ms(&mut walls),
            record,
        });
    }
    Ok(cells)
}

/// Run the (filtered) pinned suite.
pub fn run_suite(session: &Session, filter: &str, reps: usize) -> Result<SuiteRun> {
    let mut run = SuiteRun {
        reps: reps.max(1),
        filter: filter.to_string(),
        cells: Vec::new(),
        total_wall_ms: 0.0,
    };
    for entry in filtered(filter)? {
        run.cells.extend(run_entry(session, &entry, reps)?);
    }
    run.total_wall_ms = run.cells.iter().map(|c| c.wall_ms).sum();
    Ok(run)
}

/// The simulated-metric object for one cell — every field deterministic,
/// so two runs of the same suite must produce byte-identical `sim`
/// objects (the CI drift check).
fn sim_json(record: &RunRecord) -> Json {
    let st = &record.stats;
    Json::obj([
        ("makespan", Json::from(st.makespan)),
        ("serial_makespan", Json::from(record.serial_makespan)),
        ("speedup", Json::from(record.speedup)),
        ("tasks", Json::from(st.tasks)),
        ("steals", Json::from(st.steals)),
        ("steal_hops", Json::from(st.mean_steal_hops)),
        ("remote_pct", Json::from(100.0 * st.mem.remote_ratio())),
        ("sim_events", Json::from(st.sim_events)),
        ("lock_wait", Json::from(st.lock_wait_total)),
        ("pushed_home", Json::from(st.pushed_home)),
        ("affinity_hits", Json::from(st.affinity_hits)),
        ("affine_steals", Json::from(st.affine_steals)),
        ("homed_resumes", Json::from(st.homed_resumes)),
        ("batch_steals", Json::from(st.batch_steals)),
        ("tasks_migrated", Json::from(st.tasks_migrated)),
        ("mailbox_hits", Json::from(st.mailbox_hits)),
    ])
}

fn cell_json(c: &CellResult) -> Json {
    let spec = &c.record.spec;
    Json::obj([
        ("id", Json::from(c.id.as_str())),
        ("group", Json::from(c.group.as_str())),
        ("bench", Json::from(spec.bench.as_str())),
        ("size", Json::from(spec.size.name())),
        ("sched", Json::from(spec.sched.name_sig())),
        ("bind", Json::from(spec.bind.name())),
        ("mem", Json::from(spec.mem.name_sig())),
        ("threads", Json::from(spec.threads)),
        ("topo", Json::from(spec.topo.as_str())),
        ("seed", Json::from_u64_lossless(spec.seed)),
        ("sim", sim_json(&c.record)),
        ("wall_ms", Json::from(c.wall_ms)),
        // derived engine-throughput signal: simulated events retired per
        // host second (median wall).  Lives *outside* `sim` — it inherits
        // wall-time noise, so it must never participate in drift checks.
        (
            "events_per_sec",
            if c.wall_ms > 0.0 {
                Json::from(c.record.stats.sim_events as f64 / (c.wall_ms / 1e3))
            } else {
                Json::Null
            },
        ),
    ])
}

impl SuiteRun {
    /// The `BENCH_<n>.json` document.  Object keys emit in sorted order
    /// (the [`Json`] emitter guarantee), so the file is diffable and two
    /// identical runs serialize byte-identically except `wall_ms`.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("schema", Json::from(SCHEMA_VERSION)),
            ("suite", Json::from(SUITE_NAME)),
            ("provenance", Json::from(format!("numanos {}", env!("CARGO_PKG_VERSION")))),
            ("reps", Json::from(self.reps)),
            ("filter", Json::from(self.filter.as_str())),
            ("cells", Json::Arr(self.cells.iter().map(cell_json).collect())),
            (
                "harness",
                Json::obj([
                    ("cells", Json::from(self.cells.len())),
                    ("total_wall_ms", Json::from(self.total_wall_ms)),
                ]),
            ),
        ])
    }
}

// ---------------------------------------------------------------------
// Report parsing: the read side of the schema, used by `--compare` and
// by CI's schema validation.
// ---------------------------------------------------------------------

/// A parsed cell.  `sim`/`wall_ms` are `None` when the file records
/// `null` — the committed-placeholder state before any toolchain has
/// filled in measurements; comparisons treat such cells as *unmeasured*
/// rather than drifted.
#[derive(Clone, Debug, PartialEq)]
pub struct CellReport {
    pub id: String,
    pub group: String,
    pub sim: Option<BTreeMap<String, f64>>,
    pub wall_ms: Option<f64>,
}

/// A parsed `BENCH_*.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct SuiteReport {
    pub suite: String,
    pub reps: u64,
    pub filter: String,
    pub cells: Vec<CellReport>,
    pub total_wall_ms: Option<f64>,
}

impl SuiteReport {
    /// Parse and validate one report.  Every schema rule the emitter
    /// relies on is enforced here, so CI can validate an emitted file by
    /// round-tripping it through this function.
    pub fn from_json(j: &Json) -> Result<SuiteReport> {
        let schema = j.get("schema").and_then(Json::as_u64).context("report needs 'schema'")?;
        if schema != SCHEMA_VERSION {
            bail!("unsupported bench schema {schema} (this build reads {SCHEMA_VERSION})");
        }
        let suite = j
            .get("suite")
            .and_then(Json::as_str)
            .context("report needs a string 'suite'")?
            .to_string();
        let reps = j.get("reps").and_then(Json::as_u64).context("report needs 'reps'")?;
        let filter = j
            .get("filter")
            .and_then(Json::as_str)
            .context("report needs a string 'filter'")?
            .to_string();
        let raw_cells = j.get("cells").and_then(Json::as_arr).context("report needs 'cells'")?;
        let mut cells = Vec::with_capacity(raw_cells.len());
        for (i, c) in raw_cells.iter().enumerate() {
            cells.push(cell_from_json(c).with_context(|| format!("cell {i}"))?);
        }
        let total_wall_ms = match j.get("harness").and_then(|h| h.get("total_wall_ms")) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_num().context("harness.total_wall_ms must be a number")?),
        };
        Ok(SuiteReport { suite, reps, filter, cells, total_wall_ms })
    }

    pub fn parse(text: &str) -> Result<SuiteReport> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn load(path: &std::path::Path) -> Result<SuiteReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

fn cell_from_json(c: &Json) -> Result<CellReport> {
    let id = c.get("id").and_then(Json::as_str).context("cell needs a string 'id'")?.to_string();
    let group = c
        .get("group")
        .and_then(Json::as_str)
        .context("cell needs a string 'group'")?
        .to_string();
    let sim = match c.get("sim").context("cell needs 'sim' (object or null)")? {
        Json::Null => None,
        Json::Obj(map) => {
            let mut metrics = BTreeMap::new();
            for (k, v) in map {
                let n = v
                    .as_num()
                    .with_context(|| format!("sim metric '{k}' must be a number"))?;
                metrics.insert(k.clone(), n);
            }
            Some(metrics)
        }
        other => bail!("cell 'sim' must be an object or null, got {other:?}"),
    };
    let wall_ms = match c.get("wall_ms").context("cell needs 'wall_ms' (number or null)")? {
        Json::Null => None,
        v => Some(v.as_num().context("cell 'wall_ms' must be a number")?),
    };
    Ok(CellReport { id, group, sim, wall_ms })
}

/// A committed-placeholder report: every suite cell present with `sim`
/// and `wall_ms` null, so the file's *shape* (ids, groups, coverage) is
/// pinned in-repo even before a toolchain records measurements.  The
/// compare side reads null cells as unmeasured baselines.
pub fn placeholder_json() -> Result<Json> {
    let mut cells = Vec::new();
    for entry in suite() {
        for spec in entry.sweep.cells()? {
            let id = cell_id(&entry.group, &spec);
            cells.push(Json::obj([
                ("id", Json::from(id)),
                ("group", Json::from(entry.group.as_str())),
                ("bench", Json::from(spec.bench.as_str())),
                ("size", Json::from(spec.size.name())),
                ("sched", Json::from(spec.sched.name_sig())),
                ("bind", Json::from(spec.bind.name())),
                ("mem", Json::from(spec.mem.name_sig())),
                ("threads", Json::from(spec.threads)),
                ("topo", Json::from(spec.topo.as_str())),
                ("seed", Json::from_u64_lossless(spec.seed)),
                ("sim", Json::Null),
                ("wall_ms", Json::Null),
                ("events_per_sec", Json::Null),
            ]));
        }
    }
    let n = cells.len();
    Ok(Json::obj([
        ("schema", Json::from(SCHEMA_VERSION)),
        ("suite", Json::from(SUITE_NAME)),
        ("provenance", Json::from("placeholder: no toolchain run recorded yet")),
        ("reps", Json::from(0u64)),
        ("filter", Json::from("")),
        ("cells", Json::Arr(cells)),
        (
            "harness",
            Json::obj([("cells", Json::from(n)), ("total_wall_ms", Json::Null)]),
        ),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_is_pinned_and_complete() {
        let entries = suite();
        // smoke + 9 figures + 4 ablation topologies + 6 perf + 3 perf-xl
        assert_eq!(entries.len(), 1 + 9 + 4 + 6 + 3);
        let total: usize = entries.iter().map(|e| e.sweep.cell_count()).sum();
        // 2 smoke + 6×6 stock-figure + 3×3 numa-figure + 4×4 ablation
        //   + 6 perf + 3 perf-xl
        assert_eq!(total, 2 + 36 + 9 + 16 + 6 + 3);
        for e in &entries {
            for cell in e.sweep.cells().unwrap() {
                cell.validate().unwrap();
                assert_eq!(cell.seed, SUITE_SEED);
            }
        }
        let groups: Vec<&str> = entries.iter().map(|e| e.group.as_str()).collect();
        assert!(groups.contains(&"smoke"));
        assert!(groups.contains(&"fig5") && groups.contains(&"fig15"));
        assert_eq!(groups.iter().filter(|g| **g == "ablation").count(), 4);
        assert_eq!(groups.iter().filter(|g| **g == "perf").count(), 6);
        assert_eq!(groups.iter().filter(|g| **g == "perf-xl").count(), 3);
        // every perf-xl cell really is the XL size on a depth-first sched
        for e in entries.iter().filter(|e| e.group == "perf-xl") {
            for cell in e.sweep.cells().unwrap() {
                assert_eq!(cell.size, Size::XL, "{}", e.sweep.id);
            }
        }
    }

    #[test]
    fn filter_selects_by_group_and_id() {
        assert_eq!(filtered("smoke").unwrap().len(), 1);
        assert_eq!(filtered("ablation").unwrap().len(), 4);
        assert_eq!(filtered("ablation-tile16").unwrap().len(), 1);
        assert_eq!(filtered("fig1").unwrap().len(), 4, "fig10 + fig13..fig15");
        assert_eq!(filtered("").unwrap().len(), suite().len());
        let err = format!("{:#}", filtered("bogus").unwrap_err());
        assert!(err.contains("matches no suite entries"), "{err}");
    }

    #[test]
    fn placeholder_covers_the_full_suite_and_parses() {
        let j = placeholder_json().unwrap();
        let report = SuiteReport::from_json(&j).unwrap();
        assert_eq!(report.suite, SUITE_NAME);
        assert_eq!(report.cells.len(), 72);
        assert!(report.cells.iter().all(|c| c.sim.is_none() && c.wall_ms.is_none()));
        assert!(report.total_wall_ms.is_none());
        // ids are unique — a duplicated id would silently merge cells
        let mut ids: Vec<&str> = report.cells.iter().map(|c| c.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 72);
    }

    #[test]
    fn report_parser_rejects_malformed_documents() {
        for bad in [
            r#"{"suite": "numanos-pinned-v1"}"#,
            r#"{"schema": 99, "suite": "s", "reps": 1, "filter": "", "cells": []}"#,
            r#"{"schema": 1, "reps": 1, "filter": "", "cells": []}"#,
            r#"{"schema": 1, "suite": "s", "reps": 1, "filter": "", "cells": [{"id": "a"}]}"#,
            r#"{"schema": 1, "suite": "s", "reps": 1, "filter": "",
                "cells": [{"id": "a", "group": "g", "sim": 7, "wall_ms": null}]}"#,
            r#"{"schema": 1, "suite": "s", "reps": 1, "filter": "",
                "cells": [{"id": "a", "group": "g", "sim": {"x": "y"}, "wall_ms": null}]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(SuiteReport::from_json(&j).is_err(), "{bad}");
        }
    }
}
