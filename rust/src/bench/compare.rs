//! Delta reports between two `BENCH_*.json` files.
//!
//! The comparison is asymmetric by design: the **simulated** metrics are
//! deterministic, so any difference is real drift (and a makespan
//! increase beyond the threshold is a regression that fails the run);
//! the **wall-time** metrics measure the host, so they only ever warn.
//! Baseline cells whose `sim` is `null` (the committed placeholder
//! before any toolchain run) classify as *unmeasured* instead of
//! drifted, and baseline cells missing from a `--filter`ed run are
//! reported but never fail.

use anyhow::{bail, Result};

use crate::bench::{CellReport, SuiteReport};
use crate::serde::Json;

/// Thresholds and failure policy for one comparison.
#[derive(Clone, Debug)]
pub struct CompareOptions {
    /// Makespan increase (percent) beyond which a cell is a regression.
    pub max_regress_pct: f64,
    /// Absolute wall-time delta (percent) beyond which a cell warns.
    pub wall_warn_pct: f64,
    /// Fail on *any* simulated-metric difference, not just regressions
    /// (the CI determinism check: two runs of a deterministic suite).
    pub fail_on_drift: bool,
    /// Report only — never fail, whatever the deltas say.
    pub warn_only: bool,
}

impl Default for CompareOptions {
    fn default() -> Self {
        Self { max_regress_pct: 0.0, wall_warn_pct: 20.0, fail_on_drift: false, warn_only: false }
    }
}

/// Per-cell classification, in rendering order of severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Simulated metrics byte-identical.
    Same,
    /// Drifted with a strictly smaller makespan.
    Improved,
    /// Drifted within the regression threshold.
    Drift,
    /// Makespan grew past `max_regress_pct`.
    Regress,
    /// Cell absent from the baseline file.
    New,
    /// Baseline (or candidate) has `sim: null` — nothing to compare.
    Unmeasured,
}

impl Status {
    pub fn label(self) -> &'static str {
        match self {
            Status::Same => "=",
            Status::Improved => "improved",
            Status::Drift => "drift",
            Status::Regress => "REGRESS",
            Status::New => "new",
            Status::Unmeasured => "unmeasured",
        }
    }
}

/// One cell's delta row.
#[derive(Clone, Debug)]
pub struct Delta {
    pub id: String,
    pub status: Status,
    pub old_makespan: Option<f64>,
    pub new_makespan: Option<f64>,
    pub makespan_delta_pct: Option<f64>,
    pub wall_delta_pct: Option<f64>,
    /// Names of the simulated metrics that changed.
    pub drifted_metrics: Vec<String>,
}

/// A rendered-and-classified comparison.
#[derive(Clone, Debug)]
pub struct Comparison {
    pub deltas: Vec<Delta>,
    /// Baseline cells the candidate run did not execute (filtered runs).
    pub absent: usize,
    /// Geometric-mean makespan ratio (new/old) over measured pairs.
    pub geomean_ratio: Option<f64>,
    pub old_wall: Option<f64>,
    pub new_wall: Option<f64>,
    pub regressions: usize,
    pub drifted: usize,
    pub unmeasured: usize,
    pub wall_warnings: usize,
    wall_warn_pct: f64,
}

/// Compare `new` against the `old` baseline.  Iterates the candidate's
/// cells in file order; refuses to compare across suite identities.
pub fn compare(old: &SuiteReport, new: &SuiteReport, opts: &CompareOptions) -> Result<Comparison> {
    if old.suite != new.suite {
        bail!("suite mismatch: baseline is '{}', candidate is '{}'", old.suite, new.suite);
    }
    let mut deltas = Vec::with_capacity(new.cells.len());
    let mut regressions = 0;
    let mut drifted = 0;
    let mut unmeasured = 0;
    let mut wall_warnings = 0;
    let mut log_ratio_sum = 0.0;
    let mut log_ratio_n = 0usize;
    for cell in &new.cells {
        let old_cell = old.cells.iter().find(|c| c.id == cell.id);
        let mut d = classify(old_cell, cell, opts);
        match d.status {
            Status::Regress => {
                regressions += 1;
                drifted += 1;
            }
            Status::Improved | Status::Drift => drifted += 1,
            Status::New => drifted += 1,
            Status::Unmeasured => unmeasured += 1,
            Status::Same => {}
        }
        if let (Some(a), Some(b)) = (d.old_makespan, d.new_makespan) {
            if a > 0.0 && b > 0.0 {
                log_ratio_sum += (b / a).ln();
                log_ratio_n += 1;
            }
        }
        if d.wall_delta_pct.map(|w| w.abs() > opts.wall_warn_pct).unwrap_or(false) {
            wall_warnings += 1;
        }
        d.drifted_metrics.sort();
        deltas.push(d);
    }
    let absent = old.cells.iter().filter(|c| !new.cells.iter().any(|n| n.id == c.id)).count();
    Ok(Comparison {
        deltas,
        absent,
        geomean_ratio: (log_ratio_n > 0).then(|| (log_ratio_sum / log_ratio_n as f64).exp()),
        old_wall: old.total_wall_ms,
        new_wall: new.total_wall_ms,
        regressions,
        drifted,
        unmeasured,
        wall_warnings,
        wall_warn_pct: opts.wall_warn_pct,
    })
}

fn classify(old: Option<&CellReport>, new: &CellReport, opts: &CompareOptions) -> Delta {
    let mut d = Delta {
        id: new.id.clone(),
        status: Status::Same,
        old_makespan: None,
        new_makespan: new.sim.as_ref().and_then(|s| s.get("makespan").copied()),
        makespan_delta_pct: None,
        wall_delta_pct: None,
        drifted_metrics: Vec::new(),
    };
    let Some(old) = old else {
        d.status = Status::New;
        return d;
    };
    d.old_makespan = old.sim.as_ref().and_then(|s| s.get("makespan").copied());
    if let (Some(a), Some(b)) = (old.wall_ms, new.wall_ms) {
        if a > 0.0 {
            d.wall_delta_pct = Some(100.0 * (b - a) / a);
        }
    }
    let (Some(old_sim), Some(new_sim)) = (&old.sim, &new.sim) else {
        d.status = Status::Unmeasured;
        return d;
    };
    for key in old_sim.keys().chain(new_sim.keys()) {
        if old_sim.get(key) != new_sim.get(key) && !d.drifted_metrics.iter().any(|k| k == key) {
            d.drifted_metrics.push(key.clone());
        }
    }
    if let (Some(a), Some(b)) = (d.old_makespan, d.new_makespan) {
        if a > 0.0 {
            d.makespan_delta_pct = Some(100.0 * (b - a) / a);
        }
    }
    d.status = if d.drifted_metrics.is_empty() {
        Status::Same
    } else if d.makespan_delta_pct.map(|p| p > opts.max_regress_pct).unwrap_or(false) {
        Status::Regress
    } else if d.makespan_delta_pct.map(|p| p < 0.0).unwrap_or(false) {
        Status::Improved
    } else {
        Status::Drift
    };
    d
}

impl Comparison {
    /// Does this comparison fail under `opts`?  Regressions always fail;
    /// with `--fail-on-drift`, any simulated difference (including cells
    /// absent from the baseline) fails; `--warn-only` never fails.
    pub fn failed(&self, opts: &CompareOptions) -> bool {
        if opts.warn_only {
            return false;
        }
        self.regressions > 0 || (opts.fail_on_drift && self.drifted > 0)
    }

    /// The human-readable per-benchmark delta table plus aggregate line.
    pub fn render(&self) -> String {
        let id_w = self.deltas.iter().map(|d| d.id.len()).max().unwrap_or(4).max(4);
        let mut out = String::new();
        out.push_str(&format!(
            "{:<id_w$}  {:>12}  {:>12}  {:>8}  {:>9}  status\n",
            "cell", "old mkspan", "new mkspan", "sim d%", "wall d%"
        ));
        for d in &self.deltas {
            let fmt_m = |m: Option<f64>| m.map(|v| format!("{v:.0}")).unwrap_or_else(|| "-".into());
            let fmt_p = |p: Option<f64>| {
                p.map(|v| format!("{v:+.2}%")).unwrap_or_else(|| "-".into())
            };
            let mut status = d.status.label().to_string();
            if matches!(d.status, Status::Drift | Status::Regress | Status::Improved) {
                status.push_str(&format!(" [{}]", d.drifted_metrics.join(",")));
            }
            if d.wall_delta_pct.map(|w| w.abs() > self.wall_warn_pct).unwrap_or(false) {
                status.push_str(" wall!");
            }
            out.push_str(&format!(
                "{:<id_w$}  {:>12}  {:>12}  {:>8}  {:>9}  {status}\n",
                d.id,
                fmt_m(d.old_makespan),
                fmt_m(d.new_makespan),
                fmt_p(d.makespan_delta_pct),
                fmt_p(d.wall_delta_pct),
            ));
        }
        if self.absent > 0 {
            out.push_str(&format!(
                "({} baseline cell(s) not in this run — filtered?)\n",
                self.absent
            ));
        }
        let agg = match self.geomean_ratio {
            Some(r) => format!("geomean makespan ratio {:.4} ({:+.2}%)", r, 100.0 * (r - 1.0)),
            None => "geomean makespan ratio - (no measured pairs)".into(),
        };
        let wall = match (self.old_wall, self.new_wall) {
            (Some(a), Some(b)) if a > 0.0 => {
                format!("suite wall {a:.1} ms -> {b:.1} ms ({:+.1}%)", 100.0 * (b - a) / a)
            }
            _ => "suite wall - (unmeasured)".into(),
        };
        out.push_str(&format!("aggregate: {agg}, {wall}\n"));
        out.push_str(&format!(
            "result: {} regression(s), {} drifted, {} unmeasured, {} wall warning(s)\n",
            self.regressions, self.drifted, self.unmeasured, self.wall_warnings
        ));
        out
    }

    /// Machine-readable delta document (for `--compare --json`).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .deltas
            .iter()
            .map(|d| {
                let opt = |v: Option<f64>| v.map(Json::from).unwrap_or(Json::Null);
                Json::obj([
                    ("id", Json::from(d.id.as_str())),
                    ("status", Json::from(d.status.label())),
                    ("old_makespan", opt(d.old_makespan)),
                    ("new_makespan", opt(d.new_makespan)),
                    ("sim_delta_pct", opt(d.makespan_delta_pct)),
                    ("wall_delta_pct", opt(d.wall_delta_pct)),
                    (
                        "drifted_metrics",
                        Json::Arr(d.drifted_metrics.iter().map(|m| Json::from(m.as_str())).collect()),
                    ),
                ])
            })
            .collect();
        Json::obj([
            ("cells", Json::Arr(rows)),
            ("absent", Json::from(self.absent)),
            (
                "geomean_ratio",
                self.geomean_ratio.map(Json::from).unwrap_or(Json::Null),
            ),
            ("regressions", Json::from(self.regressions)),
            ("drifted", Json::from(self.drifted)),
            ("unmeasured", Json::from(self.unmeasured)),
            ("wall_warnings", Json::from(self.wall_warnings)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(cells: &[(&str, Option<&[(&str, f64)]>, Option<f64>)]) -> SuiteReport {
        SuiteReport {
            suite: crate::bench::SUITE_NAME.to_string(),
            reps: 1,
            filter: String::new(),
            cells: cells
                .iter()
                .map(|(id, sim, wall)| CellReport {
                    id: id.to_string(),
                    group: id.split('/').next().unwrap().to_string(),
                    sim: sim.map(|kv| {
                        kv.iter().map(|(k, v)| (k.to_string(), *v)).collect()
                    }),
                    wall_ms: *wall,
                })
                .collect(),
            total_wall_ms: None,
        }
    }

    const SIM_A: &[(&str, f64)] = &[("makespan", 1000.0), ("steals", 4.0)];
    const SIM_SLOWER: &[(&str, f64)] = &[("makespan", 1100.0), ("steals", 4.0)];
    const SIM_FASTER: &[(&str, f64)] = &[("makespan", 900.0), ("steals", 7.0)];

    #[test]
    fn statuses_cover_the_matrix() {
        let old = report(&[
            ("g/same", Some(SIM_A), Some(10.0)),
            ("g/slower", Some(SIM_A), Some(10.0)),
            ("g/faster", Some(SIM_A), None),
            ("g/null", None, None),
            ("g/gone", Some(SIM_A), None),
        ]);
        let new = report(&[
            ("g/same", Some(SIM_A), Some(100.0)),
            ("g/slower", Some(SIM_SLOWER), Some(10.0)),
            ("g/faster", Some(SIM_FASTER), None),
            ("g/null", Some(SIM_A), Some(5.0)),
            ("g/fresh", Some(SIM_A), None),
        ]);
        let cmp = compare(&old, &new, &CompareOptions::default()).unwrap();
        let by_id = |id: &str| cmp.deltas.iter().find(|d| d.id == id).unwrap();
        assert_eq!(by_id("g/same").status, Status::Same);
        assert_eq!(by_id("g/slower").status, Status::Regress);
        assert_eq!(by_id("g/slower").drifted_metrics, vec!["makespan".to_string()]);
        assert_eq!(by_id("g/faster").status, Status::Improved);
        assert_eq!(by_id("g/null").status, Status::Unmeasured);
        assert_eq!(by_id("g/fresh").status, Status::New);
        assert_eq!(cmp.absent, 1, "g/gone");
        assert_eq!((cmp.regressions, cmp.unmeasured), (1, 1));
        // +900% wall on g/same warns; nothing else has both walls
        assert_eq!(cmp.wall_warnings, 1);
        let table = cmp.render();
        assert!(table.contains("REGRESS") && table.contains("wall!"), "{table}");
    }

    #[test]
    fn failure_policy_matches_the_flags() {
        let old = report(&[("g/a", Some(SIM_A), None)]);
        let slower = report(&[("g/a", Some(SIM_SLOWER), None)]);
        let faster = report(&[("g/a", Some(SIM_FASTER), None)]);
        let opts = CompareOptions::default();
        // any makespan increase regresses at the default 0% threshold
        assert!(compare(&old, &slower, &opts).unwrap().failed(&opts));
        // a 10% increase passes a 15% threshold…
        let loose = CompareOptions { max_regress_pct: 15.0, ..opts.clone() };
        assert!(!compare(&old, &slower, &loose).unwrap().failed(&loose));
        // …but still counts as drift under --fail-on-drift
        let strict = CompareOptions { max_regress_pct: 15.0, fail_on_drift: true, ..opts.clone() };
        assert!(compare(&old, &slower, &strict).unwrap().failed(&strict));
        // improvements pass by default, fail the drift check
        assert!(!compare(&old, &faster, &opts).unwrap().failed(&opts));
        let drift = CompareOptions { fail_on_drift: true, ..opts.clone() };
        assert!(compare(&old, &faster, &drift).unwrap().failed(&drift));
        // --warn-only silences everything
        let warn = CompareOptions { warn_only: true, fail_on_drift: true, ..opts };
        assert!(!compare(&old, &slower, &warn).unwrap().failed(&warn));
    }

    #[test]
    fn identical_reports_are_clean() {
        let r = report(&[("g/a", Some(SIM_A), Some(5.0)), ("g/b", Some(SIM_FASTER), Some(9.0))]);
        let opts = CompareOptions { fail_on_drift: true, ..CompareOptions::default() };
        let cmp = compare(&r, &r, &opts).unwrap();
        assert!(cmp.deltas.iter().all(|d| d.status == Status::Same));
        assert!(!cmp.failed(&opts));
        assert_eq!(cmp.geomean_ratio, Some(1.0));
    }

    #[test]
    fn unmeasured_placeholder_baseline_never_fails() {
        // the committed pre-toolchain BENCH_6.json: sim null everywhere
        let old = report(&[("g/a", None, None), ("g/b", None, None)]);
        let new = report(&[("g/a", Some(SIM_A), Some(4.0)), ("g/b", Some(SIM_FASTER), None)]);
        let opts = CompareOptions { fail_on_drift: true, ..CompareOptions::default() };
        let cmp = compare(&old, &new, &opts).unwrap();
        assert_eq!(cmp.unmeasured, 2);
        assert_eq!(cmp.drifted, 0);
        assert!(!cmp.failed(&opts));
    }

    #[test]
    fn suite_identity_mismatch_is_refused() {
        let a = report(&[]);
        let mut b = report(&[]);
        b.suite = "other-suite".into();
        assert!(compare(&a, &b, &CompareOptions::default()).is_err());
    }
}
