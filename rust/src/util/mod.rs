//! Deterministic PRNG + small helpers shared across the crate.
//!
//! The simulator must be bit-reproducible under a fixed seed (the paper
//! takes best-of-50 *wall-clock* runs; we instead expose seeds so every
//! figure regenerates identically), so all randomness flows through
//! [`SplitMix64`] — no global RNG, no OS entropy on the request path.

/// SplitMix64 PRNG (Steele et al.) — tiny, fast, good enough for victim
/// selection and workload shape generation; NOT cryptographic.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`; `n` must be non-zero.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for simulator purposes
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }
}

/// Simulated time in picoseconds (integer for exact determinism).
pub type Time = u64;

/// One nanosecond in [`Time`] units.
pub const NS: Time = 1_000;
/// One microsecond.
pub const US: Time = 1_000_000;
/// One millisecond.
pub const MS: Time = 1_000_000_000;

/// Compact float formatting: integral values print without a trailing
/// `.0` (`1` not `1.0`), everything else as plain `{v}` — the form the
/// CLI accepts back for cost overrides and scheduler parameters.
pub fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Pretty-print a simulated duration.
pub fn fmt_time(t: Time) -> String {
    if t >= MS {
        format!("{:.3} ms", t as f64 / MS as f64)
    } else if t >= US {
        format!("{:.3} us", t as f64 / US as f64)
    } else {
        format!("{:.1} ns", t as f64 / NS as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_range_in_bounds() {
        let mut r = SplitMix64::new(7);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = SplitMix64::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(500).contains("ns"));
        assert!(fmt_time(5 * US).contains("us"));
        assert!(fmt_time(5 * MS).contains("ms"));
    }
}
