//! `floorplan` — VLSI cell placement by branch-and-bound (BOTS
//! `floorplan.c`).
//!
//! An irregular, pruned search tree over a small shared board — modest
//! data, lots of short tasks (paper Fig 5: work-stealing policies win
//! beyond 6 cores; NUMA allocation adds ~3%).
//!
//! The tree shape is deterministic-pseudo-random: each node tries up to
//! `max_branch` candidate placements; a candidate survives pruning with a
//! probability that decays with depth (hash-driven), mimicking the bound
//! tightening as the board fills.

use crate::bots::mix;
use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

pub struct Floorplan {
    depth: u32,
    max_branch: u32,
    seed: u64,
    /// shared board description (cells catalogue) — master-touched
    board: Region,
}

impl Floorplan {
    pub fn new(size: Size, seed: u64) -> Self {
        let (depth, max_branch) = match size {
            Size::Small => (6, 5),
            Size::Medium => (8, 6),
            Size::Large | Size::XL => (9, 6),
        };
        Self { depth, max_branch, seed, board: Region::EMPTY }
    }

    /// How many candidates survive pruning at (node, depth).
    fn branches(&self, node: u64, depth: u32) -> u32 {
        if depth >= self.depth {
            return 0;
        }
        let h = mix(node.wrapping_add(self.seed), depth as u64 + 1);
        // survival rate decays with depth: ~85% at the root, ~35% deep
        let keep_pct = 85u64.saturating_sub(6 * depth as u64);
        let mut count = 0;
        for c in 0..self.max_branch {
            if mix(h, c as u64) % 100 < keep_pct {
                count += 1;
            }
        }
        count
    }
}

impl Workload for Floorplan {
    fn name(&self) -> &'static str {
        "floorplan"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.board = mem.alloc(8 * 1024); // cells catalogue
        mem.first_touch(master_core, self.board, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(0, [1, 0, 0, 0]) // node id 1, depth 0
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        let node = desc.args[0] as u64;
        let depth = desc.args[1] as u32;
        // evaluate this placement: read the shared catalogue, copy the
        // board state (small private write), compute the bound
        ctx.read(self.board);
        ctx.compute(1_500 + (mix(node, 17) % 1_500));
        let b = self.branches(node, depth);
        if b == 0 {
            return; // pruned / leaf
        }
        // children are hinted with the shared catalogue they all read —
        // the OpenMP `affinity(board)` annotation.  Purely advisory: the
        // 8 KB board sits below the placement schedulers' default
        // min-hint floor, so stock policies behave exactly as before.
        for c in 0..b {
            ctx.spawn_on(
                TaskDesc::new(
                    0,
                    [(node * self.max_branch as u64 + c as u64 + 1) as i64, depth as i64 + 1, 0, 0],
                ),
                self.board,
            );
        }
        ctx.taskwait();
        ctx.compute(300); // fold children's best bound
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn tree_is_irregular_but_deterministic() {
        let f1 = Floorplan::new(Size::Small, 42);
        let f2 = Floorplan::new(Size::Small, 42);
        let f3 = Floorplan::new(Size::Small, 43);
        let sig = |f: &Floorplan| -> Vec<u32> { (0..50).map(|n| f.branches(n, 2)).collect() };
        assert_eq!(sig(&f1), sig(&f2));
        assert_ne!(sig(&f1), sig(&f3));
        // irregular: not all nodes have the same branching
        let s = sig(&f1);
        assert!(s.iter().any(|&b| b != s[0]));
    }

    #[test]
    fn task_count_deterministic_across_policies() {
        let rt = Runtime::paper_testbed();
        let mut counts = Vec::new();
        for &p in &[Policy::Serial, Policy::BreadthFirst, Policy::CilkBased, Policy::Dfwsrpt] {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let mut w = Floorplan::new(Size::Small, 7);
            let s = rt.run(&mut w, p, BindPolicy::Linear, threads, 7, None).unwrap();
            counts.push(s.tasks);
        }
        assert!(counts.windows(2).all(|c| c[0] == c[1]), "{counts:?}");
        assert!(counts[0] > 100, "tree too small: {}", counts[0]);
    }

    #[test]
    fn work_stealing_scales() {
        let rt = Runtime::paper_testbed();
        let mut ws = Floorplan::new(Size::Small, 3);
        let serial = rt.run_serial(&mut ws, 1).unwrap();
        let mut wp = Floorplan::new(Size::Small, 3);
        let par = rt.run(&mut wp, Policy::CilkBased, BindPolicy::Linear, 8, 3, None).unwrap();
        let sp = serial.makespan as f64 / par.makespan as f64;
        assert!(sp > 3.0, "floorplan speedup {sp}");
    }
}
