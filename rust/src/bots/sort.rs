//! `sort` — cilksort-style parallel mergesort (BOTS `sort.c`).
//!
//! High memory utilization (paper: 8.5 GB with the large set) and a deep
//! merge tree — the second NUMA-sensitive workload (Figs 9, 14).
//!
//! Decomposition: `Sort(off, n, depth)` recursively halves down to a
//! serial leaf sort; after the halves complete, the post phase spawns
//! `Merge` chunk tasks that read both sorted halves from the source buffer
//! and write the destination.  Buffers ping-pong by depth parity (X→Y→X…),
//! which reproduces the BOTS data flow: every level streams the whole
//! array once.
//!
//! Leaf tasks carry `Action::Kernel(SORT_LEAF)`: PJRT mode sorts one real
//! 1024-key vector through the bitonic-network artifact and verifies it.

use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::runtime::{Buf, ExecEngine};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

const K_SORT: u16 = 0;
const K_MERGE: u16 = 1;

pub const SORT_LEAF_KERNEL: u64 = 2;

/// Bytes per key (i32/f32 keys as in BOTS).
const ELEM: u64 = 4;

pub struct Sort {
    n: u64,
    leaf: u64,
    chunk: u64,
    x: Region,
    y: Region,
    real_in: Vec<f32>,
    real_out: Option<Vec<f32>>,
}

impl Sort {
    pub fn new(size: Size) -> Self {
        let (n, leaf, chunk) = match size {
            Size::Small => (1 << 15, 1 << 10, 1 << 10),
            Size::Medium => (1 << 21, 1 << 10, 1 << 10),
            Size::Large => (1 << 23, 1 << 11, 1 << 11),
            // 1,048,575 tasks (the merge-tree recurrence below) over
            // 2 x 64 MiB buffers — the million-task memory-bound cell
            Size::XL => (1 << 24, 1 << 9, 1 << 8),
        };
        Self::with_params(n, leaf, chunk)
    }

    pub fn with_params(n: u64, leaf: u64, chunk: u64) -> Self {
        assert!(n.is_power_of_two() && leaf.is_power_of_two());
        Self {
            n,
            leaf,
            chunk,
            x: Region::EMPTY,
            y: Region::EMPTY,
            real_in: Vec::new(),
            real_out: None,
        }
    }

    /// Source/destination buffers for a node at `depth` (ping-pong).
    fn buffers(&self, depth: u64) -> (Region, Region) {
        if depth % 2 == 0 {
            (self.x, self.y)
        } else {
            (self.y, self.x)
        }
    }

    fn log2(x: u64) -> u64 {
        63 - x.leading_zeros() as u64
    }
}

impl Workload for Sort {
    fn name(&self) -> &'static str {
        "sort"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.x = mem.alloc(self.n * ELEM);
        self.y = mem.alloc(self.n * ELEM);
        // master fills the input array (first touch); the scratch buffer
        // is touched lazily by whichever worker merges into it first —
        // exactly the asymmetry that makes NUMA stealing pay off here.
        let t = mem.first_touch(master_core, self.x, 0);
        self.real_in = (0..1024).map(|i| ((i * 193 + 71) % 1009) as f32).collect();
        t
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(K_SORT, [0, self.n as i64, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        let off = desc.args[0] as u64;
        let n = desc.args[1] as u64;
        let depth = desc.args[2] as u64;
        match desc.kind {
            K_SORT => {
                let (src, _dst) = self.buffers(depth);
                if n <= self.leaf {
                    let seg = src.slice(off * ELEM, n * ELEM);
                    ctx.read(seg);
                    ctx.kernel(SORT_LEAF_KERNEL);
                    ctx.compute(4 * n * Self::log2(n));
                    ctx.write(seg);
                    // leaves at odd depth must land in the buffer their
                    // parent merges from; model the copy-through
                    return;
                }
                let h = n / 2;
                // children sort in the *other* buffer pair orientation:
                // they sort src in place, we merge src -> dst.  Affinity:
                // each child sorts its half of the child-depth buffer.
                let (child_src, _) = self.buffers(depth + 1);
                ctx.spawn_on(
                    TaskDesc::new(K_SORT, [off as i64, h as i64, depth as i64 + 1, 0]),
                    child_src.slice(off * ELEM, h * ELEM),
                );
                ctx.spawn_on(
                    TaskDesc::new(K_SORT, [(off + h) as i64, h as i64, depth as i64 + 1, 0]),
                    child_src.slice((off + h) * ELEM, h * ELEM),
                );
                ctx.taskwait();
                let chunks = (n / self.chunk).max(1);
                let c = n / chunks;
                for i in 0..chunks {
                    // the chunk's low-half read slice (mirrors K_MERGE's `a`)
                    let read = child_src
                        .slice((off + (i * c / 2).min(h - c / 2)) * ELEM, c / 2 * ELEM);
                    ctx.spawn_on(
                        TaskDesc::new(K_MERGE, [off as i64, n as i64, depth as i64, i as i64]),
                        read,
                    );
                }
            }
            K_MERGE => {
                // children sorted at depth+1, i.e. in buffer(depth+1).0 = our dst?
                // ping-pong: merge from the children's buffer into ours.
                let (child_src, _) = self.buffers(depth + 1);
                let (our_src, _) = self.buffers(depth);
                let h = n / 2;
                let chunks = (n / self.chunk).max(1);
                let c = n / chunks;
                let i = desc.args[3] as u64;
                // a binary merge-split chunk reads c/2 from each half (on
                // average) and writes c contiguous output keys
                let a = child_src.slice((off + (i * c / 2).min(h - c / 2)) * ELEM, c / 2 * ELEM);
                let b = child_src
                    .slice((off + h + (i * c / 2).min(h - c / 2)) * ELEM, c / 2 * ELEM);
                let out = our_src.slice((off + i * c) * ELEM, c * ELEM);
                ctx.read(a);
                ctx.read(b);
                ctx.compute(3 * c);
                ctx.write(out);
            }
            k => panic!("sort: unknown task kind {k}"),
        }
    }

    fn run_kernel(&mut self, tag: u64, exec: &mut ExecEngine) -> anyhow::Result<()> {
        if tag != SORT_LEAF_KERNEL || self.real_out.is_some() {
            return Ok(());
        }
        let buf = Buf::f32(self.real_in.clone(), &[1024]);
        self.real_out = Some(exec.call1("sort_f32_1024", &[buf])?);
        Ok(())
    }

    fn verify(&self, _exec: &mut ExecEngine) -> anyhow::Result<()> {
        let Some(got) = &self.real_out else {
            anyhow::bail!("sort: no kernel output captured");
        };
        let mut want = self.real_in.clone();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        anyhow::ensure!(got == &want, "sort kernel output not sorted correctly");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn completes_under_all_policies() {
        let rt = Runtime::paper_testbed();
        let mut count = None;
        for &p in Policy::all() {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let mut w = Sort::with_params(1 << 13, 1 << 10, 1 << 9);
            let s = rt.run(&mut w, p, BindPolicy::Linear, threads, 2, None).unwrap();
            match count {
                None => count = Some(s.tasks),
                Some(c) => assert_eq!(s.tasks, c, "{}", p.name()),
            }
        }
    }

    #[test]
    fn merge_tree_task_count() {
        fn count(n: u64, leaf: u64, chunk: u64) -> u64 {
            if n <= leaf {
                1
            } else {
                1 + (n / chunk).max(1) + 2 * count(n / 2, leaf, chunk)
            }
        }
        let rt = Runtime::paper_testbed();
        let (n, leaf, chunk) = (1 << 13, 1 << 10, 1 << 9);
        let mut w = Sort::with_params(n, leaf, chunk);
        let s = rt.run_serial(&mut w, 1).unwrap();
        assert_eq!(s.tasks, count(n, leaf, chunk));
    }

    #[test]
    fn numa_bind_reduces_remote_traffic() {
        let rt = Runtime::paper_testbed();
        let mut a = Sort::new(Size::Small);
        let base = rt.run(&mut a, Policy::WorkFirst, BindPolicy::Linear, 16, 3, None).unwrap();
        let mut b = Sort::new(Size::Small);
        let numa = rt.run(&mut b, Policy::WorkFirst, BindPolicy::NumaAware, 16, 3, None).unwrap();
        // mean hop distance of missed lines must not get worse
        assert!(
            numa.mem.mean_miss_hops() <= base.mem.mean_miss_hops() + 0.25,
            "numa {} vs base {}",
            numa.mem.mean_miss_hops(),
            base.mem.mean_miss_hops()
        );
    }
}
