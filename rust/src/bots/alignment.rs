//! `alignment` — pairwise protein sequence alignment (BOTS
//! `alignment.c`, Myers-Miller over all sequence pairs).
//!
//! All-pairs independent tasks, compute-heavy (O(len²) per pair), with
//! every task reading two master-allocated sequences — a clean test of
//! read-shared data placement.  The BOTS `for` variant distributes the
//! pair loop; we mirror it with a binary split tree over the pair index
//! range.

use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

const K_SPLIT: u16 = 0;
const K_PAIR: u16 = 1;

pub struct Alignment {
    nseq: usize,
    len: u64,
    seqs: Vec<Region>,
}

impl Alignment {
    pub fn new(size: Size) -> Self {
        let (nseq, len) = match size {
            Size::Small => (20, 256),
            Size::Medium => (64, 512),
            Size::Large | Size::XL => (96, 640),
        };
        Self::with_params(nseq, len)
    }

    pub fn with_params(nseq: usize, len: u64) -> Self {
        Self { nseq, len, seqs: Vec::new() }
    }

    pub fn pairs(&self) -> u64 {
        (self.nseq * (self.nseq - 1) / 2) as u64
    }

    /// Map a flat pair index to (i, j), i < j.
    fn unpack(&self, mut p: u64) -> (usize, usize) {
        for i in 0..self.nseq {
            let row = (self.nseq - i - 1) as u64;
            if p < row {
                return (i, i + 1 + p as usize);
            }
            p -= row;
        }
        unreachable!("pair index out of range")
    }
}

impl Workload for Alignment {
    fn name(&self) -> &'static str {
        "alignment"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.seqs = (0..self.nseq).map(|_| mem.alloc(self.len)).collect();
        let mut t = 0;
        for s in &self.seqs {
            t += mem.first_touch(master_core, *s, t);
        }
        t
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(K_SPLIT, [0, self.pairs() as i64, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            K_SPLIT => {
                let lo = desc.args[0] as u64;
                let hi = desc.args[1] as u64;
                ctx.compute(40);
                // every spawn is hinted with the first sequence its
                // sub-range reads — the OpenMP `affinity(seqs[i])`
                // annotation.  Purely advisory: each sequence is far
                // below the placement schedulers' default min-hint
                // floor, so stock policies behave exactly as before.
                if hi - lo > 4 {
                    let mid = (lo + hi) / 2;
                    ctx.spawn_on(
                        TaskDesc::new(K_SPLIT, [lo as i64, mid as i64, 0, 0]),
                        self.seqs[self.unpack(lo).0],
                    );
                    ctx.spawn_on(
                        TaskDesc::new(K_SPLIT, [mid as i64, hi as i64, 0, 0]),
                        self.seqs[self.unpack(mid).0],
                    );
                } else {
                    for p in lo..hi {
                        ctx.spawn_on(
                            TaskDesc::new(K_PAIR, [p as i64, 0, 0, 0]),
                            self.seqs[self.unpack(p).0],
                        );
                    }
                }
            }
            K_PAIR => {
                let (i, j) = self.unpack(desc.args[0] as u64);
                ctx.read(self.seqs[i]);
                ctx.read(self.seqs[j]);
                // O(len^2) dynamic program, ~2 ops per cell at 4/ns
                ctx.compute(self.len * self.len / 2);
            }
            other => panic!("alignment: unknown task kind {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn pair_unpacking_is_bijective() {
        let a = Alignment::with_params(10, 64);
        let mut seen = std::collections::BTreeSet::new();
        for p in 0..a.pairs() {
            let (i, j) = a.unpack(p);
            assert!(i < j && j < 10);
            assert!(seen.insert((i, j)));
        }
        assert_eq!(seen.len() as u64, a.pairs());
    }

    #[test]
    fn pair_tasks_counted() {
        let rt = Runtime::paper_testbed();
        let mut w = Alignment::with_params(12, 64);
        let pairs = w.pairs();
        let s = rt.run(&mut w, Policy::WorkFirst, BindPolicy::Linear, 4, 1, None).unwrap();
        // split tree + pair leaves; at least `pairs` tasks ran
        assert!(s.tasks > pairs);
    }

    #[test]
    fn embarrassingly_parallel_scales() {
        let rt = Runtime::paper_testbed();
        let mut ws = Alignment::new(Size::Small);
        let serial = rt.run_serial(&mut ws, 1).unwrap();
        let mut wp = Alignment::new(Size::Small);
        let par = rt.run(&mut wp, Policy::WorkFirst, BindPolicy::Linear, 16, 1, None).unwrap();
        let sp = serial.makespan as f64 / par.makespan as f64;
        assert!(sp > 8.0, "alignment speedup {sp} too low for all-pairs");
    }
}
