//! `fft` — cache-oblivious Cooley-Tukey FFT (BOTS `fft.c`).
//!
//! The paper's stress case (Figs 7, 13): ~10–19M tasks and 6–13 GB on the
//! real machine; scaled here to preserve (a) the footprint : node-capacity
//! ratio (large ≈ 48 MB over 8×16 MB nodes ≈ the paper's 13 GB / 8×4 GB)
//! and (b) the microsecond task granularity that saturates the
//! breadth-first shared queue.
//!
//! Decomposition (recursive radix-2, one buffer + a twiddle table):
//!
//! * `Split(off, n)` — pre: spawn the two half transforms, taskwait;
//!   post: spawn `n/chunk` `Combine` butterfly tasks over the range
//!   (post-phase spawning, the `WaitingFinal` path in the engine).
//! * `Leaf(off, n)`  — in-place base transform: read+write its segment,
//!   `5·n·log2(n)` compute units.  Carries `Action::Kernel(FFT_LEAF)` so
//!   PJRT mode can run the real `fft_f32_1024` artifact.
//! * `Combine(off, n, i)` — butterfly chunk: reads its slice of both
//!   halves *and the master-allocated twiddle table* (the NUMA hotspot:
//!   first-touch places it on the master's node), writes both slices.

use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::runtime::{Buf, ExecEngine};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

const K_SPLIT: u16 = 0;
const K_LEAF: u16 = 1;
const K_COMBINE: u16 = 2;

/// Kernel tag: transform one leaf segment for real through PJRT.
pub const FFT_LEAF_KERNEL: u64 = 1;

/// Bytes per complex element (two f32 planes).
const ELEM: u64 = 8;

pub struct Fft {
    /// Total elements (power of two).
    n: u64,
    /// Serial base-case size.
    leaf: u64,
    /// Butterfly chunk per combine task.
    chunk: u64,
    data: Region,
    twiddle: Region,
    /// PJRT mode: one real leaf signal (leaf elements, re/im planes).
    real_in: Vec<f32>,
    real_out: Option<(Vec<f32>, Vec<f32>)>,
}

impl Fft {
    pub fn new(size: Size) -> Self {
        // medium/large footprints exceed one node's capacity (16 MiB at
        // simulator scale) as the paper's 6/13 GB exceed one 4 GB node
        let (n, leaf, chunk) = match size {
            Size::Small => (1 << 14, 1 << 9, 1 << 9),
            Size::Medium => (1 << 21, 1 << 9, 1 << 9),
            Size::Large | Size::XL => (1 << 22, 1 << 10, 1 << 10),
        };
        Self::with_params(n, leaf, chunk)
    }

    pub fn with_params(n: u64, leaf: u64, chunk: u64) -> Self {
        assert!(n.is_power_of_two() && leaf.is_power_of_two());
        assert!(leaf <= n && chunk <= leaf);
        Self {
            n,
            leaf,
            chunk,
            data: Region::EMPTY,
            twiddle: Region::EMPTY,
            real_in: Vec::new(),
            real_out: None,
        }
    }

    fn log2(x: u64) -> u64 {
        63 - x.leading_zeros() as u64
    }
}

impl Workload for Fft {
    fn name(&self) -> &'static str {
        "fft"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.data = mem.alloc(self.n * ELEM);
        self.twiddle = mem.alloc(self.n / 2 * ELEM);
        // master generates the input signal and twiddle factors:
        // first-touch places everything relative to the master's node.
        let mut t = mem.first_touch(master_core, self.data, 0);
        t += mem.first_touch(master_core, self.twiddle, t);
        // deterministic real signal for PJRT verification
        let leaf = self.leaf.min(4096) as usize;
        self.real_in = (0..leaf).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 - 0.5).collect();
        t
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(K_SPLIT, [0, self.n as i64, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        let off = desc.args[0] as u64;
        let n = desc.args[1] as u64;
        match desc.kind {
            K_SPLIT => {
                if n <= self.leaf {
                    // degenerate split (small sizes): run the leaf inline
                    leaf_actions(self, off, n, ctx);
                    return;
                }
                let h = n / 2;
                // affinity: each half transform touches exactly its half
                ctx.spawn_on(
                    TaskDesc::new(
                        if h <= self.leaf { K_LEAF } else { K_SPLIT },
                        [off as i64, h as i64, 0, 0],
                    ),
                    self.data.slice(off * ELEM, h * ELEM),
                );
                ctx.spawn_on(
                    TaskDesc::new(
                        if h <= self.leaf { K_LEAF } else { K_SPLIT },
                        [(off + h) as i64, h as i64, 0, 0],
                    ),
                    self.data.slice((off + h) * ELEM, h * ELEM),
                );
                ctx.taskwait();
                // combine phase: butterflies over the whole range, chunked;
                // chunk i reads/writes its low-half slice (and the mirrored
                // high-half slice at the same home, touched by the same task)
                let chunks = (h / self.chunk).max(1);
                let c = h / chunks;
                for i in 0..chunks {
                    ctx.spawn_on(
                        TaskDesc::new(K_COMBINE, [off as i64, n as i64, i as i64, 0]),
                        self.data.slice((off + i * c) * ELEM, c * ELEM),
                    );
                }
            }
            K_LEAF => leaf_actions(self, off, n, ctx),
            K_COMBINE => {
                let h = n / 2;
                let chunks = (h / self.chunk).max(1);
                let c = h / chunks;
                let i = desc.args[2] as u64;
                let lo = self.data.slice((off + i * c) * ELEM, c * ELEM);
                let hi = self.data.slice((off + h + i * c) * ELEM, c * ELEM);
                // twiddle stride mirrors the radix-2 pattern: slice of W
                let w = self.twiddle.slice(i * c * ELEM / 2, c * ELEM / 2);
                ctx.read(lo);
                ctx.read(hi);
                ctx.read(w);
                ctx.compute(4 * c);
                ctx.write(lo);
                ctx.write(hi);
            }
            k => panic!("fft: unknown task kind {k}"),
        }
    }

    fn run_kernel(&mut self, tag: u64, exec: &mut ExecEngine) -> anyhow::Result<()> {
        if tag != FFT_LEAF_KERNEL || self.real_out.is_some() {
            return Ok(()); // transform one representative leaf only
        }
        let n = self.real_in.len();
        let artifact = match n {
            1024 => "fft_f32_1024",
            4096 => "fft_f32_4096",
            _ => return Ok(()),
        };
        let re = Buf::f32(self.real_in.clone(), &[n as i64]);
        let im = Buf::f32(vec![0.0; n], &[n as i64]);
        let out = exec.call(artifact, &[re, im])?;
        anyhow::ensure!(out.len() == 2, "fft artifact must return two planes");
        self.real_out = Some((out[0].clone(), out[1].clone()));
        Ok(())
    }

    fn verify(&self, _exec: &mut ExecEngine) -> anyhow::Result<()> {
        let Some((got_re, got_im)) = &self.real_out else {
            anyhow::bail!("fft: no kernel output captured");
        };
        // O(n^2) reference DFT in f64
        let n = self.real_in.len();
        let mut max_err = 0f64;
        let mut max_mag = 1f64;
        for k in 0..n {
            let (mut sr, mut si) = (0f64, 0f64);
            for (j, &x) in self.real_in.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k as f64) * (j as f64) / n as f64;
                sr += x as f64 * ang.cos();
                si += x as f64 * ang.sin();
            }
            max_mag = max_mag.max(sr.hypot(si));
            let er = (got_re[k] as f64 - sr).abs();
            let ei = (got_im[k] as f64 - si).abs();
            max_err = max_err.max(er.max(ei));
        }
        anyhow::ensure!(
            max_err / max_mag < 1e-4,
            "fft kernel mismatch: rel err {}",
            max_err / max_mag
        );
        Ok(())
    }
}

fn leaf_actions(fft: &Fft, off: u64, n: u64, ctx: &mut BodyCtx) {
    let seg = fft.data.slice(off * ELEM, n * ELEM);
    ctx.read(seg);
    ctx.kernel(FFT_LEAF_KERNEL);
    ctx.compute(3 * n * Fft::log2(n));
    ctx.write(seg);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    fn expected_tasks(n: u64, leaf: u64, chunk: u64) -> u64 {
        // splits with post-combines + leaves
        fn rec(n: u64, leaf: u64, chunk: u64) -> u64 {
            if n <= leaf {
                return 1;
            }
            let h = n / 2;
            let combines = (h / chunk).max(1);
            1 + combines + 2 * rec(h, leaf, chunk) - 1
            // -1: the task itself counted by caller; adjust below
        }
        // simpler: count recursively
        fn count(n: u64, leaf: u64, chunk: u64) -> u64 {
            if n <= leaf {
                1
            } else {
                let h = n / 2;
                1 + (h / chunk).max(1) + 2 * count(h, leaf, chunk)
            }
        }
        let _ = rec;
        count(n, leaf, chunk)
    }

    #[test]
    fn task_count_matches_formula() {
        let rt = Runtime::paper_testbed();
        let mut w = Fft::with_params(1 << 12, 1 << 9, 1 << 8);
        let s = rt.run(&mut w, Policy::WorkFirst, BindPolicy::Linear, 4, 1, None).unwrap();
        assert_eq!(s.tasks, expected_tasks(1 << 12, 1 << 9, 1 << 8));
    }

    #[test]
    fn all_policies_complete_small() {
        let rt = Runtime::paper_testbed();
        let mut baseline = None;
        for &p in Policy::all() {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let mut w = Fft::new(Size::Small);
            let s = rt.run(&mut w, p, BindPolicy::Linear, threads, 7, None).unwrap();
            match &baseline {
                None => baseline = Some(s.tasks),
                Some(t) => assert_eq!(s.tasks, *t, "{}", p.name()),
            }
        }
    }

    #[test]
    fn memory_traffic_dominated_by_data() {
        let rt = Runtime::paper_testbed();
        let mut w = Fft::new(Size::Small);
        let s = rt.run_serial(&mut w, 1).unwrap();
        // every level touches ~n elements; bytes >= n*8*levels
        assert!(s.mem.bytes_touched > (1 << 14) * 8);
    }

    #[test]
    fn depth_first_beats_bf_at_scale() {
        // the Fig-7 ordering at 16 threads (small input, same direction)
        let rt = Runtime::paper_testbed();
        // enough fine-grained tasks to pressure the shared queue
        let mut wf = Fft::with_params(1 << 18, 1 << 9, 1 << 9);
        let swf = rt.run(&mut wf, Policy::WorkFirst, BindPolicy::Linear, 16, 5, None).unwrap();
        let mut bf = Fft::with_params(1 << 18, 1 << 9, 1 << 9);
        let sbf = rt.run(&mut bf, Policy::BreadthFirst, BindPolicy::Linear, 16, 5, None).unwrap();
        assert!(
            swf.makespan < sbf.makespan,
            "wf {} should beat bf {}",
            swf.makespan,
            sbf.makespan
        );
    }
}
