//! `nqueens` — N-Queens solution counting (BOTS `nqueens.c`).
//!
//! Near-zero data, a clean search tree with uniform node costs — the
//! benchmark where plain breadth-first wins on load balance (paper Fig 10:
//! 15.93x at 16 cores, NUMA extensions worth only ~1.35%).
//!
//! Tasks spawn per valid queen placement down to `cutoff` rows; below it
//! the subtree is solved serially inside the task, with the compute charge
//! equal to the *actual* visited-node count (the module carries a real
//! bitmask solver — this benchmark genuinely solves N-Queens).

use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

/// compute units charged per visited search node.
const UNITS_PER_NODE: u64 = 30;

pub struct NQueens {
    n: u32,
    cutoff: u32,
    board: Region,
}

impl NQueens {
    pub fn new(size: Size) -> Self {
        let (n, cutoff) = match size {
            Size::Small => (10, 3),
            Size::Medium => (12, 3),
            Size::Large | Size::XL => (13, 4),
        };
        Self::with_params(n, cutoff)
    }

    pub fn with_params(n: u32, cutoff: u32) -> Self {
        assert!(n <= 16 && cutoff < n);
        Self { n, cutoff, board: Region::EMPTY }
    }
}

/// Count solutions and visited nodes below a partial placement.
/// Bitmask depth-first search (LSB = column 0).
pub fn solve(n: u32, cols: u32, d1: u32, d2: u32, row: u32) -> (u64, u64) {
    if row == n {
        return (1, 1);
    }
    let full = (1u32 << n) - 1;
    let mut free = full & !(cols | d1 | d2);
    let mut solutions = 0;
    let mut nodes = 1;
    while free != 0 {
        let bit = free & free.wrapping_neg();
        free ^= bit;
        let (s, v) = solve(n, cols | bit, (d1 | bit) << 1, (d2 | bit) >> 1, row + 1);
        solutions += s;
        nodes += v;
    }
    (solutions, nodes)
}

impl Workload for NQueens {
    fn name(&self) -> &'static str {
        "nqueens"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        // a single shared config page (board size etc.)
        self.board = mem.alloc(256);
        mem.first_touch(master_core, self.board, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(0, [0, 0, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        let cols = desc.args[0] as u32;
        let d1 = desc.args[1] as u32;
        let d2 = desc.args[2] as u32;
        let row = desc.args[3] as u32;
        ctx.read(self.board);
        if row == self.cutoff {
            let (_, nodes) = solve(self.n, cols, d1, d2, row);
            ctx.compute(nodes * UNITS_PER_NODE);
            return;
        }
        let full = (1u32 << self.n) - 1;
        let mut free = full & !(cols | d1 | d2);
        ctx.compute(UNITS_PER_NODE);
        while free != 0 {
            let bit = free & free.wrapping_neg();
            free ^= bit;
            // affinity: all any subtree touches is the shared config page —
            // a deliberately tiny hint that placement strategies should
            // ignore (numa-home's min_kb floor), since funnelling the whole
            // search tree onto the board's node would serialize it
            ctx.spawn_on(
                TaskDesc::new(
                    0,
                    [
                        (cols | bit) as i64,
                        ((d1 | bit) << 1) as i64,
                        ((d2 | bit) >> 1) as i64,
                        (row + 1) as i64,
                    ],
                ),
                self.board,
            );
        }
        ctx.taskwait();
        ctx.compute(UNITS_PER_NODE); // reduce the counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn solver_is_correct() {
        // classic N-Queens solution counts
        assert_eq!(solve(4, 0, 0, 0, 0).0, 2);
        assert_eq!(solve(6, 0, 0, 0, 0).0, 4);
        assert_eq!(solve(8, 0, 0, 0, 0).0, 92);
        assert_eq!(solve(10, 0, 0, 0, 0).0, 724);
    }

    #[test]
    fn work_is_policy_invariant() {
        let rt = Runtime::paper_testbed();
        let mut works = Vec::new();
        for &p in &[Policy::Serial, Policy::BreadthFirst, Policy::Dfwsrpt] {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let mut w = NQueens::with_params(9, 2);
            let s = rt.run(&mut w, p, BindPolicy::Linear, threads, 1, None).unwrap();
            works.push(s.work_time);
        }
        // memory costs vary with placement; compute dominates here, so
        // totals should be within a few percent
        let base = works[0] as f64;
        for w in &works[1..] {
            assert!((*w as f64 - base).abs() / base < 0.05);
        }
    }

    #[test]
    fn task_tree_matches_prefix_counts() {
        // tasks = partial placements up to cutoff depth (+ root)
        fn prefix_nodes(n: u32, cutoff: u32, cols: u32, d1: u32, d2: u32, row: u32) -> u64 {
            if row == cutoff {
                return 1;
            }
            let full = (1u32 << n) - 1;
            let mut free = full & !(cols | d1 | d2);
            let mut total = 1;
            while free != 0 {
                let bit = free & free.wrapping_neg();
                free ^= bit;
                total +=
                    prefix_nodes(n, cutoff, cols | bit, (d1 | bit) << 1, (d2 | bit) >> 1, row + 1);
            }
            total
        }
        let rt = Runtime::paper_testbed();
        let mut w = NQueens::with_params(8, 2);
        let s = rt.run(&mut w, Policy::WorkFirst, BindPolicy::Linear, 4, 1, None).unwrap();
        assert_eq!(s.tasks, prefix_nodes(8, 2, 0, 0, 0, 0));
    }

    #[test]
    fn bf_scales_well_here() {
        let rt = Runtime::paper_testbed();
        let mut ws = NQueens::new(Size::Small);
        let serial = rt.run_serial(&mut ws, 1).unwrap();
        let mut wb = NQueens::new(Size::Small);
        let bf = rt.run(&mut wb, Policy::BreadthFirst, BindPolicy::Linear, 16, 1, None).unwrap();
        let sp = serial.makespan as f64 / bf.makespan as f64;
        // the Small tree has only ~600 tasks; Fig 10 scaling happens at
        // Medium (checked by the fig10 bench)
        assert!(sp > 2.0, "nqueens bf speedup {sp} too low");
    }
}
