//! `sparselu` — blocked LU factorization of a sparse block matrix
//! (BOTS `sparselu.c`), in both task-generation variants the paper runs:
//!
//! * **single** (`sparselu_single`): one generator — the master spawns all
//!   of a phase's tasks itself (`#pragma omp single` + tasks).  All tasks
//!   start life in one pool, so everything the other 15 threads run is
//!   *stolen* — maximal steal traffic.
//! * **for** (`sparselu_for`, Fig 6): generation is itself parallelized —
//!   phases fan out through binary `Split` tasks (the `#pragma omp for`
//!   analogue), so tasks are born distributed.
//!
//! Per step `k`: `lu0(k,k)` (inline, as BOTS does) → `fwd(k,j)` / `bdiv(i,k)`
//! over non-null blocks → taskwait → `bmod(i,j,k)` trailing updates →
//! next step.  The phase chain is expressed with nested tasks
//! (`Step(k)` → post spawns `BmodPhase(k)` → post spawns `Step(k+1)`).
//!
//! Sparsity: a deterministic ~50%-density pattern with full diagonal;
//! fill-in is precomputed in `init` by propagating the update closure.
//! Initial blocks are master-touched (first-touch on the master's node);
//! **fill-in blocks are first touched by the worker that computes them** —
//! the same NUMA dynamic as Strassen's temps.
//!
//! PJRT mode drives the *real* factorization — every lu0/fwd/bdiv/bmod
//! task calls its 64x64 Pallas-kernel artifact on live block data, and
//! `verify()` checks `L @ U ≈ A` afterwards.  The simulated scheduler
//! orders the real math (small sizes only; see `examples/e2e_compute.rs`).

use std::collections::HashMap;

use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::runtime::{Buf, ExecEngine};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

const K_STEP: u16 = 0;
const K_BMOD_PHASE: u16 = 1;
const K_FWD: u16 = 2;
const K_BDIV: u16 = 3;
const K_BMOD: u16 = 4;
/// Binary splitter for the `for` variant: args = [kind, k, lo, hi] packed.
const K_SPLIT_FWD_BDIV: u16 = 5;
const K_SPLIT_BMOD: u16 = 6;

/// Block edge (BOTS default submatrix size).
const B: u64 = 64;
/// f32 block bytes.
const BLOCK_BYTES: u64 = B * B * 4;

/// compute units (~ns) per block op at ~4 flop/ns
const LU0_UNITS: u64 = 2 * B * B * B / 3 / 4;
const TRSM_UNITS: u64 = B * B * B / 4;
const BMOD_UNITS: u64 = 2 * B * B * B / 4;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Single,
    For,
}

pub struct SparseLu {
    nb: usize,
    variant: Variant,
    /// non-null pattern after symbolic fill-in
    filled: Vec<bool>,
    /// initially non-null (master-touched at init)
    initial: Vec<bool>,
    blocks: Vec<Region>,
    /// PJRT mode: live block data + original matrix copy
    real: HashMap<(usize, usize), Vec<f32>>,
    real_orig: HashMap<(usize, usize), Vec<f32>>,
    real_enabled: bool,
}

impl SparseLu {
    pub fn new(size: Size, variant: Variant) -> Self {
        let nb = match size {
            Size::Small => 8,
            Size::Medium => 24,
            Size::Large | Size::XL => 32,
        };
        Self::with_params(nb, variant)
    }

    pub fn with_params(nb: usize, variant: Variant) -> Self {
        let initial = gen_pattern(nb);
        let filled = symbolic_fill(nb, &initial);
        Self {
            nb,
            variant,
            filled,
            initial,
            blocks: Vec::new(),
            real: HashMap::new(),
            real_orig: HashMap::new(),
            real_enabled: false,
        }
    }

    #[inline]
    fn idx(&self, i: usize, j: usize) -> usize {
        i * self.nb + j
    }

    fn nonnull(&self, i: usize, j: usize) -> bool {
        self.filled[self.idx(i, j)]
    }

    fn block(&self, i: usize, j: usize) -> Region {
        self.blocks[self.idx(i, j)]
    }

    /// Generate the real f32 blocks (PJRT mode), diagonally dominant.
    /// Only worthwhile at sizes where driving every block op through the
    /// interpret-mode artifacts stays fast.
    fn gen_real(&mut self) {
        if self.nb > 12 {
            return; // sim-only at benchmark scale
        }
        for i in 0..self.nb {
            for j in 0..self.nb {
                if !self.initial[self.idx(i, j)] {
                    continue;
                }
                let mut blk: Vec<f32> = (0..B * B)
                    .map(|e| {
                        let h = crate::bots::mix(e + 1, (i * self.nb + j) as u64 + 7);
                        (h % 1000) as f32 / 1000.0 - 0.5
                    })
                    .collect();
                if i == j {
                    for d in 0..B as usize {
                        blk[d * B as usize + d] += 2.0 * B as f32;
                    }
                }
                self.real.insert((i, j), blk.clone());
                self.real_orig.insert((i, j), blk);
            }
        }
        self.real_enabled = true;
    }

    fn tag(op: u64, i: usize, j: usize, k: usize) -> u64 {
        op | (i as u64) << 8 | (j as u64) << 24 | (k as u64) << 40
    }
}

/// BOTS-like initial sparsity: full diagonal + ~50% off-diagonal density,
/// deterministic in (i, j).
fn gen_pattern(nb: usize) -> Vec<bool> {
    let mut p = vec![false; nb * nb];
    for i in 0..nb {
        for j in 0..nb {
            p[i * nb + j] =
                i == j || crate::bots::mix(i as u64 + 1, j as u64 + 13) % 100 < 50;
        }
    }
    p
}

/// Propagate fill-in: (i,j) fills if (i,k) and (k,j) are non-null, k < min(i,j).
fn symbolic_fill(nb: usize, initial: &[bool]) -> Vec<bool> {
    let mut f = initial.to_vec();
    for k in 0..nb {
        for i in (k + 1)..nb {
            if !f[i * nb + k] {
                continue;
            }
            for j in (k + 1)..nb {
                if f[k * nb + j] {
                    f[i * nb + j] = true;
                }
            }
        }
    }
    f
}

impl Workload for SparseLu {
    fn name(&self) -> &'static str {
        match self.variant {
            Variant::Single => "sparselu_single",
            Variant::For => "sparselu_for",
        }
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        self.blocks = (0..self.nb * self.nb)
            .map(|idx| if self.filled[idx] { mem.alloc(BLOCK_BYTES) } else { Region::EMPTY })
            .collect();
        // master generates the initial matrix: first-touch of initial blocks
        let mut t = 0;
        for i in 0..self.nb {
            for j in 0..self.nb {
                if self.initial[self.idx(i, j)] {
                    t += mem.first_touch(master_core, self.block(i, j), t);
                }
            }
        }
        self.gen_real();
        t
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(K_STEP, [0, 0, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        let nb = self.nb;
        match desc.kind {
            K_STEP => {
                let k = desc.args[0] as usize;
                // lu0 inline (as the BOTS generator thread does)
                let diag = self.block(k, k);
                ctx.read(diag);
                ctx.kernel(Self::tag(1, k, k, k));
                ctx.compute(LU0_UNITS);
                ctx.write(diag);
                match self.variant {
                    Variant::Single => {
                        // affinity: each task updates one block in place
                        for j in (k + 1)..nb {
                            if self.nonnull(k, j) {
                                ctx.spawn_on(
                                    TaskDesc::new(K_FWD, [k as i64, j as i64, 0, 0]),
                                    self.block(k, j),
                                );
                            }
                        }
                        for i in (k + 1)..nb {
                            if self.nonnull(i, k) {
                                ctx.spawn_on(
                                    TaskDesc::new(K_BDIV, [i as i64, k as i64, 0, 0]),
                                    self.block(i, k),
                                );
                            }
                        }
                    }
                    Variant::For => {
                        if k + 1 < nb {
                            ctx.spawn(TaskDesc::new(
                                K_SPLIT_FWD_BDIV,
                                [k as i64, (k + 1) as i64, nb as i64, 0],
                            ));
                        }
                    }
                }
                ctx.taskwait();
                // the phase task only spawns; its children carry their own
                // block affinities
                ctx.spawn(TaskDesc::new(K_BMOD_PHASE, [k as i64, 0, 0, 0]));
            }
            K_BMOD_PHASE => {
                let k = desc.args[0] as usize;
                match self.variant {
                    Variant::Single => {
                        for i in (k + 1)..nb {
                            if !self.nonnull(i, k) {
                                continue;
                            }
                            for j in (k + 1)..nb {
                                if self.nonnull(k, j) {
                                    ctx.spawn_on(
                                        TaskDesc::new(K_BMOD, [i as i64, j as i64, k as i64, 0]),
                                        self.block(i, j),
                                    );
                                }
                            }
                        }
                    }
                    Variant::For => {
                        if k + 1 < nb {
                            ctx.spawn(TaskDesc::new(
                                K_SPLIT_BMOD,
                                [k as i64, (k + 1) as i64, nb as i64, 0],
                            ));
                        }
                    }
                }
                ctx.taskwait();
                if k + 1 < nb {
                    // the next step factors its diagonal block inline
                    ctx.spawn_on(
                        TaskDesc::new(K_STEP, [(k + 1) as i64, 0, 0, 0]),
                        self.block(k + 1, k + 1),
                    );
                }
            }
            K_SPLIT_FWD_BDIV | K_SPLIT_BMOD => {
                let k = desc.args[0] as usize;
                let lo = desc.args[1] as usize;
                let hi = desc.args[2] as usize;
                ctx.compute(50); // chunking logic
                if hi - lo > 2 {
                    let mid = (lo + hi) / 2;
                    ctx.spawn(TaskDesc::new(desc.kind, [k as i64, lo as i64, mid as i64, 0]));
                    ctx.spawn(TaskDesc::new(desc.kind, [k as i64, mid as i64, hi as i64, 0]));
                    return;
                }
                for x in lo..hi {
                    if desc.kind == K_SPLIT_FWD_BDIV {
                        if self.nonnull(k, x) {
                            ctx.spawn_on(
                                TaskDesc::new(K_FWD, [k as i64, x as i64, 0, 0]),
                                self.block(k, x),
                            );
                        }
                        if self.nonnull(x, k) {
                            ctx.spawn_on(
                                TaskDesc::new(K_BDIV, [x as i64, k as i64, 0, 0]),
                                self.block(x, k),
                            );
                        }
                    } else {
                        // bmod row x
                        if !self.nonnull(x, k) {
                            continue;
                        }
                        for j in (k + 1)..nb {
                            if self.nonnull(k, j) {
                                ctx.spawn_on(
                                    TaskDesc::new(K_BMOD, [x as i64, j as i64, k as i64, 0]),
                                    self.block(x, j),
                                );
                            }
                        }
                    }
                }
            }
            K_FWD => {
                let k = desc.args[0] as usize;
                let j = desc.args[1] as usize;
                ctx.read(self.block(k, k));
                ctx.read(self.block(k, j));
                ctx.kernel(Self::tag(2, k, j, k));
                ctx.compute(TRSM_UNITS);
                ctx.write(self.block(k, j));
            }
            K_BDIV => {
                let i = desc.args[0] as usize;
                let k = desc.args[1] as usize;
                ctx.read(self.block(k, k));
                ctx.read(self.block(i, k));
                ctx.kernel(Self::tag(3, i, k, k));
                ctx.compute(TRSM_UNITS);
                ctx.write(self.block(i, k));
            }
            K_BMOD => {
                let i = desc.args[0] as usize;
                let j = desc.args[1] as usize;
                let k = desc.args[2] as usize;
                ctx.read(self.block(i, k));
                ctx.read(self.block(k, j));
                ctx.read(self.block(i, j));
                ctx.kernel(Self::tag(4, i, j, k));
                ctx.compute(BMOD_UNITS);
                // fill-in blocks get their first touch HERE, by the
                // executing worker — worker-local placement
                ctx.write(self.block(i, j));
            }
            other => panic!("sparselu: unknown task kind {other}"),
        }
    }

    fn run_kernel(&mut self, tag: u64, exec: &mut ExecEngine) -> anyhow::Result<()> {
        if !self.real_enabled {
            return Ok(());
        }
        let op = tag & 0xff;
        let i = ((tag >> 8) & 0xffff) as usize;
        let j = ((tag >> 24) & 0xffff) as usize;
        let k = ((tag >> 40) & 0xffff) as usize;
        let shape = [B as i64, B as i64];
        let get = |m: &HashMap<(usize, usize), Vec<f32>>, key: (usize, usize)| -> Vec<f32> {
            m.get(&key).cloned().unwrap_or_else(|| vec![0f32; (B * B) as usize])
        };
        match op {
            1 => {
                let d = get(&self.real, (k, k));
                let out = exec.call1("lu0_f32_64", &[Buf::f32(d, &shape)])?;
                self.real.insert((k, k), out);
            }
            2 => {
                let d = get(&self.real, (k, k));
                let b = get(&self.real, (k, j));
                let out =
                    exec.call1("fwd_f32_64", &[Buf::f32(d, &shape), Buf::f32(b, &shape)])?;
                self.real.insert((k, j), out);
            }
            3 => {
                let d = get(&self.real, (k, k));
                let b = get(&self.real, (i, k));
                let out =
                    exec.call1("bdiv_f32_64", &[Buf::f32(d, &shape), Buf::f32(b, &shape)])?;
                self.real.insert((i, k), out);
            }
            4 => {
                let a = get(&self.real, (i, k));
                let b = get(&self.real, (k, j));
                let c = get(&self.real, (i, j));
                let out = exec.call1(
                    "bmod_f32_64",
                    &[Buf::f32(a, &shape), Buf::f32(b, &shape), Buf::f32(c, &shape)],
                )?;
                self.real.insert((i, j), out);
            }
            _ => anyhow::bail!("sparselu: bad kernel tag {tag:#x}"),
        }
        Ok(())
    }

    fn verify(&self, _exec: &mut ExecEngine) -> anyhow::Result<()> {
        // L @ U must reconstruct the original matrix on the filled pattern.
        anyhow::ensure!(self.real_enabled, "sparselu: real mode not enabled");
        let nb = self.nb;
        let n = B as usize;
        let zero = vec![0f32; n * n];
        let mut max_rel = 0f64;
        for bi in 0..nb {
            for bj in 0..nb {
                // (L @ U)[bi][bj] = sum_k L[bi][k] @ U[k][bj]
                let mut acc = vec![0f64; n * n];
                for bk in 0..=bi.min(bj) {
                    let lb = self.real.get(&(bi, bk)).unwrap_or(&zero);
                    let ub = self.real.get(&(bk, bj)).unwrap_or(&zero);
                    for r in 0..n {
                        for k in 0..n {
                            let l = if bi == bk {
                                // unit-lower packed block
                                match r.cmp(&k) {
                                    std::cmp::Ordering::Less => 0.0,
                                    std::cmp::Ordering::Equal => 1.0,
                                    std::cmp::Ordering::Greater => lb[r * n + k] as f64,
                                }
                            } else {
                                lb[r * n + k] as f64
                            };
                            if l == 0.0 {
                                continue;
                            }
                            for c in 0..n {
                                let u = if bk == bj {
                                    if k <= c { ub[k * n + c] as f64 } else { 0.0 }
                                } else {
                                    ub[k * n + c] as f64
                                };
                                acc[r * n + c] += l * u;
                            }
                        }
                    }
                }
                let orig = self.real_orig.get(&(bi, bj)).unwrap_or(&zero);
                for e in 0..n * n {
                    let err = (acc[e] - orig[e] as f64).abs();
                    max_rel = max_rel.max(err / (2.0 * B as f64));
                }
            }
        }
        anyhow::ensure!(max_rel < 1e-3, "sparselu L@U residual too large: {max_rel}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn pattern_has_full_diagonal_and_fill_monotone() {
        let nb = 12;
        let initial = gen_pattern(nb);
        let filled = symbolic_fill(nb, &initial);
        for i in 0..nb {
            assert!(initial[i * nb + i]);
        }
        for (a, b) in initial.iter().zip(&filled) {
            assert!(!a || *b, "fill-in must be a superset");
        }
        assert!(filled.iter().filter(|&&x| x).count() > initial.iter().filter(|&&x| x).count());
    }

    #[test]
    fn both_variants_complete_with_same_work() {
        let rt = Runtime::paper_testbed();
        let mut single = SparseLu::with_params(8, Variant::Single);
        let s1 = rt.run(&mut single, Policy::WorkFirst, BindPolicy::Linear, 8, 1, None).unwrap();
        let mut forv = SparseLu::with_params(8, Variant::For);
        let s2 = rt.run(&mut forv, Policy::WorkFirst, BindPolicy::Linear, 8, 1, None).unwrap();
        // identical numeric work (split tasks add only tiny chunking cost)
        let (w1, w2) = (s1.work_time as f64, s2.work_time as f64);
        assert!((w1 - w2).abs() / w1 < 0.02, "{w1} vs {w2}");
        // the for variant spreads generation => at least as many tasks
        assert!(s2.tasks >= s1.tasks);
    }

    #[test]
    fn single_variant_steals_more() {
        // all single-variant tasks are born in one pool: everyone else steals
        let rt = Runtime::paper_testbed();
        let mut single = SparseLu::with_params(10, Variant::Single);
        let s1 = rt.run(&mut single, Policy::WorkFirst, BindPolicy::Linear, 8, 3, None).unwrap();
        let mut forv = SparseLu::with_params(10, Variant::For);
        let s2 = rt.run(&mut forv, Policy::WorkFirst, BindPolicy::Linear, 8, 3, None).unwrap();
        assert!(
            s1.steals > s2.steals / 2,
            "single {} vs for {}",
            s1.steals,
            s2.steals
        );
    }

    #[test]
    fn completes_under_every_policy() {
        let rt = Runtime::paper_testbed();
        for &p in Policy::all() {
            let threads = if p == Policy::Serial { 1 } else { 6 };
            let mut w = SparseLu::with_params(6, Variant::For);
            let s = rt.run(&mut w, p, BindPolicy::Linear, threads, 5, None).unwrap();
            assert!(s.tasks > 6, "{}", p.name());
        }
    }
}
